//! Asynchronous coordination: bounded-staleness Bi-cADMM under a straggler.
//!
//! Runs the same sparse regression twice with an injected 10x-slow node:
//! once under the full barrier (`quorum = 1.0`, `staleness = 0` — exactly
//! the paper's synchronous Algorithm 1) and once under the partial barrier
//! (`quorum = 0.5`, `staleness = 2`).  Prints the wall-clock, the
//! coordination stats (staleness histogram, per-node participation,
//! resyncs), and the byte ledger with resync traffic broken out.
//!
//!     cargo run --release --example async_coordination

use psfit::config::{Config, CoordinationKind};
use psfit::coordinator::FaultSpec;
use psfit::data::SyntheticSpec;
use psfit::driver;
use psfit::sparsity::support_f1;

fn main() -> anyhow::Result<()> {
    let nodes = 4;
    let mut spec = SyntheticSpec::regression(200, 3200, nodes);
    spec.sparsity_level = 0.8;
    spec.noise_std = 0.05;
    let ds = spec.generate();

    let mut cfg = Config::default();
    cfg.platform.nodes = nodes;
    cfg.solver.kappa = spec.kappa();
    cfg.solver.rho_c = 2.0;
    cfg.solver = cfg.solver.alpha(0.5);
    cfg.solver.max_iters = 60;
    cfg.solver.tol_primal = 0.0; // fixed horizon: compare equal round counts
    cfg.coordinator.coordination = CoordinationKind::Async;
    // node 0 sleeps an extra 20 ms per round — a 10x-class straggler at
    // this problem size
    cfg.coordinator.faults = FaultSpec::default().straggler(0, 20.0);

    for (label, quorum, staleness) in [("full barrier", 1.0, 0usize), ("partial barrier", 0.5, 2)] {
        cfg.coordinator.quorum = quorum;
        cfg.coordinator.max_staleness = staleness;
        let res = driver::fit(&ds, &cfg)?;
        let stats = res.coordination.expect("async run reports stats");
        println!("=== {label} (quorum {quorum}, staleness {staleness}) ===");
        println!(
            "wall: {:.3} s over {} rounds ({:.1} rounds/s)",
            res.wall_seconds,
            res.iters,
            res.iters as f64 / res.wall_seconds
        );
        println!(
            "support F1 {:.3}, final primal {:.2e}",
            support_f1(&res.support, &ds.support_true),
            res.trace.last().map(|r| r.primal).unwrap_or(f64::NAN)
        );
        println!("coordination: {}", stats.summary());
        println!(
            "network: {:.2} MB down + {:.2} MB resync, {:.2} MB up\n",
            res.transfers.net_down_bytes as f64 / 1e6,
            res.transfers.net_resync_bytes as f64 / 1e6,
            res.transfers.net_up_bytes as f64 / 1e6,
        );
    }
    println!("the partial barrier hides the straggler: same rounds, far less wall-clock.");
    Ok(())
}

//! End-to-end validation driver — proves all layers compose.
//!
//! Runs the FULL three-layer stack on a real small workload:
//!   L1/L2: AOT JAX+Pallas artifacts (`make artifacts`) executed via PJRT,
//!   L3:    the Rust coordinator (consensus + bilinear global updates,
//!          node workers, transfer + network ledgers).
//!
//! Workload: sparse linear regression, n = 2000 features over N = 4 nodes
//! x M = 2 device queues, 40k samples total, kappa = 400.  Reports the
//! residual curve, support-recovery F1, throughput, and the transfer
//! ledger; writes results/end_to_end_trace.csv.  The numbers quoted in
//! EXPERIMENTS.md §End-to-end come from this binary.
//!
//!     cargo run --release --example end_to_end [-- --pallas]
//!
//! `--pallas` switches to the interpret-mode Pallas artifact set
//! (artifacts-pallas/), proving the L1 kernels themselves execute through
//! PJRT end to end (slower; see DESIGN.md §Hardware-Adaptation).

use psfit::config::{BackendKind, Config};
use psfit::data::SyntheticSpec;
use psfit::harness;
use psfit::losses::Squared;
use psfit::sparsity::support_f1;

fn main() -> anyhow::Result<()> {
    let pallas = std::env::args().any(|a| a == "--pallas");
    if pallas {
        std::env::set_var("PSFIT_ARTIFACTS", "artifacts-pallas");
        eprintln!("using interpret-mode Pallas artifacts (artifacts-pallas/)");
    }

    let (n, m_total, nodes) = if pallas { (512, 8_000, 4) } else { (2000, 40_000, 4) };
    let mut spec = SyntheticSpec::regression(n, m_total, nodes);
    spec.sparsity_level = 0.8;
    spec.noise_std = 0.05;
    let kappa = spec.kappa();
    eprintln!("generating SLS workload: n={n}, m={m_total}, N={nodes}, kappa={kappa}");
    let dataset = spec.generate();

    let mut cfg = Config::default();
    cfg.platform.nodes = nodes;
    cfg.platform.devices_per_node = 2;
    cfg.platform.backend = BackendKind::Xla;
    cfg.solver.kappa = kappa;
    cfg.solver.rho_c = 2.0;
    cfg.solver = cfg.solver.alpha(0.5);
    cfg.solver.rho_l = 2.0;
    cfg.solver.max_iters = if pallas { 40 } else { 300 };

    let run = harness::run_timed(&dataset, &cfg, true)?;
    let res = &run.result;

    println!("=== end-to-end validation (three-layer stack) ===");
    println!("artifacts:        {}", if pallas { "pallas (interpret)" } else { "xla" });
    println!("setup (stage+compile): {:.2} s", run.setup_seconds);
    println!("solve:            {:.2} s ({} outer iterations, converged={})",
        run.solve_seconds, res.iters, res.converged);
    println!(
        "throughput:       {:.1} outer iters/s, {:.1} Msamples-touched/s",
        res.iters as f64 / run.solve_seconds,
        (res.iters * cfg.solver.inner_iters * m_total) as f64 / run.solve_seconds / 1e6
    );
    let first = &res.trace.records[0];
    let last = res.trace.last().unwrap();
    println!(
        "residuals:        primal {:.2e} -> {:.2e}, bilinear {:.2e} -> {:.2e}",
        first.primal, last.primal, first.bilinear, last.bilinear
    );
    let f1 = support_f1(&res.support, &dataset.support_true);
    println!("support recovery: F1 = {f1:.4} ({} / {})", res.support.len(), kappa);
    let obj = psfit::admm::solver::objective(&dataset, &Squared, cfg.solver.gamma, &res.x);
    println!("final objective:  {obj:.4}");
    println!(
        "transfers:        h2d {:.1} MB / d2h {:.1} MB, {:.3} s in copies",
        res.transfers.h2d_bytes as f64 / 1e6,
        res.transfers.d2h_bytes as f64 / 1e6,
        res.transfers.copy_seconds
    );
    println!(
        "network:          {:.2} MB up / {:.2} MB down over {} rounds",
        res.transfers.net_up_bytes as f64 / 1e6,
        res.transfers.net_down_bytes as f64 / 1e6,
        res.iters
    );

    std::fs::create_dir_all("results")?;
    let path = if pallas { "results/end_to_end_pallas_trace.csv" } else { "results/end_to_end_trace.csv" };
    std::fs::write(path, res.trace.to_csv())?;
    eprintln!("wrote {path}");

    anyhow::ensure!(res.converged, "did not converge");
    anyhow::ensure!(f1 > 0.9, "support recovery too weak: {f1}");
    println!("END-TO-END: OK");
    Ok(())
}

//! Federated sparse SVM (SSVM) across 8 nodes with non-IID shards.
//!
//! Demonstrates the FL-relevant property the paper emphasizes: raw data
//! (A_i, b_i) never leaves a node — only the coefficient-space iterates
//! (x_i, u_i) and the consensus z cross the wire.  The byte ledger printed
//! at the end is the entire communication footprint.
//!
//!     cargo run --release --example federated_svm

use psfit::config::Config;
use psfit::data::{SyntheticSpec, Task};
use psfit::driver;
use psfit::losses::LossKind;
use psfit::sparsity::support_f1;
use psfit::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let nodes = 8;
    let mut spec = SyntheticSpec::regression(400, 9600, nodes);
    spec.task = Task::Binary;
    spec.sparsity_level = 0.9;
    spec.noise_std = 0.2;
    let mut ds = spec.generate();

    // make the shards non-IID: give each node a biased subsample of one
    // class (a classic federated pathology)
    let mut rng = Rng::seed_from(7);
    for (i, shard) in ds.shards.iter_mut().enumerate() {
        let keep_label = if i % 2 == 0 { 1.0 } else { -1.0 };
        // flip 30% of the opposite-class labels toward the node's bias
        for l in shard.labels.iter_mut() {
            if *l != keep_label && rng.uniform() < 0.3 {
                *l = keep_label;
            }
        }
    }

    let mut cfg = Config::default();
    cfg.loss = LossKind::Hinge;
    cfg.platform.nodes = nodes;
    cfg.solver.kappa = spec.kappa();
    cfg.solver.rho_c = 1.0;
    cfg.solver.rho_b = 0.5;
    cfg.solver.max_iters = 120;

    println!("federated SSVM: {nodes} non-IID nodes, n=400, kappa={}", spec.kappa());
    let res = driver::fit(&ds, &cfg)?;

    println!(
        "converged: {} in {} iterations ({:.2} s)",
        res.converged, res.iters, res.wall_seconds
    );
    println!(
        "support F1 vs planted model: {:.3}",
        support_f1(&res.support, &ds.support_true)
    );

    // the complete communication footprint (no raw data!)
    let per_round = (nodes * 400 * 8) as f64 / 1e3; // z down, per round
    println!("\n--- communication ledger (the ONLY data that moved) ---");
    println!(
        "coordinator -> nodes: {:.2} MB total ({:.1} KB z-broadcast per round)",
        res.transfers.net_down_bytes as f64 / 1e6,
        per_round
    );
    println!(
        "nodes -> coordinator: {:.2} MB total (x_i + u_i per node per round)",
        res.transfers.net_up_bytes as f64 / 1e6
    );
    let raw_bytes: u64 = ds
        .shards
        .iter()
        .map(|s| (s.rows() * s.data.cols() + s.labels.len()) as u64 * 4)
        .sum();
    println!(
        "raw data kept on-node:  {:.2} MB (never transmitted)",
        raw_bytes as f64 / 1e6
    );
    Ok(())
}

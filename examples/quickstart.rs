//! Quickstart: fit a sparse linear regression (SLS, Eq. 24) with Bi-cADMM
//! on a synthetic distributed dataset and inspect the recovered support.
//!
//!     cargo run --release --example quickstart

use psfit::config::Config;
use psfit::data::SyntheticSpec;
use psfit::driver;
use psfit::sparsity::support_f1;

fn main() -> anyhow::Result<()> {
    // 1. a distributed dataset: 4 nodes, 8000 samples total, 1000 features,
    //    80% of the planted coefficients are zero (kappa = 200).
    let mut spec = SyntheticSpec::regression(1000, 8000, 4);
    spec.sparsity_level = 0.8;
    spec.noise_std = 0.05;
    let dataset = spec.generate();

    // 2. solver configuration (paper defaults: rho_b = alpha * rho_c).
    let mut cfg = Config::default();
    cfg.platform.nodes = dataset.nodes();
    cfg.solver.kappa = spec.kappa();
    cfg.solver.rho_c = 2.0;
    cfg.solver = cfg.solver.alpha(0.5);
    cfg.solver.max_iters = 150;

    // 3. fit.
    let result = driver::fit(&dataset, &cfg)?;

    // 4. inspect.
    println!(
        "converged: {} in {} iterations ({:.2} s)",
        result.converged, result.iters, result.wall_seconds
    );
    let last = result.trace.last().unwrap();
    println!(
        "residuals: primal {:.2e}, dual {:.2e}, bilinear {:.2e}",
        last.primal, last.dual, last.bilinear
    );
    println!(
        "recovered {} of {} true coefficients (F1 = {:.3})",
        result.support.len(),
        dataset.support_true.len(),
        support_f1(&result.support, &dataset.support_true)
    );
    let mut preview: Vec<(usize, f64)> = result
        .support
        .iter()
        .take(5)
        .map(|&i| (i, result.x[i]))
        .collect();
    preview.sort_by_key(|&(i, _)| i);
    println!("first coefficients: {preview:?}");
    Ok(())
}

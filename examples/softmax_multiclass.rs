//! Sparse softmax regression (SSR): 10-class classification on a synthetic
//! MNIST-like mixture, with a feature-selection report.
//!
//! Exercises the multiclass path of the stack: the coefficient matrix is
//! (n x 10), the l0 constraint applies to the flattened coefficients, and
//! the node-level omega prox is the Sherman-Morrison damped Newton.
//!
//!     cargo run --release --example softmax_multiclass

use psfit::config::Config;
use psfit::data::{Dataset, SyntheticSpec, Task};
use psfit::driver;
use psfit::losses::LossKind;
use psfit::sparsity::support_f1;

const K: usize = 10;

fn accuracy(ds: &Dataset, x: &[f64]) -> f64 {
    let n = ds.n_features;
    let mut correct = 0;
    let mut total = 0;
    for shard in &ds.shards {
        let a = shard.data.to_dense();
        for r in 0..a.rows {
            let row = a.row(r);
            let mut best = (0usize, f64::NEG_INFINITY);
            for c in 0..K {
                let score: f64 = row
                    .iter()
                    .enumerate()
                    .map(|(i, &a)| a as f64 * x[c * n + i])
                    .sum();
                if score > best.1 {
                    best = (c, score);
                }
            }
            let truth = shard.labels[r * K..(r + 1) * K]
                .iter()
                .position(|&v| v == 1.0)
                .unwrap();
            correct += usize::from(best.0 == truth);
            total += 1;
        }
    }
    correct as f64 / total as f64
}

fn main() -> anyhow::Result<()> {
    let mut spec = SyntheticSpec::regression(128, 4000, 2);
    spec.task = Task::Multiclass { k: K };
    spec.sparsity_level = 0.75; // 32 informative features (x 10 classes)
    spec.noise_std = 0.2;
    let ds = spec.generate();

    let mut cfg = Config::default();
    cfg.loss = LossKind::Softmax;
    cfg.classes = K;
    cfg.platform.nodes = ds.nodes();
    cfg.solver.kappa = spec.kappa() * K; // l0 over the flattened (n x K) matrix
    cfg.solver.rho_c = 1.0;
    cfg.solver.rho_b = 0.5;
    cfg.solver.max_iters = 60;

    println!(
        "SSR: {} features x {K} classes over {} nodes, kappa = {}",
        128,
        ds.nodes(),
        cfg.solver.kappa
    );
    let res = driver::fit(&ds, &cfg)?;
    println!(
        "converged: {} in {} iterations ({:.1} s)",
        res.converged, res.iters, res.wall_seconds
    );
    println!("train accuracy: {:.4}", accuracy(&ds, &res.x));
    println!(
        "coefficient support F1: {:.3}",
        support_f1(&res.support, &ds.support_true)
    );

    // feature-selection report: which input features carry any class weight
    let n = ds.n_features;
    let mut feature_hit = vec![false; n];
    for &idx in &res.support {
        feature_hit[idx % n] = true;
    }
    let selected: Vec<usize> = (0..n).filter(|&i| feature_hit[i]).collect();
    let truth: std::collections::BTreeSet<usize> =
        ds.support_true.iter().map(|&i| i % n).collect();
    let hits = selected.iter().filter(|i| truth.contains(i)).count();
    println!(
        "feature selection: {} features selected, {}/{} true features found",
        selected.len(),
        hits,
        truth.len()
    );
    Ok(())
}

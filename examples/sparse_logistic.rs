//! Sparse logistic regression (SLogR): feature selection for binary
//! classification, with a kappa sweep showing the accuracy/sparsity
//! trade-off the paper's model zoo is built for.
//!
//!     cargo run --release --example sparse_logistic

use psfit::config::Config;
use psfit::data::{Dataset, SyntheticSpec, Task};
use psfit::driver;
use psfit::losses::LossKind;
use psfit::sparsity::support_f1;

/// Hold out every `every`-th row of each shard as a test set.
fn split_holdout(ds: &Dataset, every: usize) -> (Dataset, Dataset) {
    use psfit::data::Shard;
    use psfit::linalg::Matrix;
    let carve = |test: bool| -> Dataset {
        let shards = ds
            .shards
            .iter()
            .map(|s| {
                let full = s.data.to_dense();
                let rows: Vec<usize> = (0..full.rows)
                    .filter(|r| (r % every == 0) == test)
                    .collect();
                let mut a = Matrix::zeros(rows.len(), full.cols);
                let mut labels = Vec::with_capacity(rows.len() * s.width);
                for (new_r, &r) in rows.iter().enumerate() {
                    a.row_mut(new_r).copy_from_slice(full.row(r));
                    labels.extend_from_slice(&s.labels[r * s.width..(r + 1) * s.width]);
                }
                Shard::dense(a, labels, s.width)
            })
            .collect();
        Dataset {
            shards,
            x_true: ds.x_true.clone(),
            support_true: ds.support_true.clone(),
            n_features: ds.n_features,
            width: ds.width,
        }
    };
    (carve(false), carve(true))
}

/// Classification accuracy of coefficient vector `x` on a dataset.
fn accuracy(ds: &Dataset, x: &[f64]) -> f64 {
    let mut correct = 0usize;
    let mut total = 0usize;
    for shard in &ds.shards {
        let a = shard.data.to_dense();
        for r in 0..a.rows {
            let row = a.row(r);
            let score: f64 = row.iter().zip(x).map(|(&a, &w)| a as f64 * w).sum();
            let pred = if score >= 0.0 { 1.0 } else { -1.0 };
            correct += usize::from(pred == shard.labels[r] as f64);
            total += 1;
        }
    }
    correct as f64 / total as f64
}

fn main() -> anyhow::Result<()> {
    // 600 features, 24 truly informative, 2 nodes.  A held-out test set is
    // carved off each node's shard (same planted model, unseen rows).
    let mut spec = SyntheticSpec::regression(600, 9000, 2);
    spec.task = Task::Binary;
    spec.sparsity_level = 0.96;
    spec.noise_std = 0.3;
    let full = spec.generate();
    let (train, test) = split_holdout(&full, 3);
    let true_k = spec.kappa();

    println!("SLogR: {} features, {} informative, {} train samples",
        600, true_k, train.total_samples());
    println!("{:>6} {:>10} {:>10} {:>8} {:>6}", "kappa", "train_acc", "test_acc", "supp_f1", "iters");

    for kappa in [6, 12, 24, 48, 96] {
        let mut cfg = Config::default();
        cfg.loss = LossKind::Logistic;
        cfg.platform.nodes = train.nodes();
        cfg.solver.kappa = kappa;
        cfg.solver.rho_c = 1.0;
        cfg.solver.rho_b = 0.5;
        cfg.solver.max_iters = 120;
        let res = driver::fit(&train, &cfg)?;
        println!(
            "{:>6} {:>10.4} {:>10.4} {:>8.3} {:>6}",
            kappa,
            accuracy(&train, &res.x),
            accuracy(&test, &res.x),
            support_f1(&res.support, &train.support_true),
            res.iters
        );
    }
    println!("\n(peak test accuracy should sit near kappa = {true_k}, the true support size)");
    Ok(())
}

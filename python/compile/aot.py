"""AOT lowering: JAX tile programs -> HLO text artifacts + manifest.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (what the published ``xla`` 0.1.6 Rust crate links) rejects
(``proto.id() <= INT_MAX``).  The text parser reassigns ids, so text
round-trips cleanly (see /opt/xla-example/README.md).

Run via ``make artifacts``; a content hash of the compile package makes the
target a no-op when inputs are unchanged.  Output layout::

    artifacts/
      manifest.json          # tile shapes + per-artifact input/output specs
      <program>.hlo.txt      # one per tile program

Usage: ``python -m compile.aot --out ../artifacts`` (from python/).
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import hashlib
import json
import pathlib
import sys

import jax
from jax._src.lib import xla_client as xc

from .kernels.common import TileConfig
from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_list(avals) -> list:
    """Flatten (pytree order) and describe each leaf tensor."""
    out = []
    leaves = jax.tree_util.tree_leaves(list(avals))
    for v in leaves:
        out.append({"shape": list(v.shape), "dtype": str(v.dtype)})
    return out


def source_fingerprint() -> str:
    """Hash of every .py in the compile package (drives Makefile no-op)."""
    root = pathlib.Path(__file__).parent
    h = hashlib.sha256()
    for p in sorted(root.rglob("*.py")):
        h.update(p.name.encode())
        h.update(p.read_bytes())
    return h.hexdigest()[:16]


def build(out_dir: pathlib.Path, cfg: TileConfig, *, verbose: bool = True) -> dict:
    cfg.validate()
    out_dir.mkdir(parents=True, exist_ok=True)
    registry = dict(model.program_registry(cfg))
    registry.update(model.sweep_registry(cfg))

    manifest = {
        "version": 1,
        "fingerprint": source_fingerprint(),
        "mode": cfg.mode,
        "tile_m": cfg.tile_m,
        "block_n": cfg.block_n,
        "bm": cfg.bm,
        "cg_iters": cfg.cg_iters,
        "newton_iters": cfg.newton_iters,
        "classes": cfg.classes,
        "inner_sweeps": cfg.inner_sweeps,
        "param_slots": {
            "m_blocks": model.P_MBLOCKS,
            "rho_l": model.P_RHO_L,
            "rho_c": model.P_RHO_C,
            "reg": model.P_REG,
            "size": model.P_SIZE,
        },
        "artifacts": {},
    }

    for name, (fn, example_args, static_kwargs) in registry.items():
        lowered = fn.lower(*example_args, **static_kwargs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        (out_dir / fname).write_text(text)
        out_avals = jax.tree_util.tree_leaves(
            jax.eval_shape(functools.partial(fn, **static_kwargs), *example_args)
        )
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": _spec_list(example_args),
            "outputs": _spec_list(out_avals),
        }
        if verbose:
            print(f"  {name:18s} -> {fname} ({len(text)} chars)", file=sys.stderr)

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    if verbose:
        print(
            f"wrote {len(registry)} artifacts + manifest.json to {out_dir} "
            f"(mode={cfg.mode}, tile_m={cfg.tile_m}, block_n={cfg.block_n}, "
            f"cg_iters={cfg.cg_iters})",
            file=sys.stderr,
        )
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--tile-m", type=int, default=None)
    ap.add_argument("--block-n", type=int, default=None)
    ap.add_argument("--cg-iters", type=int, default=None)
    ap.add_argument("--mode", choices=["xla", "pallas"], default=None,
                    help="tile-program lowering (see TileConfig.mode)")
    args = ap.parse_args()

    cfg = TileConfig.from_env()
    overrides = {
        k: v
        for k, v in {
            "tile_m": args.tile_m,
            "block_n": args.block_n,
            "cg_iters": args.cg_iters,
            "mode": args.mode,
        }.items()
        if v is not None
    }
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    out_dir = pathlib.Path(args.out)
    # No-op if fingerprint matches an existing manifest (make-friendly).
    mpath = out_dir / "manifest.json"
    if mpath.exists():
        try:
            existing = json.loads(mpath.read_text())
            if existing.get("fingerprint") == source_fingerprint() and (
                existing.get("tile_m"),
                existing.get("block_n"),
                existing.get("cg_iters"),
                existing.get("mode"),
            ) == (cfg.tile_m, cfg.block_n, cfg.cg_iters, cfg.mode):
                print("artifacts up to date — skipping", file=sys.stderr)
                return
        except (json.JSONDecodeError, KeyError):
            pass
    build(out_dir, cfg)


if __name__ == "__main__":
    main()

"""L1 — Pallas kernels for the Bi-cADMM compute hot-spot.

Kernel inventory (each tested against ``ref.py``):

  matvec.matvec            A @ x           streamed row tiles (prediction)
  matvec.matvec_t          A^T @ y         streamed row tiles (back-proj)
  matvec.fused_gram_matvec A^T (A x)       single-pass Gram matvec
  gram.gram                A^T A           setup-time Gram accumulation
  gram.gemv                G @ x           per-CG-step coefficient-space op
  prox.omega_squared       SLS   omega-bar prox (closed form)
  prox.omega_logistic      SLogR omega-bar prox (Newton)
  prox.omega_hinge         SSVM  omega-bar prox (three-piece exact)
  prox.omega_softmax       SSR   omega-bar prox (Sherman-Morrison Newton)

All kernels lower with ``interpret=True`` (CPU-PJRT executable HLO); the
TPU VMEM/MXU projections live in the module docstrings and DESIGN.md §10.
"""

from . import gram, matvec, prox, ref  # noqa: F401
from .common import TileConfig, ceil_div, pad_to  # noqa: F401

"""Shared tiling utilities for the PsFiT Pallas kernels.

Tiling model
------------
Every kernel in this package operates on *fixed-shape tiles* so that a single
AOT-compiled artifact serves every problem size the benchmarks sweep over.
The Rust coordinator (L3) pads each node's local feature block to the tile
grid and streams row tiles through the compiled executables:

  * ``TILE_M``  — rows (samples) per row-tile of a feature block.  The
    sample dimension is unbounded in the paper's experiments (up to 3e5 rows
    per node), so the m-axis is tiled and accumulated by the caller.
  * ``BLOCK_N`` — columns (features) per feature block ``A_ij``.  This is the
    paper's per-GPU feature partition: node ``i`` splits its ``A_i`` into M
    column blocks, one per device queue.

VMEM budget (TPU projection; see DESIGN.md §10)
-----------------------------------------------
With the default ``(TILE_M, BLOCK_N) = (8192, 512)`` and ``bm = 1024`` the
working set of the inner matmul tile is

  A-tile  : 1024 x 512 x 4 B = 2.0 MiB
  Gram out:  512 x 512 x 4 B = 1.0 MiB
  vectors :  < 16 KiB

comfortably inside a 16 MiB VMEM.  ``bm`` is a multiple of 8 and ``BLOCK_N``
a multiple of 128, matching the f32 (8, 128) TPU tile so the MXU sees fully
populated systolic passes.

All tile-size knobs can be overridden through environment variables at
``make artifacts`` time (``PSFIT_TILE_M``, ``PSFIT_BLOCK_N``, ...); the chosen
values are recorded in ``artifacts/manifest.json`` and read back by the Rust
runtime.
"""

from __future__ import annotations

import os
from dataclasses import dataclass


def _env_int(name: str, default: int) -> int:
    raw = os.environ.get(name)
    return int(raw) if raw else default


@dataclass(frozen=True)
class TileConfig:
    """Static shape configuration baked into the AOT artifacts.

    ``mode`` selects the lowering of the tile programs:

    * ``"pallas"`` — the L1 Pallas kernels, lowered with ``interpret=True``
      so the CPU PJRT client can execute them.  Interpret mode materializes
      full-buffer copies per grid step, so this is a *correctness* vehicle
      (it proves the kernels compose through the whole stack); real-TPU
      performance is projected in DESIGN.md §10.
    * ``"xla"`` — the pure-jnp reference forms of the same tile programs
      (tested equal to the kernels in python/tests), fused by XLA into the
      shapes a production CPU/GPU lowering would produce.  This is what the
      performance benchmarks run.
    """

    tile_m: int = 8192  # rows per streamed row-tile
    block_n: int = 512  # features per device block (paper's per-GPU split)
    bm: int = 1024  # row sub-tile inside a kernel grid step
    cg_iters: int = 24  # CG iterations of the block solve artifact
    newton_iters: int = 8  # Newton steps for smooth omega proxes
    classes: int = 10  # K for the softmax (SSR) artifacts
    inner_sweeps: int = 3  # Algorithm-2 sweeps fused into node_sweep_*
    mode: str = "xla"  # "xla" (fast CPU lowering) | "pallas" (interpret)

    @staticmethod
    def from_env() -> "TileConfig":
        return TileConfig(
            tile_m=_env_int("PSFIT_TILE_M", 8192),
            block_n=_env_int("PSFIT_BLOCK_N", 512),
            bm=_env_int("PSFIT_BM", 1024),
            cg_iters=_env_int("PSFIT_CG_ITERS", 24),
            newton_iters=_env_int("PSFIT_NEWTON_ITERS", 8),
            classes=_env_int("PSFIT_CLASSES", 10),
            inner_sweeps=_env_int("PSFIT_INNER_ITERS", 3),
            mode=os.environ.get("PSFIT_MODE", "xla"),
        )

    def validate(self) -> None:
        if self.mode not in ("xla", "pallas"):
            raise ValueError(f"mode must be 'xla' or 'pallas', got {self.mode!r}")
        if self.tile_m % self.bm != 0:
            raise ValueError(f"tile_m={self.tile_m} must divide by bm={self.bm}")
        if self.bm % 8 != 0:
            raise ValueError(f"bm={self.bm} must be a multiple of 8 (f32 sublane)")
        if self.block_n % 128 != 0:
            raise ValueError(
                f"block_n={self.block_n} must be a multiple of 128 (lane width)"
            )
        if self.cg_iters < 1 or self.newton_iters < 1:
            raise ValueError("iteration counts must be >= 1")


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def pad_to(x: int, multiple: int) -> int:
    """Smallest multiple of ``multiple`` that is >= ``x``."""
    return ceil_div(x, multiple) * multiple

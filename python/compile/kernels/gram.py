"""Pallas Gram-matrix kernels.

``G_j = A_ij^T A_ij`` is *iteration-invariant*: the coordinator computes it
once per (node, block) at setup and the entire inner ADMM then runs in
coefficient space (block_n-sized objects), which is what lets a single
fixed-shape artifact serve every sample count the paper sweeps (25k..300k
rows per node).  This kernel is the setup-time hot op; ``gemv`` below is the
per-CG-step hot op.

VMEM/MXU estimate (TPU projection): with (bm, block_n) = (1024, 512) each
grid step holds a 2 MiB A-tile and the 1 MiB Gram accumulator; the
(512x1024)@(1024x512) product is a dense MXU matmul — ~4096 systolic passes
at full 128x128 occupancy, est. >70% MXU utilization.  gemv is
matrix-vector bound (~n/128 passes); batching CG across M feature blocks
(one per device queue) restores matrix-matrix shape.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(a_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    t = a_ref[...]
    o_ref[...] += t.T @ t


@functools.partial(jax.jit, static_argnames=("bm",))
def gram(a, *, bm: int = 1024):
    """``A^T A`` for one (tile_m, block_n) row tile, accumulated over bm-rows."""
    m, n = a.shape
    assert m % bm == 0, (m, bm)
    return pl.pallas_call(
        _gram_kernel,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((n, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), a.dtype),
        interpret=True,
    )(a)


def _gemv_kernel(g_ref, x_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += g_ref[...] @ x_ref[...]


@functools.partial(jax.jit, static_argnames=("bn",))
def gemv(g, x, *, bn: int = 512):
    """``G @ x`` with G: (n, n), x: (n, 1); grid over column strips of G.

    For block_n <= 1024 a single strip suffices (G fits VMEM whole); the
    grid form keeps the artifact valid if PSFIT_BLOCK_N is raised.
    """
    n = g.shape[0]
    bn = min(bn, n)
    assert n % bn == 0, (n, bn)
    return pl.pallas_call(
        _gemv_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((n, bn), lambda i: (0, i)),
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), g.dtype),
        interpret=True,
    )(g, x)

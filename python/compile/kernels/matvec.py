"""Pallas matrix-vector kernels for the streamed row tiles.

These are the data-touching hot ops of Algorithm 2: per inner iteration and
per feature block the node computes one ``A_ij @ x_ij`` (prediction,
feeds the AllReduce) and one ``A_ij^T @ v`` (back-projection of the sample-
space correction into coefficient space).  The Rust coordinator streams
``TILE_M``-row tiles of the block through the compiled artifact and
accumulates partial results, so the artifacts themselves have fixed shapes.

TPU mapping (DESIGN.md §Hardware-Adaptation): the CUDA threadblock grid of
the paper becomes a Pallas grid over (row-tile, ) with ``(bm, block_n)``
VMEM-resident A sub-tiles; the MXU consumes the ``(bm, block_n) @
(block_n, 1)`` products as weight-stationary systolic passes.  Kernels are
lowered with ``interpret=True`` so the CPU PJRT client can execute the HLO
(real-TPU lowering would emit Mosaic custom calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import TileConfig


def _matvec_kernel(a_ref, x_ref, o_ref):
    """One grid step: o_tile = A_tile @ x (x fully VMEM-resident)."""
    o_ref[...] = a_ref[...] @ x_ref[...]


@functools.partial(jax.jit, static_argnames=("bm",))
def matvec(a, x, *, bm: int = 1024):
    """``A @ x`` with A: (tile_m, block_n), x: (block_n, 1) -> (tile_m, 1).

    Grid over row sub-tiles only; ``x`` is small enough (block_n <= a few K)
    to pin in VMEM for every step, so each A element is read exactly once.
    """
    m, n = a.shape
    assert m % bm == 0, (m, bm)
    return pl.pallas_call(
        _matvec_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), a.dtype),
        interpret=True,
    )(a, x)


def _matvec_t_kernel(a_ref, y_ref, o_ref):
    """Accumulating grid step: o += A_tile^T @ y_tile.

    The output block is revisited on every grid step (its index_map is
    constant), which Pallas guarantees to execute sequentially — the
    classic reduction-over-grid pattern.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += a_ref[...].T @ y_ref[...]


@functools.partial(jax.jit, static_argnames=("bm",))
def matvec_t(a, y, *, bm: int = 1024):
    """``A^T @ y`` with A: (tile_m, block_n), y: (tile_m, 1) -> (block_n, 1)."""
    m, n = a.shape
    assert m % bm == 0, (m, bm)
    return pl.pallas_call(
        _matvec_t_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), a.dtype),
        interpret=True,
    )(a, y)


def _fused_xt_ax_kernel(a_ref, x_ref, o_ref):
    """Fused grid step: o += A_tile^T (A_tile @ x).

    One pass over A computes the Gram-matvec G x = A^T(A x) without ever
    materializing either A x (beyond one tile) or G — the roofline-optimal
    form when G itself is not cached.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = a_ref[...] @ x_ref[...]
    o_ref[...] += a_ref[...].T @ w


@functools.partial(jax.jit, static_argnames=("bm",))
def fused_gram_matvec(a, x, *, bm: int = 1024):
    """``A^T (A @ x)`` in a single streamed pass over A."""
    m, n = a.shape
    assert m % bm == 0, (m, bm)
    return pl.pallas_call(
        _fused_xt_ax_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), a.dtype),
        interpret=True,
    )(a, x)


def default_bm(cfg: TileConfig) -> int:
    return cfg.bm

"""Pallas kernels for the separable omega-bar proximal updates (Eq. 21).

Because every loss of the paper's model zoo (SLS, SLogR, SSVM, SSR) is
separable across samples, the omega-bar minimization splits into m scalar
(or K-vector for softmax) problems — "the omega-update splits entirely into
m_i scalar optimization problems" (paper §3.1).  That is an elementwise map
over the sample axis: ideal Pallas territory — a 1-D grid of (bm, 1) tiles,
VPU-only (no MXU), fully vectorized.

Scalars (M = number of feature blocks, rho_l) are passed as a (8, 1) f32
parameter vector so the artifact signature is uniform; see model.PARAMS_*.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# Parameter-vector slots (kept in sync with rust/src/runtime/params.rs)
P_MBLOCKS = 0  # M — number of feature blocks (paper's per-node GPU count)
P_RHO_L = 1  # rho_l — inner (sharing) ADMM penalty
P_SIZE = 8


def _omega_squared_kernel(b_ref, c_ref, p_ref, o_ref):
    m = p_ref[P_MBLOCKS, 0]
    rho = p_ref[P_RHO_L, 0]
    o_ref[...] = (2.0 * b_ref[...] + rho * c_ref[...]) / (2.0 * m + rho)


def _omega_logistic_kernel(b_ref, c_ref, p_ref, o_ref, *, iters: int):
    m = p_ref[P_MBLOCKS, 0]
    rho = p_ref[P_RHO_L, 0]
    b = b_ref[...]
    c = c_ref[...]
    w = c
    for _ in range(iters):  # unrolled Newton — iters is a lowering constant
        sig = jax.nn.sigmoid(-b * m * w)
        grad = -m * b * sig + m * rho * (w - c)
        hess = m * m * sig * (1.0 - sig) + m * rho
        w = w - grad / hess
    o_ref[...] = w


def _omega_hinge_kernel(b_ref, c_ref, p_ref, o_ref):
    m = p_ref[P_MBLOCKS, 0]
    rho = p_ref[P_RHO_L, 0]
    b = b_ref[...]
    c = c_ref[...]
    s = b * m * c
    o_ref[...] = jnp.where(
        s >= 1.0, c, jnp.where(s <= 1.0 - m / rho, c + b / rho, b / m)
    )


def _omega_softmax_kernel(y_ref, c_ref, p_ref, o_ref, *, iters: int):
    m = p_ref[P_MBLOCKS, 0]
    rho = p_ref[P_RHO_L, 0]
    y = y_ref[...]  # (bm, K) one-hot labels
    c = c_ref[...]

    def obj(w):
        return (
            jax.nn.logsumexp(m * w, axis=-1, keepdims=True)
            - m * jnp.sum(w * y, axis=-1, keepdims=True)
            + m * rho / 2.0 * jnp.sum((w - c) ** 2, axis=-1, keepdims=True)
        )

    w = c
    for _ in range(iters):  # damped Sherman-Morrison Newton, unrolled
        s = jax.nn.softmax(m * w, axis=-1)
        grad = m * (s - y) + m * rho * (w - c)
        d = m * m * s + m * rho
        u = m * s
        dinv_g = grad / d
        dinv_u = u / d
        # Stable form of 1 - u^T D^-1 u: since sum(s) == 1,
        #   1 - sum(M^2 s^2 / (M^2 s + M rho)) = rho * sum(M s / (M^2 s + M rho))
        # — a sum of positives, no cancellation in f32.
        denom = rho * jnp.sum(dinv_u, axis=-1, keepdims=True)
        step = dinv_g + dinv_u * (
            jnp.sum(u * dinv_g, axis=-1, keepdims=True) / denom
        )
        # Damped: best-of-menu keeps global monotone descent (H > 0) while
        # eta = 1 preserves the quadratic local rate near the optimum.
        best_w, best_f = w, obj(w)
        for eta in (1.0, 0.5, 0.25, 0.125, 0.03125):
            cand = w - eta * step
            f = obj(cand)
            take = f < best_f
            best_w = jnp.where(take, cand, best_w)
            best_f = jnp.where(take, f, best_f)
        w = best_w
    o_ref[...] = w


def _elementwise_call(kernel, b, c, params, *, bm: int, width: int = 1):
    m = b.shape[0]
    assert m % bm == 0, (m, bm)
    return pl.pallas_call(
        kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, width), lambda i: (i, 0)),
            pl.BlockSpec((bm, width), lambda i: (i, 0)),
            pl.BlockSpec((P_SIZE, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, width), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, width), b.dtype),
        interpret=True,
    )(b, c, params)


@functools.partial(jax.jit, static_argnames=("bm",))
def omega_squared(b, c, params, *, bm: int = 1024):
    """SLS omega-bar prox; b, c: (tile_m, 1); params: (8, 1)."""
    return _elementwise_call(_omega_squared_kernel, b, c, params, bm=bm)


@functools.partial(jax.jit, static_argnames=("bm", "iters"))
def omega_logistic(b, c, params, *, bm: int = 1024, iters: int = 8):
    """SLogR omega-bar prox (Newton); labels b in {-1, +1}."""
    kernel = functools.partial(_omega_logistic_kernel, iters=iters)
    return _elementwise_call(kernel, b, c, params, bm=bm)


@functools.partial(jax.jit, static_argnames=("bm",))
def omega_hinge(b, c, params, *, bm: int = 1024):
    """SSVM omega-bar prox (exact three-piece form); labels b in {-1, +1}."""
    return _elementwise_call(_omega_hinge_kernel, b, c, params, bm=bm)


@functools.partial(jax.jit, static_argnames=("bm", "iters", "classes"))
def omega_softmax(y_onehot, c, params, *, bm: int = 1024, iters: int = 8, classes: int = 10):
    """SSR omega-bar prox; y_onehot, c: (tile_m, K)."""
    kernel = functools.partial(_omega_softmax_kernel, iters=iters)
    return _elementwise_call(kernel, y_onehot, c, params, bm=bm, width=classes)

"""Pure-jnp reference oracles for every Pallas kernel and tile program.

These are the CORE correctness signal of the compile path: each Pallas
kernel in this package and each tile program in ``compile.model`` is tested
against the corresponding function here (``python/tests/``), typically in
float64 to expose accumulation-order issues.

Math notation follows the paper (arXiv Bi-cADMM, Eqs. 15-23):

  * block objective (23):  min_x  r_j(x) + rho_l/2 ||A_j x - d_j||^2
        r_j(x) = 1/(2 N gamma) ||x||^2 + rho_c/2 ||x - z_j + u_ij||^2
        d_j    = A_j x_j^k + omega_bar - w_bar - nu
  * omega-bar update (21): min_w  ell(M w - b) + M rho_l / 2 ||w - c||^2
        with c = mean_j(A_j x_j) + nu, separable across samples.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# Dense linear algebra oracles
# --------------------------------------------------------------------------


def matvec(a, x):
    """A @ x for a row tile. a: (m, n), x: (n, 1) -> (m, 1)."""
    return a @ x


def matvec_t(a, y):
    """A^T @ y for a row tile. a: (m, n), y: (m, 1) -> (n, 1)."""
    return a.T @ y


def gram(a):
    """A^T A for a row tile. a: (m, n) -> (n, n). Callers accumulate tiles."""
    return a.T @ a


def gemv(g, x):
    """Square gemv used by the coefficient-space CG. g: (n, n), x: (n, 1)."""
    return g @ x


# --------------------------------------------------------------------------
# Block proximal solve (Eq. 23) — coefficient space
# --------------------------------------------------------------------------


def block_solve_exact(g, x_prev, q, z, u, rho_l, rho_c, reg):
    """Exact minimizer of the block objective (23) in coefficient space.

    The normal equations are
        (rho_l G + reg I) x = rho_l (G x_prev + q) + rho_c (z - u)
    where G = A_j^T A_j (accumulated over row tiles), q = A_j^T (omega_bar -
    w_bar - nu), and reg = 1/(N gamma) + rho_c.  Solved densely; the Pallas
    artifact approximates this with ``cg_iters`` CG steps.
    """
    n = g.shape[0]
    h = rho_l * g + reg * jnp.eye(n, dtype=g.dtype)
    rhs = rho_l * (g @ x_prev + q) + rho_c * (z - u)
    return jnp.linalg.solve(h, rhs)


def block_solve_cg(g, x_prev, q, z, u, rho_l, rho_c, reg, iters):
    """Reference CG with identical iteration structure to the artifact."""
    rhs = rho_l * (g @ x_prev + q) + rho_c * (z - u)

    def hmul(v):
        return rho_l * (g @ v) + reg * v

    x = x_prev
    r = rhs - hmul(x)
    p = r
    rs = jnp.vdot(r, r)

    def body(_, state):
        x, r, p, rs = state
        hp = hmul(p)
        denom = jnp.vdot(p, hp)
        alpha = rs / jnp.where(denom == 0, 1.0, denom)
        x = x + alpha * p
        r = r - alpha * hp
        rs_new = jnp.vdot(r, r)
        beta = rs_new / jnp.where(rs == 0, 1.0, rs)
        p = r + beta * p
        return (x, r, p, rs_new)

    x, _, _, _ = jax.lax.fori_loop(0, iters, body, (x, r, p, rs))
    return x


# --------------------------------------------------------------------------
# omega-bar proximal updates (Eq. 21) — separable across samples
# --------------------------------------------------------------------------
#
# All solve, per sample:  min_w  phi(M w; b) + (M rho / 2) (w - c)^2
# phi is the per-sample loss of the model family.


def omega_squared(b, c, m_blocks, rho):
    """SLS: phi(p; b) = (p - b)^2.  Closed form.

    h'(w) = 2 M (M w - b) + M rho (w - c) = 0
          -> w = (2 b + rho c) / (2 M + rho)
    """
    return (2.0 * b + rho * c) / (2.0 * m_blocks + rho)


def omega_logistic(b, c, m_blocks, rho, iters=30):
    """SLogR: phi(p; b) = log(1 + exp(-b p)), b in {-1, +1}.  Newton.

    h'(w)  = -M b sigma(-b M w) + M rho (w - c)
    h''(w) =  M^2 sigma'(b M w) + M rho        (sigma' in (0, 1/4])
    """
    m = m_blocks

    def body(_, w):
        z = b * m * w
        sig = jax.nn.sigmoid(-z)  # sigma(-bMw)
        grad = -m * b * sig + m * rho * (w - c)
        hess = m * m * sig * (1.0 - sig) + m * rho
        return w - grad / hess

    return jax.lax.fori_loop(0, iters, body, c)


def omega_hinge(b, c, m_blocks, rho):
    """SSVM: phi(p; b) = max(0, 1 - b p).  Three-piece closed form.

    With s = b M c:
      s >= 1            -> w = c           (margin already satisfied)
      s <= 1 - M / rho  -> w = c + b/rho   (inside the linear piece)
      otherwise         -> w = b / M       (at the kink)
    """
    m = m_blocks
    s = b * m * c
    at_c = c
    linear = c + b / rho
    kink = b / m
    return jnp.where(s >= 1.0, at_c, jnp.where(s <= 1.0 - m / rho, linear, kink))


def omega_softmax(labels_onehot, c, m_blocks, rho, iters=20):
    """SSR: per sample w in R^K, phi(p; y) = logsumexp(p) - p_y.

    Newton with the exact softmax Hessian, inverted per sample by
    Sherman-Morrison:  H = diag(M^2 s + M rho) - (M s)(M s)^T  with
    s = softmax(M w); 1 - u^T D^{-1} u > 0 whenever rho > 0.

    labels_onehot, c: (m, K).  Returns (m, K).
    """
    m = m_blocks

    def obj(w):
        return (
            jax.nn.logsumexp(m * w, axis=-1, keepdims=True)
            - m * jnp.sum(w * labels_onehot, axis=-1, keepdims=True)
            + m * rho / 2.0 * jnp.sum((w - c) ** 2, axis=-1, keepdims=True)
        )

    def body(_, w):
        s = jax.nn.softmax(m * w, axis=-1)
        grad = m * (s - labels_onehot) + m * rho * (w - c)
        d = m * m * s + m * rho  # diagonal of H
        u = m * s  # rank-one factor
        dinv_g = grad / d
        dinv_u = u / d
        # Stable: 1 - u^T D^-1 u == rho * sum(dinv_u) exactly (sum(s) == 1).
        denom = rho * jnp.sum(dinv_u, axis=-1, keepdims=True)
        step = dinv_g + dinv_u * (
            jnp.sum(u * dinv_g, axis=-1, keepdims=True) / denom
        )
        # Damped Newton: pick the best of a fixed step menu per sample —
        # H > 0 makes `step` a descent direction, so this is monotone and
        # keeps the quadratic local rate (eta = 1 wins near the optimum).
        best_w, best_f = w, obj(w)
        for eta in (1.0, 0.5, 0.25, 0.125, 0.03125):
            cand = w - eta * step
            f = obj(cand)
            take = f < best_f
            best_w = jnp.where(take, cand, best_w)
            best_f = jnp.where(take, f, best_f)
        return best_w

    return jax.lax.fori_loop(0, iters, body, c)


# --------------------------------------------------------------------------
# Loss values (for residual / objective reporting)
# --------------------------------------------------------------------------


def loss_value_squared(pred, b):
    return jnp.sum((pred - b) ** 2)


def loss_value_logistic(pred, b):
    return jnp.sum(jnp.logaddexp(0.0, -b * pred))


def loss_value_hinge(pred, b):
    return jnp.sum(jnp.maximum(0.0, 1.0 - b * pred))


def loss_value_softmax(pred, labels_onehot):
    return jnp.sum(
        jax.nn.logsumexp(pred, axis=-1) - jnp.sum(pred * labels_onehot, axis=-1)
    )


# --------------------------------------------------------------------------
# Elementwise CG helpers
# --------------------------------------------------------------------------


def saxpy(alpha, x, y):
    return alpha * x + y


def vdot(x, y):
    return jnp.sum(x * y)

"""L2 — the Bi-cADMM node-level tile programs (Algorithm 2 of the paper).

Each public function here is a *tile program*: a jitted JAX function with
fixed shapes that composes the L1 Pallas kernels into one step of the
node-level inner ADMM.  ``aot.py`` lowers every program to HLO text once;
the Rust coordinator (L3) loads the artifacts via PJRT and streams data
through them — Python never runs at request time.

Coefficient-space formulation
-----------------------------
The block x-update (Eq. 23) is a ridge least-squares whose normal matrix
``rho_l * G_j + reg * I`` (``G_j = A_ij^T A_ij``) is iteration-invariant.
The programs therefore split into:

  * setup-time (once per dataset): ``gram_tile`` accumulates G_j over
    streamed row tiles;
  * per-inner-iteration: ``matvec_t_tile`` back-projects the sample-space
    correction ``omega_bar - w_bar - nu`` into ``q_j``; ``block_solve``
    runs ``cg_iters`` CG steps entirely in (block_n)-space;
    ``matvec_tile`` recomputes the block prediction ``w_j = A_j x_j``
    feeding the AllReduce; ``omega_*`` applies the separable prox (21).

Scalar parameters travel in an (8, 1) f32 vector (slots below) so all
artifacts share a uniform ABI with the Rust runtime
(``rust/src/runtime/params.rs`` mirrors the slot layout).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import gram as gram_k
from .kernels import matvec as mv_k
from .kernels import prox as prox_k
from .kernels import ref
from .kernels.common import TileConfig

# Parameter-vector slots — keep in sync with rust/src/runtime/params.rs
P_MBLOCKS = 0  # M     — feature blocks per node (the paper's GPU count)
P_RHO_L = 1  # rho_l — inner sharing-ADMM penalty
P_RHO_C = 2  # rho_c — outer consensus penalty
P_REG = 3  # reg   — 1/(N gamma) + rho_c (Tikhonov + consensus curvature)
P_SIZE = 8

assert P_MBLOCKS == prox_k.P_MBLOCKS and P_RHO_L == prox_k.P_RHO_L


def make_params(m_blocks, rho_l, rho_c, reg, dtype=jnp.float32):
    p = jnp.zeros((P_SIZE, 1), dtype)
    return (
        p.at[P_MBLOCKS, 0]
        .set(m_blocks)
        .at[P_RHO_L, 0]
        .set(rho_l)
        .at[P_RHO_C, 0]
        .set(rho_c)
        .at[P_REG, 0]
        .set(reg)
    )


# --------------------------------------------------------------------------
# Lowering-mode dispatch (see TileConfig.mode)
# --------------------------------------------------------------------------
#
# "pallas": the L1 kernels (interpret=True) — correctness vehicle on CPU.
# "xla":    the tested-equal jnp forms, fused by XLA — the perf lowering.


def _matvec(a, x, *, bm, mode):
    if mode == "pallas":
        return mv_k.matvec(a, x, bm=bm)
    return a @ x


def _matvec_t(a, y, *, bm, mode):
    if mode == "pallas":
        return mv_k.matvec_t(a, y, bm=bm)
    # (y^T A)^T streams A row-major (sequential loads); the naive A^T @ y
    # form makes XLA-CPU walk columns — ~50x slower at (8192, 512).
    return (y.reshape(1, -1) @ a).reshape(-1, 1)


def _gram(a, *, bm, mode):
    if mode == "pallas":
        return gram_k.gram(a, bm=bm)
    return a.T @ a


def _gemv(g, x, *, bn, mode):
    if mode == "pallas":
        return gram_k.gemv(g, x, bn=bn)
    return g @ x


# --------------------------------------------------------------------------
# Setup-time programs
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("bm", "mode"))
def gram_tile(a, *, bm: int = 1024, mode: str = "pallas"):
    """Partial Gram ``A_tile^T A_tile`` of one streamed row tile.

    The caller (Rust) sums the partials over all row tiles of the block.
    Zero-padded rows contribute nothing, so padding the last tile is exact.
    """
    return (_gram(a, bm=bm, mode=mode),)


# --------------------------------------------------------------------------
# Per-iteration programs
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("bm", "mode"))
def matvec_tile(a, x, *, bm: int = 1024, mode: str = "pallas"):
    """Block prediction tile: ``w = A_tile @ x_j`` (feeds the AllReduce)."""
    return (_matvec(a, x, bm=bm, mode=mode),)


@functools.partial(jax.jit, static_argnames=("bm", "mode"))
def matvec_t_tile(a, y, *, bm: int = 1024, mode: str = "pallas"):
    """Back-projection tile: partial ``q = A_tile^T y_tile`` (caller sums)."""
    return (_matvec_t(a, y, bm=bm, mode=mode),)


@functools.partial(jax.jit, static_argnames=("cg_iters", "bn", "mode"))
def block_solve(g, x_prev, q, z, u, params, *, cg_iters: int = 24, bn: int = 512, mode: str = "pallas"):
    """Eq. (23): ridge LS in coefficient space by ``cg_iters`` CG steps.

    Solves  (rho_l G + reg I) x = rho_l (G x_prev + q) + rho_c (z - u)
    with the Pallas ``gemv`` as the operator, warm-started at x_prev.
    Shapes: g (block_n, block_n); all vectors (block_n, 1).
    """
    rho_l = params[P_RHO_L, 0]
    rho_c = params[P_RHO_C, 0]
    reg = params[P_REG, 0]

    def hmul(v):
        return rho_l * _gemv(g, v, bn=bn, mode=mode) + reg * v

    rhs = rho_l * (_gemv(g, x_prev, bn=bn, mode=mode) + q) + rho_c * (z - u)
    x = x_prev
    r = rhs - hmul(x)
    p = r
    rs = jnp.sum(r * r)

    # The loop is UNROLLED at trace time: cg_iters is a lowering constant,
    # and straight-line HLO avoids the per-iteration while-loop overhead of
    # the TFRT CPU runtime (~ms/iter, dominating the actual 0.5 MFLOP gemv).
    for _ in range(cg_iters):
        hp = hmul(p)
        denom = jnp.sum(p * hp)
        alpha = rs / jnp.where(denom == 0, 1.0, denom)
        x = x + alpha * p
        r = r - alpha * hp
        rs_new = jnp.sum(r * r)
        beta = rs_new / jnp.where(rs == 0, 1.0, rs)
        p = r + beta * p
        rs = rs_new
    return (x,)


@functools.partial(jax.jit, static_argnames=("bm", "mode"))
def omega_squared(b, c, params, *, bm: int = 1024, mode: str = "pallas"):
    """SLS omega-bar prox tile (closed form)."""
    if mode == "pallas":
        return (prox_k.omega_squared(b, c, params, bm=bm),)
    return (ref.omega_squared(b, c, params[P_MBLOCKS, 0], params[P_RHO_L, 0]),)


@functools.partial(jax.jit, static_argnames=("bm", "iters", "mode"))
def omega_logistic(b, c, params, *, bm: int = 1024, iters: int = 8, mode: str = "pallas"):
    """SLogR omega-bar prox tile (Newton, labels in {-1,+1})."""
    if mode == "pallas":
        return (prox_k.omega_logistic(b, c, params, bm=bm, iters=iters),)
    return (ref.omega_logistic(b, c, params[P_MBLOCKS, 0], params[P_RHO_L, 0], iters=iters),)


@functools.partial(jax.jit, static_argnames=("bm", "mode"))
def omega_hinge(b, c, params, *, bm: int = 1024, mode: str = "pallas"):
    """SSVM omega-bar prox tile (exact three-piece form)."""
    if mode == "pallas":
        return (prox_k.omega_hinge(b, c, params, bm=bm),)
    return (ref.omega_hinge(b, c, params[P_MBLOCKS, 0], params[P_RHO_L, 0]),)


@functools.partial(jax.jit, static_argnames=("bm", "iters", "classes", "mode"))
def omega_softmax(y_onehot, c, params, *, bm: int = 1024, iters: int = 8, classes: int = 10,
                  mode: str = "pallas"):
    """SSR omega-bar prox tile (Sherman-Morrison Newton)."""
    if mode == "pallas":
        return (
            prox_k.omega_softmax(y_onehot, c, params, bm=bm, iters=iters, classes=classes),
        )
    return (ref.omega_softmax(y_onehot, c, params[P_MBLOCKS, 0], params[P_RHO_L, 0], iters=iters),)


# --------------------------------------------------------------------------
# Fused inner-iteration program (perf ablation; see EXPERIMENTS.md §Perf)
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cg_iters", "bn", "bm", "mode"))
def block_iteration(
    g, a, x_prev, corr, z, u, params, *, cg_iters: int = 24, bn: int = 512, bm: int = 1024,
    mode: str = "pallas",
):
    """One fused inner step for a single-row-tile block.

    ``q = A^T corr``; ``x = block_solve(...)``; ``w = A x`` — one PJRT call
    instead of three when the block's sample count fits a single tile.
    """
    q = _matvec_t(a, corr, bm=bm, mode=mode)
    (x,) = block_solve(g, x_prev, q, z, u, params, cg_iters=cg_iters, bn=bn, mode=mode)
    w = _matvec(a, x, bm=bm, mode=mode)
    return (x, w)


# --------------------------------------------------------------------------
# Fused node-level sweep (the launch-granularity optimization; §Perf)
# --------------------------------------------------------------------------


def _omega_dispatch(loss, b, c, params, *, bm, iters, mode):
    if loss == "squared":
        return omega_squared(b, c, params, bm=bm, mode=mode)[0]
    if loss == "logistic":
        return omega_logistic(b, c, params, bm=bm, iters=iters, mode=mode)[0]
    if loss == "hinge":
        return omega_hinge(b, c, params, bm=bm, mode=mode)[0]
    raise ValueError(f"node_sweep does not support loss {loss!r}")


@functools.partial(
    jax.jit,
    static_argnames=("sweeps", "cg_iters", "bn", "bm", "iters", "mode", "loss"),
)
def node_sweep(
    a_blocks,
    g_blocks,
    x_blocks,
    w_blocks,
    omega,
    nu,
    z_blocks,
    u_blocks,
    b,
    params,
    *,
    sweeps: int = 3,
    cg_iters: int = 24,
    bn: int = 512,
    bm: int = 1024,
    iters: int = 8,
    mode: str = "pallas",
    loss: str = "squared",
):
    """Algorithm 2, fully fused: `sweeps` inner iterations over all M
    feature blocks of one node in a single PJRT call.

    This is the launch-granularity optimization of the perf pass: the
    granular path costs ~8 host<->device operations per (block, sweep);
    this artifact costs one execute + one state round-trip per *outer*
    iteration.  Both loops are unrolled at trace time.

    Blocks are passed as TUPLES of separate (tile_m, block_n) arrays —
    not one stacked (M, tile_m, block_n) tensor — so XLA never
    materializes 16 MB slice copies per use (8.5x faster on CPU) and the
    Rust runtime can feed its per-block persistent device buffers
    directly.  HLO parameter order = pytree order:
    a_0..a_{M-1}, g_0.., x_0.., w_0.., omega, nu, z_0.., u_0.., b, params.
    Outputs: x_0..x_{M-1}, w_0..w_{M-1}, omega, nu.

    Single-class losses only (squared / logistic / hinge).
    """
    m_blocks = len(a_blocks)
    xs = list(x_blocks)
    ws = list(w_blocks)
    inv_m = 1.0 / m_blocks

    for _ in range(sweeps):
        wbar = sum(ws) * inv_m
        corr = omega - wbar - nu
        for j in range(m_blocks):
            q = _matvec_t(a_blocks[j], corr, bm=bm, mode=mode)
            (xj,) = block_solve(
                g_blocks[j], xs[j], q, z_blocks[j], u_blocks[j], params,
                cg_iters=cg_iters, bn=bn, mode=mode,
            )
            xs[j] = xj
            ws[j] = _matvec(a_blocks[j], xj, bm=bm, mode=mode)
        wbar = sum(ws) * inv_m
        c = wbar + nu
        omega = _omega_dispatch(loss, b, c, params, bm=bm, iters=iters, mode=mode)
        nu = nu + wbar - omega

    return tuple(xs) + tuple(ws) + (omega, nu)


# --------------------------------------------------------------------------
# Program registry consumed by aot.py
# --------------------------------------------------------------------------


def program_registry(cfg: TileConfig):
    """Returns ``{name: (jitted_fn, example_args, static_kwargs)}``.

    ``aot.py`` lowers via ``fn.lower(*args, **kwargs)`` so the static
    (shape-determining) keywords are baked into the artifact.
    """
    f32 = jnp.float32
    tm, nb, k = cfg.tile_m, cfg.block_n, cfg.classes
    a = jax.ShapeDtypeStruct((tm, nb), f32)
    vec_m = jax.ShapeDtypeStruct((tm, 1), f32)
    vec_n = jax.ShapeDtypeStruct((nb, 1), f32)
    mat_g = jax.ShapeDtypeStruct((nb, nb), f32)
    mat_k = jax.ShapeDtypeStruct((tm, k), f32)
    par = jax.ShapeDtypeStruct((P_SIZE, 1), f32)

    bm = cfg.bm
    mode = cfg.mode
    return {
        "gram_tile": (gram_tile, (a,), {"bm": bm, "mode": mode}),
        "matvec_tile": (matvec_tile, (a, vec_n), {"bm": bm, "mode": mode}),
        "matvec_t_tile": (matvec_t_tile, (a, vec_m), {"bm": bm, "mode": mode}),
        "block_solve": (
            block_solve,
            (mat_g, vec_n, vec_n, vec_n, vec_n, par),
            {"cg_iters": cfg.cg_iters, "bn": nb, "mode": mode},
        ),
        "block_iteration": (
            block_iteration,
            (mat_g, a, vec_n, vec_m, vec_n, vec_n, par),
            {"cg_iters": cfg.cg_iters, "bn": nb, "bm": bm, "mode": mode},
        ),
        "omega_squared": (omega_squared, (vec_m, vec_m, par), {"bm": bm, "mode": mode}),
        "omega_logistic": (
            omega_logistic,
            (vec_m, vec_m, par),
            {"bm": bm, "iters": cfg.newton_iters, "mode": mode},
        ),
        "omega_hinge": (omega_hinge, (vec_m, vec_m, par), {"bm": bm, "mode": mode}),
        "omega_softmax": (
            omega_softmax,
            (mat_k, mat_k, par),
            {"bm": bm, "iters": cfg.newton_iters, "classes": k, "mode": mode},
        ),
    }


def sweep_registry(cfg: TileConfig, m_block_counts=(1, 2, 4), losses=("squared", "logistic", "hinge")):
    """Fused node_sweep artifacts: one per (M, loss) combination."""
    f32 = jnp.float32
    tm, nb = cfg.tile_m, cfg.block_n
    par = jax.ShapeDtypeStruct((P_SIZE, 1), f32)
    vec_m = jax.ShapeDtypeStruct((tm, 1), f32)
    out = {}
    for m in m_block_counts:
        a_t = tuple(jax.ShapeDtypeStruct((tm, nb), f32) for _ in range(m))
        g_t = tuple(jax.ShapeDtypeStruct((nb, nb), f32) for _ in range(m))
        x_t = tuple(jax.ShapeDtypeStruct((nb, 1), f32) for _ in range(m))
        w_t = tuple(jax.ShapeDtypeStruct((tm, 1), f32) for _ in range(m))
        for loss in losses:
            out[f"node_sweep_{loss}_m{m}"] = (
                node_sweep,
                (a_t, g_t, x_t, w_t, vec_m, vec_m, x_t, x_t, vec_m, par),
                {
                    "sweeps": cfg.inner_sweeps,
                    "cg_iters": cfg.cg_iters,
                    "bn": nb,
                    "bm": cfg.bm,
                    "iters": cfg.newton_iters,
                    "mode": cfg.mode,
                    "loss": loss,
                },
            )
    return out

"""Shared fixtures for the compile-path test suite."""

from __future__ import annotations

import jax
import numpy as np
import pytest

# f64 oracles need real double precision.
jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(20240607)


def make_matrix(rng, m, n, dtype=np.float32, normalize=True):
    """Standard-normal feature matrix with unit-l2 columns (paper §4)."""
    a = rng.normal(size=(m, n)).astype(dtype)
    if normalize:
        norms = np.linalg.norm(a, axis=0, keepdims=True)
        norms[norms == 0] = 1.0
        a = a / norms
    return a

"""Pallas kernels vs pure-jnp oracles — the CORE correctness signal.

Hypothesis sweeps shapes (within the tile grid constraints: rows divisible
by the row sub-tile, feature counts in lane multiples) and the numeric
regime of every kernel in ``compile.kernels``.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import gram as gram_k
from compile.kernels import matvec as mv_k
from compile.kernels import prox as prox_k
from compile.kernels import ref

from .conftest import make_matrix

# Small-but-representative tile grid for the sweeps (kernels are
# shape-generic; the AOT shapes are exercised in test_model/test_aot).
BMS = [8, 16, 32]
ROW_MULTIPLES = st.integers(min_value=1, max_value=6)
COLS = st.sampled_from([8, 16, 64, 128])


def _params(m_blocks, rho_l, rho_c=0.0, reg=0.0):
    p = np.zeros((8, 1), np.float32)
    p[0, 0], p[1, 0], p[2, 0], p[3, 0] = m_blocks, rho_l, rho_c, reg
    return jnp.asarray(p)


class TestMatvec:
    @settings(max_examples=20, deadline=None)
    @given(rows=ROW_MULTIPLES, n=COLS, bm=st.sampled_from(BMS), seed=st.integers(0, 2**31))
    def test_matvec_matches_ref(self, rows, n, bm, seed):
        rng = np.random.default_rng(seed)
        m = rows * bm
        a = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
        x = jnp.asarray(rng.normal(size=(n, 1)), jnp.float32)
        got = mv_k.matvec(a, x, bm=bm)
        np.testing.assert_allclose(got, ref.matvec(a, x), rtol=1e-4, atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(rows=ROW_MULTIPLES, n=COLS, bm=st.sampled_from(BMS), seed=st.integers(0, 2**31))
    def test_matvec_t_matches_ref(self, rows, n, bm, seed):
        rng = np.random.default_rng(seed)
        m = rows * bm
        a = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
        y = jnp.asarray(rng.normal(size=(m, 1)), jnp.float32)
        got = mv_k.matvec_t(a, y, bm=bm)
        np.testing.assert_allclose(got, ref.matvec_t(a, y), rtol=1e-3, atol=1e-3)

    @settings(max_examples=15, deadline=None)
    @given(rows=ROW_MULTIPLES, n=COLS, bm=st.sampled_from(BMS), seed=st.integers(0, 2**31))
    def test_fused_gram_matvec_matches_ref(self, rows, n, bm, seed):
        rng = np.random.default_rng(seed)
        m = rows * bm
        a = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
        x = jnp.asarray(rng.normal(size=(n, 1)), jnp.float32)
        got = mv_k.fused_gram_matvec(a, x, bm=bm)
        want = ref.gram(a) @ x
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_zero_padding_rows_is_exact(self, rng):
        """Padding rows with zeros must not change A^T y or A^T A."""
        a = make_matrix(rng, 48, 16)
        y = rng.normal(size=(48, 1)).astype(np.float32)
        a_pad = np.vstack([a, np.zeros((16, 16), np.float32)])
        y_pad = np.vstack([y, np.zeros((16, 1), np.float32)])
        got = mv_k.matvec_t(jnp.asarray(a_pad), jnp.asarray(y_pad), bm=16)
        want = ref.matvec_t(jnp.asarray(a), jnp.asarray(y))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


class TestGram:
    @settings(max_examples=15, deadline=None)
    @given(rows=ROW_MULTIPLES, n=COLS, bm=st.sampled_from(BMS), seed=st.integers(0, 2**31))
    def test_gram_matches_ref(self, rows, n, bm, seed):
        rng = np.random.default_rng(seed)
        m = rows * bm
        a = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
        np.testing.assert_allclose(
            gram_k.gram(a, bm=bm), ref.gram(a), rtol=1e-3, atol=1e-3
        )

    def test_gram_is_symmetric_psd(self, rng):
        a = jnp.asarray(make_matrix(rng, 64, 32))
        g = np.asarray(gram_k.gram(a, bm=16))
        np.testing.assert_allclose(g, g.T, atol=1e-6)
        eigs = np.linalg.eigvalsh(g.astype(np.float64))
        assert eigs.min() > -1e-5

    @settings(max_examples=15, deadline=None)
    @given(n=st.sampled_from([64, 128, 256]), bn=st.sampled_from([32, 64]), seed=st.integers(0, 2**31))
    def test_gemv_matches_ref(self, n, bn, seed):
        rng = np.random.default_rng(seed)
        g = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)
        x = jnp.asarray(rng.normal(size=(n, 1)), jnp.float32)
        np.testing.assert_allclose(
            gram_k.gemv(g, x, bn=bn), ref.gemv(g, x), rtol=1e-3, atol=1e-3
        )


class TestOmegaProx:
    """Each prox kernel must (a) match ref and (b) satisfy first-order
    optimality of  min_w phi(M w; b) + (M rho / 2)(w - c)^2."""

    @settings(max_examples=25, deadline=None)
    @given(
        m_blocks=st.sampled_from([1.0, 2.0, 4.0, 8.0]),
        rho=st.floats(0.5, 16.0),
        seed=st.integers(0, 2**31),
    )
    def test_squared_matches_ref_and_is_optimal(self, m_blocks, rho, seed):
        rng = np.random.default_rng(seed)
        b = jnp.asarray(rng.normal(size=(32, 1)), jnp.float32)
        c = jnp.asarray(rng.normal(size=(32, 1)), jnp.float32)
        got = prox_k.omega_squared(b, c, _params(m_blocks, rho), bm=8)
        want = ref.omega_squared(b, c, m_blocks, rho)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
        # optimality: 2M(Mw - b) + M rho (w - c) == 0
        w = np.asarray(got, np.float64)
        grad = 2 * m_blocks * (m_blocks * w - np.asarray(b)) + m_blocks * rho * (
            w - np.asarray(c)
        )
        np.testing.assert_allclose(grad, 0.0, atol=1e-3)

    @settings(max_examples=25, deadline=None)
    @given(
        m_blocks=st.sampled_from([1.0, 2.0, 4.0]),
        rho=st.floats(0.5, 8.0),
        seed=st.integers(0, 2**31),
    )
    def test_logistic_matches_ref_and_is_optimal(self, m_blocks, rho, seed):
        rng = np.random.default_rng(seed)
        b = jnp.asarray(np.where(rng.normal(size=(32, 1)) > 0, 1.0, -1.0), jnp.float32)
        c = jnp.asarray(rng.normal(size=(32, 1)), jnp.float32)
        got = prox_k.omega_logistic(b, c, _params(m_blocks, rho), bm=8, iters=12)
        want = ref.omega_logistic(b, c, m_blocks, rho, iters=40)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)
        w = np.asarray(got, np.float64)
        bb, cc = np.asarray(b, np.float64), np.asarray(c, np.float64)
        sig = 1.0 / (1.0 + np.exp(bb * m_blocks * w))
        grad = -m_blocks * bb * sig + m_blocks * rho * (w - cc)
        np.testing.assert_allclose(grad, 0.0, atol=1e-3)

    @settings(max_examples=25, deadline=None)
    @given(
        m_blocks=st.sampled_from([1.0, 2.0, 4.0]),
        rho=st.floats(0.5, 8.0),
        seed=st.integers(0, 2**31),
    )
    def test_hinge_matches_ref(self, m_blocks, rho, seed):
        rng = np.random.default_rng(seed)
        b = jnp.asarray(np.where(rng.normal(size=(32, 1)) > 0, 1.0, -1.0), jnp.float32)
        c = jnp.asarray(rng.normal(size=(32, 1)), jnp.float32)
        got = prox_k.omega_hinge(b, c, _params(m_blocks, rho), bm=8)
        want = ref.omega_hinge(b, c, m_blocks, rho)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_hinge_is_global_min_by_scan(self, rng):
        """Brute-force: the closed form beats a dense grid of candidates."""
        m_blocks, rho = 2.0, 3.0
        b = np.where(rng.normal(size=(16, 1)) > 0, 1.0, -1.0).astype(np.float32)
        c = rng.normal(size=(16, 1)).astype(np.float32)
        w = np.asarray(
            prox_k.omega_hinge(
                jnp.asarray(b), jnp.asarray(c), _params(m_blocks, rho), bm=8
            )
        )

        def h(wv):
            return np.maximum(0, 1 - b * m_blocks * wv) + m_blocks * rho / 2 * (
                wv - c
            ) ** 2

        h_star = h(w)
        grid = np.linspace(-4, 4, 801, dtype=np.float64)
        for g in grid:
            assert np.all(h_star <= h(np.full_like(w, g)) + 1e-4)

    @settings(max_examples=15, deadline=None)
    @given(
        m_blocks=st.sampled_from([1.0, 2.0, 4.0]),
        rho=st.floats(0.5, 8.0),
        k=st.sampled_from([4, 10]),
        seed=st.integers(0, 2**31),
    )
    def test_softmax_matches_ref_and_is_optimal(self, m_blocks, rho, k, seed):
        rng = np.random.default_rng(seed)
        y = jnp.asarray(np.eye(k, dtype=np.float32)[rng.integers(0, k, 24)])
        c = jnp.asarray(rng.normal(size=(24, k)), jnp.float32)
        got = prox_k.omega_softmax(
            y, c, _params(m_blocks, rho), bm=8, iters=12, classes=k
        )
        want = ref.omega_softmax(y, c, m_blocks, rho, iters=40)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=1e-3)
        w = np.asarray(got, np.float64)
        yy, cc = np.asarray(y, np.float64), np.asarray(c, np.float64)
        p = np.exp(m_blocks * w - (m_blocks * w).max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        grad = m_blocks * (p - yy) + m_blocks * rho * (w - cc)
        np.testing.assert_allclose(grad, 0.0, atol=5e-3)


class TestRefSelfConsistency:
    """The oracles themselves satisfy the optimality conditions in f64."""

    def test_block_solve_exact_stationarity(self, rng):
        n = 32
        a = make_matrix(rng, 96, n).astype(np.float64)
        g = jnp.asarray(a.T @ a)
        x_prev = jnp.asarray(rng.normal(size=(n, 1)))
        q = jnp.asarray(rng.normal(size=(n, 1)))
        z = jnp.asarray(rng.normal(size=(n, 1)))
        u = jnp.asarray(rng.normal(size=(n, 1)))
        rho_l, rho_c, reg = 2.0, 1.5, 1.7
        x = ref.block_solve_exact(g, x_prev, q, z, u, rho_l, rho_c, reg)
        # gradient of the quadratic: (rho_l G + reg I)x - rhs == 0
        lhs = rho_l * np.asarray(g) @ np.asarray(x) + reg * np.asarray(x)
        rhs = rho_l * (
            np.asarray(g) @ np.asarray(x_prev) + np.asarray(q)
        ) + rho_c * (np.asarray(z) - np.asarray(u))
        np.testing.assert_allclose(lhs, rhs, rtol=1e-9, atol=1e-9)

    def test_cg_converges_to_exact(self, rng):
        n = 48
        a = make_matrix(rng, 128, n).astype(np.float64)
        g = jnp.asarray(a.T @ a)
        args = [jnp.asarray(rng.normal(size=(n, 1))) for _ in range(4)]
        exact = ref.block_solve_exact(g, *args, 2.0, 1.0, 1.5)
        cg = ref.block_solve_cg(g, *args, 2.0, 1.0, 1.5, iters=n * 2)
        np.testing.assert_allclose(cg, exact, rtol=1e-8, atol=1e-8)

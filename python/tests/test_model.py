"""L2 tile programs vs exact solutions, and the AOT manifest contract."""

from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref
from compile.kernels.common import TileConfig

from .conftest import make_matrix


SMALL = TileConfig(tile_m=128, block_n=128, bm=32, cg_iters=40, newton_iters=8, classes=4)


class TestBlockSolve:
    def test_block_solve_matches_exact(self, rng):
        n = 128
        a = make_matrix(rng, 256, n)
        g = jnp.asarray((a.T @ a).astype(np.float32))
        x_prev = jnp.asarray(rng.normal(size=(n, 1)), jnp.float32)
        q = jnp.asarray(rng.normal(size=(n, 1)), jnp.float32)
        z = jnp.asarray(rng.normal(size=(n, 1)), jnp.float32)
        u = jnp.asarray(rng.normal(size=(n, 1)), jnp.float32)
        rho_l, rho_c, reg = 2.0, 1.0, 1.5
        params = model.make_params(4.0, rho_l, rho_c, reg)
        (x,) = model.block_solve(g, x_prev, q, z, u, params, cg_iters=80, bn=n)
        exact = ref.block_solve_exact(
            jnp.asarray(g, jnp.float64),
            jnp.asarray(x_prev, jnp.float64),
            jnp.asarray(q, jnp.float64),
            jnp.asarray(z, jnp.float64),
            jnp.asarray(u, jnp.float64),
            rho_l,
            rho_c,
            reg,
        )
        np.testing.assert_allclose(x, exact, rtol=1e-3, atol=1e-4)

    def test_block_solve_warm_start_is_fixed_point(self, rng):
        """If x_prev already solves the system, CG must not move it."""
        n = 64
        a = make_matrix(rng, 128, n)
        g64 = (a.T @ a).astype(np.float64)
        rho_l, rho_c, reg = 2.0, 1.0, 1.5
        q = rng.normal(size=(n, 1))
        z = rng.normal(size=(n, 1))
        u = rng.normal(size=(n, 1))
        # find the fixed point x*: (rho_l G + reg I) x* = rho_l(G x* + q) + rho_c(z-u)
        #   -> reg x* = rho_l q + rho_c (z - u)
        x_star = (rho_l * q + rho_c * (z - u)) / reg
        params = model.make_params(4.0, rho_l, rho_c, reg)
        (x,) = model.block_solve(
            jnp.asarray(g64, jnp.float32),
            jnp.asarray(x_star, jnp.float32),
            jnp.asarray(q, jnp.float32),
            jnp.asarray(z, jnp.float32),
            jnp.asarray(u, jnp.float32),
            params,
            cg_iters=5,
            bn=n,
        )
        np.testing.assert_allclose(x, x_star.astype(np.float32), rtol=1e-4, atol=1e-4)


class TestBlockIteration:
    def test_fused_equals_composition(self, rng):
        tm, nb = 64, 128
        a = jnp.asarray(make_matrix(rng, tm, nb))
        g = jnp.asarray(np.asarray(a.T @ a))
        x_prev = jnp.asarray(rng.normal(size=(nb, 1)), jnp.float32)
        corr = jnp.asarray(rng.normal(size=(tm, 1)), jnp.float32)
        z = jnp.asarray(rng.normal(size=(nb, 1)), jnp.float32)
        u = jnp.asarray(rng.normal(size=(nb, 1)), jnp.float32)
        params = model.make_params(2.0, 2.0, 1.0, 1.5)
        x_f, w_f = model.block_iteration(
            g, a, x_prev, corr, z, u, params, cg_iters=30, bn=nb, bm=32
        )
        (q,) = model.matvec_t_tile(a, corr, bm=32)
        (x_c,) = model.block_solve(g, x_prev, q, z, u, params, cg_iters=30, bn=nb)
        (w_c,) = model.matvec_tile(a, x_c, bm=32)
        np.testing.assert_allclose(x_f, x_c, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(w_f, w_c, rtol=1e-5, atol=1e-6)


class TestInnerAdmmSweep:
    """Compose the tile programs into the full Algorithm 2 and check that it
    solves the node subproblem (15) for the squared loss."""

    def test_inner_admm_solves_prox_problem(self, rng):
        m, n, blocks = 96, 64, 2
        nb = n // blocks
        n_nodes, gamma = 2.0, 10.0
        rho_c, rho_l = 1.0, 2.0
        reg = 1.0 / (n_nodes * gamma) + rho_c
        a = make_matrix(rng, m, n).astype(np.float64)
        b = rng.normal(size=(m, 1))
        z = rng.normal(size=(n, 1))
        u = rng.normal(size=(n, 1))

        # exact minimizer of (15): (2 A^T A + reg I) x = 2 A^T b + rho_c (z-u)
        h = 2 * a.T @ a + reg * np.eye(n)
        x_exact = np.linalg.solve(h, 2 * a.T @ b + rho_c * (z - u))

        # inner ADMM via tile programs (f32)
        a32 = a.astype(np.float32)
        params = model.make_params(float(blocks), rho_l, rho_c, reg)
        xs = [np.zeros((nb, 1), np.float32) for _ in range(blocks)]
        ws = [np.zeros((m, 1), np.float32) for _ in range(blocks)]
        omega = np.zeros((m, 1), np.float32)
        nu = np.zeros((m, 1), np.float32)
        ablocks = [a32[:, j * nb : (j + 1) * nb] for j in range(blocks)]
        grams = [np.asarray(model.gram_tile(jnp.asarray(aj), bm=32)[0]) for aj in ablocks]
        zs = [z[j * nb : (j + 1) * nb].astype(np.float32) for j in range(blocks)]
        us = [u[j * nb : (j + 1) * nb].astype(np.float32) for j in range(blocks)]

        for _ in range(60):
            wbar = sum(ws) / blocks
            corr = omega - wbar - nu
            for j in range(blocks):
                (q,) = model.matvec_t_tile(jnp.asarray(ablocks[j]), jnp.asarray(corr), bm=32)
                (xj,) = model.block_solve(
                    jnp.asarray(grams[j]),
                    jnp.asarray(xs[j]),
                    q,
                    jnp.asarray(zs[j]),
                    jnp.asarray(us[j]),
                    params,
                    cg_iters=40,
                    bn=nb,
                )
                xs[j] = np.asarray(xj)
                ws[j] = np.asarray(model.matvec_tile(jnp.asarray(ablocks[j]), xj, bm=32)[0])
            wbar = sum(ws) / blocks
            c = wbar + nu
            (omega_j,) = model.omega_squared(
                jnp.asarray(b, jnp.float32), jnp.asarray(c), params, bm=32
            )
            omega = np.asarray(omega_j)
            nu = nu + wbar - omega

        x_admm = np.vstack(xs)
        np.testing.assert_allclose(x_admm, x_exact, rtol=5e-3, atol=5e-3)


class TestAotManifest:
    @pytest.fixture(scope="class")
    def built(self, tmp_path_factory):
        out = tmp_path_factory.mktemp("artifacts")
        manifest = aot.build(out, SMALL, verbose=False)
        return out, manifest

    def test_manifest_lists_all_programs(self, built):
        _, manifest = built
        expected = set(model.program_registry(SMALL).keys())
        expected |= set(model.sweep_registry(SMALL).keys())
        assert set(manifest["artifacts"].keys()) == expected

    def test_files_exist_and_are_hlo_text(self, built):
        out, manifest = built
        for name, art in manifest["artifacts"].items():
            p = out / art["file"]
            assert p.exists(), name
            head = p.read_text()[:200]
            assert "HloModule" in head, name

    def test_manifest_shapes_match_registry(self, built):
        import jax

        _, manifest = built
        reg = dict(model.program_registry(SMALL))
        reg.update(model.sweep_registry(SMALL))
        for name, art in manifest["artifacts"].items():
            _, args, _ = reg[name]
            leaves = jax.tree_util.tree_leaves(list(args))
            assert len(art["inputs"]) == len(leaves)
            for spec, aval in zip(art["inputs"], leaves):
                assert spec["shape"] == list(aval.shape)
                assert spec["dtype"] == "float32"

    def test_fingerprint_stable(self, built):
        _, manifest = built
        assert manifest["fingerprint"] == aot.source_fingerprint()

    def test_roundtrip_is_noop(self, built, capfd):
        out, manifest = built
        on_disk = json.loads((pathlib.Path(out) / "manifest.json").read_text())
        assert on_disk["fingerprint"] == manifest["fingerprint"]

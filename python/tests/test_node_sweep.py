"""The fused node_sweep program vs the granular tile-program composition,
in both lowering modes — the contract the Rust fused path relies on."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

from .conftest import make_matrix


def manual_sweeps(a_blocks, b, z_blocks, u_blocks, params, *, sweeps, cg_iters, bn, bm, mode, loss):
    """Compose the granular programs exactly as admm::local does."""
    M = len(a_blocks)
    tm = a_blocks[0].shape[0]
    nb = a_blocks[0].shape[1]
    grams = [np.asarray(model.gram_tile(a, bm=bm, mode=mode)[0]) for a in a_blocks]
    xs = [np.zeros((nb, 1), np.float32) for _ in range(M)]
    ws = [np.zeros((tm, 1), np.float32) for _ in range(M)]
    omega = np.zeros((tm, 1), np.float32)
    nu = np.zeros((tm, 1), np.float32)
    omega_fn = {
        "squared": model.omega_squared,
        "logistic": model.omega_logistic,
        "hinge": model.omega_hinge,
    }[loss]
    for _ in range(sweeps):
        wbar = sum(ws) / M
        corr = omega - wbar - nu
        for j in range(M):
            (q,) = model.matvec_t_tile(a_blocks[j], jnp.asarray(corr), bm=bm, mode=mode)
            (xj,) = model.block_solve(
                jnp.asarray(grams[j]), jnp.asarray(xs[j]), q,
                z_blocks[j], u_blocks[j], params, cg_iters=cg_iters, bn=nb, mode=mode,
            )
            xs[j] = np.asarray(xj)
            ws[j] = np.asarray(model.matvec_tile(a_blocks[j], xj, bm=bm, mode=mode)[0])
        wbar = sum(ws) / M
        c = jnp.asarray(wbar + nu)
        omega = np.asarray(omega_fn(jnp.asarray(b), c, params, bm=bm, mode=mode)[0])
        nu = nu + wbar - omega
    return xs, ws, omega, nu


@pytest.mark.parametrize("mode", ["xla", "pallas"])
@pytest.mark.parametrize("loss", ["squared", "logistic", "hinge"])
@pytest.mark.parametrize("m_blocks", [1, 2])
def test_node_sweep_equals_composition(rng, mode, loss, m_blocks):
    tm, nb, sweeps, cg = 64, 32, 2, 30
    a_blocks = tuple(jnp.asarray(make_matrix(rng, tm, nb)) for _ in range(m_blocks))
    g_blocks = tuple(
        model.gram_tile(a, bm=16, mode=mode)[0] for a in a_blocks
    )
    x0 = tuple(jnp.zeros((nb, 1), jnp.float32) for _ in range(m_blocks))
    w0 = tuple(jnp.zeros((tm, 1), jnp.float32) for _ in range(m_blocks))
    omega0 = jnp.zeros((tm, 1), jnp.float32)
    nu0 = jnp.zeros((tm, 1), jnp.float32)
    z = tuple(jnp.asarray(rng.normal(size=(nb, 1)), jnp.float32) for _ in range(m_blocks))
    u = tuple(jnp.asarray(rng.normal(size=(nb, 1)) * 0.1, jnp.float32) for _ in range(m_blocks))
    if loss == "squared":
        b = jnp.asarray(rng.normal(size=(tm, 1)), jnp.float32)
    else:
        b = jnp.asarray(np.where(rng.normal(size=(tm, 1)) > 0, 1.0, -1.0), jnp.float32)
    params = model.make_params(float(m_blocks), 2.0, 1.0, 1.05)

    out = model.node_sweep(
        a_blocks, g_blocks, x0, w0, omega0, nu0, z, u, b, params,
        sweeps=sweeps, cg_iters=cg, bn=nb, bm=16, iters=8, mode=mode, loss=loss,
    )
    xs = out[:m_blocks]
    ws = out[m_blocks : 2 * m_blocks]
    omega, nu = out[2 * m_blocks], out[2 * m_blocks + 1]

    xs2, ws2, omega2, nu2 = manual_sweeps(
        a_blocks, b, z, u, params,
        sweeps=sweeps, cg_iters=cg, bn=nb, bm=16, mode=mode, loss=loss,
    )
    for j in range(m_blocks):
        np.testing.assert_allclose(xs[j], xs2[j], rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(ws[j], ws2[j], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(omega, omega2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(nu, nu2, rtol=1e-4, atol=1e-5)


def test_modes_agree_with_each_other(rng):
    """The xla and pallas lowerings are the same math."""
    tm, nb = 64, 32
    a = (jnp.asarray(make_matrix(rng, tm, nb)),)
    g = (model.gram_tile(a[0], bm=16, mode="xla")[0],)
    x0 = (jnp.zeros((nb, 1), jnp.float32),)
    w0 = (jnp.zeros((tm, 1), jnp.float32),)
    z = (jnp.asarray(rng.normal(size=(nb, 1)), jnp.float32),)
    zero = jnp.zeros((tm, 1), jnp.float32)
    b = jnp.asarray(rng.normal(size=(tm, 1)), jnp.float32)
    params = model.make_params(1.0, 2.0, 1.0, 1.05)
    kw = dict(sweeps=2, cg_iters=30, bn=nb, bm=16, iters=8, loss="squared")
    out_x = model.node_sweep(a, g, x0, w0, zero, zero, z, z, b, params, mode="xla", **kw)
    out_p = model.node_sweep(a, g, x0, w0, zero, zero, z, z, b, params, mode="pallas", **kw)
    for ax, ap in zip(out_x, out_p):
        np.testing.assert_allclose(ax, ap, rtol=1e-4, atol=1e-5)

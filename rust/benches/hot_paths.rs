//! Micro-benchmarks of the iteration hot paths (criterion-style harness
//! from `psfit::util::bench`; criterion itself is unavailable offline).
//!
//! Groups:
//!   linalg       — native matvec / gram / Cholesky primitives
//!   sparsity     — l1-ball & epigraph projections, s-update (coordinator)
//!   global       — the full (z,t,s,v) coordinator update at paper dims
//!   block        — native block_step (the per-device inner op)
//!   omega        — separable prox per loss
//!   xla          — artifact execution (block_iteration, node_sweep) if
//!                  artifacts are built
//!
//! Run: `cargo bench --bench hot_paths [-- <group-filter>]`

use std::time::Duration;

use psfit::backend::native::{NativeBackend, SolveMode};
use psfit::backend::{BlockParams, NodeBackend};
use psfit::data::{FeaturePlan, SyntheticSpec};
use psfit::linalg::{Cholesky, Matrix};
use psfit::losses::{Hinge, Logistic, Loss, Squared};
use psfit::sparsity;
use psfit::util::bench::bench;
use psfit::util::rng::Rng;

const TARGET: Duration = Duration::from_millis(300);

fn filter_match(filter: &Option<String>, group: &str) -> bool {
    filter.as_deref().map_or(true, |f| group.contains(f))
}

fn main() {
    let filter = std::env::args().skip(1).find(|a| a != "--bench");
    let mut rng = Rng::seed_from(42);

    if filter_match(&filter, "linalg") {
        println!("\n== linalg ==");
        let a = {
            let mut m = Matrix::zeros(2048, 512);
            m.for_each_mut(|v| *v = rng.normal_f32());
            m
        };
        let x: Vec<f32> = (0..512).map(|_| rng.normal_f32()).collect();
        let mut y = vec![0.0f32; 2048];
        println!("{}", bench("matvec 2048x512", TARGET, || a.matvec(&x, &mut y)).report());
        let mut q = vec![0.0f32; 512];
        println!(
            "{}",
            bench("matvec_t 2048x512", TARGET, || a.matvec_t(&y, &mut q)).report()
        );
        let mut g = vec![0.0f32; 512 * 512];
        println!(
            "{}",
            bench("gram 2048x512 (setup op)", Duration::from_millis(600), || {
                g.fill(0.0);
                a.gram_accumulate(&mut g);
            })
            .report()
        );
        let mut h = vec![0.0f64; 512 * 512];
        for i in 0..512 {
            for j in 0..512 {
                h[i * 512 + j] = 2.0 * g[i * 512 + j] as f64;
            }
            h[i * 512 + i] += 1.5;
        }
        println!(
            "{}",
            bench("cholesky factor 512", Duration::from_millis(600), || {
                let _ = Cholesky::factor(&h, 512).unwrap();
            })
            .report()
        );
    }

    if filter_match(&filter, "sparsity") {
        println!("\n== sparsity (coordinator geometry) ==");
        for n in [1000usize, 4000, 10000] {
            let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            println!(
                "{}",
                bench(&format!("project_l1_ball n={n}"), TARGET, || {
                    let _ = sparsity::project_l1_ball(&v, 10.0);
                })
                .report()
            );
            println!(
                "{}",
                bench(&format!("project_l1_epigraph n={n}"), TARGET, || {
                    let _ = sparsity::project_l1_epigraph(&v, 5.0);
                })
                .report()
            );
            println!(
                "{}",
                bench(&format!("s_update n={n} kappa={}", n / 5), TARGET, || {
                    let _ = sparsity::s_update(&v, 3.0, n / 5);
                })
                .report()
            );
        }
    }

    if filter_match(&filter, "global") {
        println!("\n== global (z,t,s,v) update at paper dims ==");
        for n in [2000usize, 4000] {
            let mut g = psfit::admm::GlobalState::new(n);
            let c: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            g.s = sparsity::s_update(&c, 2.0, n / 5);
            println!(
                "{}",
                bench(&format!("zt_update n={n} (80 PG iters)"), TARGET, || {
                    g.zt_update(&c, 4, 2.0, 1.0, 80);
                })
                .report()
            );
            println!(
                "{}",
                bench(&format!("s_update+v n={n}"), TARGET, || {
                    g.s_update(n / 5);
                    g.v_update();
                })
                .report()
            );
        }
    }

    if filter_match(&filter, "block") {
        println!("\n== native block_step (per-device inner op) ==");
        let spec = SyntheticSpec::regression(512, 2048, 1);
        let ds = spec.generate();
        let plan = FeaturePlan::new(512, 1, 1 << 20);
        let params = BlockParams {
            rho_l: 2.0,
            rho_c: 2.0,
            reg: 2.025,
        };
        for (label, mode) in [
            ("cg24", SolveMode::Cg { iters: 24 }),
            ("direct", SolveMode::Direct),
        ] {
            let mut be = NativeBackend::new(&ds.shards[0], &plan, Box::new(Squared), mode);
            let corr: Vec<f32> = (0..2048).map(|_| rng.normal_f32()).collect();
            let z = vec![0.1f32; 512];
            let u = vec![0.0f32; 512];
            let mut x = vec![0.0f32; 512];
            let mut pred = vec![0.0f32; 2048];
            println!(
                "{}",
                bench(&format!("block_step 2048x512 {label}"), TARGET, || {
                    be.block_step(0, params, &corr, &z, &u, &mut x, &mut pred);
                })
                .report()
            );
        }
    }

    if filter_match(&filter, "omega") {
        println!("\n== omega prox (per-sample separable) ==");
        let m = 8192;
        let labels: Vec<f32> = (0..m)
            .map(|_| if rng.uniform() < 0.5 { -1.0 } else { 1.0 })
            .collect();
        let c: Vec<f32> = (0..m).map(|_| rng.normal_f32()).collect();
        let mut out = vec![0.0f32; m];
        for (name, loss) in [
            ("squared", &Squared as &dyn Loss),
            ("logistic", &Logistic),
            ("hinge", &Hinge),
        ] {
            println!(
                "{}",
                bench(&format!("omega_{name} m=8192"), TARGET, || {
                    loss.omega_update(&labels, &c, 2.0, 2.0, &mut out);
                })
                .report()
            );
        }
    }

    if filter_match(&filter, "xla") {
        let dir = psfit::driver::default_artifacts_dir();
        if dir.join("manifest.json").exists() {
            println!("\n== xla artifact execution ==");
            let spec = SyntheticSpec::regression(512, 2048, 1);
            let ds = spec.generate();
            let rt = std::rc::Rc::new(psfit::runtime::XlaRuntime::open(&dir).unwrap());
            let plan = FeaturePlan::new(512, 1, rt.manifest().block_n);
            let mut be =
                psfit::backend::xla::XlaBackend::new(rt, &ds.shards[0], &plan, Box::new(Squared))
                    .unwrap();
            let params = BlockParams {
                rho_l: 2.0,
                rho_c: 2.0,
                reg: 2.025,
            };
            let corr: Vec<f32> = (0..2048).map(|_| rng.normal_f32()).collect();
            let z = vec![0.1f32; 512];
            let u = vec![0.0f32; 512];
            let mut x = vec![0.0f32; 512];
            let mut pred = vec![0.0f32; 2048];
            println!(
                "{}",
                bench(
                    "xla block_iteration 8192x512 (padded)",
                    Duration::from_secs(2),
                    || {
                        be.block_step(0, params, &corr, &z, &u, &mut x, &mut pred);
                    }
                )
                .report()
            );
            let z_blocks = vec![z.clone()];
            let u_blocks = vec![u.clone()];
            let mut x_blocks = vec![x.clone()];
            let mut preds = vec![pred.clone()];
            let mut omega = vec![0.0f32; 2048];
            let mut nu = vec![0.0f32; 2048];
            println!(
                "{}",
                bench("xla node_sweep M=1 (3 sweeps)", Duration::from_secs(2), || {
                    let ok = be.node_sweep(
                        params,
                        3,
                        &z_blocks,
                        &u_blocks,
                        &mut x_blocks,
                        &mut preds,
                        &mut omega,
                        &mut nu,
                    );
                    assert!(ok);
                })
                .report()
            );
        } else {
            eprintln!("(xla group skipped: run `make artifacts`)");
        }
    }
}

//! End-to-end benchmark per paper table/figure — `cargo bench` entry point.
//!
//! Runs each experiment harness at REDUCED sizes (bench-budget versions of
//! the `psfit fig1..fig4 / table1` commands, which remain the full
//! regeneration path) and prints the same rows the paper reports.  The
//! point of this binary is CI-sized evidence that every harness runs and
//! produces the paper's qualitative shape; EXPERIMENTS.md records a full
//! run of the real harnesses.
//!
//! Run: `cargo bench --bench paper_tables [-- <filter>]`

use psfit::config::BackendKind;
use psfit::harness;

fn filter_match(filter: &Option<String>, group: &str) -> bool {
    filter.as_deref().map_or(true, |f| group.contains(f))
}

fn main() -> anyhow::Result<()> {
    let filter = std::env::args().skip(1).find(|a| a != "--bench");
    let artifacts = psfit::driver::default_artifacts_dir()
        .join("manifest.json")
        .exists();

    if filter_match(&filter, "fig1") {
        println!("\n===== Figure 1 (residuals vs rho_b) — bench-sized =====");
        let opts = harness::fig1::Fig1Opts {
            full: false,
            iters: 25,
            backend: BackendKind::Native,
            out: None,
        };
        let t = harness::fig1(&opts)?;
        // print the last row of each rho_b series (the converged residuals)
        let mut last: std::collections::BTreeMap<String, Vec<String>> = Default::default();
        for row in &t.rows {
            last.insert(row[0].clone(), row.clone());
        }
        println!("rho_b   iter   primal       dual         bilinear");
        for (_, row) in last {
            println!("{:<7} {:<6} {:<12} {:<12} {}", row[0], row[1], row[2], row[3], row[4]);
        }
    }

    if filter_match(&filter, "table1") {
        println!("\n===== Table 1 (Bi-cADMM vs MIP vs Lasso) — bench-sized =====");
        let opts = harness::table1::Table1Opts {
            full: false,
            backend: if artifacts {
                BackendKind::Xla
            } else {
                BackendKind::Native
            },
            mip_budget: 20.0,
            out: None,
        };
        let t = table1_reduced(&opts)?;
        println!("{}", t.to_pretty());
    }

    if filter_match(&filter, "fig23") {
        println!("\n===== Figures 2 & 3 (scaling) — bench-sized =====");
        if artifacts {
            let opts = harness::scaling::ScalingOpts {
                full: false,
                iters: 5,
                out: None,
            };
            let t = harness::fig2(&opts)?;
            println!("{}", t.to_pretty());
        } else {
            eprintln!("(skipped: run `make artifacts`)");
        }
    }

    Ok(())
}

/// Table 1 on an even smaller grid than the CLI default (bench budget).
fn table1_reduced(opts: &harness::table1::Table1Opts) -> anyhow::Result<psfit::metrics::CsvTable> {
    use psfit::baselines::{best_subset_bnb, lasso_path, BnbStatus};
    use psfit::config::Config;
    use psfit::data::SyntheticSpec;
    use psfit::metrics::CsvTable;
    use psfit::sparsity::support_f1;
    use psfit::util::Stopwatch;

    let mut table = CsvTable::new(&[
        "s_l", "m", "n", "bicadmm_s", "bicadmm_f1", "mip_s", "mip_status", "lasso_s",
        "lasso_recovered",
    ]);
    for &sl in &[0.6, 0.9] {
        let (m, n) = (2000usize, 128usize);
        let mut spec = SyntheticSpec::regression(n, m, 4);
        spec.sparsity_level = sl;
        spec.noise_std = 0.05;
        let ds = spec.generate();
        let kappa = spec.kappa();

        let mut cfg = Config::default();
        cfg.platform.nodes = 4;
        cfg.platform.backend = opts.backend;
        cfg.solver.kappa = kappa;
        cfg.solver.rho_c = 2.0;
        cfg.solver.rho_b = 1.0;
        cfg.solver.rho_l = 2.0;
        cfg.solver.max_iters = 120;
        cfg.solver.polish = false;
        let run = harness::run_timed(&ds, &cfg, true)?;
        let f1 = support_f1(&run.result.support, &ds.support_true);

        let (a, b) = ds.stacked();
        let mip = best_subset_bnb(&a, &b, kappa, cfg.solver.gamma, opts.mip_budget);
        let mip_status = match mip.status {
            BnbStatus::Optimal => "optimal".to_string(),
            BnbStatus::CutOff => "cut off".to_string(),
        };
        let watch = Stopwatch::start();
        let lasso = lasso_path(&a, &b, kappa, 40, 200);
        let lasso_s = watch.elapsed_secs();
        let lasso_top = {
            let mut idx = psfit::sparsity::top_k_indices(&lasso.x, kappa);
            idx.sort_unstable();
            idx
        };
        let recovered = lasso_top == ds.support_true;
        table.row(vec![
            format!("{sl}"),
            m.to_string(),
            n.to_string(),
            format!("{:.2}", run.solve_seconds),
            format!("{f1:.3}"),
            format!("{:.1}", mip.wall_seconds),
            mip_status,
            format!("{:.2}{}", lasso_s, if recovered { "" } else { "*" }),
            recovered.to_string(),
        ]);
    }
    Ok(table)
}

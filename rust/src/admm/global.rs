//! Coordinator-side (global node) updates of Bi-cADMM.
//!
//! These are the "cost-effective computations" the paper keeps on CPUs:
//! they touch only coefficient-space vectors (length n), never the data.

use crate::linalg::ops;
use crate::metrics::IterRecord;
use crate::sparsity::{self, project_l1_epigraph};

/// Global variables (z, t, s, v) plus the previous z for the dual residual.
///
/// The struct is `Clone` and all fields are public so the path subsystem
/// can snapshot it between path points (warm starts) and the checkpoint
/// layer can serialize it bit-exactly — see `path::checkpoint`.
#[derive(Debug, Clone, PartialEq)]
pub struct GlobalState {
    /// Consensus iterate z (class-major flattened, length n * width).
    pub z: Vec<f64>,
    /// Epigraph variable t (the l1-norm surrogate, Eq. 7b).
    pub t: f64,
    /// Bi-linear certificate s in S^kappa (Eq. 7c/12).
    pub s: Vec<f64>,
    /// Scaled bilinear multiplier v = lambda / rho_b (Eq. 11/13).
    pub v: f64,
    /// z at the previous iteration — the dual residual (Eq. 14) measures
    /// `rho_c ||z - z_prev||`.  Serialized with the rest of the state so a
    /// resumed solve reports the same first-round residuals.
    pub z_prev: Vec<f64>,
}

impl GlobalState {
    /// Fresh (cold-start) state: every variable zero.
    pub fn new(dim: usize) -> GlobalState {
        GlobalState {
            z: vec![0.0; dim],
            t: 0.0,
            s: vec![0.0; dim],
            v: 0.0,
            z_prev: vec![0.0; dim],
        }
    }

    /// The (z, t)-update (7b): minimize
    ///   F(z, t) = (N rho_c / 2) ||z - c||^2
    ///           + (rho_b / 2) (z^T s - t + v)^2
    /// over the l1 epigraph {||z||_1 <= t}, where `c = mean_i(x_i + u_i)`.
    ///
    /// Solved by FISTA with the exact epigraph projection; the gradient is
    ///   dF/dz = N rho_c (z - c) + rho_b g s,   dF/dt = -rho_b g,
    /// with g = z^T s - t + v, and the Lipschitz constant is bounded by
    ///   L <= N rho_c + rho_b (||s||^2 + 1).
    /// Warm-started from the previous (z, t); `iters` projected-gradient
    /// steps (paper: "convex QP performed on a coordinator node").
    pub fn zt_update(&mut self, c: &[f64], n_nodes: usize, rho_c: f64, rho_b: f64, iters: usize) {
        let dim = self.z.len();
        assert_eq!(c.len(), dim);
        self.z_prev.copy_from_slice(&self.z);

        let n_rho = n_nodes as f64 * rho_c;
        let s_sq = ops::dot(&self.s, &self.s);
        let lip = n_rho + rho_b * (s_sq + 1.0);
        if !lip.is_finite() {
            // penalty overflow: no usable step size exists.  Poison the
            // iterate explicitly so the solver's divergence watchdog
            // trips on the residuals, instead of freezing z in place and
            // "converging" at a zero dual residual.
            self.poison();
            return;
        }
        let step = 1.0 / lip;

        // FISTA state: y = extrapolated point
        let mut zy = self.z.clone();
        let mut ty = self.t;
        let mut z_old = self.z.clone();
        let mut t_old = self.t;
        let mut theta = 1.0f64;
        let mut grad = vec![0.0; dim];

        for _ in 0..iters {
            let g = ops::dot(&zy, &self.s) - ty + self.v;
            for i in 0..dim {
                grad[i] = n_rho * (zy[i] - c[i]) + rho_b * g * self.s[i];
            }
            let gt = -rho_b * g;
            // gradient step then epigraph projection
            for i in 0..dim {
                zy[i] -= step * grad[i];
            }
            let t_cand = ty - step * gt;
            if !t_cand.is_finite() || zy.iter().any(|v| !v.is_finite()) {
                // mid-descent overflow (huge penalties, poisoned s):
                // never feed non-finite values to the projection —
                // poison the iterate for the watchdog instead
                self.poison();
                return;
            }
            let (z_new, t_new) = project_l1_epigraph(&zy, t_cand);

            // FISTA extrapolation
            let theta_new = 0.5 * (1.0 + (1.0 + 4.0 * theta * theta).sqrt());
            let beta = (theta - 1.0) / theta_new;
            for i in 0..dim {
                zy[i] = z_new[i] + beta * (z_new[i] - z_old[i]);
            }
            ty = t_new + beta * (t_new - t_old);
            z_old = z_new;
            t_old = t_new;
            theta = theta_new;
        }
        self.z = z_old;
        self.t = t_old;
    }

    /// Mark the iterate as numerically dead: the (z, t) pair becomes NaN
    /// so every residual computed from it is NaN and the solver's
    /// divergence watchdog trips on the next check.
    fn poison(&mut self) {
        self.z.iter_mut().for_each(|v| *v = f64::NAN);
        self.t = f64::NAN;
    }

    /// The s-update (7c)/(12): closed form over S^kappa.
    pub fn s_update(&mut self, kappa: usize) {
        self.s = sparsity::s_update(&self.z, self.t - self.v, kappa);
    }

    /// Scaled bilinear dual update (13): v += g(z, s, t).
    pub fn v_update(&mut self) {
        self.v += self.bilinear_residual_signed();
    }

    /// Signed value of the bilinear constraint g(z, s, t) = z^T s - t.
    pub fn bilinear_residual_signed(&self) -> f64 {
        sparsity::bilinear_g(&self.z, &self.s, self.t)
    }

    /// Residuals (Eq. 14).  `xs` yields the collected x_i^{k+1}, borrowed
    /// from the transport's reply buffers (the solver recycles those
    /// buffers after this call instead of consuming them).  Taking an
    /// iterator lets the solver stream straight out of the reply list —
    /// no per-round `Vec<&[f64]>` marshalling allocation.
    pub fn residuals<'a, I>(&self, xs: I, rho_c: f64, iter: usize, wall: f64) -> IterRecord
    where
        I: ExactSizeIterator<Item = &'a [f64]>,
    {
        let participants = xs.len();
        let primal: f64 = xs.map(|x| ops::dist2(x, &self.z).sqrt()).sum();
        let dual =
            (participants as f64).sqrt() * rho_c * ops::dist2(&self.z, &self.z_prev).sqrt();
        IterRecord {
            iter,
            primal,
            dual,
            bilinear: self.bilinear_residual_signed().abs(),
            wall,
            participants,
            max_lag: 0,
            restarts: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn zt_update_shrinks_toward_c_with_zero_s() {
        // with s = 0, v = 0:  F = (N rho_c / 2)||z - c||^2 + (rho_b/2) t^2,
        // so the optimum has t = ||z||_1 (boundary) and z is a shrunken c
        // (the t^2 term penalizes ||z||_1^2).  Check the shrinkage
        // structure and first-order optimality of the scalarized problem.
        let mut g = GlobalState::new(3);
        let c = vec![0.5, -0.25, 0.0];
        let (n_nodes, rho_c, rho_b) = (2, 1.0, 0.5);
        g.zt_update(&c, n_nodes, rho_c, rho_b, 500);
        let l1: f64 = g.z.iter().map(|v| v.abs()).sum();
        assert!((g.t - l1).abs() < 1e-5, "t should sit on the boundary");
        // shrinkage: same signs, smaller magnitudes
        for (zi, ci) in g.z.iter().zip(&c) {
            assert!(zi.abs() <= ci.abs() + 1e-9);
            assert!(zi * ci >= -1e-12);
        }
        // stationarity on the active coordinates of
        //   N rho_c/2 ||z - c||^2 + rho_b/2 (sum |z_i|)^2:
        //   N rho_c (z_i - c_i) + rho_b * l1 * sign(z_i) = 0
        for (zi, ci) in g.z.iter().zip(&c) {
            if zi.abs() > 1e-9 {
                let grad = n_nodes as f64 * rho_c * (zi - ci) + rho_b * l1 * zi.signum();
                assert!(grad.abs() < 1e-4, "grad {grad}");
            }
        }
    }

    #[test]
    fn zt_update_result_is_feasible_and_stationary() {
        let mut rng = Rng::seed_from(4);
        let dim = 24;
        let mut g = GlobalState::new(dim);
        g.s = sparsity::s_update(
            &(0..dim).map(|_| rng.normal()).collect::<Vec<_>>(),
            2.0,
            6,
        );
        g.v = 0.3;
        let c: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        let (n_nodes, rho_c, rho_b) = (4, 1.5, 0.75);
        g.zt_update(&c, n_nodes, rho_c, rho_b, 800);

        // feasibility
        let l1: f64 = g.z.iter().map(|v| v.abs()).sum();
        assert!(l1 <= g.t + 1e-8, "infeasible: {l1} > {}", g.t);

        // stationarity: projected gradient step must be a fixed point
        let n_rho = n_nodes as f64 * rho_c;
        let gg = ops::dot(&g.z, &g.s) - g.t + g.v;
        let step = 1e-3;
        let zc: Vec<f64> = (0..dim)
            .map(|i| g.z[i] - step * (n_rho * (g.z[i] - c[i]) + rho_b * gg * g.s[i]))
            .collect();
        let tc = g.t - step * (-rho_b * gg);
        let (zp, tp) = project_l1_epigraph(&zc, tc);
        assert!(ops::dist2(&zp, &g.z).sqrt() < 1e-5, "z moved");
        assert!((tp - g.t).abs() < 1e-5, "t moved");
    }

    #[test]
    fn s_and_v_updates_drive_bilinear_residual() {
        let mut g = GlobalState::new(4);
        g.z = vec![2.0, 0.0, -1.0, 0.1];
        g.t = 2.5;
        g.s_update(2);
        // target t - v = 2.5 reachable (mx = 3) -> residual 0
        assert!(g.bilinear_residual_signed().abs() < 1e-12);
        g.v_update();
        assert!(g.v.abs() < 1e-12);
    }

    #[test]
    fn residual_record_shapes() {
        let mut g = GlobalState::new(2);
        g.z = vec![1.0, 0.0];
        let xs: Vec<&[f64]> = vec![&[1.0, 0.0], &[0.0, 0.0]];
        let rec = g.residuals(xs.iter().copied(), 2.0, 7, 0.5);
        assert_eq!(rec.iter, 7);
        assert!((rec.primal - 1.0).abs() < 1e-12); // ||x_2 - z|| = 1
        // dual: z_prev = 0 -> sqrt(2) * 2 * 1 = 2 sqrt 2
        assert!((rec.dual - 2.0 * 2.0f64.sqrt()).abs() < 1e-12);
    }
}

//! Poison quarantine: numerical validation of node replies before they
//! touch [`super::GlobalState`].
//!
//! One NaN in a reply would propagate through the consensus average into
//! `z` and silently poison every later iterate, so [`ReplyGuard::screen`]
//! checks every collected `(x_i, u_i)` for non-finite values and norm
//! blowups *before* the fold.  A poisoned reply is quarantined — removed
//! from the round exactly like a degraded peer under the
//! participant-weighted averaging, with the count surfaced through
//! [`crate::metrics::CoordinationStats::quarantined`] — and a node that
//! stays poisoned for `platform.quarantine_limit` consecutive rounds is
//! banished via [`Cluster::banish`]: a structured death that the socket
//! transport's rejoin/resync machinery may later heal.

use crate::network::{Cluster, NodeReply};

/// Infinity-norm cap above which a finite reply still counts as poisoned.
/// Anything past this is numerically meaningless for a consensus average
/// (squaring it in a residual already overflows to infinity), but the cap
/// is far beyond any legitimate iterate, so healthy solves never trip it.
pub const NORM_CAP: f64 = 1e150;

/// Why a reply was quarantined.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoisonKind {
    /// A NaN or infinity in `x` or `u`.
    NonFinite,
    /// Every value finite, but the infinity norm exceeds [`NORM_CAP`].
    NormBlowup,
}

impl std::fmt::Display for PoisonKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PoisonKind::NonFinite => write!(f, "non-finite value"),
            PoisonKind::NormBlowup => write!(f, "norm blowup past {NORM_CAP:e}"),
        }
    }
}

/// Inspect one reply; `None` means clean.
pub fn poison_of(reply: &NodeReply) -> Option<PoisonKind> {
    let mut max = 0.0f64;
    for v in reply.x.iter().chain(reply.u.iter()) {
        if !v.is_finite() {
            return Some(PoisonKind::NonFinite);
        }
        max = max.max(v.abs());
    }
    if max > NORM_CAP {
        return Some(PoisonKind::NormBlowup);
    }
    None
}

/// Per-solve reply screen with consecutive-offense tracking.
#[derive(Debug, Default)]
pub struct ReplyGuard {
    /// `platform.quarantine_limit`: consecutive poisoned replies that
    /// banish a node.  `0` quarantines forever without banishing.
    limit: u64,
    /// Consecutive poisoned replies per node; a clean reply resets it.
    offenses: Vec<u64>,
    /// Total replies quarantined over the solve.
    pub quarantined: u64,
    /// Nodes banished for exceeding the limit.
    pub banished: u64,
}

impl ReplyGuard {
    /// Guard with the given consecutive-offense banish limit.
    pub fn new(limit: u64) -> ReplyGuard {
        ReplyGuard {
            limit,
            ..Default::default()
        }
    }

    /// Screen a round's replies in place: clean replies stay (in order);
    /// poisoned ones are pulled out, logged, counted, recycled back to
    /// the transport, and — past the offense limit — get their node
    /// banished.  Returns how many replies this round were quarantined.
    pub fn screen(
        &mut self,
        round: usize,
        replies: &mut Vec<NodeReply>,
        cluster: &mut dyn Cluster,
    ) -> usize {
        // fast path: a healthy round scans once and moves nothing
        if replies.iter().all(|r| poison_of(r).is_none()) {
            for r in replies.iter() {
                if let Some(o) = self.offenses.get_mut(r.node) {
                    *o = 0;
                }
            }
            return 0;
        }
        let mut poisoned = Vec::new();
        let mut kept = Vec::with_capacity(replies.len());
        for reply in replies.drain(..) {
            match poison_of(&reply) {
                None => {
                    if let Some(o) = self.offenses.get_mut(reply.node) {
                        *o = 0;
                    }
                    kept.push(reply);
                }
                Some(kind) => {
                    if self.offenses.len() <= reply.node {
                        self.offenses.resize(reply.node + 1, 0);
                    }
                    self.offenses[reply.node] += 1;
                    self.quarantined += 1;
                    let strikes = self.offenses[reply.node];
                    eprintln!(
                        "[guard] round {round}: node {} quarantined ({kind}; strike {strikes})",
                        reply.node
                    );
                    if self.limit > 0 && strikes >= self.limit {
                        let why = format!(
                            "{strikes} consecutive poisoned replies (last: {kind})"
                        );
                        cluster.banish(reply.node, &why);
                        self.banished += 1;
                        self.offenses[reply.node] = 0;
                    }
                    poisoned.push(reply);
                }
            }
        }
        let n = poisoned.len();
        *replies = kept;
        // quarantined buffers go back to the transport like consumed ones
        cluster.recycle(poisoned);
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::WarmState;
    use crate::backend::BlockParams;
    use crate::metrics::TransferLedger;

    /// Minimal cluster that records banish calls.
    #[derive(Default)]
    struct StubCluster {
        banished: Vec<(usize, String)>,
        recycled: usize,
    }

    impl Cluster for StubCluster {
        fn nodes(&self) -> usize {
            3
        }
        fn round(&mut self, _z: &[f64]) -> anyhow::Result<Vec<NodeReply>> {
            anyhow::bail!("unused")
        }
        fn loss_value(&mut self) -> anyhow::Result<f64> {
            Ok(0.0)
        }
        fn ledger(&mut self) -> TransferLedger {
            TransferLedger::default()
        }
        fn recycle(&mut self, replies: Vec<NodeReply>) {
            self.recycled += replies.len();
        }
        fn export_warm(&mut self) -> anyhow::Result<Vec<WarmState>> {
            anyhow::bail!("unused")
        }
        fn reseed(&mut self, _s: &[WarmState], _p: BlockParams) -> anyhow::Result<()> {
            anyhow::bail!("unused")
        }
        fn banish(&mut self, node: usize, why: &str) {
            self.banished.push((node, why.to_string()));
        }
    }

    fn reply(node: usize, x: Vec<f64>) -> NodeReply {
        NodeReply {
            node,
            round: 0,
            lag: 0,
            u: vec![0.0; x.len()],
            x,
        }
    }

    #[test]
    fn poison_predicate_catches_nan_inf_and_blowup() {
        assert_eq!(poison_of(&reply(0, vec![1.0, -2.0])), None);
        assert_eq!(
            poison_of(&reply(0, vec![1.0, f64::NAN])),
            Some(PoisonKind::NonFinite)
        );
        assert_eq!(
            poison_of(&reply(0, vec![f64::INFINITY])),
            Some(PoisonKind::NonFinite)
        );
        assert_eq!(
            poison_of(&reply(0, vec![1e300])),
            Some(PoisonKind::NormBlowup)
        );
        // the dual is screened too
        let mut r = reply(0, vec![0.0]);
        r.u[0] = f64::NEG_INFINITY;
        assert_eq!(poison_of(&r), Some(PoisonKind::NonFinite));
    }

    #[test]
    fn screen_quarantines_recycles_and_keeps_order() {
        let mut guard = ReplyGuard::new(0);
        let mut cluster = StubCluster::default();
        let mut replies = vec![
            reply(0, vec![0.5]),
            reply(1, vec![f64::NAN]),
            reply(2, vec![-0.25]),
        ];
        let n = guard.screen(4, &mut replies, &mut cluster);
        assert_eq!(n, 1);
        assert_eq!(guard.quarantined, 1);
        assert_eq!(
            replies.iter().map(|r| r.node).collect::<Vec<_>>(),
            vec![0, 2]
        );
        assert_eq!(cluster.recycled, 1, "poisoned buffers are recycled");
        // limit 0 never banishes, however often a node offends
        for round in 0..5 {
            let mut rs = vec![reply(1, vec![f64::NAN])];
            guard.screen(round, &mut rs, &mut cluster);
        }
        assert!(cluster.banished.is_empty());
        assert_eq!(guard.banished, 0);
    }

    #[test]
    fn repeat_offender_is_banished_and_a_clean_reply_resets_strikes() {
        let mut guard = ReplyGuard::new(3);
        let mut cluster = StubCluster::default();
        // two strikes, then a clean round, then two more: never banished
        for round in 0..2 {
            let mut rs = vec![reply(1, vec![f64::INFINITY])];
            guard.screen(round, &mut rs, &mut cluster);
        }
        let mut rs = vec![reply(1, vec![0.0])];
        guard.screen(2, &mut rs, &mut cluster);
        for round in 3..5 {
            let mut rs = vec![reply(1, vec![f64::INFINITY])];
            guard.screen(round, &mut rs, &mut cluster);
        }
        assert!(cluster.banished.is_empty(), "strikes must reset on clean");
        // the third consecutive strike banishes
        let mut rs = vec![reply(1, vec![f64::INFINITY])];
        guard.screen(5, &mut rs, &mut cluster);
        assert_eq!(cluster.banished.len(), 1);
        assert_eq!(cluster.banished[0].0, 1);
        assert!(cluster.banished[0].1.contains("3 consecutive"));
        assert_eq!(guard.banished, 1);
    }
}

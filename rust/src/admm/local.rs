//! Node-level Algorithm 2: the feature-decomposed inner sharing-ADMM that
//! evaluates the proximal operator (10) of the local objective.
//!
//! Per inner sweep (Eq. 20, in the averaged form of Eq. 21-23):
//!   1. w_bar = mean_j pred_j                      (AllReduce across devices)
//!   2. corr  = omega_bar - w_bar - nu             (sample space)
//!   3. per block j (per device queue):
//!        x_j  <- argmin r_j(x) + rho_l/2 || A_j x - (A_j x_j + corr) ||^2
//!        pred_j <- A_j x_j                        (both via the backend)
//!   4. w_bar recompute; c = w_bar + nu
//!   5. omega_bar <- separable prox (Eq. 21)       (loss-specific)
//!   6. nu += w_bar - omega_bar
//!
//! All inner state (x_j, pred_j, omega_bar, nu) is warm-started across
//! outer iterations.  Step 3 goes through `NodeBackend::block_sweep`: the
//! correction is frozen once per sweep, so the block updates are
//! Jacobi-independent and the native backend runs them on its worker pool
//! (multiclass batches all class columns per block as one multi-RHS
//! solve; only the omega prox couples classes).

use crate::backend::{BlockParams, NodeBackend};
use crate::data::FeaturePlan;

/// Node-level proximal-operator evaluator: owns a [`NodeBackend`] plus the
/// warm-started inner sharing-ADMM state (Algorithm 2).
pub struct LocalProx {
    backend: Box<dyn NodeBackend>,
    plan: FeaturePlan,
    /// Class count (1 for scalar losses).
    width: usize,
    m: usize,
    /// Per block: coefficients, class-major (width x block_width).
    x_blocks: Vec<Vec<f32>>,
    /// Per block: predictions A_j x_j, class-major (width x m).
    preds: Vec<Vec<f32>>,
    /// omega_bar, class-major (width x m).
    omega: Vec<f32>,
    /// nu (scaled inner dual), class-major (width x m).
    nu: Vec<f32>,
    // scratch (allocated once, reused across solve calls)
    wbar: Vec<f32>,
    corr: Vec<f32>,
    /// Frozen sweep correction `omega - wbar - nu`, class-major (width, m).
    corr_cm: Vec<f32>,
    rowmaj_c: Vec<f32>,
    rowmaj_o: Vec<f32>,
    /// Per-block consensus slices, class-major (width, bw_j).
    z_blocks: Vec<Vec<f32>>,
    u_blocks: Vec<Vec<f32>>,
    /// Row-major prediction buffer for `prediction_rowmajor`/`loss_value`
    /// (interior mutability so reporting stays `&self`).
    pred_scratch: std::cell::RefCell<Vec<f32>>,
}

impl LocalProx {
    /// Build the evaluator over a backend; all inner state starts at zero.
    pub fn new(backend: Box<dyn NodeBackend>, plan: FeaturePlan, width: usize) -> LocalProx {
        let m = backend.samples();
        let blocks = backend.blocks();
        assert_eq!(blocks, plan.blocks);
        let x_blocks = plan
            .ranges
            .iter()
            .map(|&(_, w)| vec![0.0f32; w * width])
            .collect();
        let preds = (0..blocks).map(|_| vec![0.0f32; m * width]).collect();
        let z_blocks: Vec<Vec<f32>> = plan
            .ranges
            .iter()
            .map(|&(_, w)| vec![0.0f32; w * width])
            .collect();
        LocalProx {
            backend,
            plan,
            width,
            m,
            x_blocks,
            preds,
            omega: vec![0.0; m * width],
            nu: vec![0.0; m * width],
            wbar: vec![0.0; m * width],
            corr: vec![0.0; m],
            corr_cm: vec![0.0; m * width],
            rowmaj_c: Vec::new(),
            rowmaj_o: Vec::new(),
            u_blocks: z_blocks.clone(),
            z_blocks,
            pred_scratch: std::cell::RefCell::new(Vec::new()),
        }
    }

    /// Flattened coefficient dimension n * width.
    pub fn dim(&self) -> usize {
        self.plan.n * self.width
    }

    /// Clone out the inner sharing-ADMM state `(omega, nu, preds)` for a
    /// warm-start snapshot (see `network::WarmState`).
    pub fn warm_parts(&self) -> (Vec<f32>, Vec<f32>, Vec<Vec<f32>>) {
        (self.omega.clone(), self.nu.clone(), self.preds.clone())
    }

    /// Restore the inner state from a warm snapshot: scatter the flattened
    /// `x` back into per-block coefficients (bit-exact — the f64s were
    /// cast from those very f32s) and copy omega, nu, and the per-block
    /// predictions.  Panics on any shape mismatch: a warm state must come
    /// from an identically-partitioned problem.
    pub fn reseed(&mut self, x: &[f64], omega: &[f32], nu: &[f32], preds: &[Vec<f32>]) {
        let n = self.plan.n;
        let width = self.width;
        assert_eq!(x.len(), n * width, "warm x has the wrong dimension");
        assert_eq!(omega.len(), self.m * width, "warm omega shape mismatch");
        assert_eq!(nu.len(), self.m * width, "warm nu shape mismatch");
        assert_eq!(preds.len(), self.preds.len(), "warm block count mismatch");
        for (j, &(start, bw)) in self.plan.ranges.iter().enumerate() {
            for c in 0..width {
                for i in 0..bw {
                    self.x_blocks[j][c * bw + i] = x[c * n + start + i] as f32;
                }
            }
        }
        self.omega.copy_from_slice(omega);
        self.nu.copy_from_slice(nu);
        for (dst, src) in self.preds.iter_mut().zip(preds) {
            assert_eq!(dst.len(), src.len(), "warm prediction length mismatch");
            dst.copy_from_slice(src);
        }
    }

    fn compute_wbar(&mut self) {
        let blocks = self.preds.len() as f32;
        self.wbar.fill(0.0);
        for p in &self.preds {
            for (w, &v) in self.wbar.iter_mut().zip(p) {
                *w += v;
            }
        }
        for w in self.wbar.iter_mut() {
            *w /= blocks;
        }
    }

    /// Evaluate x_i^{k+1} = prox (Eq. 10) by `sweeps` inner iterations,
    /// writing the flattened class-major solution into `x_out`.
    ///
    /// `z` and `u` are the global consensus / scaled-dual vectors
    /// (class-major, length n * width); `params` carries the penalties.
    pub fn solve(
        &mut self,
        z: &[f64],
        u: &[f64],
        params: BlockParams,
        sweeps: usize,
        x_out: &mut [f64],
    ) {
        let n = self.plan.n;
        let width = self.width;
        assert_eq!(z.len(), n * width);
        assert_eq!(u.len(), n * width);
        assert_eq!(x_out.len(), n * width);
        let m = self.m;
        let m_blocks = self.backend.blocks() as f64;

        // gather per-block consensus slices once per solve (z and u are
        // fixed for every sweep) into the reusable class-major scratch
        for (j, &(start, bw)) in self.plan.ranges.iter().enumerate() {
            for c in 0..width {
                for i in 0..bw {
                    self.z_blocks[j][c * bw + i] = z[c * n + start + i] as f32;
                    self.u_blocks[j][c * bw + i] = u[c * n + start + i] as f32;
                }
            }
        }

        // ---- fused backend path (one artifact call per outer iteration) --
        if width == 1
            && self.backend.node_sweep(
                params,
                sweeps,
                &self.z_blocks,
                &self.u_blocks,
                &mut self.x_blocks,
                &mut self.preds,
                &mut self.omega,
                &mut self.nu,
            )
        {
            for j in 0..self.plan.blocks {
                let (start, bw) = self.plan.ranges[j];
                for i in 0..bw {
                    x_out[start + i] = self.x_blocks[j][i] as f64;
                }
            }
            return;
        }

        for _ in 0..sweeps {
            // 1. AllReduce: w_bar = mean_j pred_j (over old predictions)
            self.compute_wbar();

            // 2. corr = omega - wbar - nu: one frozen snapshot for the
            //    whole sweep — this is what makes the block updates below
            //    Jacobi-independent (order-free, safe to run in parallel)
            for i in 0..m * width {
                self.corr_cm[i] = self.omega[i] - self.wbar[i] - self.nu[i];
            }

            // 3. all blocks, all class columns — batched (and, on the
            //    native backend, pooled across worker threads)
            self.backend.block_sweep(
                params,
                width,
                &self.corr_cm,
                &self.z_blocks,
                &self.u_blocks,
                &mut self.x_blocks,
                &mut self.preds,
            );

            // 4. recompute w_bar with fresh predictions
            self.compute_wbar();

            // 5. omega prox on c = w_bar + nu (row-major marshalling)
            if width == 1 {
                for i in 0..m {
                    self.corr[i] = self.wbar[i] + self.nu[i];
                }
                self.rowmaj_o.resize(m, 0.0);
                self.backend
                    .omega_update(&self.corr, m_blocks, params.rho_l, &mut self.rowmaj_o);
                self.omega.copy_from_slice(&self.rowmaj_o);
            } else {
                self.rowmaj_c.resize(m * width, 0.0);
                self.rowmaj_o.resize(m * width, 0.0);
                for c in 0..width {
                    for i in 0..m {
                        self.rowmaj_c[i * width + c] = self.wbar[c * m + i] + self.nu[c * m + i];
                    }
                }
                self.backend.omega_update(
                    &self.rowmaj_c,
                    m_blocks,
                    params.rho_l,
                    &mut self.rowmaj_o,
                );
                for c in 0..width {
                    for i in 0..m {
                        self.omega[c * m + i] = self.rowmaj_o[i * width + c];
                    }
                }
            }

            // 6. nu += w_bar - omega
            for i in 0..m * width {
                self.nu[i] += self.wbar[i] - self.omega[i];
            }
        }

        // assemble x_i (class-major flattened)
        for j in 0..self.plan.blocks {
            let (start, bw) = self.plan.ranges[j];
            for c in 0..width {
                for i in 0..bw {
                    x_out[c * n + start + i] = self.x_blocks[j][c * bw + i] as f64;
                }
            }
        }
    }

    /// Samples m_i in this node's shard.
    pub fn samples(&self) -> usize {
        self.m
    }

    /// Mini-batch variant of [`LocalProx::solve`]: the inner sweeps run
    /// over the row window `span = [r0, r1)` only.  Rows outside the
    /// window keep their warm-started state untouched — predictions,
    /// omega, and nu are read and written on the chunk rows alone, so a
    /// round touches O(chunk) samples of data (the out-of-core working
    /// set).  `span = None` is the full-batch path and routes through
    /// [`LocalProx::solve`] verbatim, which keeps full-batch trajectories
    /// bit-identical with mini-batch disabled.
    pub fn solve_span(
        &mut self,
        z: &[f64],
        u: &[f64],
        params: BlockParams,
        sweeps: usize,
        span: Option<(usize, usize)>,
        x_out: &mut [f64],
    ) {
        let (r0, r1) = match span {
            None => return self.solve(z, u, params, sweeps, x_out),
            Some(sp) => sp,
        };
        let n = self.plan.n;
        let width = self.width;
        assert_eq!(z.len(), n * width);
        assert_eq!(u.len(), n * width);
        assert_eq!(x_out.len(), n * width);
        let m = self.m;
        assert!(r0 < r1 && r1 <= m, "bad row span [{r0}, {r1})");
        let cm = r1 - r0;
        let m_blocks = self.backend.blocks() as f64;

        // gather per-block consensus slices once per solve (as in `solve`)
        for (j, &(start, bw)) in self.plan.ranges.iter().enumerate() {
            for c in 0..width {
                for i in 0..bw {
                    self.z_blocks[j][c * bw + i] = z[c * n + start + i] as f32;
                    self.u_blocks[j][c * bw + i] = u[c * n + start + i] as f32;
                }
            }
        }

        // chunk-local sample-space state, class-major (width, cm) except
        // the row-major omega marshalling pair
        let blocks_f = self.preds.len() as f32;
        let mut wbar_c = vec![0.0f32; cm * width];
        let mut corr_c = vec![0.0f32; cm * width];
        let mut preds_c: Vec<Vec<f32>> =
            (0..self.preds.len()).map(|_| vec![0.0f32; cm * width]).collect();
        let mut rowmaj_c = vec![0.0f32; cm * width];
        let mut rowmaj_o = vec![0.0f32; cm * width];

        for _ in 0..sweeps {
            // 1. AllReduce over the chunk rows: w_bar = mean_j pred_j
            wbar_c.fill(0.0);
            for p in &self.preds {
                for c in 0..width {
                    for i in 0..cm {
                        wbar_c[c * cm + i] += p[c * m + r0 + i];
                    }
                }
            }
            for w in wbar_c.iter_mut() {
                *w /= blocks_f;
            }

            // 2. frozen chunk correction
            for c in 0..width {
                for i in 0..cm {
                    corr_c[c * cm + i] =
                        self.omega[c * m + r0 + i] - wbar_c[c * cm + i] - self.nu[c * m + r0 + i];
                }
            }

            // 3. all blocks, chunk rows only (lazily cached chunk Grams)
            self.backend.block_sweep_span(
                (r0, r1),
                params,
                width,
                &corr_c,
                &self.z_blocks,
                &self.u_blocks,
                &mut self.x_blocks,
                &mut preds_c,
            );
            // scatter the refreshed chunk predictions back into the full
            // per-block buffers (rows outside the window stay warm)
            for (p, pc) in self.preds.iter_mut().zip(&preds_c) {
                for c in 0..width {
                    p[c * m + r0..c * m + r1].copy_from_slice(&pc[c * cm..(c + 1) * cm]);
                }
            }

            // 4. recompute chunk w_bar with fresh predictions
            wbar_c.fill(0.0);
            for p in &self.preds {
                for c in 0..width {
                    for i in 0..cm {
                        wbar_c[c * cm + i] += p[c * m + r0 + i];
                    }
                }
            }
            for w in wbar_c.iter_mut() {
                *w /= blocks_f;
            }

            // 5. omega prox on the chunk rows (row-major marshalling)
            for c in 0..width {
                for i in 0..cm {
                    rowmaj_c[i * width + c] = wbar_c[c * cm + i] + self.nu[c * m + r0 + i];
                }
            }
            self.backend.omega_update_span(
                (r0, r1),
                &rowmaj_c,
                m_blocks,
                params.rho_l,
                &mut rowmaj_o,
            );
            for c in 0..width {
                for i in 0..cm {
                    self.omega[c * m + r0 + i] = rowmaj_o[i * width + c];
                }
            }

            // 6. nu += w_bar - omega on the chunk rows
            for c in 0..width {
                for i in 0..cm {
                    self.nu[c * m + r0 + i] += wbar_c[c * cm + i] - self.omega[c * m + r0 + i];
                }
            }
        }

        // assemble x_i (class-major flattened)
        for j in 0..self.plan.blocks {
            let (start, bw) = self.plan.ranges[j];
            for c in 0..width {
                for i in 0..bw {
                    x_out[c * n + start + i] = self.x_blocks[j][c * bw + i] as f64;
                }
            }
        }
    }

    /// Sum the per-block predictions into `sum`, row-major (m, width).
    fn prediction_into(&self, sum: &mut Vec<f32>) {
        let m = self.m;
        let width = self.width;
        sum.resize(m * width, 0.0);
        sum.fill(0.0);
        for p in &self.preds {
            for c in 0..width {
                for i in 0..m {
                    sum[i * width + c] += p[c * m + i];
                }
            }
        }
    }

    /// Current total prediction (sum over blocks), row-major (m, width) —
    /// for objective reporting.  Reporting never mutates solver state, so
    /// the receiver is `&self`.
    pub fn prediction_rowmajor(&self) -> Vec<f32> {
        let mut sum = Vec::new();
        self.prediction_into(&mut sum);
        sum
    }

    /// Training loss at the current prediction.  This is the call the
    /// solver repeats every round, so it reuses an interior scratch buffer
    /// instead of allocating (the borrow never escapes this method).
    pub fn loss_value(&self) -> f64 {
        let mut scratch = self.pred_scratch.borrow_mut();
        self.prediction_into(&mut scratch);
        self.backend.loss_value(&scratch)
    }

    /// The backend's transfer/byte ledger (staging copies, reuse counters).
    pub fn ledger(&self) -> crate::metrics::TransferLedger {
        self.backend.ledger()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::{NativeBackend, SolveMode};
    use crate::data::{FeaturePlan, SyntheticSpec};
    use crate::linalg::{Cholesky, Matrix};
    use crate::losses::Squared;

    /// The inner ADMM must converge to the exact prox (15):
    ///   min 2 * ... actually for squared loss phi = ||Ax-b||^2:
    ///   (2 A^T A + reg I) x = 2 A^T b + rho_c (z - u)
    #[test]
    fn inner_admm_solves_prox_squared() {
        let spec = SyntheticSpec::regression(20, 64, 1);
        let ds = spec.generate();
        let shard = &ds.shards[0];
        let plan = FeaturePlan::new(20, 2, 512);
        let params = BlockParams {
            rho_l: 2.0,
            rho_c: 1.0,
            reg: 1.0 / 10.0 + 1.0, // N=1, gamma=10
        };
        let backend = NativeBackend::new(shard, &plan, Box::new(Squared), SolveMode::Direct);
        let mut prox = LocalProx::new(Box::new(backend), plan, 1);

        let z: Vec<f64> = (0..20).map(|i| (i as f64 * 0.1).sin() * 0.5).collect();
        let u: Vec<f64> = (0..20).map(|i| (i as f64 * 0.3).cos() * 0.2).collect();
        let mut x = vec![0.0; 20];
        prox.solve(&z, &u, params, 200, &mut x);

        // exact solution via dense normal equations
        let a = shard.data.as_dense().unwrap();
        let n = 20;
        let mut h = vec![0.0f64; n * n];
        let mut g32 = vec![0.0f32; n * n];
        a.gram_accumulate(&mut g32);
        for i in 0..n {
            for j in 0..n {
                h[i * n + j] = 2.0 * g32[i * n + j] as f64;
            }
            h[i * n + i] += params.reg;
        }
        let mut atb = vec![0.0f32; n];
        a.matvec_t(&shard.labels, &mut atb);
        let mut rhs: Vec<f64> = (0..n)
            .map(|i| 2.0 * atb[i] as f64 + params.rho_c * (z[i] - u[i]))
            .collect();
        Cholesky::factor(&h, n).unwrap().solve(&mut rhs);

        for (got, want) in x.iter().zip(&rhs) {
            assert!((got - want).abs() < 1e-3, "{got} vs {want}");
        }
    }

    /// With a single feature block the inner ADMM reduces to the one-shot
    /// sharing problem and must converge quickly.
    #[test]
    fn single_block_converges_fast() {
        let spec = SyntheticSpec::regression(8, 32, 1);
        let ds = spec.generate();
        let plan = FeaturePlan::new(8, 1, 512);
        let params = BlockParams {
            rho_l: 4.0,
            rho_c: 1.0,
            reg: 1.1,
        };
        let backend =
            NativeBackend::new(&ds.shards[0], &plan, Box::new(Squared), SolveMode::Direct);
        let mut prox = LocalProx::new(Box::new(backend), plan, 1);
        let z = vec![0.0; 8];
        let u = vec![0.0; 8];
        let mut x_few = vec![0.0; 8];
        prox.solve(&z, &u, params, 60, &mut x_few);
        let mut x_more = x_few.clone();
        prox.solve(&z, &u, params, 60, &mut x_more);
        // converged: more sweeps barely move the solution
        for (a, b) in x_few.iter().zip(&x_more) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    /// `solve_span` over the whole row window must be bit-identical to
    /// `solve` — the chunk arithmetic degenerates to the full-batch one.
    #[test]
    fn full_window_solve_span_matches_solve_bit_for_bit() {
        let spec = SyntheticSpec::regression(16, 48, 1);
        let ds = spec.generate();
        let plan = FeaturePlan::new(16, 2, 512);
        let params = BlockParams {
            rho_l: 2.0,
            rho_c: 1.0,
            reg: 1.2,
        };
        let mk = || {
            let backend = NativeBackend::new(
                &ds.shards[0],
                &plan,
                Box::new(Squared),
                SolveMode::Direct,
            );
            LocalProx::new(Box::new(backend), plan.clone(), 1)
        };
        let z: Vec<f64> = (0..16).map(|i| (i as f64 * 0.2).sin() * 0.4).collect();
        let u: Vec<f64> = (0..16).map(|i| (i as f64 * 0.5).cos() * 0.1).collect();

        let mut prox_a = mk();
        let mut x_a = vec![0.0; 16];
        prox_a.solve(&z, &u, params, 25, &mut x_a);

        let mut prox_b = mk();
        let mut x_b = vec![0.0; 16];
        prox_b.solve_span(&z, &u, params, 25, Some((0, 48)), &mut x_b);

        assert_eq!(x_a, x_b);
        assert_eq!(prox_a.warm_parts(), prox_b.warm_parts());
    }

    #[test]
    fn prediction_rowmajor_sums_blocks() {
        let spec = SyntheticSpec::regression(10, 16, 1);
        let ds = spec.generate();
        let plan = FeaturePlan::new(10, 2, 512);
        let params = BlockParams {
            rho_l: 2.0,
            rho_c: 1.0,
            reg: 1.1,
        };
        let backend =
            NativeBackend::new(&ds.shards[0], &plan, Box::new(Squared), SolveMode::Direct);
        let mut prox = LocalProx::new(Box::new(backend), plan.clone(), 1);
        let z = vec![0.1; 10];
        let u = vec![0.0; 10];
        let mut x = vec![0.0; 10];
        prox.solve(&z, &u, params, 30, &mut x);

        // prediction == A x (sum of block predictions)
        let a = ds.shards[0].data.as_dense().unwrap();
        let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let mut want = vec![0.0f32; 16];
        a.matvec(&xf, &mut want);
        let got = prox.prediction_rowmajor();
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4);
        }
        let _ = Matrix::zeros(1, 1);
    }
}

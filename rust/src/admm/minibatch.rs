//! Deterministic mini-batch chunk schedule for out-of-core rounds.
//!
//! Every node slices its sample window into fixed `minibatch`-row chunks
//! and, each outer round, runs the inner Algorithm-2 sweeps over ONE
//! chunk — the working set a round touches is O(chunk) rows of the
//! (possibly mmap-backed) shard instead of the whole thing.  Which chunk
//! runs in which round is a pure function of `(seed, round)`:
//!
//!   * no RNG state to checkpoint — a resumed solve replays the exact
//!     schedule by re-evaluating the function at the restored round index
//!     (see `Cluster::fast_forward`);
//!   * every transport (in-process or socket) derives the same schedule
//!     from the wire-carried round counter, so trajectories are
//!     bit-identical across transports;
//!   * the schedule is printable up front: [`schedule_fingerprint`] folds
//!     the first rounds into one hex token that two runs can compare.
//!
//! The hash is the repo-wide FNV-1a (`util::fnv1a`) — the same primitive
//! the checkpoint problem hash, the wire checksums, and the `PSD1` shard
//! header use.

use crate::util::fnv1a_fold;
use crate::util::FNV_OFFSET;

/// Per-round hash: FNV-1a over the little-endian bytes of `seed` then
/// `round`.  Stable across platforms (explicit LE) and across sessions
/// (no ambient state).
pub fn round_hash(seed: u64, round: u64) -> u64 {
    let h = fnv1a_fold(FNV_OFFSET, &seed.to_le_bytes());
    fnv1a_fold(h, &round.to_le_bytes())
}

/// Chunk index scheduled for `round` out of `n_chunks` equal slices.
pub fn chunk_index(seed: u64, round: u64, n_chunks: usize) -> usize {
    assert!(n_chunks > 0, "chunk schedule needs at least one chunk");
    (round_hash(seed, round) % n_chunks as u64) as usize
}

/// How many rounds [`schedule_fingerprint`] folds.
pub const FINGERPRINT_ROUNDS: u64 = 64;

/// One printable token summarizing the first [`FINGERPRINT_ROUNDS`]
/// rounds of the schedule: two runs (or a run and its resume) agree on
/// the whole schedule iff they print the same fingerprint.
pub fn schedule_fingerprint(seed: u64, n_chunks: usize) -> u64 {
    let mut h = FNV_OFFSET;
    for round in 0..FINGERPRINT_ROUNDS {
        let idx = chunk_index(seed, round, n_chunks) as u64;
        h = fnv1a_fold(h, &idx.to_le_bytes());
    }
    h
}

/// The row window `[r0, r1)` of the chunk scheduled for `round`, over a
/// shard of `m` rows sliced into `minibatch`-row chunks.  `None` means
/// full batch — mini-batch off (`minibatch == 0`) or a chunk that would
/// cover every row anyway; callers then take the ordinary full-batch
/// path, which keeps `--minibatch >= m` bit-identical to a plain solve.
pub fn chunk_for(minibatch: usize, seed: u64, round: u64, m: usize) -> Option<(usize, usize)> {
    if minibatch == 0 || minibatch >= m {
        return None;
    }
    let n_chunks = m.div_ceil(minibatch);
    let idx = chunk_index(seed, round, n_chunks);
    let r0 = idx * minibatch;
    let r1 = ((idx + 1) * minibatch).min(m);
    Some((r0, r1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_is_a_pure_function_of_seed_and_round() {
        for round in 0..200 {
            assert_eq!(
                chunk_index(0x5EED, round, 7),
                chunk_index(0x5EED, round, 7)
            );
        }
        // different seeds decorrelate
        let a: Vec<usize> = (0..64).map(|r| chunk_index(1, r, 7)).collect();
        let b: Vec<usize> = (0..64).map(|r| chunk_index(2, r, 7)).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn every_chunk_is_visited() {
        let n_chunks = 5;
        let mut seen = vec![false; n_chunks];
        for round in 0..256 {
            seen[chunk_index(42, round, n_chunks)] = true;
        }
        assert!(seen.iter().all(|&s| s), "some chunk never scheduled: {seen:?}");
    }

    #[test]
    fn chunk_for_windows_are_in_bounds_and_sized() {
        let (mb, m) = (12usize, 50usize);
        for round in 0..128 {
            let (r0, r1) = chunk_for(mb, 9, round, m).unwrap();
            assert!(r0 < r1 && r1 <= m);
            assert!(r1 - r0 <= mb);
            assert_eq!(r0 % mb, 0, "chunks are fixed slices");
        }
    }

    #[test]
    fn full_batch_sentinels() {
        assert_eq!(chunk_for(0, 1, 0, 40), None, "minibatch off");
        assert_eq!(chunk_for(40, 1, 0, 40), None, "chunk covers the shard");
        assert_eq!(chunk_for(64, 1, 0, 40), None, "chunk larger than shard");
        assert!(chunk_for(39, 1, 0, 40).is_some());
    }

    #[test]
    fn fingerprint_pins_the_schedule() {
        assert_eq!(schedule_fingerprint(7, 4), schedule_fingerprint(7, 4));
        assert_ne!(schedule_fingerprint(7, 4), schedule_fingerprint(8, 4));
        assert_ne!(schedule_fingerprint(7, 4), schedule_fingerprint(7, 5));
    }
}

//! The Bi-cADMM algorithm (the paper's core contribution).
//!
//! * [`global`] — coordinator-side updates: the (z, t) epigraph-constrained
//!   QP (7b), the closed-form s-update (7c)/(12), the scaled bilinear dual
//!   (13), and the three residuals (14).
//! * [`local`]  — node-side Algorithm 2: the feature-decomposed inner
//!   sharing-ADMM that evaluates the proximal operator (10) over a
//!   [`crate::backend::NodeBackend`].
//! * [`solver`] — Algorithm 1: the outer consensus loop over a cluster of
//!   node workers, with residual-based termination and solution
//!   extraction (hard threshold + optional ridge polish).
//!
//! Coefficient-space layout: all global vectors (`x_i`, `u_i`, `z`, `s`)
//! are flattened class-major — entry `(class c, feature i)` lives at
//! `c * n + i`.  Width is 1 for the scalar losses, `k` for softmax.

/// Coordinator-side (z, t, s, v) updates and residuals.
pub mod global;
/// Poison quarantine: reply validation before the consensus fold.
pub mod guard;
/// Node-side Algorithm 2: the feature-decomposed inner sharing-ADMM.
pub mod local;
/// Deterministic mini-batch chunk schedule (out-of-core rounds).
pub mod minibatch;
/// Algorithm 1: the outer consensus loop with resumable state.
pub mod solver;

pub use global::GlobalState;
pub use guard::ReplyGuard;
pub use local::LocalProx;
pub use solver::{
    solve, solve_checkpointed, solve_from, solve_from_with, SolveError, SolveOptions,
    SolveResult, SolveScratch, SolverState,
};

//! Algorithm 1 — the outer Bi-cADMM consensus loop.
//!
//! Orchestrates a [`Cluster`] of node workers against the coordinator's
//! [`GlobalState`], with residual-based termination (Eq. 14) and solution
//! extraction (hard threshold to kappa + optional ridge polish on the
//! recovered support).

use crate::backend::BlockParams;
use crate::config::Config;
use crate::data::{Dataset, ShardData};
use crate::linalg::ops;
use crate::losses::LossKind;
use crate::metrics::{Trace, TransferLedger};
use crate::network::{Cluster, WarmState};
use crate::path::checkpoint::{self, FitCheckpoint};
use crate::sparsity::{hard_threshold, support_of};
use crate::util::Stopwatch;

use super::global::GlobalState;
use super::guard::ReplyGuard;

/// Complete resumable solver state: the coordinator's global variables
/// plus every node's warm-start snapshot.
///
/// This is the unit the path subsystem hands from one path point to the
/// next (warm starts) and what `path::checkpoint` serializes so a killed
/// sweep resumes bit-identically at the last completed point.  Capturing
/// and re-injecting it through [`Cluster::export_warm`] /
/// [`Cluster::reseed`] is the *only* state transfer between path points,
/// so a resumed run and an uninterrupted run see exactly the same inputs.
#[derive(Debug, Clone, PartialEq)]
pub struct SolverState {
    /// Coordinator-side (z, t, s, v, z_prev).
    pub global: GlobalState,
    /// Per-node (x_i, u_i) plus the inner sharing-ADMM state, sorted by
    /// node id.
    pub nodes: Vec<WarmState>,
}

impl SolverState {
    /// Snapshot the full solver state after a solve: the given global
    /// variables plus the warm state exported from every node.
    pub fn capture(cluster: &mut dyn Cluster, global: &GlobalState) -> anyhow::Result<SolverState> {
        Ok(SolverState {
            global: global.clone(),
            nodes: cluster.export_warm()?,
        })
    }
}

/// Reusable allocation pool for the solver's per-round and per-call
/// temporaries (the consensus average, the extraction/polish buffers, and
/// the objective's prediction marshalling).
///
/// One solve allocates each buffer once; reusing the scratch across
/// solves — the path subsystem holds one for its whole budget sweep —
/// turns every later solve's temporary into a `resize` on warm capacity.
/// The bytes this avoids are recorded and surfaced through
/// [`crate::metrics::TransferLedger::net_alloc_saved_bytes`], alongside
/// the transport-layer reuse counters.
#[derive(Debug, Default)]
pub struct SolveScratch {
    /// Consensus average c = mean_i(x_i + u_i), length dim.
    c: Vec<f64>,
    /// Support-slot map of the polish step (length dim, usize::MAX = off
    /// support).
    slot: Vec<usize>,
    /// Polish right-hand side / iterate (length |support|).
    rhs: Vec<f64>,
    /// Polish CG iterate (length |support|).
    w: Vec<f64>,
    /// Objective: one class column of x in f32 (length n).
    obj_xc: Vec<f32>,
    /// Objective: one shard's prediction column (length m_i).
    obj_col: Vec<f32>,
    /// Objective: one shard's row-major prediction block (m_i * width).
    obj_pred: Vec<f32>,
    /// Allocation bytes avoided by reuse since construction (drained into
    /// the solve ledger by `solve_from_with`).
    saved_bytes: u64,
}

impl SolveScratch {
    /// Resize `buf` to `len` zeros, crediting an avoided allocation when
    /// the capacity was already there.
    fn reuse_f64(buf: &mut Vec<f64>, len: usize, saved: &mut u64) {
        if buf.capacity() >= len && len > 0 {
            *saved += (len * std::mem::size_of::<f64>()) as u64;
        }
        buf.clear();
        buf.resize(len, 0.0);
    }

    /// f32 twin of [`SolveScratch::reuse_f64`].
    fn reuse_f32(buf: &mut Vec<f32>, len: usize, saved: &mut u64) {
        if buf.capacity() >= len && len > 0 {
            *saved += (len * std::mem::size_of::<f32>()) as u64;
        }
        buf.clear();
        buf.resize(len, 0.0);
    }
}

/// Options orthogonal to the math: transport and reporting.
#[derive(Debug, Clone)]
pub struct SolveOptions {
    /// Record the (expensive) training loss each iteration.
    pub track_loss: bool,
    /// Print per-iteration residuals to stderr.
    pub verbose: bool,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions {
            track_loss: false,
            verbose: false,
        }
    }
}

/// Structured outer-loop failures beyond transport errors, returned
/// through `anyhow` so callers can `downcast_ref::<SolveError>()`.
#[derive(Debug, Clone)]
pub enum SolveError {
    /// The divergence watchdog tripped (non-finite residuals, sustained
    /// residual growth, or rounds in which every reply was quarantined)
    /// and either exhausted its safeguarded restarts or never saw a
    /// finite state to restart from.
    Diverged {
        /// Outer iteration at which the watchdog gave up.
        round: usize,
        /// Recent primal-residual window leading up to the trip.
        residuals: Vec<f64>,
        /// Safeguarded restarts performed before giving up.
        restarts: usize,
    },
}

impl std::fmt::Display for SolveError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SolveError::Diverged {
                round,
                residuals,
                restarts,
            } => {
                let tail: Vec<String> =
                    residuals.iter().map(|r| format!("{r:.3e}")).collect();
                write!(
                    f,
                    "solve diverged at round {round} after {restarts} safeguarded \
                     restart(s); recent primal residuals [{}]",
                    tail.join(", ")
                )
            }
        }
    }
}

impl std::error::Error for SolveError {}

/// Everything a finished Bi-cADMM solve reports back.
#[derive(Debug, Clone)]
pub struct SolveResult {
    /// Dense consensus iterate at termination.
    pub z: Vec<f64>,
    /// Async coordination accounting (None for synchronous clusters).
    pub coordination: Option<crate::metrics::CoordinationStats>,
    /// kappa-sparse solution (hard-thresholded z, optionally polished).
    pub x: Vec<f64>,
    /// Support of `x` (sorted indices into the flattened coefficients).
    pub support: Vec<usize>,
    /// Per-iteration residual records (Eq. 14).
    pub trace: Trace,
    /// Merged transfer + network byte ledger over all nodes.
    pub transfers: TransferLedger,
    /// Outer iterations executed.
    pub iters: usize,
    /// Whether the residual thresholds were met before `max_iters`.
    pub converged: bool,
    /// Whether `solver.deadline_ms` cut the solve off at a round boundary
    /// (the result is then the best-so-far iterate, not a converged one).
    pub timed_out: bool,
    /// Safeguarded watchdog restarts performed during the solve.
    pub restarts: usize,
    /// Wall-clock seconds spent in the outer loop.
    pub wall_seconds: f64,
    /// Training loss at the final iterate (if tracked or cheap).
    pub final_loss: Option<f64>,
}

/// Run Bi-cADMM over an already-built cluster, cold-started.
///
/// `dim` = n_features * width.  The polish step (squared loss only)
/// re-fits a ridge on the recovered support using the dataset.
pub fn solve(
    cluster: &mut dyn Cluster,
    dim: usize,
    cfg: &Config,
    dataset: Option<&Dataset>,
    opts: &SolveOptions,
) -> anyhow::Result<SolveResult> {
    let mut global = GlobalState::new(dim);
    solve_from(cluster, &mut global, cfg, dataset, opts)
}

/// Run Bi-cADMM starting from the given global state (warm start).
///
/// This is [`solve`] with the monolithic loop's state extracted: the
/// caller owns `global`, which is read as the starting point and left at
/// the final iterate, so consecutive solves over a [`Cluster`] that was
/// re-seeded with matching node state continue one trajectory.  The path
/// subsystem drives its budget/penalty sweeps through here.
pub fn solve_from(
    cluster: &mut dyn Cluster,
    global: &mut GlobalState,
    cfg: &Config,
    dataset: Option<&Dataset>,
    opts: &SolveOptions,
) -> anyhow::Result<SolveResult> {
    let mut scratch = SolveScratch::default();
    solve_from_with(cluster, global, cfg, dataset, opts, &mut scratch)
}

/// [`solve_from`] with a caller-owned [`SolveScratch`], so consecutive
/// solves (the path subsystem's budget sweep) reuse every temporary
/// instead of re-allocating it per point.
pub fn solve_from_with(
    cluster: &mut dyn Cluster,
    global: &mut GlobalState,
    cfg: &Config,
    dataset: Option<&Dataset>,
    opts: &SolveOptions,
    scratch: &mut SolveScratch,
) -> anyhow::Result<SolveResult> {
    solve_loop(cluster, global, cfg, dataset, opts, scratch, LoopCtl::default())
}

/// Mid-fit snapshot sink: where `solve_loop` writes PSF1 checkpoints,
/// how often, and the problem fingerprint stamped into them.
struct CkptSink<'a> {
    path: &'a std::path::Path,
    every: usize,
    hash: u64,
    /// Full roster size; a snapshot whose warm export does not cover
    /// every node (degraded cluster) is skipped, because a resume builds
    /// a fresh full cluster that such a partial state could never seed.
    roster: usize,
}

/// Resume/checkpoint controls threaded through [`solve_loop`]; the
/// default is a plain cold-started, non-checkpointing solve.
#[derive(Default)]
struct LoopCtl<'a> {
    /// First outer iteration to run (`> 0` when resuming a checkpoint).
    start: usize,
    /// Records of iterations completed before `start`, prepended to the
    /// returned trace.
    trace: Trace,
    /// Periodic snapshot sink, if checkpointing.
    ckpt: Option<CkptSink<'a>>,
}

/// The shared outer loop behind [`solve_from_with`] and
/// [`solve_checkpointed`].
fn solve_loop(
    cluster: &mut dyn Cluster,
    global: &mut GlobalState,
    cfg: &Config,
    dataset: Option<&Dataset>,
    opts: &SolveOptions,
    scratch: &mut SolveScratch,
    ctl: LoopCtl<'_>,
) -> anyhow::Result<SolveResult> {
    cfg.solver.validate()?;
    let sc = &cfg.solver;
    let watch = Stopwatch::start();

    let dim = global.z.len();
    let LoopCtl {
        start,
        mut trace,
        ckpt,
    } = ctl;
    SolveScratch::reuse_f64(&mut scratch.c, dim, &mut scratch.saved_bytes);
    let c = &mut scratch.c;
    let mut converged = false;
    let mut timed_out = false;
    let mut iters = start;

    // penalties are locals because the divergence watchdog rescales them
    // on a safeguarded restart; everywhere below reads rc/rb, not config
    let mut rc = sc.rho_c;
    let mut rb = sc.rho_b;

    // ---- numerical guardrails ------------------------------------------
    // quarantine: every reply is screened for poison before the fold
    let mut guard = ReplyGuard::new(cfg.platform.quarantine_limit);
    // watchdog: windowed residual-growth trend + non-finite trips
    let window = sc.watchdog_window;
    let mut best_primal = f64::INFINITY;
    let mut growth_streak = 0usize;
    let mut recent: Vec<f64> = Vec::new();
    let mut restarts_done = 0usize;
    let mut last_finite: Option<SolverState> = None;

    // scaled termination thresholds (absolute tolerances scaled by the
    // problem dimension, Boyd §3.3 style); the primal threshold scales
    // with the nodes that actually contributed residual terms this round,
    // so a degraded async cluster is held to the same per-node accuracy
    let d_thresh = sc.tol_dual * (dim as f64).sqrt().max(1.0);
    let b_thresh = sc.tol_bilinear;

    for k in start..sc.max_iters {
        // ---- deadline: abort cleanly at a round boundary ----------------
        // Checked before the round (but never before the first), so a
        // timed-out solve always carries at least one completed round of
        // best-so-far state into extraction.
        if sc.deadline_ms > 0
            && k > start
            && watch.elapsed_secs() * 1000.0 >= sc.deadline_ms as f64
        {
            timed_out = true;
            eprintln!(
                "[deadline] round {k}: solver.deadline_ms = {} exceeded; \
                 returning best-so-far result",
                sc.deadline_ms
            );
            break;
        }
        iters = k + 1;
        // ---- Bcast z^k / Collect x_i^{k+1}, u_i^k -----------------------
        let mut replies = cluster.round(&global.z)?;
        // ---- poison quarantine: screen before anything is folded --------
        let quarantined_now = guard.screen(k, &mut replies, cluster);
        if replies.is_empty() {
            anyhow::ensure!(
                quarantined_now > 0,
                "round {k}: no node replies (cluster lost its quorum)"
            );
            // every reply this round was poisoned — nothing usable to
            // fold.  That is a divergence signal, not a quorum loss:
            // route it to the watchdog so a pathological config ends in
            // a structured `Diverged`, never a transport error.
            growth_streak += 1;
            if window > 0 && growth_streak >= window.min(3) {
                if watchdog_restart(
                    cluster,
                    global,
                    sc,
                    &last_finite,
                    &mut rc,
                    &mut rb,
                    &mut restarts_done,
                    k,
                ) {
                    best_primal = f64::INFINITY;
                    growth_streak = 0;
                    recent.clear();
                    continue;
                }
                return Err(anyhow::Error::new(SolveError::Diverged {
                    round: k,
                    residuals: recent.clone(),
                    restarts: restarts_done,
                }));
            }
            continue;
        }

        // ---- global updates (7b), (12), (13) ----------------------------
        // Averages are weighted by the nodes that actually participated
        // (Zhu-style partial barrier): under synchronous coordination every
        // node replies and this reduces exactly to the 1/N mean.
        let participants = replies.len();
        let max_lag = replies.iter().map(|r| r.lag).max().unwrap_or(0);
        c.fill(0.0);
        for r in &replies {
            for i in 0..dim {
                c[i] += r.x[i] + r.u[i];
            }
        }
        let inv = 1.0 / participants as f64;
        for ci in c.iter_mut() {
            *ci *= inv;
        }
        global.zt_update(c, participants, rc, rb, sc.zt_iters);

        // ---- residuals (14): bilinear measured against the PREVIOUS s ---
        // (g(z^{k+1}, s^k, t^{k+1}) — the quantity the rho_b penalty acts
        // on; the closed-form s-update that follows zeroes g whenever the
        // target is reachable, so measuring after it would be trivially 0)
        // The replies stream straight into the residual computation — no
        // per-round `Vec<&[f64]>` marshalling at all (streaming needs no
        // ledger credit: there is simply nothing left to allocate).
        let mut rec = global.residuals(
            replies.iter().map(|r| r.x.as_slice()),
            rc,
            k,
            watch.elapsed_secs(),
        );
        rec.max_lag = max_lag;
        rec.restarts = restarts_done;
        // hand the reply buffers back to the transport for reuse — the
        // next round's Collect fills them in place instead of allocating
        cluster.recycle(replies);

        // ---- divergence watchdog ----------------------------------------
        // Trip immediately on any non-finite residual or iterate;
        // otherwise trip after `window` consecutive rounds of the primal
        // residual sitting 1e4x above the best one seen.
        let finite = rec.primal.is_finite()
            && rec.dual.is_finite()
            && rec.bilinear.is_finite()
            && global.z.iter().all(|v| v.is_finite());

        // the closed-form s-update partial-sorts z, so a poisoned iterate
        // must go straight to the watchdog, never into the sorter
        if finite {
            global.s_update(sc.kappa);
            global.v_update();
        }

        if opts.verbose {
            eprintln!(
                "iter {:>4}  primal {:>10.3e}  dual {:>10.3e}  bilinear {:>10.3e}",
                k, rec.primal, rec.dual, rec.bilinear
            );
        }
        if finite {
            if rec.primal > 1e4 * best_primal.max(1e-12) {
                if growth_streak == 0
                    && last_finite.is_none()
                    && sc.watchdog_restarts > 0
                    && window > 0
                {
                    // first warning of this streak: snapshot the still-
                    // finite state so a restart has something to re-seed
                    // from (clusters without warm export stay None and
                    // the watchdog goes straight to Diverged)
                    last_finite = SolverState::capture(cluster, global).ok();
                }
                growth_streak += 1;
            } else {
                growth_streak = 0;
                best_primal = best_primal.min(rec.primal);
            }
            recent.push(rec.primal);
            if recent.len() > window.max(1) {
                recent.remove(0);
            }
        }
        let tripped = window > 0 && (!finite || growth_streak >= window);

        let p_thresh = sc.tol_primal * ((participants * dim) as f64).sqrt().max(1.0);
        let done = !tripped
            && k > 0
            && rec.primal <= p_thresh
            && rec.dual <= d_thresh
            && rec.bilinear <= b_thresh;
        trace.push(rec);
        if done {
            converged = true;
            break;
        }
        if tripped {
            if watchdog_restart(
                cluster,
                global,
                sc,
                &last_finite,
                &mut rc,
                &mut rb,
                &mut restarts_done,
                k,
            ) {
                best_primal = f64::INFINITY;
                growth_streak = 0;
                recent.clear();
                continue;
            }
            return Err(anyhow::Error::new(SolveError::Diverged {
                round: k,
                residuals: recent.clone(),
                restarts: restarts_done,
            }));
        }
        // ---- periodic mid-fit snapshot ----------------------------------
        // Captured at the iteration boundary — exactly the state the next
        // iteration reads — so a resume replays nothing and the remaining
        // trace is bit-identical to an uninterrupted run.
        if let Some(sink) = &ckpt {
            if iters % sink.every == 0 {
                let state = SolverState::capture(cluster, global)?;
                let full = state.nodes.len() == sink.roster
                    && (0..sink.roster).all(|i| state.nodes.iter().any(|w| w.node == i));
                if full {
                    // reaching here means the round was finite (a tripped
                    // round exits above), so this snapshot doubles as the
                    // watchdog's restart seed — the freshest finite state
                    if window > 0 && sc.watchdog_restarts > 0 {
                        last_finite = Some(state.clone());
                    }
                    checkpoint::save_fit(
                        sink.path,
                        &FitCheckpoint {
                            problem_hash: sink.hash,
                            iters_done: iters as u64,
                            trace: trace.records.clone(),
                            state,
                        },
                    )?;
                }
            }
        }
    }

    // ---- solution extraction -------------------------------------------
    let mut x = global.z.clone();
    hard_threshold(&mut x, sc.kappa);
    let support = support_of(&x, 0.0);
    if sc.polish && cfg.loss == LossKind::Squared {
        if let Some(ds) = dataset {
            polish_ridge_with(ds, &support, sc.gamma, &mut x, scratch);
        }
    }

    let final_loss = if opts.track_loss {
        Some(cluster.loss_value()?)
    } else {
        None
    };

    // ledger first: collecting it can surface deaths that the
    // coordination snapshot should include
    let mut transfers = cluster.ledger();
    // fold in the solver-side reuse: scratch buffers that were served
    // from warm capacity this solve instead of freshly allocated
    transfers.net_alloc_saved_bytes += std::mem::take(&mut scratch.saved_bytes);
    // fold the guard's quarantine count into the coordination stats,
    // materializing them for synchronous transports that track none
    let mut coordination = cluster.coordination();
    if guard.quarantined > 0 {
        coordination
            .get_or_insert_with(|| crate::metrics::CoordinationStats::new(cluster.nodes()))
            .quarantined += guard.quarantined;
    }
    Ok(SolveResult {
        z: global.z.clone(),
        coordination,
        x,
        support,
        trace,
        transfers,
        iters,
        converged,
        timed_out,
        restarts: restarts_done,
        wall_seconds: watch.elapsed_secs(),
        final_loss,
    })
}

/// Attempt one safeguarded watchdog restart: rescale the penalties a
/// decade down, restore the last finite coordinator state, and re-seed
/// every node from its matching warm snapshot.  Returns `false` (leaving
/// the solve to report `SolveError::Diverged`) when the restart budget is
/// spent, no finite state was ever captured, or the cluster cannot be
/// re-seeded.
#[allow(clippy::too_many_arguments)]
fn watchdog_restart(
    cluster: &mut dyn Cluster,
    global: &mut GlobalState,
    sc: &crate::config::SolverConfig,
    last_finite: &Option<SolverState>,
    rc: &mut f64,
    rb: &mut f64,
    restarts_done: &mut usize,
    round: usize,
) -> bool {
    if *restarts_done >= sc.watchdog_restarts {
        return false;
    }
    let Some(state) = last_finite else {
        return false;
    };
    let rc_new = *rc / 10.0;
    let rb_new = *rb / 10.0;
    let params = BlockParams {
        rho_l: sc.rho_l,
        rho_c: rc_new,
        reg: 1.0 / (cluster.nodes() as f64 * sc.gamma) + rc_new,
    };
    if cluster.reseed(&state.nodes, params).is_err() {
        return false;
    }
    *global = state.global.clone();
    *rc = rc_new;
    *rb = rb_new;
    *restarts_done += 1;
    eprintln!(
        "[watchdog] round {round}: divergence detected; safeguarded restart \
         {}/{} with rho_c {rc_new:.3e} rho_b {rb_new:.3e}",
        *restarts_done, sc.watchdog_restarts
    );
    true
}

/// Run Bi-cADMM with mid-fit checkpointing (`psfit train --checkpoint`,
/// serve jobs).
///
/// With `cfg.solver.checkpoint` empty this is exactly [`solve`].
/// Otherwise the solve writes a PSF1 snapshot (full [`SolverState`] plus
/// the trace so far) to that path every `cfg.solver.checkpoint_every`
/// completed iterations, atomically; and when the file already holds a
/// snapshot of the *same* problem (checked via
/// [`checkpoint::problem_hash`] over the dataset and every
/// trajectory-shaping setting), the fit resumes at the saved iteration
/// instead of restarting.  Snapshots land on iteration boundaries, so
/// the resumed run's remaining residual trace is bit-identical to an
/// uninterrupted run's.  A checkpoint written for a different problem is
/// rejected, never silently re-seeded.
pub fn solve_checkpointed(
    cluster: &mut dyn Cluster,
    dim: usize,
    cfg: &Config,
    dataset: &Dataset,
    opts: &SolveOptions,
) -> anyhow::Result<SolveResult> {
    cfg.solver.validate()?;
    if cfg.solver.checkpoint.is_empty() {
        return solve(cluster, dim, cfg, Some(dataset), opts);
    }
    let ck_path = std::path::Path::new(&cfg.solver.checkpoint);
    // The iteration budget is deliberately excluded from the fingerprint:
    // a checkpointed fit may legitimately resume with a larger max_iters
    // (more budget), and a kill leaves the budget partially spent — only
    // the trajectory-shaping settings must match.
    let hash = {
        let mut hcfg = cfg.clone();
        hcfg.solver.max_iters = 0;
        checkpoint::problem_hash(dataset, &hcfg, &[])
    };
    let mut global = GlobalState::new(dim);
    let mut ctl = LoopCtl {
        ckpt: Some(CkptSink {
            path: ck_path,
            every: cfg.solver.checkpoint_every.max(1),
            hash,
            roster: dataset.nodes(),
        }),
        ..LoopCtl::default()
    };
    if ck_path.exists() {
        let ck = checkpoint::load_fit(ck_path)?;
        anyhow::ensure!(
            ck.problem_hash == hash,
            "checkpoint {} was written for a different fit (hash mismatch); \
             delete it or point solver.checkpoint elsewhere",
            ck_path.display()
        );
        let params = BlockParams {
            rho_l: cfg.solver.rho_l,
            rho_c: cfg.solver.rho_c,
            reg: cfg.solver.block_reg(dataset.nodes()),
        };
        cluster.reseed(&ck.state.nodes, params)?;
        global = ck.state.global.clone();
        ctl.start = ck.iters_done as usize;
        // round-indexed schedules (the mini-batch chunk cycle) must replay
        // from the same round counter the killed run would have reached
        cluster.fast_forward(ctl.start);
        ctl.trace.records = ck.trace;
        eprintln!(
            "[checkpoint] resuming fit at iteration {} from {}",
            ctl.start,
            ck_path.display()
        );
    }
    let mut scratch = SolveScratch::default();
    solve_loop(cluster, &mut global, cfg, Some(dataset), opts, &mut scratch, ctl)
}

/// Ridge re-fit on the recovered support (squared loss):
///   min_w sum_i ||A_{i,S} w - b_i||^2 + 1/(2 gamma) ||w||^2
/// solved by CG on the normal equations with per-shard matvecs (never
/// materializes the stacked data).
pub fn polish_ridge(ds: &Dataset, support: &[usize], gamma: f64, x: &mut [f64]) {
    polish_ridge_with(ds, support, gamma, x, &mut SolveScratch::default())
}

/// [`polish_ridge`] with caller-owned scratch (the slot map, right-hand
/// side, and CG iterate reuse the solve's allocation pool).
pub fn polish_ridge_with(
    ds: &Dataset,
    support: &[usize],
    gamma: f64,
    x: &mut [f64],
    scratch: &mut SolveScratch,
) {
    let s = support.len();
    if s == 0 {
        return;
    }
    // d/dx of 1/(2 gamma) ||x||^2 is x / gamma
    let reg = 1.0 / gamma;

    // column -> support-slot map so CSR rows join the support by index
    // probe instead of scanning it per entry
    if scratch.slot.capacity() >= x.len() && !x.is_empty() {
        scratch.saved_bytes += (x.len() * std::mem::size_of::<usize>()) as u64;
    }
    scratch.slot.clear();
    scratch.slot.resize(x.len(), usize::MAX);
    let slot = &mut scratch.slot;
    for (si, &col) in support.iter().enumerate() {
        slot[col] = si;
    }

    // rhs = 2 A_S^T b ; operator v -> 2 A_S^T A_S v + reg v, both
    // dispatched on shard storage (dense rows vs stored entries)
    SolveScratch::reuse_f64(&mut scratch.rhs, s, &mut scratch.saved_bytes);
    let rhs = &mut scratch.rhs;
    for shard in &ds.shards {
        match &shard.data {
            ShardData::Dense(a) => {
                for r in 0..a.rows {
                    let row = a.row(r);
                    let b = shard.labels[r] as f64;
                    for (si, &col) in support.iter().enumerate() {
                        rhs[si] += 2.0 * row[col] as f64 * b;
                    }
                }
            }
            ShardData::Csr(csr) => {
                for r in 0..csr.rows {
                    let b = shard.labels[r] as f64;
                    let (cols, vals) = csr.row(r);
                    for (&c, &v) in cols.iter().zip(vals) {
                        let si = slot[c as usize];
                        if si != usize::MAX {
                            rhs[si] += 2.0 * v as f64 * b;
                        }
                    }
                }
            }
            ShardData::Mapped(m) if m.is_csr() => {
                for r in 0..m.rows() {
                    let b = shard.labels[r] as f64;
                    let (cols, vals) = m.csr_row(r);
                    for (&c, &v) in cols.iter().zip(vals) {
                        let si = slot[c as usize];
                        if si != usize::MAX {
                            rhs[si] += 2.0 * v as f64 * b;
                        }
                    }
                }
            }
            ShardData::Mapped(m) => {
                for r in 0..m.rows() {
                    let row = m.dense_row(r);
                    let b = shard.labels[r] as f64;
                    for (si, &col) in support.iter().enumerate() {
                        rhs[si] += 2.0 * row[col] as f64 * b;
                    }
                }
            }
        }
    }
    SolveScratch::reuse_f64(&mut scratch.w, s, &mut scratch.saved_bytes);
    let w = &mut scratch.w;
    for (wi, &i) in w.iter_mut().zip(support) {
        *wi = x[i];
    }
    let slot = &scratch.slot;
    let apply = |v: &[f64], out: &mut [f64]| {
        out.iter_mut().for_each(|o| *o = 0.0);
        for shard in &ds.shards {
            match &shard.data {
                ShardData::Dense(a) => {
                    for r in 0..a.rows {
                        let row = a.row(r);
                        let mut av = 0.0f64;
                        for (si, &col) in support.iter().enumerate() {
                            av += row[col] as f64 * v[si];
                        }
                        for (si, &col) in support.iter().enumerate() {
                            out[si] += 2.0 * row[col] as f64 * av;
                        }
                    }
                }
                ShardData::Csr(csr) => {
                    for r in 0..csr.rows {
                        let (cols, vals) = csr.row(r);
                        let mut av = 0.0f64;
                        for (&c, &val) in cols.iter().zip(vals) {
                            let si = slot[c as usize];
                            if si != usize::MAX {
                                av += val as f64 * v[si];
                            }
                        }
                        if av == 0.0 {
                            continue;
                        }
                        for (&c, &val) in cols.iter().zip(vals) {
                            let si = slot[c as usize];
                            if si != usize::MAX {
                                out[si] += 2.0 * val as f64 * av;
                            }
                        }
                    }
                }
                ShardData::Mapped(m) if m.is_csr() => {
                    for r in 0..m.rows() {
                        let (cols, vals) = m.csr_row(r);
                        let mut av = 0.0f64;
                        for (&c, &val) in cols.iter().zip(vals) {
                            let si = slot[c as usize];
                            if si != usize::MAX {
                                av += val as f64 * v[si];
                            }
                        }
                        if av == 0.0 {
                            continue;
                        }
                        for (&c, &val) in cols.iter().zip(vals) {
                            let si = slot[c as usize];
                            if si != usize::MAX {
                                out[si] += 2.0 * val as f64 * av;
                            }
                        }
                    }
                }
                ShardData::Mapped(m) => {
                    for r in 0..m.rows() {
                        let row = m.dense_row(r);
                        let mut av = 0.0f64;
                        for (si, &col) in support.iter().enumerate() {
                            av += row[col] as f64 * v[si];
                        }
                        for (si, &col) in support.iter().enumerate() {
                            out[si] += 2.0 * row[col] as f64 * av;
                        }
                    }
                }
            }
        }
        for (o, vv) in out.iter_mut().zip(v) {
            *o += reg * vv;
        }
    };
    crate::linalg::conjugate_gradient(apply, rhs, w, 2 * s.min(200), 1e-10);
    for (si, &i) in support.iter().enumerate() {
        x[i] = w[si];
    }
}

/// Full regularized objective (Eq. 1) of a candidate solution — used by the
/// experiment harnesses to compare methods.
pub fn objective(ds: &Dataset, loss: &dyn crate::losses::Loss, gamma: f64, x: &[f64]) -> f64 {
    objective_with(ds, loss, gamma, x, &mut SolveScratch::default())
}

/// [`objective`] with caller-owned scratch: the per-class coefficient
/// cast, the per-shard prediction column, and the row-major prediction
/// block all come from the solve's allocation pool, so repeated
/// evaluations (harness sweeps, the solver benchmark) allocate nothing
/// after the first call.
pub fn objective_with(
    ds: &Dataset,
    loss: &dyn crate::losses::Loss,
    gamma: f64,
    x: &[f64],
    scratch: &mut SolveScratch,
) -> f64 {
    let width = loss.width();
    let n = ds.n_features;
    let mut total = 0.0;
    SolveScratch::reuse_f32(&mut scratch.obj_xc, n, &mut scratch.saved_bytes);
    let xc = &mut scratch.obj_xc;
    let col = &mut scratch.obj_col;
    let pred = &mut scratch.obj_pred;
    for shard in &ds.shards {
        let m = shard.rows();
        pred.clear();
        pred.resize(m * width, 0.0);
        col.clear();
        col.resize(m, 0.0);
        for c in 0..width {
            for (i, xi) in xc.iter_mut().enumerate() {
                *xi = x[c * n + i] as f32;
            }
            shard.data.matvec(xc, col);
            for r in 0..m {
                pred[r * width + c] = col[r];
            }
        }
        total += loss.value(&pred[..m * width], &shard.labels);
    }
    total + ops::dot(x, x) / (2.0 * gamma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::{NativeBackend, SolveMode};
    use crate::backend::BlockParams;
    use crate::config::Config;
    use crate::data::{FeaturePlan, SyntheticSpec};
    use crate::losses::{make_loss, Squared};
    use crate::network::{NodeWorker, SequentialCluster};
    use crate::sparsity::support_f1;

    fn build_cluster(ds: &Dataset, cfg: &Config, sweeps: usize) -> SequentialCluster {
        let plan = FeaturePlan::new(ds.n_features, cfg.platform.devices_per_node, 1 << 20);
        let params = BlockParams {
            rho_l: cfg.solver.rho_l,
            rho_c: cfg.solver.rho_c,
            reg: cfg.solver.block_reg(ds.nodes()),
        };
        let workers = ds
            .shards
            .iter()
            .enumerate()
            .map(|(i, shard)| {
                let loss = make_loss(cfg.loss, ds.width);
                let be = NativeBackend::new(shard, &plan, loss, SolveMode::Direct);
                NodeWorker::new(
                    i,
                    crate::admm::LocalProx::new(Box::new(be), plan.clone(), ds.width),
                    params,
                    sweeps,
                )
                .with_minibatch(cfg.solver.minibatch, cfg.solver.minibatch_seed)
            })
            .collect();
        SequentialCluster::new(workers, ds.n_features * ds.width)
    }

    use crate::data::Dataset;

    #[test]
    fn recovers_planted_support_small_regression() {
        let mut spec = SyntheticSpec::regression(40, 400, 2);
        spec.sparsity_level = 0.8; // kappa = 8
        spec.noise_std = 0.02;
        let ds = spec.generate();

        let mut cfg = Config::default();
        cfg.platform.nodes = 2;
        cfg.solver.kappa = spec.kappa();
        cfg.solver.rho_c = 1.0;
        cfg.solver.rho_b = 0.5;
        cfg.solver.max_iters = 300;
        let mut cluster = build_cluster(&ds, &cfg, 4);
        let res = solve(
            &mut cluster,
            40,
            &cfg,
            Some(&ds),
            &SolveOptions::default(),
        )
        .unwrap();

        let f1 = support_f1(&res.support, &ds.support_true);
        assert!(f1 > 0.9, "support F1 = {f1}, iters = {}", res.iters);
        assert_eq!(res.support.len(), spec.kappa());

        // polished solution must beat the thresholded consensus on objective
        let obj = objective(&ds, &Squared, cfg.solver.gamma, &res.x);
        let mut zt = res.z.clone();
        crate::sparsity::hard_threshold(&mut zt, cfg.solver.kappa);
        let obj_raw = objective(&ds, &Squared, cfg.solver.gamma, &zt);
        assert!(obj <= obj_raw + 1e-9, "{obj} > {obj_raw}");
    }

    #[test]
    fn residuals_decrease_and_terminate() {
        let mut spec = SyntheticSpec::regression(30, 240, 3);
        spec.sparsity_level = 0.9;
        let ds = spec.generate();
        let mut cfg = Config::default();
        cfg.platform.nodes = 3;
        cfg.solver.kappa = spec.kappa();
        cfg.solver.max_iters = 400;
        let mut cluster = build_cluster(&ds, &cfg, 3);
        let res = solve(&mut cluster, 30, &cfg, Some(&ds), &SolveOptions::default()).unwrap();
        assert!(res.converged, "did not converge in {} iters", res.iters);
        let first = &res.trace.records[1];
        let last = res.trace.last().unwrap();
        assert!(last.primal < first.primal);
        assert!(last.bilinear < 1e-3);
    }

    #[test]
    fn ledger_reflects_round_count() {
        let spec = SyntheticSpec::regression(10, 60, 2);
        let ds = spec.generate();
        let mut cfg = Config::default();
        cfg.platform.nodes = 2;
        cfg.solver.kappa = 2;
        cfg.solver.max_iters = 5;
        cfg.solver.tol_primal = 0.0; // force all iterations
        let mut cluster = build_cluster(&ds, &cfg, 2);
        let res = solve(&mut cluster, 10, &cfg, Some(&ds), &SolveOptions::default()).unwrap();
        assert_eq!(res.iters, 5);
        let per_round_down = 2 * 10 * 8; // nodes * dim * 8
        assert_eq!(res.transfers.net_down_bytes, (5 * per_round_down) as u64);
    }

    /// The scratch pool must (a) leave results identical to fresh
    /// allocation and (b) credit reused bytes to the solve ledger.
    #[test]
    fn solve_scratch_reuse_is_ledgered_and_bit_identical() {
        let spec = SyntheticSpec::regression(12, 80, 2);
        let ds = spec.generate();
        let mut cfg = Config::default();
        cfg.platform.nodes = 2;
        cfg.solver.kappa = 3;
        cfg.solver.max_iters = 6;
        cfg.solver.tol_primal = 0.0; // fixed rounds

        let run = |scratch: &mut SolveScratch| {
            let mut cluster = build_cluster(&ds, &cfg, 2);
            let mut global = GlobalState::new(12);
            solve_from_with(
                &mut cluster,
                &mut global,
                &cfg,
                Some(&ds),
                &SolveOptions::default(),
                scratch,
            )
            .unwrap()
        };
        let mut fresh = SolveScratch::default();
        let first = run(&mut fresh);
        // a warm scratch reuses the consensus/polish buffers
        let second = run(&mut fresh);
        assert!(
            second.transfers.net_alloc_saved_bytes
                >= first.transfers.net_alloc_saved_bytes + (12 * 8) as u64,
            "warm scratch reuse not credited: {} vs {}",
            second.transfers.net_alloc_saved_bytes,
            first.transfers.net_alloc_saved_bytes
        );
        // and the math is untouched by the pooling
        assert_eq!(first.z, second.z);
        assert_eq!(first.x, second.x);
        assert_eq!(first.support, second.support);
    }

    /// A fit killed mid-run and resumed from its PSF1 checkpoint must
    /// finish with a remaining trace bit-identical to an uninterrupted
    /// run — the same invariant the path subsystem pins for sweeps.
    #[test]
    fn checkpointed_fit_resumes_bit_identically() {
        let spec = SyntheticSpec::regression(16, 100, 2);
        let ds = spec.generate();
        let mut cfg = Config::default();
        cfg.platform.nodes = 2;
        cfg.solver.kappa = 4;
        cfg.solver.max_iters = 12;
        cfg.solver.tol_primal = 0.0; // fixed rounds: the full trace runs

        // reference: one uninterrupted solve
        let mut cluster = build_cluster(&ds, &cfg, 2);
        let reference =
            solve(&mut cluster, 16, &cfg, Some(&ds), &SolveOptions::default()).unwrap();
        assert_eq!(reference.trace.iters(), 12);

        // interrupted: checkpoint every 2 iterations, "kill" after 7 by
        // capping the budget, then resume with the full budget
        let path = std::env::temp_dir().join("psfit_solver_resume.psf");
        let _ = std::fs::remove_file(&path);
        let mut ck_cfg = cfg.clone();
        ck_cfg.solver.checkpoint = path.to_string_lossy().into_owned();
        ck_cfg.solver.checkpoint_every = 2;
        let mut half = ck_cfg.clone();
        half.solver.max_iters = 7;
        let mut cluster = build_cluster(&ds, &half, 2);
        let partial =
            solve_checkpointed(&mut cluster, 16, &half, &ds, &SolveOptions::default()).unwrap();
        assert!(!partial.converged);
        assert!(path.exists(), "no checkpoint was written");

        let mut cluster = build_cluster(&ds, &ck_cfg, 2);
        let resumed =
            solve_checkpointed(&mut cluster, 16, &ck_cfg, &ds, &SolveOptions::default()).unwrap();
        assert_eq!(resumed.iters, 12);
        assert_eq!(resumed.trace.iters(), reference.trace.iters());
        for (a, b) in resumed.trace.records.iter().zip(&reference.trace.records) {
            assert_eq!(a.iter, b.iter);
            assert_eq!(a.primal.to_bits(), b.primal.to_bits(), "iter {}", a.iter);
            assert_eq!(a.dual.to_bits(), b.dual.to_bits(), "iter {}", a.iter);
            assert_eq!(a.bilinear.to_bits(), b.bilinear.to_bits(), "iter {}", a.iter);
        }
        assert_eq!(resumed.z, reference.z);
        assert_eq!(resumed.x, reference.x);
        assert_eq!(resumed.support, reference.support);

        // a snapshot of a *different* problem is rejected, not re-seeded
        let other = SyntheticSpec::regression(16, 100, 3).generate();
        let mut cluster = build_cluster(&other, &ck_cfg, 2);
        let err = solve_checkpointed(&mut cluster, 16, &ck_cfg, &other, &SolveOptions::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("different fit"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    /// Mini-batch rounds are a pure function of (seed, round): two runs
    /// with the same seed must produce bit-identical traces and iterates.
    #[test]
    fn minibatch_same_seed_is_bit_identical() {
        let spec = SyntheticSpec::regression(16, 120, 2);
        let ds = spec.generate();
        let mut cfg = Config::default();
        cfg.platform.nodes = 2;
        cfg.solver.kappa = 4;
        cfg.solver.max_iters = 10;
        cfg.solver.tol_primal = 0.0; // fixed rounds
        cfg.solver.minibatch = 16; // 60 rows/node -> 4 chunks
        cfg.solver.minibatch_seed = 7;

        let run = || {
            let mut cluster = build_cluster(&ds, &cfg, 2);
            solve(&mut cluster, 16, &cfg, Some(&ds), &SolveOptions::default()).unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.trace.iters(), 10);
        for (ra, rb) in a.trace.records.iter().zip(&b.trace.records) {
            assert_eq!(ra.primal.to_bits(), rb.primal.to_bits(), "iter {}", ra.iter);
            assert_eq!(ra.dual.to_bits(), rb.dual.to_bits(), "iter {}", ra.iter);
        }
        assert_eq!(a.z, b.z);
        assert_eq!(a.x, b.x);
        assert_eq!(a.support, b.support);
    }

    /// A window at least as large as the shard is the full-batch sentinel:
    /// the run must reproduce `minibatch = 0` bit-for-bit.
    #[test]
    fn minibatch_window_covering_shard_matches_full_batch_bit_for_bit() {
        let spec = SyntheticSpec::regression(14, 96, 2);
        let ds = spec.generate();
        let mut cfg = Config::default();
        cfg.platform.nodes = 2;
        cfg.solver.kappa = 3;
        cfg.solver.max_iters = 8;
        cfg.solver.tol_primal = 0.0;

        let run = |mb: usize| {
            let mut c = cfg.clone();
            c.solver.minibatch = mb;
            c.solver.minibatch_seed = 99;
            let mut cluster = build_cluster(&ds, &c, 2);
            solve(&mut cluster, 14, &c, Some(&ds), &SolveOptions::default()).unwrap()
        };
        let full = run(0);
        // 48 rows per node: a window of exactly the shard and one far past
        // it both degenerate to the full-batch trajectory
        for mb in [48, 1000] {
            let win = run(mb);
            assert_eq!(win.z, full.z, "minibatch = {mb}");
            assert_eq!(win.x, full.x, "minibatch = {mb}");
            assert_eq!(win.support, full.support, "minibatch = {mb}");
            for (ra, rb) in win.trace.records.iter().zip(&full.trace.records) {
                assert_eq!(ra.primal.to_bits(), rb.primal.to_bits(), "iter {}", ra.iter);
            }
        }
    }

    /// A mini-batch fit killed mid-run and resumed from its checkpoint
    /// must replay the chunk schedule: `Cluster::fast_forward` restores
    /// the round counter, so the remaining trace is bit-identical to an
    /// uninterrupted run's.
    #[test]
    fn minibatch_resume_replays_the_chunk_schedule() {
        let spec = SyntheticSpec::regression(16, 100, 2);
        let ds = spec.generate();
        let mut cfg = Config::default();
        cfg.platform.nodes = 2;
        cfg.solver.kappa = 4;
        cfg.solver.max_iters = 12;
        cfg.solver.tol_primal = 0.0;
        cfg.solver.minibatch = 16; // 50 rows/node -> 4 chunks
        cfg.solver.minibatch_seed = 3;

        let mut cluster = build_cluster(&ds, &cfg, 2);
        let reference =
            solve(&mut cluster, 16, &cfg, Some(&ds), &SolveOptions::default()).unwrap();
        assert_eq!(reference.trace.iters(), 12);

        let path = std::env::temp_dir().join("psfit_minibatch_resume.psf");
        let _ = std::fs::remove_file(&path);
        let mut ck_cfg = cfg.clone();
        ck_cfg.solver.checkpoint = path.to_string_lossy().into_owned();
        ck_cfg.solver.checkpoint_every = 1;
        let mut half = ck_cfg.clone();
        half.solver.max_iters = 7;
        let mut cluster = build_cluster(&ds, &half, 2);
        let partial =
            solve_checkpointed(&mut cluster, 16, &half, &ds, &SolveOptions::default()).unwrap();
        assert!(!partial.converged);
        assert!(path.exists(), "no checkpoint was written");

        let mut cluster = build_cluster(&ds, &ck_cfg, 2);
        let resumed =
            solve_checkpointed(&mut cluster, 16, &ck_cfg, &ds, &SolveOptions::default()).unwrap();
        assert_eq!(resumed.iters, 12);
        for (a, b) in resumed.trace.records.iter().zip(&reference.trace.records) {
            assert_eq!(a.primal.to_bits(), b.primal.to_bits(), "iter {}", a.iter);
            assert_eq!(a.dual.to_bits(), b.dual.to_bits(), "iter {}", a.iter);
        }
        assert_eq!(resumed.z, reference.z);
        assert_eq!(resumed.x, reference.x);
        assert_eq!(resumed.support, reference.support);

        // a checkpoint from a different chunk schedule is a different fit
        let mut other = ck_cfg.clone();
        other.solver.minibatch_seed = 4;
        let mut cluster = build_cluster(&ds, &other, 2);
        let err = solve_checkpointed(&mut cluster, 16, &other, &ds, &SolveOptions::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("different fit"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    /// Wrapper that scales every reply by a factor exploding 1e5x per
    /// round inside `[grow_from, grow_until)` — a deterministic stand-in
    /// for a numerically runaway trajectory.  The factors stay well below
    /// the guard's 1e150 norm cap, so the replies pass quarantine and the
    /// growth must be caught by the *watchdog's* residual trend.
    struct GrowingCluster {
        inner: SequentialCluster,
        grow_from: usize,
        grow_until: usize,
        round: usize,
    }

    impl Cluster for GrowingCluster {
        fn nodes(&self) -> usize {
            self.inner.nodes()
        }
        fn round(&mut self, z: &[f64]) -> anyhow::Result<Vec<crate::network::NodeReply>> {
            let mut replies = self.inner.round(z)?;
            if self.round >= self.grow_from && self.round < self.grow_until {
                let exp = 5 * (self.round - self.grow_from + 1) as i32;
                let f = 10f64.powi(exp.min(280)).min(1e140);
                for r in &mut replies {
                    for v in &mut r.x {
                        *v *= f;
                    }
                }
            }
            self.round += 1;
            Ok(replies)
        }
        fn loss_value(&mut self) -> anyhow::Result<f64> {
            self.inner.loss_value()
        }
        fn ledger(&mut self) -> TransferLedger {
            self.inner.ledger()
        }
        fn recycle(&mut self, replies: Vec<crate::network::NodeReply>) {
            self.inner.recycle(replies)
        }
        fn export_warm(&mut self) -> anyhow::Result<Vec<WarmState>> {
            self.inner.export_warm()
        }
        fn reseed(&mut self, states: &[WarmState], params: BlockParams) -> anyhow::Result<()> {
            self.inner.reseed(states, params)
        }
    }

    fn growth_problem() -> (Dataset, Config) {
        let mut spec = SyntheticSpec::regression(24, 160, 2);
        spec.sparsity_level = 0.8;
        spec.noise_std = 0.02;
        let ds = spec.generate();
        let mut cfg = Config::default();
        cfg.platform.nodes = 2;
        cfg.solver.kappa = spec.kappa();
        cfg.solver.rho_c = 1.0;
        cfg.solver.rho_b = 0.5;
        cfg.solver.watchdog_window = 3;
        (ds, cfg)
    }

    /// A penalty so large it overflows the coordinator's Lipschitz bound
    /// must end in a structured `SolveError::Diverged` within the
    /// watchdog window — never a silent full-budget run and never a panic
    /// inside the projections.
    #[test]
    fn pathological_rho_returns_structured_diverged() {
        let mut spec = SyntheticSpec::regression(20, 120, 2);
        spec.sparsity_level = 0.8;
        let ds = spec.generate();
        let mut cfg = Config::default();
        cfg.platform.nodes = 2;
        cfg.solver.kappa = spec.kappa();
        cfg.solver.rho_c = 1e308; // participants * rho_c overflows to inf
        cfg.solver.max_iters = 400;
        let mut cluster = build_cluster(&ds, &cfg, 2);
        let err = solve(&mut cluster, 20, &cfg, Some(&ds), &SolveOptions::default()).unwrap_err();
        let diverged = err
            .downcast_ref::<SolveError>()
            .unwrap_or_else(|| panic!("expected SolveError, got: {err:#}"));
        let SolveError::Diverged {
            round, restarts, ..
        } = diverged;
        assert!(
            *round <= cfg.solver.watchdog_window,
            "diverged at round {round}, after the watchdog window"
        );
        // no finite state was ever captured, so no restart was possible
        assert_eq!(*restarts, 0);
        assert!(err.to_string().contains("diverged"), "{err}");
    }

    /// Transient injected growth trips the watchdog once; the safeguarded
    /// restart (rho/10, re-seed from the last finite state) lets the
    /// solve continue, and the restart count lands in the result and in
    /// every subsequent trace record.
    #[test]
    fn watchdog_restart_recovers_from_transient_growth() {
        let (ds, mut cfg) = growth_problem();
        cfg.solver.max_iters = 600;
        let mut cluster = GrowingCluster {
            inner: build_cluster(&ds, &cfg, 3),
            grow_from: 1,
            grow_until: 4, // rounds 1..=3 explode, then the fault clears
            round: 0,
        };
        let res = solve(&mut cluster, 24, &cfg, Some(&ds), &SolveOptions::default()).unwrap();
        assert_eq!(res.restarts, 1, "exactly one safeguarded restart");
        assert!(res.iters > 4, "solve continued past the trip");
        let last = res.trace.last().unwrap();
        assert_eq!(last.restarts, 1, "trace records carry the restart count");
        assert!(
            res.trace.records.iter().any(|r| r.restarts == 0),
            "pre-restart records show zero restarts"
        );
    }

    /// Persistent growth exhausts the restart budget and ends in
    /// `Diverged` carrying the number of restarts that were attempted.
    #[test]
    fn exhausted_restarts_end_in_structured_diverged() {
        let (ds, mut cfg) = growth_problem();
        cfg.solver.max_iters = 80;
        cfg.solver.watchdog_restarts = 2;
        let mut cluster = GrowingCluster {
            inner: build_cluster(&ds, &cfg, 3),
            grow_from: 1,
            grow_until: usize::MAX, // the fault never clears
            round: 0,
        };
        let err = solve(&mut cluster, 24, &cfg, Some(&ds), &SolveOptions::default()).unwrap_err();
        match err.downcast_ref::<SolveError>() {
            Some(SolveError::Diverged {
                restarts, round, ..
            }) => {
                assert_eq!(*restarts, 2, "both restarts were spent first");
                assert!(*round < 40, "gave up at round {round}");
            }
            None => panic!("expected SolveError::Diverged, got: {err:#}"),
        }
    }

    /// `solver.deadline_ms` cuts the solve at a round boundary: at least
    /// one round always completes, the result carries the best-so-far
    /// iterate (nonempty support, usable trace), and `timed_out` is set.
    #[test]
    fn deadline_returns_best_so_far_cleanly() {
        let mut spec = SyntheticSpec::regression(20, 120, 2);
        spec.sparsity_level = 0.8;
        let ds = spec.generate();
        let mut cfg = Config::default();
        cfg.platform.nodes = 2;
        cfg.solver.kappa = spec.kappa();
        cfg.solver.tol_primal = 0.0; // never converges on tolerance
        cfg.solver.max_iters = 2_000_000;
        cfg.solver.deadline_ms = 1;
        let mut cluster = build_cluster(&ds, &cfg, 2);
        let res = solve(&mut cluster, 20, &cfg, Some(&ds), &SolveOptions::default()).unwrap();
        assert!(res.timed_out, "deadline must trip");
        assert!(!res.converged);
        assert!(res.iters >= 1, "at least one round completes");
        assert!(res.iters < 2_000_000, "deadline cut the budget");
        assert_eq!(res.trace.iters(), res.iters);
        assert!(!res.support.is_empty(), "best-so-far support is usable");
    }

    #[test]
    fn polish_ridge_fits_exactly_on_noiseless_support() {
        let mut spec = SyntheticSpec::regression(20, 200, 2);
        spec.noise_std = 0.0;
        spec.sparsity_level = 0.85;
        let ds = spec.generate();
        let mut x = vec![0.0f64; 20];
        polish_ridge(&ds, &ds.support_true, 1e9, &mut x);
        for &i in &ds.support_true {
            assert!(
                (x[i] - ds.x_true[i]).abs() < 1e-3,
                "{} vs {}",
                x[i],
                ds.x_true[i]
            );
        }
    }
}

//! Algorithm 1 — the outer Bi-cADMM consensus loop.
//!
//! Orchestrates a [`Cluster`] of node workers against the coordinator's
//! [`GlobalState`], with residual-based termination (Eq. 14) and solution
//! extraction (hard threshold to kappa + optional ridge polish on the
//! recovered support).

use crate::backend::BlockParams;
use crate::config::Config;
use crate::data::{Dataset, ShardData};
use crate::linalg::ops;
use crate::losses::LossKind;
use crate::metrics::{Trace, TransferLedger};
use crate::network::{Cluster, WarmState};
use crate::path::checkpoint::{self, FitCheckpoint};
use crate::sparsity::{hard_threshold, support_of};
use crate::util::Stopwatch;

use super::global::GlobalState;

/// Complete resumable solver state: the coordinator's global variables
/// plus every node's warm-start snapshot.
///
/// This is the unit the path subsystem hands from one path point to the
/// next (warm starts) and what `path::checkpoint` serializes so a killed
/// sweep resumes bit-identically at the last completed point.  Capturing
/// and re-injecting it through [`Cluster::export_warm`] /
/// [`Cluster::reseed`] is the *only* state transfer between path points,
/// so a resumed run and an uninterrupted run see exactly the same inputs.
#[derive(Debug, Clone, PartialEq)]
pub struct SolverState {
    /// Coordinator-side (z, t, s, v, z_prev).
    pub global: GlobalState,
    /// Per-node (x_i, u_i) plus the inner sharing-ADMM state, sorted by
    /// node id.
    pub nodes: Vec<WarmState>,
}

impl SolverState {
    /// Snapshot the full solver state after a solve: the given global
    /// variables plus the warm state exported from every node.
    pub fn capture(cluster: &mut dyn Cluster, global: &GlobalState) -> anyhow::Result<SolverState> {
        Ok(SolverState {
            global: global.clone(),
            nodes: cluster.export_warm()?,
        })
    }
}

/// Reusable allocation pool for the solver's per-round and per-call
/// temporaries (the consensus average, the extraction/polish buffers, and
/// the objective's prediction marshalling).
///
/// One solve allocates each buffer once; reusing the scratch across
/// solves — the path subsystem holds one for its whole budget sweep —
/// turns every later solve's temporary into a `resize` on warm capacity.
/// The bytes this avoids are recorded and surfaced through
/// [`crate::metrics::TransferLedger::net_alloc_saved_bytes`], alongside
/// the transport-layer reuse counters.
#[derive(Debug, Default)]
pub struct SolveScratch {
    /// Consensus average c = mean_i(x_i + u_i), length dim.
    c: Vec<f64>,
    /// Support-slot map of the polish step (length dim, usize::MAX = off
    /// support).
    slot: Vec<usize>,
    /// Polish right-hand side / iterate (length |support|).
    rhs: Vec<f64>,
    /// Polish CG iterate (length |support|).
    w: Vec<f64>,
    /// Objective: one class column of x in f32 (length n).
    obj_xc: Vec<f32>,
    /// Objective: one shard's prediction column (length m_i).
    obj_col: Vec<f32>,
    /// Objective: one shard's row-major prediction block (m_i * width).
    obj_pred: Vec<f32>,
    /// Allocation bytes avoided by reuse since construction (drained into
    /// the solve ledger by `solve_from_with`).
    saved_bytes: u64,
}

impl SolveScratch {
    /// Resize `buf` to `len` zeros, crediting an avoided allocation when
    /// the capacity was already there.
    fn reuse_f64(buf: &mut Vec<f64>, len: usize, saved: &mut u64) {
        if buf.capacity() >= len && len > 0 {
            *saved += (len * std::mem::size_of::<f64>()) as u64;
        }
        buf.clear();
        buf.resize(len, 0.0);
    }

    /// f32 twin of [`SolveScratch::reuse_f64`].
    fn reuse_f32(buf: &mut Vec<f32>, len: usize, saved: &mut u64) {
        if buf.capacity() >= len && len > 0 {
            *saved += (len * std::mem::size_of::<f32>()) as u64;
        }
        buf.clear();
        buf.resize(len, 0.0);
    }
}

/// Options orthogonal to the math: transport and reporting.
#[derive(Debug, Clone)]
pub struct SolveOptions {
    /// Record the (expensive) training loss each iteration.
    pub track_loss: bool,
    /// Print per-iteration residuals to stderr.
    pub verbose: bool,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions {
            track_loss: false,
            verbose: false,
        }
    }
}

/// Everything a finished Bi-cADMM solve reports back.
#[derive(Debug, Clone)]
pub struct SolveResult {
    /// Dense consensus iterate at termination.
    pub z: Vec<f64>,
    /// Async coordination accounting (None for synchronous clusters).
    pub coordination: Option<crate::metrics::CoordinationStats>,
    /// kappa-sparse solution (hard-thresholded z, optionally polished).
    pub x: Vec<f64>,
    /// Support of `x` (sorted indices into the flattened coefficients).
    pub support: Vec<usize>,
    /// Per-iteration residual records (Eq. 14).
    pub trace: Trace,
    /// Merged transfer + network byte ledger over all nodes.
    pub transfers: TransferLedger,
    /// Outer iterations executed.
    pub iters: usize,
    /// Whether the residual thresholds were met before `max_iters`.
    pub converged: bool,
    /// Wall-clock seconds spent in the outer loop.
    pub wall_seconds: f64,
    /// Training loss at the final iterate (if tracked or cheap).
    pub final_loss: Option<f64>,
}

/// Run Bi-cADMM over an already-built cluster, cold-started.
///
/// `dim` = n_features * width.  The polish step (squared loss only)
/// re-fits a ridge on the recovered support using the dataset.
pub fn solve(
    cluster: &mut dyn Cluster,
    dim: usize,
    cfg: &Config,
    dataset: Option<&Dataset>,
    opts: &SolveOptions,
) -> anyhow::Result<SolveResult> {
    let mut global = GlobalState::new(dim);
    solve_from(cluster, &mut global, cfg, dataset, opts)
}

/// Run Bi-cADMM starting from the given global state (warm start).
///
/// This is [`solve`] with the monolithic loop's state extracted: the
/// caller owns `global`, which is read as the starting point and left at
/// the final iterate, so consecutive solves over a [`Cluster`] that was
/// re-seeded with matching node state continue one trajectory.  The path
/// subsystem drives its budget/penalty sweeps through here.
pub fn solve_from(
    cluster: &mut dyn Cluster,
    global: &mut GlobalState,
    cfg: &Config,
    dataset: Option<&Dataset>,
    opts: &SolveOptions,
) -> anyhow::Result<SolveResult> {
    let mut scratch = SolveScratch::default();
    solve_from_with(cluster, global, cfg, dataset, opts, &mut scratch)
}

/// [`solve_from`] with a caller-owned [`SolveScratch`], so consecutive
/// solves (the path subsystem's budget sweep) reuse every temporary
/// instead of re-allocating it per point.
pub fn solve_from_with(
    cluster: &mut dyn Cluster,
    global: &mut GlobalState,
    cfg: &Config,
    dataset: Option<&Dataset>,
    opts: &SolveOptions,
    scratch: &mut SolveScratch,
) -> anyhow::Result<SolveResult> {
    solve_loop(cluster, global, cfg, dataset, opts, scratch, LoopCtl::default())
}

/// Mid-fit snapshot sink: where `solve_loop` writes PSF1 checkpoints,
/// how often, and the problem fingerprint stamped into them.
struct CkptSink<'a> {
    path: &'a std::path::Path,
    every: usize,
    hash: u64,
    /// Full roster size; a snapshot whose warm export does not cover
    /// every node (degraded cluster) is skipped, because a resume builds
    /// a fresh full cluster that such a partial state could never seed.
    roster: usize,
}

/// Resume/checkpoint controls threaded through [`solve_loop`]; the
/// default is a plain cold-started, non-checkpointing solve.
#[derive(Default)]
struct LoopCtl<'a> {
    /// First outer iteration to run (`> 0` when resuming a checkpoint).
    start: usize,
    /// Records of iterations completed before `start`, prepended to the
    /// returned trace.
    trace: Trace,
    /// Periodic snapshot sink, if checkpointing.
    ckpt: Option<CkptSink<'a>>,
}

/// The shared outer loop behind [`solve_from_with`] and
/// [`solve_checkpointed`].
fn solve_loop(
    cluster: &mut dyn Cluster,
    global: &mut GlobalState,
    cfg: &Config,
    dataset: Option<&Dataset>,
    opts: &SolveOptions,
    scratch: &mut SolveScratch,
    ctl: LoopCtl<'_>,
) -> anyhow::Result<SolveResult> {
    cfg.solver.validate()?;
    let sc = &cfg.solver;
    let watch = Stopwatch::start();

    let dim = global.z.len();
    let LoopCtl {
        start,
        mut trace,
        ckpt,
    } = ctl;
    SolveScratch::reuse_f64(&mut scratch.c, dim, &mut scratch.saved_bytes);
    let c = &mut scratch.c;
    let mut converged = false;
    let mut iters = start;

    // scaled termination thresholds (absolute tolerances scaled by the
    // problem dimension, Boyd §3.3 style); the primal threshold scales
    // with the nodes that actually contributed residual terms this round,
    // so a degraded async cluster is held to the same per-node accuracy
    let d_thresh = sc.tol_dual * (dim as f64).sqrt().max(1.0);
    let b_thresh = sc.tol_bilinear;

    for k in start..sc.max_iters {
        iters = k + 1;
        // ---- Bcast z^k / Collect x_i^{k+1}, u_i^k -----------------------
        let replies = cluster.round(&global.z)?;
        anyhow::ensure!(
            !replies.is_empty(),
            "round {k}: no node replies (cluster lost its quorum)"
        );

        // ---- global updates (7b), (12), (13) ----------------------------
        // Averages are weighted by the nodes that actually participated
        // (Zhu-style partial barrier): under synchronous coordination every
        // node replies and this reduces exactly to the 1/N mean.
        let participants = replies.len();
        let max_lag = replies.iter().map(|r| r.lag).max().unwrap_or(0);
        c.fill(0.0);
        for r in &replies {
            for i in 0..dim {
                c[i] += r.x[i] + r.u[i];
            }
        }
        let inv = 1.0 / participants as f64;
        for ci in c.iter_mut() {
            *ci *= inv;
        }
        global.zt_update(c, participants, sc.rho_c, sc.rho_b, sc.zt_iters);

        // ---- residuals (14): bilinear measured against the PREVIOUS s ---
        // (g(z^{k+1}, s^k, t^{k+1}) — the quantity the rho_b penalty acts
        // on; the closed-form s-update that follows zeroes g whenever the
        // target is reachable, so measuring after it would be trivially 0)
        // The replies stream straight into the residual computation — no
        // per-round `Vec<&[f64]>` marshalling at all (streaming needs no
        // ledger credit: there is simply nothing left to allocate).
        let mut rec = global.residuals(
            replies.iter().map(|r| r.x.as_slice()),
            sc.rho_c,
            k,
            watch.elapsed_secs(),
        );
        rec.max_lag = max_lag;
        // hand the reply buffers back to the transport for reuse — the
        // next round's Collect fills them in place instead of allocating
        cluster.recycle(replies);

        global.s_update(sc.kappa);
        global.v_update();

        if opts.verbose {
            eprintln!(
                "iter {:>4}  primal {:>10.3e}  dual {:>10.3e}  bilinear {:>10.3e}",
                k, rec.primal, rec.dual, rec.bilinear
            );
        }
        let p_thresh = sc.tol_primal * ((participants * dim) as f64).sqrt().max(1.0);
        let done = k > 0
            && rec.primal <= p_thresh
            && rec.dual <= d_thresh
            && rec.bilinear <= b_thresh;
        trace.push(rec);
        if done {
            converged = true;
            break;
        }
        // ---- periodic mid-fit snapshot ----------------------------------
        // Captured at the iteration boundary — exactly the state the next
        // iteration reads — so a resume replays nothing and the remaining
        // trace is bit-identical to an uninterrupted run.
        if let Some(sink) = &ckpt {
            if iters % sink.every == 0 {
                let state = SolverState::capture(cluster, global)?;
                let full = state.nodes.len() == sink.roster
                    && (0..sink.roster).all(|i| state.nodes.iter().any(|w| w.node == i));
                if full {
                    checkpoint::save_fit(
                        sink.path,
                        &FitCheckpoint {
                            problem_hash: sink.hash,
                            iters_done: iters as u64,
                            trace: trace.records.clone(),
                            state,
                        },
                    )?;
                }
            }
        }
    }

    // ---- solution extraction -------------------------------------------
    let mut x = global.z.clone();
    hard_threshold(&mut x, sc.kappa);
    let support = support_of(&x, 0.0);
    if sc.polish && cfg.loss == LossKind::Squared {
        if let Some(ds) = dataset {
            polish_ridge_with(ds, &support, sc.gamma, &mut x, scratch);
        }
    }

    let final_loss = if opts.track_loss {
        Some(cluster.loss_value()?)
    } else {
        None
    };

    // ledger first: collecting it can surface deaths that the
    // coordination snapshot should include
    let mut transfers = cluster.ledger();
    // fold in the solver-side reuse: scratch buffers that were served
    // from warm capacity this solve instead of freshly allocated
    transfers.net_alloc_saved_bytes += std::mem::take(&mut scratch.saved_bytes);
    Ok(SolveResult {
        z: global.z.clone(),
        coordination: cluster.coordination(),
        x,
        support,
        trace,
        transfers,
        iters,
        converged,
        wall_seconds: watch.elapsed_secs(),
        final_loss,
    })
}

/// Run Bi-cADMM with mid-fit checkpointing (`psfit train --checkpoint`,
/// serve jobs).
///
/// With `cfg.solver.checkpoint` empty this is exactly [`solve`].
/// Otherwise the solve writes a PSF1 snapshot (full [`SolverState`] plus
/// the trace so far) to that path every `cfg.solver.checkpoint_every`
/// completed iterations, atomically; and when the file already holds a
/// snapshot of the *same* problem (checked via
/// [`checkpoint::problem_hash`] over the dataset and every
/// trajectory-shaping setting), the fit resumes at the saved iteration
/// instead of restarting.  Snapshots land on iteration boundaries, so
/// the resumed run's remaining residual trace is bit-identical to an
/// uninterrupted run's.  A checkpoint written for a different problem is
/// rejected, never silently re-seeded.
pub fn solve_checkpointed(
    cluster: &mut dyn Cluster,
    dim: usize,
    cfg: &Config,
    dataset: &Dataset,
    opts: &SolveOptions,
) -> anyhow::Result<SolveResult> {
    cfg.solver.validate()?;
    if cfg.solver.checkpoint.is_empty() {
        return solve(cluster, dim, cfg, Some(dataset), opts);
    }
    let ck_path = std::path::Path::new(&cfg.solver.checkpoint);
    // The iteration budget is deliberately excluded from the fingerprint:
    // a checkpointed fit may legitimately resume with a larger max_iters
    // (more budget), and a kill leaves the budget partially spent — only
    // the trajectory-shaping settings must match.
    let hash = {
        let mut hcfg = cfg.clone();
        hcfg.solver.max_iters = 0;
        checkpoint::problem_hash(dataset, &hcfg, &[])
    };
    let mut global = GlobalState::new(dim);
    let mut ctl = LoopCtl {
        ckpt: Some(CkptSink {
            path: ck_path,
            every: cfg.solver.checkpoint_every.max(1),
            hash,
            roster: dataset.nodes(),
        }),
        ..LoopCtl::default()
    };
    if ck_path.exists() {
        let ck = checkpoint::load_fit(ck_path)?;
        anyhow::ensure!(
            ck.problem_hash == hash,
            "checkpoint {} was written for a different fit (hash mismatch); \
             delete it or point solver.checkpoint elsewhere",
            ck_path.display()
        );
        let params = BlockParams {
            rho_l: cfg.solver.rho_l,
            rho_c: cfg.solver.rho_c,
            reg: cfg.solver.block_reg(dataset.nodes()),
        };
        cluster.reseed(&ck.state.nodes, params)?;
        global = ck.state.global.clone();
        ctl.start = ck.iters_done as usize;
        ctl.trace.records = ck.trace;
        eprintln!(
            "[checkpoint] resuming fit at iteration {} from {}",
            ctl.start,
            ck_path.display()
        );
    }
    let mut scratch = SolveScratch::default();
    solve_loop(cluster, &mut global, cfg, Some(dataset), opts, &mut scratch, ctl)
}

/// Ridge re-fit on the recovered support (squared loss):
///   min_w sum_i ||A_{i,S} w - b_i||^2 + 1/(2 gamma) ||w||^2
/// solved by CG on the normal equations with per-shard matvecs (never
/// materializes the stacked data).
pub fn polish_ridge(ds: &Dataset, support: &[usize], gamma: f64, x: &mut [f64]) {
    polish_ridge_with(ds, support, gamma, x, &mut SolveScratch::default())
}

/// [`polish_ridge`] with caller-owned scratch (the slot map, right-hand
/// side, and CG iterate reuse the solve's allocation pool).
pub fn polish_ridge_with(
    ds: &Dataset,
    support: &[usize],
    gamma: f64,
    x: &mut [f64],
    scratch: &mut SolveScratch,
) {
    let s = support.len();
    if s == 0 {
        return;
    }
    // d/dx of 1/(2 gamma) ||x||^2 is x / gamma
    let reg = 1.0 / gamma;

    // column -> support-slot map so CSR rows join the support by index
    // probe instead of scanning it per entry
    if scratch.slot.capacity() >= x.len() && !x.is_empty() {
        scratch.saved_bytes += (x.len() * std::mem::size_of::<usize>()) as u64;
    }
    scratch.slot.clear();
    scratch.slot.resize(x.len(), usize::MAX);
    let slot = &mut scratch.slot;
    for (si, &col) in support.iter().enumerate() {
        slot[col] = si;
    }

    // rhs = 2 A_S^T b ; operator v -> 2 A_S^T A_S v + reg v, both
    // dispatched on shard storage (dense rows vs stored entries)
    SolveScratch::reuse_f64(&mut scratch.rhs, s, &mut scratch.saved_bytes);
    let rhs = &mut scratch.rhs;
    for shard in &ds.shards {
        match &shard.data {
            ShardData::Dense(a) => {
                for r in 0..a.rows {
                    let row = a.row(r);
                    let b = shard.labels[r] as f64;
                    for (si, &col) in support.iter().enumerate() {
                        rhs[si] += 2.0 * row[col] as f64 * b;
                    }
                }
            }
            ShardData::Csr(csr) => {
                for r in 0..csr.rows {
                    let b = shard.labels[r] as f64;
                    let (cols, vals) = csr.row(r);
                    for (&c, &v) in cols.iter().zip(vals) {
                        let si = slot[c as usize];
                        if si != usize::MAX {
                            rhs[si] += 2.0 * v as f64 * b;
                        }
                    }
                }
            }
        }
    }
    SolveScratch::reuse_f64(&mut scratch.w, s, &mut scratch.saved_bytes);
    let w = &mut scratch.w;
    for (wi, &i) in w.iter_mut().zip(support) {
        *wi = x[i];
    }
    let slot = &scratch.slot;
    let apply = |v: &[f64], out: &mut [f64]| {
        out.iter_mut().for_each(|o| *o = 0.0);
        for shard in &ds.shards {
            match &shard.data {
                ShardData::Dense(a) => {
                    for r in 0..a.rows {
                        let row = a.row(r);
                        let mut av = 0.0f64;
                        for (si, &col) in support.iter().enumerate() {
                            av += row[col] as f64 * v[si];
                        }
                        for (si, &col) in support.iter().enumerate() {
                            out[si] += 2.0 * row[col] as f64 * av;
                        }
                    }
                }
                ShardData::Csr(csr) => {
                    for r in 0..csr.rows {
                        let (cols, vals) = csr.row(r);
                        let mut av = 0.0f64;
                        for (&c, &val) in cols.iter().zip(vals) {
                            let si = slot[c as usize];
                            if si != usize::MAX {
                                av += val as f64 * v[si];
                            }
                        }
                        if av == 0.0 {
                            continue;
                        }
                        for (&c, &val) in cols.iter().zip(vals) {
                            let si = slot[c as usize];
                            if si != usize::MAX {
                                out[si] += 2.0 * val as f64 * av;
                            }
                        }
                    }
                }
            }
        }
        for (o, vv) in out.iter_mut().zip(v) {
            *o += reg * vv;
        }
    };
    crate::linalg::conjugate_gradient(apply, rhs, w, 2 * s.min(200), 1e-10);
    for (si, &i) in support.iter().enumerate() {
        x[i] = w[si];
    }
}

/// Full regularized objective (Eq. 1) of a candidate solution — used by the
/// experiment harnesses to compare methods.
pub fn objective(ds: &Dataset, loss: &dyn crate::losses::Loss, gamma: f64, x: &[f64]) -> f64 {
    objective_with(ds, loss, gamma, x, &mut SolveScratch::default())
}

/// [`objective`] with caller-owned scratch: the per-class coefficient
/// cast, the per-shard prediction column, and the row-major prediction
/// block all come from the solve's allocation pool, so repeated
/// evaluations (harness sweeps, the solver benchmark) allocate nothing
/// after the first call.
pub fn objective_with(
    ds: &Dataset,
    loss: &dyn crate::losses::Loss,
    gamma: f64,
    x: &[f64],
    scratch: &mut SolveScratch,
) -> f64 {
    let width = loss.width();
    let n = ds.n_features;
    let mut total = 0.0;
    SolveScratch::reuse_f32(&mut scratch.obj_xc, n, &mut scratch.saved_bytes);
    let xc = &mut scratch.obj_xc;
    let col = &mut scratch.obj_col;
    let pred = &mut scratch.obj_pred;
    for shard in &ds.shards {
        let m = shard.rows();
        pred.clear();
        pred.resize(m * width, 0.0);
        col.clear();
        col.resize(m, 0.0);
        for c in 0..width {
            for (i, xi) in xc.iter_mut().enumerate() {
                *xi = x[c * n + i] as f32;
            }
            shard.data.matvec(xc, col);
            for r in 0..m {
                pred[r * width + c] = col[r];
            }
        }
        total += loss.value(&pred[..m * width], &shard.labels);
    }
    total + ops::dot(x, x) / (2.0 * gamma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::{NativeBackend, SolveMode};
    use crate::backend::BlockParams;
    use crate::config::Config;
    use crate::data::{FeaturePlan, SyntheticSpec};
    use crate::losses::{make_loss, Squared};
    use crate::network::{NodeWorker, SequentialCluster};
    use crate::sparsity::support_f1;

    fn build_cluster(ds: &Dataset, cfg: &Config, sweeps: usize) -> SequentialCluster {
        let plan = FeaturePlan::new(ds.n_features, cfg.platform.devices_per_node, 1 << 20);
        let params = BlockParams {
            rho_l: cfg.solver.rho_l,
            rho_c: cfg.solver.rho_c,
            reg: cfg.solver.block_reg(ds.nodes()),
        };
        let workers = ds
            .shards
            .iter()
            .enumerate()
            .map(|(i, shard)| {
                let loss = make_loss(cfg.loss, ds.width);
                let be = NativeBackend::new(shard, &plan, loss, SolveMode::Direct);
                NodeWorker::new(
                    i,
                    crate::admm::LocalProx::new(Box::new(be), plan.clone(), ds.width),
                    params,
                    sweeps,
                )
            })
            .collect();
        SequentialCluster::new(workers, ds.n_features * ds.width)
    }

    use crate::data::Dataset;

    #[test]
    fn recovers_planted_support_small_regression() {
        let mut spec = SyntheticSpec::regression(40, 400, 2);
        spec.sparsity_level = 0.8; // kappa = 8
        spec.noise_std = 0.02;
        let ds = spec.generate();

        let mut cfg = Config::default();
        cfg.platform.nodes = 2;
        cfg.solver.kappa = spec.kappa();
        cfg.solver.rho_c = 1.0;
        cfg.solver.rho_b = 0.5;
        cfg.solver.max_iters = 300;
        let mut cluster = build_cluster(&ds, &cfg, 4);
        let res = solve(
            &mut cluster,
            40,
            &cfg,
            Some(&ds),
            &SolveOptions::default(),
        )
        .unwrap();

        let f1 = support_f1(&res.support, &ds.support_true);
        assert!(f1 > 0.9, "support F1 = {f1}, iters = {}", res.iters);
        assert_eq!(res.support.len(), spec.kappa());

        // polished solution must beat the thresholded consensus on objective
        let obj = objective(&ds, &Squared, cfg.solver.gamma, &res.x);
        let mut zt = res.z.clone();
        crate::sparsity::hard_threshold(&mut zt, cfg.solver.kappa);
        let obj_raw = objective(&ds, &Squared, cfg.solver.gamma, &zt);
        assert!(obj <= obj_raw + 1e-9, "{obj} > {obj_raw}");
    }

    #[test]
    fn residuals_decrease_and_terminate() {
        let mut spec = SyntheticSpec::regression(30, 240, 3);
        spec.sparsity_level = 0.9;
        let ds = spec.generate();
        let mut cfg = Config::default();
        cfg.platform.nodes = 3;
        cfg.solver.kappa = spec.kappa();
        cfg.solver.max_iters = 400;
        let mut cluster = build_cluster(&ds, &cfg, 3);
        let res = solve(&mut cluster, 30, &cfg, Some(&ds), &SolveOptions::default()).unwrap();
        assert!(res.converged, "did not converge in {} iters", res.iters);
        let first = &res.trace.records[1];
        let last = res.trace.last().unwrap();
        assert!(last.primal < first.primal);
        assert!(last.bilinear < 1e-3);
    }

    #[test]
    fn ledger_reflects_round_count() {
        let spec = SyntheticSpec::regression(10, 60, 2);
        let ds = spec.generate();
        let mut cfg = Config::default();
        cfg.platform.nodes = 2;
        cfg.solver.kappa = 2;
        cfg.solver.max_iters = 5;
        cfg.solver.tol_primal = 0.0; // force all iterations
        let mut cluster = build_cluster(&ds, &cfg, 2);
        let res = solve(&mut cluster, 10, &cfg, Some(&ds), &SolveOptions::default()).unwrap();
        assert_eq!(res.iters, 5);
        let per_round_down = 2 * 10 * 8; // nodes * dim * 8
        assert_eq!(res.transfers.net_down_bytes, (5 * per_round_down) as u64);
    }

    /// The scratch pool must (a) leave results identical to fresh
    /// allocation and (b) credit reused bytes to the solve ledger.
    #[test]
    fn solve_scratch_reuse_is_ledgered_and_bit_identical() {
        let spec = SyntheticSpec::regression(12, 80, 2);
        let ds = spec.generate();
        let mut cfg = Config::default();
        cfg.platform.nodes = 2;
        cfg.solver.kappa = 3;
        cfg.solver.max_iters = 6;
        cfg.solver.tol_primal = 0.0; // fixed rounds

        let run = |scratch: &mut SolveScratch| {
            let mut cluster = build_cluster(&ds, &cfg, 2);
            let mut global = GlobalState::new(12);
            solve_from_with(
                &mut cluster,
                &mut global,
                &cfg,
                Some(&ds),
                &SolveOptions::default(),
                scratch,
            )
            .unwrap()
        };
        let mut fresh = SolveScratch::default();
        let first = run(&mut fresh);
        // a warm scratch reuses the consensus/polish buffers
        let second = run(&mut fresh);
        assert!(
            second.transfers.net_alloc_saved_bytes
                >= first.transfers.net_alloc_saved_bytes + (12 * 8) as u64,
            "warm scratch reuse not credited: {} vs {}",
            second.transfers.net_alloc_saved_bytes,
            first.transfers.net_alloc_saved_bytes
        );
        // and the math is untouched by the pooling
        assert_eq!(first.z, second.z);
        assert_eq!(first.x, second.x);
        assert_eq!(first.support, second.support);
    }

    /// A fit killed mid-run and resumed from its PSF1 checkpoint must
    /// finish with a remaining trace bit-identical to an uninterrupted
    /// run — the same invariant the path subsystem pins for sweeps.
    #[test]
    fn checkpointed_fit_resumes_bit_identically() {
        let spec = SyntheticSpec::regression(16, 100, 2);
        let ds = spec.generate();
        let mut cfg = Config::default();
        cfg.platform.nodes = 2;
        cfg.solver.kappa = 4;
        cfg.solver.max_iters = 12;
        cfg.solver.tol_primal = 0.0; // fixed rounds: the full trace runs

        // reference: one uninterrupted solve
        let mut cluster = build_cluster(&ds, &cfg, 2);
        let reference =
            solve(&mut cluster, 16, &cfg, Some(&ds), &SolveOptions::default()).unwrap();
        assert_eq!(reference.trace.iters(), 12);

        // interrupted: checkpoint every 2 iterations, "kill" after 7 by
        // capping the budget, then resume with the full budget
        let path = std::env::temp_dir().join("psfit_solver_resume.psf");
        let _ = std::fs::remove_file(&path);
        let mut ck_cfg = cfg.clone();
        ck_cfg.solver.checkpoint = path.to_string_lossy().into_owned();
        ck_cfg.solver.checkpoint_every = 2;
        let mut half = ck_cfg.clone();
        half.solver.max_iters = 7;
        let mut cluster = build_cluster(&ds, &half, 2);
        let partial =
            solve_checkpointed(&mut cluster, 16, &half, &ds, &SolveOptions::default()).unwrap();
        assert!(!partial.converged);
        assert!(path.exists(), "no checkpoint was written");

        let mut cluster = build_cluster(&ds, &ck_cfg, 2);
        let resumed =
            solve_checkpointed(&mut cluster, 16, &ck_cfg, &ds, &SolveOptions::default()).unwrap();
        assert_eq!(resumed.iters, 12);
        assert_eq!(resumed.trace.iters(), reference.trace.iters());
        for (a, b) in resumed.trace.records.iter().zip(&reference.trace.records) {
            assert_eq!(a.iter, b.iter);
            assert_eq!(a.primal.to_bits(), b.primal.to_bits(), "iter {}", a.iter);
            assert_eq!(a.dual.to_bits(), b.dual.to_bits(), "iter {}", a.iter);
            assert_eq!(a.bilinear.to_bits(), b.bilinear.to_bits(), "iter {}", a.iter);
        }
        assert_eq!(resumed.z, reference.z);
        assert_eq!(resumed.x, reference.x);
        assert_eq!(resumed.support, reference.support);

        // a snapshot of a *different* problem is rejected, not re-seeded
        let other = SyntheticSpec::regression(16, 100, 3).generate();
        let mut cluster = build_cluster(&other, &ck_cfg, 2);
        let err = solve_checkpointed(&mut cluster, 16, &ck_cfg, &other, &SolveOptions::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("different fit"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn polish_ridge_fits_exactly_on_noiseless_support() {
        let mut spec = SyntheticSpec::regression(20, 200, 2);
        spec.noise_std = 0.0;
        spec.sparsity_level = 0.85;
        let ds = spec.generate();
        let mut x = vec![0.0f64; 20];
        polish_ridge(&ds, &ds.support_true, 1e9, &mut x);
        for &i in &ds.support_true {
            assert!(
                (x[i] - ds.x_true[i]).abs() < 1e-3,
                "{} vs {}",
                x[i],
                ds.x_true[i]
            );
        }
    }
}

//! Compute backends for the node-level data path (Algorithm 2).
//!
//! The paper runs the feature-decomposed inner ADMM on GPUs (PyTorch/CUDA)
//! with a CPU fallback.  Here:
//!
//!   * [`native::NativeBackend`] — dependency-free Rust (the "CPU backend")
//!   * [`xla::XlaBackend`]       — AOT-compiled JAX/Pallas artifacts
//!     executed through PJRT (the "GPU backend"; DESIGN.md §3)
//!
//! Both implement [`NodeBackend`], whose operations are *per feature block
//! and per class column* — the driver in `admm::local` owns the sweep
//! logic, so the two backends share iteration structure exactly (a
//! prerequisite for the backend-parity tests).

pub mod native;
/// XLA-artifact backend executed through PJRT.
pub mod xla;

use crate::metrics::TransferLedger;

/// Scalar parameters of the block subproblem (Eq. 23).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BlockParams {
    /// Inner sharing-ADMM penalty rho_l.
    pub rho_l: f64,
    /// Consensus penalty rho_c.
    pub rho_c: f64,
    /// Curvature of r_j: 1/(N gamma) + rho_c.
    pub reg: f64,
}

/// One node's compute engine: holds the feature-decomposed local dataset
/// (the paper's per-GPU partitions) and executes the two data-touching
/// primitives of the inner sweep.
pub trait NodeBackend: Send {
    /// Number of feature blocks M (device queues engaged).
    fn blocks(&self) -> usize;
    /// Samples m_i in this node's shard.
    fn samples(&self) -> usize;
    /// Width of the coefficient block `j` (unpadded).
    fn block_width(&self, j: usize) -> usize;

    /// Block x-update (Eq. 23) followed by the prediction refresh
    /// `pred_j = A_j x_j`, for one class column.
    ///
    /// * `corr`  — sample-space correction `omega_bar - w_bar - nu` (m)
    /// * `z_j`, `u_j` — consensus slice and scaled dual for this block
    /// * `x_j`   — in: warm start; out: updated coefficients
    /// * `pred_j`— out: A_j x_j
    fn block_step(
        &mut self,
        j: usize,
        params: BlockParams,
        corr: &[f32],
        z_j: &[f32],
        u_j: &[f32],
        x_j: &mut [f32],
        pred_j: &mut [f32],
    );

    /// Step 3 of the inner sweep for ALL feature blocks and class columns
    /// in one call: per (block j, class c) run the x-update (Eq. 23) and
    /// the prediction refresh `pred_j = A_j x_j`.
    ///
    /// Layouts (all class-major):
    /// * `corr` — `(width, m)`: the frozen correction `omega - w_bar - nu`
    /// * `z_blocks[j]` / `u_blocks[j]` — `(width, bw_j)` consensus slices
    /// * `x_blocks[j]` — `(width, bw_j)` warm-start in / solution out
    /// * `preds[j]` — `(width, m)` prediction out
    ///
    /// Blocks are Jacobi-independent within a sweep (Deng et al.,
    /// arXiv:1312.3040): every input is a snapshot taken before the sweep,
    /// so block updates commute.  Overrides may therefore batch class
    /// columns (multi-RHS) or run blocks concurrently, but MUST keep each
    /// block's result independent of execution order.  The default loops
    /// serially over blocks then classes via [`NodeBackend::block_step`] —
    /// exactly the historical iteration order.
    fn block_sweep(
        &mut self,
        params: BlockParams,
        width: usize,
        corr: &[f32],
        z_blocks: &[Vec<f32>],
        u_blocks: &[Vec<f32>],
        x_blocks: &mut [Vec<f32>],
        preds: &mut [Vec<f32>],
    ) {
        let m = self.samples();
        debug_assert_eq!(corr.len(), width * m);
        for j in 0..self.blocks() {
            let bw = self.block_width(j);
            for c in 0..width {
                let x_j = &mut x_blocks[j][c * bw..(c + 1) * bw];
                let pred_j = &mut preds[j][c * m..(c + 1) * m];
                self.block_step(
                    j,
                    params,
                    &corr[c * m..(c + 1) * m],
                    &z_blocks[j][c * bw..(c + 1) * bw],
                    &u_blocks[j][c * bw..(c + 1) * bw],
                    x_j,
                    pred_j,
                );
            }
        }
    }

    /// Mini-batch variant of [`NodeBackend::block_sweep`]: the sweep runs
    /// over the row window `span = [r0, r1)` only.  `corr` and `preds[j]`
    /// are **chunk-local** — class-major `(width, r1 - r0)` — while
    /// `z`/`u`/`x` keep their full per-block shapes (coefficients are not
    /// row-indexed).
    ///
    /// The default only supports the trivial full window (mini-batch
    /// rounds are gated to backends that override this — today the native
    /// backend); `config::validate` rejects `solver.minibatch` on other
    /// backends before a solve ever gets here.
    #[allow(clippy::too_many_arguments)]
    fn block_sweep_span(
        &mut self,
        span: (usize, usize),
        params: BlockParams,
        width: usize,
        corr: &[f32],
        z_blocks: &[Vec<f32>],
        u_blocks: &[Vec<f32>],
        x_blocks: &mut [Vec<f32>],
        preds: &mut [Vec<f32>],
    ) {
        assert_eq!(
            span,
            (0, self.samples()),
            "this backend does not support partial row spans (mini-batch rounds need the native backend)"
        );
        self.block_sweep(params, width, corr, z_blocks, u_blocks, x_blocks, preds);
    }

    /// Separable omega-bar prox (Eq. 21) against this node's labels.
    /// `c` and `out` are row-major (m, width).
    fn omega_update(&mut self, c: &[f32], m_blocks: f64, rho_l: f64, out: &mut [f32]);

    /// Mini-batch variant of [`NodeBackend::omega_update`] over the row
    /// window `span = [r0, r1)`: `c` and `out` are chunk-local, row-major
    /// `(r1 - r0, width)`.  Default as in
    /// [`NodeBackend::block_sweep_span`]: full window only.
    fn omega_update_span(
        &mut self,
        span: (usize, usize),
        c: &[f32],
        m_blocks: f64,
        rho_l: f64,
        out: &mut [f32],
    ) {
        assert_eq!(
            span,
            (0, self.samples()),
            "this backend does not support partial row spans (mini-batch rounds need the native backend)"
        );
        self.omega_update(c, m_blocks, rho_l, out);
    }

    /// Loss value at the given predictions (row-major (m, width)) —
    /// objective reporting only, not on the iteration hot path.
    fn loss_value(&self, pred: &[f32]) -> f64;

    /// Staging-copy ledger plus the factorization-reuse counters (the
    /// native backend records no staging bytes, only the counters).
    fn ledger(&self) -> TransferLedger;
    /// Zero the ledger (between timed phases of a harness).
    fn reset_ledger(&mut self);

    /// Fused Algorithm-2 path: run `sweeps` inner iterations over ALL
    /// blocks in a single backend call (the launch-granularity
    /// optimization; see `python/compile/model.py::node_sweep`).
    ///
    /// `z_blocks`/`u_blocks` are per-block consensus slices (unpadded);
    /// `x_blocks` (per block coefficients), `preds` (per block A_j x_j),
    /// `omega`, `nu` are the inner state, updated in place on success.
    /// Returns false when the backend (or this problem shape) does not
    /// support the fused path — the caller then uses the granular ops.
    #[allow(clippy::too_many_arguments)]
    fn node_sweep(
        &mut self,
        _params: BlockParams,
        _sweeps: usize,
        _z_blocks: &[Vec<f32>],
        _u_blocks: &[Vec<f32>],
        _x_blocks: &mut [Vec<f32>],
        _preds: &mut [Vec<f32>],
        _omega: &mut [f32],
        _nu: &mut [f32],
    ) -> bool {
        false
    }
}

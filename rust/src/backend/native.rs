//! Native Rust backend — the paper's "CPU backend".
//!
//! Per feature block it caches the Gram matrix `G_j = A_j^T A_j` (f64) at
//! construction, computed **in place** from the shard — through a
//! stride-aware [`crate::linalg::ColumnBlockView`] on dense storage, or a
//! per-block [`crate::linalg::CsrBlockView`] on CSR storage (the density-adaptive
//! sparse data path; see `data::ShardData`).  No packed per-block copy
//! either way (the bytes the old eager `column_block` packing would have
//! cost are reported via `TransferLedger::host_copy_saved_bytes`).  Each
//! block step is then one `A_j^T corr` kernel call over the shared shard
//! plus a coefficient-space solve; the data-touching kernels dispatch on
//! the storage kind per block, so sparse shards do O(nnz) work where the
//! dense path does O(m n).  Every kernel call additionally routes through
//! the runtime ISA dispatch table (`linalg::simd`): on an AVX2 or NEON
//! host the block sweep runs the explicit-SIMD variants over the shard's
//! 64-byte-aligned padded-stride storage, with the tiled-scalar kernels
//! as the guaranteed fallback (`platform.isa` / `PSFIT_ISA` pin a
//! variant).  Two solver modes:
//!
//!   * `Cg { iters }` — identical iteration structure to the XLA artifact
//!     (used by the parity tests and the honest CPU-vs-GPU comparison);
//!   * `Direct`       — Cholesky of `rho_l G + reg I`, re-factored only
//!     when the penalties change (ablation: direct vs iterative).
//!
//! The batched [`NodeBackend::block_sweep`] override is the hot path:
//!
//!   * independent feature blocks run concurrently on a
//!     [`WorkerPool`] — the CPU analogue of the paper's per-GPU block
//!     queues (`--threads` / `platform.threads`).  Each worker owns its
//!     block's coefficients, predictions, and scratch; nothing else is
//!     written, and the `w_bar` reduction happens in `admm::local` in
//!     fixed block order, so solver output is bit-identical at any thread
//!     count.
//!   * multiclass solves batch all `width` class columns per block: one
//!     `A_j^T C` multi-vector kernel call, one multi-RHS
//!     Cholesky/CG solve, one `A_j X` prediction refresh — instead of
//!     re-running the granular step per class column.

use super::{BlockParams, NodeBackend};
use crate::data::{FeaturePlan, Shard, ShardData};
use crate::linalg::csr;
use crate::linalg::kernels;
use crate::linalg::{conjugate_gradient, Cholesky, ColumnBlockView, CsrBlockView, CsrParts};
use crate::losses::Loss;
use crate::metrics::TransferLedger;
use crate::util::pool::WorkerPool;

/// How the per-block coefficient solve is performed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SolveMode {
    /// Fixed-iteration CG on the cached Gram operator (artifact-parallel).
    Cg { iters: usize },
    /// Cached Cholesky factorization of the block normal matrix.
    Direct,
}

/// Per-block f64 scratch, owned by the block so pooled workers never
/// share buffers (and reused across sweeps — no per-call allocation).
#[derive(Default)]
struct Scratch {
    /// A_j^T C for all class columns (class-major `(width, n_j)`), f32.
    qt: Vec<f32>,
    /// Right-hand sides, class-major `(width, n_j)`.
    rhs: Vec<f64>,
    /// Solutions (warm-started), class-major `(width, n_j)`.
    x: Vec<f64>,
}

/// Most distinct penalty sets a block keeps factors for — generous for
/// any realistic rho ladder while bounding memory on a runaway sweep.
const CHOL_CACHE_CAP: usize = 16;

/// Gram + factorization state for one feature block over one row span —
/// the full shard owns one (`Block::full`); each mini-batch chunk that
/// actually runs gets its own lazily (`Block::spans`), since a chunk's
/// normal matrix `A_j[r0..r1]^T A_j[r0..r1]` differs from the full one.
struct SolveState {
    /// Cached Gram (width x width), f64.
    gram: Vec<f64>,
    /// Cholesky factors of `rho_l G + reg I`, keyed by the penalties they
    /// were built for (Direct mode only).  The path subsystem's rho
    /// ladder revisits penalty sets; a keyed cache turns each revisit
    /// into a lookup instead of an O(w^3) refactorization.
    chol_cache: Vec<(BlockParams, Cholesky)>,
    /// Penalties of the most recent Direct-mode step.  Steady-state calls
    /// (unchanged penalties) touch neither counter below, so the counters
    /// measure *transitions*: factors built vs. revisits served from the
    /// cache.
    chol_last: Option<BlockParams>,
    /// Cache index of the factor for `chol_last` — `solve_block` reads it
    /// directly so the per-step access stays O(1) (no cache scan on the
    /// hot path; only penalty *transitions* search the cache).
    chol_active: usize,
    /// Distinct factorizations computed.
    chol_factored: u64,
    /// Penalty revisits that found their factor in the cache.
    chol_reused: u64,
}

impl SolveState {
    fn new(gram: Vec<f64>) -> SolveState {
        SolveState {
            gram,
            chol_cache: Vec::new(),
            chol_last: None,
            chol_active: 0,
            chol_factored: 0,
            chol_reused: 0,
        }
    }
}

struct Block {
    /// Column range `[start, start + width)` of the shard — the feature
    /// block `A_j`, read in place through `ColumnBlockView` (dense) or
    /// `CsrBlockView` (CSR).
    start: usize,
    width: usize,
    /// Per-row entry subranges of the block within the parent CSR
    /// (`Some` iff the shard layout is CSR — resident or mapped; computed
    /// once here so every sweep reuses them).  Ranges hold absolute entry
    /// offsets, so a row span just slices `ranges[r0..r1]`.
    csr_ranges: Option<Vec<(usize, usize)>>,
    /// Full-batch solve state (Gram over every shard row).
    full: SolveState,
    /// Per-chunk solve states for mini-batch rounds, keyed by row span
    /// and built on first use.  Chunk counts are small (`m / minibatch`),
    /// so a linear scan is fine.
    spans: Vec<((usize, usize), SolveState)>,
    scratch: Scratch,
}

/// Borrowed, storage-kind-erased handle on the shard's raw arrays.
/// Resident and mapped storage collapse to the same two layouts here, so
/// every kernel dispatch below this point is shared — the bit-parity seam
/// `tests/oocore.rs` pins.
#[derive(Clone, Copy)]
enum StorageRef<'a> {
    Dense { data: &'a [f32], stride: usize },
    Csr(CsrParts<'a>),
}

fn storage_ref(a: &ShardData) -> StorageRef<'_> {
    match a {
        ShardData::Dense(m) => StorageRef::Dense {
            data: m.padded_data(),
            stride: m.stride(),
        },
        ShardData::Csr(c) => StorageRef::Csr(c.parts()),
        ShardData::Mapped(m) => {
            if m.is_csr() {
                StorageRef::Csr(m.csr_parts())
            } else {
                StorageRef::Dense {
                    data: m.dense_padded(),
                    stride: m.stride(),
                }
            }
        }
    }
}

/// Gram matrix of the feature block over rows `[r0, r1)`, in the exact
/// kernel/summation order the resident full-batch path uses.
fn build_gram(
    a: &ShardData,
    csr_ranges: &Option<Vec<(usize, usize)>>,
    start: usize,
    width: usize,
    span: (usize, usize),
) -> Vec<f64> {
    let (r0, r1) = span;
    let mut gram32 = vec![0.0f32; width * width];
    match storage_ref(a) {
        StorageRef::Dense { data, stride } => {
            let view = ColumnBlockView::new(&data[r0 * stride..], r1 - r0, width, stride, start);
            kernels::gram(&view, &mut gram32);
        }
        StorageRef::Csr(parts) => {
            let ranges = csr_ranges.as_ref().expect("csr shard without block ranges");
            let view = CsrBlockView::new(parts, r0, r1 - r0, start, width, &ranges[r0..r1]);
            csr::gram_sparse(&view, &mut gram32);
        }
    }
    gram32.iter().map(|&v| v as f64).collect()
}

/// Dependency-free Rust backend (the paper's "CPU backend").
pub struct NativeBackend {
    /// The node's full design matrix, shared with the dataset shard (Arc
    /// inside either storage variant — construction copies no feature
    /// data).  Kernels dispatch on the variant per block.
    a: ShardData,
    blocks: Vec<Block>,
    labels: Vec<f32>,
    loss: Box<dyn Loss>,
    mode: SolveMode,
    m: usize,
    pool: WorkerPool,
    /// Bytes the eager per-block packing used to copy at construction.
    inplace_saved_bytes: u64,
}

impl NativeBackend {
    /// Build the backend over one shard: per-block Gram matrices are
    /// computed here (in place, through views), everything else lazily.
    pub fn new(shard: &Shard, plan: &FeaturePlan, loss: Box<dyn Loss>, mode: SolveMode) -> Self {
        assert_eq!(shard.width, loss.width(), "label width mismatch");
        let a = shard.data.clone();
        let rows = a.rows();
        let mut saved = 0u64;
        let blocks = plan
            .ranges
            .iter()
            .map(|&(start, width)| {
                let csr_ranges = match &a {
                    ShardData::Dense(_) => None,
                    ShardData::Csr(c) => Some(c.block_ranges(start, width)),
                    ShardData::Mapped(m) => {
                        if m.is_csr() {
                            Some(m.block_ranges(start, width))
                        } else {
                            None
                        }
                    }
                };
                let gram = build_gram(&a, &csr_ranges, start, width, (0, rows));
                saved += (rows * width * std::mem::size_of::<f32>()) as u64;
                Block {
                    start,
                    width,
                    csr_ranges,
                    full: SolveState::new(gram),
                    spans: Vec::new(),
                    scratch: Scratch::default(),
                }
            })
            .collect();
        NativeBackend {
            m: rows,
            a,
            blocks,
            labels: shard.labels.clone(),
            loss,
            mode,
            pool: WorkerPool::new(1),
            inplace_saved_bytes: saved,
        }
    }

    /// Storage kind actually backing the data path ("dense" | "csr").
    pub fn storage(&self) -> &'static str {
        self.a.storage_name()
    }

    /// Set the worker-pool width for the block sweep: `1` = serial
    /// (default), `0` = all available cores.  Results are bit-identical
    /// at any width (see `util::pool`).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.pool = WorkerPool::new(threads);
        self
    }

    /// Worker threads the block sweep uses.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }
}

/// Make sure the state's keyed cache holds a factor for `params`.
/// Steady-state calls (same penalties as the previous step) return
/// immediately; a penalty *transition* either reuses a cached factor
/// (rho-ladder revisit) or computes and caches a new one.
fn ensure_chol(state: &mut SolveState, n: usize, params: BlockParams) {
    if state.chol_last == Some(params) {
        return; // steady state: chol_active already points at the factor
    }
    if let Some(idx) = state.chol_cache.iter().position(|(p, _)| *p == params) {
        state.chol_reused += 1;
        state.chol_active = idx;
    } else {
        let mut h = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                h[i * n + j] = params.rho_l * state.gram[i * n + j];
            }
            h[i * n + i] += params.reg;
        }
        let chol = Cholesky::factor(&h, n).expect("block normal matrix is SPD");
        if state.chol_cache.len() >= CHOL_CACHE_CAP {
            state.chol_cache.remove(0); // evict the oldest penalty set
        }
        state.chol_cache.push((params, chol));
        state.chol_active = state.chol_cache.len() - 1;
        state.chol_factored += 1;
    }
    state.chol_last = Some(params);
}

/// The block x-update (Eq. 23) + prediction refresh for all `width` class
/// columns of one feature block, batched: one `A_j^T C` kernel call, one
/// multi-RHS solve, one `A_j X` kernel call.  Shared verbatim by the
/// granular `block_step` (`width == 1`) and the pooled `block_sweep`, so
/// the two paths are bit-identical.
///
/// `span` selects the row window `[r0, r1)` the step runs over:
/// `None` (or the full window) is the full-batch path and uses the
/// block's cached state untouched, so full-batch behaviour is
/// bit-identical to the pre-span code by construction.  A partial span is
/// a mini-batch chunk: `corr` / `pred_j` are **chunk-local** (length
/// `width * (r1 - r0)`), and the chunk's Gram + factor cache is built
/// lazily and kept per span.
fn solve_block(
    a: &ShardData,
    mode: SolveMode,
    block: &mut Block,
    params: BlockParams,
    width: usize,
    span: Option<(usize, usize)>,
    corr: &[f32],
    z_j: &[f32],
    u_j: &[f32],
    x_j: &mut [f32],
    pred_j: &mut [f32],
) {
    let n = block.width;
    let m_total = a.rows();
    let (r0, r1) = span.unwrap_or((0, m_total));
    debug_assert!(r0 < r1 && r1 <= m_total, "bad row span [{r0}, {r1})");
    let cm = r1 - r0;
    debug_assert_eq!(corr.len(), width * cm);
    debug_assert_eq!(x_j.len(), width * n);
    debug_assert_eq!(pred_j.len(), width * cm);

    let Block {
        start,
        width: _,
        csr_ranges,
        full,
        spans,
        scratch: s,
    } = block;
    let start = *start;

    // Pick the solve state for this row window.  The full window shares
    // the constructor-built state; each chunk gets its own on first use.
    let state: &mut SolveState = if (r0, r1) == (0, m_total) {
        full
    } else {
        match spans.iter().position(|(sp, _)| *sp == (r0, r1)) {
            Some(i) => &mut spans[i].1,
            None => {
                let gram = build_gram(a, csr_ranges, start, n, (r0, r1));
                spans.push(((r0, r1), SolveState::new(gram)));
                &mut spans.last_mut().unwrap().1
            }
        }
    };

    if matches!(mode, SolveMode::Direct) {
        ensure_chol(state, n, params);
    }
    let gram = &state.gram;
    let chol = state.chol_cache.get(state.chol_active).map(|(_, c)| c);
    debug_assert!(
        matches!(mode, SolveMode::Cg { .. })
            || state
                .chol_cache
                .get(state.chol_active)
                .is_some_and(|(p, _)| *p == params),
        "active cholesky factor does not match the step's penalties"
    );
    s.qt.resize(width * n, 0.0);
    s.rhs.resize(width * n, 0.0);
    s.x.resize(width * n, 0.0);

    // Q = A_j^T C for all class columns at once (the data-touching op,
    // dispatched on the storage layout — resident and mapped collapse to
    // the same two branches here)
    match storage_ref(a) {
        StorageRef::Dense { data, stride } => {
            let view = ColumnBlockView::new(&data[r0 * stride..], cm, n, stride, start);
            kernels::matmul_t(&view, corr, width, &mut s.qt);
        }
        StorageRef::Csr(parts) => {
            let ranges = csr_ranges.as_ref().expect("csr shard without block ranges");
            let view = CsrBlockView::new(parts, r0, cm, start, n, &ranges[r0..r1]);
            csr::spmm_t(&view, corr, width, &mut s.qt);
        }
    }

    // rhs_c = rho_l (G x_c + q_c) + rho_c (z_c - u_c); warm-start x_c
    for c in 0..width {
        let x_c = &x_j[c * n..(c + 1) * n];
        for i in 0..n {
            let row = &gram[i * n..(i + 1) * n];
            let mut gx = 0.0f64;
            for (g, &xv) in row.iter().zip(x_c) {
                gx += g * xv as f64;
            }
            s.rhs[c * n + i] = params.rho_l * (gx + s.qt[c * n + i] as f64)
                + params.rho_c * (z_j[c * n + i] as f64 - u_j[c * n + i] as f64);
            s.x[c * n + i] = x_c[i] as f64; // warm start
        }
    }

    match mode {
        SolveMode::Cg { iters } => {
            // H v = rho_l G v + reg v — same operator as the artifact's CG
            let rho_l = params.rho_l;
            let reg = params.reg;
            for c in 0..width {
                let rhs_c = &s.rhs[c * n..(c + 1) * n];
                let x_c = &mut s.x[c * n..(c + 1) * n];
                conjugate_gradient(
                    |v, out| {
                        for i in 0..n {
                            let row = &gram[i * n..(i + 1) * n];
                            let mut acc = 0.0;
                            for (g, &vv) in row.iter().zip(v) {
                                acc += g * vv;
                            }
                            out[i] = rho_l * acc + reg * v[i];
                        }
                    },
                    rhs_c,
                    x_c,
                    iters,
                    0.0, // fixed-iteration, matching the artifact
                );
            }
        }
        SolveMode::Direct => {
            s.x.copy_from_slice(&s.rhs);
            chol.expect("ensure_chol populated the cache")
                .solve_multi(&mut s.x, width);
        }
    }

    for (o, &v) in x_j.iter_mut().zip(s.x.iter()) {
        *o = v as f32;
    }
    // pred_j = A_j X for all class columns (chunk rows only)
    match storage_ref(a) {
        StorageRef::Dense { data, stride } => {
            let view = ColumnBlockView::new(&data[r0 * stride..], cm, n, stride, start);
            kernels::matmul(&view, x_j, width, pred_j);
        }
        StorageRef::Csr(parts) => {
            let ranges = csr_ranges.as_ref().expect("csr shard without block ranges");
            let view = CsrBlockView::new(parts, r0, cm, start, n, &ranges[r0..r1]);
            csr::spmm(&view, x_j, width, pred_j);
        }
    }
}

impl NodeBackend for NativeBackend {
    fn blocks(&self) -> usize {
        self.blocks.len()
    }

    fn samples(&self) -> usize {
        self.m
    }

    fn block_width(&self, j: usize) -> usize {
        self.blocks[j].width
    }

    fn block_step(
        &mut self,
        j: usize,
        params: BlockParams,
        corr: &[f32],
        z_j: &[f32],
        u_j: &[f32],
        x_j: &mut [f32],
        pred_j: &mut [f32],
    ) {
        solve_block(
            &self.a,
            self.mode,
            &mut self.blocks[j],
            params,
            1,
            None,
            corr,
            z_j,
            u_j,
            x_j,
            pred_j,
        );
    }

    /// Pooled Jacobi sweep: every feature block (with all its class
    /// columns batched) is one job on the worker pool.  Disjoint writes
    /// per job; the caller reduces `w_bar` in fixed order afterwards.
    fn block_sweep(
        &mut self,
        params: BlockParams,
        width: usize,
        corr: &[f32],
        z_blocks: &[Vec<f32>],
        u_blocks: &[Vec<f32>],
        x_blocks: &mut [Vec<f32>],
        preds: &mut [Vec<f32>],
    ) {
        debug_assert_eq!(corr.len(), width * self.m);
        let a = &self.a;
        let mode = self.mode;
        let jobs: Vec<_> = self
            .blocks
            .iter_mut()
            .zip(x_blocks.iter_mut())
            .zip(preds.iter_mut())
            .zip(z_blocks.iter().zip(u_blocks))
            .map(|(((block, x_j), pred_j), (z_j, u_j))| {
                move || {
                    solve_block(a, mode, block, params, width, None, corr, z_j, u_j, x_j, pred_j);
                }
            })
            .collect();
        self.pool.run(jobs);
    }

    /// Mini-batch sweep over row window `[r0, r1)`: same pooled structure
    /// as `block_sweep`, but `corr` and `preds` are chunk-local and each
    /// block solves against its lazily cached chunk Gram.  The full
    /// window routes to the exact full-batch state, so
    /// `block_sweep_span((0, m), ..)` is bit-identical to `block_sweep`.
    fn block_sweep_span(
        &mut self,
        span: (usize, usize),
        params: BlockParams,
        width: usize,
        corr: &[f32],
        z_blocks: &[Vec<f32>],
        u_blocks: &[Vec<f32>],
        x_blocks: &mut [Vec<f32>],
        preds: &mut [Vec<f32>],
    ) {
        let (r0, r1) = span;
        debug_assert!(r0 < r1 && r1 <= self.m, "bad row span [{r0}, {r1})");
        debug_assert_eq!(corr.len(), width * (r1 - r0));
        let a = &self.a;
        let mode = self.mode;
        let jobs: Vec<_> = self
            .blocks
            .iter_mut()
            .zip(x_blocks.iter_mut())
            .zip(preds.iter_mut())
            .zip(z_blocks.iter().zip(u_blocks))
            .map(|(((block, x_j), pred_j), (z_j, u_j))| {
                move || {
                    solve_block(
                        a,
                        mode,
                        block,
                        params,
                        width,
                        Some(span),
                        corr,
                        z_j,
                        u_j,
                        x_j,
                        pred_j,
                    );
                }
            })
            .collect();
        self.pool.run(jobs);
    }

    fn omega_update(&mut self, c: &[f32], m_blocks: f64, rho_l: f64, out: &mut [f32]) {
        self.loss.omega_update(&self.labels, c, m_blocks, rho_l, out);
    }

    /// Chunk-local omega update: the loss is per-row separable, so the
    /// window's rows see exactly the arithmetic the full update applies
    /// to them — only the label slice narrows.
    fn omega_update_span(
        &mut self,
        span: (usize, usize),
        c: &[f32],
        m_blocks: f64,
        rho_l: f64,
        out: &mut [f32],
    ) {
        let (r0, r1) = span;
        let w = self.loss.width();
        debug_assert!(r0 < r1 && r1 <= self.m, "bad row span [{r0}, {r1})");
        self.loss
            .omega_update(&self.labels[r0 * w..r1 * w], c, m_blocks, rho_l, out);
    }

    fn loss_value(&self, pred: &[f32]) -> f64 {
        self.loss.value(pred, &self.labels)
    }

    fn ledger(&self) -> TransferLedger {
        // no staging copies on the native path — only the packing note
        // plus the factorization-reuse counters the path subsystem reads
        let mut l = TransferLedger {
            host_copy_saved_bytes: self.inplace_saved_bytes,
            ..Default::default()
        };
        for b in &self.blocks {
            // one full-batch Gram at construction + one per chunk span
            l.gram_builds += 1 + b.spans.len() as u64;
            l.chol_factorizations += b.full.chol_factored;
            l.chol_reuses += b.full.chol_reused;
            for (_, st) in &b.spans {
                l.chol_factorizations += st.chol_factored;
                l.chol_reuses += st.chol_reused;
            }
        }
        l
    }

    fn reset_ledger(&mut self) {}
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::data::{FeaturePlan, SparseMode, SyntheticSpec};
    use crate::linalg::Matrix;
    use crate::losses::Squared;
    use crate::util::rng::Rng;

    fn setup(mode: SolveMode) -> (NativeBackend, FeaturePlan, usize, Arc<Matrix>) {
        let ds = SyntheticSpec::regression(24, 60, 1).generate();
        let plan = FeaturePlan::new(24, 2, 512);
        let a = ds.shards[0].data.as_dense().unwrap().clone();
        let be = NativeBackend::new(&ds.shards[0], &plan, Box::new(Squared), mode);
        (be, plan, 60, a)
    }

    fn params() -> BlockParams {
        BlockParams {
            rho_l: 2.0,
            rho_c: 1.0,
            reg: 1.5,
        }
    }

    #[test]
    fn block_step_solves_normal_equations_direct() {
        let (mut be, plan, m, a) = setup(SolveMode::Direct);
        let mut rng = Rng::seed_from(1);
        let params = params();
        let (start, n0) = plan.ranges[0];
        let corr: Vec<f32> = (0..m).map(|_| rng.normal_f32()).collect();
        let z: Vec<f32> = (0..n0).map(|_| rng.normal_f32()).collect();
        let u: Vec<f32> = (0..n0).map(|_| rng.normal_f32()).collect();
        let x_prev: Vec<f32> = (0..n0).map(|_| rng.normal_f32()).collect();
        let mut x = x_prev.clone();
        let mut pred = vec![0.0f32; m];
        be.block_step(0, params, &corr, &z, &u, &mut x, &mut pred);

        // residual of (rho_l G + reg I) x = rho_l (G x_prev + q) + rho_c (z-u)
        let block_a = a.column_block(start, n0);
        let gram = &be.blocks[0].full.gram;
        let mut q = vec![0.0f32; n0];
        block_a.matvec_t(&corr, &mut q);
        for i in 0..n0 {
            let hx: f64 = (0..n0)
                .map(|k| params.rho_l * gram[i * n0 + k] * x[k] as f64)
                .sum::<f64>()
                + params.reg * x[i] as f64;
            let gxp: f64 = (0..n0).map(|k| gram[i * n0 + k] * x_prev[k] as f64).sum();
            let rhs = params.rho_l * (gxp + q[i] as f64)
                + params.rho_c * (z[i] as f64 - u[i] as f64);
            assert!((hx - rhs).abs() < 1e-3, "i={i}: {hx} vs {rhs}");
        }
        // pred = A x — same kernel, same order: exact
        let mut want = vec![0.0f32; m];
        block_a.matvec(&x, &mut want);
        assert_eq!(pred, want);
    }

    #[test]
    fn cg_mode_approaches_direct() {
        let params = params();
        let mut rng = Rng::seed_from(2);
        let (mut be_cg, plan, m, _) = setup(SolveMode::Cg { iters: 60 });
        let (mut be_dir, _, _, _) = setup(SolveMode::Direct);
        let n0 = plan.ranges[0].1;
        let corr: Vec<f32> = (0..m).map(|_| rng.normal_f32()).collect();
        let z = vec![0.1f32; n0];
        let u = vec![0.0f32; n0];
        let mut x_cg = vec![0.0f32; n0];
        let mut x_dir = vec![0.0f32; n0];
        let mut pred = vec![0.0f32; m];
        be_cg.block_step(0, params, &corr, &z, &u, &mut x_cg, &mut pred);
        be_dir.block_step(0, params, &corr, &z, &u, &mut x_dir, &mut pred);
        for (a, b) in x_cg.iter().zip(&x_dir) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn chol_cache_keys_by_params_and_reuses_on_revisit() {
        let (mut be, plan, m, _) = setup(SolveMode::Direct);
        let n0 = plan.ranges[0].1;
        let corr = vec![0.0f32; m];
        let z = vec![0.0f32; n0];
        let u = vec![0.0f32; n0];
        let mut x = vec![0.0f32; n0];
        let mut pred = vec![0.0f32; m];
        let p1 = BlockParams { rho_l: 1.0, rho_c: 1.0, reg: 1.0 };
        let p2 = BlockParams { rho_l: 9.0, rho_c: 1.0, reg: 4.0 };
        be.block_step(0, p1, &corr, &z, &u, &mut x, &mut pred);
        assert_eq!(be.blocks[0].full.chol_cache.len(), 1);
        assert_eq!(be.blocks[0].full.chol_factored, 1);
        // steady state: repeating the same penalties touches no counter
        be.block_step(0, p1, &corr, &z, &u, &mut x, &mut pred);
        assert_eq!(be.blocks[0].full.chol_factored, 1);
        assert_eq!(be.blocks[0].full.chol_reused, 0);
        // new penalties: a second factor joins the cache
        be.block_step(0, p2, &corr, &z, &u, &mut x, &mut pred);
        assert_eq!(be.blocks[0].full.chol_cache.len(), 2);
        assert_eq!(be.blocks[0].full.chol_factored, 2);
        // revisiting p1 (the rho-ladder pattern) reuses the cached factor
        be.block_step(0, p1, &corr, &z, &u, &mut x, &mut pred);
        assert_eq!(be.blocks[0].full.chol_cache.len(), 2);
        assert_eq!(be.blocks[0].full.chol_factored, 2);
        assert_eq!(be.blocks[0].full.chol_reused, 1);
        let ledger = be.ledger();
        // 2 blocks in the plan: block 0 factored twice, block 1 never hit
        assert_eq!(ledger.chol_factorizations, 2);
        assert_eq!(ledger.chol_reuses, 1);
        assert_eq!(ledger.gram_builds, 2);
    }

    /// A revisited penalty set must solve with the *same* factor bits as
    /// the first visit — a cache hit returns identical solutions.
    #[test]
    fn chol_cache_revisit_solves_identically() {
        let mut rng = Rng::seed_from(11);
        let (mut be_a, plan, m, _) = setup(SolveMode::Direct);
        let (mut be_b, _, _, _) = setup(SolveMode::Direct);
        let n0 = plan.ranges[0].1;
        let corr: Vec<f32> = (0..m).map(|_| rng.normal_f32()).collect();
        let z: Vec<f32> = (0..n0).map(|_| rng.normal_f32()).collect();
        let u = vec![0.0f32; n0];
        let p1 = BlockParams { rho_l: 2.0, rho_c: 1.0, reg: 1.5 };
        let p2 = BlockParams { rho_l: 5.0, rho_c: 1.0, reg: 2.5 };
        let mut pred = vec![0.0f32; m];

        // reference: p1 solved on a backend that only ever sees p1
        let mut x_ref = vec![0.0f32; n0];
        be_a.block_step(0, p1, &corr, &z, &u, &mut x_ref, &mut pred);

        // cache path: p1, then p2, then p1 again (served from the cache)
        let mut x0 = vec![0.0f32; n0];
        be_b.block_step(0, p1, &corr, &z, &u, &mut x0, &mut pred);
        let mut x_scratch = vec![0.0f32; n0];
        be_b.block_step(0, p2, &corr, &z, &u, &mut x_scratch, &mut pred);
        let mut x_revisit = vec![0.0f32; n0];
        be_b.block_step(0, p1, &corr, &z, &u, &mut x_revisit, &mut pred);

        assert_eq!(be_b.blocks[0].full.chol_reused, 1, "revisit must hit the cache");
        assert_eq!(x_ref, x_revisit);
    }

    /// Random per-(block, class) inputs for sweep tests.
    fn sweep_inputs(
        rng: &mut Rng,
        plan: &FeaturePlan,
        m: usize,
        width: usize,
    ) -> (Vec<f32>, Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let corr: Vec<f32> = (0..width * m).map(|_| rng.normal_f32()).collect();
        let mk = |rng: &mut Rng, len: usize| -> Vec<f32> {
            (0..len).map(|_| rng.normal_f32()).collect()
        };
        let z: Vec<Vec<f32>> = plan.ranges.iter().map(|&(_, w)| mk(rng, width * w)).collect();
        let u: Vec<Vec<f32>> = plan.ranges.iter().map(|&(_, w)| mk(rng, width * w)).collect();
        let x: Vec<Vec<f32>> = plan.ranges.iter().map(|&(_, w)| mk(rng, width * w)).collect();
        let p: Vec<Vec<f32>> = plan.ranges.iter().map(|_| vec![0.0; width * m]).collect();
        (corr, z, u, x, p)
    }

    #[test]
    fn pooled_sweep_is_bit_identical_to_serial() {
        for mode in [SolveMode::Direct, SolveMode::Cg { iters: 12 }] {
            let mut rng = Rng::seed_from(3);
            let ds = SyntheticSpec::regression(24, 60, 1).generate();
            let plan = FeaturePlan::new(24, 4, 512);
            let (corr, z, u, x0, p0) = sweep_inputs(&mut rng, &plan, 60, 1);

            let mut results = Vec::new();
            for threads in [1usize, 4] {
                let mut be = NativeBackend::new(&ds.shards[0], &plan, Box::new(Squared), mode)
                    .with_threads(threads);
                let mut x = x0.clone();
                let mut p = p0.clone();
                be.block_sweep(params(), 1, &corr, &z, &u, &mut x, &mut p);
                results.push((x, p));
            }
            assert_eq!(results[0], results[1], "mode {mode:?}");
        }
    }

    #[test]
    fn batched_sweep_matches_granular_block_steps() {
        // width = 3 multiclass batch vs three explicit width-1 solves
        let width = 3;
        let ds = SyntheticSpec::regression(18, 40, 1).generate();
        let plan = FeaturePlan::new(18, 3, 512);
        let m = 40;
        let mut rng = Rng::seed_from(4);
        let (corr, z, u, x0, p0) = sweep_inputs(&mut rng, &plan, m, width);

        let mk = || NativeBackend::new(&ds.shards[0], &plan, Box::new(Squared), SolveMode::Direct);
        let mut be_batch = mk();
        let mut x_b = x0.clone();
        let mut p_b = p0.clone();
        be_batch.block_sweep(params(), width, &corr, &z, &u, &mut x_b, &mut p_b);

        let mut be_gran = mk();
        let mut x_g = x0;
        let mut p_g = p0;
        for (j, &(_, bw)) in plan.ranges.iter().enumerate() {
            for c in 0..width {
                let x_j = &mut x_g[j][c * bw..(c + 1) * bw];
                let pred_j = &mut p_g[j][c * m..(c + 1) * m];
                be_gran.block_step(
                    j,
                    params(),
                    &corr[c * m..(c + 1) * m],
                    &z[j][c * bw..(c + 1) * bw],
                    &u[j][c * bw..(c + 1) * bw],
                    x_j,
                    pred_j,
                );
            }
        }
        assert_eq!(x_b, x_g);
        assert_eq!(p_b, p_g);
    }

    #[test]
    fn ledger_reports_inplace_savings() {
        let (be, _, m, a) = setup(SolveMode::Direct);
        let l = be.ledger();
        assert_eq!(l.host_copy_saved_bytes, (m * a.cols * 4) as u64);
        assert_eq!(l.h2d_bytes, 0);
        assert_eq!(l.gram_builds, 2, "one Gram per feature block");
        assert_eq!(l.chol_factorizations, 0, "no Direct step has run yet");
    }

    /// The CSR data path must agree with the dense path on the same data
    /// to kernel tolerance, for both solver modes and any thread count.
    #[test]
    fn csr_sweep_matches_dense_sweep() {
        for mode in [SolveMode::Direct, SolveMode::Cg { iters: 24 }] {
            let mut spec = SyntheticSpec::regression(24, 60, 1);
            spec.density = 0.15;
            let ds = spec.generate();
            let plan = FeaturePlan::new(24, 4, 512);
            let mut rng = Rng::seed_from(9);
            let (corr, z, u, x0, p0) = sweep_inputs(&mut rng, &plan, 60, 1);

            let dense_shard = ds.shards[0].with_storage_policy(SparseMode::Never, 0.0);
            let csr_shard = ds.shards[0].with_storage_policy(SparseMode::Always, 0.0);
            assert_eq!(csr_shard.data.storage_name(), "csr");

            let mut results = Vec::new();
            for (shard, threads) in [(&dense_shard, 1), (&csr_shard, 1), (&csr_shard, 4)] {
                let mut be = NativeBackend::new(shard, &plan, Box::new(Squared), mode)
                    .with_threads(threads);
                let mut x = x0.clone();
                let mut p = p0.clone();
                be.block_sweep(params(), 1, &corr, &z, &u, &mut x, &mut p);
                results.push((x, p));
            }
            // dense vs csr: kernel tolerance (summation orders differ)
            for (xb, pb) in [(&results[0].0, &results[1].0), (&results[0].1, &results[1].1)] {
                for (va, vb) in xb.iter().zip(pb) {
                    for (x, y) in va.iter().zip(vb) {
                        let scale = 1.0f32.max(x.abs()).max(y.abs());
                        assert!((x - y).abs() <= 1e-4 * scale, "{mode:?}: {x} vs {y}");
                    }
                }
            }
            // csr serial vs csr pooled: bit-identical
            assert_eq!(results[1], results[2], "mode {mode:?}");
        }
    }

    /// `block_sweep_span` over the full row window must be bit-identical
    /// to `block_sweep` — same code path, same cached full-batch state.
    #[test]
    fn full_span_sweep_matches_block_sweep_bit_for_bit() {
        for mode in [SolveMode::Direct, SolveMode::Cg { iters: 12 }] {
            let mut rng = Rng::seed_from(21);
            let ds = SyntheticSpec::regression(24, 60, 1).generate();
            let plan = FeaturePlan::new(24, 4, 512);
            let (corr, z, u, x0, p0) = sweep_inputs(&mut rng, &plan, 60, 1);

            let mut be_a = NativeBackend::new(&ds.shards[0], &plan, Box::new(Squared), mode);
            let mut x_a = x0.clone();
            let mut p_a = p0.clone();
            be_a.block_sweep(params(), 1, &corr, &z, &u, &mut x_a, &mut p_a);

            let mut be_b = NativeBackend::new(&ds.shards[0], &plan, Box::new(Squared), mode);
            let mut x_b = x0.clone();
            let mut p_b = p0.clone();
            be_b.block_sweep_span((0, 60), params(), 1, &corr, &z, &u, &mut x_b, &mut p_b);

            assert_eq!(x_a, x_b, "mode {mode:?}");
            assert_eq!(p_a, p_b, "mode {mode:?}");
            // the full window reuses the constructor Gram — no span state
            assert_eq!(be_b.ledger().gram_builds, plan.ranges.len() as u64);
        }
    }

    /// A partial span on the full backend must match a backend built on a
    /// shard containing exactly those rows — the chunk really is "the
    /// solver run on the chunk", bit for bit.
    #[test]
    fn partial_span_sweep_matches_backend_on_row_slice() {
        let (r0, r1) = (16usize, 48usize);
        let cm = r1 - r0;
        for csr in [false, true] {
            let mut spec = SyntheticSpec::regression(24, 60, 1);
            if csr {
                spec.density = 0.2;
            }
            let ds = spec.generate();
            let shard = ds.shards[0].with_storage_policy(
                if csr { SparseMode::Always } else { SparseMode::Never },
                0.0,
            );
            let plan = FeaturePlan::new(24, 4, 512);
            let mut rng = Rng::seed_from(22);
            let corr: Vec<f32> = (0..cm).map(|_| rng.normal_f32()).collect();
            let mk = |rng: &mut Rng, len: usize| -> Vec<f32> {
                (0..len).map(|_| rng.normal_f32()).collect()
            };
            let z: Vec<Vec<f32>> = plan.ranges.iter().map(|&(_, w)| mk(&mut rng, w)).collect();
            let u: Vec<Vec<f32>> = plan.ranges.iter().map(|&(_, w)| mk(&mut rng, w)).collect();
            let x0: Vec<Vec<f32>> = plan.ranges.iter().map(|&(_, w)| mk(&mut rng, w)).collect();
            let p0: Vec<Vec<f32>> = plan.ranges.iter().map(|_| vec![0.0; cm]).collect();

            // sub-shard holding exactly rows [r0, r1)
            let sub_labels = shard.labels[r0..r1].to_vec();
            let sub_shard = if csr {
                let c = match &shard.data {
                    ShardData::Csr(c) => c,
                    _ => unreachable!(),
                };
                let rows: Vec<Vec<(u32, f32)>> = (r0..r1)
                    .map(|r| {
                        let (cols, vals) = c.row(r);
                        cols.iter().copied().zip(vals.iter().copied()).collect()
                    })
                    .collect();
                crate::data::Shard {
                    data: ShardData::Csr(Arc::new(crate::linalg::CsrMatrix::from_rows(24, rows))),
                    labels: sub_labels,
                    width: 1,
                }
            } else {
                let full = shard.data.as_dense().unwrap();
                let mut a = Matrix::zeros(cm, 24);
                for r in 0..cm {
                    a.row_mut(r).copy_from_slice(full.row(r0 + r));
                }
                crate::data::Shard::dense(a, sub_labels, 1)
            };

            let mut be_sub =
                NativeBackend::new(&sub_shard, &plan, Box::new(Squared), SolveMode::Direct);
            let mut x_s = x0.clone();
            let mut p_s = p0.clone();
            be_sub.block_sweep(params(), 1, &corr, &z, &u, &mut x_s, &mut p_s);

            let mut be_full =
                NativeBackend::new(&shard, &plan, Box::new(Squared), SolveMode::Direct);
            let mut x_f = x0.clone();
            let mut p_f = p0.clone();
            be_full.block_sweep_span((r0, r1), params(), 1, &corr, &z, &u, &mut x_f, &mut p_f);

            assert_eq!(x_s, x_f, "csr={csr}");
            assert_eq!(p_s, p_f, "csr={csr}");
            // one span Gram per block joined the ledger
            assert_eq!(be_full.ledger().gram_builds, 2 * plan.ranges.len() as u64);
            // revisiting the same span reuses its cached state (no new Gram)
            let mut x_f2 = x0.clone();
            let mut p_f2 = p0.clone();
            be_full.block_sweep_span((r0, r1), params(), 1, &corr, &z, &u, &mut x_f2, &mut p_f2);
            assert_eq!(be_full.ledger().gram_builds, 2 * plan.ranges.len() as u64);
        }
    }

    /// Chunk-local omega update equals the matching slice of the full one
    /// (the loss is per-row separable).
    #[test]
    fn omega_update_span_matches_full_slice() {
        let ds = SyntheticSpec::regression(24, 60, 1).generate();
        let plan = FeaturePlan::new(24, 2, 512);
        let mut be = NativeBackend::new(&ds.shards[0], &plan, Box::new(Squared), SolveMode::Direct);
        let mut rng = Rng::seed_from(23);
        let c: Vec<f32> = (0..60).map(|_| rng.normal_f32()).collect();
        let mut full = vec![0.0f32; 60];
        be.omega_update(&c, 2.0, 1.5, &mut full);
        let (r0, r1) = (10usize, 40usize);
        let mut chunk = vec![0.0f32; r1 - r0];
        be.omega_update_span((r0, r1), &c[r0..r1], 2.0, 1.5, &mut chunk);
        assert_eq!(chunk, full[r0..r1]);
    }

    /// A backend over a mapped PSD1 shard must produce bit-identical
    /// sweeps to the resident shard it was written from — dense and CSR.
    #[test]
    fn mapped_shard_backend_matches_resident_bit_for_bit() {
        use crate::data::shardfile::{open_shard, write_shard};
        for csr in [false, true] {
            let mut spec = SyntheticSpec::regression(24, 60, 1);
            if csr {
                spec.density = 0.2;
            }
            let ds = spec.generate();
            let shard = ds.shards[0].with_storage_policy(
                if csr { SparseMode::Always } else { SparseMode::Never },
                0.0,
            );
            let mut path = std::env::temp_dir();
            path.push(format!(
                "psfit-native-mapped-{}-{}.psd1",
                std::process::id(),
                csr
            ));
            write_shard(&shard, &path).unwrap();
            let mapped = open_shard(&path).unwrap();
            assert!(mapped.data.is_mapped());
            assert_eq!(mapped.data.is_csr(), csr);
            assert_eq!(mapped.labels, shard.labels);

            let plan = FeaturePlan::new(24, 4, 512);
            let mut rng = Rng::seed_from(24);
            let (corr, z, u, x0, p0) = sweep_inputs(&mut rng, &plan, 60, 1);
            let mut out = Vec::new();
            for s in [&shard, &mapped] {
                let mut be = NativeBackend::new(s, &plan, Box::new(Squared), SolveMode::Direct);
                let mut x = x0.clone();
                let mut p = p0.clone();
                be.block_sweep(params(), 1, &corr, &z, &u, &mut x, &mut p);
                // and a partial span, through the lazily built chunk Gram
                let corr_c = &corr[8..40];
                let mut pc: Vec<Vec<f32>> = plan.ranges.iter().map(|_| vec![0.0; 32]).collect();
                let mut xc = x0.clone();
                be.block_sweep_span((8, 40), params(), 1, corr_c, &z, &u, &mut xc, &mut pc);
                out.push((x, p, xc, pc));
            }
            assert_eq!(out[0], out[1], "csr={csr}");
            let _ = std::fs::remove_file(&path);
        }
    }

    #[test]
    fn csr_multiclass_batches_match_dense() {
        let width = 3;
        let mut spec = SyntheticSpec::regression(18, 40, 1);
        spec.density = 0.2;
        let ds = spec.generate();
        let plan = FeaturePlan::new(18, 3, 512);
        let mut rng = Rng::seed_from(10);
        let (corr, z, u, x0, p0) = sweep_inputs(&mut rng, &plan, 40, width);

        let mut out = Vec::new();
        for mode in [SparseMode::Never, SparseMode::Always] {
            let shard = ds.shards[0].with_storage_policy(mode, 0.0);
            let mut be =
                NativeBackend::new(&shard, &plan, Box::new(Squared), SolveMode::Direct);
            let mut x = x0.clone();
            let mut p = p0.clone();
            be.block_sweep(params(), width, &corr, &z, &u, &mut x, &mut p);
            out.push(x);
        }
        for (va, vb) in out[0].iter().zip(&out[1]) {
            for (x, y) in va.iter().zip(vb) {
                let scale = 1.0f32.max(x.abs()).max(y.abs());
                assert!((x - y).abs() <= 1e-4 * scale, "{x} vs {y}");
            }
        }
    }
}

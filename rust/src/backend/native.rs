//! Native Rust backend — the paper's "CPU backend".
//!
//! Per feature block it caches the Gram matrix `G_j = A_j^T A_j` (f64) at
//! construction; each `block_step` is then one `A_j^T corr` matvec over the
//! raw data plus a coefficient-space solve.  Two solver modes:
//!
//!   * `Cg { iters }` — identical iteration structure to the XLA artifact
//!     (used by the parity tests and the honest CPU-vs-GPU comparison);
//!   * `Direct`       — Cholesky of `rho_l G + reg I`, re-factored only
//!     when the penalties change (ablation: direct vs iterative).

use super::{BlockParams, NodeBackend};
use crate::data::{FeaturePlan, Shard};
use crate::linalg::{conjugate_gradient, Cholesky, Matrix};
use crate::losses::Loss;
use crate::metrics::TransferLedger;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SolveMode {
    /// Fixed-iteration CG on the cached Gram operator (artifact-parallel).
    Cg { iters: usize },
    /// Cached Cholesky factorization of the block normal matrix.
    Direct,
}

struct Block {
    /// Packed column block of the shard (m x width_j).
    a: Matrix,
    /// Cached Gram (width_j x width_j), f64.
    gram: Vec<f64>,
    /// Cached Cholesky of rho_l G + reg I (Direct mode only).
    chol: Option<Cholesky>,
    /// Penalties the factorization was built for.
    chol_params: Option<BlockParams>,
}

pub struct NativeBackend {
    blocks: Vec<Block>,
    labels: Vec<f32>,
    loss: Box<dyn Loss>,
    mode: SolveMode,
    m: usize,
    scratch: Scratch,
}

#[derive(Default)]
struct Scratch {
    q: Vec<f64>,
    rhs: Vec<f64>,
    x: Vec<f64>,
    hv: Vec<f64>,
    qf32: Vec<f32>,
}

impl NativeBackend {
    pub fn new(shard: &Shard, plan: &FeaturePlan, loss: Box<dyn Loss>, mode: SolveMode) -> Self {
        assert_eq!(shard.width, loss.width(), "label width mismatch");
        let blocks = plan
            .ranges
            .iter()
            .map(|&(start, width)| {
                let a = shard.a.column_block(start, width);
                let mut gram32 = vec![0.0f32; width * width];
                a.gram_accumulate(&mut gram32);
                Block {
                    a,
                    gram: gram32.iter().map(|&v| v as f64).collect(),
                    chol: None,
                    chol_params: None,
                }
            })
            .collect();
        NativeBackend {
            blocks,
            labels: shard.labels.clone(),
            loss,
            mode,
            m: shard.a.rows,
            scratch: Scratch::default(),
        }
    }

    fn ensure_chol(block: &mut Block, params: BlockParams) {
        if block.chol_params == Some(params) && block.chol.is_some() {
            return;
        }
        let n = block.a.cols;
        let mut h = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                h[i * n + j] = params.rho_l * block.gram[i * n + j];
            }
            h[i * n + i] += params.reg;
        }
        block.chol = Some(Cholesky::factor(&h, n).expect("block normal matrix is SPD"));
        block.chol_params = Some(params);
    }
}

impl NodeBackend for NativeBackend {
    fn blocks(&self) -> usize {
        self.blocks.len()
    }

    fn samples(&self) -> usize {
        self.m
    }

    fn block_width(&self, j: usize) -> usize {
        self.blocks[j].a.cols
    }

    fn block_step(
        &mut self,
        j: usize,
        params: BlockParams,
        corr: &[f32],
        z_j: &[f32],
        u_j: &[f32],
        x_j: &mut [f32],
        pred_j: &mut [f32],
    ) {
        let block = &mut self.blocks[j];
        let n = block.a.cols;
        debug_assert_eq!(corr.len(), self.m);
        debug_assert_eq!(x_j.len(), n);
        debug_assert_eq!(pred_j.len(), self.m);

        let s = &mut self.scratch;
        s.qf32.resize(n, 0.0);
        s.q.resize(n, 0.0);
        s.rhs.resize(n, 0.0);
        s.x.resize(n, 0.0);
        s.hv.resize(n, 0.0);

        // q = A_j^T corr  (the data-touching op)
        block.a.matvec_t(corr, &mut s.qf32);
        for (qi, &v) in s.q.iter_mut().zip(&s.qf32) {
            *qi = v as f64;
        }

        // rhs = rho_l (G x_prev + q) + rho_c (z - u)
        let gram = &block.gram;
        for i in 0..n {
            let mut gx = 0.0f64;
            let row = &gram[i * n..(i + 1) * n];
            for (g, &xv) in row.iter().zip(x_j.iter()) {
                gx += g * xv as f64;
            }
            s.rhs[i] = params.rho_l * (gx + s.q[i])
                + params.rho_c * (z_j[i] as f64 - u_j[i] as f64);
            s.x[i] = x_j[i] as f64; // warm start
        }

        match self.mode {
            SolveMode::Cg { iters } => {
                // H v = rho_l G v + reg v — same operator as the artifact's CG
                let rho_l = params.rho_l;
                let reg = params.reg;
                let rhs = std::mem::take(&mut s.rhs);
                let mut x = std::mem::take(&mut s.x);
                conjugate_gradient(
                    |v, out| {
                        for i in 0..n {
                            let row = &gram[i * n..(i + 1) * n];
                            let mut acc = 0.0;
                            for (g, &vv) in row.iter().zip(v) {
                                acc += g * vv;
                            }
                            out[i] = rho_l * acc + reg * v[i];
                        }
                    },
                    &rhs,
                    &mut x,
                    iters,
                    0.0, // fixed-iteration, matching the artifact
                );
                s.rhs = rhs;
                s.x = x;
            }
            SolveMode::Direct => {
                Self::ensure_chol(block, params);
                s.x.copy_from_slice(&s.rhs);
                block.chol.as_ref().unwrap().solve(&mut s.x);
            }
        }

        for (o, &v) in x_j.iter_mut().zip(s.x.iter()) {
            *o = v as f32;
        }
        // pred_j = A_j x_j
        block.a.matvec(x_j, pred_j);
    }

    fn omega_update(&mut self, c: &[f32], m_blocks: f64, rho_l: f64, out: &mut [f32]) {
        self.loss.omega_update(&self.labels, c, m_blocks, rho_l, out);
    }

    fn loss_value(&self, pred: &[f32]) -> f64 {
        self.loss.value(pred, &self.labels)
    }

    fn ledger(&self) -> TransferLedger {
        TransferLedger::default() // no staging copies on the native path
    }

    fn reset_ledger(&mut self) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SyntheticSpec, FeaturePlan};
    use crate::losses::Squared;
    use crate::util::rng::Rng;

    fn setup(mode: SolveMode) -> (NativeBackend, FeaturePlan, usize) {
        let ds = SyntheticSpec::regression(24, 60, 1).generate();
        let plan = FeaturePlan::new(24, 2, 512);
        let be = NativeBackend::new(&ds.shards[0], &plan, Box::new(Squared), mode);
        (be, plan, 60)
    }

    #[test]
    fn block_step_solves_normal_equations_direct() {
        let (mut be, plan, m) = setup(SolveMode::Direct);
        let mut rng = Rng::seed_from(1);
        let params = BlockParams {
            rho_l: 2.0,
            rho_c: 1.0,
            reg: 1.5,
        };
        let n0 = plan.ranges[0].1;
        let corr: Vec<f32> = (0..m).map(|_| rng.normal_f32()).collect();
        let z: Vec<f32> = (0..n0).map(|_| rng.normal_f32()).collect();
        let u: Vec<f32> = (0..n0).map(|_| rng.normal_f32()).collect();
        let x_prev: Vec<f32> = (0..n0).map(|_| rng.normal_f32()).collect();
        let mut x = x_prev.clone();
        let mut pred = vec![0.0f32; m];
        be.block_step(0, params, &corr, &z, &u, &mut x, &mut pred);

        // residual of (rho_l G + reg I) x = rho_l (G x_prev + q) + rho_c (z-u)
        let block_a = &be.blocks[0].a;
        let gram = &be.blocks[0].gram;
        let mut q = vec![0.0f32; n0];
        block_a.matvec_t(&corr, &mut q);
        for i in 0..n0 {
            let hx: f64 = (0..n0)
                .map(|k| params.rho_l * gram[i * n0 + k] * x[k] as f64)
                .sum::<f64>()
                + params.reg * x[i] as f64;
            let gxp: f64 = (0..n0).map(|k| gram[i * n0 + k] * x_prev[k] as f64).sum();
            let rhs = params.rho_l * (gxp + q[i] as f64)
                + params.rho_c * (z[i] as f64 - u[i] as f64);
            assert!((hx - rhs).abs() < 1e-3, "i={i}: {hx} vs {rhs}");
        }
        // pred = A x
        let mut want = vec![0.0f32; m];
        block_a.matvec(&x, &mut want);
        assert_eq!(pred, want);
    }

    #[test]
    fn cg_mode_approaches_direct() {
        let params = BlockParams {
            rho_l: 2.0,
            rho_c: 1.0,
            reg: 1.5,
        };
        let mut rng = Rng::seed_from(2);
        let (mut be_cg, plan, m) = setup(SolveMode::Cg { iters: 60 });
        let (mut be_dir, _, _) = setup(SolveMode::Direct);
        let n0 = plan.ranges[0].1;
        let corr: Vec<f32> = (0..m).map(|_| rng.normal_f32()).collect();
        let z = vec![0.1f32; n0];
        let u = vec![0.0f32; n0];
        let mut x_cg = vec![0.0f32; n0];
        let mut x_dir = vec![0.0f32; n0];
        let mut pred = vec![0.0f32; m];
        be_cg.block_step(0, params, &corr, &z, &u, &mut x_cg, &mut pred);
        be_dir.block_step(0, params, &corr, &z, &u, &mut x_dir, &mut pred);
        for (a, b) in x_cg.iter().zip(&x_dir) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn chol_refactors_on_param_change() {
        let (mut be, plan, m) = setup(SolveMode::Direct);
        let n0 = plan.ranges[0].1;
        let corr = vec![0.0f32; m];
        let z = vec![0.0f32; n0];
        let u = vec![0.0f32; n0];
        let mut x = vec![0.0f32; n0];
        let mut pred = vec![0.0f32; m];
        let p1 = BlockParams { rho_l: 1.0, rho_c: 1.0, reg: 1.0 };
        let p2 = BlockParams { rho_l: 9.0, rho_c: 1.0, reg: 4.0 };
        be.block_step(0, p1, &corr, &z, &u, &mut x, &mut pred);
        assert_eq!(be.blocks[0].chol_params, Some(p1));
        be.block_step(0, p2, &corr, &z, &u, &mut x, &mut pred);
        assert_eq!(be.blocks[0].chol_params, Some(p2));
    }
}

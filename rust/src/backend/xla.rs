//! XLA backend — the paper's "GPU backend" (DESIGN.md §Hardware-Adaptation).
//!
//! Per node, construction mirrors the paper's device placement: each
//! feature block `A_ij` is packed into fixed-shape row tiles and staged
//! once as persistent device buffers ("data partitions reside on the j-th
//! GPU"), and the block Gram matrix is accumulated on device via the
//! `gram_tile` artifact.  Per inner iteration only small vectors cross the
//! host/device boundary; every crossing is recorded in the transfer
//! ledger (Figure 4).
//!
//! The artifacts executed here are the AOT-lowered JAX/Pallas tile
//! programs (`python/compile/model.py`); `block_solve` runs the same
//! fixed-iteration CG the native backend mirrors in `SolveMode::Cg`.

use super::{BlockParams, NodeBackend};
use crate::data::{FeaturePlan, Shard};
use crate::losses::Loss;
use crate::metrics::TransferLedger;
use crate::runtime::{DeviceTensor, Manifest, ParamsBuffer, XlaRuntime};

struct XBlock {
    /// Row tiles of A_ij, each (tile_m, block_n), zero-padded.
    a_tiles: Vec<DeviceTensor>,
    /// Gram matrix (block_n, block_n), zero-padded outside width x width.
    gram: DeviceTensor,
    /// Actual (unpadded) feature count of this block.
    width: usize,
}

/// Fused node_sweep state.  The A tiles and Gram matrices are the
/// per-block persistent buffers already staged at setup — the artifact
/// takes blocks as separate parameters precisely so they can be reused.
struct FusedSweep {
    exe: std::rc::Rc<xla::PjRtLoadedExecutable>,
    /// (tile_m, 1) labels.
    b: DeviceTensor,
    /// Sweeps baked into the artifact.
    sweeps: usize,
}

/// AOT-artifact backend executed through PJRT (the paper's "GPU
/// backend"): per-block tiles and Grams stay device-resident, every
/// staging copy is ledgered.
pub struct XlaBackend {
    rt: std::rc::Rc<XlaRuntime>,
    blocks: Vec<XBlock>,
    fused: Option<FusedSweep>,
    labels_host: Vec<f32>,
    /// Per row tile: labels staged as (tile_m, label_width).
    label_tiles: Vec<DeviceTensor>,
    loss: Box<dyn Loss>,
    m: usize,
    tile_m: usize,
    block_n: usize,
    tiles: usize,
    params: ParamsBuffer,
    ledger: TransferLedger,
    // artifact names; compiled lazily via the runtime cache on first use
    // (compiling the full set eagerly costs ~15 s per node, and the fused
    // path never touches the granular executables)
    omega_artifact: &'static str,
    // scratch
    tile_buf: Vec<f32>,
    vec_buf: Vec<f32>,
}

// SAFETY: every `Rc`-refcounted xla wrapper object reachable from an
// `XlaBackend` (client, executables, device buffers) is created privately
// by `XlaBackend::new` and never aliased outside the struct, PROVIDED the
// runtime handed in is not shared (driver::build_workers creates one
// private runtime per node unless `platform.share_runtime` is set, in
// which case the driver forces the sequential in-thread cluster so the
// shared graph never crosses threads).  Under that invariant the whole
// object graph moves to the node worker's thread as one unit and is only
// ever touched from that thread.
unsafe impl Send for XlaBackend {}

impl XlaBackend {
    /// Stage one shard's tiles + Grams on the runtime's device and bind
    /// the artifact set the plan requires.
    pub fn new(
        rt: std::rc::Rc<XlaRuntime>,
        shard: &Shard,
        plan: &FeaturePlan,
        loss: Box<dyn Loss>,
    ) -> anyhow::Result<XlaBackend> {
        let man = rt.manifest().clone();
        let (tile_m, block_n) = (man.tile_m, man.block_n);
        anyhow::ensure!(
            plan.padded_width == block_n,
            "feature plan padded_width {} != artifact block_n {}",
            plan.padded_width,
            block_n
        );
        if loss.width() > 1 {
            anyhow::ensure!(
                loss.width() == man.classes,
                "softmax width {} != artifact classes {}",
                loss.width(),
                man.classes
            );
        }
        // The staging path packs dense row tiles into PJRT literals; CSR
        // shards are densified once here (device-side sparse formats are
        // the seam `ShardData` leaves open, not yet an artifact).
        let a = shard.data.to_dense();
        let m = a.rows;
        let tiles = m.div_ceil(tile_m);
        let mut ledger = TransferLedger::default();

        let exe_gram = rt.executable("gram_tile")?;
        let omega_artifact = Manifest::omega_artifact(loss.kind());

        // ---- stage feature tiles + accumulate Gram per block -------------
        let mut blocks = Vec::with_capacity(plan.blocks);
        let mut tile_buf = vec![0.0f32; tile_m * block_n];
        for &(start, width) in &plan.ranges {
            let mut a_tiles = Vec::with_capacity(tiles);
            let mut gram_host = vec![0.0f32; block_n * block_n];
            for t in 0..tiles {
                let row0 = t * tile_m;
                let count = (m - row0).min(tile_m);
                // pack rows [row0, row0+count) of columns [start, start+width)
                tile_buf.fill(0.0);
                for r in 0..count {
                    let src = &a.row(row0 + r)[start..start + width];
                    tile_buf[r * block_n..r * block_n + width].copy_from_slice(src);
                }
                let (tensor, secs) = rt.stage(&tile_buf, &[tile_m, block_n])?;
                ledger.record_h2d(tile_buf.len() * 4, secs);

                // Gram partial on device
                let out = rt.run(&exe_gram, &[&tensor.buffer])?;
                let (parts, secs) = rt.fetch_tuple(&out[0])?;
                ledger.record_d2h(parts[0].len() * 4, secs);
                for (g, &p) in gram_host.iter_mut().zip(&parts[0]) {
                    *g += p;
                }
                a_tiles.push(tensor);
            }
            let (gram, secs) = rt.stage(&gram_host, &[block_n, block_n])?;
            ledger.record_h2d(gram_host.len() * 4, secs);
            blocks.push(XBlock {
                a_tiles,
                gram,
                width,
            });
        }

        // ---- stage label tiles for the omega artifact ---------------------
        let lw = loss.width();
        let mut label_tiles = Vec::with_capacity(tiles);
        let mut lbuf = vec![0.0f32; tile_m * lw];
        for t in 0..tiles {
            let row0 = t * tile_m;
            let count = (m - row0).min(tile_m);
            lbuf.fill(0.0);
            lbuf[..count * lw].copy_from_slice(&shard.labels[row0 * lw..(row0 + count) * lw]);
            let (tensor, secs) = rt.stage(&lbuf, &[tile_m, lw])?;
            ledger.record_h2d(lbuf.len() * 4, secs);
            label_tiles.push(tensor);
        }

        // ---- fused node_sweep path (launch-granularity optimization) -----
        // Eligible when the whole shard fits one row tile, the loss is
        // single-class, and a matching artifact was lowered.
        let sweep_name = format!(
            "node_sweep_{}_m{}",
            match loss.kind() {
                crate::losses::LossKind::Squared => "squared",
                crate::losses::LossKind::Logistic => "logistic",
                crate::losses::LossKind::Hinge => "hinge",
                crate::losses::LossKind::Softmax => "softmax",
            },
            plan.blocks
        );
        let fused = if tiles == 1 && lw == 1 && man.artifacts.contains_key(&sweep_name) {
            let exe = rt.executable(&sweep_name)?;
            let (b, secs) = {
                let mut lb = vec![0.0f32; tile_m];
                lb[..m].copy_from_slice(&shard.labels);
                rt.stage(&lb, &[tile_m, 1])?
            };
            ledger.record_h2d(tile_m * 4, secs);
            Some(FusedSweep {
                exe,
                b,
                sweeps: man.inner_sweeps,
            })
        } else {
            None
        };

        let param_size = man.param_size;
        Ok(XlaBackend {
            rt,
            blocks,
            fused,
            labels_host: shard.labels.clone(),
            label_tiles,
            loss,
            m,
            tile_m,
            block_n,
            tiles,
            params: ParamsBuffer::new(param_size),
            ledger,
            omega_artifact,
            tile_buf: vec![0.0f32; tile_m * man.classes.max(1)],
            vec_buf: vec![0.0f32; block_n],
        })
    }

    /// Stage an m-vector as zero-padded (tile_m, 1) tiles.
    fn stage_sample_tiles(&mut self, v: &[f32]) -> anyhow::Result<Vec<DeviceTensor>> {
        let mut out = Vec::with_capacity(self.tiles);
        for t in 0..self.tiles {
            let row0 = t * self.tile_m;
            let count = (self.m - row0).min(self.tile_m);
            self.tile_buf[..self.tile_m].fill(0.0);
            self.tile_buf[..count].copy_from_slice(&v[row0..row0 + count]);
            let (tensor, secs) = self
                .rt
                .stage(&self.tile_buf[..self.tile_m], &[self.tile_m, 1])?;
            self.ledger.record_h2d(self.tile_m * 4, secs);
            out.push(tensor);
        }
        Ok(out)
    }

    /// Stage a coefficient vector zero-padded to (block_n, 1).
    fn stage_coeff(&mut self, v: &[f32]) -> anyhow::Result<DeviceTensor> {
        self.vec_buf.fill(0.0);
        self.vec_buf[..v.len()].copy_from_slice(v);
        let (tensor, secs) = self.rt.stage(&self.vec_buf, &[self.block_n, 1])?;
        self.ledger.record_h2d(self.block_n * 4, secs);
        Ok(tensor)
    }

    fn try_block_step(
        &mut self,
        j: usize,
        params: BlockParams,
        corr: &[f32],
        z_j: &[f32],
        u_j: &[f32],
        x_j: &mut [f32],
        pred_j: &mut [f32],
    ) -> anyhow::Result<()> {
        let bw = self.blocks[j].width;
        debug_assert_eq!(x_j.len(), bw);
        debug_assert_eq!(corr.len(), self.m);
        let m_blocks = self.blocks.len() as f64;

        let x_prev = self.stage_coeff(x_j)?;
        let z_buf = self.stage_coeff(z_j)?;
        let u_buf = self.stage_coeff(u_j)?;
        {
            let (_, pbytes, psecs) = self.params.get(&self.rt, m_blocks, params)?;
            if pbytes > 0 {
                self.ledger.record_h2d(pbytes, psecs);
            }
        }

        if self.tiles == 1 {
            // fused path: q = A^T corr; CG; pred = A x in one artifact call
            let exe = self.rt.executable("block_iteration")?;
            let corr_tiles = self.stage_sample_tiles(corr)?;
            let out = {
                let params_buf = &self.params.get(&self.rt, m_blocks, params)?.0.buffer;
                let block = &self.blocks[j];
                self.rt.run(
                    &exe,
                    &[
                        &block.gram.buffer,
                        &block.a_tiles[0].buffer,
                        &x_prev.buffer,
                        &corr_tiles[0].buffer,
                        &z_buf.buffer,
                        &u_buf.buffer,
                        params_buf,
                    ],
                )?
            };
            let (parts, secs) = self.rt.fetch_tuple(&out[0])?;
            self.ledger
                .record_d2h((parts[0].len() + parts[1].len()) * 4, secs);
            x_j.copy_from_slice(&parts[0][..bw]);
            pred_j.copy_from_slice(&parts[1][..self.m]);
            return Ok(());
        }

        // ---- multi-tile path ------------------------------------------
        // q = sum_t A_t^T corr_t
        let exe_matvec = self.rt.executable("matvec_tile")?;
        let exe_matvec_t = self.rt.executable("matvec_t_tile")?;
        let exe_block_solve = self.rt.executable("block_solve")?;
        let corr_tiles = self.stage_sample_tiles(corr)?;
        let mut q_host = vec![0.0f32; self.block_n];
        for (t, ct) in corr_tiles.iter().enumerate() {
            let out = self.rt.run(
                &exe_matvec_t,
                &[&self.blocks[j].a_tiles[t].buffer, &ct.buffer],
            )?;
            let (parts, secs) = self.rt.fetch_tuple(&out[0])?;
            self.ledger.record_d2h(parts[0].len() * 4, secs);
            for (qi, &p) in q_host.iter_mut().zip(&parts[0]) {
                *qi += p;
            }
        }
        let (q_buf, secs) = self.rt.stage(&q_host, &[self.block_n, 1])?;
        self.ledger.record_h2d(q_host.len() * 4, secs);

        // coefficient-space CG
        let out = {
            let params_buf = &self.params.get(&self.rt, m_blocks, params)?.0.buffer;
            self.rt.run(
                &exe_block_solve,
                &[
                    &self.blocks[j].gram.buffer,
                    &x_prev.buffer,
                    &q_buf.buffer,
                    &z_buf.buffer,
                    &u_buf.buffer,
                    params_buf,
                ],
            )?
        };
        let (parts, secs) = self.rt.fetch_tuple(&out[0])?;
        self.ledger.record_d2h(parts[0].len() * 4, secs);
        x_j.copy_from_slice(&parts[0][..bw]);

        // pred = A x, streamed over tiles
        let (x_buf, secs) = self.rt.stage(&parts[0], &[self.block_n, 1])?;
        self.ledger.record_h2d(parts[0].len() * 4, secs);
        for t in 0..self.tiles {
            let out = self.rt.run(
                &exe_matvec,
                &[&self.blocks[j].a_tiles[t].buffer, &x_buf.buffer],
            )?;
            let (parts, secs) = self.rt.fetch_tuple(&out[0])?;
            self.ledger.record_d2h(parts[0].len() * 4, secs);
            let row0 = t * self.tile_m;
            let count = (self.m - row0).min(self.tile_m);
            pred_j[row0..row0 + count].copy_from_slice(&parts[0][..count]);
        }
        Ok(())
    }

    fn try_omega_update(
        &mut self,
        c: &[f32],
        m_blocks: f64,
        rho_l: f64,
        out: &mut [f32],
    ) -> anyhow::Result<()> {
        let lw = self.loss.width();
        // omega artifacts read only the M and rho_l slots, so staging a
        // BlockParams with zeroed rho_c/reg is exact.
        let params = BlockParams {
            rho_l,
            rho_c: 0.0,
            reg: 0.0,
        };
        {
            let (_, pbytes, psecs) = self.params.get(&self.rt, m_blocks, params)?;
            if pbytes > 0 {
                self.ledger.record_h2d(pbytes, psecs);
            }
        }
        for t in 0..self.tiles {
            let row0 = t * self.tile_m;
            let count = (self.m - row0).min(self.tile_m);
            self.tile_buf[..self.tile_m * lw].fill(0.0);
            self.tile_buf[..count * lw]
                .copy_from_slice(&c[row0 * lw..(row0 + count) * lw]);
            let (c_buf, secs) = self
                .rt
                .stage(&self.tile_buf[..self.tile_m * lw], &[self.tile_m, lw])?;
            self.ledger.record_h2d(self.tile_m * lw * 4, secs);
            let outb = {
                let exe = self.rt.executable(self.omega_artifact)?;
                let params_buf = &self.params.get(&self.rt, m_blocks, params)?.0.buffer;
                self.rt.run(
                    &exe,
                    &[&self.label_tiles[t].buffer, &c_buf.buffer, params_buf],
                )?
            };
            let (parts, secs) = self.rt.fetch_tuple(&outb[0])?;
            self.ledger.record_d2h(parts[0].len() * 4, secs);
            out[row0 * lw..(row0 + count) * lw].copy_from_slice(&parts[0][..count * lw]);
        }
        Ok(())
    }
}

impl XlaBackend {
    /// Stage one coefficient vector zero-padded to (block_n, 1), ledgered.
    fn stage_coeff_block(&mut self, v: &[f32]) -> anyhow::Result<DeviceTensor> {
        let bn = self.block_n;
        let mut host = vec![0.0f32; bn];
        host[..v.len()].copy_from_slice(v);
        let (tensor, secs) = self.rt.stage(&host, &[bn, 1])?;
        self.ledger.record_h2d(bn * 4, secs);
        Ok(tensor)
    }

    /// Stage one sample vector zero-padded to (tile_m, 1), ledgered.
    fn stage_m_vec(&mut self, v: &[f32]) -> anyhow::Result<DeviceTensor> {
        let tm = self.tile_m;
        let mut host = vec![0.0f32; tm];
        host[..v.len().min(tm)].copy_from_slice(&v[..v.len().min(tm)]);
        let (tensor, secs) = self.rt.stage(&host, &[tm, 1])?;
        self.ledger.record_h2d(tm * 4, secs);
        Ok(tensor)
    }

    #[allow(clippy::too_many_arguments)]
    fn try_node_sweep(
        &mut self,
        params: BlockParams,
        calls: usize,
        z_blocks: &[Vec<f32>],
        u_blocks: &[Vec<f32>],
        x_blocks: &mut [Vec<f32>],
        preds: &mut [Vec<f32>],
        omega: &mut [f32],
        nu: &mut [f32],
    ) -> anyhow::Result<()> {
        let mblocks = self.blocks.len();
        let (tm, bn, m) = (self.tile_m, self.block_n, self.m);
        let m_blocks_f = mblocks as f64;

        // per-round-trip staging: z/u once, state before each call
        let z_bufs: Vec<DeviceTensor> = z_blocks
            .iter()
            .map(|z| self.stage_coeff_block(z))
            .collect::<anyhow::Result<_>>()?;
        let u_bufs: Vec<DeviceTensor> = u_blocks
            .iter()
            .map(|u| self.stage_coeff_block(u))
            .collect::<anyhow::Result<_>>()?;
        {
            let (_, pbytes, psecs) = self.params.get(&self.rt, m_blocks_f, params)?;
            if pbytes > 0 {
                self.ledger.record_h2d(pbytes, psecs);
            }
        }

        let mut x_bufs: Vec<DeviceTensor> = x_blocks
            .iter()
            .map(|x| self.stage_coeff_block(x))
            .collect::<anyhow::Result<_>>()?;
        let mut w_bufs: Vec<DeviceTensor> = preds
            .iter()
            .map(|p| self.stage_m_vec(p))
            .collect::<anyhow::Result<_>>()?;
        let mut omega_buf = self.stage_m_vec(omega)?;
        let mut nu_buf = self.stage_m_vec(nu)?;

        for call in 0..calls {
            // HLO parameter order = pytree order of node_sweep:
            // a_0.., g_0.., x_0.., w_0.., omega, nu, z_0.., u_0.., b, params
            let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(4 * mblocks + 4);
            for b in &self.blocks {
                args.push(&b.a_tiles[0].buffer);
            }
            for b in &self.blocks {
                args.push(&b.gram.buffer);
            }
            for x in &x_bufs {
                args.push(&x.buffer);
            }
            for w in &w_bufs {
                args.push(&w.buffer);
            }
            args.push(&omega_buf.buffer);
            args.push(&nu_buf.buffer);
            for z in &z_bufs {
                args.push(&z.buffer);
            }
            for u in &u_bufs {
                args.push(&u.buffer);
            }
            let fused = self.fused.as_ref().unwrap();
            args.push(&fused.b.buffer);
            let params_tensor = self.params.get(&self.rt, m_blocks_f, params)?.0 as *const DeviceTensor;
            // SAFETY: params buffer lives in self.params for the whole call
            args.push(unsafe { &(*params_tensor).buffer });

            let fused = self.fused.as_ref().unwrap();
            let out = self.rt.run(&fused.exe, &args)?;
            // outputs: x_0..x_{M-1}, w_0..w_{M-1}, omega, nu
            let (parts, secs) = self.rt.fetch_tuple(&out[0])?;
            let bytes: usize = parts.iter().map(|p| p.len() * 4).sum();
            self.ledger.record_d2h(bytes, secs);

            if call + 1 < calls {
                for (bi, part) in parts[..mblocks].iter().enumerate() {
                    let (t, secs) = self.rt.stage(part, &[bn, 1])?;
                    self.ledger.record_h2d(part.len() * 4, secs);
                    x_bufs[bi] = t;
                }
                for (bi, part) in parts[mblocks..2 * mblocks].iter().enumerate() {
                    let (t, secs) = self.rt.stage(part, &[tm, 1])?;
                    self.ledger.record_h2d(part.len() * 4, secs);
                    w_bufs[bi] = t;
                }
                let (t, secs) = self.rt.stage(&parts[2 * mblocks], &[tm, 1])?;
                self.ledger.record_h2d(tm * 4, secs);
                omega_buf = t;
                let (t, secs) = self.rt.stage(&parts[2 * mblocks + 1], &[tm, 1])?;
                self.ledger.record_h2d(tm * 4, secs);
                nu_buf = t;
            } else {
                for (bi, xb) in x_blocks.iter_mut().enumerate() {
                    let w = xb.len();
                    xb.copy_from_slice(&parts[bi][..w]);
                }
                for (bi, p) in preds.iter_mut().enumerate() {
                    p[..m].copy_from_slice(&parts[mblocks + bi][..m]);
                }
                omega[..m].copy_from_slice(&parts[2 * mblocks][..m]);
                nu[..m].copy_from_slice(&parts[2 * mblocks + 1][..m]);
            }
        }
        Ok(())
    }
}

impl NodeBackend for XlaBackend {
    fn blocks(&self) -> usize {
        self.blocks.len()
    }

    fn samples(&self) -> usize {
        self.m
    }

    fn block_width(&self, j: usize) -> usize {
        self.blocks[j].width
    }

    fn block_step(
        &mut self,
        j: usize,
        params: BlockParams,
        corr: &[f32],
        z_j: &[f32],
        u_j: &[f32],
        x_j: &mut [f32],
        pred_j: &mut [f32],
    ) {
        self.try_block_step(j, params, corr, z_j, u_j, x_j, pred_j)
            .expect("xla block_step failed");
    }

    fn omega_update(&mut self, c: &[f32], m_blocks: f64, rho_l: f64, out: &mut [f32]) {
        self.try_omega_update(c, m_blocks, rho_l, out)
            .expect("xla omega_update failed");
    }

    fn loss_value(&self, pred: &[f32]) -> f64 {
        self.loss.value(pred, &self.labels_host)
    }

    fn ledger(&self) -> TransferLedger {
        self.ledger.clone()
    }

    fn reset_ledger(&mut self) {
        self.ledger = TransferLedger::default();
    }

    fn node_sweep(
        &mut self,
        params: BlockParams,
        sweeps: usize,
        z_blocks: &[Vec<f32>],
        u_blocks: &[Vec<f32>],
        x_blocks: &mut [Vec<f32>],
        preds: &mut [Vec<f32>],
        omega: &mut [f32],
        nu: &mut [f32],
    ) -> bool {
        let Some(f) = &self.fused else { return false };
        // the artifact bakes its sweep count; only a multiple avoids drift
        if sweeps % f.sweeps != 0 {
            return false;
        }
        let calls = sweeps / f.sweeps;
        self.try_node_sweep(params, calls, z_blocks, u_blocks, x_blocks, preds, omega, nu)
            .expect("xla node_sweep failed");
        true
    }
}

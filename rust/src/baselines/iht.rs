//! Iterative hard thresholding:  x <- H_kappa(x - eta * grad f(x)) with
//! f(x) = ||A x - b||^2 + 1/(2 gamma) ||x||^2 — the projection-based
//! family the paper cites (Tong et al. 2022, Olama et al. 2023c); used in
//! the ablation benches as a cheap non-convex baseline.

use crate::linalg::Matrix;
use crate::sparsity::hard_threshold;

/// What an IHT run returns.
#[derive(Debug, Clone)]
pub struct IhtResult {
    /// The kappa-sparse iterate at termination.
    pub x: Vec<f64>,
    /// Nonzero indices of `x`.
    pub support: Vec<usize>,
    /// Gradient steps taken.
    pub iters: usize,
}

/// Run IHT on the stacked problem until the iterate moves less than
/// `tol` in l-infinity or `max_iters` is hit.
pub fn iht(
    a: &Matrix,
    b: &[f32],
    kappa: usize,
    gamma: f64,
    max_iters: usize,
    tol: f64,
) -> IhtResult {
    let (m, n) = (a.rows, a.cols);
    // step 1/L via power iteration on 2 A^T A + I/gamma
    let mut v = vec![1.0f32; n];
    let mut av = vec![0.0f32; m];
    let mut atav = vec![0.0f32; n];
    let mut sigma2 = 1.0f64;
    for _ in 0..50 {
        a.matvec(&v, &mut av);
        a.matvec_t(&av, &mut atav);
        let nrm = atav.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
        if nrm == 0.0 {
            break;
        }
        sigma2 = nrm;
        for (vi, &t) in v.iter_mut().zip(&atav) {
            *vi = (t as f64 / nrm) as f32;
        }
    }
    let lip = 2.0 * sigma2 + 1.0 / gamma;
    let step = 1.0 / lip;

    let mut x = vec![0.0f64; n];
    let mut xf = vec![0.0f32; n];
    let mut grad = vec![0.0f32; n];
    let mut iters = 0;
    for k in 0..max_iters {
        iters = k + 1;
        for (o, &v) in xf.iter_mut().zip(&x) {
            *o = v as f32;
        }
        a.matvec(&xf, &mut av);
        for (ri, &bi) in av.iter_mut().zip(b) {
            *ri -= bi;
        }
        a.matvec_t(&av, &mut grad);
        let mut moved = 0.0f64;
        let x_old = x.clone();
        for j in 0..n {
            x[j] -= step * (2.0 * grad[j] as f64 + x[j] / gamma);
        }
        hard_threshold(&mut x, kappa);
        for (new, old) in x.iter().zip(&x_old) {
            moved = moved.max((new - old).abs());
        }
        if moved < tol {
            break;
        }
    }
    let support = crate::sparsity::support_of(&x, 0.0);
    IhtResult { x, support, iters }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;
    use crate::sparsity::support_f1;

    #[test]
    fn iht_recovers_easy_planted_support() {
        let mut spec = SyntheticSpec::regression(40, 400, 1);
        spec.sparsity_level = 0.9; // kappa = 4
        spec.noise_std = 0.02;
        let ds = spec.generate();
        let (a, b) = ds.stacked();
        let res = iht(&a, &b, 4, 10.0, 2000, 1e-9);
        let f1 = support_f1(&res.support, &ds.support_true);
        assert!(f1 > 0.9, "f1 = {f1}");
    }

    #[test]
    fn iht_output_is_kappa_sparse() {
        let ds = SyntheticSpec::regression(20, 100, 1).generate();
        let (a, b) = ds.stacked();
        let res = iht(&a, &b, 5, 10.0, 200, 1e-8);
        assert!(res.support.len() <= 5);
    }

    #[test]
    fn iht_is_deterministic_and_stable() {
        let mut spec = SyntheticSpec::regression(30, 300, 1);
        spec.noise_std = 0.01;
        let ds = spec.generate();
        let (a, b) = ds.stacked();
        let r1 = iht(&a, &b, 6, 10.0, 1500, 1e-9);
        let r2 = iht(&a, &b, 6, 10.0, 1500, 1e-9);
        assert_eq!(r1.x, r2.x);
        // the support stabilizes even if tiny coefficient drift continues
        let r3 = iht(&a, &b, 6, 10.0, 3000, 1e-9);
        assert_eq!(r1.support, r3.support);
    }
}

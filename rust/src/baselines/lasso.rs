//! Lasso baseline: min ||A x - b||^2 + lambda ||x||_1.
//!
//! Two solvers sharing one objective convention:
//!   * [`lasso_cd`]   — cyclic coordinate descent with residual updates
//!     (glmnet-style), warm-startable;
//!   * [`lasso_fista`]— accelerated proximal gradient (used as a
//!     cross-check in tests).
//!
//! [`lasso_path`] runs a glmnet-style geometric lambda path with warm
//! starts and returns the path solution whose support size first reaches
//! the target cardinality — the procedure the paper's Table 1 times.

use crate::linalg::Matrix;

/// A Lasso path solution.
#[derive(Debug, Clone)]
pub struct LassoResult {
    /// Coefficients at the returned lambda.
    pub x: Vec<f64>,
    /// The lambda the path stopped at.
    pub lambda: f64,
    /// Coordinate-descent sweeps spent in total.
    pub sweeps: usize,
    /// Support (|x_i| > 0) at the returned solution.
    pub support: Vec<usize>,
}

#[inline]
fn soft(x: f64, t: f64) -> f64 {
    x.signum() * (x.abs() - t).max(0.0)
}

/// Cyclic coordinate descent.  `col_sq[j] = ||a_j||^2` must be positive.
/// Maintains the residual r = b - A x across updates; each coordinate step
/// costs O(m).  Returns the sweep count used.
pub fn lasso_cd(
    a: &Matrix,
    b: &[f32],
    lambda: f64,
    x: &mut [f64],
    max_sweeps: usize,
    tol: f64,
) -> usize {
    let (m, n) = (a.rows, a.cols);
    assert_eq!(b.len(), m);
    assert_eq!(x.len(), n);

    // column norms and initial residual r = b - A x
    let mut col_sq = vec![0.0f64; n];
    for i in 0..m {
        for (j, &v) in a.row(i).iter().enumerate() {
            col_sq[j] += (v as f64) * (v as f64);
        }
    }
    let mut r = vec![0.0f64; m];
    for i in 0..m {
        let mut ax = 0.0f64;
        for (j, &v) in a.row(i).iter().enumerate() {
            ax += v as f64 * x[j];
        }
        r[i] = b[i] as f64 - ax;
    }

    for sweep in 0..max_sweeps {
        let mut max_delta = 0.0f64;
        for j in 0..n {
            if col_sq[j] == 0.0 {
                continue;
            }
            // partial residual correlation: c_j = a_j^T r + ||a_j||^2 x_j
            let mut ar = 0.0f64;
            for i in 0..m {
                ar += a.at(i, j) as f64 * r[i];
            }
            let cj = ar + col_sq[j] * x[j];
            // objective is ||r||^2 (no 1/2), so the quadratic coefficient
            // is 2 ||a_j||^2 and the threshold is lambda / 2.
            let x_new = soft(cj, lambda / 2.0) / col_sq[j];
            let delta = x_new - x[j];
            if delta != 0.0 {
                for i in 0..m {
                    r[i] -= a.at(i, j) as f64 * delta;
                }
                x[j] = x_new;
                max_delta = max_delta.max(delta.abs());
            }
        }
        if max_delta < tol {
            return sweep + 1;
        }
    }
    max_sweeps
}

/// FISTA with step 1/L, L = 2 lambda_max(A^T A) estimated by power
/// iteration.  Used by tests to cross-validate `lasso_cd`.
pub fn lasso_fista(a: &Matrix, b: &[f32], lambda: f64, iters: usize) -> Vec<f64> {
    let (m, n) = (a.rows, a.cols);
    // power iteration for ||A||_2^2
    let mut v = vec![1.0f32; n];
    let mut av = vec![0.0f32; m];
    let mut atav = vec![0.0f32; n];
    let mut sigma2 = 1.0f64;
    for _ in 0..50 {
        a.matvec(&v, &mut av);
        a.matvec_t(&av, &mut atav);
        let nrm = atav.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
        if nrm == 0.0 {
            break;
        }
        sigma2 = nrm;
        for (vi, &t) in v.iter_mut().zip(&atav) {
            *vi = (t as f64 / nrm) as f32;
        }
    }
    let lip = 2.0 * sigma2;
    let step = 1.0 / lip;

    let mut x = vec![0.0f64; n];
    let mut y = vec![0.0f64; n];
    let mut theta = 1.0f64;
    let mut yf = vec![0.0f32; n];
    let mut grad = vec![0.0f32; n];
    for _ in 0..iters {
        for (o, &v) in yf.iter_mut().zip(&y) {
            *o = v as f32;
        }
        a.matvec(&yf, &mut av);
        for (ri, &bi) in av.iter_mut().zip(b) {
            *ri -= bi;
        }
        a.matvec_t(&av, &mut grad);
        let x_old = x.clone();
        for j in 0..n {
            x[j] = soft(y[j] - step * 2.0 * grad[j] as f64, step * lambda);
        }
        let theta_new = 0.5 * (1.0 + (1.0 + 4.0 * theta * theta).sqrt());
        let beta = (theta - 1.0) / theta_new;
        for j in 0..n {
            y[j] = x[j] + beta * (x[j] - x_old[j]);
        }
        theta = theta_new;
    }
    x
}

/// Geometric lambda path with warm starts (glmnet recipe): from
/// lambda_max = 2 ||A^T b||_inf down over `path_len` points; returns the
/// first solution whose support reaches `target_support` nonzeros (or the
/// densest path point if none does).
pub fn lasso_path(
    a: &Matrix,
    b: &[f32],
    target_support: usize,
    path_len: usize,
    sweeps_per_lambda: usize,
) -> LassoResult {
    let n = a.cols;
    let mut atb = vec![0.0f32; n];
    a.matvec_t(b, &mut atb);
    let lambda_max = 2.0 * atb.iter().fold(0.0f64, |mx, &v| mx.max((v as f64).abs()));
    let lambda_min = lambda_max * 1e-3;
    let ratio = (lambda_min / lambda_max).powf(1.0 / (path_len.max(2) - 1) as f64);

    let mut x = vec![0.0f64; n];
    let mut total_sweeps = 0;
    let mut lambda = lambda_max;
    let mut best: Option<LassoResult> = None;
    for _ in 0..path_len {
        total_sweeps += lasso_cd(a, b, lambda, &mut x, sweeps_per_lambda, 1e-7);
        let support: Vec<usize> = x
            .iter()
            .enumerate()
            .filter(|(_, &v)| v != 0.0)
            .map(|(i, _)| i)
            .collect();
        let done = support.len() >= target_support;
        best = Some(LassoResult {
            x: x.clone(),
            lambda,
            sweeps: total_sweeps,
            support,
        });
        if done {
            break;
        }
        lambda *= ratio;
    }
    best.expect("path_len >= 1")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;

    fn stacked(n: usize, m: usize) -> (Matrix, Vec<f32>, Vec<usize>) {
        let mut spec = SyntheticSpec::regression(n, m, 1);
        spec.sparsity_level = 0.8;
        spec.noise_std = 0.05;
        let ds = spec.generate();
        let (a, b) = ds.stacked();
        (a, b, ds.support_true)
    }

    #[test]
    fn cd_matches_fista() {
        let (a, b, _) = stacked(24, 120);
        let lambda = 0.8;
        let mut x_cd = vec![0.0; 24];
        lasso_cd(&a, &b, lambda, &mut x_cd, 500, 1e-10);
        let x_f = lasso_fista(&a, &b, lambda, 4000);
        for (c, f) in x_cd.iter().zip(&x_f) {
            assert!((c - f).abs() < 1e-4, "{c} vs {f}");
        }
    }

    #[test]
    fn cd_satisfies_kkt() {
        let (a, b, _) = stacked(16, 100);
        let lambda = 0.5;
        let mut x = vec![0.0; 16];
        lasso_cd(&a, &b, lambda, &mut x, 1000, 1e-12);
        // KKT: |2 a_j^T (Ax - b)| <= lambda, equality with -sign on support
        let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let mut ax = vec![0.0f32; a.rows];
        a.matvec(&xf, &mut ax);
        let resid: Vec<f32> = ax.iter().zip(&b).map(|(p, l)| p - l).collect();
        let mut grad = vec![0.0f32; 16];
        a.matvec_t(&resid, &mut grad);
        for j in 0..16 {
            let g = 2.0 * grad[j] as f64;
            if x[j] != 0.0 {
                assert!(
                    (g + lambda * x[j].signum()).abs() < 1e-3,
                    "j={j}: g={g}, x={}",
                    x[j]
                );
            } else {
                assert!(g.abs() <= lambda + 1e-3, "j={j}: |g|={} > {lambda}", g.abs());
            }
        }
    }

    #[test]
    fn lambda_zero_gives_least_squares_fit() {
        let (a, b, _) = stacked(8, 60);
        let mut x = vec![0.0; 8];
        lasso_cd(&a, &b, 0.0, &mut x, 2000, 1e-13);
        // gradient of ||Ax-b||^2 must vanish
        let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let mut ax = vec![0.0f32; a.rows];
        a.matvec(&xf, &mut ax);
        let resid: Vec<f32> = ax.iter().zip(&b).map(|(p, l)| p - l).collect();
        let mut grad = vec![0.0f32; 8];
        a.matvec_t(&resid, &mut grad);
        for g in grad {
            assert!(g.abs() < 1e-3, "{g}");
        }
    }

    #[test]
    fn path_reaches_target_support() {
        let (a, b, truth) = stacked(30, 300);
        let res = lasso_path(&a, &b, truth.len(), 40, 200);
        assert!(res.support.len() >= truth.len());
        // lasso picks up most of the true support (but typically extra too)
        let hits = res
            .support
            .iter()
            .filter(|i| truth.contains(i))
            .count();
        assert!(hits as f64 >= 0.8 * truth.len() as f64, "{hits}/{}", truth.len());
    }
}

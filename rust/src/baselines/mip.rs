//! Exact best-subset selection by branch-and-bound — the Gurobi stand-in.
//!
//! Problem (the MIP reformulation of Eq. 24, as in Bertsimas et al. 2016):
//!     min ||A x - b||^2 + 1/(2 gamma) ||x||^2   s.t.  ||x||_0 <= kappa
//!
//! Node = (forced-in F, forced-out O).  Lower bound: the *cardinality-free*
//! ridge restricted to the allowed columns (dropping the l0 constraint is a
//! valid relaxation).  Upper bound / incumbent: hard-threshold the
//! relaxation to kappa and re-fit on that support.  Branching: the
//! undecided column with the largest |x| in the relaxation, in/out.
//!
//! Everything runs on the precomputed Gram (A^T A, A^T b), so node solves
//! are O(n_sub^3) Cholesky — the same dense-algebra regime Gurobi's
//! simplex/barrier works in for these instances, and the same exponential
//! node growth the paper's Table 1 demonstrates (with a time budget and a
//! "cut off" status).

use crate::linalg::{Cholesky, Matrix};
use crate::sparsity::top_k_indices;
use crate::util::Stopwatch;

/// How a branch-and-bound run ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BnbStatus {
    /// Proved optimal within the budget.
    Optimal,
    /// Time budget exhausted — incumbent returned (paper: "cut off").
    CutOff,
}

/// What a branch-and-bound run returns.
#[derive(Debug, Clone)]
pub struct BnbResult {
    /// Best kappa-sparse solution found.
    pub x: Vec<f64>,
    /// Objective value of `x`.
    pub objective: f64,
    /// Nonzero indices of `x`.
    pub support: Vec<usize>,
    /// Optimal or cut off.
    pub status: BnbStatus,
    /// Branch-and-bound nodes expanded.
    pub nodes_explored: usize,
    /// Wall-clock seconds spent.
    pub wall_seconds: f64,
}

struct Workspace {
    /// Gram = A^T A (n x n, f64), atb = A^T b, btb = ||b||^2.
    gram: Vec<f64>,
    atb: Vec<f64>,
    btb: f64,
    n: usize,
    reg: f64,
}

impl Workspace {
    fn build(a: &Matrix, b: &[f32], gamma: f64) -> Workspace {
        let n = a.cols;
        let mut gram32 = vec![0.0f32; n * n];
        a.gram_accumulate(&mut gram32);
        let mut atb32 = vec![0.0f32; n];
        a.matvec_t(b, &mut atb32);
        Workspace {
            gram: gram32.iter().map(|&v| v as f64).collect(),
            atb: atb32.iter().map(|&v| v as f64).collect(),
            btb: b.iter().map(|&v| (v as f64) * (v as f64)).sum(),
            n,
            reg: 1.0 / gamma, // gradient coefficient of 1/(2 gamma)||x||^2
        }
    }

    /// Ridge on the columns in `cols`: minimize
    /// ||A_S w - b||^2 + 1/(2 gamma)||w||^2.  Returns (w, objective).
    fn ridge_on(&self, cols: &[usize]) -> (Vec<f64>, f64) {
        let s = cols.len();
        if s == 0 {
            return (Vec::new(), self.btb);
        }
        // normal matrix 2 G_S + reg I, rhs 2 (A^T b)_S
        let mut h = vec![0.0f64; s * s];
        for (i, &ci) in cols.iter().enumerate() {
            for (j, &cj) in cols.iter().enumerate() {
                h[i * s + j] = 2.0 * self.gram[ci * self.n + cj];
            }
            h[i * s + i] += self.reg;
        }
        let mut w: Vec<f64> = cols.iter().map(|&c| 2.0 * self.atb[c]).collect();
        let chol = Cholesky::factor(&h, s).expect("ridge normal matrix SPD");
        chol.solve(&mut w);
        // objective = ||Aw-b||^2 + reg/2 ||w||^2
        //           = w^T G_S w - 2 w^T (A^T b)_S + b^T b + reg/2 ||w||^2
        let mut quad = 0.0;
        for (i, &ci) in cols.iter().enumerate() {
            let mut gw = 0.0;
            for (j, &cj) in cols.iter().enumerate() {
                gw += self.gram[ci * self.n + cj] * w[j];
            }
            quad += w[i] * gw - 2.0 * w[i] * self.atb[ci];
        }
        let ridge = 0.5 * self.reg * w.iter().map(|v| v * v).sum::<f64>();
        (w, quad + self.btb + ridge)
    }
}

struct Node {
    forced_in: Vec<usize>,
    forced_out: Vec<usize>,
}

/// Best-subset branch-and-bound with a wall-clock budget.
pub fn best_subset_bnb(
    a: &Matrix,
    b: &[f32],
    kappa: usize,
    gamma: f64,
    time_limit_secs: f64,
) -> BnbResult {
    let watch = Stopwatch::start();
    let ws = Workspace::build(a, b, gamma);
    let n = ws.n;
    let kappa = kappa.min(n);

    // incumbent from the root relaxation, thresholded + refit
    let all: Vec<usize> = (0..n).collect();
    let (x_relax, root_lb) = ws.ridge_on(&all);
    let mut incumbent_support = {
        let mut idx = top_k_indices(&x_relax, kappa);
        idx.sort_unstable();
        idx
    };
    let (mut incumbent_w, mut incumbent_obj) = ws.ridge_on(&incumbent_support);

    let mut nodes_explored = 0usize;
    let mut status = BnbStatus::Optimal;
    // best-first search on lower bound
    let mut heap: std::collections::BinaryHeap<std::cmp::Reverse<(ordered::F64, usize)>> =
        std::collections::BinaryHeap::new();
    let mut arena: Vec<Node> = vec![Node {
        forced_in: Vec::new(),
        forced_out: Vec::new(),
    }];
    heap.push(std::cmp::Reverse((ordered::F64(root_lb), 0)));

    while let Some(std::cmp::Reverse((lb, idx))) = heap.pop() {
        if lb.0 >= incumbent_obj - 1e-9 {
            break; // best-first: all remaining nodes are dominated
        }
        if watch.elapsed_secs() > time_limit_secs {
            status = BnbStatus::CutOff;
            break;
        }
        nodes_explored += 1;
        let node = &arena[idx];
        let forced_in = node.forced_in.clone();
        let forced_out = node.forced_out.clone();

        let allowed: Vec<usize> = (0..n).filter(|i| !forced_out.contains(i)).collect();
        // leaf conditions
        if forced_in.len() == kappa || allowed.len() <= kappa {
            let support: Vec<usize> = if forced_in.len() == kappa {
                forced_in.clone()
            } else {
                allowed.clone()
            };
            let (w, obj) = ws.ridge_on(&support);
            if obj < incumbent_obj {
                incumbent_obj = obj;
                incumbent_support = support;
                incumbent_w = w;
            }
            continue;
        }

        // relaxation on allowed columns
        let (w_relax, lb_here) = ws.ridge_on(&allowed);
        if lb_here >= incumbent_obj - 1e-9 {
            continue; // prune
        }
        // refresh incumbent from this relaxation
        let mut dense = vec![0.0f64; n];
        for (wi, &c) in w_relax.iter().zip(&allowed) {
            dense[c] = *wi;
        }
        // candidate support: forced_in first, then largest relaxation coords
        let mut cand = forced_in.clone();
        for &i in &top_k_indices(&dense, n) {
            if cand.len() == kappa {
                break;
            }
            if !cand.contains(&i) && !forced_out.contains(&i) {
                cand.push(i);
            }
        }
        cand.sort_unstable();
        let (w_cand, obj_cand) = ws.ridge_on(&cand);
        if obj_cand < incumbent_obj {
            incumbent_obj = obj_cand;
            incumbent_support = cand;
            incumbent_w = w_cand;
        }

        // branch on the largest undecided coordinate of the relaxation
        let branch = (0..n)
            .filter(|i| !forced_in.contains(i) && !forced_out.contains(i))
            .max_by(|&i, &j| {
                dense[i]
                    .abs()
                    .partial_cmp(&dense[j].abs())
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
        let Some(bi) = branch else { continue };

        let mut child_in = forced_in.clone();
        child_in.push(bi);
        arena.push(Node {
            forced_in: child_in,
            forced_out: forced_out.clone(),
        });
        heap.push(std::cmp::Reverse((ordered::F64(lb_here), arena.len() - 1)));

        let mut child_out = forced_out.clone();
        child_out.push(bi);
        // tightened bound for the out-branch: relaxation without column bi
        let allowed_out: Vec<usize> = allowed.iter().copied().filter(|&c| c != bi).collect();
        let (_, lb_out) = ws.ridge_on(&allowed_out);
        if lb_out < incumbent_obj - 1e-9 {
            arena.push(Node {
                forced_in,
                forced_out: child_out,
            });
            heap.push(std::cmp::Reverse((ordered::F64(lb_out), arena.len() - 1)));
        }
    }

    // canonical order: support sorted, weights re-fit in that order
    let mut pairs: Vec<(usize, f64)> = incumbent_support
        .iter()
        .copied()
        .zip(incumbent_w.iter().copied())
        .collect();
    pairs.sort_by_key(|&(c, _)| c);
    let incumbent_support: Vec<usize> = pairs.iter().map(|&(c, _)| c).collect();
    let mut x = vec![0.0f64; n];
    for &(c, w) in &pairs {
        x[c] = w;
    }
    BnbResult {
        x,
        objective: incumbent_obj,
        support: incumbent_support,
        status,
        nodes_explored,
        wall_seconds: watch.elapsed_secs(),
    }
}

/// Total-ordered f64 wrapper for the heap.
mod ordered {
    #[derive(PartialEq, PartialOrd)]
    pub struct F64(pub f64);
    impl Eq for F64 {}
    #[allow(clippy::derive_ord_xor_partial_ord)]
    impl Ord for F64 {
        fn cmp(&self, other: &Self) -> std::cmp::Ordering {
            self.partial_cmp(other).unwrap_or(std::cmp::Ordering::Equal)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;

    /// Exhaustive oracle over all kappa-subsets.
    fn brute_force(a: &Matrix, b: &[f32], kappa: usize, gamma: f64) -> (Vec<usize>, f64) {
        let ws = Workspace::build(a, b, gamma);
        let n = a.cols;
        let mut best = (Vec::new(), f64::INFINITY);
        let mut subset = vec![0usize; kappa];
        fn rec(
            ws: &Workspace,
            n: usize,
            k: usize,
            start: usize,
            subset: &mut Vec<usize>,
            pos: usize,
            best: &mut (Vec<usize>, f64),
        ) {
            if pos == k {
                let (_, obj) = ws.ridge_on(&subset[..k]);
                if obj < best.1 {
                    *best = (subset[..k].to_vec(), obj);
                }
                return;
            }
            for i in start..n {
                subset[pos] = i;
                rec(ws, n, k, i + 1, subset, pos + 1, best);
            }
        }
        rec(&ws, n, kappa, 0, &mut subset, 0, &mut best);
        best
    }

    #[test]
    fn bnb_matches_bruteforce_on_small_instances() {
        for (n, m, kappa, seed) in [(8, 40, 2, 1u64), (10, 60, 3, 2), (12, 50, 2, 3)] {
            let mut spec = SyntheticSpec::regression(n, m, 1);
            spec.seed = seed;
            spec.sparsity_level = 1.0 - kappa as f64 / n as f64;
            spec.noise_std = 0.1;
            let ds = spec.generate();
            let (a, b) = ds.stacked();
            let res = best_subset_bnb(&a, &b, kappa, 10.0, 60.0);
            assert_eq!(res.status, BnbStatus::Optimal);
            let (bf_support, bf_obj) = brute_force(&a, &b, kappa, 10.0);
            assert!(
                (res.objective - bf_obj).abs() < 1e-6 * (1.0 + bf_obj),
                "n={n}: {} vs {}",
                res.objective,
                bf_obj
            );
            assert_eq!(res.support, bf_support, "n={n}");
        }
    }

    #[test]
    fn bnb_recovers_planted_support() {
        let mut spec = SyntheticSpec::regression(20, 200, 1);
        spec.sparsity_level = 0.85; // kappa = 3
        spec.noise_std = 0.02;
        let ds = spec.generate();
        let (a, b) = ds.stacked();
        let res = best_subset_bnb(&a, &b, 3, 10.0, 60.0);
        assert_eq!(res.support, ds.support_true);
    }

    #[test]
    fn bnb_respects_time_budget() {
        let mut spec = SyntheticSpec::regression(60, 120, 1);
        spec.sparsity_level = 0.75; // kappa = 15 — combinatorially hard
        spec.noise_std = 0.5;
        let ds = spec.generate();
        let (a, b) = ds.stacked();
        let watch = Stopwatch::start();
        let res = best_subset_bnb(&a, &b, 15, 10.0, 0.3);
        assert!(watch.elapsed_secs() < 5.0, "budget ignored");
        // either finished fast or reported the cut-off honestly
        if res.wall_seconds > 0.3 {
            assert_eq!(res.status, BnbStatus::CutOff);
        }
        assert_eq!(res.support.len(), 15);
    }

    #[test]
    fn incumbent_is_always_feasible() {
        let mut spec = SyntheticSpec::regression(16, 80, 1);
        spec.sparsity_level = 0.75;
        let ds = spec.generate();
        let (a, b) = ds.stacked();
        let res = best_subset_bnb(&a, &b, 4, 10.0, 30.0);
        assert!(res.support.len() <= 4);
        let nnz = res.x.iter().filter(|&&v| v != 0.0).count();
        assert!(nnz <= 4);
    }
}

//! Baseline solvers for the Table 1 comparison:
//!
//!   * [`lasso`] — l1-relaxation (glmnet-style pathwise coordinate descent
//!     + FISTA), the paper's "Lasso" column.  The paper's asterisks
//!     ("could not recover the true sparsity") emerge from the l1 bias.
//!   * [`mip`]   — exact best-subset selection by branch-and-bound with
//!     ridge-relaxation bounds and a time budget — the stand-in for the
//!     paper's Gurobi MIP column (same problem class, same exponential
//!     blow-up, "cut off" behaviour included).
//!   * [`iht`]   — iterative hard thresholding (the projection-based
//!     family the paper cites as related work; used in ablations).
//!
//! All baselines are *centralized*: they see the stacked dataset, exactly
//! like the paper runs Gurobi and glmnet on a single machine.

/// Iterative hard thresholding.
pub mod iht;
/// Lasso via FISTA on the stacked problem.
pub mod lasso;
/// Best-subset branch-and-bound (the Gurobi stand-in).
pub mod mip;

pub use iht::iht;
pub use lasso::{lasso_cd, lasso_path, LassoResult};
pub use mip::{best_subset_bnb, BnbResult, BnbStatus};

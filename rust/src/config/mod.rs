//! Configuration system: solver, problem, and platform settings with
//! validated builders and JSON file loading (`psfit train --config x.json`).

use crate::coordinator::fault::FaultSpec;
use crate::data::SparseMode;
use crate::linalg::simd::IsaChoice;
use crate::losses::LossKind;
use crate::path::PathConfig;
use crate::util::json::Json;

/// Which compute backend executes the node-level data path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Native Rust (the paper's "CPU backend").
    Native,
    /// AOT XLA artifacts via PJRT (the paper's "GPU backend"; see
    /// DESIGN.md §Hardware-Adaptation).
    Xla,
}

impl BackendKind {
    /// Parse a CLI/JSON backend name.
    pub fn parse(s: &str) -> anyhow::Result<BackendKind> {
        match s {
            "native" | "cpu" => Ok(BackendKind::Native),
            "xla" | "gpu" => Ok(BackendKind::Xla),
            other => anyhow::bail!("unknown backend `{other}` (native|xla)"),
        }
    }

    /// Canonical name (inverse of [`BackendKind::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Xla => "xla",
        }
    }
}

/// Which transport carries the consensus rounds between the coordinator
/// and the node workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TransportKind {
    /// In-process workers (sequential, threaded, or async per the
    /// coordination setting) — the default, and the only transport the
    /// XLA backend supports.
    #[default]
    Local,
    /// Standalone `psfit worker` processes reached over TCP or Unix
    /// sockets (`network::socket::SocketCluster`); requires
    /// `platform.workers` addresses.
    Socket,
}

impl TransportKind {
    /// Parse a CLI/JSON transport name.
    pub fn parse(s: &str) -> anyhow::Result<TransportKind> {
        match s {
            "local" => Ok(TransportKind::Local),
            "socket" | "tcp" => Ok(TransportKind::Socket),
            other => anyhow::bail!("unknown transport `{other}` (local|socket)"),
        }
    }

    /// Canonical name (inverse of [`TransportKind::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Local => "local",
            TransportKind::Socket => "socket",
        }
    }
}

/// Bi-cADMM solver parameters (Eq. 7 and Algorithm 2).
#[derive(Clone, Debug)]
pub struct SolverConfig {
    /// Consensus penalty rho_c.
    pub rho_c: f64,
    /// Bi-linear penalty rho_b.  Paper guidance: rho_b = alpha * rho_c,
    /// alpha in (0, 1].
    pub rho_b: f64,
    /// Inner sharing-ADMM penalty rho_l (Algorithm 2).
    pub rho_l: f64,
    /// Tikhonov weight gamma (objective has 1/(2 gamma) ||x||^2).
    pub gamma: f64,
    /// Cardinality bound kappa.
    pub kappa: usize,
    /// Outer iteration cap.
    pub max_iters: usize,
    /// Inner (node-level) ADMM sweeps per outer iteration.
    pub inner_iters: usize,
    /// CG iterations per block solve (must match the artifact's baked
    /// count on the XLA path).
    pub cg_iters: usize,
    /// Termination tolerance on the primal residual (Eq. 14).
    pub tol_primal: f64,
    /// Termination tolerance on the dual residual.
    pub tol_dual: f64,
    /// Termination tolerance on the bilinear residual.
    pub tol_bilinear: f64,
    /// Projected-gradient iterations for the (z,t)-update (7b).
    pub zt_iters: usize,
    /// Re-fit the dense solution on the recovered support at the end.
    pub polish: bool,
    /// Mid-fit checkpoint file (PSF1) for `psfit train --checkpoint` and
    /// serve jobs; empty disables checkpointing.  When the file already
    /// holds a compatible snapshot the fit resumes from it with a
    /// bit-identical remaining trace.
    pub checkpoint: String,
    /// Outer iterations between checkpoint writes (>= 1 when
    /// `checkpoint` is set).
    pub checkpoint_every: usize,
    /// Wall-clock budget in milliseconds; past it the solve stops cleanly
    /// at the next round boundary and returns the best-so-far iterate
    /// with `timed_out` set.  `0` (default) disables the deadline.
    pub deadline_ms: u64,
    /// Divergence-watchdog window: consecutive rounds of sustained
    /// residual growth (or any non-finite residual) that trigger a
    /// safeguarded restart.  `0` disables the watchdog.
    pub watchdog_window: usize,
    /// Safeguarded restarts (rescale rho_c/rho_b, re-seed from the last
    /// finite state) the watchdog may attempt before the solve returns
    /// `SolveError::Diverged`.
    pub watchdog_restarts: usize,
    /// Mini-batch window in rows for the inner node solve: each outer
    /// round visits one seeded chunk of `minibatch` rows instead of the
    /// full shard (see `admm::minibatch`).  `0` (default) disables
    /// mini-batching; a window >= the shard rows degenerates to the
    /// full-batch solve bit-for-bit.  Requires the native backend and
    /// sync coordination.
    pub minibatch: usize,
    /// Seed for the deterministic mini-batch chunk schedule: the same
    /// seed yields an identical schedule fingerprint and a bit-identical
    /// trajectory on every transport.
    pub minibatch_seed: u64,
}

impl Default for SolverConfig {
    fn default() -> Self {
        SolverConfig {
            rho_c: 1.0,
            rho_b: 0.5,
            rho_l: 1.0,
            gamma: 10.0,
            kappa: 1,
            max_iters: 200,
            inner_iters: 3,
            cg_iters: 24,
            tol_primal: 1e-4,
            tol_dual: 1e-4,
            tol_bilinear: 1e-4,
            zt_iters: 80,
            polish: true,
            checkpoint: String::new(),
            checkpoint_every: 1,
            deadline_ms: 0,
            watchdog_window: 25,
            watchdog_restarts: 2,
            minibatch: 0,
            minibatch_seed: 0,
        }
    }
}

impl SolverConfig {
    /// Defaults with the given cardinality bound.
    pub fn with_kappa(kappa: usize) -> SolverConfig {
        SolverConfig {
            kappa,
            ..Default::default()
        }
    }

    /// Paper's selection rule: rho_b = alpha * rho_c, alpha in (0, 1].
    pub fn alpha(mut self, alpha: f64) -> SolverConfig {
        self.rho_b = alpha * self.rho_c;
        self
    }

    /// Reject non-positive penalties and degenerate iteration counts.
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.rho_c <= 0.0 || self.rho_b <= 0.0 || self.rho_l <= 0.0 {
            anyhow::bail!("penalties must be positive");
        }
        if self.gamma <= 0.0 {
            anyhow::bail!("gamma must be positive");
        }
        if self.kappa == 0 {
            anyhow::bail!("kappa must be >= 1");
        }
        if self.max_iters == 0 || self.inner_iters == 0 || self.cg_iters == 0 {
            anyhow::bail!("iteration counts must be >= 1");
        }
        if !self.checkpoint.is_empty() && self.checkpoint_every == 0 {
            anyhow::bail!("solver.checkpoint_every must be >= 1 when checkpointing");
        }
        Ok(())
    }

    /// Curvature of r_j: reg = 1/(N gamma) + rho_c (see Eq. 17).
    pub fn block_reg(&self, nodes: usize) -> f64 {
        1.0 / (nodes as f64 * self.gamma) + self.rho_c
    }
}

/// Which coordination protocol drives the outer consensus rounds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoordinationKind {
    /// Full barrier: every round waits for every node (the paper's MPI
    /// loop; `SequentialCluster` / `ThreadedCluster`).
    Sync,
    /// Partial barrier with bounded staleness (`coordinator::AsyncCluster`).
    Async,
}

impl CoordinationKind {
    /// Parse a CLI/JSON coordination name.
    pub fn parse(s: &str) -> anyhow::Result<CoordinationKind> {
        match s {
            "sync" => Ok(CoordinationKind::Sync),
            "async" => Ok(CoordinationKind::Async),
            other => anyhow::bail!("unknown coordination `{other}` (sync|async)"),
        }
    }

    /// Canonical name (inverse of [`CoordinationKind::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            CoordinationKind::Sync => "sync",
            CoordinationKind::Async => "async",
        }
    }
}

/// Settings for the coordination layer (see `coordinator/`).
///
/// With the defaults (`quorum = 1.0`, `max_staleness = 0`) the async
/// scheduler degenerates to a full barrier and reproduces the synchronous
/// clusters bit-for-bit — the convergence guardrail the parity tests pin.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Which protocol drives the outer rounds.
    pub coordination: CoordinationKind,
    /// Fraction of active nodes whose replies commit a round, in (0, 1].
    pub quorum: f64,
    /// Replies older than this many rounds are dropped and the node is
    /// resynced with the current z.
    pub max_staleness: usize,
    /// Liveness-probe interval while waiting on a quorum.
    pub heartbeat_ms: u64,
    /// Deterministic straggler/crash model (empty = healthy cluster).
    pub faults: FaultSpec,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            coordination: CoordinationKind::Sync,
            quorum: 1.0,
            max_staleness: 0,
            heartbeat_ms: 50,
            faults: FaultSpec::default(),
        }
    }
}

impl CoordinatorConfig {
    /// Reject out-of-range quorum/heartbeat settings.
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.quorum.is_nan() || self.quorum <= 0.0 || self.quorum > 1.0 {
            anyhow::bail!("coordinator.quorum must be in (0, 1], got {}", self.quorum);
        }
        if self.heartbeat_ms == 0 {
            anyhow::bail!("coordinator.heartbeat_ms must be >= 1");
        }
        self.faults.validate()
    }
}

/// Platform topology: node count, devices per node, transfer cost model.
#[derive(Clone, Debug)]
pub struct PlatformConfig {
    /// Computational nodes N (row shards).
    pub nodes: usize,
    /// Device (simulated GPU) queues per node = the feature-block count M.
    pub devices_per_node: usize,
    /// Worker threads per node for the native backend's block sweep
    /// (`1` = serial, `0` = all available cores).  Results are
    /// bit-identical at any value — see `util::pool`.
    pub threads: usize,
    /// Shard storage policy: `auto` (density-adaptive, the default),
    /// `always` (force CSR), `never` (force dense).  See
    /// `data::ShardData` and `psfit train --sparse`.
    pub sparse: SparseMode,
    /// Density at or below which `auto` picks CSR storage.  0.25 by
    /// default: the crossover measured by `psfit bench` sits between the
    /// 0.25 and 1.0 sweep points on the acceptance shape, and below it
    /// the O(nnz) kernels win on both FLOPs and memory traffic.
    pub sparse_threshold: f64,
    /// Which compute backend the nodes run.
    pub backend: BackendKind,
    /// Kernel instruction-set variant for the native backend:
    /// `auto` (default; widest the host supports), `scalar` (tiled
    /// fallback, bit-identical to the historical kernels), `avx2`, or
    /// `neon`.  Applied process-wide at CLI startup via
    /// `linalg::simd::select`; also overridable with `PSFIT_ISA` for
    /// testing.  Forcing a variant the host lacks is a startup error.
    pub isa: IsaChoice,
    /// Optional synthetic PCIe model for the transfer ledger: seconds =
    /// bytes / (gbps * 1e9 / 8) + latency.  `None` records measured copy
    /// time only.
    pub pcie_gbps: Option<f64>,
    /// Per-transfer latency of the synthetic PCIe model (microseconds).
    pub pcie_latency_us: f64,
    /// Share one PJRT runtime (and its compiled-executable cache) across
    /// all node backends.  Compiles each artifact once per process instead
    /// of once per node, but forces the sequential cluster (the shared
    /// `Rc` graph must stay on one thread).  Default true for the XLA
    /// backend benchmarks.
    pub share_runtime: bool,
    /// Which transport carries the consensus rounds: `local` in-process
    /// workers (default) or `socket` worker processes.
    pub transport: TransportKind,
    /// Worker addresses for the socket transport, one per node in roster
    /// order (`host:port` or `unix:/path`); ignored by `local`.
    pub workers: Vec<String>,
    /// Socket transport: per-attempt connect timeout in milliseconds.
    pub connect_timeout_ms: u64,
    /// Socket transport: read timeout per reply in milliseconds; a worker
    /// silent for longer is declared dead and the round degrades.  `0`
    /// waits forever.
    pub read_timeout_ms: u64,
    /// Socket transport: connect retries after the first attempt (linear
    /// backoff), absorbing workers that are still binding at startup.
    pub connect_retries: u32,
    /// Socket transport: keep probing dead workers between rounds and
    /// fold them back into the fleet (fresh `Setup` plus a warm-state
    /// resync when one is cached).
    pub rejoin: bool,
    /// Socket transport: minimum live workers per round; a round with
    /// fewer replies fails instead of degrading further.  `0` accepts
    /// any non-empty quorum.
    pub quorum: u64,
    /// Consecutive poisoned (non-finite / norm-blowup) replies after
    /// which the reply guard banishes a node from the roster — a
    /// structured death, eligible for `rejoin` on the socket transport.
    /// `0` quarantines per round but never banishes.
    pub quarantine_limit: u64,
}

impl PlatformConfig {
    /// Reject out-of-range storage-policy and transport settings.
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.sparse_threshold.is_nan()
            || !(0.0..=1.0).contains(&self.sparse_threshold)
        {
            anyhow::bail!(
                "platform.sparse_threshold must be in [0, 1], got {}",
                self.sparse_threshold
            );
        }
        if self.transport == TransportKind::Socket && self.connect_timeout_ms == 0 {
            anyhow::bail!("platform.connect_timeout_ms must be >= 1 for the socket transport");
        }
        Ok(())
    }
}

impl Default for PlatformConfig {
    fn default() -> Self {
        PlatformConfig {
            nodes: 4,
            devices_per_node: 2,
            threads: 1,
            sparse: SparseMode::Auto,
            sparse_threshold: 0.25,
            backend: BackendKind::Native,
            isa: IsaChoice::Auto,
            pcie_gbps: None,
            pcie_latency_us: 10.0,
            share_runtime: true,
            transport: TransportKind::Local,
            workers: Vec::new(),
            connect_timeout_ms: 3000,
            read_timeout_ms: 30_000,
            connect_retries: 3,
            rejoin: false,
            quorum: 0,
            quarantine_limit: 3,
        }
    }
}

/// Settings for the `psfit serve` daemon's durable control plane (see
/// `serve::journal` and DESIGN.md §Durable-control-plane).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Durable state directory: the job journal, model artifacts, and
    /// per-job PSF1 checkpoints live here.  Empty keeps the daemon
    /// in-memory-only (a restart forgets every job).
    pub state_dir: String,
    /// How long a drain (SIGTERM/SIGINT) waits for running jobs before
    /// exiting anyway; their checkpoints make the wait a courtesy.
    pub drain_grace_ms: u64,
    /// Whether to journal at all when a state dir is set; per-job
    /// checkpoints are still written when `false`.
    pub journal: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            state_dir: String::new(),
            drain_grace_ms: 10_000,
            journal: true,
        }
    }
}

/// Complete experiment configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Bi-cADMM solver parameters.
    pub solver: SolverConfig,
    /// Platform topology and storage policy.
    pub platform: PlatformConfig,
    /// Coordination protocol settings.
    pub coordinator: CoordinatorConfig,
    /// Which loss the nodes minimize.
    pub loss: LossKind,
    /// Class count for the softmax loss (ignored by scalar losses).
    pub classes: usize,
    /// Sparsity-path sweep settings (`psfit path`; empty budgets means
    /// no path is configured).
    pub path: PathConfig,
    /// `psfit serve` durability settings (`--state-dir` et al.).
    pub serve: ServeConfig,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            solver: SolverConfig::default(),
            platform: PlatformConfig::default(),
            coordinator: CoordinatorConfig::default(),
            loss: LossKind::Squared,
            classes: 2,
            path: PathConfig::default(),
            serve: ServeConfig::default(),
        }
    }
}

impl Config {
    /// Load from a JSON file; unknown keys are rejected.
    pub fn from_json_file(path: &std::path::Path) -> anyhow::Result<Config> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Parse a JSON config object; unknown keys are rejected.
    pub fn from_json(v: &Json) -> anyhow::Result<Config> {
        let mut cfg = Config::default();
        let obj = v
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("config must be a JSON object"))?;
        for (key, val) in obj {
            match key.as_str() {
                "solver" => {
                    let s = val
                        .as_obj()
                        .ok_or_else(|| anyhow::anyhow!("solver must be an object"))?;
                    for (k, v) in s {
                        let f = || {
                            v.as_f64()
                                .ok_or_else(|| anyhow::anyhow!("solver.{k} must be a number"))
                        };
                        let u = || {
                            v.as_usize()
                                .ok_or_else(|| anyhow::anyhow!("solver.{k} must be an integer"))
                        };
                        match k.as_str() {
                            "rho_c" => cfg.solver.rho_c = f()?,
                            "rho_b" => cfg.solver.rho_b = f()?,
                            "rho_l" => cfg.solver.rho_l = f()?,
                            "gamma" => cfg.solver.gamma = f()?,
                            "kappa" => cfg.solver.kappa = u()?,
                            "max_iters" => cfg.solver.max_iters = u()?,
                            "inner_iters" => cfg.solver.inner_iters = u()?,
                            "cg_iters" => cfg.solver.cg_iters = u()?,
                            "tol_primal" => cfg.solver.tol_primal = f()?,
                            "tol_dual" => cfg.solver.tol_dual = f()?,
                            "tol_bilinear" => cfg.solver.tol_bilinear = f()?,
                            "zt_iters" => cfg.solver.zt_iters = u()?,
                            "polish" => {
                                cfg.solver.polish = v
                                    .as_bool()
                                    .ok_or_else(|| anyhow::anyhow!("solver.polish: bool"))?
                            }
                            "checkpoint" => {
                                cfg.solver.checkpoint = v
                                    .as_str()
                                    .ok_or_else(|| anyhow::anyhow!("solver.checkpoint: str"))?
                                    .to_string()
                            }
                            "checkpoint_every" => cfg.solver.checkpoint_every = u()?,
                            "deadline_ms" => cfg.solver.deadline_ms = u()? as u64,
                            "watchdog_window" => cfg.solver.watchdog_window = u()?,
                            "watchdog_restarts" => cfg.solver.watchdog_restarts = u()?,
                            "minibatch" => cfg.solver.minibatch = u()?,
                            "minibatch_seed" => cfg.solver.minibatch_seed = u()? as u64,
                            other => anyhow::bail!("unknown solver key `{other}`"),
                        }
                    }
                }
                "platform" => {
                    let p = val
                        .as_obj()
                        .ok_or_else(|| anyhow::anyhow!("platform must be an object"))?;
                    for (k, v) in p {
                        match k.as_str() {
                            "nodes" => {
                                cfg.platform.nodes = v
                                    .as_usize()
                                    .ok_or_else(|| anyhow::anyhow!("platform.nodes: int"))?
                            }
                            "devices_per_node" => {
                                cfg.platform.devices_per_node = v.as_usize().ok_or_else(|| {
                                    anyhow::anyhow!("platform.devices_per_node: int")
                                })?
                            }
                            "threads" => {
                                cfg.platform.threads = v
                                    .as_usize()
                                    .ok_or_else(|| anyhow::anyhow!("platform.threads: int"))?
                            }
                            "sparse" => {
                                cfg.platform.sparse = SparseMode::parse(
                                    v.as_str()
                                        .ok_or_else(|| anyhow::anyhow!("platform.sparse: str"))?,
                                )?
                            }
                            "sparse_threshold" => {
                                cfg.platform.sparse_threshold = v.as_f64().ok_or_else(|| {
                                    anyhow::anyhow!("platform.sparse_threshold: num")
                                })?
                            }
                            "backend" => {
                                cfg.platform.backend = BackendKind::parse(
                                    v.as_str()
                                        .ok_or_else(|| anyhow::anyhow!("platform.backend: str"))?,
                                )?
                            }
                            "isa" => {
                                cfg.platform.isa = IsaChoice::parse(
                                    v.as_str()
                                        .ok_or_else(|| anyhow::anyhow!("platform.isa: str"))?,
                                )?
                            }
                            "pcie_gbps" => cfg.platform.pcie_gbps = v.as_f64(),
                            "share_runtime" => {
                                cfg.platform.share_runtime = v
                                    .as_bool()
                                    .ok_or_else(|| anyhow::anyhow!("share_runtime: bool"))?
                            }
                            "pcie_latency_us" => {
                                cfg.platform.pcie_latency_us = v
                                    .as_f64()
                                    .ok_or_else(|| anyhow::anyhow!("pcie_latency_us: num"))?
                            }
                            "transport" => {
                                cfg.platform.transport = TransportKind::parse(
                                    v.as_str().ok_or_else(|| {
                                        anyhow::anyhow!("platform.transport: str")
                                    })?,
                                )?
                            }
                            "workers" => {
                                let arr = v
                                    .as_arr()
                                    .ok_or_else(|| anyhow::anyhow!("platform.workers: array"))?;
                                cfg.platform.workers = arr
                                    .iter()
                                    .map(|x| {
                                        x.as_str().map(str::to_string).ok_or_else(|| {
                                            anyhow::anyhow!("platform.workers entries: str")
                                        })
                                    })
                                    .collect::<anyhow::Result<_>>()?;
                            }
                            "connect_timeout_ms" => {
                                cfg.platform.connect_timeout_ms =
                                    v.as_usize().ok_or_else(|| {
                                        anyhow::anyhow!("platform.connect_timeout_ms: int")
                                    })? as u64
                            }
                            "read_timeout_ms" => {
                                cfg.platform.read_timeout_ms = v.as_usize().ok_or_else(|| {
                                    anyhow::anyhow!("platform.read_timeout_ms: int")
                                })? as u64
                            }
                            "connect_retries" => {
                                cfg.platform.connect_retries =
                                    v.as_usize().ok_or_else(|| {
                                        anyhow::anyhow!("platform.connect_retries: int")
                                    })? as u32
                            }
                            "rejoin" => {
                                cfg.platform.rejoin = v
                                    .as_bool()
                                    .ok_or_else(|| anyhow::anyhow!("platform.rejoin: bool"))?
                            }
                            "quorum" => {
                                cfg.platform.quorum = v.as_usize().ok_or_else(|| {
                                    anyhow::anyhow!("platform.quorum: int")
                                })? as u64
                            }
                            "quarantine_limit" => {
                                cfg.platform.quarantine_limit =
                                    v.as_usize().ok_or_else(|| {
                                        anyhow::anyhow!("platform.quarantine_limit: int")
                                    })? as u64
                            }
                            other => anyhow::bail!("unknown platform key `{other}`"),
                        }
                    }
                }
                "coordinator" => {
                    let c = val
                        .as_obj()
                        .ok_or_else(|| anyhow::anyhow!("coordinator must be an object"))?;
                    for (k, v) in c {
                        match k.as_str() {
                            "coordination" => {
                                cfg.coordinator.coordination = CoordinationKind::parse(
                                    v.as_str().ok_or_else(|| {
                                        anyhow::anyhow!("coordinator.coordination: str")
                                    })?,
                                )?
                            }
                            "quorum" => {
                                cfg.coordinator.quorum = v
                                    .as_f64()
                                    .ok_or_else(|| anyhow::anyhow!("coordinator.quorum: num"))?
                            }
                            "max_staleness" => {
                                cfg.coordinator.max_staleness = v.as_usize().ok_or_else(|| {
                                    anyhow::anyhow!("coordinator.max_staleness: int")
                                })?
                            }
                            "heartbeat_ms" => {
                                cfg.coordinator.heartbeat_ms =
                                    v.as_usize().ok_or_else(|| {
                                        anyhow::anyhow!("coordinator.heartbeat_ms: int")
                                    })? as u64
                            }
                            "seed" => {
                                cfg.coordinator.faults.seed = v
                                    .as_usize()
                                    .ok_or_else(|| anyhow::anyhow!("coordinator.seed: int"))?
                                    as u64
                            }
                            "jitter_ms" => {
                                cfg.coordinator.faults.jitter_ms = v
                                    .as_f64()
                                    .ok_or_else(|| anyhow::anyhow!("coordinator.jitter_ms: num"))?
                            }
                            "stragglers" => {
                                let arr = v.as_arr().ok_or_else(|| {
                                    anyhow::anyhow!("coordinator.stragglers: array")
                                })?;
                                for entry in arr {
                                    let node = entry
                                        .req("node")?
                                        .as_usize()
                                        .ok_or_else(|| anyhow::anyhow!("straggler.node: int"))?;
                                    let delay_ms =
                                        entry.req("delay_ms")?.as_f64().ok_or_else(|| {
                                            anyhow::anyhow!("straggler.delay_ms: num")
                                        })?;
                                    cfg.coordinator.faults =
                                        std::mem::take(&mut cfg.coordinator.faults)
                                            .straggler(node, delay_ms);
                                }
                            }
                            "crashes" => {
                                let arr = v
                                    .as_arr()
                                    .ok_or_else(|| anyhow::anyhow!("coordinator.crashes: array"))?;
                                for entry in arr {
                                    let node = entry
                                        .req("node")?
                                        .as_usize()
                                        .ok_or_else(|| anyhow::anyhow!("crash.node: int"))?;
                                    let round = entry
                                        .req("round")?
                                        .as_usize()
                                        .ok_or_else(|| anyhow::anyhow!("crash.round: int"))?;
                                    cfg.coordinator.faults =
                                        std::mem::take(&mut cfg.coordinator.faults)
                                            .crash(node, round);
                                }
                            }
                            other => anyhow::bail!("unknown coordinator key `{other}`"),
                        }
                    }
                }
                "path" => {
                    let p = val
                        .as_obj()
                        .ok_or_else(|| anyhow::anyhow!("path must be an object"))?;
                    for (k, v) in p {
                        match k.as_str() {
                            "budgets" => {
                                let arr = v
                                    .as_arr()
                                    .ok_or_else(|| anyhow::anyhow!("path.budgets: array"))?;
                                cfg.path.budgets = arr
                                    .iter()
                                    .map(|x| {
                                        x.as_usize().ok_or_else(|| {
                                            anyhow::anyhow!("path.budgets entries must be integers")
                                        })
                                    })
                                    .collect::<anyhow::Result<_>>()?;
                            }
                            "rho_ladder" => {
                                let arr = v
                                    .as_arr()
                                    .ok_or_else(|| anyhow::anyhow!("path.rho_ladder: array"))?;
                                cfg.path.rho_ladder = arr
                                    .iter()
                                    .map(|x| {
                                        x.as_f64().ok_or_else(|| {
                                            anyhow::anyhow!("path.rho_ladder entries must be numbers")
                                        })
                                    })
                                    .collect::<anyhow::Result<_>>()?;
                            }
                            "warm_start" => {
                                cfg.path.warm_start = v
                                    .as_bool()
                                    .ok_or_else(|| anyhow::anyhow!("path.warm_start: bool"))?
                            }
                            "checkpoint" => {
                                cfg.path.checkpoint = Some(
                                    v.as_str()
                                        .ok_or_else(|| anyhow::anyhow!("path.checkpoint: str"))?
                                        .to_string(),
                                )
                            }
                            "direct" => {
                                cfg.path.direct = v
                                    .as_bool()
                                    .ok_or_else(|| anyhow::anyhow!("path.direct: bool"))?
                            }
                            other => anyhow::bail!("unknown path key `{other}`"),
                        }
                    }
                    // semantic validation (descending budgets etc.) is
                    // deliberately deferred to `path::run_path` / the
                    // `psfit path` command: a config may carry a partial
                    // "path" section (e.g. only a ladder) that the CLI
                    // completes, and non-path subcommands never use it
                }
                "serve" => {
                    let s = val
                        .as_obj()
                        .ok_or_else(|| anyhow::anyhow!("serve must be an object"))?;
                    for (k, v) in s {
                        match k.as_str() {
                            "state_dir" => {
                                cfg.serve.state_dir = v
                                    .as_str()
                                    .ok_or_else(|| anyhow::anyhow!("serve.state_dir: str"))?
                                    .to_string()
                            }
                            "drain_grace_ms" => {
                                cfg.serve.drain_grace_ms = v.as_usize().ok_or_else(|| {
                                    anyhow::anyhow!("serve.drain_grace_ms: int")
                                })? as u64
                            }
                            "journal" => {
                                cfg.serve.journal = v
                                    .as_bool()
                                    .ok_or_else(|| anyhow::anyhow!("serve.journal: bool"))?
                            }
                            other => anyhow::bail!("unknown serve key `{other}`"),
                        }
                    }
                }
                "loss" => {
                    cfg.loss = LossKind::parse(
                        val.as_str()
                            .ok_or_else(|| anyhow::anyhow!("loss must be a string"))?,
                    )?
                }
                "classes" => {
                    cfg.classes = val
                        .as_usize()
                        .ok_or_else(|| anyhow::anyhow!("classes must be an integer"))?
                }
                other => anyhow::bail!("unknown config key `{other}`"),
            }
        }
        cfg.solver.validate()?;
        cfg.coordinator.validate()?;
        cfg.platform.validate()?;
        cfg.validate_cross()?;
        Ok(cfg)
    }

    /// Cross-section rules no single section can check alone.  Called by
    /// [`Config::from_json`], and again by the CLI after flags overlay the
    /// file config.
    pub fn validate_cross(&self) -> anyhow::Result<()> {
        if self.solver.minibatch > 0 {
            if self.platform.backend != BackendKind::Native {
                anyhow::bail!(
                    "solver.minibatch requires the native backend \
                     (partial row spans are a native-kernel feature)"
                );
            }
            if self.coordinator.coordination != CoordinationKind::Sync {
                anyhow::bail!(
                    "solver.minibatch requires sync coordination \
                     (the chunk schedule is indexed by the global round)"
                );
            }
        }
        Ok(())
    }

    /// Serialize to a JSON object that [`Config::from_json`] parses back to
    /// an equivalent config.  The socket transport relies on this to ship
    /// the coordinator's exact settings to `psfit worker` processes, so the
    /// solver math runs from identical parameters on both sides of the wire.
    ///
    /// `path.limit` is a process-local test hook with no JSON key and is
    /// deliberately not serialized.
    pub fn to_json(&self) -> Json {
        let s = &self.solver;
        let mut solver = vec![
            ("rho_c", Json::Num(s.rho_c)),
            ("rho_b", Json::Num(s.rho_b)),
            ("rho_l", Json::Num(s.rho_l)),
            ("gamma", Json::Num(s.gamma)),
            ("kappa", Json::Num(s.kappa as f64)),
            ("max_iters", Json::Num(s.max_iters as f64)),
            ("inner_iters", Json::Num(s.inner_iters as f64)),
            ("cg_iters", Json::Num(s.cg_iters as f64)),
            ("tol_primal", Json::Num(s.tol_primal)),
            ("tol_dual", Json::Num(s.tol_dual)),
            ("tol_bilinear", Json::Num(s.tol_bilinear)),
            ("zt_iters", Json::Num(s.zt_iters as f64)),
            ("polish", Json::Bool(s.polish)),
            ("checkpoint_every", Json::Num(s.checkpoint_every as f64)),
            ("deadline_ms", Json::Num(s.deadline_ms as f64)),
            ("watchdog_window", Json::Num(s.watchdog_window as f64)),
            ("watchdog_restarts", Json::Num(s.watchdog_restarts as f64)),
            ("minibatch", Json::Num(s.minibatch as f64)),
            ("minibatch_seed", Json::Num(s.minibatch_seed as f64)),
        ];
        if !s.checkpoint.is_empty() {
            solver.push(("checkpoint", Json::Str(s.checkpoint.clone())));
        }
        let p = &self.platform;
        let mut platform = vec![
            ("nodes", Json::Num(p.nodes as f64)),
            ("devices_per_node", Json::Num(p.devices_per_node as f64)),
            ("threads", Json::Num(p.threads as f64)),
            ("sparse", Json::Str(p.sparse.name().to_string())),
            ("sparse_threshold", Json::Num(p.sparse_threshold)),
            ("backend", Json::Str(p.backend.name().to_string())),
            ("isa", Json::Str(p.isa.name().to_string())),
            ("pcie_latency_us", Json::Num(p.pcie_latency_us)),
            ("share_runtime", Json::Bool(p.share_runtime)),
            ("transport", Json::Str(p.transport.name().to_string())),
            (
                "workers",
                Json::Arr(p.workers.iter().map(|w| Json::Str(w.clone())).collect()),
            ),
            ("connect_timeout_ms", Json::Num(p.connect_timeout_ms as f64)),
            ("read_timeout_ms", Json::Num(p.read_timeout_ms as f64)),
            ("connect_retries", Json::Num(p.connect_retries as f64)),
            ("rejoin", Json::Bool(p.rejoin)),
            ("quorum", Json::Num(p.quorum as f64)),
            ("quarantine_limit", Json::Num(p.quarantine_limit as f64)),
        ];
        if let Some(gbps) = p.pcie_gbps {
            platform.push(("pcie_gbps", Json::Num(gbps)));
        }
        let c = &self.coordinator;
        let mut coordinator = vec![
            ("coordination", Json::Str(c.coordination.name().to_string())),
            ("quorum", Json::Num(c.quorum)),
            ("max_staleness", Json::Num(c.max_staleness as f64)),
            ("heartbeat_ms", Json::Num(c.heartbeat_ms as f64)),
            ("seed", Json::Num(c.faults.seed as f64)),
            ("jitter_ms", Json::Num(c.faults.jitter_ms)),
        ];
        if !c.faults.stragglers.is_empty() {
            let arr = c
                .faults
                .stragglers
                .iter()
                .map(|x| {
                    Json::obj(vec![
                        ("node", Json::Num(x.node as f64)),
                        ("delay_ms", Json::Num(x.delay_ms)),
                    ])
                })
                .collect();
            coordinator.push(("stragglers", Json::Arr(arr)));
        }
        if !c.faults.crashes.is_empty() {
            let arr = c
                .faults
                .crashes
                .iter()
                .map(|x| {
                    Json::obj(vec![
                        ("node", Json::Num(x.node as f64)),
                        ("round", Json::Num(x.round as f64)),
                    ])
                })
                .collect();
            coordinator.push(("crashes", Json::Arr(arr)));
        }
        let pa = &self.path;
        let mut path = vec![
            (
                "budgets",
                Json::Arr(pa.budgets.iter().map(|&b| Json::Num(b as f64)).collect()),
            ),
            ("rho_ladder", Json::arr_f64(&pa.rho_ladder)),
            ("warm_start", Json::Bool(pa.warm_start)),
            ("direct", Json::Bool(pa.direct)),
        ];
        if let Some(ck) = &pa.checkpoint {
            path.push(("checkpoint", Json::Str(ck.clone())));
        }
        let sv = &self.serve;
        let serve = vec![
            ("state_dir", Json::Str(sv.state_dir.clone())),
            ("drain_grace_ms", Json::Num(sv.drain_grace_ms as f64)),
            ("journal", Json::Bool(sv.journal)),
        ];
        Json::obj(vec![
            ("solver", Json::obj(solver)),
            ("platform", Json::obj(platform)),
            ("coordinator", Json::obj(coordinator)),
            ("path", Json::obj(path)),
            ("serve", Json::obj(serve)),
            ("loss", Json::Str(self.loss.name().to_string())),
            ("classes", Json::Num(self.classes as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        Config::default().solver.validate().unwrap();
    }

    #[test]
    fn alpha_rule() {
        let s = SolverConfig {
            rho_c: 4.0,
            ..Default::default()
        }
        .alpha(0.5);
        assert_eq!(s.rho_b, 2.0);
    }

    #[test]
    fn block_reg_formula() {
        let s = SolverConfig {
            rho_c: 1.5,
            gamma: 10.0,
            ..Default::default()
        };
        assert!((s.block_reg(4) - (1.0 / 40.0 + 1.5)).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrip() {
        let src = r#"{
            "solver": {"rho_c": 2.0, "kappa": 10, "polish": false},
            "platform": {"nodes": 8, "backend": "xla", "threads": 4,
                         "sparse": "always", "sparse_threshold": 0.1,
                         "isa": "scalar"},
            "loss": "logistic"
        }"#;
        let cfg = Config::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(cfg.solver.rho_c, 2.0);
        assert_eq!(cfg.solver.kappa, 10);
        assert!(!cfg.solver.polish);
        assert_eq!(cfg.platform.nodes, 8);
        assert_eq!(cfg.platform.backend, BackendKind::Xla);
        assert_eq!(cfg.platform.threads, 4);
        assert_eq!(cfg.platform.sparse, SparseMode::Always);
        assert_eq!(cfg.platform.sparse_threshold, 0.1);
        assert_eq!(
            cfg.platform.isa,
            IsaChoice::Force(crate::linalg::simd::Isa::Scalar)
        );
        assert_eq!(cfg.loss, LossKind::Logistic);
        // defaults stay serial / density-adaptive / auto-ISA
        assert_eq!(Config::default().platform.threads, 1);
        assert_eq!(Config::default().platform.sparse, SparseMode::Auto);
        assert_eq!(Config::default().platform.sparse_threshold, 0.25);
        assert_eq!(Config::default().platform.isa, IsaChoice::Auto);
    }

    #[test]
    fn unknown_keys_rejected() {
        let src = r#"{"solver": {"rho_x": 2.0}}"#;
        assert!(Config::from_json(&Json::parse(src).unwrap()).is_err());
        let src = r#"{"whatever": 1}"#;
        assert!(Config::from_json(&Json::parse(src).unwrap()).is_err());
    }

    #[test]
    fn invalid_values_rejected() {
        let src = r#"{"solver": {"rho_c": -1.0}}"#;
        assert!(Config::from_json(&Json::parse(src).unwrap()).is_err());
        for bad in [
            r#"{"platform": {"sparse": "sometimes"}}"#,
            r#"{"platform": {"sparse_threshold": 1.5}}"#,
            r#"{"platform": {"sparse_threshold": -0.1}}"#,
            r#"{"platform": {"isa": "sse9"}}"#,
            r#"{"solver": {"checkpoint": "fit.psf", "checkpoint_every": 0}}"#,
        ] {
            assert!(
                Config::from_json(&Json::parse(bad).unwrap()).is_err(),
                "accepted: {bad}"
            );
        }
    }

    #[test]
    fn coordinator_section_roundtrip() {
        let src = r#"{
            "coordinator": {
                "coordination": "async",
                "quorum": 0.75,
                "max_staleness": 2,
                "heartbeat_ms": 25,
                "seed": 9,
                "jitter_ms": 1.5,
                "stragglers": [{"node": 0, "delay_ms": 20.0}],
                "crashes": [{"node": 2, "round": 5}]
            }
        }"#;
        let cfg = Config::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(cfg.coordinator.coordination, CoordinationKind::Async);
        assert_eq!(cfg.coordinator.quorum, 0.75);
        assert_eq!(cfg.coordinator.max_staleness, 2);
        assert_eq!(cfg.coordinator.heartbeat_ms, 25);
        assert_eq!(cfg.coordinator.faults.seed, 9);
        assert_eq!(cfg.coordinator.faults.jitter_ms, 1.5);
        assert_eq!(cfg.coordinator.faults.stragglers.len(), 1);
        assert_eq!(cfg.coordinator.faults.stragglers[0].node, 0);
        assert_eq!(cfg.coordinator.faults.crashes[0].round, 5);
    }

    #[test]
    fn path_section_roundtrip() {
        let src = r#"{
            "path": {
                "budgets": [200, 100, 50],
                "rho_ladder": [2.0, 1.0, 0.5],
                "warm_start": true,
                "checkpoint": "sweep.psc",
                "direct": false
            }
        }"#;
        let cfg = Config::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(cfg.path.budgets, vec![200, 100, 50]);
        assert_eq!(cfg.path.rho_ladder, vec![2.0, 1.0, 0.5]);
        assert!(cfg.path.warm_start);
        assert_eq!(cfg.path.checkpoint.as_deref(), Some("sweep.psc"));
        assert!(!cfg.path.direct);
        // defaults: no path configured, warm + direct when one is
        let d = Config::default();
        assert!(d.path.budgets.is_empty());
        assert!(d.path.warm_start);
        assert!(d.path.direct);
    }

    #[test]
    fn path_section_rejects_bad_types_but_defers_semantics() {
        // type errors and typos fail at parse time
        for bad in [
            r#"{"path": {"budgets": [8, 4], "typo": 1}}"#,
            r#"{"path": {"budgets": "50"}}"#,
            r#"{"path": {"budgets": [8, "x"]}}"#,
            r#"{"path": {"warm_start": 1}}"#,
        ] {
            assert!(
                Config::from_json(&Json::parse(bad).unwrap()).is_err(),
                "accepted: {bad}"
            );
        }
        // semantic problems load fine (a partial section the CLI may
        // complete) and are caught by PathConfig::validate at run time
        let src = r#"{"path": {"budgets": [10, 20], "rho_ladder": [0.0]}}"#;
        let cfg = Config::from_json(&Json::parse(src).unwrap()).unwrap();
        assert!(cfg.path.validate().is_err());
        let src = r#"{"path": {"rho_ladder": [2.0, 1.0]}}"#;
        let cfg = Config::from_json(&Json::parse(src).unwrap()).unwrap();
        assert!(cfg.path.budgets.is_empty());
        assert_eq!(cfg.path.rho_ladder, vec![2.0, 1.0]);
    }

    #[test]
    fn transport_keys_roundtrip() {
        let src = r#"{
            "platform": {"transport": "socket",
                         "workers": ["127.0.0.1:7001", "unix:/tmp/w2.sock"],
                         "connect_timeout_ms": 500, "read_timeout_ms": 0,
                         "connect_retries": 5, "rejoin": true, "quorum": 2}
        }"#;
        let cfg = Config::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(cfg.platform.transport, TransportKind::Socket);
        assert_eq!(cfg.platform.workers.len(), 2);
        assert_eq!(cfg.platform.workers[1], "unix:/tmp/w2.sock");
        assert_eq!(cfg.platform.connect_timeout_ms, 500);
        assert_eq!(cfg.platform.read_timeout_ms, 0);
        assert_eq!(cfg.platform.connect_retries, 5);
        assert!(cfg.platform.rejoin);
        assert_eq!(cfg.platform.quorum, 2);
        assert!(!Config::default().platform.rejoin);
        assert_eq!(Config::default().platform.quarantine_limit, 3);
        // defaults stay in-process with sane timeouts
        let d = Config::default();
        assert_eq!(d.platform.transport, TransportKind::Local);
        assert!(d.platform.workers.is_empty());
        assert_eq!(d.platform.connect_timeout_ms, 3000);
        // bad values fail at parse/validate time
        for bad in [
            r#"{"platform": {"transport": "carrier-pigeon"}}"#,
            r#"{"platform": {"workers": [1]}}"#,
            r#"{"platform": {"transport": "socket", "connect_timeout_ms": 0}}"#,
        ] {
            assert!(
                Config::from_json(&Json::parse(bad).unwrap()).is_err(),
                "accepted: {bad}"
            );
        }
    }

    #[test]
    fn to_json_is_parsed_back_identically() {
        // exercise every branch: non-default everything, faults, path,
        // checkpoint, pcie model, socket transport
        let mut cfg = Config::default();
        cfg.solver.rho_c = 2.5;
        cfg.solver.kappa = 7;
        cfg.solver.polish = false;
        cfg.solver.checkpoint = "fit.psf".into();
        cfg.solver.checkpoint_every = 5;
        cfg.solver.deadline_ms = 1500;
        cfg.solver.watchdog_window = 12;
        cfg.solver.watchdog_restarts = 1;
        cfg.platform.nodes = 3;
        cfg.platform.rejoin = true;
        cfg.platform.quorum = 2;
        cfg.platform.quarantine_limit = 5;
        cfg.platform.threads = 2;
        cfg.platform.sparse = SparseMode::Always;
        cfg.platform.sparse_threshold = 0.5;
        cfg.platform.isa = IsaChoice::Force(crate::linalg::simd::Isa::Scalar);
        cfg.platform.pcie_gbps = Some(16.0);
        cfg.platform.transport = TransportKind::Socket;
        cfg.platform.workers = vec!["127.0.0.1:7001".into(), "127.0.0.1:7002".into()];
        cfg.platform.read_timeout_ms = 0;
        cfg.coordinator.coordination = CoordinationKind::Async;
        cfg.coordinator.quorum = 0.75;
        cfg.coordinator.max_staleness = 2;
        cfg.coordinator.faults = FaultSpec::default().straggler(0, 5.0).crash(1, 9);
        cfg.loss = LossKind::Softmax;
        cfg.classes = 4;
        cfg.path.budgets = vec![50, 20];
        cfg.path.rho_ladder = vec![2.0, 1.0];
        cfg.path.checkpoint = Some("sweep.psc".into());
        cfg.path.warm_start = false;
        cfg.serve.state_dir = "/tmp/psfit-state".into();
        cfg.serve.drain_grace_ms = 500;
        cfg.serve.journal = false;

        let text = cfg.to_json().to_string();
        let back = Config::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(format!("{:?}", back), format!("{:?}", cfg));
        // and serializing again is a fixed point
        assert_eq!(back.to_json().to_string(), text);

        // the default config round-trips too (empty fault/path arrays)
        let d = Config::default();
        let back = Config::from_json(&Json::parse(&d.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(format!("{:?}", back), format!("{:?}", d));
    }

    #[test]
    fn guardrail_keys_roundtrip() {
        let src = r#"{
            "solver": {"deadline_ms": 2000, "watchdog_window": 8,
                       "watchdog_restarts": 0},
            "platform": {"quarantine_limit": 1}
        }"#;
        let cfg = Config::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(cfg.solver.deadline_ms, 2000);
        assert_eq!(cfg.solver.watchdog_window, 8);
        assert_eq!(cfg.solver.watchdog_restarts, 0);
        assert_eq!(cfg.platform.quarantine_limit, 1);
        // defaults: no deadline, watchdog armed, three-strike quarantine
        let d = Config::default();
        assert_eq!(d.solver.deadline_ms, 0);
        assert_eq!(d.solver.watchdog_window, 25);
        assert_eq!(d.solver.watchdog_restarts, 2);
    }

    #[test]
    fn minibatch_keys_roundtrip_and_gate() {
        let src = r#"{"solver": {"minibatch": 64, "minibatch_seed": 9}}"#;
        let cfg = Config::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(cfg.solver.minibatch, 64);
        assert_eq!(cfg.solver.minibatch_seed, 9);
        // defaults: mini-batching off
        assert_eq!(Config::default().solver.minibatch, 0);
        assert_eq!(Config::default().solver.minibatch_seed, 0);
        // the window is native-backend + sync-coordination only
        for bad in [
            r#"{"solver": {"minibatch": 64}, "platform": {"backend": "xla"}}"#,
            r#"{"solver": {"minibatch": 64},
                "coordinator": {"coordination": "async"}}"#,
        ] {
            assert!(
                Config::from_json(&Json::parse(bad).unwrap()).is_err(),
                "accepted: {bad}"
            );
        }
        // minibatch == 0 is compatible with everything
        let src = r#"{"platform": {"backend": "xla"}}"#;
        assert!(Config::from_json(&Json::parse(src).unwrap()).is_ok());
    }

    #[test]
    fn serve_section_roundtrip() {
        let src = r#"{
            "serve": {"state_dir": "/var/lib/psfit", "drain_grace_ms": 250,
                      "journal": false}
        }"#;
        let cfg = Config::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(cfg.serve.state_dir, "/var/lib/psfit");
        assert_eq!(cfg.serve.drain_grace_ms, 250);
        assert!(!cfg.serve.journal);
        // defaults: in-memory daemon, 10 s grace, journaling on
        let d = Config::default();
        assert!(d.serve.state_dir.is_empty());
        assert_eq!(d.serve.drain_grace_ms, 10_000);
        assert!(d.serve.journal);
        for bad in [
            r#"{"serve": {"state_dir": 7}}"#,
            r#"{"serve": {"journal": "yes"}}"#,
            r#"{"serve": {"typo": 1}}"#,
        ] {
            assert!(
                Config::from_json(&Json::parse(bad).unwrap()).is_err(),
                "accepted: {bad}"
            );
        }
    }

    #[test]
    fn coordinator_validation_rejects_bad_values() {
        for bad in [
            r#"{"coordinator": {"quorum": 0.0}}"#,
            r#"{"coordinator": {"quorum": 1.5}}"#,
            r#"{"coordinator": {"heartbeat_ms": 0}}"#,
            r#"{"coordinator": {"coordination": "gossip"}}"#,
            r#"{"coordinator": {"typo_key": 1}}"#,
            r#"{"coordinator": {"stragglers": [{"node": 0, "delay_ms": -2.0}]}}"#,
        ] {
            assert!(
                Config::from_json(&Json::parse(bad).unwrap()).is_err(),
                "accepted: {bad}"
            );
        }
        let mut c = CoordinatorConfig::default();
        c.validate().unwrap();
        c.quorum = 0.5;
        c.max_staleness = 3;
        c.validate().unwrap();
    }
}

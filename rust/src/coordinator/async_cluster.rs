//! Event-driven async cluster: threads + channels around the scheduler.
//!
//! One OS thread per node (like `network::ThreadedCluster`) but the
//! barrier is partial: `round` commits as soon as the scheduler's quorum
//! of replies has landed, folds bounded-stale replies from stragglers,
//! resyncs nodes that fall too far behind, and degrades the shard of any
//! node whose channel is gone (crash).  The [`super::fault::FaultInjector`]
//! runs *inside* the worker threads, so seeded straggler/crash scenarios
//! exercise the real wire protocol.
//!
//! Liveness: a node's death is detected either eagerly (a broadcast to it
//! fails) or lazily (the collect loop times out on `heartbeat` and probes
//! every busy node with a ping — a failed ping send means the worker's
//! receiver is gone).  Because each node has at most one outstanding
//! broadcast, a live-but-slow node can always be told apart from a dead
//! one without wall-clock guesswork.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use super::fault::FaultInjector;
use super::scheduler::{ReplyAction, RoundScheduler};
use crate::backend::BlockParams;
use crate::config::CoordinatorConfig;
use crate::metrics::{CoordinationStats, TransferLedger};
use crate::network::{refresh_payload, Cluster, NodeReply, NodeWorker, WarmState};

enum Command {
    Round { round: usize, z: Arc<Vec<f64>> },
    Ping,
    Loss,
    Ledger,
    Export,
    Reseed(Arc<Vec<WarmState>>, BlockParams),
    Stop,
}

enum Reply {
    Round {
        node: usize,
        round: usize,
        x: Vec<f64>,
        u: Vec<f64>,
    },
    Loss {
        node: usize,
        value: f64,
    },
    Ledger {
        node: usize,
        ledger: TransferLedger,
    },
    Warm {
        node: usize,
        state: Box<WarmState>,
    },
    Reseeded {
        node: usize,
        ok: bool,
    },
}

struct NodeLink {
    sender: Option<mpsc::Sender<Command>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

fn spawn_worker(
    mut w: NodeWorker,
    rx: mpsc::Receiver<Command>,
    out: mpsc::Sender<Reply>,
    fault: FaultInjector,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let node = w.id;
        while let Ok(cmd) = rx.recv() {
            match cmd {
                Command::Round { round, z } => {
                    if fault.crashes_at(node, round) {
                        // simulated crash: drop the receiver and vanish
                        return;
                    }
                    let (x, u) = w.round(&z);
                    let delay = fault.delay(node, round);
                    if delay > Duration::ZERO {
                        std::thread::sleep(delay);
                    }
                    if out.send(Reply::Round { node, round, x, u }).is_err() {
                        return;
                    }
                }
                Command::Ping => {} // liveness probe: receipt is the answer
                Command::Loss => {
                    let value = w.loss_value();
                    if out.send(Reply::Loss { node, value }).is_err() {
                        return;
                    }
                }
                Command::Ledger => {
                    let ledger = w.ledger();
                    if out.send(Reply::Ledger { node, ledger }).is_err() {
                        return;
                    }
                }
                Command::Export => {
                    let state = Box::new(w.export_warm());
                    if out.send(Reply::Warm { node, state }).is_err() {
                        return;
                    }
                }
                Command::Reseed(states, params) => {
                    let ok = match states.iter().find(|s| s.node == w.id) {
                        Some(ws) => {
                            w.reseed(ws, params);
                            true
                        }
                        None => false,
                    };
                    if out.send(Reply::Reseeded { node, ok }).is_err() {
                        return;
                    }
                }
                Command::Stop => return,
            }
        }
    })
}

/// Partial-barrier cluster: one thread per node, quorum commits, bounded
/// staleness, elastic membership, seeded fault injection.
pub struct AsyncCluster {
    links: Vec<NodeLink>,
    reply_tx: mpsc::Sender<Reply>,
    replies: mpsc::Receiver<Reply>,
    scheduler: RoundScheduler,
    injector: FaultInjector,
    heartbeat: Duration,
    current_z: Option<Arc<Vec<f64>>>,
}

impl AsyncCluster {
    /// Spawn one worker thread per node under the given coordination
    /// settings (quorum, staleness bound, heartbeat, fault model).
    pub fn new(workers: Vec<NodeWorker>, dim: usize, cfg: &CoordinatorConfig) -> AsyncCluster {
        let n = workers.len();
        let injector = FaultInjector::new(cfg.faults.clone());
        let (reply_tx, replies) = mpsc::channel::<Reply>();
        let mut links = Vec::with_capacity(n);
        for w in workers {
            let (tx, rx) = mpsc::channel::<Command>();
            let handle = spawn_worker(w, rx, reply_tx.clone(), injector.clone());
            links.push(NodeLink {
                sender: Some(tx),
                handle: Some(handle),
            });
        }
        AsyncCluster {
            links,
            reply_tx,
            replies,
            scheduler: RoundScheduler::new(n, dim, cfg.quorum, cfg.max_staleness),
            injector,
            heartbeat: Duration::from_millis(cfg.heartbeat_ms.max(1)),
            current_z: None,
        }
    }

    /// Protocol accounting so far.
    pub fn stats(&self) -> &CoordinationStats {
        &self.scheduler.stats
    }

    /// Node ids whose shards are degraded (dead members).
    pub fn degraded(&self) -> Vec<usize> {
        self.scheduler.membership.degraded()
    }

    /// Elastically add a node mid-solve.  The worker's id is rewritten to
    /// the next roster slot; it is primed with the current z (resync
    /// traffic) and becomes a full quorum member on its first reply.
    pub fn join(&mut self, mut worker: NodeWorker) -> usize {
        let id = self.scheduler.register_join();
        worker.id = id;
        let (tx, rx) = mpsc::channel::<Command>();
        let handle = spawn_worker(worker, rx, self.reply_tx.clone(), self.injector.clone());
        self.links.push(NodeLink {
            sender: Some(tx),
            handle: Some(handle),
        });
        if let Some(z) = self.current_z.clone() {
            let round = self.scheduler.current_round();
            self.push_z(id, round, z, true);
        }
        id
    }

    /// Gracefully remove a node (its shard leaves the consensus).
    pub fn leave(&mut self, node: usize) {
        if let Some(tx) = &self.links[node].sender {
            let _ = tx.send(Command::Stop);
        }
        self.scheduler.remove(node);
        self.links[node].sender = None;
        if let Some(h) = self.links[node].handle.take() {
            let _ = h.join();
        }
    }

    /// Send z to one node; on a dead channel, degrade the node instead.
    fn push_z(&mut self, node: usize, round: usize, z: Arc<Vec<f64>>, resync: bool) {
        let ok = match &self.links[node].sender {
            Some(tx) => tx.send(Command::Round { round, z }).is_ok(),
            None => false,
        };
        if ok {
            if resync {
                self.scheduler.on_resync_sent(node);
            } else {
                self.scheduler.on_sent(node);
            }
        } else {
            self.reap(node);
        }
    }

    /// Degrade a node whose channel is gone and reclaim its thread.
    fn reap(&mut self, node: usize) {
        self.scheduler.on_send_failed(node);
        self.links[node].sender = None;
        if let Some(h) = self.links[node].handle.take() {
            let _ = h.join();
        }
    }

    /// Ping `node`: a failed send means the worker's receiver is gone, so
    /// reap it.  Returns whether the node is still alive.  The single
    /// liveness primitive — round laggard checks, collect-loop probes,
    /// and query pruning all go through here.
    fn ping_or_reap(&mut self, node: usize) -> bool {
        let alive = match &self.links[node].sender {
            Some(tx) => tx.send(Command::Ping).is_ok(),
            None => false,
        };
        if !alive {
            self.reap(node);
        }
        alive
    }

    /// Ping every busy node; a failed send unmasks a silent crash.
    fn probe(&mut self) {
        for node in 0..self.links.len() {
            if self.scheduler.is_busy(node) && self.scheduler.membership.is_reachable(node) {
                self.ping_or_reap(node);
            }
        }
    }

    /// Drop any pending-query nodes whose channels turn out to be dead.
    fn prune_dead(&mut self, pending: &mut Vec<usize>) {
        for node in pending.clone() {
            if !self.ping_or_reap(node) {
                pending.retain(|&n| n != node);
            }
        }
    }
}

impl Cluster for AsyncCluster {
    fn nodes(&self) -> usize {
        self.scheduler.membership.len()
    }

    fn round(&mut self, z: &[f64]) -> anyhow::Result<Vec<NodeReply>> {
        // one shared payload per round; refilled in place when no
        // straggler still holds last round's copy
        let (payload, reused) = refresh_payload(&mut self.current_z, z);
        if reused {
            self.scheduler.net.net_alloc_saved_bytes += (z.len() * 8) as u64;
        }
        let (k, targets) = self.scheduler.begin_round();
        for node in targets {
            self.push_z(node, k, payload.clone(), false);
        }
        // a node still owing an older round's reply is either slow or
        // silently dead — a ping on its channel tells the two apart
        for node in self.scheduler.laggards() {
            self.ping_or_reap(node);
        }
        let mut collected = 0usize;
        while collected < self.scheduler.quorum_needed() {
            anyhow::ensure!(
                !self.scheduler.membership.reachable_nodes().is_empty(),
                "round {k}: every node is dead or departed"
            );
            match self.replies.recv_timeout(self.heartbeat) {
                Ok(Reply::Round { node, round, x, u }) => {
                    match self.scheduler.on_reply(node, round, x, u) {
                        ReplyAction::Fresh | ReplyAction::Folded { .. } => collected += 1,
                        ReplyAction::Dropped { .. } => {
                            // beyond the staleness bound: resync with the
                            // freshest z so the straggler does useful work
                            self.push_z(node, k, payload.clone(), true);
                        }
                        ReplyAction::Ignored => {}
                    }
                }
                Ok(_) => {} // stale loss/ledger responses: not part of a round
                Err(mpsc::RecvTimeoutError::Timeout) => self.probe(),
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("round {k}: all node workers disconnected");
                }
            }
        }
        let replies = self.scheduler.collect();
        anyhow::ensure!(
            !replies.is_empty(),
            "round {k}: no replies within the staleness bound"
        );
        Ok(replies)
    }

    fn loss_value(&mut self) -> anyhow::Result<f64> {
        let mut pending = Vec::new();
        for node in self.scheduler.membership.reachable_nodes() {
            let ok = match &self.links[node].sender {
                Some(tx) => tx.send(Command::Loss).is_ok(),
                None => false,
            };
            if ok {
                pending.push(node);
            } else {
                self.reap(node);
            }
        }
        let mut total = 0.0;
        while !pending.is_empty() {
            match self.replies.recv_timeout(self.heartbeat) {
                Ok(Reply::Loss { node, value }) => {
                    if pending.contains(&node) {
                        pending.retain(|&n| n != node);
                        total += value;
                    }
                }
                Ok(Reply::Round { node, .. }) => {
                    // a straggler's reply surfacing after the last round:
                    // free its slot, but no global update will consume it
                    self.scheduler.on_stray_reply(node);
                }
                Ok(Reply::Ledger { .. }) => {}
                Err(mpsc::RecvTimeoutError::Timeout) => self.prune_dead(&mut pending),
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("all node workers disconnected during the loss query");
                }
            }
        }
        Ok(total)
    }

    fn ledger(&mut self) -> TransferLedger {
        let mut total = self.scheduler.net.clone();
        let mut pending = Vec::new();
        for node in self.scheduler.membership.reachable_nodes() {
            let ok = match &self.links[node].sender {
                Some(tx) => tx.send(Command::Ledger).is_ok(),
                None => false,
            };
            if ok {
                pending.push(node);
            } else {
                self.reap(node);
            }
        }
        while !pending.is_empty() {
            match self.replies.recv_timeout(self.heartbeat) {
                Ok(Reply::Ledger { node, ledger }) => {
                    if pending.contains(&node) {
                        pending.retain(|&n| n != node);
                        total.merge(&ledger);
                    }
                }
                Ok(Reply::Round { node, .. }) => {
                    self.scheduler.on_stray_reply(node);
                }
                Ok(Reply::Loss { .. }) => {}
                Err(mpsc::RecvTimeoutError::Timeout) => self.prune_dead(&mut pending),
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        total
    }

    fn coordination(&self) -> Option<CoordinationStats> {
        Some(self.scheduler.stats.clone())
    }

    /// Best-effort warm export over the *reachable* roster.  Commands
    /// queue behind any in-flight round on each node, so the snapshot is
    /// taken after the node finishes its outstanding work; stray round
    /// replies surfacing meanwhile free their slots without folding.
    fn export_warm(&mut self) -> anyhow::Result<Vec<WarmState>> {
        let mut pending = Vec::new();
        for node in self.scheduler.membership.reachable_nodes() {
            let ok = match &self.links[node].sender {
                Some(tx) => tx.send(Command::Export).is_ok(),
                None => false,
            };
            if ok {
                pending.push(node);
            } else {
                self.reap(node);
            }
        }
        anyhow::ensure!(!pending.is_empty(), "no reachable node to export from");
        let mut out: Vec<WarmState> = Vec::with_capacity(pending.len());
        while !pending.is_empty() {
            match self.replies.recv_timeout(self.heartbeat) {
                Ok(Reply::Warm { node, state }) => {
                    if pending.contains(&node) {
                        pending.retain(|&n| n != node);
                        out.push(*state);
                    }
                }
                Ok(Reply::Round { node, .. }) => {
                    self.scheduler.on_stray_reply(node);
                }
                Ok(_) => {}
                Err(mpsc::RecvTimeoutError::Timeout) => self.prune_dead(&mut pending),
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("all node workers disconnected during the warm-state export");
                }
            }
        }
        out.sort_by_key(|s| s.node);
        Ok(out)
    }

    fn reseed(&mut self, states: &[WarmState], params: BlockParams) -> anyhow::Result<()> {
        let shared = Arc::new(states.to_vec());
        let mut pending = Vec::new();
        for node in self.scheduler.membership.reachable_nodes() {
            let ok = match &self.links[node].sender {
                Some(tx) => tx.send(Command::Reseed(shared.clone(), params)).is_ok(),
                None => false,
            };
            if ok {
                pending.push(node);
            } else {
                self.reap(node);
            }
        }
        anyhow::ensure!(!pending.is_empty(), "no reachable node to re-seed");
        while !pending.is_empty() {
            match self.replies.recv_timeout(self.heartbeat) {
                Ok(Reply::Reseeded { node, ok }) => {
                    if pending.contains(&node) {
                        pending.retain(|&n| n != node);
                        anyhow::ensure!(ok, "no warm state for node {node}");
                    }
                }
                Ok(Reply::Round { node, .. }) => {
                    self.scheduler.on_stray_reply(node);
                }
                Ok(_) => {}
                Err(mpsc::RecvTimeoutError::Timeout) => self.prune_dead(&mut pending),
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("all node workers disconnected during the re-seed");
                }
            }
        }
        Ok(())
    }
}

impl Drop for AsyncCluster {
    fn drop(&mut self) {
        for link in &mut self.links {
            link.sender = None; // closes channels; workers exit their loops
        }
        for link in &mut self.links {
            if let Some(h) = link.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::admm::LocalProx;
    use crate::backend::native::{NativeBackend, SolveMode};
    use crate::backend::BlockParams;
    use crate::coordinator::fault::FaultSpec;
    use crate::data::{FeaturePlan, SyntheticSpec};
    use crate::losses::Squared;
    use crate::network::SequentialCluster;

    fn make_workers(nodes: usize) -> (Vec<NodeWorker>, usize) {
        let ds = SyntheticSpec::regression(12, 40 * nodes, nodes).generate();
        let plan = FeaturePlan::new(12, 2, 512);
        let params = BlockParams {
            rho_l: 2.0,
            rho_c: 1.0,
            reg: 1.0 / (nodes as f64 * 10.0) + 1.0,
        };
        let workers = ds
            .shards
            .iter()
            .enumerate()
            .map(|(i, shard)| {
                let be = NativeBackend::new(shard, &plan, Box::new(Squared), SolveMode::Direct);
                NodeWorker::new(i, LocalProx::new(Box::new(be), plan.clone(), 1), params, 10)
            })
            .collect();
        (workers, 12)
    }

    fn full_barrier_cfg() -> CoordinatorConfig {
        CoordinatorConfig {
            coordination: crate::config::CoordinationKind::Async,
            quorum: 1.0,
            max_staleness: 0,
            heartbeat_ms: 25,
            faults: FaultSpec::default(),
        }
    }

    #[test]
    fn full_barrier_async_matches_sequential_bit_for_bit() {
        let (w1, dim) = make_workers(3);
        let (w2, _) = make_workers(3);
        let mut seq = SequentialCluster::new(w1, dim);
        let mut asy = AsyncCluster::new(w2, dim, &full_barrier_cfg());
        let z = vec![0.05; dim];
        for k in 0..3 {
            let a = seq.round(&z).unwrap();
            let b = asy.round(&z).unwrap();
            assert_eq!(a.len(), b.len());
            for (ra, rb) in a.iter().zip(&b) {
                assert_eq!(ra.node, rb.node);
                assert_eq!(rb.round, k, "full barrier replies must be fresh");
                assert_eq!(ra.x, rb.x, "x must match bit-for-bit");
                assert_eq!(ra.u, rb.u, "u must match bit-for-bit");
            }
        }
        let stats = asy.coordination().unwrap();
        assert_eq!(stats.rounds, 3);
        assert_eq!(stats.drops, 0);
        assert_eq!(stats.resyncs, 0);
        assert_eq!(stats.staleness_hist, vec![9], "3 rounds x 3 nodes, lag 0");
        let dl = (seq.loss_value().unwrap() - asy.loss_value().unwrap()).abs();
        assert!(dl < 1e-12, "loss drifted by {dl}");
    }

    #[test]
    fn crash_mid_run_degrades_the_shard_and_rounds_continue() {
        let (workers, dim) = make_workers(3);
        let cfg = CoordinatorConfig {
            coordination: crate::config::CoordinationKind::Async,
            quorum: 0.6,
            max_staleness: 1,
            heartbeat_ms: 10,
            faults: FaultSpec::default().crash(2, 2),
        };
        let mut cluster = AsyncCluster::new(workers, dim, &cfg);
        let z = vec![0.0; dim];
        for _ in 0..6 {
            let replies = cluster.round(&z).unwrap();
            assert!(!replies.is_empty());
        }
        assert_eq!(cluster.degraded(), vec![2], "node 2 must be degraded");
        // the dead shard must no longer appear in round snapshots
        let replies = cluster.round(&z).unwrap();
        assert!(replies.iter().all(|r| r.node != 2));
        assert_eq!(cluster.coordination().unwrap().deaths, 1);
        // loss and ledger remain answerable on the quorum
        let _ = cluster.loss_value().unwrap();
        let ledger = cluster.ledger();
        assert!(ledger.net_down_bytes > 0);
    }

    #[test]
    fn elastic_join_and_leave_mid_run() {
        let (workers, dim) = make_workers(2);
        let (mut extra, _) = make_workers(3);
        let cfg = full_barrier_cfg();
        let mut cluster = AsyncCluster::new(workers, dim, &cfg);
        let z = vec![0.01; dim];
        cluster.round(&z).unwrap();

        // join node: primed via resync, counted after its first reply
        let id = cluster.join(extra.pop().unwrap());
        assert_eq!(id, 2);
        let mut saw_three = false;
        for _ in 0..4 {
            let replies = cluster.round(&z).unwrap();
            if replies.len() == 3 {
                saw_three = true;
            }
        }
        assert!(saw_three, "joined node never reached the snapshot");
        let stats = cluster.coordination().unwrap();
        assert_eq!(stats.joins, 1);
        assert!(stats.resyncs >= 1, "join must be primed via resync");

        // graceful leave shrinks the roster again
        cluster.leave(id);
        let replies = cluster.round(&z).unwrap();
        assert!(replies.iter().all(|r| r.node != id));
        assert!(cluster.degraded().is_empty(), "leave is not a failure");
    }
}

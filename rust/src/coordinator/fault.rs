//! Deterministic fault injection for the coordination layer.
//!
//! The async coordinator is only worth having if stragglers and crashes
//! are testable without real machines, so faults are a *model*, not an
//! accident: a [`FaultSpec`] names which nodes are slow (fixed per-round
//! delay plus optional seeded jitter) and which nodes crash at which
//! round, and a [`FaultInjector`] evaluates that model as a pure function
//! of `(node, round)`.  Two injectors built from the same spec agree on
//! every decision, so failure scenarios reproduce bit-exactly.

use std::time::Duration;

use crate::util::rng::Rng;

/// A node that takes extra wall-clock time per round.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StragglerSpec {
    /// Which node is slow.
    pub node: usize,
    /// Extra milliseconds added to every round this node computes.
    pub delay_ms: f64,
}

/// A node that dies when it picks up work for `round` (or any later one).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CrashSpec {
    /// Which node dies.
    pub node: usize,
    /// First round at which picking up work kills it.
    pub round: usize,
}

/// The full failure model for one run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultSpec {
    /// Seed for the jitter stream (delays are deterministic given it).
    pub seed: u64,
    /// Uniform jitter in [0, jitter_ms) added on top of straggler delays.
    pub jitter_ms: f64,
    /// Slow nodes.
    pub stragglers: Vec<StragglerSpec>,
    /// Crashing nodes.
    pub crashes: Vec<CrashSpec>,
}

impl FaultSpec {
    /// True when the model injects nothing (the healthy-cluster default).
    pub fn is_empty(&self) -> bool {
        self.stragglers.is_empty() && self.crashes.is_empty() && self.jitter_ms == 0.0
    }

    /// Builder: slow `node` down by `delay_ms` per round.
    pub fn straggler(mut self, node: usize, delay_ms: f64) -> FaultSpec {
        self.stragglers.push(StragglerSpec { node, delay_ms });
        self
    }

    /// Builder: kill `node` when it starts work for `round`.
    pub fn crash(mut self, node: usize, round: usize) -> FaultSpec {
        self.crashes.push(CrashSpec { node, round });
        self
    }

    /// Reject negative delays/jitter.
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.jitter_ms < 0.0 {
            anyhow::bail!("fault jitter_ms must be >= 0");
        }
        for s in &self.stragglers {
            if s.delay_ms.is_nan() || s.delay_ms < 0.0 {
                anyhow::bail!("straggler delay_ms must be >= 0 (node {})", s.node);
            }
        }
        Ok(())
    }
}

/// Evaluates a [`FaultSpec`]; cloned into every node worker thread.
#[derive(Clone, Debug)]
pub struct FaultInjector {
    spec: FaultSpec,
}

impl FaultInjector {
    /// Wrap a spec for evaluation.
    pub fn new(spec: FaultSpec) -> FaultInjector {
        FaultInjector { spec }
    }

    /// The model being evaluated.
    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// Does `node` die when it picks up work for `round`?
    pub fn crashes_at(&self, node: usize, round: usize) -> bool {
        self.spec
            .crashes
            .iter()
            .any(|c| c.node == node && round >= c.round)
    }

    /// Injected extra compute time for `(node, round)` — a pure function
    /// of the spec, so repeated queries (and re-built injectors) agree.
    pub fn delay(&self, node: usize, round: usize) -> Duration {
        let base: f64 = self
            .spec
            .stragglers
            .iter()
            .filter(|s| s.node == node)
            .map(|s| s.delay_ms)
            .sum();
        let jitter = if self.spec.jitter_ms > 0.0 {
            // stateless per-(node, round) stream: hash the coordinates
            // into a seed so the draw does not depend on query order
            let mix = self
                .spec
                .seed
                .wrapping_add((node as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
                .wrapping_add((round as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F));
            Rng::seed_from(mix).uniform() * self.spec.jitter_ms
        } else {
            0.0
        };
        let total_ms = base + jitter;
        if total_ms <= 0.0 {
            Duration::ZERO
        } else {
            Duration::from_secs_f64(total_ms / 1e3)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_spec_injects_nothing() {
        let inj = FaultInjector::new(FaultSpec::default());
        assert!(inj.spec().is_empty());
        for node in 0..4 {
            for round in 0..8 {
                assert_eq!(inj.delay(node, round), Duration::ZERO);
                assert!(!inj.crashes_at(node, round));
            }
        }
    }

    #[test]
    fn straggler_delay_is_deterministic_and_targeted() {
        let spec = FaultSpec {
            seed: 11,
            jitter_ms: 3.0,
            ..Default::default()
        }
        .straggler(1, 20.0);
        let a = FaultInjector::new(spec.clone());
        let b = FaultInjector::new(spec);
        for round in 0..16 {
            assert_eq!(a.delay(1, round), b.delay(1, round));
            let d = a.delay(1, round).as_secs_f64() * 1e3;
            assert!((20.0..23.0).contains(&d), "delay {d} ms");
            // non-straggler nodes see jitter only
            let d0 = a.delay(0, round).as_secs_f64() * 1e3;
            assert!((0.0..3.0).contains(&d0), "jitter {d0} ms");
        }
    }

    #[test]
    fn crash_fires_at_and_after_its_round() {
        let inj = FaultInjector::new(FaultSpec::default().crash(2, 5));
        assert!(!inj.crashes_at(2, 4));
        assert!(inj.crashes_at(2, 5));
        assert!(inj.crashes_at(2, 9));
        assert!(!inj.crashes_at(1, 9));
    }

    #[test]
    fn validate_rejects_negative_delays() {
        assert!(FaultSpec::default().straggler(0, -1.0).validate().is_err());
        let bad = FaultSpec {
            jitter_ms: -0.5,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        assert!(FaultSpec::default().straggler(0, 5.0).validate().is_ok());
    }
}

//! Elastic cluster membership for the async coordinator.
//!
//! The roster is an append-only table of node slots (slot index == node
//! id, so shard ownership never moves).  Slots step through a small state
//! machine:
//!
//! ```text
//!           join()                 first reply
//! (new) ----------> Joining ---------------------> Active
//!                      |                             |
//!                      | crash / send failure        | crash / send failure
//!                      v                             v
//!                    Dead  <------------------------+        leave() -> Left
//! ```
//!
//! `Dead` marks the shard *degraded*: the solve continues on the quorum of
//! the remaining actives, which is the whole point of the partial barrier.

/// Lifecycle state of one node slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeState {
    /// Participating: receives broadcasts, counts toward quorum.
    Active,
    /// Joined mid-solve; receives broadcasts but does not count toward the
    /// quorum denominator until its first reply lands.
    Joining,
    /// Crashed or unreachable — its shard is degraded.
    Dead,
    /// Gracefully removed via `leave`.
    Left,
}

/// The coordinator's membership table.
#[derive(Clone, Debug)]
pub struct Membership {
    states: Vec<NodeState>,
}

impl Membership {
    /// Fresh roster of `nodes` active slots.
    pub fn new(nodes: usize) -> Membership {
        Membership {
            states: vec![NodeState::Active; nodes],
        }
    }

    /// Total slots ever allocated (including dead/left ones).
    pub fn len(&self) -> usize {
        self.states.len()
    }

    /// True when no slot was ever allocated.
    pub fn is_empty(&self) -> bool {
        self.states.is_empty()
    }

    /// Current lifecycle state of a slot.
    pub fn state(&self, node: usize) -> NodeState {
        self.states[node]
    }

    /// Counts toward the quorum denominator.
    pub fn is_active(&self, node: usize) -> bool {
        self.states[node] == NodeState::Active
    }

    /// Should receive broadcasts (Active or Joining).
    pub fn is_reachable(&self, node: usize) -> bool {
        matches!(self.states[node], NodeState::Active | NodeState::Joining)
    }

    /// Number of Active slots (the quorum denominator).
    pub fn active_count(&self) -> usize {
        self.states
            .iter()
            .filter(|s| **s == NodeState::Active)
            .count()
    }

    /// Slots that should receive broadcasts, in id order.
    pub fn reachable_nodes(&self) -> Vec<usize> {
        (0..self.states.len())
            .filter(|&i| self.is_reachable(i))
            .collect()
    }

    /// Replies required before the coordinator commits a round: a fraction
    /// of the *active* roster, at least one.
    pub fn quorum_needed(&self, quorum_frac: f64) -> usize {
        let frac = quorum_frac.clamp(0.0, 1.0);
        let need = (frac * self.active_count() as f64).ceil() as usize;
        need.max(1)
    }

    /// Mark a node dead (crash detected); returns true on a fresh death so
    /// callers can count it once.
    pub fn mark_dead(&mut self, node: usize) -> bool {
        if matches!(self.states[node], NodeState::Dead | NodeState::Left) {
            return false;
        }
        self.states[node] = NodeState::Dead;
        true
    }

    /// Promote a Joining node after its first reply.
    pub fn mark_active(&mut self, node: usize) {
        if self.states[node] == NodeState::Joining {
            self.states[node] = NodeState::Active;
        }
    }

    /// Allocate a slot for an elastically-joining node.
    pub fn join(&mut self) -> usize {
        self.states.push(NodeState::Joining);
        self.states.len() - 1
    }

    /// Gracefully remove a node.
    pub fn leave(&mut self, node: usize) {
        self.states[node] = NodeState::Left;
    }

    /// Node ids whose shards are degraded (dead members).
    pub fn degraded(&self) -> Vec<usize> {
        (0..self.states.len())
            .filter(|&i| self.states[i] == NodeState::Dead)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quorum_tracks_active_count() {
        let mut m = Membership::new(4);
        assert_eq!(m.quorum_needed(1.0), 4);
        assert_eq!(m.quorum_needed(0.5), 2);
        assert_eq!(m.quorum_needed(0.6), 3); // ceil(2.4)
        assert!(m.mark_dead(3));
        assert!(!m.mark_dead(3), "second death must not double-count");
        assert_eq!(m.active_count(), 3);
        assert_eq!(m.quorum_needed(1.0), 3);
        assert_eq!(m.degraded(), vec![3]);
        // quorum never drops to zero
        m.mark_dead(0);
        m.mark_dead(1);
        m.mark_dead(2);
        assert_eq!(m.quorum_needed(0.5), 1);
    }

    #[test]
    fn join_is_reachable_but_not_counted_until_first_reply() {
        let mut m = Membership::new(2);
        let id = m.join();
        assert_eq!(id, 2);
        assert_eq!(m.state(id), NodeState::Joining);
        assert!(m.is_reachable(id));
        assert!(!m.is_active(id));
        assert_eq!(m.quorum_needed(1.0), 2);
        m.mark_active(id);
        assert!(m.is_active(id));
        assert_eq!(m.quorum_needed(1.0), 3);
    }

    #[test]
    fn leave_removes_from_everything() {
        let mut m = Membership::new(3);
        m.leave(1);
        assert_eq!(m.state(1), NodeState::Left);
        assert!(!m.is_reachable(1));
        assert_eq!(m.active_count(), 2);
        assert_eq!(m.reachable_nodes(), vec![0, 2]);
        assert!(m.degraded().is_empty(), "leave is not a failure");
    }
}

//! The coordination subsystem — the paper's L3 (global coordinator)
//! layer, grown from a stub into a real distributed-systems component.
//!
//! The paper's Algorithm 1 runs a strict full barrier: the coordinator
//! broadcasts z, then blocks for all N `(x_i, u_i)` replies, so the
//! slowest node gates every iteration.  This subsystem implements the
//! partial-barrier alternative of Zhu et al. (arXiv:1802.08882) and the
//! multi-block analysis of Deng et al. (arXiv:1312.3040): commit a global
//! update once a **quorum fraction** of active nodes has replied, fold
//! late replies in with **bounded staleness**, and resync any node that
//! falls further behind.  Membership is **elastic** — nodes can join or
//! leave mid-solve, and a crashed node's shard is marked degraded while
//! the fit continues on the quorum.
//!
//! Layout (see DESIGN.md §Coordinator-subsystem):
//!
//!   * [`scheduler`]  — the pure round state machine: dispatch, quorum,
//!     staleness policy, and per-decision byte accounting
//!   * [`membership`] — the elastic roster (Active / Joining / Dead / Left)
//!   * [`fault`]      — deterministic, seeded straggler + crash models so
//!     failure scenarios are testable without real machines
//!   * [`async_cluster`] — the event-driven transport shell (threads +
//!     channels) implementing [`crate::network::Cluster`]
//!
//! Convergence guardrail: with `quorum = 1.0` and `max_staleness = 0` the
//! async scheduler degenerates to a full barrier and reproduces
//! [`crate::network::SequentialCluster`] **bit-for-bit** (pinned by the
//! parity tests in `tests/coordinator.rs`).

/// The event-driven transport shell (threads + channels).
pub mod async_cluster;
/// Deterministic, seeded straggler + crash models.
pub mod fault;
/// The elastic roster (Active / Joining / Dead / Left).
pub mod membership;
/// The pure round state machine: dispatch, quorum, staleness.
pub mod scheduler;

pub use async_cluster::AsyncCluster;
pub use fault::{CrashSpec, FaultInjector, FaultSpec, StragglerSpec};
pub use membership::{Membership, NodeState};
pub use scheduler::{ReplyAction, RoundScheduler};

//! The coordinator's round scheduler — a pure state machine.
//!
//! All partial-barrier policy lives here, with no threads or channels, so
//! every protocol decision is unit-testable: which nodes get the next
//! broadcast, whether an arriving reply is fresh / folded (late but within
//! the staleness bound) / dropped (too stale -> resync), when the quorum
//! is satisfied, and the exact byte accounting of each decision (round
//! broadcasts, resync broadcasts, and replies are ledgered separately).
//! [`super::AsyncCluster`] is a thin transport shell around this type.
//!
//! The protocol follows Zhu et al.'s block-wise async consensus ADMM
//! (arXiv:1802.08882): the coordinator keeps the last reply it folded from
//! every node and commits a global update as soon as a quorum fraction of
//! the active roster has replied; each node has at most one outstanding
//! broadcast, so a straggler is simply re-dispatched with the *current* z
//! whenever it surfaces, rather than queueing up stale work.

use super::membership::{Membership, NodeState};
use crate::metrics::{CoordinationStats, TransferLedger};
use crate::network::NodeReply;

/// Per-node dispatch state: at most one outstanding broadcast.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Dispatch {
    Idle,
    /// Owes a reply for the given round's broadcast.
    Busy(usize),
}

#[derive(Clone, Debug)]
struct CachedReply {
    x: Vec<f64>,
    u: Vec<f64>,
    round: usize,
}

/// What the scheduler decided about an arriving reply.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplyAction {
    /// Computed against the current round's z.
    Fresh,
    /// Late but within the staleness bound — folded into the cache.
    Folded { lag: usize },
    /// Beyond `max_staleness`: discarded; the node needs a resync.
    Dropped { lag: usize },
    /// From a dead or departed node; ignored entirely.
    Ignored,
}

/// The pure round state machine of the async coordinator: who was
/// dispatched what, which replies are cached, and what commits a round.
pub struct RoundScheduler {
    dim: usize,
    quorum_frac: f64,
    max_staleness: usize,
    round: usize,
    started: bool,
    dispatch: Vec<Dispatch>,
    cache: Vec<Option<CachedReply>>,
    /// The elastic roster.
    pub membership: Membership,
    /// Protocol accounting (rounds, folds, drops, deaths, joins).
    pub stats: CoordinationStats,
    /// Network accounting (coordinator side): round broadcasts in
    /// `net_down_bytes`, resyncs in `net_resync_bytes`, replies in
    /// `net_up_bytes`.
    pub net: TransferLedger,
}

impl RoundScheduler {
    /// Scheduler over `nodes` slots broadcasting `dim`-length vectors.
    pub fn new(nodes: usize, dim: usize, quorum_frac: f64, max_staleness: usize) -> RoundScheduler {
        RoundScheduler {
            dim,
            quorum_frac,
            max_staleness,
            round: 0,
            started: false,
            dispatch: vec![Dispatch::Idle; nodes],
            cache: vec![None; nodes],
            membership: Membership::new(nodes),
            stats: CoordinationStats::new(nodes),
            net: TransferLedger::default(),
        }
    }

    fn z_bytes(&self) -> u64 {
        self.dim as u64 * 8
    }

    /// Index of the round currently being collected.
    pub fn current_round(&self) -> usize {
        self.round
    }

    /// The staleness bound replies are folded under.
    pub fn max_staleness(&self) -> usize {
        self.max_staleness
    }

    /// Start the next round: returns its index and the reachable idle
    /// nodes to broadcast z to.  Nodes still busy with older work are
    /// skipped — they will be re-dispatched when their reply surfaces.
    pub fn begin_round(&mut self) -> (usize, Vec<usize>) {
        if self.started {
            self.round += 1;
        } else {
            self.started = true;
        }
        self.stats.rounds += 1;
        let targets = (0..self.dispatch.len())
            .filter(|&i| self.membership.is_reachable(i) && self.dispatch[i] == Dispatch::Idle)
            .collect();
        (self.round, targets)
    }

    /// A round broadcast reached `node`.
    pub fn on_sent(&mut self, node: usize) {
        self.dispatch[node] = Dispatch::Busy(self.round);
        self.net.net_down_bytes += self.z_bytes();
    }

    /// A resync broadcast (current z re-pushed to a stale or joining
    /// node) reached `node` — accounted separately from round traffic.
    pub fn on_resync_sent(&mut self, node: usize) {
        self.dispatch[node] = Dispatch::Busy(self.round);
        self.net.net_resync_bytes += self.z_bytes();
        self.stats.resyncs += 1;
    }

    /// A broadcast to `node` failed: its channel is gone, so it is dead.
    /// Returns true on a fresh death.
    pub fn on_send_failed(&mut self, node: usize) -> bool {
        self.kill(node)
    }

    /// Declare `node` dead (shard degraded).  Its cached reply is evicted
    /// so it stops contributing to the consensus average.
    pub fn kill(&mut self, node: usize) -> bool {
        let fresh = self.membership.mark_dead(node);
        if fresh {
            self.stats.deaths += 1;
        }
        self.cache[node] = None;
        self.dispatch[node] = Dispatch::Idle;
        fresh
    }

    /// Whether `node` owes a reply for some dispatched round.
    pub fn is_busy(&self, node: usize) -> bool {
        matches!(self.dispatch[node], Dispatch::Busy(_))
    }

    /// Reachable nodes still owing a reply for an *older* round — the
    /// candidates for a liveness probe (a silently-crashed node looks
    /// exactly like a straggler until its channel is tested).
    pub fn laggards(&self) -> Vec<usize> {
        (0..self.dispatch.len())
            .filter(|&i| {
                let behind = matches!(self.dispatch[i], Dispatch::Busy(r) if r < self.round);
                behind && self.membership.is_reachable(i)
            })
            .collect()
    }

    /// Replies that must land in the current collect phase before the
    /// round commits.
    pub fn quorum_needed(&self) -> usize {
        self.membership.quorum_needed(self.quorum_frac)
    }

    /// Handle a reply from `node` computed against round `tag`.
    pub fn on_reply(&mut self, node: usize, tag: usize, x: Vec<f64>, u: Vec<f64>) -> ReplyAction {
        self.dispatch[node] = Dispatch::Idle;
        if !self.membership.is_reachable(node) {
            return ReplyAction::Ignored;
        }
        self.net.net_up_bytes += 2 * self.z_bytes();
        // a joining node is a full member from its first reply on
        if self.membership.state(node) == NodeState::Joining {
            self.membership.mark_active(node);
        }
        let lag = self.round.saturating_sub(tag);
        if lag > self.max_staleness {
            self.stats.drops += 1;
            return ReplyAction::Dropped { lag };
        }
        self.cache[node] = Some(CachedReply { x, u, round: tag });
        self.stats.record_fold(node, lag);
        if lag == 0 {
            ReplyAction::Fresh
        } else {
            ReplyAction::Folded { lag }
        }
    }

    /// A reply surfacing outside any round collect (loss/ledger queries
    /// after the solve): free the dispatch slot and ledger the wire
    /// bytes, but do NOT fold it — no further global update will consume
    /// it, so folding would skew the participation statistics.
    pub fn on_stray_reply(&mut self, node: usize) {
        self.dispatch[node] = Dispatch::Idle;
        if self.membership.is_reachable(node) {
            self.net.net_up_bytes += 2 * self.z_bytes();
            if self.membership.state(node) == NodeState::Joining {
                self.membership.mark_active(node);
            }
        }
    }

    /// Snapshot for the solver: every active node's latest folded reply
    /// that is still within the staleness bound, sorted by node id.
    pub fn collect(&self) -> Vec<NodeReply> {
        let mut out = Vec::with_capacity(self.cache.len());
        for (node, entry) in self.cache.iter().enumerate() {
            if !self.membership.is_active(node) {
                continue;
            }
            if let Some(c) = entry {
                let lag = self.round.saturating_sub(c.round);
                if lag <= self.max_staleness {
                    out.push(NodeReply {
                        node,
                        round: c.round,
                        lag,
                        x: c.x.clone(),
                        u: c.u.clone(),
                    });
                }
            }
        }
        out
    }

    /// Allocate the slot for an elastically-joining node.
    pub fn register_join(&mut self) -> usize {
        let id = self.membership.join();
        self.dispatch.push(Dispatch::Idle);
        self.cache.push(None);
        if self.stats.participation.len() <= id {
            self.stats.participation.resize(id + 1, 0);
        }
        self.stats.joins += 1;
        id
    }

    /// Gracefully remove a node from the roster.
    pub fn remove(&mut self, node: usize) {
        self.membership.leave(node);
        self.cache[node] = None;
        self.dispatch[node] = Dispatch::Idle;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reply(v: f64, dim: usize) -> (Vec<f64>, Vec<f64>) {
        (vec![v; dim], vec![-v; dim])
    }

    #[test]
    fn full_barrier_mode_waits_for_everyone_and_stays_fresh() {
        let dim = 3;
        let mut s = RoundScheduler::new(2, dim, 1.0, 0);
        let (k, targets) = s.begin_round();
        assert_eq!(k, 0);
        assert_eq!(targets, vec![0, 1]);
        assert_eq!(s.quorum_needed(), 2);
        s.on_sent(0);
        s.on_sent(1);
        let (x, u) = reply(1.0, dim);
        assert_eq!(s.on_reply(0, 0, x, u), ReplyAction::Fresh);
        let (x, u) = reply(2.0, dim);
        assert_eq!(s.on_reply(1, 0, x, u), ReplyAction::Fresh);
        let replies = s.collect();
        assert_eq!(replies.len(), 2);
        assert_eq!(replies[0].node, 0);
        assert_eq!(replies[1].node, 1);
        assert!(replies.iter().all(|r| r.round == 0));
    }

    #[test]
    fn byte_accounting_separates_round_and_resync_traffic() {
        // satellite requirement: resync bytes are ledgered apart from the
        // regular round broadcasts.
        let dim = 4;
        let zb = (dim * 8) as u64;
        let mut s = RoundScheduler::new(2, dim, 0.5, 0);

        // round 0: both nodes broadcast (2 x round traffic)
        let (_, targets) = s.begin_round();
        assert_eq!(targets.len(), 2);
        s.on_sent(0);
        s.on_sent(1);
        // node 0 replies; quorum = ceil(0.5 * 2) = 1 -> round commits
        let (x, u) = reply(1.0, dim);
        assert_eq!(s.on_reply(0, 0, x, u), ReplyAction::Fresh);
        assert_eq!(s.collect().len(), 1, "node 1 has not replied yet");

        // round 1: only idle node 0 gets the round broadcast
        let (_, targets) = s.begin_round();
        assert_eq!(targets, vec![0]);
        s.on_sent(0);
        // node 1's old reply surfaces now: lag 1 > max_staleness 0 -> drop
        let (x, u) = reply(9.0, dim);
        assert_eq!(s.on_reply(1, 0, x, u), ReplyAction::Dropped { lag: 1 });
        // the coordinator resyncs it with the current z
        s.on_resync_sent(1);

        assert_eq!(s.net.net_down_bytes, 3 * zb, "3 round broadcasts");
        assert_eq!(s.net.net_resync_bytes, zb, "1 resync broadcast");
        assert_eq!(s.net.net_up_bytes, 2 * 2 * zb, "2 replies (x_i + u_i)");
        assert_eq!(s.stats.drops, 1);
        assert_eq!(s.stats.resyncs, 1);
    }

    #[test]
    fn bounded_staleness_folds_late_replies_then_evicts() {
        let dim = 2;
        let mut s = RoundScheduler::new(3, dim, 1.0 / 3.0, 1);
        let (_, t) = s.begin_round(); // round 0
        for n in t {
            s.on_sent(n);
        }
        let (x, u) = reply(1.0, dim);
        s.on_reply(0, 0, x, u);
        let (_, t) = s.begin_round(); // round 1
        for n in t {
            s.on_sent(n);
        }
        // node 1's round-0 reply arrives one round late: folded
        let (x, u) = reply(2.0, dim);
        assert_eq!(s.on_reply(1, 0, x, u), ReplyAction::Folded { lag: 1 });
        // node 0's cache (round 0) is still within the bound at round 1
        let replies = s.collect();
        assert_eq!(
            replies.iter().map(|r| r.node).collect::<Vec<_>>(),
            vec![0, 1]
        );
        // two rounds later both entries age out of the staleness window
        s.begin_round(); // round 2
        s.begin_round(); // round 3
        assert!(s.collect().is_empty());
        assert_eq!(s.stats.staleness_hist, vec![1, 1]);
        assert_eq!(s.stats.participation, vec![1, 1, 0]);
    }

    #[test]
    fn death_degrades_the_shard_and_shrinks_the_quorum() {
        let dim = 2;
        let mut s = RoundScheduler::new(3, dim, 1.0, 0);
        let (_, t) = s.begin_round();
        for n in t {
            s.on_sent(n);
        }
        assert_eq!(s.quorum_needed(), 3);
        let (x, u) = reply(1.0, dim);
        s.on_reply(0, 0, x, u);
        assert!(s.kill(2));
        assert_eq!(s.quorum_needed(), 2);
        assert_eq!(s.membership.degraded(), vec![2]);
        // a dead node's late reply is ignored, not folded
        let (x, u) = reply(7.0, dim);
        assert_eq!(s.on_reply(2, 0, x, u), ReplyAction::Ignored);
        assert_eq!(s.collect().len(), 1);
        assert_eq!(s.stats.deaths, 1);
    }

    #[test]
    fn elastic_join_becomes_active_on_first_reply() {
        let dim = 2;
        let mut s = RoundScheduler::new(2, dim, 1.0, 1);
        s.begin_round();
        let id = s.register_join();
        assert_eq!(id, 2);
        assert_eq!(s.quorum_needed(), 2, "joining node not yet counted");
        s.on_resync_sent(id); // joiner is primed with the current z
        let (x, u) = reply(3.0, dim);
        assert_eq!(s.on_reply(id, 0, x, u), ReplyAction::Fresh);
        assert_eq!(s.quorum_needed(), 3, "promoted after first reply");
        assert_eq!(s.stats.joins, 1);
        s.remove(id);
        assert_eq!(s.quorum_needed(), 2);
    }
}

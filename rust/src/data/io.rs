//! Dataset persistence: a simple self-describing binary format (PSF1) for
//! distributed datasets, plus a dense-CSV loader for real data.
//!
//! Layout (little-endian):
//!   magic "PSF1" | u32 nodes | u32 n_features | u32 width
//!   | u32 truth_len | truth_len x f64 (x_true, class-major)
//!   | per shard: u32 rows | rows*n f32 (A row-major) | rows*width f32
//!
//! `support_true` is re-derived from `x_true` on load, so the file stays
//! minimal.  Used by the examples to cache generated workloads and by
//! users to bring their own data (`load_csv` builds a single-shard
//! dataset that `partition::shard_sizes` can re-split).

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::{Dataset, Shard};
use crate::linalg::Matrix;

const MAGIC: &[u8; 4] = b"PSF1";

fn write_u32<W: Write>(w: &mut W, v: u32) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn read_u32<R: Read>(r: &mut R) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn write_f32s<W: Write>(w: &mut W, xs: &[f32]) -> std::io::Result<()> {
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_f32s<R: Read>(r: &mut R, n: usize) -> std::io::Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

pub fn save(ds: &Dataset, path: &Path) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC)?;
    write_u32(&mut w, ds.shards.len() as u32)?;
    write_u32(&mut w, ds.n_features as u32)?;
    write_u32(&mut w, ds.width as u32)?;
    write_u32(&mut w, ds.x_true.len() as u32)?;
    for &v in &ds.x_true {
        w.write_all(&v.to_le_bytes())?;
    }
    for shard in &ds.shards {
        write_u32(&mut w, shard.a.rows as u32)?;
        write_f32s(&mut w, &shard.a.data)?;
        write_f32s(&mut w, &shard.labels)?;
    }
    w.flush()?;
    Ok(())
}

pub fn load(path: &Path) -> anyhow::Result<Dataset> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "not a PSF1 dataset file");
    let nodes = read_u32(&mut r)? as usize;
    let n = read_u32(&mut r)? as usize;
    let width = read_u32(&mut r)? as usize;
    anyhow::ensure!(nodes > 0 && n > 0 && width > 0, "corrupt header");
    let truth_len = read_u32(&mut r)? as usize;
    anyhow::ensure!(truth_len == n * width, "truth length mismatch");
    let mut x_true = vec![0.0f64; truth_len];
    for v in x_true.iter_mut() {
        let mut b = [0u8; 8];
        r.read_exact(&mut b)?;
        *v = f64::from_le_bytes(b);
    }
    let mut shards = Vec::with_capacity(nodes);
    for _ in 0..nodes {
        let rows = read_u32(&mut r)? as usize;
        let data = read_f32s(&mut r, rows * n)?;
        let labels = read_f32s(&mut r, rows * width)?;
        shards.push(Shard {
            a: std::sync::Arc::new(Matrix {
                rows,
                cols: n,
                data,
            }),
            labels,
            width,
        });
    }
    let support_true = x_true
        .iter()
        .enumerate()
        .filter(|(_, &v)| v != 0.0)
        .map(|(i, _)| i)
        .collect();
    Ok(Dataset {
        shards,
        x_true,
        support_true,
        n_features: n,
        width,
    })
}

/// Load a dense CSV (last column = label, others = features) as a
/// single-shard regression/classification dataset.  No ground truth.
pub fn load_csv(path: &Path) -> anyhow::Result<Dataset> {
    let text = std::fs::read_to_string(path)?;
    let mut rows: Vec<Vec<f32>> = Vec::new();
    let mut labels = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let cells: Vec<f32> = line
            .split(',')
            .map(|c| {
                c.trim()
                    .parse::<f32>()
                    .map_err(|_| anyhow::anyhow!("line {}: bad number `{c}`", lineno + 1))
            })
            .collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(cells.len() >= 2, "line {}: need >= 2 columns", lineno + 1);
        labels.push(*cells.last().unwrap());
        rows.push(cells[..cells.len() - 1].to_vec());
    }
    anyhow::ensure!(!rows.is_empty(), "empty csv");
    let n = rows[0].len();
    anyhow::ensure!(
        rows.iter().all(|r| r.len() == n),
        "ragged rows in csv"
    );
    let a = Matrix::from_rows(rows);
    Ok(Dataset {
        shards: vec![Shard {
            a: std::sync::Arc::new(a),
            labels,
            width: 1,
        }],
        x_true: vec![0.0; n],
        support_true: Vec::new(),
        n_features: n,
        width: 1,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SyntheticSpec, Task};

    #[test]
    fn roundtrip_regression() {
        let ds = SyntheticSpec::regression(12, 50, 3).generate();
        let path = std::env::temp_dir().join("psfit_io_test.psf");
        save(&ds, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.n_features, ds.n_features);
        assert_eq!(back.nodes(), ds.nodes());
        assert_eq!(back.x_true, ds.x_true);
        assert_eq!(back.support_true, ds.support_true);
        for (a, b) in back.shards.iter().zip(&ds.shards) {
            assert_eq!(a.a.data, b.a.data);
            assert_eq!(a.labels, b.labels);
        }
    }

    #[test]
    fn roundtrip_multiclass() {
        let mut spec = SyntheticSpec::regression(8, 30, 2);
        spec.task = Task::Multiclass { k: 3 };
        let ds = spec.generate();
        let path = std::env::temp_dir().join("psfit_io_test_mc.psf");
        save(&ds, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.width, 3);
        assert_eq!(back.shards[1].labels, ds.shards[1].labels);
    }

    #[test]
    fn rejects_garbage_file() {
        let path = std::env::temp_dir().join("psfit_io_garbage.psf");
        std::fs::write(&path, b"not a dataset").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn csv_loader_parses_and_validates() {
        let path = std::env::temp_dir().join("psfit_io_test.csv");
        std::fs::write(&path, "# comment\n1.0, 2.0, 3.5\n4.0, 5.0, -1.5\n").unwrap();
        let ds = load_csv(&path).unwrap();
        assert_eq!(ds.n_features, 2);
        assert_eq!(ds.total_samples(), 2);
        assert_eq!(ds.shards[0].labels, vec![3.5, -1.5]);

        std::fs::write(&path, "1.0, x\n").unwrap();
        assert!(load_csv(&path).is_err());
        std::fs::write(&path, "1.0,2.0,3.0\n1.0,2.0\n").unwrap();
        assert!(load_csv(&path).is_err());
    }
}

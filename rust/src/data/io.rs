//! Dataset persistence: a simple self-describing binary format (PSF1) for
//! distributed datasets, a dense-CSV loader, and a LIBSVM/SVMLight reader
//! for real sparse data (text, one-hot, genomics).
//!
//! Layout (little-endian):
//!   magic "PSF1" | u32 nodes | u32 n_features | u32 width
//!   | u32 truth_len | truth_len x f64 (x_true, class-major)
//!   | per shard: u32 rows | rows*n f32 (A row-major) | rows*width f32
//!
//! `support_true` is re-derived from `x_true` on load, so the file stays
//! minimal.  Used by the examples to cache generated workloads and by
//! users to bring their own data (`load_csv` / `load_libsvm` build a
//! single-shard dataset that `partition::shard_sizes` can re-split).
//! PSF1 is a dense format: CSR shards are densified on save and the
//! storage policy re-decides the format after load.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::partition::ShardData;
use super::{Dataset, Shard};
use crate::linalg::{CsrMatrix, Matrix};

const MAGIC: &[u8; 4] = b"PSF1";

fn write_u32<W: Write>(w: &mut W, v: u32) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn read_u32<R: Read>(r: &mut R) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn write_f32s<W: Write>(w: &mut W, xs: &[f32]) -> std::io::Result<()> {
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_f32s<R: Read>(r: &mut R, n: usize) -> std::io::Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write a dataset in the PSF1 binary format (dense; CSR shards are
/// densified row-wise).
pub fn save(ds: &Dataset, path: &Path) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC)?;
    write_u32(&mut w, ds.shards.len() as u32)?;
    write_u32(&mut w, ds.n_features as u32)?;
    write_u32(&mut w, ds.width as u32)?;
    write_u32(&mut w, ds.x_true.len() as u32)?;
    for &v in &ds.x_true {
        w.write_all(&v.to_le_bytes())?;
    }
    for shard in &ds.shards {
        let a = shard.data.to_dense();
        write_u32(&mut w, a.rows as u32)?;
        // logical rows only: alignment padding is never serialized
        for r in 0..a.rows {
            write_f32s(&mut w, a.row(r))?;
        }
        write_f32s(&mut w, &shard.labels)?;
    }
    w.flush()?;
    Ok(())
}

/// Read a PSF1 dataset back (storage starts dense; apply a policy to
/// re-decide the format).
pub fn load(path: &Path) -> anyhow::Result<Dataset> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "not a PSF1 dataset file");
    let nodes = read_u32(&mut r)? as usize;
    let n = read_u32(&mut r)? as usize;
    let width = read_u32(&mut r)? as usize;
    anyhow::ensure!(nodes > 0 && n > 0 && width > 0, "corrupt header");
    let truth_len = read_u32(&mut r)? as usize;
    anyhow::ensure!(truth_len == n * width, "truth length mismatch");
    let mut x_true = vec![0.0f64; truth_len];
    for v in x_true.iter_mut() {
        let mut b = [0u8; 8];
        r.read_exact(&mut b)?;
        *v = f64::from_le_bytes(b);
    }
    let mut shards = Vec::with_capacity(nodes);
    for _ in 0..nodes {
        let rows = read_u32(&mut r)? as usize;
        let data = read_f32s(&mut r, rows * n)?;
        let labels = read_f32s(&mut r, rows * width)?;
        shards.push(Shard {
            data: ShardData::Dense(std::sync::Arc::new(Matrix::from_flat(rows, n, &data))),
            labels,
            width,
        });
    }
    let support_true = x_true
        .iter()
        .enumerate()
        .filter(|(_, &v)| v != 0.0)
        .map(|(i, _)| i)
        .collect();
    Ok(Dataset {
        shards,
        x_true,
        support_true,
        n_features: n,
        width,
    })
}

/// Load a dense CSV (last column = label, others = features) as a
/// single-shard regression/classification dataset.  No ground truth.
///
/// Rejects non-finite values (`nan`, `inf` — which `parse::<f32>`
/// happily accepts) with a line-numbered error; see
/// [`load_csv_sanitized`] to drop such rows instead.
pub fn load_csv(path: &Path) -> anyhow::Result<Dataset> {
    load_csv_opts(path, false)
}

/// [`load_csv`] that drops rows containing non-finite values instead of
/// erroring, reporting how many were dropped on stderr (`--sanitize`).
pub fn load_csv_sanitized(path: &Path) -> anyhow::Result<Dataset> {
    load_csv_opts(path, true)
}

/// Outcome of parsing one CSV line (shared by the resident loader and the
/// streaming `PSD1` converter, so both apply byte-identical parse rules).
pub(crate) enum CsvLine {
    /// Blank or comment line.
    Skip,
    /// Row dropped by `--sanitize` (non-finite cell).
    Dropped,
    /// Parsed cells, label last.
    Row(Vec<f32>),
}

/// Parse one CSV line under the exact `load_csv` dialect.
pub(crate) fn parse_csv_line(lineno: usize, raw: &str, sanitize: bool) -> anyhow::Result<CsvLine> {
    let line = raw.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(CsvLine::Skip);
    }
    let cells: Vec<f32> = line
        .split(',')
        .map(|c| {
            c.trim()
                .parse::<f32>()
                .map_err(|_| anyhow::anyhow!("line {}: bad number `{c}`", lineno + 1))
        })
        .collect::<anyhow::Result<_>>()?;
    anyhow::ensure!(cells.len() >= 2, "line {}: need >= 2 columns", lineno + 1);
    if let Some(col) = cells.iter().position(|v| !v.is_finite()) {
        if sanitize {
            return Ok(CsvLine::Dropped);
        }
        anyhow::bail!(
            "line {}: non-finite value `{}` in column {} \
             (use --sanitize to drop such rows)",
            lineno + 1,
            cells[col],
            col + 1
        );
    }
    Ok(CsvLine::Row(cells))
}

/// Outcome of parsing one LIBSVM line (shared like [`CsvLine`]).
pub(crate) enum SvmLine {
    /// Blank or comment-only line.
    Skip,
    /// Row dropped by `--sanitize` (non-finite label or value).
    Dropped,
    /// Label + entries (0-based strictly increasing columns, explicit
    /// zeros kept — the loader's storage semantics).
    Row(f32, Vec<(u32, f32)>),
}

/// Parse one LIBSVM line under the exact `load_libsvm` dialect.
pub(crate) fn parse_libsvm_line(
    lineno: usize,
    raw: &str,
    sanitize: bool,
) -> anyhow::Result<SvmLine> {
    let line = raw.split('#').next().unwrap_or("").trim();
    if line.is_empty() {
        return Ok(SvmLine::Skip);
    }
    let mut parts = line.split_whitespace();
    let label: f32 = parts
        .next()
        .unwrap()
        .parse()
        .map_err(|_| anyhow::anyhow!("line {}: bad label", lineno + 1))?;
    if !label.is_finite() {
        if sanitize {
            return Ok(SvmLine::Dropped);
        }
        anyhow::bail!(
            "line {}: non-finite label `{label}` \
             (use --sanitize to drop such rows)",
            lineno + 1
        );
    }
    let mut entries: Vec<(u32, f32)> = Vec::new();
    for tok in parts {
        if tok.starts_with("qid:") {
            continue; // ranking qualifier: not a feature
        }
        let (idx, val) = tok
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("line {}: expected idx:val, got `{tok}`", lineno + 1))?;
        let idx: usize = idx
            .parse()
            .map_err(|_| anyhow::anyhow!("line {}: bad index `{idx}`", lineno + 1))?;
        anyhow::ensure!(idx >= 1, "line {}: LIBSVM indices are 1-based", lineno + 1);
        anyhow::ensure!(
            idx <= u32::MAX as usize,
            "line {}: index {idx} exceeds the u32 column limit",
            lineno + 1
        );
        let val: f32 = val
            .parse()
            .map_err(|_| anyhow::anyhow!("line {}: bad value `{val}`", lineno + 1))?;
        if !val.is_finite() {
            if sanitize {
                return Ok(SvmLine::Dropped);
            }
            anyhow::bail!(
                "line {}: non-finite value `{val}` at index {idx} \
                 (use --sanitize to drop such rows)",
                lineno + 1
            );
        }
        let col = idx - 1;
        if let Some(&(prev, _)) = entries.last() {
            anyhow::ensure!(
                col as u32 > prev,
                "line {}: indices must be strictly increasing",
                lineno + 1
            );
        }
        entries.push((col as u32, val));
    }
    Ok(SvmLine::Row(label, entries))
}

fn load_csv_opts(path: &Path, sanitize: bool) -> anyhow::Result<Dataset> {
    let text = std::fs::read_to_string(path)?;
    let mut rows: Vec<Vec<f32>> = Vec::new();
    let mut labels = Vec::new();
    let mut dropped = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        match parse_csv_line(lineno, line, sanitize)? {
            CsvLine::Skip => {}
            CsvLine::Dropped => dropped += 1,
            CsvLine::Row(cells) => {
                labels.push(*cells.last().unwrap());
                rows.push(cells[..cells.len() - 1].to_vec());
            }
        }
    }
    if dropped > 0 {
        eprintln!("[sanitize] dropped {dropped} csv row(s) with non-finite values");
    }
    anyhow::ensure!(!rows.is_empty(), "empty csv");
    let n = rows[0].len();
    anyhow::ensure!(
        rows.iter().all(|r| r.len() == n),
        "ragged rows in csv"
    );
    let a = Matrix::from_rows(rows);
    Ok(Dataset {
        shards: vec![Shard::dense(a, labels, 1)],
        x_true: vec![0.0; n],
        support_true: Vec::new(),
        n_features: n,
        width: 1,
    })
}

/// Load a LIBSVM/SVMLight file (`label idx:val ...`, 1-based ascending
/// indices, `#` comments) as a single-shard dataset stored in CSR — the
/// natural format for these files, which are overwhelmingly sparse.  The
/// feature count is the largest index seen unless `n_features` pins it
/// (needed when train/test splits see different tails).  No ground truth.
///
/// Re-split the loaded single shard with [`Dataset::resplit`] to
/// distribute it across a cluster (`psfit train --libsvm f.svm --nodes 4`
/// does exactly that).
///
/// ```
/// let path = std::env::temp_dir().join("psfit_doc_libsvm.svm");
/// std::fs::write(&path, "1 1:0.5 3:-2.0  # a sparse row\n-1 2:1.5\n").unwrap();
/// let ds = psfit::data::io::load_libsvm(&path, None).unwrap();
/// assert_eq!(ds.n_features, 3);
/// assert_eq!(ds.total_samples(), 2);
/// assert_eq!(ds.shards[0].labels, vec![1.0, -1.0]);
/// assert!(ds.shards[0].data.is_csr());
/// let spread = ds.resplit(2);
/// assert_eq!(spread.nodes(), 2);
/// ```
pub fn load_libsvm(path: &Path, n_features: Option<usize>) -> anyhow::Result<Dataset> {
    load_libsvm_opts(path, n_features, false)
}

/// [`load_libsvm`] that drops rows containing non-finite labels or
/// values instead of erroring, reporting how many were dropped on stderr
/// (`--sanitize`).
pub fn load_libsvm_sanitized(path: &Path, n_features: Option<usize>) -> anyhow::Result<Dataset> {
    load_libsvm_opts(path, n_features, true)
}

fn load_libsvm_opts(
    path: &Path,
    n_features: Option<usize>,
    sanitize: bool,
) -> anyhow::Result<Dataset> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    let mut rows: Vec<Vec<(u32, f32)>> = Vec::new();
    let mut labels: Vec<f32> = Vec::new();
    let mut max_col = 0usize;
    let mut dropped = 0usize;
    for (lineno, raw) in text.lines().enumerate() {
        match parse_libsvm_line(lineno, raw, sanitize)? {
            SvmLine::Skip => {}
            SvmLine::Dropped => dropped += 1,
            SvmLine::Row(label, entries) => {
                // column span committed only for rows that survive, so a
                // dropped row never widens the feature space
                if let Some(&(last, _)) = entries.last() {
                    max_col = max_col.max(last as usize + 1);
                }
                labels.push(label);
                rows.push(entries);
            }
        }
    }
    if dropped > 0 {
        eprintln!("[sanitize] dropped {dropped} libsvm row(s) with non-finite values");
    }
    anyhow::ensure!(!rows.is_empty(), "empty libsvm file");
    let n = match n_features {
        Some(n) => {
            anyhow::ensure!(n >= max_col, "n_features {n} < largest index {max_col}");
            n
        }
        None => max_col,
    };
    anyhow::ensure!(n > 0, "no features in libsvm file");
    let csr = CsrMatrix::from_rows(n, rows);
    Ok(Dataset {
        shards: vec![Shard {
            data: ShardData::Csr(std::sync::Arc::new(csr)),
            labels,
            width: 1,
        }],
        x_true: vec![0.0; n],
        support_true: Vec::new(),
        n_features: n,
        width: 1,
    })
}

/// Write a width-1 dataset in LIBSVM format (1-based indices, nonzeros
/// only) — the round-trip partner of [`load_libsvm`].
pub fn save_libsvm(ds: &Dataset, path: &Path) -> anyhow::Result<()> {
    anyhow::ensure!(ds.width == 1, "libsvm export is scalar-label only");
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    for shard in &ds.shards {
        let csr = shard.data.to_csr();
        for r in 0..csr.rows {
            write!(w, "{}", shard.labels[r])?;
            let (cols, vals) = csr.row(r);
            for (&c, &v) in cols.iter().zip(vals) {
                write!(w, " {}:{}", c + 1, v)?;
            }
            writeln!(w)?;
        }
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SyntheticSpec, Task};

    #[test]
    fn roundtrip_regression() {
        let ds = SyntheticSpec::regression(12, 50, 3).generate();
        let path = std::env::temp_dir().join("psfit_io_test.psf");
        save(&ds, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.n_features, ds.n_features);
        assert_eq!(back.nodes(), ds.nodes());
        assert_eq!(back.x_true, ds.x_true);
        assert_eq!(back.support_true, ds.support_true);
        for (a, b) in back.shards.iter().zip(&ds.shards) {
            assert_eq!(*a.data.to_dense(), *b.data.to_dense());
            assert_eq!(a.labels, b.labels);
        }
    }

    #[test]
    fn roundtrip_multiclass() {
        let mut spec = SyntheticSpec::regression(8, 30, 2);
        spec.task = Task::Multiclass { k: 3 };
        let ds = spec.generate();
        let path = std::env::temp_dir().join("psfit_io_test_mc.psf");
        save(&ds, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.width, 3);
        assert_eq!(back.shards[1].labels, ds.shards[1].labels);
    }

    #[test]
    fn rejects_garbage_file() {
        let path = std::env::temp_dir().join("psfit_io_garbage.psf");
        std::fs::write(&path, b"not a dataset").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn libsvm_loader_parses_sparse_rows() {
        let path = std::env::temp_dir().join("psfit_io_test.svm");
        std::fs::write(
            &path,
            "# header comment\n1 1:0.5 3:-2.0  # trailing comment\n-1 2:1.5\n1\n",
        )
        .unwrap();
        let ds = load_libsvm(&path, None).unwrap();
        assert_eq!(ds.n_features, 3);
        assert_eq!(ds.total_samples(), 3);
        assert_eq!(ds.shards[0].labels, vec![1.0, -1.0, 1.0]);
        let csr = ds.shards[0].data.as_csr().unwrap();
        assert_eq!(csr.nnz(), 3);
        let dense = csr.to_dense();
        assert_eq!(dense.row(0), &[0.5, 0.0, -2.0]);
        assert_eq!(dense.row(1), &[0.0, 1.5, 0.0]);
        assert_eq!(dense.row(2), &[0.0, 0.0, 0.0]); // empty row is legal

        // pinned feature count pads the tail
        let ds = load_libsvm(&path, Some(5)).unwrap();
        assert_eq!(ds.n_features, 5);
        assert!(load_libsvm(&path, Some(2)).is_err(), "too-small pin");
    }

    #[test]
    fn libsvm_roundtrip_preserves_values() {
        let mut spec = SyntheticSpec::regression(15, 40, 2);
        spec.density = 0.2;
        let mut ds = spec.generate();
        ds.apply_storage(crate::data::SparseMode::Always, 0.0);
        let path = std::env::temp_dir().join("psfit_io_roundtrip.svm");
        save_libsvm(&ds, &path).unwrap();
        let back = load_libsvm(&path, Some(15)).unwrap();
        assert_eq!(back.total_samples(), 40);
        let (a0, l0) = ds.stacked();
        let (a1, l1) = back.stacked();
        assert_eq!(l0, l1);
        for (x, y) in a0.to_vec().iter().zip(&a1.to_vec()) {
            // values survive the decimal text round-trip to f32 accuracy
            assert!((x - y).abs() <= 1e-6 * x.abs().max(1.0), "{x} vs {y}");
        }
    }

    #[test]
    fn resplit_distributes_loaded_shard_preserving_rows() {
        let path = std::env::temp_dir().join("psfit_io_resplit.svm");
        std::fs::write(
            &path,
            "1 1:1.0\n-1 2:2.0\n1 3:3.0\n-1 1:4.0\n1 2:5.0\n",
        )
        .unwrap();
        let ds = load_libsvm(&path, None).unwrap();
        let split = ds.resplit(2);
        assert_eq!(split.nodes(), 2);
        let sizes: Vec<usize> = split.shards.iter().map(|s| s.rows()).collect();
        assert_eq!(sizes, vec![3, 2]);
        // storage kind preserved, row order and content intact
        assert!(split.shards.iter().all(|s| s.data.is_csr()));
        let (a0, l0) = ds.stacked();
        let (a1, l1) = split.stacked();
        assert_eq!(a0, a1);
        assert_eq!(l0, l1);

        // dense datasets resplit densely
        let dense = SyntheticSpec::regression(6, 10, 1).generate();
        let split = dense.resplit(3);
        assert_eq!(split.nodes(), 3);
        assert!(split.shards.iter().all(|s| !s.data.is_csr()));
        assert_eq!(dense.stacked().0, split.stacked().0);
    }

    #[test]
    fn libsvm_rejects_malformed_lines() {
        let path = std::env::temp_dir().join("psfit_io_bad.svm");
        for bad in ["1 3:0.5 2:0.5\n", "1 0:1.0\n", "1 x:1.0\n", "abc 1:1.0\n", ""] {
            std::fs::write(&path, bad).unwrap();
            assert!(load_libsvm(&path, None).is_err(), "accepted: {bad:?}");
        }
    }

    #[test]
    fn loaders_reject_non_finite_values_with_line_numbers() {
        let path = std::env::temp_dir().join("psfit_io_nonfinite.csv");
        std::fs::write(&path, "1.0, 2.0, 3.5\n4.0, nan, -1.5\n").unwrap();
        let err = load_csv(&path).unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        assert!(err.contains("non-finite"), "{err}");
        assert!(err.contains("column 2"), "{err}");
        std::fs::write(&path, "1.0, 2.0, inf\n").unwrap();
        assert!(load_csv(&path).is_err(), "inf label accepted");

        let path = std::env::temp_dir().join("psfit_io_nonfinite.svm");
        std::fs::write(&path, "1 1:0.5\n-1 2:nan\n").unwrap();
        let err = load_libsvm(&path, None).unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        assert!(err.contains("non-finite"), "{err}");
        std::fs::write(&path, "inf 1:0.5\n").unwrap();
        let err = load_libsvm(&path, None).unwrap_err().to_string();
        assert!(err.contains("non-finite label"), "{err}");
    }

    #[test]
    fn sanitized_loaders_drop_poisoned_rows() {
        let path = std::env::temp_dir().join("psfit_io_sanitize.csv");
        std::fs::write(&path, "1.0, 2.0, 3.5\n4.0, nan, -1.5\n5.0, 6.0, 0.5\n").unwrap();
        let ds = load_csv_sanitized(&path).unwrap();
        assert_eq!(ds.total_samples(), 2);
        assert_eq!(ds.shards[0].labels, vec![3.5, 0.5]);

        let path = std::env::temp_dir().join("psfit_io_sanitize.svm");
        // the widest row is the poisoned one: dropping it must also drop
        // its column span
        std::fs::write(&path, "1 1:0.5 7:inf\n-1 2:1.5\nnan 3:1.0\n1 3:2.0\n").unwrap();
        let ds = load_libsvm_sanitized(&path, None).unwrap();
        assert_eq!(ds.total_samples(), 2);
        assert_eq!(ds.shards[0].labels, vec![-1.0, 1.0]);
        assert_eq!(ds.n_features, 3, "dropped row widened the feature space");

        // an all-poisoned file still errors (nothing left to fit)
        std::fs::write(&path, "nan 1:1.0\n").unwrap();
        assert!(load_libsvm_sanitized(&path, None).is_err());
    }

    #[test]
    fn csv_loader_parses_and_validates() {
        let path = std::env::temp_dir().join("psfit_io_test.csv");
        std::fs::write(&path, "# comment\n1.0, 2.0, 3.5\n4.0, 5.0, -1.5\n").unwrap();
        let ds = load_csv(&path).unwrap();
        assert_eq!(ds.n_features, 2);
        assert_eq!(ds.total_samples(), 2);
        assert_eq!(ds.shards[0].labels, vec![3.5, -1.5]);

        std::fs::write(&path, "1.0, x\n").unwrap();
        assert!(load_csv(&path).is_err());
        std::fs::write(&path, "1.0,2.0,3.0\n1.0,2.0\n").unwrap();
        assert!(load_csv(&path).is_err());
    }
}

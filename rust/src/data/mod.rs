//! Dataset substrate: synthetic generators (paper §4), sample
//! decomposition across nodes, and the delayed feature-decomposition plan.

pub mod io;
pub mod partition;
pub mod synthetic;

pub use partition::{FeaturePlan, Shard};
pub use synthetic::{SyntheticSpec, Task};

use crate::linalg::Matrix;

/// A distributed dataset: one shard per computational node plus the ground
/// truth used for recovery metrics.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub shards: Vec<Shard>,
    /// Planted coefficients, flattened (n * width).
    pub x_true: Vec<f64>,
    /// Planted support (indices into the flattened coefficient vector).
    pub support_true: Vec<usize>,
    pub n_features: usize,
    /// Label / prediction width (1, or k for softmax).
    pub width: usize,
}

impl Dataset {
    pub fn total_samples(&self) -> usize {
        self.shards.iter().map(|s| s.a.rows).sum()
    }

    pub fn nodes(&self) -> usize {
        self.shards.len()
    }

    /// Stack all shards back into one (m_total, n) matrix + labels —
    /// used by the centralized baselines (Lasso, MIP, IHT).
    pub fn stacked(&self) -> (Matrix, Vec<f32>) {
        let m_total = self.total_samples();
        let mut a = Matrix::zeros(m_total, self.n_features);
        let mut labels = Vec::with_capacity(m_total * self.width);
        let mut row = 0;
        for shard in &self.shards {
            let bytes = shard.a.rows * self.n_features;
            a.data[row * self.n_features..row * self.n_features + bytes]
                .copy_from_slice(&shard.a.data);
            labels.extend_from_slice(&shard.labels);
            row += shard.a.rows;
        }
        (a, labels)
    }
}

//! Dataset substrate: synthetic generators (paper §4), sample
//! decomposition across nodes, and the delayed feature-decomposition plan.

pub mod io;
/// Sample decomposition, shard storage, and the feature plan.
pub mod partition;
/// `PSD1` out-of-core shard files: mmap reader + streaming converter.
pub mod shardfile;
/// Synthetic dataset generators (paper §4).
pub mod synthetic;

pub use partition::{FeaturePlan, Shard, ShardData, SparseMode};
pub use shardfile::{ConvertInput, ConvertOptions, ConvertSummary, MappedShard};
pub use shardfile::{convert, open_dataset, open_shard, shard_path};
pub use synthetic::{SyntheticSpec, Task};

use crate::linalg::Matrix;

/// A distributed dataset: one shard per computational node plus the ground
/// truth used for recovery metrics.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// One shard per computational node.
    pub shards: Vec<Shard>,
    /// Planted coefficients, flattened (n * width).
    pub x_true: Vec<f64>,
    /// Planted support (indices into the flattened coefficient vector).
    pub support_true: Vec<usize>,
    /// Feature count n (columns of every shard).
    pub n_features: usize,
    /// Label / prediction width (1, or k for softmax).
    pub width: usize,
}

impl Dataset {
    /// Total samples over all shards.
    pub fn total_samples(&self) -> usize {
        self.shards.iter().map(|s| s.rows()).sum()
    }

    /// Number of shards (computational nodes).
    pub fn nodes(&self) -> usize {
        self.shards.len()
    }

    /// Stored-entry fraction over all shards (weighting each by size).
    pub fn density(&self) -> f64 {
        let size: usize = self.shards.iter().map(|s| s.rows() * s.data.cols()).sum();
        if size == 0 {
            return 1.0;
        }
        let nnz: usize = self.shards.iter().map(|s| s.data.nnz()).sum();
        nnz as f64 / size as f64
    }

    /// Convert every shard's storage per the policy (see
    /// [`ShardData::with_policy`]) — the "partition time" storage decision
    /// the `--sparse` CLI and `platform.sparse_threshold` config drive.
    pub fn apply_storage(&mut self, mode: SparseMode, threshold: f64) {
        for shard in self.shards.iter_mut() {
            shard.data = shard.data.with_policy(mode, threshold);
        }
    }

    /// Re-split all samples into `nodes` row shards, as evenly as
    /// possible, preserving row order and storage kind (CSR stays CSR
    /// when every source shard is CSR; otherwise the result is dense).
    /// This is how a single-shard dataset from `io::load_libsvm` /
    /// `io::load_csv` becomes a distributed one.
    pub fn resplit(&self, nodes: usize) -> Dataset {
        let total = self.total_samples();
        assert!(nodes > 0, "need at least one node");
        assert!(total >= nodes, "cannot split {total} samples across {nodes} nodes");
        let n = self.n_features;
        let sizes = partition::shard_sizes(total, nodes);
        let all_csr = self.shards.iter().all(|s| s.data.is_csr());
        // dense row access is only materialized when the output is dense
        let dense_src: Vec<Option<std::sync::Arc<Matrix>>> = self
            .shards
            .iter()
            .map(|s| if all_csr { None } else { Some(s.data.to_dense()) })
            .collect();
        // prefix offsets of source shards for global-row lookup
        let mut src_off = vec![0usize];
        for s in &self.shards {
            src_off.push(src_off.last().unwrap() + s.rows());
        }
        let locate = |g: usize| -> (usize, usize) {
            let si = src_off.partition_point(|&o| o <= g) - 1;
            (si, g - src_off[si])
        };
        let mut shards_out = Vec::with_capacity(nodes);
        let mut g0 = 0usize;
        for &count in &sizes {
            let g1 = g0 + count;
            let mut labels = Vec::with_capacity(count * self.width);
            if all_csr {
                let mut rows: Vec<Vec<(u32, f32)>> = Vec::with_capacity(count);
                for g in g0..g1 {
                    let (si, r) = locate(g);
                    let (cols, vals) = match &self.shards[si].data {
                        ShardData::Csr(c) => c.row(r),
                        ShardData::Mapped(m) => m.csr_row(r),
                        ShardData::Dense(_) => unreachable!("all_csr checked"),
                    };
                    rows.push(cols.iter().copied().zip(vals.iter().copied()).collect());
                    labels.extend_from_slice(
                        &self.shards[si].labels[r * self.width..(r + 1) * self.width],
                    );
                }
                shards_out.push(Shard {
                    data: ShardData::Csr(std::sync::Arc::new(
                        crate::linalg::CsrMatrix::from_rows(n, rows),
                    )),
                    labels,
                    width: self.width,
                });
            } else {
                let mut a = Matrix::zeros(count, n);
                for (out_r, g) in (g0..g1).enumerate() {
                    let (si, r) = locate(g);
                    let src = dense_src[si].as_ref().unwrap();
                    a.row_mut(out_r).copy_from_slice(src.row(r));
                    labels.extend_from_slice(
                        &self.shards[si].labels[r * self.width..(r + 1) * self.width],
                    );
                }
                shards_out.push(Shard::dense(a, labels, self.width));
            }
            g0 = g1;
        }
        Dataset {
            shards: shards_out,
            x_true: self.x_true.clone(),
            support_true: self.support_true.clone(),
            n_features: n,
            width: self.width,
        }
    }

    /// Stack all shards back into one (m_total, n) matrix + labels —
    /// used by the centralized baselines (Lasso, MIP, IHT).  CSR shards
    /// scatter their stored entries directly into the output (no dense
    /// intermediate).
    pub fn stacked(&self) -> (Matrix, Vec<f32>) {
        let m_total = self.total_samples();
        let n = self.n_features;
        let mut a = Matrix::zeros(m_total, n);
        let mut labels = Vec::with_capacity(m_total * self.width);
        let mut row = 0;
        for shard in &self.shards {
            match &shard.data {
                ShardData::Dense(d) => {
                    for r in 0..d.rows {
                        a.row_mut(row + r).copy_from_slice(d.row(r));
                    }
                }
                ShardData::Csr(c) => {
                    for r in 0..c.rows {
                        let (cols, vals) = c.row(r);
                        let dst = a.row_mut(row + r);
                        for (&cc, &v) in cols.iter().zip(vals) {
                            dst[cc as usize] = v;
                        }
                    }
                }
                ShardData::Mapped(m) => {
                    for r in 0..m.rows() {
                        if m.is_csr() {
                            let (cols, vals) = m.csr_row(r);
                            let dst = a.row_mut(row + r);
                            for (&cc, &v) in cols.iter().zip(vals) {
                                dst[cc as usize] = v;
                            }
                        } else {
                            a.row_mut(row + r).copy_from_slice(m.dense_row(r));
                        }
                    }
                }
            }
            labels.extend_from_slice(&shard.labels);
            row += shard.rows();
        }
        (a, labels)
    }
}

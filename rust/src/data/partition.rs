//! Decomposition plans.
//!
//! * Sample decomposition (network level): each node holds a row shard of
//!   the global dataset — done at generation time, `Shard` is the result.
//! * Feature decomposition (device level, the paper's "delayed"
//!   decomposition): each node splits its columns into M blocks, one per
//!   device queue, padded to the artifact's `block_n`.

use std::sync::Arc;

use super::shardfile::MappedShard;
use crate::linalg::{CsrMatrix, Matrix};

/// Storage-format policy for shard design matrices (config
/// `platform.sparse` / `psfit train --sparse {auto,always,never}`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SparseMode {
    /// Pick CSR when the measured density is at or below the threshold.
    Auto,
    /// Force CSR storage regardless of density.
    Always,
    /// Force dense storage (the historical behaviour).
    Never,
}

impl SparseMode {
    /// Parse a CLI/JSON storage-mode name.
    pub fn parse(s: &str) -> anyhow::Result<SparseMode> {
        match s {
            "auto" => Ok(SparseMode::Auto),
            "always" | "csr" => Ok(SparseMode::Always),
            "never" | "dense" => Ok(SparseMode::Never),
            other => anyhow::bail!("unknown sparse mode `{other}` (auto|always|never)"),
        }
    }

    /// Canonical name (the inverse of [`SparseMode::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            SparseMode::Auto => "auto",
            SparseMode::Always => "always",
            SparseMode::Never => "never",
        }
    }
}

/// A shard's design matrix in one of the supported storage formats — the
/// repo's first storage abstraction, the seam later device-side sparse
/// formats (CSC, blocked-ELL) plug into.  Reference-counted either way so
/// backends hold the data without copying.
#[derive(Debug, Clone)]
pub enum ShardData {
    /// Row-major dense — read in place through stride-aware
    /// [`crate::linalg::ColumnBlockView`]s.
    Dense(Arc<Matrix>),
    /// Compressed sparse rows — read in place through per-column-block
    /// [`crate::linalg::CsrBlockView`]s.
    Csr(Arc<CsrMatrix>),
    /// Out-of-core: a `PSD1` shard file consumed in place off a read-only
    /// memory map, in either of the two layouts above (bit-identical to
    /// its resident twin — see `data::shardfile`).
    Mapped(Arc<MappedShard>),
}

impl ShardData {
    /// Row count, independent of storage kind.
    pub fn rows(&self) -> usize {
        match self {
            ShardData::Dense(a) => a.rows,
            ShardData::Csr(c) => c.rows,
            ShardData::Mapped(m) => m.rows(),
        }
    }

    /// Column count, independent of storage kind.
    pub fn cols(&self) -> usize {
        match self {
            ShardData::Dense(a) => a.cols,
            ShardData::Csr(c) => c.cols,
            ShardData::Mapped(m) => m.cols(),
        }
    }

    /// Nonzero count (dense storage counts on demand; mapped shards
    /// answer from their header, which records the same quantity for the
    /// matching resident kind).
    pub fn nnz(&self) -> usize {
        match self {
            ShardData::Dense(a) => (0..a.rows)
                .map(|i| a.row(i).iter().filter(|&&v| v != 0.0).count())
                .sum(),
            ShardData::Csr(c) => c.nnz(),
            ShardData::Mapped(m) => m.nnz(),
        }
    }

    /// Nonzero fraction in [0, 1] (1.0 for empty shapes, so the storage
    /// policy never picks CSR for degenerate data).
    pub fn density(&self) -> f64 {
        let size = self.rows() * self.cols();
        if size == 0 {
            1.0
        } else {
            self.nnz() as f64 / size as f64
        }
    }

    /// Whether the shard's *layout* is CSR (true for both resident CSR
    /// and csr-mapped storage).
    pub fn is_csr(&self) -> bool {
        match self {
            ShardData::Csr(_) => true,
            ShardData::Mapped(m) => m.is_csr(),
            ShardData::Dense(_) => false,
        }
    }

    /// Whether the shard is consumed off a memory map.
    pub fn is_mapped(&self) -> bool {
        matches!(self, ShardData::Mapped(_))
    }

    /// "dense", "csr", "mapped-dense" or "mapped-csr" — for reports and
    /// tests.
    pub fn storage_name(&self) -> &'static str {
        match self {
            ShardData::Dense(_) => "dense",
            ShardData::Csr(_) => "csr",
            ShardData::Mapped(m) if m.is_csr() => "mapped-csr",
            ShardData::Mapped(_) => "mapped-dense",
        }
    }

    /// The resident dense storage, if that is the active kind.
    pub fn as_dense(&self) -> Option<&Arc<Matrix>> {
        match self {
            ShardData::Dense(a) => Some(a),
            _ => None,
        }
    }

    /// The resident CSR storage, if that is the active kind.
    pub fn as_csr(&self) -> Option<&Arc<CsrMatrix>> {
        match self {
            ShardData::Csr(c) => Some(c),
            _ => None,
        }
    }

    /// The mapped storage, if that is the active kind.
    pub fn as_mapped(&self) -> Option<&Arc<MappedShard>> {
        match self {
            ShardData::Mapped(m) => Some(m),
            _ => None,
        }
    }

    /// Dense view of the data: a cheap `Arc` clone for dense storage, a
    /// materialization for CSR and mapped shards (the XLA staging path and
    /// the centralized baselines need packed rows).
    pub fn to_dense(&self) -> Arc<Matrix> {
        match self {
            ShardData::Dense(a) => a.clone(),
            ShardData::Csr(c) => Arc::new(c.to_dense()),
            ShardData::Mapped(m) => Arc::new(m.to_matrix()),
        }
    }

    /// CSR view of the data: a cheap `Arc` clone for CSR storage, a
    /// compression/materialization otherwise.
    pub fn to_csr(&self) -> Arc<CsrMatrix> {
        match self {
            ShardData::Dense(a) => Arc::new(CsrMatrix::from_dense(a)),
            ShardData::Csr(c) => c.clone(),
            ShardData::Mapped(m) => Arc::new(m.to_csr_matrix()),
        }
    }

    /// y = A x, dispatched on storage kind.
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        match self {
            ShardData::Dense(a) => a.matvec(x, y),
            ShardData::Csr(c) => c.spmv(x, y),
            ShardData::Mapped(m) => m.matvec(x, y),
        }
    }

    /// y = A^T v, dispatched on storage kind.
    pub fn matvec_t(&self, v: &[f32], y: &mut [f32]) {
        match self {
            ShardData::Dense(a) => a.matvec_t(v, y),
            ShardData::Csr(c) => c.spmv_t(v, y),
            ShardData::Mapped(m) => m.matvec_t(v, y),
        }
    }

    /// The storage the policy picks for this data (cheap `Arc` clone when
    /// no conversion is needed).  `Auto` compares the measured density
    /// against `threshold` (CSR at or below it).  A mapped shard whose
    /// layout already matches the decision stays mapped — out-of-core data
    /// is only materialized when the policy demands the *other* layout.
    pub fn with_policy(&self, mode: SparseMode, threshold: f64) -> ShardData {
        let want_csr = match mode {
            SparseMode::Always => true,
            SparseMode::Never => false,
            SparseMode::Auto => self.density() <= threshold,
        };
        if let ShardData::Mapped(m) = self {
            if m.is_csr() == want_csr {
                return self.clone();
            }
        }
        if want_csr {
            ShardData::Csr(self.to_csr())
        } else {
            ShardData::Dense(self.to_dense())
        }
    }
}

/// One node's local data.
///
/// The design matrix is reference-counted so backends can hold it without
/// copying: the native backend reads its feature blocks in place through
/// stride-aware [`crate::linalg::ColumnBlockView`]s (dense storage) or
/// per-block [`crate::linalg::CsrBlockView`]s (CSR storage) — the paper's
/// "delayed" decomposition is a view either way, not a packing copy.
#[derive(Debug, Clone)]
pub struct Shard {
    /// The design matrix in its chosen storage format.
    pub data: ShardData,
    /// Row-major (rows, width) labels.
    pub labels: Vec<f32>,
    /// Label width (1, or k for softmax).
    pub width: usize,
}

impl Shard {
    /// Dense-backed shard (the historical constructor shape).
    pub fn dense(a: Matrix, labels: Vec<f32>, width: usize) -> Shard {
        Shard {
            data: ShardData::Dense(Arc::new(a)),
            labels,
            width,
        }
    }

    /// Samples in this shard.
    pub fn rows(&self) -> usize {
        self.data.rows()
    }

    /// This shard with its storage converted per the policy (labels are
    /// cloned; the design matrix is `Arc`-shared when no conversion is
    /// needed).
    pub fn with_storage_policy(&self, mode: SparseMode, threshold: f64) -> Shard {
        Shard {
            data: self.data.with_policy(mode, threshold),
            labels: self.labels.clone(),
            width: self.width,
        }
    }
}

/// The feature-decomposition plan for one node: M column blocks.
#[derive(Debug, Clone, PartialEq)]
pub struct FeaturePlan {
    /// Total features covered by the plan.
    pub n: usize,
    /// Number of blocks (devices engaged).
    pub blocks: usize,
    /// (start, width) of each block, covering 0..n disjointly in order.
    pub ranges: Vec<(usize, usize)>,
    /// Artifact block width (blocks are zero-padded to this for the XLA
    /// backend; the native backend uses the exact width).
    pub padded_width: usize,
}

impl FeaturePlan {
    /// Split `n` features into at most `max_blocks` blocks of width at most
    /// `block_n` each.  Blocks are as even as possible; every feature is
    /// covered exactly once.
    pub fn new(n: usize, max_blocks: usize, block_n: usize) -> FeaturePlan {
        assert!(n > 0 && max_blocks > 0 && block_n > 0);
        let needed = n.div_ceil(block_n);
        let blocks = needed.max(max_blocks.min(n));
        // distribute n over `blocks` as evenly as possible
        let base = n / blocks;
        let extra = n % blocks;
        let mut ranges = Vec::with_capacity(blocks);
        let mut start = 0;
        for b in 0..blocks {
            let w = base + usize::from(b < extra);
            if w == 0 {
                continue;
            }
            ranges.push((start, w));
            start += w;
        }
        debug_assert_eq!(start, n);
        let max_w = ranges.iter().map(|&(_, w)| w).max().unwrap_or(0);
        assert!(
            max_w <= block_n,
            "block width {max_w} exceeds artifact block_n {block_n}"
        );
        FeaturePlan {
            n,
            blocks: ranges.len(),
            ranges,
            padded_width: block_n,
        }
    }

    /// Scatter a block-local vector back into the global coefficient vector.
    pub fn scatter(&self, block: usize, local: &[f64], global: &mut [f64]) {
        let (start, w) = self.ranges[block];
        global[start..start + w].copy_from_slice(&local[..w]);
    }

    /// Gather the global vector's slice for one block (padded with zeros to
    /// `len`, which is `padded_width` on the XLA path).
    pub fn gather(&self, block: usize, global: &[f64], len: usize, out: &mut Vec<f64>) {
        let (start, w) = self.ranges[block];
        out.clear();
        out.extend_from_slice(&global[start..start + w]);
        out.resize(len.max(w), 0.0);
    }
}

/// Split `m_total` samples into `nodes` shard sizes (as even as possible).
pub fn shard_sizes(m_total: usize, nodes: usize) -> Vec<usize> {
    assert!(nodes > 0);
    let base = m_total / nodes;
    let extra = m_total % nodes;
    (0..nodes)
        .map(|i| base + usize::from(i < extra))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_sizes_cover_total() {
        for (m, n) in [(10, 3), (100, 4), (7, 7), (5, 8)] {
            let sizes = shard_sizes(m, n);
            assert_eq!(sizes.iter().sum::<usize>(), m);
            let mx = *sizes.iter().max().unwrap();
            let mn = *sizes.iter().min().unwrap();
            assert!(mx - mn <= 1);
        }
    }

    #[test]
    fn feature_plan_covers_disjointly() {
        for (n, blocks, bn) in [(100, 4, 512), (1000, 3, 512), (513, 1, 512), (512, 2, 512)] {
            let plan = FeaturePlan::new(n, blocks, bn);
            let mut covered = vec![false; n];
            for &(s, w) in &plan.ranges {
                for i in s..s + w {
                    assert!(!covered[i], "overlap at {i}");
                    covered[i] = true;
                }
                assert!(w <= bn);
            }
            assert!(covered.iter().all(|&c| c), "n={n} blocks={blocks}");
        }
    }

    #[test]
    fn feature_plan_splits_when_exceeding_block_n() {
        // 1000 features with block_n=512 needs at least 2 blocks even if
        // the caller asked for 1.
        let plan = FeaturePlan::new(1000, 1, 512);
        assert!(plan.blocks >= 2);
    }

    #[test]
    fn shard_data_policy_picks_storage_by_density() {
        // 2 nonzeros in 8 entries: density 0.25
        let a = Matrix::from_rows(vec![vec![1.0, 0.0, 0.0, 0.0], vec![0.0, 0.0, 2.0, 0.0]]);
        let d = ShardData::Dense(Arc::new(a));
        assert!((d.density() - 0.25).abs() < 1e-12);
        assert!(d.with_policy(SparseMode::Auto, 0.25).is_csr());
        assert!(!d.with_policy(SparseMode::Auto, 0.2).is_csr());
        assert!(d.with_policy(SparseMode::Always, 0.0).is_csr());
        let back = d
            .with_policy(SparseMode::Always, 0.0)
            .with_policy(SparseMode::Never, 0.0);
        assert_eq!(*back.to_dense(), *d.to_dense());
        assert_eq!(back.storage_name(), "dense");
    }

    #[test]
    fn shard_data_matvec_dispatches_identically() {
        let a = Matrix::from_rows(vec![vec![1.0, 0.0, 3.0], vec![0.0, -2.0, 0.0]]);
        let dense = ShardData::Dense(Arc::new(a));
        let csr = dense.with_policy(SparseMode::Always, 0.0);
        let x = [1.0f32, 2.0, -1.0];
        let v = [0.5f32, 4.0];
        let (mut y0, mut y1) = (vec![0.0f32; 2], vec![0.0f32; 2]);
        dense.matvec(&x, &mut y0);
        csr.matvec(&x, &mut y1);
        assert_eq!(y0, vec![-2.0, -4.0]);
        assert_eq!(y0, y1);
        let (mut z0, mut z1) = (vec![0.0f32; 3], vec![0.0f32; 3]);
        dense.matvec_t(&v, &mut z0);
        csr.matvec_t(&v, &mut z1);
        assert_eq!(z0, vec![0.5, -8.0, 1.5]);
        assert_eq!(z0, z1);
    }

    #[test]
    fn sparse_mode_parses() {
        assert_eq!(SparseMode::parse("auto").unwrap(), SparseMode::Auto);
        assert_eq!(SparseMode::parse("always").unwrap(), SparseMode::Always);
        assert_eq!(SparseMode::parse("dense").unwrap(), SparseMode::Never);
        assert!(SparseMode::parse("maybe").is_err());
        assert_eq!(SparseMode::Never.name(), "never");
    }

    #[test]
    fn scatter_gather_roundtrip() {
        let plan = FeaturePlan::new(10, 3, 512);
        let global: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let mut out = Vec::new();
        let mut rebuilt = vec![0.0; 10];
        for b in 0..plan.blocks {
            plan.gather(b, &global, 512, &mut out);
            assert_eq!(out.len(), 512);
            plan.scatter(b, &out, &mut rebuilt);
        }
        assert_eq!(rebuilt, global);
    }
}

//! Decomposition plans.
//!
//! * Sample decomposition (network level): each node holds a row shard of
//!   the global dataset — done at generation time, `Shard` is the result.
//! * Feature decomposition (device level, the paper's "delayed"
//!   decomposition): each node splits its columns into M blocks, one per
//!   device queue, padded to the artifact's `block_n`.

use std::sync::Arc;

use crate::linalg::Matrix;

/// One node's local data.
///
/// The design matrix is reference-counted so backends can hold it without
/// copying: the native backend reads its feature blocks in place through
/// stride-aware [`crate::linalg::ColumnBlockView`]s (the paper's "delayed"
/// decomposition becomes a view, not a packing copy).
#[derive(Debug, Clone)]
pub struct Shard {
    pub a: Arc<Matrix>,
    /// Row-major (rows, width) labels.
    pub labels: Vec<f32>,
    pub width: usize,
}

/// The feature-decomposition plan for one node: M column blocks.
#[derive(Debug, Clone, PartialEq)]
pub struct FeaturePlan {
    pub n: usize,
    /// Number of blocks (devices engaged).
    pub blocks: usize,
    /// (start, width) of each block, covering 0..n disjointly in order.
    pub ranges: Vec<(usize, usize)>,
    /// Artifact block width (blocks are zero-padded to this for the XLA
    /// backend; the native backend uses the exact width).
    pub padded_width: usize,
}

impl FeaturePlan {
    /// Split `n` features into at most `max_blocks` blocks of width at most
    /// `block_n` each.  Blocks are as even as possible; every feature is
    /// covered exactly once.
    pub fn new(n: usize, max_blocks: usize, block_n: usize) -> FeaturePlan {
        assert!(n > 0 && max_blocks > 0 && block_n > 0);
        let needed = n.div_ceil(block_n);
        let blocks = needed.max(max_blocks.min(n));
        // distribute n over `blocks` as evenly as possible
        let base = n / blocks;
        let extra = n % blocks;
        let mut ranges = Vec::with_capacity(blocks);
        let mut start = 0;
        for b in 0..blocks {
            let w = base + usize::from(b < extra);
            if w == 0 {
                continue;
            }
            ranges.push((start, w));
            start += w;
        }
        debug_assert_eq!(start, n);
        let max_w = ranges.iter().map(|&(_, w)| w).max().unwrap_or(0);
        assert!(
            max_w <= block_n,
            "block width {max_w} exceeds artifact block_n {block_n}"
        );
        FeaturePlan {
            n,
            blocks: ranges.len(),
            ranges,
            padded_width: block_n,
        }
    }

    /// Scatter a block-local vector back into the global coefficient vector.
    pub fn scatter(&self, block: usize, local: &[f64], global: &mut [f64]) {
        let (start, w) = self.ranges[block];
        global[start..start + w].copy_from_slice(&local[..w]);
    }

    /// Gather the global vector's slice for one block (padded with zeros to
    /// `len`, which is `padded_width` on the XLA path).
    pub fn gather(&self, block: usize, global: &[f64], len: usize, out: &mut Vec<f64>) {
        let (start, w) = self.ranges[block];
        out.clear();
        out.extend_from_slice(&global[start..start + w]);
        out.resize(len.max(w), 0.0);
    }
}

/// Split `m_total` samples into `nodes` shard sizes (as even as possible).
pub fn shard_sizes(m_total: usize, nodes: usize) -> Vec<usize> {
    assert!(nodes > 0);
    let base = m_total / nodes;
    let extra = m_total % nodes;
    (0..nodes)
        .map(|i| base + usize::from(i < extra))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_sizes_cover_total() {
        for (m, n) in [(10, 3), (100, 4), (7, 7), (5, 8)] {
            let sizes = shard_sizes(m, n);
            assert_eq!(sizes.iter().sum::<usize>(), m);
            let mx = *sizes.iter().max().unwrap();
            let mn = *sizes.iter().min().unwrap();
            assert!(mx - mn <= 1);
        }
    }

    #[test]
    fn feature_plan_covers_disjointly() {
        for (n, blocks, bn) in [(100, 4, 512), (1000, 3, 512), (513, 1, 512), (512, 2, 512)] {
            let plan = FeaturePlan::new(n, blocks, bn);
            let mut covered = vec![false; n];
            for &(s, w) in &plan.ranges {
                for i in s..s + w {
                    assert!(!covered[i], "overlap at {i}");
                    covered[i] = true;
                }
                assert!(w <= bn);
            }
            assert!(covered.iter().all(|&c| c), "n={n} blocks={blocks}");
        }
    }

    #[test]
    fn feature_plan_splits_when_exceeding_block_n() {
        // 1000 features with block_n=512 needs at least 2 blocks even if
        // the caller asked for 1.
        let plan = FeaturePlan::new(1000, 1, 512);
        assert!(plan.blocks >= 2);
    }

    #[test]
    fn scatter_gather_roundtrip() {
        let plan = FeaturePlan::new(10, 3, 512);
        let global: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let mut out = Vec::new();
        let mut rebuilt = vec![0.0; 10];
        for b in 0..plan.blocks {
            plan.gather(b, &global, 512, &mut out);
            assert_eq!(out.len(), 512);
            plan.scatter(b, &out, &mut rebuilt);
        }
        assert_eq!(rebuilt, global);
    }
}

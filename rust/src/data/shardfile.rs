//! `PSD1` — the out-of-core shard file format and its streaming converter.
//!
//! A `PSD1` file holds ONE node's shard (design matrix + labels) in a
//! layout that memory-maps straight into the SIMD kernel layer with zero
//! copy: dense payloads are stored at the exact padded row stride of
//! [`Matrix`] (64-byte-aligned row starts), CSR payloads store the exact
//! four arrays of [`CsrMatrix`] *including* the `SIMD_PAD` run padding, so
//! a mapped shard and its RAM-resident twin are bit-identical inputs to
//! every kernel — the property `tests/oocore.rs` pins end to end.
//!
//! # Layout (little-endian, 144-byte header, 64-byte-aligned sections)
//!
//! ```text
//! off   0  magic "PSD1"
//! off   4  u32 version (1)
//! off   8  u32 kind (0 dense | 1 csr)
//! off  12  u32 width (label columns)
//! off  16  u64 rows | 24 u64 cols | 32 u64 stride (dense; 0 csr) | 40 u64 nnz
//! off  48  5 x (u64 offset, u64 len): labels, then
//!            dense: vals(padded rows x stride f32), -, -, -
//!            csr:   row_ptr(u64), row_len(u64), col_idx(u32), vals(f32)
//! off 128  u64 reserved (0)
//! off 136  u64 FNV-1a checksum of bytes [0, 136)
//! ```
//!
//! Every section offset is a multiple of 64; mappings are page-aligned, so
//! mapped sections inherit the alignment [`crate::linalg::AlignedVec`]
//! guarantees for resident storage.  The header checksum guards the
//! *structure*; payload sections are not checksummed (faulting a terabyte
//! shard to verify it would defeat the point).  Structural CSR arrays
//! (`row_ptr`/`row_len`) are decoded and bounds-validated at open, so a
//! corrupt payload can at worst produce a Rust bounds panic — never UB or
//! a silent partial read.  All open errors are prefixed `psd1:` with a
//! stable name per failure mode.
//!
//! The converter ([`convert`]) turns LIBSVM/CSV input into one `PSD1` file
//! per node in two streaming passes — O(rows) bookkeeping (labels, per-row
//! entry counts), never the full matrix — and reproduces the resident
//! pipeline (`io::load_libsvm` → `Dataset::resplit` → storage policy)
//! bit-for-bit.

use std::fs::File;
use std::io::{BufRead, BufReader, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use super::io::{parse_csv_line, parse_libsvm_line, CsvLine, SvmLine};
use super::partition::{shard_sizes, Shard, ShardData, SparseMode};
use crate::linalg::csr::SIMD_PAD;
use crate::linalg::matrix::padded_stride;
use crate::linalg::{ColumnBlockView, CsrBlockView, CsrMatrix, CsrParts, Matrix};
use crate::util::mmap::Mmap;
use crate::util::{fnv1a, fnv1a_fold};

/// File magic.
pub const MAGIC: &[u8; 4] = b"PSD1";
/// Current format version.
pub const VERSION: u32 = 1;
/// Header length in bytes (checksum included).
pub const HEADER_LEN: usize = 144;
/// Section alignment in bytes.
pub const ALIGN: usize = 64;

const KIND_DENSE: u32 = 0;
const KIND_CSR: u32 = 1;

const SEC_LABELS: usize = 0;
const SEC_DENSE_VALS: usize = 1;
const SEC_ROW_PTR: usize = 1;
const SEC_ROW_LEN: usize = 2;
const SEC_COL_IDX: usize = 3;
const SEC_VALS: usize = 4;

fn align_up(x: u64) -> u64 {
    x.div_ceil(ALIGN as u64) * ALIGN as u64
}

/// Decoded header fields.
#[derive(Debug, Clone)]
struct Header {
    kind: u32,
    width: usize,
    rows: usize,
    cols: usize,
    stride: usize,
    nnz: usize,
    sections: [(u64, u64); 5],
}

impl Header {
    fn encode(&self) -> [u8; HEADER_LEN] {
        let mut b = [0u8; HEADER_LEN];
        b[0..4].copy_from_slice(MAGIC);
        b[4..8].copy_from_slice(&VERSION.to_le_bytes());
        b[8..12].copy_from_slice(&self.kind.to_le_bytes());
        b[12..16].copy_from_slice(&(self.width as u32).to_le_bytes());
        b[16..24].copy_from_slice(&(self.rows as u64).to_le_bytes());
        b[24..32].copy_from_slice(&(self.cols as u64).to_le_bytes());
        b[32..40].copy_from_slice(&(self.stride as u64).to_le_bytes());
        b[40..48].copy_from_slice(&(self.nnz as u64).to_le_bytes());
        for (i, &(off, len)) in self.sections.iter().enumerate() {
            let at = 48 + i * 16;
            b[at..at + 8].copy_from_slice(&off.to_le_bytes());
            b[at + 8..at + 16].copy_from_slice(&len.to_le_bytes());
        }
        // bytes 128..136 reserved (zero)
        let sum = fnv1a(&b[..136]);
        b[136..144].copy_from_slice(&sum.to_le_bytes());
        b
    }

    fn decode(bytes: &[u8]) -> anyhow::Result<Header> {
        anyhow::ensure!(bytes.len() >= HEADER_LEN, "psd1: truncated header");
        anyhow::ensure!(&bytes[0..4] == MAGIC, "psd1: bad magic");
        let u32_at = |o: usize| u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap());
        let u64_at = |o: usize| u64::from_le_bytes(bytes[o..o + 8].try_into().unwrap());
        anyhow::ensure!(
            u64_at(136) == fnv1a(&bytes[..136]),
            "psd1: header checksum mismatch"
        );
        let version = u32_at(4);
        anyhow::ensure!(version == VERSION, "psd1: unsupported version {version}");
        let kind = u32_at(8);
        anyhow::ensure!(
            kind == KIND_DENSE || kind == KIND_CSR,
            "psd1: unknown shard kind {kind}"
        );
        let as_usize = |v: u64| -> anyhow::Result<usize> {
            usize::try_from(v).map_err(|_| anyhow::anyhow!("psd1: header field overflow"))
        };
        let mut sections = [(0u64, 0u64); 5];
        for (i, s) in sections.iter_mut().enumerate() {
            *s = (u64_at(48 + i * 16), u64_at(48 + i * 16 + 8));
        }
        Ok(Header {
            kind,
            width: u32_at(12) as usize,
            rows: as_usize(u64_at(16))?,
            cols: as_usize(u64_at(24))?,
            stride: as_usize(u64_at(32))?,
            nnz: as_usize(u64_at(40))?,
            sections,
        })
    }

    /// Section offsets laid out sequentially from the first aligned
    /// position after the header, given the section byte lengths.
    fn layout(lens: [u64; 5]) -> [(u64, u64); 5] {
        let mut sections = [(0u64, 0u64); 5];
        let mut pos = align_up(HEADER_LEN as u64);
        for (i, &len) in lens.iter().enumerate() {
            if len > 0 {
                sections[i] = (pos, len);
                pos = align_up(pos + len);
            }
        }
        sections
    }
}

// ---------------------------------------------------------------------------
// Writing
// ---------------------------------------------------------------------------

/// Buffered positioned writer for one file section: bytes accumulate and
/// flush at an explicit file offset, so two sections (CSR `col_idx` and
/// `vals`) can interleave row-by-row during a streaming pass without
/// holding either in memory.
struct SectionWriter<'f> {
    file: &'f File,
    pos: u64,
    buf: Vec<u8>,
}

impl<'f> SectionWriter<'f> {
    fn new(file: &'f File, pos: u64) -> SectionWriter<'f> {
        SectionWriter {
            file,
            pos,
            buf: Vec::with_capacity(1 << 18),
        }
    }

    fn write(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        self.buf.extend_from_slice(bytes);
        if self.buf.len() >= 1 << 18 {
            self.flush()?;
        }
        Ok(())
    }

    fn write_f32s(&mut self, xs: &[f32]) -> anyhow::Result<()> {
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
        if self.buf.len() >= 1 << 18 {
            self.flush()?;
        }
        Ok(())
    }

    fn flush(&mut self) -> anyhow::Result<()> {
        if !self.buf.is_empty() {
            let mut f = self.file;
            f.seek(SeekFrom::Start(self.pos))?;
            f.write_all(&self.buf)?;
            self.pos += self.buf.len() as u64;
            self.buf.clear();
        }
        Ok(())
    }

    /// Flush and return the file position one past the last byte written.
    fn finish(mut self) -> anyhow::Result<u64> {
        self.flush()?;
        Ok(self.pos)
    }
}

fn ensure_little_endian() -> anyhow::Result<()> {
    #[cfg(target_endian = "big")]
    anyhow::bail!("psd1: little-endian hosts only");
    #[allow(unreachable_code)]
    Ok(())
}

fn write_header_and_labels(
    file: &File,
    header: &Header,
    labels: &[f32],
) -> anyhow::Result<()> {
    anyhow::ensure!(
        labels.len() == header.rows * header.width,
        "psd1: label shape mismatch at write"
    );
    let mut w = SectionWriter::new(file, 0);
    w.write(&header.encode())?;
    w.finish()?;
    let (off, _) = header.sections[SEC_LABELS];
    let mut w = SectionWriter::new(file, off);
    w.write_f32s(labels)?;
    w.finish()?;
    Ok(())
}

/// Write an in-memory shard to `path` in its current storage kind
/// (mapped shards re-serialize as their underlying kind).
pub fn write_shard(shard: &Shard, path: &Path) -> anyhow::Result<()> {
    match &shard.data {
        ShardData::Dense(a) => write_dense(
            path,
            shard.width,
            &shard.labels,
            a.rows,
            a.cols,
            a.stride(),
            a.padded_data(),
            shard.data.nnz(),
        ),
        ShardData::Csr(c) => write_csr(
            path,
            shard.width,
            &shard.labels,
            c.rows,
            c.cols,
            c.parts(),
            c.nnz(),
        ),
        ShardData::Mapped(m) => {
            if m.is_csr() {
                write_csr(
                    path,
                    shard.width,
                    &shard.labels,
                    m.rows(),
                    m.cols(),
                    m.csr_parts(),
                    m.nnz(),
                )
            } else {
                write_dense(
                    path,
                    shard.width,
                    &shard.labels,
                    m.rows(),
                    m.cols(),
                    m.stride(),
                    m.dense_padded(),
                    m.nnz(),
                )
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn write_dense(
    path: &Path,
    width: usize,
    labels: &[f32],
    rows: usize,
    cols: usize,
    stride: usize,
    padded: &[f32],
    nnz: usize,
) -> anyhow::Result<()> {
    ensure_little_endian()?;
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let file = File::create(path)?;
    let lens = {
        let mut l = [0u64; 5];
        l[SEC_LABELS] = (rows * width * 4) as u64;
        l[SEC_DENSE_VALS] = (rows * stride * 4) as u64;
        l
    };
    let header = Header {
        kind: KIND_DENSE,
        width,
        rows,
        cols,
        stride,
        nnz,
        sections: Header::layout(lens),
    };
    write_header_and_labels(&file, &header, labels)?;
    let mut w = SectionWriter::new(&file, header.sections[SEC_DENSE_VALS].0);
    w.write_f32s(&padded[..rows * stride])?;
    w.finish()?;
    file.sync_all()?;
    Ok(())
}

fn write_csr(
    path: &Path,
    width: usize,
    labels: &[f32],
    rows: usize,
    cols: usize,
    parts: CsrParts<'_>,
    nnz: usize,
) -> anyhow::Result<()> {
    ensure_little_endian()?;
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let file = File::create(path)?;
    let entries = parts.row_ptr[rows];
    let lens = {
        let mut l = [0u64; 5];
        l[SEC_LABELS] = (rows * width * 4) as u64;
        l[SEC_ROW_PTR] = ((rows + 1) * 8) as u64;
        l[SEC_ROW_LEN] = (rows * 8) as u64;
        l[SEC_COL_IDX] = (entries * 4) as u64;
        l[SEC_VALS] = (entries * 4) as u64;
        l
    };
    let header = Header {
        kind: KIND_CSR,
        width,
        rows,
        cols,
        stride: 0,
        nnz,
        sections: Header::layout(lens),
    };
    write_header_and_labels(&file, &header, labels)?;
    let mut w = SectionWriter::new(&file, header.sections[SEC_ROW_PTR].0);
    for &p in parts.row_ptr {
        w.write(&(p as u64).to_le_bytes())?;
    }
    w.finish()?;
    let mut w = SectionWriter::new(&file, header.sections[SEC_ROW_LEN].0);
    for &l in parts.row_len {
        w.write(&(l as u64).to_le_bytes())?;
    }
    w.finish()?;
    let mut w = SectionWriter::new(&file, header.sections[SEC_COL_IDX].0);
    for &c in parts.col_idx {
        w.write(&c.to_le_bytes())?;
    }
    w.finish()?;
    let mut w = SectionWriter::new(&file, header.sections[SEC_VALS].0);
    w.write_f32s(parts.vals)?;
    w.finish()?;
    file.sync_all()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Mapped shards
// ---------------------------------------------------------------------------

#[derive(Debug)]
enum MappedKind {
    Dense {
        stride: usize,
        /// Byte range of the padded values section.
        vals: (usize, usize),
    },
    Csr {
        /// Decoded at open (small, O(rows)); the entry arrays stay mapped.
        row_ptr: Vec<usize>,
        row_len: Vec<usize>,
        col_idx: (usize, usize),
        vals: (usize, usize),
    },
}

/// A `PSD1` shard consumed in place off a read-only memory map — the
/// out-of-core twin of `Dense`/`Csr` storage (see the module docs for the
/// exact bit-parity contract).
#[derive(Debug)]
pub struct MappedShard {
    map: Mmap,
    path: PathBuf,
    kind: MappedKind,
    rows: usize,
    cols: usize,
    width: usize,
    nnz: usize,
}

impl MappedShard {
    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Label width recorded in the header.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Stored-entry count from the header: nonzeros for a dense payload,
    /// real stored entries for CSR — the same semantics as the matching
    /// resident storage, so policy decisions and problem hashes agree.
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Whether the payload is CSR.
    pub fn is_csr(&self) -> bool {
        matches!(self.kind, MappedKind::Csr { .. })
    }

    /// Source file path (for reports).
    pub fn path(&self) -> &Path {
        &self.path
    }

    fn f32_section(&self, range: (usize, usize)) -> &[f32] {
        let (off, len) = range;
        let bytes = &self.map.as_slice()[off..off + len];
        // Safety: offset 64-byte-aligned within a page-aligned map (both
        // validated at open), length a multiple of 4, and any bit pattern
        // is a valid f32.
        unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const f32, len / 4) }
    }

    fn u32_section(&self, range: (usize, usize)) -> &[u32] {
        let (off, len) = range;
        let bytes = &self.map.as_slice()[off..off + len];
        // Safety: as in `f32_section`.
        unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const u32, len / 4) }
    }

    /// Dense padded stride (panics on CSR payloads).
    pub fn stride(&self) -> usize {
        match &self.kind {
            MappedKind::Dense { stride, .. } => *stride,
            MappedKind::Csr { .. } => panic!("stride of a csr-mapped shard"),
        }
    }

    /// The full padded dense payload (`rows * stride` f32), read off the
    /// map — the exact buffer [`Matrix::padded_data`] would hold.
    pub fn dense_padded(&self) -> &[f32] {
        match &self.kind {
            MappedKind::Dense { vals, .. } => self.f32_section(*vals),
            MappedKind::Csr { .. } => panic!("dense payload of a csr-mapped shard"),
        }
    }

    /// Logical row `i` of a dense payload.
    pub fn dense_row(&self, i: usize) -> &[f32] {
        let stride = self.stride();
        &self.dense_padded()[i * stride..i * stride + self.cols]
    }

    /// Whole-shard dense view for the kernel layer.
    pub fn dense_view(&self) -> ColumnBlockView<'_> {
        ColumnBlockView::new(self.dense_padded(), self.rows, self.cols, self.stride(), 0)
    }

    /// The CSR arrays as borrowed [`CsrParts`] (structure arrays decoded
    /// at open, entry arrays straight off the map).
    pub fn csr_parts(&self) -> CsrParts<'_> {
        match &self.kind {
            MappedKind::Csr {
                row_ptr,
                row_len,
                col_idx,
                vals,
            } => CsrParts {
                row_ptr,
                row_len,
                col_idx: self.u32_section(*col_idx),
                vals: self.f32_section(*vals),
            },
            MappedKind::Dense { .. } => panic!("csr parts of a dense-mapped shard"),
        }
    }

    /// Row `i`'s real entries of a CSR payload.
    pub fn csr_row(&self, i: usize) -> (&[u32], &[f32]) {
        self.csr_parts().row(i)
    }

    /// All real stored values in row-major entry order (padding excluded)
    /// — the same stream [`CsrMatrix::values`] yields, so the checkpoint
    /// problem hash samples identically.
    pub fn csr_values(&self) -> impl Iterator<Item = f32> + '_ {
        let parts = self.csr_parts();
        (0..self.rows).flat_map(move |i| parts.row(i).1.iter().copied())
    }

    /// Per-row entry subranges for a column block (CSR payloads).
    pub fn block_ranges(&self, col0: usize, width: usize) -> Vec<(usize, usize)> {
        assert!(col0 + width <= self.cols, "column block out of range");
        self.csr_parts().block_ranges(col0, width)
    }

    /// Block view through precomputed ranges (CSR payloads).
    pub fn block_view<'a>(
        &'a self,
        ranges: &'a [(usize, usize)],
        col0: usize,
        width: usize,
    ) -> CsrBlockView<'a> {
        CsrBlockView::new(self.csr_parts(), 0, self.rows, col0, width, ranges)
    }

    /// Materialize as a resident dense matrix (bit-identical storage).
    pub fn to_matrix(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        match &self.kind {
            MappedKind::Dense { .. } => {
                for i in 0..self.rows {
                    out.row_mut(i).copy_from_slice(self.dense_row(i));
                }
            }
            MappedKind::Csr { .. } => {
                for i in 0..self.rows {
                    let (cols, vals) = self.csr_row(i);
                    let row = out.row_mut(i);
                    for (&c, &v) in cols.iter().zip(vals) {
                        row[c as usize] = v;
                    }
                }
            }
        }
        out
    }

    /// Materialize as a resident CSR matrix (bit-identical arrays: the
    /// builder re-derives the exact padding the file stores).
    pub fn to_csr_matrix(&self) -> CsrMatrix {
        match &self.kind {
            MappedKind::Csr { .. } => {
                let rows: Vec<Vec<(u32, f32)>> = (0..self.rows)
                    .map(|i| {
                        let (cols, vals) = self.csr_row(i);
                        cols.iter().copied().zip(vals.iter().copied()).collect()
                    })
                    .collect();
                CsrMatrix::from_rows(self.cols, rows)
            }
            MappedKind::Dense { .. } => CsrMatrix::from_dense(&self.to_matrix()),
        }
    }

    /// y = A x, dispatched on the mapped payload kind.
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        match &self.kind {
            MappedKind::Dense { .. } => crate::linalg::kernels::matvec(&self.dense_view(), x, y),
            MappedKind::Csr { .. } => {
                crate::linalg::csr::spmv_parts(self.csr_parts(), self.cols, x, y)
            }
        }
    }

    /// y = A^T v, dispatched on the mapped payload kind.
    pub fn matvec_t(&self, v: &[f32], y: &mut [f32]) {
        match &self.kind {
            MappedKind::Dense { .. } => crate::linalg::kernels::matvec_t(&self.dense_view(), v, y),
            MappedKind::Csr { .. } => {
                crate::linalg::csr::spmv_t_parts(self.csr_parts(), self.cols, v, y)
            }
        }
    }
}

/// Open a `PSD1` shard file: validate the header, decode the CSR
/// structure arrays, copy the labels out, and return a [`Shard`] whose
/// design matrix is consumed lazily off the map.
pub fn open_shard(path: &Path) -> anyhow::Result<Shard> {
    ensure_little_endian()?;
    let file = File::open(path).map_err(|e| anyhow::anyhow!("psd1: open {}: {e}", path.display()))?;
    let map = Mmap::map(&file)?;
    let bytes = map.as_slice();
    let header = Header::decode(bytes)?;
    anyhow::ensure!(
        header.width >= 1 && header.rows >= 1 && header.cols >= 1,
        "psd1: degenerate shape"
    );

    let section = |idx: usize, expect_len: Option<u64>| -> anyhow::Result<(usize, usize)> {
        let (off, len) = header.sections[idx];
        anyhow::ensure!(off % ALIGN as u64 == 0, "psd1: misaligned section offset");
        anyhow::ensure!(
            off >= HEADER_LEN as u64 && len % 4 == 0,
            "psd1: corrupt section bounds"
        );
        let end = off
            .checked_add(len)
            .ok_or_else(|| anyhow::anyhow!("psd1: corrupt section bounds"))?;
        anyhow::ensure!(end <= bytes.len() as u64, "psd1: truncated file");
        if let Some(e) = expect_len {
            anyhow::ensure!(len == e, "psd1: section length mismatch");
        }
        Ok((off as usize, len as usize))
    };

    let labels_sec = section(SEC_LABELS, Some((header.rows * header.width * 4) as u64))?;
    let labels: Vec<f32> = bytes[labels_sec.0..labels_sec.0 + labels_sec.1]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();

    let kind = match header.kind {
        KIND_DENSE => {
            anyhow::ensure!(
                header.stride == padded_stride(header.cols),
                "psd1: dense stride mismatch"
            );
            let vals = section(
                SEC_DENSE_VALS,
                Some((header.rows * header.stride * 4) as u64),
            )?;
            MappedKind::Dense {
                stride: header.stride,
                vals,
            }
        }
        KIND_CSR => {
            let ptr_sec = section(SEC_ROW_PTR, Some(((header.rows + 1) * 8) as u64))?;
            let len_sec = section(SEC_ROW_LEN, Some((header.rows * 8) as u64))?;
            let decode_u64s = |(off, len): (usize, usize)| -> anyhow::Result<Vec<usize>> {
                bytes[off..off + len]
                    .chunks_exact(8)
                    .map(|c| {
                        usize::try_from(u64::from_le_bytes(c.try_into().unwrap()))
                            .map_err(|_| anyhow::anyhow!("psd1: corrupt csr index"))
                    })
                    .collect()
            };
            let row_ptr = decode_u64s(ptr_sec)?;
            let row_len = decode_u64s(len_sec)?;
            let entries = row_ptr[header.rows];
            let col_idx = section(SEC_COL_IDX, Some((entries * 4) as u64))?;
            let vals = section(SEC_VALS, Some((entries * 4) as u64))?;
            // structure validation: every row slice must be in bounds so
            // reads can never escape the entry arrays
            for i in 0..header.rows {
                anyhow::ensure!(
                    row_ptr[i] <= row_ptr[i + 1]
                        && row_ptr[i] + row_len[i] <= row_ptr[i + 1]
                        && row_ptr[i + 1] <= entries,
                    "psd1: corrupt csr index"
                );
            }
            MappedKind::Csr {
                row_ptr,
                row_len,
                col_idx,
                vals,
            }
        }
        _ => unreachable!("kind validated in decode"),
    };

    let mapped = MappedShard {
        map,
        path: path.to_path_buf(),
        kind,
        rows: header.rows,
        cols: header.cols,
        width: header.width,
        nnz: header.nnz,
    };
    Ok(Shard {
        width: mapped.width,
        labels,
        data: ShardData::Mapped(Arc::new(mapped)),
    })
}

/// Open a set of `PSD1` shard files (one per node, in roster order) as a
/// [`Dataset`](super::Dataset).  All shards must agree on feature count
/// and label width; planted-truth fields are empty (real data has no
/// oracle support).
pub fn open_dataset(paths: &[PathBuf]) -> anyhow::Result<super::Dataset> {
    anyhow::ensure!(!paths.is_empty(), "psd1: no shard files given");
    let mut shards = Vec::with_capacity(paths.len());
    for p in paths {
        shards.push(open_shard(p)?);
    }
    let cols = shards[0].data.cols();
    let width = shards[0].width;
    for (s, p) in shards.iter().zip(paths) {
        anyhow::ensure!(
            s.data.cols() == cols && s.width == width,
            "psd1: {} has shape ({}, width {}) but {} has ({cols}, width {width})",
            p.display(),
            s.data.cols(),
            s.width,
            paths[0].display()
        );
    }
    Ok(super::Dataset {
        shards,
        x_true: Vec::new(),
        support_true: Vec::new(),
        n_features: cols,
        width,
    })
}

// ---------------------------------------------------------------------------
// Streaming conversion
// ---------------------------------------------------------------------------

/// What `convert` reads.
#[derive(Debug, Clone)]
pub enum ConvertInput {
    /// LIBSVM/SVMLight text (same dialect as `io::load_libsvm`).
    Libsvm(PathBuf),
    /// Dense CSV, last column = label (same dialect as `io::load_csv`).
    Csv(PathBuf),
}

/// Conversion knobs — mirrors the fit-time storage policy so a converted
/// file reproduces the resident pipeline exactly.
#[derive(Debug, Clone)]
pub struct ConvertOptions {
    /// Shard count (one `PSD1` file per node).
    pub nodes: usize,
    /// Storage policy, decided per shard exactly like
    /// [`ShardData::with_policy`] on the resident pipeline.
    pub mode: SparseMode,
    /// Density threshold for [`SparseMode::Auto`].
    pub threshold: f64,
    /// Pin the feature count (else the largest index seen).
    pub n_features: Option<usize>,
    /// Drop rows with non-finite values instead of erroring.
    pub sanitize: bool,
}

/// One emitted shard file.
#[derive(Debug, Clone)]
pub struct ShardReport {
    /// Path of the `PSD1` file.
    pub path: PathBuf,
    /// Rows in this shard.
    pub rows: usize,
    /// "dense" or "csr".
    pub storage: &'static str,
    /// Stored-entry count recorded in the header.
    pub nnz: usize,
}

/// Conversion result summary.
#[derive(Debug, Clone)]
pub struct ConvertSummary {
    /// Per-shard reports, in node order.
    pub shards: Vec<ShardReport>,
    /// Total rows converted.
    pub rows: usize,
    /// Feature count.
    pub cols: usize,
    /// Stored-entry fraction over the whole input.
    pub density: f64,
    /// Rows dropped by `--sanitize`.
    pub dropped: usize,
}

struct Scan {
    rows: usize,
    max_col: usize,
    /// Stored entries per surviving row (LIBSVM: file entries incl.
    /// explicit zeros; CSV: nonzero cells) — the unit the resident
    /// density/policy math uses for the matching storage kind.
    row_entries: Vec<u32>,
    dropped: usize,
}

fn scan_input(input: &ConvertInput, sanitize: bool) -> anyhow::Result<Scan> {
    let mut scan = Scan {
        rows: 0,
        max_col: 0,
        row_entries: Vec::new(),
        dropped: 0,
    };
    let path = match input {
        ConvertInput::Libsvm(p) | ConvertInput::Csv(p) => p,
    };
    let reader = BufReader::new(
        File::open(path).map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?,
    );
    let mut csv_cols: Option<usize> = None;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        match input {
            ConvertInput::Libsvm(_) => match parse_libsvm_line(lineno, &line, sanitize)? {
                SvmLine::Skip => {}
                SvmLine::Dropped => scan.dropped += 1,
                SvmLine::Row(_, entries) => {
                    if let Some(&(last, _)) = entries.last() {
                        scan.max_col = scan.max_col.max(last as usize + 1);
                    }
                    scan.row_entries.push(entries.len() as u32);
                    scan.rows += 1;
                }
            },
            ConvertInput::Csv(_) => match parse_csv_line(lineno, &line, sanitize)? {
                CsvLine::Skip => {}
                CsvLine::Dropped => scan.dropped += 1,
                CsvLine::Row(cells) => {
                    let n = cells.len() - 1;
                    match csv_cols {
                        None => csv_cols = Some(n),
                        Some(c) => anyhow::ensure!(c == n, "ragged rows in csv"),
                    }
                    scan.max_col = scan.max_col.max(n);
                    scan.row_entries
                        .push(cells[..n].iter().filter(|&&v| v != 0.0).count() as u32);
                    scan.rows += 1;
                }
            },
        }
    }
    Ok(scan)
}

fn padded_entries(len: usize) -> usize {
    if len == 0 {
        0
    } else {
        len.div_ceil(SIMD_PAD) * SIMD_PAD
    }
}

/// One parsed input row handed to a sink, in whichever representation the
/// source provides (so zero-sign and explicit-zero semantics match the
/// resident loaders exactly — see the sink methods).
enum RowRef<'a> {
    Sparse(&'a [(u32, f32)]),
    DenseCells(&'a [f32]),
}

/// Streaming writer for one node's `PSD1` file.
struct NodeSink {
    file: File,
    path: PathBuf,
    csr: bool,
    rows_expected: usize,
    rows_seen: usize,
    cols: usize,
    width: usize,
    labels: Vec<f32>,
    nnz: usize,
    // dense state
    stride: usize,
    rowbuf: Vec<f32>,
    dense_pos: u64,
    dense_buf: Vec<u8>,
    // csr state
    row_ptr: Vec<usize>,
    row_len: Vec<usize>,
    col_pos: u64,
    col_buf: Vec<u8>,
    val_pos: u64,
    val_buf: Vec<u8>,
    sections: [(u64, u64); 5],
}

impl NodeSink {
    fn create(
        path: PathBuf,
        rows: usize,
        cols: usize,
        width: usize,
        csr: bool,
        padded_total: usize,
    ) -> anyhow::Result<NodeSink> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let file = File::create(&path)?;
        let stride = padded_stride(cols);
        let lens = {
            let mut l = [0u64; 5];
            l[SEC_LABELS] = (rows * width * 4) as u64;
            if csr {
                l[SEC_ROW_PTR] = ((rows + 1) * 8) as u64;
                l[SEC_ROW_LEN] = (rows * 8) as u64;
                l[SEC_COL_IDX] = (padded_total * 4) as u64;
                l[SEC_VALS] = (padded_total * 4) as u64;
            } else {
                l[SEC_DENSE_VALS] = (rows * stride * 4) as u64;
            }
            l
        };
        let sections = Header::layout(lens);
        Ok(NodeSink {
            file,
            path,
            csr,
            rows_expected: rows,
            rows_seen: 0,
            cols,
            width,
            labels: Vec::with_capacity(rows * width),
            nnz: 0,
            stride,
            rowbuf: vec![0.0; stride],
            dense_pos: sections[SEC_DENSE_VALS].0,
            dense_buf: Vec::new(),
            row_ptr: vec![0],
            row_len: Vec::new(),
            col_pos: sections[SEC_COL_IDX].0,
            col_buf: Vec::new(),
            val_pos: sections[SEC_VALS].0,
            val_buf: Vec::new(),
            sections,
        })
    }

    fn flush_buf(file: &File, pos: &mut u64, buf: &mut Vec<u8>) -> anyhow::Result<()> {
        if !buf.is_empty() {
            let mut f = file;
            f.seek(SeekFrom::Start(*pos))?;
            f.write_all(buf)?;
            *pos += buf.len() as u64;
            buf.clear();
        }
        Ok(())
    }

    fn push_row(&mut self, label: f32, row: RowRef<'_>) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.rows_seen < self.rows_expected,
            "psd1: input changed between passes"
        );
        self.rows_seen += 1;
        self.labels.push(label);
        if self.csr {
            // entries: LIBSVM rows keep explicit zeros (as the resident
            // CSR loader does); dense cells keep nonzeros only (as
            // `CsrMatrix::from_dense` does)
            let mut push_entry = |c: u32, v: f32, buf_c: &mut Vec<u8>, buf_v: &mut Vec<u8>| {
                buf_c.extend_from_slice(&c.to_le_bytes());
                buf_v.extend_from_slice(&v.to_le_bytes());
            };
            let mut len = 0usize;
            let mut last_col = 0u32;
            match row {
                RowRef::Sparse(entries) => {
                    for &(c, v) in entries {
                        push_entry(c, v, &mut self.col_buf, &mut self.val_buf);
                        last_col = c;
                        len += 1;
                    }
                }
                RowRef::DenseCells(cells) => {
                    for (j, &v) in cells.iter().enumerate() {
                        if v != 0.0 {
                            push_entry(j as u32, v, &mut self.col_buf, &mut self.val_buf);
                            last_col = j as u32;
                            len += 1;
                        }
                    }
                }
            }
            // pad the run exactly like `CsrBuilder::finish_row`
            for _ in len..padded_entries(len) {
                push_entry(last_col, 0.0, &mut self.col_buf, &mut self.val_buf);
            }
            self.nnz += len;
            self.row_len.push(len);
            self.row_ptr
                .push(self.row_ptr.last().unwrap() + padded_entries(len));
            if self.col_buf.len() >= 1 << 18 {
                Self::flush_buf(&self.file, &mut self.col_pos, &mut self.col_buf)?;
                Self::flush_buf(&self.file, &mut self.val_pos, &mut self.val_buf)?;
            }
        } else {
            self.rowbuf.fill(0.0);
            match row {
                RowRef::Sparse(entries) => {
                    // scatter all stored entries (explicit zeros and zero
                    // signs land bit-identically to `to_dense`)
                    for &(c, v) in entries {
                        self.rowbuf[c as usize] = v;
                    }
                }
                RowRef::DenseCells(cells) => {
                    self.rowbuf[..cells.len()].copy_from_slice(cells);
                }
            }
            self.nnz += self.rowbuf[..self.cols]
                .iter()
                .filter(|&&v| v != 0.0)
                .count();
            for &v in &self.rowbuf {
                self.dense_buf.extend_from_slice(&v.to_le_bytes());
            }
            if self.dense_buf.len() >= 1 << 18 {
                Self::flush_buf(&self.file, &mut self.dense_pos, &mut self.dense_buf)?;
            }
        }
        Ok(())
    }

    fn finish(mut self) -> anyhow::Result<ShardReport> {
        anyhow::ensure!(
            self.rows_seen == self.rows_expected,
            "psd1: input changed between passes"
        );
        let (kind, stride) = if self.csr {
            Self::flush_buf(&self.file, &mut self.col_pos, &mut self.col_buf)?;
            Self::flush_buf(&self.file, &mut self.val_pos, &mut self.val_buf)?;
            anyhow::ensure!(
                self.col_pos == self.sections[SEC_COL_IDX].0 + self.sections[SEC_COL_IDX].1,
                "psd1: input changed between passes"
            );
            let mut w = SectionWriter::new(&self.file, self.sections[SEC_ROW_PTR].0);
            for &p in &self.row_ptr {
                w.write(&(p as u64).to_le_bytes())?;
            }
            w.finish()?;
            let mut w = SectionWriter::new(&self.file, self.sections[SEC_ROW_LEN].0);
            for &l in &self.row_len {
                w.write(&(l as u64).to_le_bytes())?;
            }
            w.finish()?;
            (KIND_CSR, 0)
        } else {
            Self::flush_buf(&self.file, &mut self.dense_pos, &mut self.dense_buf)?;
            (KIND_DENSE, self.stride)
        };
        let header = Header {
            kind,
            width: self.width,
            rows: self.rows_expected,
            cols: self.cols,
            stride,
            nnz: self.nnz,
            sections: self.sections,
        };
        write_header_and_labels(&self.file, &header, &self.labels)?;
        self.file.sync_all()?;
        Ok(ShardReport {
            path: self.path,
            rows: self.rows_expected,
            storage: if self.csr { "csr" } else { "dense" },
            nnz: self.nnz,
        })
    }
}

/// Per-node output path: `<base>.<node>.psd1` (any extension on `base` is
/// kept as part of the stem).
pub fn shard_path(base: &Path, node: usize) -> PathBuf {
    let mut s = base.as_os_str().to_os_string();
    s.push(format!(".{node}.psd1"));
    PathBuf::from(s)
}

/// Convert LIBSVM/CSV input into `nodes` `PSD1` shard files
/// (`<out_base>.<node>.psd1`) in two streaming passes — bounded memory:
/// the matrix itself is never resident, only O(rows) bookkeeping.  The
/// emitted shards reproduce the resident pipeline (`load` → `resplit` →
/// storage policy) bit-for-bit; `tests/oocore.rs` pins that property.
pub fn convert(
    input: &ConvertInput,
    out_base: &Path,
    opts: &ConvertOptions,
) -> anyhow::Result<ConvertSummary> {
    ensure_little_endian()?;
    anyhow::ensure!(opts.nodes > 0, "need at least one node");
    let scan = scan_input(input, opts.sanitize)?;
    if scan.dropped > 0 {
        eprintln!(
            "[sanitize] dropped {} row(s) with non-finite values",
            scan.dropped
        );
    }
    anyhow::ensure!(scan.rows > 0, "empty input file");
    anyhow::ensure!(
        scan.rows >= opts.nodes,
        "cannot split {} samples across {} nodes",
        scan.rows,
        opts.nodes
    );
    let cols = match opts.n_features {
        Some(n) => {
            anyhow::ensure!(
                n >= scan.max_col,
                "n_features {n} < largest index {}",
                scan.max_col
            );
            n
        }
        None => scan.max_col,
    };
    anyhow::ensure!(cols > 0, "no features in input file");

    // shard boundaries + per-shard storage decisions (same density math
    // as `ShardData::with_policy` on the resident pipeline)
    let sizes = shard_sizes(scan.rows, opts.nodes);
    let mut bounds = vec![0usize];
    for &s in &sizes {
        bounds.push(bounds.last().unwrap() + s);
    }
    let mut shard_csr = Vec::with_capacity(opts.nodes);
    let mut shard_padded = Vec::with_capacity(opts.nodes);
    let mut total_entries = 0usize;
    for node in 0..opts.nodes {
        let rows = &scan.row_entries[bounds[node]..bounds[node + 1]];
        let entries: usize = rows.iter().map(|&e| e as usize).sum();
        total_entries += entries;
        let density = if sizes[node] * cols == 0 {
            1.0
        } else {
            entries as f64 / (sizes[node] * cols) as f64
        };
        let csr = match opts.mode {
            SparseMode::Always => true,
            SparseMode::Never => false,
            SparseMode::Auto => density <= opts.threshold,
        };
        shard_csr.push(csr);
        shard_padded.push(rows.iter().map(|&e| padded_entries(e as usize)).sum());
    }

    // pass 2: stream rows into the per-node sinks
    let path = match input {
        ConvertInput::Libsvm(p) | ConvertInput::Csv(p) => p,
    };
    let reader = BufReader::new(File::open(path)?);
    let mut reports = Vec::with_capacity(opts.nodes);
    let mut node = 0usize;
    let mut sink: Option<NodeSink> = None;
    let mut row_global = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let parsed: Option<(f32, RowParsed)> = match input {
            ConvertInput::Libsvm(_) => match parse_libsvm_line(lineno, &line, opts.sanitize)? {
                SvmLine::Skip | SvmLine::Dropped => None,
                SvmLine::Row(label, entries) => Some((label, RowParsed::Sparse(entries))),
            },
            ConvertInput::Csv(_) => match parse_csv_line(lineno, &line, opts.sanitize)? {
                CsvLine::Skip | CsvLine::Dropped => None,
                CsvLine::Row(cells) => {
                    let label = *cells.last().unwrap();
                    Some((label, RowParsed::DenseCells(cells)))
                }
            },
        };
        let Some((label, row)) = parsed else { continue };
        anyhow::ensure!(row_global < scan.rows, "psd1: input changed between passes");
        if row_global == bounds[node + 1] {
            reports.push(sink.take().unwrap().finish()?);
            node += 1;
        }
        if sink.is_none() {
            sink = Some(NodeSink::create(
                shard_path(out_base, node),
                sizes[node],
                cols,
                1,
                shard_csr[node],
                shard_padded[node],
            )?);
        }
        let sink_ref = sink.as_mut().unwrap();
        match &row {
            RowParsed::Sparse(entries) => {
                for &(c, _) in entries {
                    anyhow::ensure!(
                        (c as usize) < cols,
                        "line {}: column {} out of range {cols}",
                        lineno + 1,
                        c + 1
                    );
                }
                sink_ref.push_row(label, RowRef::Sparse(entries))?;
            }
            RowParsed::DenseCells(cells) => {
                sink_ref.push_row(label, RowRef::DenseCells(&cells[..cells.len() - 1]))?;
            }
        }
        row_global += 1;
    }
    anyhow::ensure!(row_global == scan.rows, "psd1: input changed between passes");
    reports.push(sink.take().unwrap().finish()?);

    Ok(ConvertSummary {
        shards: reports,
        rows: scan.rows,
        cols,
        density: total_entries as f64 / (scan.rows * cols) as f64,
        dropped: scan.dropped,
    })
}

enum RowParsed {
    Sparse(Vec<(u32, f32)>),
    DenseCells(Vec<f32>),
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SyntheticSpec, SparseMode};
    use crate::util::testkit::{run_prop, PropConfig};

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("psfit_psd1_{}_{name}", std::process::id()))
    }

    fn sample_shard(csr: bool) -> Shard {
        let mut spec = SyntheticSpec::regression(13, 27, 1);
        spec.density = 0.3;
        let ds = spec.generate();
        let mode = if csr { SparseMode::Always } else { SparseMode::Never };
        ds.shards[0].with_storage_policy(mode, 0.0)
    }

    fn roundtrip(shard: &Shard, name: &str) -> Shard {
        let path = tmp(name);
        write_shard(shard, &path).unwrap();
        let back = open_shard(&path).unwrap();
        std::fs::remove_file(&path).unwrap();
        back
    }

    #[test]
    fn dense_shard_roundtrips_bit_exactly() {
        let shard = sample_shard(false);
        let back = roundtrip(&shard, "dense_rt");
        let ShardData::Mapped(m) = &back.data else {
            panic!("expected mapped storage")
        };
        assert!(!m.is_csr());
        assert_eq!(back.labels, shard.labels);
        let orig = shard.data.as_dense().unwrap();
        assert_eq!(m.dense_padded(), orig.padded_data());
        assert_eq!(m.to_matrix(), **orig);
        assert_eq!(back.data.nnz(), shard.data.nnz());
    }

    #[test]
    fn csr_shard_roundtrips_bit_exactly() {
        let shard = sample_shard(true);
        let back = roundtrip(&shard, "csr_rt");
        let ShardData::Mapped(m) = &back.data else {
            panic!("expected mapped storage")
        };
        assert!(m.is_csr());
        assert_eq!(back.labels, shard.labels);
        let orig = shard.data.as_csr().unwrap();
        let (op, mp) = (orig.parts(), m.csr_parts());
        assert_eq!(op.row_ptr, mp.row_ptr);
        assert_eq!(op.row_len, mp.row_len);
        assert_eq!(op.col_idx, mp.col_idx);
        assert_eq!(op.vals, mp.vals);
        assert_eq!(back.data.nnz(), shard.data.nnz());
    }

    #[test]
    fn mapped_matvec_matches_resident() {
        for csr in [false, true] {
            let shard = sample_shard(csr);
            let back = roundtrip(&shard, if csr { "mv_csr" } else { "mv_dense" });
            let n = shard.data.cols();
            let m = shard.data.rows();
            let x: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
            let v: Vec<f32> = (0..m).map(|i| (i as f32 * 0.11).cos()).collect();
            let (mut y0, mut y1) = (vec![0.0f32; m], vec![0.0f32; m]);
            shard.data.matvec(&x, &mut y0);
            back.data.matvec(&x, &mut y1);
            assert_eq!(y0, y1, "matvec csr={csr}");
            let (mut z0, mut z1) = (vec![0.0f32; n], vec![0.0f32; n]);
            shard.data.matvec_t(&v, &mut z0);
            back.data.matvec_t(&v, &mut z1);
            assert_eq!(z0, z1, "matvec_t csr={csr}");
        }
    }

    #[test]
    fn open_names_all_header_failure_modes() {
        let shard = sample_shard(true);
        let path = tmp("mut_named");
        write_shard(&shard, &path).unwrap();
        let good = std::fs::read(&path).unwrap();

        let open_err = |bytes: &[u8]| -> String {
            std::fs::write(&path, bytes).unwrap();
            open_shard(&path).unwrap_err().to_string()
        };

        // truncated header
        assert!(open_err(&good[..40]).contains("psd1: truncated header"));
        // bad magic
        let mut b = good.clone();
        b[0] = b'X';
        assert!(open_err(&b).contains("psd1: bad magic"));
        // checksum mismatch (flip a header byte without re-checksumming)
        let mut b = good.clone();
        b[17] ^= 0x40;
        assert!(open_err(&b).contains("psd1: header checksum mismatch"));
        // version mismatch, checksum recomputed
        let mut b = good.clone();
        b[4..8].copy_from_slice(&2u32.to_le_bytes());
        let sum = fnv1a(&b[..136]);
        b[136..144].copy_from_slice(&sum.to_le_bytes());
        assert!(open_err(&b).contains("psd1: unsupported version 2"));
        // misaligned section offset, checksum recomputed
        let mut b = good.clone();
        let off = u64::from_le_bytes(b[48..56].try_into().unwrap());
        b[48..56].copy_from_slice(&(off + 4).to_le_bytes());
        let sum = fnv1a(&b[..136]);
        b[136..144].copy_from_slice(&sum.to_le_bytes());
        assert!(open_err(&b).contains("psd1: misaligned section offset"));
        // truncated body
        assert!(open_err(&good[..good.len() - 8]).contains("psd1: truncated file"));

        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn prop_mutated_headers_never_panic_the_reader() {
        let dense = sample_shard(false);
        let csr = sample_shard(true);
        let path = tmp("mut_prop");
        write_shard(&dense, &path).unwrap();
        let dense_bytes = std::fs::read(&path).unwrap();
        write_shard(&csr, &path).unwrap();
        let csr_bytes = std::fs::read(&path).unwrap();

        run_prop(
            "psd1_header_mutations",
            PropConfig {
                cases: 192,
                ..PropConfig::default()
            },
            |rng, _size| {
                let base = if rng.next_u64() % 2 == 0 {
                    &dense_bytes
                } else {
                    &csr_bytes
                };
                let mut bytes = base.clone();
                match rng.next_u64() % 3 {
                    0 => {
                        // truncate anywhere
                        let at = (rng.next_u64() as usize) % bytes.len();
                        bytes.truncate(at);
                    }
                    1 => {
                        // flip a byte in the structural prefix (header +
                        // labels + csr index sections)
                        let span = bytes.len().min(4096);
                        let at = (rng.next_u64() as usize) % span;
                        bytes[at] ^= 1 << (rng.next_u64() % 8);
                    }
                    _ => {
                        // rewrite a random header u64 then re-checksum, so
                        // validation (not the checksum) must catch it
                        let field = 16 + 8 * ((rng.next_u64() as usize) % 15);
                        let v = rng.next_u64() % 0x1_0000_0000;
                        bytes[field..field + 8].copy_from_slice(&v.to_le_bytes());
                        let sum = fnv1a(&bytes[..136]);
                        bytes[136..144].copy_from_slice(&sum.to_le_bytes());
                    }
                }
                std::fs::write(&path, &bytes).map_err(|e| e.to_string())?;
                // must never panic; errors must carry the psd1: prefix
                match open_shard(&path) {
                    Ok(shard) => {
                        // survivors must stay in-bounds for basic reads
                        let _ = shard.data.nnz();
                        Ok(())
                    }
                    Err(e) => {
                        let msg = e.to_string();
                        if msg.starts_with("psd1:") {
                            Ok(())
                        } else {
                            Err(format!("unnamed error: {msg}"))
                        }
                    }
                }
            },
        );
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn convert_matches_resident_pipeline_bit_for_bit() {
        // libsvm -> convert must equal load_libsvm -> resplit -> policy
        let mut spec = SyntheticSpec::regression(19, 41, 1);
        spec.density = 0.25;
        let mut ds = spec.generate();
        ds.apply_storage(SparseMode::Always, 0.0);
        let svm = tmp("conv_in.svm");
        crate::data::io::save_libsvm(&ds, &svm).unwrap();

        let resident = crate::data::io::load_libsvm(&svm, Some(19)).unwrap().resplit(3);
        let base = tmp("conv_out");
        for (mode, name) in [(SparseMode::Always, "csr"), (SparseMode::Never, "dense")] {
            let summary = convert(
                &ConvertInput::Libsvm(svm.clone()),
                &base,
                &ConvertOptions {
                    nodes: 3,
                    mode,
                    threshold: 0.25,
                    n_features: Some(19),
                    sanitize: false,
                },
            )
            .unwrap();
            assert_eq!(summary.rows, 41);
            assert_eq!(summary.cols, 19);
            for (i, report) in summary.shards.iter().enumerate() {
                assert_eq!(report.storage, name);
                let mapped = open_shard(&report.path).unwrap();
                let want = resident.shards[i].with_storage_policy(mode, 0.25);
                assert_eq!(mapped.labels, want.labels, "labels node {i}");
                let ShardData::Mapped(m) = &mapped.data else { panic!() };
                match &want.data {
                    ShardData::Csr(c) => {
                        let (a, b) = (c.parts(), m.csr_parts());
                        assert_eq!(a.row_ptr, b.row_ptr, "node {i}");
                        assert_eq!(a.col_idx, b.col_idx, "node {i}");
                        assert_eq!(a.vals, b.vals, "node {i}");
                    }
                    ShardData::Dense(d) => {
                        assert_eq!(m.dense_padded(), d.padded_data(), "node {i}");
                    }
                    ShardData::Mapped(_) => unreachable!(),
                }
                std::fs::remove_file(&report.path).unwrap();
            }
        }
        std::fs::remove_file(&svm).unwrap();
    }

    #[test]
    fn convert_auto_decides_per_shard_like_with_policy() {
        let mut spec = SyntheticSpec::regression(16, 30, 1);
        spec.density = 0.2;
        let mut ds = spec.generate();
        ds.apply_storage(SparseMode::Always, 0.0);
        let svm = tmp("conv_auto.svm");
        crate::data::io::save_libsvm(&ds, &svm).unwrap();
        let resident = crate::data::io::load_libsvm(&svm, Some(16)).unwrap().resplit(2);
        let base = tmp("conv_auto_out");
        let threshold = resident.shards[0].data.density(); // node 0 -> csr
        let summary = convert(
            &ConvertInput::Libsvm(svm.clone()),
            &base,
            &ConvertOptions {
                nodes: 2,
                mode: SparseMode::Auto,
                threshold,
                n_features: Some(16),
                sanitize: false,
            },
        )
        .unwrap();
        for (i, report) in summary.shards.iter().enumerate() {
            let want = resident.shards[i].data.with_policy(SparseMode::Auto, threshold);
            assert_eq!(report.storage, want.storage_name(), "node {i}");
            std::fs::remove_file(&report.path).unwrap();
        }
        std::fs::remove_file(&svm).unwrap();
    }

    #[test]
    fn convert_csv_matches_resident_dense() {
        let csv = tmp("conv.csv");
        std::fs::write(
            &csv,
            "1.0, 0.0, 3.5, 2.0\n0.5, -1.0, 0.0, -2.0\n0.0, 2.5, 1.5, 0.5\n",
        )
        .unwrap();
        let resident = crate::data::io::load_csv(&csv).unwrap().resplit(1);
        let base = tmp("conv_csv_out");
        let summary = convert(
            &ConvertInput::Csv(csv.clone()),
            &base,
            &ConvertOptions {
                nodes: 1,
                mode: SparseMode::Never,
                threshold: 0.25,
                n_features: None,
                sanitize: false,
            },
        )
        .unwrap();
        let mapped = open_shard(&summary.shards[0].path).unwrap();
        assert_eq!(mapped.labels, resident.shards[0].labels);
        let ShardData::Mapped(m) = &mapped.data else { panic!() };
        assert_eq!(
            m.dense_padded(),
            resident.shards[0].data.as_dense().unwrap().padded_data()
        );
        std::fs::remove_file(&summary.shards[0].path).unwrap();
        std::fs::remove_file(&csv).unwrap();
    }
}

//! Synthetic dataset generators following the paper's experimental setup
//! (§4): dense standard-normal feature matrices with unit-l2-normalized
//! columns, a planted kappa-sparse ground truth, Gaussian label noise, and
//! per-node sample shards.  Classification variants reuse the same design
//! matrix recipe with sign / argmax labelling.

use super::partition::{shard_sizes, Shard};
use super::Dataset;
use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// Label-generation recipe.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Task {
    /// b = A x_true + noise  (SLS, Eq. 24)
    Regression,
    /// b = sign(A x_true + noise)  in {-1, +1}  (SLogR / SSVM)
    Binary,
    /// one-hot argmax over k planted coefficient columns (SSR)
    Multiclass { k: usize },
}

/// Everything that defines a synthetic experiment instance.
#[derive(Debug, Clone)]
pub struct SyntheticSpec {
    /// Feature count n.
    pub n_features: usize,
    /// Total samples across all nodes.
    pub m_total: usize,
    /// Node (shard) count N.
    pub nodes: usize,
    /// Paper's s_l in (0, 1): fraction of zero coefficients.
    /// kappa = round(n * (1 - s_l)).
    pub sparsity_level: f64,
    /// Nonzero fraction of the design matrix in (0, 1]: 1.0 plants the
    /// paper's dense standard-normal features; below 1.0 each entry is
    /// kept with this probability (Bernoulli mask) before column
    /// normalization, planting a genuinely sparse design matrix (text /
    /// one-hot / genomics style).  Storage stays dense here; the
    /// `--sparse` policy decides the format at partition time.
    pub density: f64,
    /// Label noise standard deviation.
    pub noise_std: f64,
    /// Label-generation recipe.
    pub task: Task,
    /// Seed for every random draw (bit-exact reproduction).
    pub seed: u64,
}

impl SyntheticSpec {
    /// Paper-default regression spec (sparsity 0.8, dense design).
    pub fn regression(n: usize, m_total: usize, nodes: usize) -> SyntheticSpec {
        SyntheticSpec {
            n_features: n,
            m_total,
            nodes,
            sparsity_level: 0.8,
            density: 1.0,
            noise_std: 0.1,
            task: Task::Regression,
            seed: 42,
        }
    }

    /// The planted cardinality `round(n * (1 - s_l))`, clamped to [1, n].
    pub fn kappa(&self) -> usize {
        let k = (self.n_features as f64 * (1.0 - self.sparsity_level)).round() as usize;
        k.clamp(1, self.n_features)
    }

    /// Label width the task implies (1, or k for multiclass).
    pub fn width(&self) -> usize {
        match self.task {
            Task::Multiclass { k } => k,
            _ => 1,
        }
    }

    /// Generate the distributed dataset.
    pub fn generate(&self) -> Dataset {
        assert!(self.nodes > 0 && self.n_features > 0 && self.m_total >= self.nodes);
        assert!(
            (0.0..1.0).contains(&self.sparsity_level),
            "sparsity_level in [0, 1)"
        );
        assert!(
            self.density > 0.0 && self.density <= 1.0,
            "density in (0, 1]"
        );
        let mut rng = Rng::seed_from(self.seed);
        let n = self.n_features;
        let kappa = self.kappa();
        let width = self.width();

        // planted coefficients: kappa active rows shared across columns.
        // Layout is CLASS-MAJOR — entry (class c, feature i) at c*n + i —
        // matching the solver's flattened coefficient space (admm::mod).
        let active = {
            let mut idx = rng.choose_indices(n, kappa);
            idx.sort_unstable();
            idx
        };
        let mut x_true = vec![0.0f64; n * width];
        for &i in &active {
            for c in 0..width {
                // well-separated magnitudes so the support is identifiable
                x_true[c * n + i] = rng.normal() + 2.0 * rng.normal().signum();
            }
        }
        let support_true: Vec<usize> = match self.task {
            Task::Multiclass { .. } => (0..n * width)
                .filter(|&j| x_true[j] != 0.0)
                .collect(),
            _ => active.clone(),
        };

        // per-node shards
        let sizes = shard_sizes(self.m_total, self.nodes);
        let mut shards = Vec::with_capacity(self.nodes);
        for (node, &m_i) in sizes.iter().enumerate() {
            let mut node_rng = rng.split(node as u64 + 1);
            let mut a = Matrix::zeros(m_i, n);
            // logical elements in row-major order: the same RNG draw
            // sequence as the historical contiguous layout, so padded
            // storage reproduces every seeded dataset bit-for-bit
            a.for_each_mut(|v| *v = node_rng.normal_f32());
            if self.density < 1.0 {
                // Bernoulli sparsity mask (only consumes RNG draws when a
                // sub-unit density is requested, so dense seeds reproduce
                // the historical datasets bit-for-bit)
                a.for_each_mut(|v| {
                    if node_rng.uniform() >= self.density {
                        *v = 0.0;
                    }
                });
            }
            a.normalize_columns(); // paper: per-node column normalization

            // clean predictions (f64 accumulate for the planted signal)
            let mut labels = vec![0.0f32; m_i * width];
            for r in 0..m_i {
                let row = a.row(r);
                for c in 0..width {
                    let mut acc = 0.0f64;
                    for &i in &active {
                        acc += row[i] as f64 * x_true[c * n + i];
                    }
                    labels[r * width + c] = acc as f32;
                }
            }
            // noise + task-specific labelling
            match self.task {
                Task::Regression => {
                    for l in labels.iter_mut() {
                        *l += (node_rng.normal() * self.noise_std) as f32;
                    }
                }
                Task::Binary => {
                    for l in labels.iter_mut() {
                        let noisy = *l as f64 + node_rng.normal() * self.noise_std;
                        *l = if noisy >= 0.0 { 1.0 } else { -1.0 };
                    }
                }
                Task::Multiclass { k } => {
                    for r in 0..m_i {
                        let row = &mut labels[r * k..(r + 1) * k];
                        let mut best = 0;
                        let mut best_v = f64::NEG_INFINITY;
                        for (c, v) in row.iter().enumerate() {
                            let noisy = *v as f64 + node_rng.normal() * self.noise_std;
                            if noisy > best_v {
                                best_v = noisy;
                                best = c;
                            }
                        }
                        row.fill(0.0);
                        row[best] = 1.0;
                    }
                }
            }
            shards.push(Shard::dense(a, labels, width));
        }

        Dataset {
            shards,
            x_true,
            support_true,
            n_features: n,
            width,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_shapes() {
        let spec = SyntheticSpec::regression(50, 203, 4);
        let ds = spec.generate();
        assert_eq!(ds.nodes(), 4);
        assert_eq!(ds.total_samples(), 203);
        assert_eq!(ds.n_features, 50);
        let sizes: Vec<usize> = ds.shards.iter().map(|s| s.rows()).collect();
        assert_eq!(sizes, vec![51, 51, 51, 50]);
    }

    #[test]
    fn kappa_matches_paper_formula() {
        let mut spec = SyntheticSpec::regression(4000, 100, 2);
        spec.sparsity_level = 0.8;
        assert_eq!(spec.kappa(), 800); // round(4000 * 0.2)
        spec.sparsity_level = 0.9;
        assert_eq!(spec.kappa(), 400);
    }

    #[test]
    fn ground_truth_has_kappa_nonzeros() {
        let spec = SyntheticSpec::regression(100, 80, 2);
        let ds = spec.generate();
        let nnz = ds.x_true.iter().filter(|&&v| v != 0.0).count();
        assert_eq!(nnz, spec.kappa());
        assert_eq!(ds.support_true.len(), spec.kappa());
    }

    #[test]
    fn columns_are_normalized_per_node() {
        let ds = SyntheticSpec::regression(20, 100, 2).generate();
        for shard in &ds.shards {
            let a = shard.data.as_dense().unwrap();
            for j in 0..20 {
                let s: f64 = (0..a.rows).map(|i| (a.at(i, j) as f64).powi(2)).sum();
                assert!((s.sqrt() - 1.0).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn density_knob_plants_sparse_designs() {
        let mut spec = SyntheticSpec::regression(40, 400, 2);
        spec.density = 0.05;
        let ds = spec.generate();
        let d = ds.density();
        assert!(d > 0.01 && d < 0.12, "measured density {d} far from 0.05");
        // labels still carry planted signal: at least one is nonzero
        assert!(ds.shards.iter().any(|s| s.labels.iter().any(|&l| l != 0.0)));
        // dense default consumes no mask draws: density 1.0 reproduces
        // the historical dataset bit-for-bit
        let dense = SyntheticSpec::regression(40, 400, 2).generate();
        let again = SyntheticSpec::regression(40, 400, 2).generate();
        assert_eq!(
            **dense.shards[0].data.as_dense().unwrap(),
            **again.shards[0].data.as_dense().unwrap()
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = SyntheticSpec::regression(10, 30, 2).generate();
        let b = SyntheticSpec::regression(10, 30, 2).generate();
        assert_eq!(
            **a.shards[0].data.as_dense().unwrap(),
            **b.shards[0].data.as_dense().unwrap()
        );
        assert_eq!(a.x_true, b.x_true);
    }

    #[test]
    fn binary_labels_are_signs() {
        let mut spec = SyntheticSpec::regression(10, 40, 2);
        spec.task = Task::Binary;
        let ds = spec.generate();
        for s in &ds.shards {
            assert!(s.labels.iter().all(|&l| l == 1.0 || l == -1.0));
        }
    }

    #[test]
    fn multiclass_labels_are_onehot() {
        let mut spec = SyntheticSpec::regression(10, 40, 2);
        spec.task = Task::Multiclass { k: 3 };
        let ds = spec.generate();
        assert_eq!(ds.width, 3);
        for s in &ds.shards {
            for r in 0..s.rows() {
                let row = &s.labels[r * 3..(r + 1) * 3];
                assert_eq!(row.iter().filter(|&&v| v == 1.0).count(), 1);
                assert_eq!(row.iter().filter(|&&v| v == 0.0).count(), 2);
            }
        }
    }

    #[test]
    fn stacked_concatenates_rows() {
        let ds = SyntheticSpec::regression(5, 14, 3).generate();
        let (a, labels) = ds.stacked();
        assert_eq!(a.rows, 14);
        assert_eq!(labels.len(), 14);
        // first shard rows appear first
        let first = ds.shards[0].data.as_dense().unwrap();
        for r in 0..first.rows {
            assert_eq!(a.row(r), first.row(r));
        }
    }
}

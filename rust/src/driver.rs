//! High-level drivers: build a cluster for a dataset + config and solve.
//!
//! This is the API the CLI, examples, and benchmark harnesses use:
//!
//! ```no_run
//! use psfit::{config::Config, data::SyntheticSpec, driver};
//! let ds = SyntheticSpec::regression(1000, 8000, 4).generate();
//! let mut cfg = Config::default();
//! cfg.solver.kappa = 200;
//! let result = driver::fit(&ds, &cfg).unwrap();
//! println!("support recovered: {:?}", &result.support[..5]);
//! ```
//!
//! For `BackendKind::Xla`, each node worker gets its **own** PJRT runtime
//! (client + compiled executables + staged tiles) so the whole object graph
//! moves to that node's thread — mirroring the paper, where each node owns
//! its GPU context.

use std::path::{Path, PathBuf};

use crate::admm::{self, LocalProx, SolveOptions, SolveResult};
use crate::backend::native::{NativeBackend, SolveMode};
use crate::backend::xla::XlaBackend;
use crate::backend::BlockParams;
use crate::config::{BackendKind, Config, CoordinationKind, TransportKind};
use crate::coordinator::AsyncCluster;
use crate::data::{Dataset, FeaturePlan};
use crate::losses::make_loss;
use crate::network::socket::SocketCluster;
use crate::network::{Cluster, NodeWorker, SequentialCluster, ThreadedCluster};
use crate::runtime::{Manifest, XlaRuntime};

/// Locate the repo's artifact directory (env override, then ./artifacts,
/// then the crate root).
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("PSFIT_ARTIFACTS") {
        return dir.into();
    }
    let local = Path::new("artifacts");
    if local.join("manifest.json").exists() {
        return local.to_path_buf();
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// The feature-decomposition plan a config implies for a dataset.
pub fn plan_for(ds: &Dataset, cfg: &Config, artifacts: &Path) -> anyhow::Result<FeaturePlan> {
    Ok(match cfg.platform.backend {
        BackendKind::Xla => {
            let man = Manifest::load(&artifacts.join("manifest.json"))?;
            FeaturePlan::new(ds.n_features, cfg.platform.devices_per_node, man.block_n)
        }
        BackendKind::Native => {
            FeaturePlan::new(ds.n_features, cfg.platform.devices_per_node, usize::MAX >> 1)
        }
    })
}

/// Build the node workers for a dataset under a config.
///
/// For `BackendKind::Xla` with `platform.share_runtime` (the default) all
/// backends share one PJRT runtime — each artifact compiles once per
/// process — and the cluster MUST be sequential (enforced by
/// `fit_with_options`).  With `share_runtime = false`, every node gets a
/// private runtime and may run on its own thread.
pub fn build_workers(ds: &Dataset, cfg: &Config) -> anyhow::Result<Vec<NodeWorker>> {
    build_workers_mode(
        ds,
        cfg,
        SolveMode::Cg {
            iters: cfg.solver.cg_iters,
        },
    )
}

/// [`build_workers`] with an explicit native block-solve mode.
///
/// The default fit path keeps the artifact-parallel CG mode; the path
/// subsystem passes `SolveMode::Direct` so its keyed Cholesky cache pays
/// off across penalty revisits.  The XLA backend ignores `mode` (its
/// iteration count is baked into the artifacts).
pub fn build_workers_mode(
    ds: &Dataset,
    cfg: &Config,
    mode: SolveMode,
) -> anyhow::Result<Vec<NodeWorker>> {
    let artifacts = default_artifacts_dir();
    let plan = plan_for(ds, cfg, &artifacts)?;
    let params = BlockParams {
        rho_l: cfg.solver.rho_l,
        rho_c: cfg.solver.rho_c,
        reg: cfg.solver.block_reg(ds.nodes()),
    };
    let shared_rt = match (cfg.platform.backend, cfg.platform.share_runtime) {
        (BackendKind::Xla, true) => Some(std::rc::Rc::new(XlaRuntime::open(&artifacts)?)),
        _ => None,
    };
    let mut workers = Vec::with_capacity(ds.nodes());
    for (i, shard) in ds.shards.iter().enumerate() {
        let loss = make_loss(cfg.loss, ds.width.max(cfg.classes));
        let backend: Box<dyn crate::backend::NodeBackend> = match cfg.platform.backend {
            BackendKind::Native => {
                // partition-time storage decision: the configured policy
                // (`--sparse` / platform.sparse_threshold) picks dense or
                // CSR per shard; `Auto` measures the actual density
                let shard = shard.with_storage_policy(
                    cfg.platform.sparse,
                    cfg.platform.sparse_threshold,
                );
                Box::new(
                    NativeBackend::new(&shard, &plan, loss, mode)
                        .with_threads(cfg.platform.threads),
                )
            }
            BackendKind::Xla => {
                let rt = match &shared_rt {
                    Some(rt) => rt.clone(),
                    None => std::rc::Rc::new(XlaRuntime::open(&artifacts)?),
                };
                Box::new(XlaBackend::new(rt, shard, &plan, loss)?)
            }
        };
        workers.push(
            NodeWorker::new(
                i,
                LocalProx::new(backend, plan.clone(), ds.width),
                params,
                cfg.solver.inner_iters,
            )
            .with_minibatch(cfg.solver.minibatch, cfg.solver.minibatch_seed),
        );
    }
    Ok(workers)
}

/// True when this config requires the sequential (single-thread) cluster.
pub fn requires_sequential(cfg: &Config) -> bool {
    cfg.platform.backend == BackendKind::Xla && cfg.platform.share_runtime
}

/// Build the transport for a set of workers.  `config.coordinator.
/// coordination` selects it: `sync` (default) is the full-barrier
/// threaded/sequential cluster, `async` the partial-barrier
/// [`AsyncCluster`].  Single policy point — the fit API, the harness
/// timer, and the straggler scenario all construct clusters here.
pub fn build_cluster(
    workers: Vec<NodeWorker>,
    dim: usize,
    cfg: &Config,
    threaded: bool,
) -> anyhow::Result<Box<dyn Cluster>> {
    cfg.coordinator.validate()?;
    Ok(match cfg.coordinator.coordination {
        CoordinationKind::Async => {
            anyhow::ensure!(
                !requires_sequential(cfg),
                "async coordination needs per-node runtimes: set platform.share_runtime = false"
            );
            Box::new(AsyncCluster::new(workers, dim, &cfg.coordinator))
        }
        CoordinationKind::Sync => {
            if threaded && !requires_sequential(cfg) {
                Box::new(ThreadedCluster::new(workers, dim))
            } else {
                Box::new(SequentialCluster::new(workers, dim))
            }
        }
    })
}

/// Build the complete transport a config asks for, honoring
/// `platform.transport`: `local` constructs in-process workers and hands
/// them to [`build_cluster`]; `socket` connects a
/// [`SocketCluster`] to the `platform.workers` fleet (shipping the shards
/// over the wire).  The `psfit path` subsystem stays on the in-process
/// transports — its per-point rebuild churn belongs next to the data.
pub fn build_transport_cluster(
    ds: &Dataset,
    cfg: &Config,
    threaded: bool,
) -> anyhow::Result<Box<dyn Cluster>> {
    match cfg.platform.transport {
        TransportKind::Socket => {
            anyhow::ensure!(
                cfg.platform.backend == BackendKind::Native,
                "transport `socket` runs workers on the native backend only"
            );
            Ok(Box::new(SocketCluster::connect(ds, cfg)?))
        }
        TransportKind::Local => {
            let workers = build_workers(ds, cfg)?;
            build_cluster(workers, ds.n_features * ds.width, cfg, threaded)
        }
    }
}

/// End-to-end fit: build the configured cluster, run Bi-cADMM, return
/// the result.
pub fn fit(ds: &Dataset, cfg: &Config) -> anyhow::Result<SolveResult> {
    fit_with_options(ds, cfg, &SolveOptions::default(), true)
}

/// [`fit`] with explicit solve options and transport choice (`threaded =
/// false` forces the deterministic sequential cluster on the local
/// transport).  With `cfg.solver.checkpoint` set, the fit writes and —
/// when the file already holds a compatible snapshot — resumes mid-fit
/// PSF1 checkpoints via [`admm::solve_checkpointed`].
pub fn fit_with_options(
    ds: &Dataset,
    cfg: &Config,
    opts: &SolveOptions,
    threaded: bool,
) -> anyhow::Result<SolveResult> {
    let dim = ds.n_features * ds.width;
    let mut cluster = build_transport_cluster(ds, cfg, threaded)?;
    if cfg.solver.checkpoint.is_empty() {
        admm::solve(cluster.as_mut(), dim, cfg, Some(ds), opts)
    } else {
        admm::solve_checkpointed(cluster.as_mut(), dim, cfg, ds, opts)
    }
}

//! `psfit chaos` — deterministic fault-injection harness for the socket
//! transport.
//!
//! Stands up an in-process worker fleet, fits one reference problem over
//! a clean socket cluster, then repeats the same fit twice with every
//! worker connection routed through a seeded
//! [`crate::network::socket::ChaosProxy`] while `platform.rejoin` heals
//! the fleet between rounds.  Because each faulted run builds its own
//! proxies, the per-connection fault schedules are identical across
//! runs — the printed schedule fingerprint proves it — and the harness
//! asserts that every faulted run that converges recovers **exactly**
//! the clean run's support.  A run that loses its whole quorum is
//! reported, not failed: losing everything is a legitimate outcome of a
//! fault schedule, silently missing parity is not.

use crate::config::{Config, TransportKind};
use crate::data::SyntheticSpec;
use crate::driver;
use crate::network::socket::{spawn_local_worker, ChaosProxy, ChaosSpec};

/// Settings for `psfit chaos`.
#[derive(Debug, Clone)]
pub struct ChaosOpts {
    /// Smaller problem and iteration budget (CI smoke).
    pub quick: bool,
    /// Fault-schedule seed; overrides the spec default (and any `seed=`
    /// inside `--faults`) when set to a non-default value.
    pub seed: u64,
    /// Compact fault spec (`drop=0.02,corrupt=0.02,...`); `None` uses a
    /// mild mixed schedule that exercises every fault kind.
    pub faults: Option<String>,
    /// Worker fleet size.
    pub nodes: usize,
}

/// The mild default schedule: a percent of frames die or arrive damaged
/// (each one kills — and heals — a connection), a tenth arrive split or
/// late — every decoder path gets hit without starving the fit of a
/// quorum or resetting dual state faster than consensus re-equilibrates.
const DEFAULT_FAULTS: &str = "drop=0.01,corrupt=0.01,split=0.10,delay=0.05:5";

/// Run the harness; errors mean a parity violation (or a setup failure),
/// so CI can gate on the exit code.
pub fn chaos(opts: &ChaosOpts) -> anyhow::Result<()> {
    anyhow::ensure!(opts.nodes >= 1, "psfit chaos needs at least one node");
    let mut spec = ChaosSpec::parse(opts.faults.as_deref().unwrap_or(DEFAULT_FAULTS))?;
    if opts.seed != ChaosSpec::default().seed {
        spec.seed = opts.seed;
    }

    let (n, m, iters) = if opts.quick {
        (40usize, 400usize, 800usize)
    } else {
        (64, 600, 1000)
    };
    // well-conditioned recovery instance at loose tolerances — the exact
    // regime tests/integration.rs pins as converging comfortably.  The
    // harness judges fault tolerance, not solver difficulty, and the
    // generous iteration budget absorbs the re-equilibration rounds each
    // death costs.
    let mut sspec = SyntheticSpec::regression(n, m, opts.nodes);
    sspec.sparsity_level = 0.9;
    sspec.noise_std = 0.05;
    let ds = sspec.generate();

    let mut cfg = Config::default();
    cfg.platform.nodes = opts.nodes;
    cfg.platform.transport = TransportKind::Socket;
    cfg.platform.rejoin = true;
    cfg.platform.read_timeout_ms = 10_000;
    cfg.solver.kappa = sspec.kappa();
    cfg.solver.rho_c = 1.0;
    cfg.solver.rho_b = 0.5;
    cfg.solver.max_iters = iters;
    cfg.solver.tol_primal = 1e-2;
    cfg.solver.tol_dual = 1e-2;
    cfg.solver.tol_bilinear = 1e-1;

    // one shared fleet: a worker serves one node session per connection,
    // so the clean run and both faulted runs multiplex over it safely
    let fleet: Vec<String> = (0..opts.nodes)
        .map(|_| spawn_local_worker())
        .collect::<anyhow::Result<_>>()?;

    let fingerprint = spec.schedule_fingerprint(2 * opts.nodes as u64, 64);
    println!("fault spec:  {spec}");
    println!("fingerprint: {fingerprint:#018x} (same seed => same schedule, every run)");

    // ---- clean reference run -------------------------------------------
    cfg.platform.workers = fleet.clone();
    let clean = driver::fit(&ds, &cfg)?;
    anyhow::ensure!(
        clean.converged,
        "the clean run did not converge in {iters} iterations; the chaos \
         parity check needs a converged reference"
    );
    println!(
        "clean run:   converged in {} iters, support {:?}",
        clean.iters,
        &clean.support
    );

    // ---- faulted runs ---------------------------------------------------
    let mut converged_runs = 0usize;
    for run in 1..=2u32 {
        // fresh proxies per run: connection counters restart at 0, so
        // this run faces the identical fault schedule as the last one
        let proxies: Vec<ChaosProxy> = fleet
            .iter()
            .map(|w| ChaosProxy::spawn(w, &spec))
            .collect::<anyhow::Result<_>>()?;
        cfg.platform.workers = proxies.iter().map(|p| p.addr().to_string()).collect();
        // periodic checkpoints keep the rejoin layer's warm cache fresh,
        // so a killed connection resyncs at most 10 rounds stale instead
        // of cold-restarting its dual state (a per-run file: each run
        // must fit from scratch, never resume its predecessor)
        let ck = std::env::temp_dir().join(format!("psfit_chaos_run{run}.psf"));
        let _ = std::fs::remove_file(&ck);
        cfg.solver.checkpoint = ck.to_string_lossy().into_owned();
        cfg.solver.checkpoint_every = 10;
        let outcome = driver::fit(&ds, &cfg);
        let _ = std::fs::remove_file(&ck);
        match outcome {
            Ok(res) => {
                let injected: u64 = proxies.iter().map(|p| p.injected_faults()).sum();
                let coord = res
                    .coordination
                    .as_ref()
                    .map(|c| c.summary())
                    .unwrap_or_else(|| "no coordination stats".to_string());
                println!(
                    "chaos run {run}: converged={} iters={} faults_injected={injected}",
                    res.converged, res.iters
                );
                println!("             {coord}");
                if res.converged {
                    converged_runs += 1;
                    anyhow::ensure!(
                        res.support == clean.support,
                        "chaos run {run} converged to support {:?}, clean run \
                         recovered {:?} — fault injection changed the answer",
                        res.support,
                        clean.support
                    );
                    println!("             support parity with the clean run: OK");
                } else {
                    println!(
                        "             did not converge under faults; parity not checked"
                    );
                }
            }
            Err(e) => {
                // quorum loss is a legitimate outcome of a fault schedule
                println!("chaos run {run}: failed cleanly ({e:#})");
            }
        }
    }
    anyhow::ensure!(
        converged_runs > 0,
        "no faulted run converged — the schedule is too hostile for a \
         meaningful parity check (try a tamer --faults)"
    );
    println!("chaos: {converged_runs}/2 faulted run(s) converged with support parity");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The CI smoke path end-to-end, on a tiny problem: same seed, same
    /// schedule, parity against the clean run.
    #[test]
    fn quick_chaos_run_passes_parity() {
        let opts = ChaosOpts {
            quick: true,
            seed: ChaosSpec::default().seed,
            faults: Some("split=0.10,delay=0.05:2".to_string()),
            nodes: 2,
        };
        chaos(&opts).unwrap();
    }
}

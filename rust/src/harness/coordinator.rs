//! `psfit chaos --coordinator` — coordinator kill/restart chaos over a
//! real subprocess fleet.
//!
//! Where `psfit chaos` damages worker traffic and `--numerics` damages
//! the math, this mode kills the *coordinator*: it stands up `psfit
//! worker` subprocesses and a `psfit serve --state-dir` daemon, submits a
//! batch of deterministic jobs, then `SIGKILL`s and restarts the daemon
//! on a seeded schedule while a reconnecting [`ServeClient`] rides
//! through every restart.  The same jobs run once on an uninterrupted
//! daemon first, and the harness asserts that every killed-and-resumed
//! job still lands `done` with a **bit-identical** PSM1 artifact —
//! same support, same objective bits, same prediction bits on seeded
//! probe queries.  The printed schedule fingerprint is a pure function
//! of `(seed, kills, jobs)`, so two runs with one seed can prove they
//! faced the same kill schedule with a plain `cmp`.

use std::fs::File;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use crate::config::Config;
use crate::network::socket::wire::{fnv1a, JobSpec};
use crate::serve::journal;
use crate::serve::{FittedModel, JobPhase, ServeClient};
use crate::util::rng::Rng;

/// Settings for `psfit chaos --coordinator`.
#[derive(Debug, Clone)]
pub struct CoordinatorChaosOpts {
    /// Smaller job batch and iteration budget (CI smoke).
    pub quick: bool,
    /// Kill-schedule seed: same seed, same kill delays, every run.
    pub seed: u64,
    /// Coordinator kills to perform; `0` picks the mode default
    /// (1 quick, 2 full).
    pub kills: u32,
    /// Jobs to submit; `0` picks the mode default (2 quick, 3 full).
    pub jobs: u32,
}

/// Kills every child it still owns on drop — no orphaned workers or
/// daemons survive a failed assertion.
struct Reaper(Vec<Child>);

impl Drop for Reaper {
    fn drop(&mut self) {
        for child in &mut self.0 {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

/// Spawn `bin` with stdout+stderr redirected into `log` (the harness
/// parses announced addresses out of it).
fn spawn_logged(bin: &Path, args: &[String], log: &Path) -> anyhow::Result<Child> {
    let out = File::create(log)
        .map_err(|e| anyhow::anyhow!("cannot create log {}: {e}", log.display()))?;
    let err = out
        .try_clone()
        .map_err(|e| anyhow::anyhow!("cannot clone log handle: {e}"))?;
    Command::new(bin)
        .args(args)
        .stdin(Stdio::null())
        .stdout(Stdio::from(out))
        .stderr(Stdio::from(err))
        .spawn()
        .map_err(|e| anyhow::anyhow!("cannot spawn {}: {e}", bin.display()))
}

/// Poll `log` until a line starting with `needle` appears; returns the
/// first whitespace-separated token after the prefix (the announced
/// address for both the worker and serve banners).
fn await_line(log: &Path, needle: &str, timeout: Duration) -> anyhow::Result<String> {
    let deadline = Instant::now() + timeout;
    loop {
        if let Ok(text) = std::fs::read_to_string(log) {
            for line in text.lines() {
                if let Some(rest) = line.strip_prefix(needle) {
                    let token = rest.split_whitespace().next().unwrap_or("");
                    anyhow::ensure!(
                        !token.is_empty(),
                        "`{needle}` line in {} carries no address",
                        log.display()
                    );
                    return Ok(token.to_string());
                }
            }
        }
        anyhow::ensure!(
            Instant::now() < deadline,
            "`{needle}` did not appear in {} within {timeout:?}",
            log.display()
        );
        std::thread::sleep(Duration::from_millis(25));
    }
}

/// Spawn a `psfit serve` daemon child over `workers` with durable state
/// in `state_dir`, logging to `log`.
fn spawn_serve_child(
    bin: &Path,
    workers: &str,
    state_dir: &Path,
    listen: &str,
    log: &Path,
) -> anyhow::Result<Child> {
    spawn_logged(
        bin,
        &[
            "serve".to_string(),
            "--listen".to_string(),
            listen.to_string(),
            "--workers".to_string(),
            workers.to_string(),
            "--state-dir".to_string(),
            state_dir.display().to_string(),
        ],
        log,
    )
}

/// Milliseconds from the previous schedule event to kill `k` — pure in
/// `(seed, k)`, landing in `[400, 1200)` so kills interleave with the
/// fits instead of bunching at either end.
fn kill_delay_ms(seed: u64, k: u32) -> u64 {
    let mut key = [0u8; 12];
    key[..8].copy_from_slice(&seed.to_le_bytes());
    key[8..].copy_from_slice(&k.to_le_bytes());
    400 + fnv1a(&key) % 800
}

/// FNV-1a digest of the whole kill schedule — what two same-seed runs
/// `cmp` to prove they faced identical chaos.
fn schedule_fingerprint(seed: u64, kills: u32, jobs: u32) -> u64 {
    let mut buf = Vec::with_capacity(16 + 8 * kills as usize);
    buf.extend_from_slice(&seed.to_le_bytes());
    buf.extend_from_slice(&kills.to_le_bytes());
    buf.extend_from_slice(&jobs.to_le_bytes());
    for k in 0..kills {
        buf.extend_from_slice(&kill_delay_ms(seed, k).to_le_bytes());
    }
    fnv1a(&buf)
}

/// One deterministic job: zero tolerances pin the exact iteration count,
/// so a resumed fit and an uninterrupted one walk the same rounds and the
/// final iterate is bit-identical by construction.
fn job_spec(seed: u64, idx: u32, iters: usize) -> JobSpec {
    let mut cfg = Config::default();
    cfg.solver.max_iters = iters;
    cfg.solver.tol_primal = 0.0;
    cfg.solver.tol_dual = 0.0;
    cfg.solver.tol_bilinear = 0.0;
    cfg.solver.kappa = 8;
    JobSpec {
        n: 48,
        m: 480,
        nodes: 2,
        sparsity: 0.85,
        density: 1.0,
        noise_std: 0.1,
        seed: seed ^ (0x10001 * (idx as u64 + 1)),
        kappa: 8,
        config: cfg.to_json().to_string(),
    }
}

/// Seeded sparse probe queries for prediction bit-parity (indices inside
/// the jobs' 48-feature dimension).
fn probe_queries(seed: u64) -> Vec<Vec<(u32, f64)>> {
    let mut rng = Rng::seed_from(seed ^ 0x9E37_79B9_7F4A_7C15);
    (0..4)
        .map(|_| {
            (0..6)
                .map(|_| ((rng.uniform() * 48.0) as u32 % 48, rng.uniform() * 2.0 - 1.0))
                .collect()
        })
        .collect()
}

/// One job's reference outcome: support, objective bits, and prediction
/// bits on the probe queries.
struct Outcome {
    support: Vec<usize>,
    objective_bits: u64,
    prediction_bits: Vec<u64>,
}

/// Read job `job`'s PSM1 artifact out of `dir` and reduce it to the
/// bit-comparable outcome.
fn outcome_from_state(dir: &Path, job: u64, probes: &[Vec<(u32, f64)>]) -> anyhow::Result<Outcome> {
    let path = journal::model_blob_path(dir, job);
    let blob = std::fs::read(&path)
        .map_err(|e| anyhow::anyhow!("cannot read model artifact {}: {e}", path.display()))?;
    let model = FittedModel::from_bytes(&blob)?;
    let prediction_bits = probes
        .iter()
        .flat_map(|q| model.predict_sparse(q))
        .map(f64::to_bits)
        .collect();
    Ok(Outcome {
        support: model.support.clone(),
        objective_bits: model.objective.to_bits(),
        prediction_bits,
    })
}

/// Submit the job batch and wait until every job is `done`.
fn run_jobs(
    client: &mut ServeClient,
    seed: u64,
    jobs: u32,
    iters: usize,
    wait_each: Duration,
) -> anyhow::Result<()> {
    for j in 0..jobs {
        let id = client.submit(&format!("coordchaos-{j}"), job_spec(seed, j, iters))?;
        anyhow::ensure!(
            id == j as u64 + 1,
            "expected job id {} from a fresh daemon, got {id}",
            j + 1
        );
    }
    for j in 1..=jobs as u64 {
        let st = client.wait(j, wait_each)?;
        anyhow::ensure!(
            JobPhase::from_code(st.phase)? == JobPhase::Done,
            "job {j} finished in phase `{}`, not `done`",
            JobPhase::from_code(st.phase)?.name()
        );
    }
    Ok(())
}

/// Run the harness; errors mean a job failed to land `done`, an artifact
/// broke bit-parity, or a subprocess misbehaved — CI gates on the exit
/// code.
pub fn coordinator_chaos(opts: &CoordinatorChaosOpts) -> anyhow::Result<()> {
    let (default_jobs, default_kills, iters) = if opts.quick {
        (2u32, 1u32, 900usize)
    } else {
        (3, 2, 1500)
    };
    let jobs = if opts.jobs > 0 { opts.jobs } else { default_jobs };
    let kills = if opts.kills > 0 { opts.kills } else { default_kills };
    let wait_each = Duration::from_secs(180);

    let bin = std::env::current_exe()
        .map_err(|e| anyhow::anyhow!("cannot locate the psfit binary: {e}"))?;
    let scratch: PathBuf =
        std::env::temp_dir().join(format!("psfit_coordchaos_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&scratch);
    std::fs::create_dir_all(&scratch)
        .map_err(|e| anyhow::anyhow!("cannot create {}: {e}", scratch.display()))?;

    let fingerprint = schedule_fingerprint(opts.seed, kills, jobs);
    println!(
        "fault spec:  seed={} kills={kills} jobs={jobs} delays_ms={:?}",
        opts.seed,
        (0..kills).map(|k| kill_delay_ms(opts.seed, k)).collect::<Vec<_>>()
    );
    println!("fingerprint: {fingerprint:#018x} (same seed => same schedule, every run)");

    let mut reaper = Reaper(Vec::new());

    // ---- subprocess worker fleet (shared by both runs) -----------------
    let mut fleet = Vec::new();
    for w in 0..2 {
        let log = scratch.join(format!("worker{w}.log"));
        reaper.0.push(spawn_logged(
            &bin,
            &[
                "worker".to_string(),
                "--listen".to_string(),
                "127.0.0.1:0".to_string(),
            ],
            &log,
        )?);
        fleet.push(await_line(
            &log,
            "psfit worker listening on ",
            Duration::from_secs(20),
        )?);
    }
    let workers = fleet.join(",");
    println!("fleet:       {workers}");

    // ---- clean reference run (uninterrupted daemon) --------------------
    let clean_dir = scratch.join("state-clean");
    let clean_log = scratch.join("serve-clean.log");
    reaper.0.push(spawn_serve_child(&bin, &workers, &clean_dir, "127.0.0.1:0", &clean_log)?);
    let clean_addr = await_line(&clean_log, "psfit serve listening on ", Duration::from_secs(20))?;
    let mut client = ServeClient::connect(&clean_addr)?;
    run_jobs(&mut client, opts.seed, jobs, iters, wait_each)?;
    let probes = probe_queries(opts.seed);
    let reference: Vec<Outcome> = (1..=jobs as u64)
        .map(|j| outcome_from_state(&clean_dir, j, &probes))
        .collect::<anyhow::Result<_>>()?;
    println!(
        "clean run:   {jobs} job(s) done, supports {:?}",
        reference.iter().map(|o| o.support.len()).collect::<Vec<_>>()
    );

    // ---- chaos run: kill -9 the coordinator on the seeded schedule -----
    let chaos_dir = scratch.join("state-chaos");
    let chaos_log = scratch.join("serve-chaos-0.log");
    let mut daemon = spawn_serve_child(&bin, &workers, &chaos_dir, "127.0.0.1:0", &chaos_log)?;
    let chaos_addr = await_line(&chaos_log, "psfit serve listening on ", Duration::from_secs(20))?;
    let mut client = ServeClient::connect(&chaos_addr)?;
    for j in 0..jobs {
        let id = client.submit(&format!("coordchaos-{j}"), job_spec(opts.seed, j, iters))?;
        anyhow::ensure!(id == j as u64 + 1, "chaos daemon assigned unexpected job id {id}");
    }
    let mut restart_logs = Vec::new();
    for k in 0..kills {
        let delay = kill_delay_ms(opts.seed, k);
        std::thread::sleep(Duration::from_millis(delay));
        daemon
            .kill()
            .map_err(|e| anyhow::anyhow!("kill {k} failed: {e}"))?;
        let _ = daemon.wait();
        println!(
            "kill {k}:      coordinator SIGKILLed after {delay} ms; restarting on {chaos_addr}"
        );
        let log = scratch.join(format!("serve-chaos-{}.log", k + 1));
        daemon = spawn_serve_child(&bin, &workers, &chaos_dir, &chaos_addr, &log)?;
        await_line(&log, "psfit serve listening on ", Duration::from_secs(20))?;
        restart_logs.push(log);
    }
    // every job must still land `done` — the reconnecting client rides
    // through the restarts, the journal + checkpoints carry the jobs
    for j in 1..=jobs as u64 {
        let st = client.wait(j, wait_each)?;
        anyhow::ensure!(
            JobPhase::from_code(st.phase)? == JobPhase::Done,
            "job {j} finished in phase `{}` after {kills} coordinator kill(s)",
            JobPhase::from_code(st.phase)?.name()
        );
    }
    // at least one restart must have seen the crash (no drain marker was
    // ever written — SIGKILL leaves none)
    let crash_seen = restart_logs.iter().any(|log| {
        std::fs::read_to_string(log)
            .map(|t| t.contains("crash detected"))
            .unwrap_or(false)
    });
    anyhow::ensure!(
        crash_seen,
        "no restarted daemon reported `crash detected` — the journal \
         replay misread a SIGKILL as a clean drain"
    );
    if client.reconnects() > 0 {
        println!(
            "client:      rode through {} reconnect(s) transparently",
            client.reconnects()
        );
    }

    // ---- bit-parity: killed-and-resumed vs uninterrupted ---------------
    for (i, want) in reference.iter().enumerate() {
        let job = i as u64 + 1;
        let got = outcome_from_state(&chaos_dir, job, &probes)?;
        anyhow::ensure!(
            got.support == want.support,
            "job {job}: support diverged after coordinator kills \
             (chaos {:?} vs clean {:?})",
            got.support,
            want.support
        );
        anyhow::ensure!(
            got.objective_bits == want.objective_bits,
            "job {job}: objective bits diverged after coordinator kills \
             ({:#018x} vs {:#018x})",
            got.objective_bits,
            want.objective_bits
        );
        anyhow::ensure!(
            got.prediction_bits == want.prediction_bits,
            "job {job}: prediction bits diverged after coordinator kills"
        );
        // the live restarted daemon must serve the same bits over the wire
        for (q, probe) in probes.iter().enumerate() {
            let answer = client.predict(job, probe)?;
            let served: Vec<u64> = answer.iter().map(|v| v.to_bits()).collect();
            let want_slice = &want.prediction_bits[q * served.len()..(q + 1) * served.len()];
            anyhow::ensure!(
                served == want_slice,
                "job {job} probe {q}: served prediction differs from the clean run"
            );
        }
    }
    reaper.0.push(daemon);
    drop(reaper);
    let _ = std::fs::remove_dir_all(&scratch);
    println!(
        "coordinator chaos: {jobs}/{jobs} job(s) done with bit-identical \
         artifacts across {kills} SIGKILL(s)"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kill_schedule_is_pure_and_seed_sensitive() {
        for k in 0..8 {
            let d = kill_delay_ms(7, k);
            assert_eq!(d, kill_delay_ms(7, k));
            assert!((400..1200).contains(&d), "delay {d} out of range");
        }
        assert_eq!(schedule_fingerprint(7, 2, 3), schedule_fingerprint(7, 2, 3));
        assert_ne!(schedule_fingerprint(7, 2, 3), schedule_fingerprint(8, 2, 3));
        assert_ne!(schedule_fingerprint(7, 2, 3), schedule_fingerprint(7, 3, 3));
    }

    #[test]
    fn probe_queries_are_deterministic_and_in_range() {
        let a = probe_queries(11);
        let b = probe_queries(11);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        for q in &a {
            for &(idx, v) in q {
                assert!(idx < 48);
                assert!(v.is_finite());
            }
        }
        assert_ne!(probe_queries(11), probe_queries(12));
    }

    #[test]
    fn job_specs_differ_by_index_but_share_the_pinned_config() {
        let a = job_spec(5, 0, 900);
        let b = job_spec(5, 1, 900);
        assert_ne!(a.seed, b.seed);
        assert_eq!(a.config, b.config);
        // zero tolerances pin the iteration count — the determinism the
        // bit-parity assertion rests on
        let json = crate::util::json::Json::parse(&a.config).unwrap();
        let cfg = Config::from_json(&json).unwrap();
        assert_eq!(cfg.solver.tol_primal, 0.0);
        assert_eq!(cfg.solver.tol_dual, 0.0);
        assert_eq!(cfg.solver.max_iters, 900);
    }
}

//! Figure 1 — empirical convergence: primal, dual, and bi-linear residuals
//! for rho_b in {2, 4, 8, 16} (log scale in the paper's plot).
//!
//! Paper settings: n = 4000, m = 10000, s_l = 0.8, alpha = 0.5 (i.e.
//! rho_c = 2 rho_b).  The expected shape: rho_b barely moves the primal
//! and dual curves but strongly controls how fast the bilinear residual
//! collapses.

use crate::config::{BackendKind, Config};
use crate::data::SyntheticSpec;
use crate::metrics::CsvTable;

/// Options of the Figure-1 harness.
pub struct Fig1Opts {
    /// Paper-size grid instead of the scaled default.
    pub full: bool,
    /// Outer iterations to trace.
    pub iters: usize,
    /// Backend the nodes run.
    pub backend: BackendKind,
    /// Optional CSV output path.
    pub out: Option<String>,
}

impl Default for Fig1Opts {
    fn default() -> Self {
        Fig1Opts {
            full: false,
            iters: 60,
            backend: BackendKind::Native,
            out: None,
        }
    }
}

/// Regenerate Figure 1 (residual convergence vs rho_b).
pub fn fig1(opts: &Fig1Opts) -> anyhow::Result<CsvTable> {
    let (n, m) = if opts.full { (4000, 10_000) } else { (500, 2_000) };
    let nodes = 4;
    let rho_bs = [2.0, 4.0, 8.0, 16.0];

    let mut spec = SyntheticSpec::regression(n, m, nodes);
    spec.sparsity_level = 0.8;
    let ds = spec.generate();

    // rho_c is FIXED across the sweep (the paper's claim "rho_b has minimal
    // impact on the primal and dual residuals" is about varying rho_b under
    // a fixed consensus penalty); the alpha = 0.5 rule anchors rho_c to the
    // largest rho_b in the sweep: rho_c = max(rho_b) / alpha.
    let rho_c = rho_bs.last().unwrap() / 0.5;
    let mut table = CsvTable::new(&["rho_b", "iter", "primal", "dual", "bilinear"]);
    for &rho_b in &rho_bs {
        let mut cfg = Config::default();
        cfg.platform.nodes = nodes;
        cfg.platform.backend = opts.backend;
        cfg.solver.kappa = spec.kappa();
        cfg.solver.rho_b = rho_b;
        cfg.solver.rho_c = rho_c;
        cfg.solver.rho_l = rho_c;
        cfg.solver.max_iters = opts.iters;
        cfg.solver.tol_primal = 0.0; // run the full horizon for the curves
        cfg.solver.polish = false;

        eprintln!("fig1: rho_b = {rho_b} (n={n}, m={m}, N={nodes})");
        let run = super::run_timed(&ds, &cfg, true)?;
        for rec in &run.result.trace.records {
            table.row(vec![
                format!("{rho_b}"),
                rec.iter.to_string(),
                format!("{:.6e}", rec.primal),
                format!("{:.6e}", rec.dual),
                format!("{:.6e}", rec.bilinear),
            ]);
        }
    }
    Ok(table)
}

//! Figure 4 — total CPU<->GPU data-transfer time during execution, for both
//! the feature-scaling and sample-scaling scenarios, N in {2, 4, 8}.
//!
//! Transfers are the staging copies into/out of PJRT buffers recorded by
//! the ledger (measured), plus a modeled PCIe time when `--pcie-gbps` is
//! given (`bytes / bandwidth`), which projects the measured volume onto
//! the paper's physical link.  Expected shape: transfer time grows with
//! the feature count (bigger z/u/x vectors each round) and stays nearly
//! flat in the sample sweep (fixed parameter volume per iteration; only
//! the setup staging grows).

use crate::metrics::CsvTable;

/// Options of the Figure-4 harness.
pub struct Fig4Opts {
    /// Paper-size grid instead of the scaled default.
    pub full: bool,
    /// Outer iterations to time.
    pub iters: usize,
    /// Synthetic PCIe bandwidth for the transfer model (Gbps).
    pub pcie_gbps: Option<f64>,
    /// Optional CSV output path.
    pub out: Option<String>,
}

impl Default for Fig4Opts {
    fn default() -> Self {
        Fig4Opts {
            full: false,
            iters: 10,
            pcie_gbps: Some(16.0), // PCIe 3.0 x16-ish, the paper's 4070 link class
            out: None,
        }
    }
}

/// Regenerate Figure 4 (CPU<->GPU transfer time vs n and m).
pub fn fig4(opts: &Fig4Opts) -> anyhow::Result<CsvTable> {
    let mut table = CsvTable::new(&[
        "scenario",
        "sweep_value",
        "nodes",
        "measured_transfer_s",
        "modeled_pcie_s",
        "h2d_mb",
        "d2h_mb",
    ]);

    let scaling = super::scaling::ScalingOpts {
        full: opts.full,
        iters: opts.iters,
        out: None,
    };

    // feature sweep
    let feat = super::scaling::fig2(&scaling)?;
    harvest("features", &feat, opts, &mut table);
    // sample sweep
    let samp = super::scaling::fig3(&scaling)?;
    harvest("samples", &samp, opts, &mut table);
    Ok(table)
}

fn harvest(scenario: &str, src: &CsvTable, opts: &Fig4Opts, out: &mut CsvTable) {
    // columns of the scaling table:
    // 0 sweep, 1 nodes, 2 backend, 3 solve, 4 setup, 5 transfer_s, 6 h2d, 7 d2h
    for row in &src.rows {
        if row[2] != "xla" {
            continue; // only the GPU backend has transfers
        }
        let h2d_mb: f64 = row[6].parse().unwrap_or(0.0);
        let d2h_mb: f64 = row[7].parse().unwrap_or(0.0);
        let modeled = opts
            .pcie_gbps
            .map(|g| (h2d_mb + d2h_mb) * 1e6 / (g * 1e9 / 8.0))
            .unwrap_or(0.0);
        out.row(vec![
            scenario.to_string(),
            row[0].clone(),
            row[1].clone(),
            row[5].clone(),
            format!("{modeled:.4}"),
            row[6].clone(),
            row[7].clone(),
        ]);
    }
}

//! `psfit bench` — kernel-layer micro-benchmarks: tiled-scalar vs SIMD
//! kernels (the runtime-ISA dispatch table's two endpoints), serial vs
//! pooled block sweeps, and the dense-vs-CSR sparse data path swept across
//! densities (0.01, 0.05, 0.25, 1.0) so the report records the storage
//! crossover that calibrates `platform.sparse_threshold`.
//!
//! The dense entries (`matvec`, `matvec_t`, `gram`, `matmul_k8`) time the
//! pinned scalar variant against the host's widest SIMD variant — the
//! ISSUE's acceptance numbers (>= 2x on `matvec`/`gram` on an AVX2 host)
//! come straight from this table.  On a scalar-only host both sides time
//! the same kernels and the speedup hovers at 1.0.
//!
//! Prints the usual pretty table / optional CSV and always writes a
//! machine-readable `BENCH_kernels.json` (validated by the CI smoke step
//! and summarized in EXPERIMENTS.md), seeding the repo's perf trajectory:
//! every future kernel change can be judged against this file.

use std::time::Duration;

use crate::backend::native::{NativeBackend, SolveMode};
use crate::backend::{BlockParams, NodeBackend};
use crate::data::{FeaturePlan, SparseMode, SyntheticSpec};
use crate::linalg::simd::{self, Isa};
use crate::linalg::{csr, kernels, CsrMatrix, Matrix};
use crate::losses::Squared;
use crate::metrics::CsvTable;
use crate::util::bench::bench;
use crate::util::json::Json;
use crate::util::pool::WorkerPool;
use crate::util::rng::Rng;

/// Options of the `psfit bench` kernel harness.
pub struct KernelBenchOpts {
    /// Small shapes + short timing windows (CI smoke).
    pub quick: bool,
    /// Worker threads for the pooled sweep (`0` = all cores).
    pub threads: usize,
    /// Where to write the JSON report.
    pub json: String,
    /// Optional CSV path (same convention as the figure harnesses).
    pub out: Option<String>,
}

struct Entry {
    name: &'static str,
    m: usize,
    n: usize,
    blocks: usize,
    /// Design-matrix nonzero fraction the entry ran on (1.0 = dense).
    density: f64,
    baseline_ns: f64,
    optimized_ns: f64,
}

impl Entry {
    fn speedup(&self) -> f64 {
        if self.optimized_ns > 0.0 {
            self.baseline_ns / self.optimized_ns
        } else {
            0.0
        }
    }

    fn json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.to_string())),
            ("m", Json::Num(self.m as f64)),
            ("n", Json::Num(self.n as f64)),
            ("blocks", Json::Num(self.blocks as f64)),
            ("density", Json::Num(self.density)),
            ("baseline_ns", Json::Num(self.baseline_ns)),
            ("optimized_ns", Json::Num(self.optimized_ns)),
            ("speedup", Json::Num(self.speedup())),
        ])
    }
}

fn report_json(entries: &[Entry], quick: bool, threads: usize, isa: Isa) -> Json {
    Json::obj(vec![
        ("schema", Json::Num(3.0)),
        ("generated_by", Json::Str("psfit bench".to_string())),
        ("quick", Json::Bool(quick)),
        ("threads", Json::Num(threads as f64)),
        ("isa", Json::Str(isa.name().to_string())),
        (
            "entries",
            Json::Arr(entries.iter().map(|e| e.json()).collect()),
        ),
    ])
}

/// Run the kernel micro-benchmarks and write `BENCH_kernels.json`.
pub fn kernels(opts: &KernelBenchOpts) -> anyhow::Result<CsvTable> {
    // (m, n, blocks): the last full shape is the ISSUE's acceptance shape
    let shapes: &[(usize, usize, usize)] = if opts.quick {
        &[(256, 96, 2)]
    } else {
        &[(512, 128, 2), (2048, 512, 4), (4096, 1024, 8)]
    };
    // sparse-path density sweep (recorded per entry in the report)
    const DENSITIES: &[f64] = &[0.01, 0.05, 0.25, 1.0];
    let target = Duration::from_millis(if opts.quick { 12 } else { 120 });
    let threads = WorkerPool::new(opts.threads).threads();
    // the two endpoints of the dispatch table on this host
    let wide = simd::active();

    let mut entries: Vec<Entry> = Vec::new();
    for &(m, n, blocks) in shapes {
        eprintln!("# shape m={m} n={n} blocks={blocks} (scalar vs {})", wide.name());
        let mut rng = Rng::seed_from(42);
        let mut a = Matrix::zeros(m, n);
        a.for_each_mut(|v| *v = rng.normal_f32());
        let view = a.view();
        let x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let v: Vec<f32> = (0..m).map(|_| rng.normal_f32()).collect();
        // `cols` is the column count the op actually ran on (the gram
        // entry benches one feature block, not the full matrix)
        let mut push = |name, cols: usize, base_ns, opt_ns| {
            entries.push(Entry {
                name,
                m,
                n: cols,
                blocks,
                density: 1.0,
                baseline_ns: base_ns,
                optimized_ns: opt_ns,
            });
        };

        // matvec: y = A x — tiled scalar vs the active SIMD variant
        let mut y = vec![0.0f32; m];
        let b0 = bench("matvec_scalar", target, || {
            kernels::matvec_isa(Isa::Scalar, &view, &x, &mut y);
            std::hint::black_box(&y);
        });
        let b1 = bench("matvec_simd", target, || {
            kernels::matvec_isa(wide, &view, &x, &mut y);
            std::hint::black_box(&y);
        });
        push("matvec", n, b0.median_ns, b1.median_ns);

        // matvec_t: y = A^T v (the per-iteration data-touching op)
        let mut yt = vec![0.0f32; n];
        let b0 = bench("matvec_t_scalar", target, || {
            kernels::matvec_t_isa(Isa::Scalar, &view, &v, &mut yt);
            std::hint::black_box(&yt);
        });
        let b1 = bench("matvec_t_simd", target, || {
            kernels::matvec_t_isa(wide, &view, &v, &mut yt);
            std::hint::black_box(&yt);
        });
        push("matvec_t", n, b0.median_ns, b1.median_ns);

        // gram on one feature block (setup-time op), read in place
        let bw = n / blocks;
        let bview = a.column_block_view(0, bw);
        let mut g = vec![0.0f32; bw * bw];
        let b0 = bench("gram_scalar", target, || {
            g.fill(0.0);
            kernels::gram_isa(Isa::Scalar, &bview, &mut g);
            std::hint::black_box(&g);
        });
        let b1 = bench("gram_simd", target, || {
            g.fill(0.0);
            kernels::gram_isa(wide, &bview, &mut g);
            std::hint::black_box(&g);
        });
        push("gram", bw, b0.median_ns, b1.median_ns);

        // multi-RHS matmul: 8 class columns at once, scalar vs SIMD
        let k = 8;
        let xk: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let mut yk = vec![0.0f32; k * m];
        let b0 = bench("matmul_scalar_k8", target, || {
            kernels::matmul_isa(Isa::Scalar, &view, &xk, k, &mut yk);
            std::hint::black_box(&yk);
        });
        let b1 = bench("matmul_simd_k8", target, || {
            kernels::matmul_isa(wide, &view, &xk, k, &mut yk);
            std::hint::black_box(&yk);
        });
        push("matmul_k8", n, b0.median_ns, b1.median_ns);

        // block sweep: serial vs pooled (CG mode keeps the data-touching
        // kernels dominant, like the artifact path; both sides dispatch
        // to the active ISA)
        let ds = SyntheticSpec::regression(n, m, 1).generate();
        let plan = FeaturePlan::new(n, blocks, usize::MAX >> 1);
        let params = BlockParams {
            rho_l: 2.0,
            rho_c: 1.0,
            reg: 1.1,
        };
        let mode = SolveMode::Cg { iters: 8 };
        let corr: Vec<f32> = (0..m).map(|_| rng.normal_f32()).collect();
        let z: Vec<Vec<f32>> = plan.ranges.iter().map(|&(_, w)| vec![0.1; w]).collect();
        let u: Vec<Vec<f32>> = plan.ranges.iter().map(|&(_, w)| vec![0.0; w]).collect();
        let mut xb: Vec<Vec<f32>> = plan.ranges.iter().map(|&(_, w)| vec![0.0; w]).collect();
        let mut pb: Vec<Vec<f32>> = plan.ranges.iter().map(|_| vec![0.0; m]).collect();
        let mut serial =
            NativeBackend::new(&ds.shards[0], &plan, Box::new(Squared), mode).with_threads(1);
        let mut pooled = NativeBackend::new(&ds.shards[0], &plan, Box::new(Squared), mode)
            .with_threads(threads);
        let b0 = bench("sweep_serial", target, || {
            serial.block_sweep(params, 1, &corr, &z, &u, &mut xb, &mut pb);
        });
        let b1 = bench("sweep_pooled", target, || {
            pooled.block_sweep(params, 1, &corr, &z, &u, &mut xb, &mut pb);
        });
        push("block_sweep", n, b0.median_ns, b1.median_ns);

        // ---- sparse data path: dense tiled vs CSR, swept over density --
        // (records the storage crossover; at density 1.0 CSR loses, which
        // is exactly what `platform.sparse_threshold` encodes; both
        // storage formats dispatch to the active ISA)
        for &density in DENSITIES {
            eprintln!("#   density {density}");
            let mut srng = Rng::seed_from(7);
            let mut ad = Matrix::zeros(m, n);
            ad.for_each_mut(|vv| *vv = srng.normal_f32());
            if density < 1.0 {
                ad.for_each_mut(|vv| {
                    if srng.uniform() >= density {
                        *vv = 0.0;
                    }
                });
            }
            let sp = CsrMatrix::from_dense(&ad);
            let dview = ad.view();

            // spmv_t: the per-iteration data-touching op
            let vm: Vec<f32> = (0..m).map(|_| srng.normal_f32()).collect();
            let ranges = sp.block_ranges(0, n);
            let sview = sp.block_view(&ranges, 0, n);
            let mut ys = vec![0.0f32; n];
            let b0 = bench("spmv_t_dense", target, || {
                kernels::matvec_t(&dview, &vm, &mut ys);
                std::hint::black_box(&ys);
            });
            let b1 = bench("spmv_t_csr", target, || {
                csr::spmv_t(&sview, &vm, &mut ys);
                std::hint::black_box(&ys);
            });
            entries.push(Entry {
                name: "spmv_t",
                m,
                n,
                blocks,
                density,
                baseline_ns: b0.median_ns,
                optimized_ns: b1.median_ns,
            });

            // gram on one feature block (setup-time op), both in place
            let sbw = n / blocks;
            let branges = sp.block_ranges(0, sbw);
            let bsview = sp.block_view(&branges, 0, sbw);
            let bdview = ad.column_block_view(0, sbw);
            let mut gs = vec![0.0f32; sbw * sbw];
            let b0 = bench("gram_dense", target, || {
                gs.fill(0.0);
                kernels::gram(&bdview, &mut gs);
                std::hint::black_box(&gs);
            });
            let b1 = bench("gram_csr", target, || {
                gs.fill(0.0);
                csr::gram_sparse(&bsview, &mut gs);
                std::hint::black_box(&gs);
            });
            entries.push(Entry {
                name: "gram_sparse",
                m,
                n: sbw,
                blocks,
                density,
                baseline_ns: b0.median_ns,
                optimized_ns: b1.median_ns,
            });

            // whole inner-sweep step 3 on a planted sparse dataset:
            // dense tiled backend vs CSR backend, storage the only delta
            let mut sspec = SyntheticSpec::regression(n, m, 1);
            sspec.density = density;
            let sds = sspec.generate();
            let dense_shard = sds.shards[0].with_storage_policy(SparseMode::Never, 0.0);
            let csr_shard = sds.shards[0].with_storage_policy(SparseMode::Always, 0.0);
            let scorr: Vec<f32> = (0..m).map(|_| srng.normal_f32()).collect();
            let sz: Vec<Vec<f32>> =
                plan.ranges.iter().map(|&(_, w)| vec![0.1; w]).collect();
            let su: Vec<Vec<f32>> =
                plan.ranges.iter().map(|&(_, w)| vec![0.0; w]).collect();
            let mut sxb: Vec<Vec<f32>> =
                plan.ranges.iter().map(|&(_, w)| vec![0.0; w]).collect();
            let mut spb: Vec<Vec<f32>> = plan.ranges.iter().map(|_| vec![0.0; m]).collect();
            let mut dense_be =
                NativeBackend::new(&dense_shard, &plan, Box::new(Squared), mode);
            let mut csr_be = NativeBackend::new(&csr_shard, &plan, Box::new(Squared), mode);
            let b0 = bench("sweep_dense", target, || {
                dense_be.block_sweep(params, 1, &scorr, &sz, &su, &mut sxb, &mut spb);
            });
            let b1 = bench("sweep_csr", target, || {
                csr_be.block_sweep(params, 1, &scorr, &sz, &su, &mut sxb, &mut spb);
            });
            entries.push(Entry {
                name: "sparse_block_sweep",
                m,
                n,
                blocks,
                density,
                baseline_ns: b0.median_ns,
                optimized_ns: b1.median_ns,
            });
        }
    }

    // ---- emit ------------------------------------------------------------
    let json = report_json(&entries, opts.quick, threads, wide);
    std::fs::write(&opts.json, format!("{json}\n"))
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", opts.json))?;
    eprintln!("wrote {}", opts.json);

    let mut table = CsvTable::new(&[
        "kernel",
        "m",
        "n",
        "blocks",
        "density",
        "baseline_ns",
        "optimized_ns",
        "speedup",
    ]);
    for e in &entries {
        table.row(vec![
            e.name.to_string(),
            e.m.to_string(),
            e.n.to_string(),
            e.blocks.to_string(),
            format!("{}", e.density),
            format!("{:.0}", e.baseline_ns),
            format!("{:.0}", e.optimized_ns),
            format!("{:.2}", e.speedup()),
        ]);
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_is_well_formed() {
        let entries = vec![Entry {
            name: "matvec",
            m: 64,
            n: 16,
            blocks: 2,
            density: 0.05,
            baseline_ns: 200.0,
            optimized_ns: 100.0,
        }];
        let j = report_json(&entries, true, 4, Isa::Scalar);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_usize(), Some(3));
        assert_eq!(parsed.get("quick").unwrap().as_bool(), Some(true));
        assert_eq!(parsed.get("threads").unwrap().as_usize(), Some(4));
        assert_eq!(parsed.get("isa").unwrap().as_str(), Some("scalar"));
        let arr = parsed.get("entries").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("name").unwrap().as_str(), Some("matvec"));
        assert_eq!(arr[0].get("density").unwrap().as_f64(), Some(0.05));
        assert_eq!(arr[0].get("speedup").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn speedup_handles_zero_denominator() {
        let e = Entry {
            name: "x",
            m: 1,
            n: 1,
            blocks: 1,
            density: 1.0,
            baseline_ns: 10.0,
            optimized_ns: 0.0,
        };
        assert_eq!(e.speedup(), 0.0);
    }
}

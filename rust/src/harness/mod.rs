//! Experiment harnesses — one per table/figure of the paper's §4.
//!
//! Every harness has a **scaled** default grid (laptop-friendly, same
//! qualitative shape) and a `--full` grid at the paper's sizes (needs the
//! paper's 32 GB-class testbed; fig3's full sample sweep in particular).
//! Each prints the table to stdout and optionally writes a CSV.
//!
//! | Harness  | Paper artifact | What must reproduce                        |
//! |----------|----------------|--------------------------------------------|
//! | [`fig1`] | Figure 1       | residual curves; rho_b moves b_r only      |
//! | [`table1`]| Table 1       | Bi-cADMM << Lasso << Gurobi(BnB); asterisks|
//! | [`fig2`] | Figure 2       | GPU(XLA) flatter than CPU(native) in n     |
//! | [`fig3`] | Figure 3       | same, in per-node samples                  |
//! | [`fig4`] | Figure 4       | transfer time grows with n; flat-ish in m  |
//! | [`straggler`] | (new)     | async coordination hides a 1x-16x straggler|
//! | [`kernels`] | (new)       | SIMD kernels / pooled sweeps beat scalar   |
//! | [`solver`]  | (new)       | end-to-end rounds/sec + time-to-tolerance  |
//! | [`path`]    | (new)       | warm path sweep beats cold-started sequence|
//! | [`transport`] | (new)     | in-process vs localhost-socket round cost  |

/// Deterministic fault-injection harness (`psfit chaos`).
pub mod chaos;
/// Coordinator kill/restart chaos (`psfit chaos --coordinator`).
pub mod coordinator;
/// Deterministic numerical-poison harness (`psfit chaos --numerics`).
pub mod numerics;
/// Figure 1: residual convergence vs rho_b.
pub mod fig1;
/// Figure 4: CPU<->GPU transfer time.
pub mod fig4;
/// Kernel-layer micro-benchmarks (`psfit bench`).
pub mod kernels;
/// Warm-vs-cold sparsity-path benchmark (`psfit pathbench`).
pub mod path;
/// Figures 2 and 3: feature/sample scaling.
pub mod scaling;
/// End-to-end solver benchmark (`psfit bench --solver`).
pub mod solver;
/// Sync-vs-async coordination under a straggler.
pub mod straggler;
/// Table 1: Bi-cADMM vs MIP vs Lasso.
pub mod table1;
/// Transport round-latency benchmark (`psfit bench --transport`).
pub mod transport;

pub use chaos::chaos;
pub use coordinator::coordinator_chaos;
pub use fig1::fig1;
pub use numerics::numerics;
pub use fig4::fig4;
pub use kernels::kernels;
pub use path::path_bench;
pub use scaling::{fig2, fig3};
pub use solver::solver_bench;
pub use straggler::straggler;
pub use table1::table1;
pub use transport::transport_bench;

use crate::admm::{SolveOptions, SolveResult};
use crate::config::Config;
use crate::data::Dataset;
use crate::driver;
use crate::util::Stopwatch;

/// A solve with setup (backend construction / staging / compile) separated
/// from the iteration loop — Table 1 and the scaling figures time the
/// iteration loop, like the paper times the solver (not data loading).
pub struct TimedRun {
    /// The finished solve.
    pub result: SolveResult,
    /// Seconds spent building workers + cluster (staging, compiles).
    pub setup_seconds: f64,
    /// Seconds spent in the iteration loop.
    pub solve_seconds: f64,
}

/// Fit `ds` under `cfg`, timing setup and solve separately.  Honors
/// `platform.transport`, so a benchmark config can point at a socket
/// fleet; setup time then covers connect + shard shipping.  With
/// `solver.checkpoint` set the solve writes (and resumes) mid-fit PSF1
/// snapshots — `psfit train --checkpoint` lands here.
pub fn run_timed(ds: &Dataset, cfg: &Config, threaded: bool) -> anyhow::Result<TimedRun> {
    let watch = Stopwatch::start();
    let dim = ds.n_features * ds.width;
    let mut cluster = driver::build_transport_cluster(ds, cfg, threaded)?;
    let setup_seconds = watch.elapsed_secs();
    let opts = SolveOptions::default();
    let result = if cfg.solver.checkpoint.is_empty() {
        crate::admm::solve(cluster.as_mut(), dim, cfg, Some(ds), &opts)?
    } else {
        crate::admm::solve_checkpointed(cluster.as_mut(), dim, cfg, ds, &opts)?
    };
    let solve_seconds = result.wall_seconds;
    Ok(TimedRun {
        result,
        setup_seconds,
        solve_seconds,
    })
}

/// Write a CSV if a path was given; always print the pretty table.
pub fn emit(table: &crate::metrics::CsvTable, out: Option<&str>) -> anyhow::Result<()> {
    println!("{}", table.to_pretty());
    if let Some(path) = out {
        table.write_file(std::path::Path::new(path))?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

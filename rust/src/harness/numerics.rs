//! `psfit chaos --numerics` — deterministic *numerical* fault injection.
//!
//! Where the wire-chaos harness damages frames, this one damages the
//! math: a [`PoisonCluster`] wraps any transport and, on a pure seeded
//! per-`(node, round)` schedule, overwrites one entry of a node's reply
//! with `NaN`, `Inf`, or a `1e300` blowup *after* the transport delivers
//! it — exactly the poison a faulting accelerator or a corrupted
//! reduction would hand the coordinator.  The harness fits one clean
//! reference problem, repeats it twice under the identical poison
//! schedule (the printed fingerprint proves it), and asserts:
//!
//!   * every injected poison was quarantined by the reply guard before
//!     folding (`quarantined == injected`, checked per run);
//!   * no non-finite value ever reached `GlobalState` — the wrapper
//!     rejects any broadcast `z` with a non-finite entry, so a guard
//!     leak fails the run loudly instead of silently corrupting it;
//!   * every poisoned run that converges recovers **exactly** the clean
//!     run's support.

use crate::backend::BlockParams;
use crate::config::Config;
use crate::data::SyntheticSpec;
use crate::driver;
use crate::metrics::{CoordinationStats, TransferLedger};
use crate::network::socket::wire::fnv1a;
use crate::network::{Cluster, NodeReply, WarmState};
use crate::util::rng::Rng;

/// A seeded poison schedule: per-(node, round) probabilities of each
/// poison kind, mutually exclusive (a reply suffers at most one), so
/// they must sum to at most `1.0`.  Parsed from the compact form `psfit
/// chaos --numerics --faults` accepts, e.g. `"nan=0.02,inf=0.02,huge=0.05"`.
#[derive(Debug, Clone, PartialEq)]
pub struct PoisonSpec {
    /// Probability a reply gets one entry overwritten with `NaN`.
    pub nan: f64,
    /// Probability a reply gets one entry overwritten with `+Inf`.
    pub inf: f64,
    /// Probability a reply gets one entry overwritten with `1e300` — a
    /// finite norm blowup, the kind only the guard's cap can catch.
    pub huge: f64,
    /// Schedule seed: same seed, same poisons, every run.
    pub seed: u64,
}

impl Default for PoisonSpec {
    fn default() -> Self {
        PoisonSpec {
            nan: 0.0,
            inf: 0.0,
            huge: 0.0,
            seed: 0xBADF1A,
        }
    }
}

/// One reply's fate under a [`PoisonSpec`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Poison {
    /// Deliver untouched.
    Clean,
    /// Overwrite one entry with `NaN`.
    Nan,
    /// Overwrite one entry with `+Inf`.
    Inf,
    /// Overwrite one entry with `1e300`.
    Huge,
}

impl Poison {
    /// The value this poison plants, if any.
    pub fn value(self) -> Option<f64> {
        match self {
            Poison::Clean => None,
            Poison::Nan => Some(f64::NAN),
            Poison::Inf => Some(f64::INFINITY),
            Poison::Huge => Some(1e300),
        }
    }
}

impl PoisonSpec {
    /// Parse the compact `key=value,...` form.  Keys: `nan`, `inf`,
    /// `huge`, `seed`.  Empty input is the all-quiet spec.
    pub fn parse(s: &str) -> anyhow::Result<PoisonSpec> {
        let mut spec = PoisonSpec::default();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("poison spec `{part}` is not key=value"))?;
            let prob = |v: &str| -> anyhow::Result<f64> {
                let p: f64 = v
                    .parse()
                    .map_err(|_| anyhow::anyhow!("poison spec `{key}`: `{v}` is not a number"))?;
                anyhow::ensure!(
                    (0.0..=1.0).contains(&p),
                    "poison spec `{key}`: probability {p} outside [0, 1]"
                );
                Ok(p)
            };
            match key {
                "nan" => spec.nan = prob(value)?,
                "inf" => spec.inf = prob(value)?,
                "huge" => spec.huge = prob(value)?,
                "seed" => {
                    spec.seed = value
                        .parse()
                        .map_err(|_| anyhow::anyhow!("poison spec `seed`: `{value}` is not a u64"))?
                }
                other => anyhow::bail!("unknown poison spec key `{other}`"),
            }
        }
        let total = spec.nan + spec.inf + spec.huge;
        anyhow::ensure!(
            total <= 1.0 + 1e-12,
            "poison probabilities sum to {total}, which exceeds 1"
        );
        Ok(spec)
    }

    /// The poison (if any) node `node`'s reply suffers in round `round`.
    /// Pure in its arguments — this *is* the poison schedule.
    pub fn fault_for(&self, node: u64, round: u64) -> Poison {
        let mut key = [0u8; 24];
        key[..8].copy_from_slice(&self.seed.to_le_bytes());
        key[8..16].copy_from_slice(&node.to_le_bytes());
        key[16..].copy_from_slice(&round.to_le_bytes());
        let mut rng = Rng::seed_from(fnv1a(&key));
        let draw = rng.uniform();
        let mut edge = self.nan;
        if draw < edge {
            return Poison::Nan;
        }
        edge += self.inf;
        if draw < edge {
            return Poison::Inf;
        }
        edge += self.huge;
        if draw < edge {
            return Poison::Huge;
        }
        Poison::Clean
    }

    /// FNV-1a digest of the schedule's first `rounds` decisions for every
    /// node — the value `psfit chaos --numerics` prints so two runs can
    /// prove they faced the same schedule.
    pub fn schedule_fingerprint(&self, nodes: u64, rounds: u64) -> u64 {
        let mut codes = Vec::with_capacity((nodes * rounds) as usize);
        for node in 0..nodes {
            for round in 0..rounds {
                codes.push(match self.fault_for(node, round) {
                    Poison::Clean => 0u8,
                    Poison::Nan => 1,
                    Poison::Inf => 2,
                    Poison::Huge => 3,
                });
            }
        }
        fnv1a(&codes)
    }
}

impl std::fmt::Display for PoisonSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "nan={},inf={},huge={},seed={}",
            self.nan, self.inf, self.huge, self.seed
        )
    }
}

/// A [`Cluster`] adapter that poisons replies on a [`PoisonSpec`]
/// schedule and enforces the solver's cardinal numerical invariant: no
/// broadcast `z` may ever carry a non-finite entry.  If the reply guard
/// leaks a poisoned reply into the fold, the next `round()` here fails
/// the run with a structured error instead of letting NaN propagate.
pub struct PoisonCluster {
    inner: Box<dyn Cluster>,
    spec: PoisonSpec,
    round_no: u64,
    injected: u64,
}

impl PoisonCluster {
    /// Wrap `inner`, poisoning its replies per `spec`.
    pub fn new(inner: Box<dyn Cluster>, spec: PoisonSpec) -> PoisonCluster {
        PoisonCluster {
            inner,
            spec,
            round_no: 0,
            injected: 0,
        }
    }

    /// Poisons injected so far (one reply entry each).
    pub fn injected(&self) -> u64 {
        self.injected
    }
}

impl Cluster for PoisonCluster {
    fn nodes(&self) -> usize {
        self.inner.nodes()
    }

    fn round(&mut self, z: &[f64]) -> anyhow::Result<Vec<NodeReply>> {
        anyhow::ensure!(
            z.iter().all(|v| v.is_finite()),
            "round {}: broadcast z carries a non-finite entry — the reply \
             guard leaked poison into GlobalState",
            self.round_no
        );
        let mut replies = self.inner.round(z)?;
        for reply in &mut replies {
            if let Some(v) = self.spec.fault_for(reply.node as u64, self.round_no).value() {
                let n = reply.x.len();
                if n > 0 {
                    reply.x[self.round_no as usize % n] = v;
                    self.injected += 1;
                }
            }
        }
        self.round_no += 1;
        Ok(replies)
    }

    fn loss_value(&mut self) -> anyhow::Result<f64> {
        self.inner.loss_value()
    }

    fn ledger(&mut self) -> TransferLedger {
        self.inner.ledger()
    }

    fn recycle(&mut self, replies: Vec<NodeReply>) {
        self.inner.recycle(replies)
    }

    fn coordination(&self) -> Option<CoordinationStats> {
        self.inner.coordination()
    }

    fn export_warm(&mut self) -> anyhow::Result<Vec<WarmState>> {
        self.inner.export_warm()
    }

    fn reseed(&mut self, states: &[WarmState], params: BlockParams) -> anyhow::Result<()> {
        self.inner.reseed(states, params)
    }

    fn banish(&mut self, node: usize, why: &str) {
        self.inner.banish(node, why)
    }
}

/// Settings for `psfit chaos --numerics`.
#[derive(Debug, Clone)]
pub struct NumericsOpts {
    /// Smaller problem and iteration budget (CI smoke).
    pub quick: bool,
    /// Poison-schedule seed; overrides the spec default (and any `seed=`
    /// inside `--faults`) when set to a non-default value.
    pub seed: u64,
    /// Compact poison spec (`nan=0.02,inf=0.02,huge=0.05`); `None` uses
    /// a mild mixed schedule that exercises every poison kind.
    pub faults: Option<String>,
    /// Node count (in-process threaded cluster).
    pub nodes: usize,
}

/// The mild default schedule: a tenth of replies arrive poisoned, split
/// across all three kinds so the guard's non-finite path *and* its norm
/// cap both fire — frequent enough that quarantines land every run,
/// rare enough that consensus re-equilibrates between them.
const DEFAULT_FAULTS: &str = "nan=0.02,inf=0.02,huge=0.05";

/// Run the harness; errors mean a guard leak or a parity violation (or a
/// setup failure), so CI can gate on the exit code.
pub fn numerics(opts: &NumericsOpts) -> anyhow::Result<()> {
    anyhow::ensure!(opts.nodes >= 1, "psfit chaos --numerics needs at least one node");
    let mut spec = PoisonSpec::parse(opts.faults.as_deref().unwrap_or(DEFAULT_FAULTS))?;
    if opts.seed != PoisonSpec::default().seed {
        spec.seed = opts.seed;
    }

    let (n, m, iters) = if opts.quick {
        (40usize, 400usize, 800usize)
    } else {
        (64, 600, 1000)
    };
    // same well-conditioned recovery instance as the wire-chaos harness:
    // this harness judges the guard, not solver difficulty
    let mut sspec = SyntheticSpec::regression(n, m, opts.nodes);
    sspec.sparsity_level = 0.9;
    sspec.noise_std = 0.05;
    let ds = sspec.generate();

    let mut cfg = Config::default();
    cfg.platform.nodes = opts.nodes;
    // never banish: the poison schedule is i.i.d. per round, so a node
    // that drew three strikes in a row is not actually broken — keep the
    // roster intact so converged runs stay comparable to the clean one
    // (escalation is covered by the guard's own tests and tests/heal.rs)
    cfg.platform.quarantine_limit = 0;
    cfg.solver.kappa = sspec.kappa();
    cfg.solver.rho_c = 1.0;
    cfg.solver.rho_b = 0.5;
    cfg.solver.max_iters = iters;
    cfg.solver.tol_primal = 1e-2;
    cfg.solver.tol_dual = 1e-2;
    cfg.solver.tol_bilinear = 1e-1;

    let fingerprint = spec.schedule_fingerprint(opts.nodes as u64, iters as u64);
    println!("poison spec: {spec}");
    println!("fingerprint: {fingerprint:#018x} (same seed => same schedule, every run)");

    // ---- clean reference run -------------------------------------------
    let clean = driver::fit(&ds, &cfg)?;
    anyhow::ensure!(
        clean.converged,
        "the clean run did not converge in {iters} iterations; the numerics \
         parity check needs a converged reference"
    );
    println!(
        "clean run:   converged in {} iters, support {:?}",
        clean.iters, &clean.support
    );

    // ---- poisoned runs --------------------------------------------------
    let dim = ds.n_features * ds.width;
    let mut converged_runs = 0usize;
    for run in 1..=2u32 {
        // a fresh wrapper per run: the round counter restarts at 0, so
        // this run faces the identical poison schedule as the last one
        let inner = driver::build_transport_cluster(&ds, &cfg, true)?;
        let mut cluster = PoisonCluster::new(inner, spec.clone());
        let outcome = crate::admm::solve(
            &mut cluster,
            dim,
            &cfg,
            Some(&ds),
            &crate::admm::SolveOptions::default(),
        );
        match outcome {
            Ok(res) => {
                let injected = cluster.injected();
                let quarantined = res
                    .coordination
                    .as_ref()
                    .map(|c| c.quarantined)
                    .unwrap_or(0);
                println!(
                    "numerics run {run}: converged={} iters={} poisons_injected={injected} quarantined={quarantined}",
                    res.converged, res.iters
                );
                anyhow::ensure!(
                    quarantined == injected,
                    "numerics run {run}: injected {injected} poison(s) but the \
                     guard quarantined {quarantined} — a poisoned reply reached \
                     the fold"
                );
                if res.converged {
                    converged_runs += 1;
                    anyhow::ensure!(
                        res.support == clean.support,
                        "numerics run {run} converged to support {:?}, clean run \
                         recovered {:?} — poison injection changed the answer",
                        res.support,
                        clean.support
                    );
                    println!("             support parity with the clean run: OK");
                } else {
                    println!("             did not converge under poison; parity not checked");
                }
            }
            Err(e) => {
                // a watchdog trip is a legitimate outcome of a schedule
                // hostile enough to starve whole rounds
                println!("numerics run {run}: failed cleanly ({e:#})");
            }
        }
    }
    anyhow::ensure!(
        converged_runs > 0,
        "no poisoned run converged — the schedule is too hostile for a \
         meaningful parity check (try a tamer --faults)"
    );
    println!("numerics: {converged_runs}/2 poisoned run(s) converged with support parity");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poison_schedule_is_pure_and_parses_round_trip() {
        let spec = PoisonSpec::parse("nan=0.1,inf=0.2,huge=0.3,seed=7").unwrap();
        assert_eq!(spec, PoisonSpec::parse(&spec.to_string()).unwrap());
        for node in 0..4u64 {
            for round in 0..32u64 {
                assert_eq!(spec.fault_for(node, round), spec.fault_for(node, round));
            }
        }
        assert_eq!(
            spec.schedule_fingerprint(4, 32),
            spec.schedule_fingerprint(4, 32)
        );
        // a different seed must move the fingerprint
        let other = PoisonSpec {
            seed: 8,
            ..spec.clone()
        };
        assert_ne!(
            spec.schedule_fingerprint(4, 32),
            other.schedule_fingerprint(4, 32)
        );
        assert!(PoisonSpec::parse("nan=0.6,inf=0.6").is_err());
        assert!(PoisonSpec::parse("gamma=0.1").is_err());
    }

    /// The CI smoke path end-to-end, on a tiny problem: same seed, same
    /// schedule, every poison quarantined, parity against the clean run.
    #[test]
    fn quick_numerics_run_passes_parity() {
        let opts = NumericsOpts {
            quick: true,
            seed: PoisonSpec::default().seed,
            faults: Some(DEFAULT_FAULTS.to_string()),
            nodes: 2,
        };
        numerics(&opts).unwrap();
    }
}

//! `psfit pathbench` — warm-started sparsity paths vs. the equivalent
//! cold-started sequence of independent solves, swept across the density
//! grid from the sparse-data-path PR ({0.01, 0.05, 0.25, 1.0}).
//!
//! For each density the same planted dataset is solved over a descending
//! budget ladder twice:
//!
//!   * **cold** — one independent run per budget, each rebuilding its
//!     cluster (Gram recompute, fresh factorizations, zero state), i.e.
//!     exactly a sequence of `psfit train` runs;
//!   * **warm** — one `path::run_path` sweep: a single cluster, per-block
//!     Gram computed once, Cholesky factors cached, and every point
//!     warm-started from the previous [`crate::admm::SolverState`].
//!
//! The machine-readable report (`BENCH_path.json`, schema 1) records
//! wall-clock, summed outer iterations, and the reuse counters per entry;
//! a CI smoke step validates the schema and that the warm sweep never
//! needs more iterations than the cold sequence.

use crate::admm::SolveOptions;
use crate::config::Config;
use crate::data::SyntheticSpec;
use crate::metrics::CsvTable;
use crate::path::run_path;
use crate::util::json::Json;
use crate::util::Stopwatch;

/// Options of the `psfit pathbench` harness.
pub struct PathBenchOpts {
    /// Small shapes + short ladders (the CI smoke configuration).
    pub quick: bool,
    /// Where to write the JSON report.
    pub json: String,
    /// Optional CSV path (same convention as the figure harnesses).
    pub out: Option<String>,
}

struct Entry {
    n: usize,
    m: usize,
    nodes: usize,
    density: f64,
    budgets: Vec<usize>,
    cold_seconds: f64,
    warm_seconds: f64,
    cold_iters: usize,
    warm_iters: usize,
    gram_builds_cold: u64,
    gram_builds_warm: u64,
    chol_reuses_warm: u64,
}

impl Entry {
    fn speedup(&self) -> f64 {
        if self.warm_seconds > 0.0 {
            self.cold_seconds / self.warm_seconds
        } else {
            0.0
        }
    }

    fn json(&self) -> Json {
        Json::obj(vec![
            ("n", Json::Num(self.n as f64)),
            ("m", Json::Num(self.m as f64)),
            ("nodes", Json::Num(self.nodes as f64)),
            ("density", Json::Num(self.density)),
            (
                "budgets",
                Json::Arr(self.budgets.iter().map(|&b| Json::Num(b as f64)).collect()),
            ),
            ("cold_seconds", Json::Num(self.cold_seconds)),
            ("warm_seconds", Json::Num(self.warm_seconds)),
            ("speedup", Json::Num(self.speedup())),
            ("cold_iters", Json::Num(self.cold_iters as f64)),
            ("warm_iters", Json::Num(self.warm_iters as f64)),
            ("gram_builds_cold", Json::Num(self.gram_builds_cold as f64)),
            ("gram_builds_warm", Json::Num(self.gram_builds_warm as f64)),
            ("chol_reuses_warm", Json::Num(self.chol_reuses_warm as f64)),
        ])
    }
}

fn report_json(entries: &[Entry], quick: bool) -> Json {
    Json::obj(vec![
        ("schema", Json::Num(1.0)),
        ("generated_by", Json::Str("psfit pathbench".to_string())),
        ("quick", Json::Bool(quick)),
        (
            "entries",
            Json::Arr(entries.iter().map(|e| e.json()).collect()),
        ),
    ])
}

/// Run the warm-vs-cold path benchmark and write `BENCH_path.json`.
pub fn path_bench(opts: &PathBenchOpts) -> anyhow::Result<CsvTable> {
    // (n, m, nodes, budgets): the full shape matches the acceptance
    // criterion (3+ budgets); quick is the CI smoke configuration
    let (n, m, nodes, budgets): (usize, usize, usize, Vec<usize>) = if opts.quick {
        (96, 384, 2, vec![24, 12, 6])
    } else {
        (1024, 4096, 4, vec![200, 100, 50])
    };
    let densities: &[f64] = if opts.quick {
        &[0.05, 1.0]
    } else {
        &[0.01, 0.05, 0.25, 1.0]
    };

    let mut entries = Vec::new();
    for &density in densities {
        eprintln!("# density {density}: budgets {budgets:?}");
        let mut spec = SyntheticSpec::regression(n, m, nodes);
        spec.density = density;
        spec.sparsity_level = 1.0 - budgets[0] as f64 / n as f64;
        let ds = spec.generate();

        let mut cfg = Config::default();
        cfg.platform.nodes = nodes;
        cfg.path.budgets = budgets.clone();

        // ---- cold: one independent single-point run per budget ---------
        let watch = Stopwatch::start();
        let mut cold_iters = 0usize;
        let mut gram_builds_cold = 0u64;
        for &k in &budgets {
            let mut ck = cfg.clone();
            ck.path.budgets = vec![k];
            let outcome = run_path(&ds, &ck, &SolveOptions::default(), true)?;
            cold_iters += outcome.trace.total_iters();
            gram_builds_cold += outcome.trace.points.iter().map(|p| p.gram_builds).sum::<u64>();
        }
        let cold_seconds = watch.elapsed_secs();

        // ---- warm: one sweep, one cluster, shared factorizations -------
        let watch = Stopwatch::start();
        let outcome = run_path(&ds, &cfg, &SolveOptions::default(), true)?;
        let warm_seconds = watch.elapsed_secs();
        let warm_iters = outcome.trace.total_iters();
        let gram_builds_warm: u64 = outcome.trace.points.iter().map(|p| p.gram_builds).sum();
        let chol_reuses_warm: u64 = outcome.trace.points.iter().map(|p| p.chol_reuses).sum();

        eprintln!(
            "#   cold {cold_seconds:.3}s / {cold_iters} iters, warm {warm_seconds:.3}s / {warm_iters} iters"
        );
        entries.push(Entry {
            n,
            m,
            nodes,
            density,
            budgets: budgets.clone(),
            cold_seconds,
            warm_seconds,
            cold_iters,
            warm_iters,
            gram_builds_cold,
            gram_builds_warm,
            chol_reuses_warm,
        });
    }

    // ---- emit ------------------------------------------------------------
    let json = report_json(&entries, opts.quick);
    std::fs::write(&opts.json, format!("{json}\n"))
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", opts.json))?;
    eprintln!("wrote {}", opts.json);

    let mut table = CsvTable::new(&[
        "n",
        "m",
        "nodes",
        "density",
        "budgets",
        "cold_s",
        "warm_s",
        "speedup",
        "cold_iters",
        "warm_iters",
        "chol_reuses_warm",
    ]);
    for e in &entries {
        let budgets = e
            .budgets
            .iter()
            .map(|b| b.to_string())
            .collect::<Vec<_>>()
            .join("|");
        table.row(vec![
            e.n.to_string(),
            e.m.to_string(),
            e.nodes.to_string(),
            format!("{}", e.density),
            budgets,
            format!("{:.3}", e.cold_seconds),
            format!("{:.3}", e.warm_seconds),
            format!("{:.2}", e.speedup()),
            e.cold_iters.to_string(),
            e.warm_iters.to_string(),
            e.chol_reuses_warm.to_string(),
        ]);
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_is_well_formed() {
        let entries = vec![Entry {
            n: 96,
            m: 384,
            nodes: 2,
            density: 0.05,
            budgets: vec![24, 12, 6],
            cold_seconds: 3.0,
            warm_seconds: 1.5,
            cold_iters: 300,
            warm_iters: 150,
            gram_builds_cold: 12,
            gram_builds_warm: 4,
            chol_reuses_warm: 8,
        }];
        let j = report_json(&entries, true);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_usize(), Some(1));
        assert_eq!(parsed.get("quick").unwrap().as_bool(), Some(true));
        let arr = parsed.get("entries").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        let e = &arr[0];
        assert_eq!(e.get("speedup").unwrap().as_f64(), Some(2.0));
        assert_eq!(e.get("budgets").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(e.get("warm_iters").unwrap().as_usize(), Some(150));
    }

    #[test]
    fn speedup_handles_zero_denominator() {
        let e = Entry {
            n: 1,
            m: 1,
            nodes: 1,
            density: 1.0,
            budgets: vec![1],
            cold_seconds: 1.0,
            warm_seconds: 0.0,
            cold_iters: 1,
            warm_iters: 1,
            gram_builds_cold: 0,
            gram_builds_warm: 0,
            chol_reuses_warm: 0,
        };
        assert_eq!(e.speedup(), 0.0);
    }
}

//! Figures 2 & 3 — scalability of Bi-cADMM across features (fig2) and
//! per-node samples (fig3), for N in {2, 4, 8} nodes, on both backends.
//!
//! Expected shape: the XLA ("GPU") backend stays flatter than the native
//! ("CPU") backend as the swept dimension grows, on both sweeps — the
//! paper's Figures 2 and 3.

use crate::config::{BackendKind, Config};
use crate::data::SyntheticSpec;
use crate::metrics::CsvTable;

/// Options shared by the Figure-2/Figure-3 scaling harnesses.
pub struct ScalingOpts {
    /// Paper-size grid instead of the scaled default.
    pub full: bool,
    /// Outer iterations to time (fixed horizon for comparability).
    pub iters: usize,
    /// Optional CSV output path.
    pub out: Option<String>,
}

impl Default for ScalingOpts {
    fn default() -> Self {
        ScalingOpts {
            full: false,
            iters: 10,
            out: None,
        }
    }
}

fn run_point(
    n: usize,
    m_per_node: usize,
    nodes: usize,
    backend: BackendKind,
    iters: usize,
) -> anyhow::Result<(f64, f64, crate::metrics::TransferLedger)> {
    let mut spec = SyntheticSpec::regression(n, m_per_node * nodes, nodes);
    spec.sparsity_level = 0.8;
    let ds = spec.generate();
    let mut cfg = Config::default();
    cfg.platform.nodes = nodes;
    cfg.platform.backend = backend;
    cfg.platform.devices_per_node = 2;
    cfg.solver.kappa = spec.kappa();
    cfg.solver.rho_c = 2.0;
    cfg.solver.rho_b = 1.0;
    cfg.solver.rho_l = 2.0;
    cfg.solver.max_iters = iters;
    cfg.solver.tol_primal = 0.0; // fixed horizon
    cfg.solver.polish = false;
    let run = super::run_timed(&ds, &cfg, true)?;
    Ok((
        run.solve_seconds,
        run.setup_seconds,
        run.result.transfers,
    ))
}

/// Figure 2: fixed m_i = 800 rows per node, sweep the feature count.
pub fn fig2(opts: &ScalingOpts) -> anyhow::Result<CsvTable> {
    let (ns, m_per_node) = if opts.full {
        (vec![1000, 2000, 4000, 6000, 8000, 10_000], 800)
    } else {
        (vec![256, 512, 1024, 2048], 400)
    };
    sweep("features", &ns, |n| (n, m_per_node), opts)
}

/// Figure 3: fixed n = 4000 features, sweep per-node samples.
pub fn fig3(opts: &ScalingOpts) -> anyhow::Result<CsvTable> {
    let (ms, n) = if opts.full {
        (
            vec![25_000, 50_000, 100_000, 200_000, 300_000],
            4000,
        )
    } else {
        (vec![2_000, 4_000, 8_000, 16_000], 512)
    };
    sweep("samples_per_node", &ms, |m| (n, m), opts)
}

fn sweep(
    sweep_name: &str,
    points: &[usize],
    shape: impl Fn(usize) -> (usize, usize),
    opts: &ScalingOpts,
) -> anyhow::Result<CsvTable> {
    let mut table = CsvTable::new(&[
        sweep_name,
        "nodes",
        "backend",
        "solve_s",
        "setup_s",
        "transfer_s",
        "h2d_mb",
        "d2h_mb",
    ]);
    for &nodes in &[2usize, 4, 8] {
        for backend in [BackendKind::Native, BackendKind::Xla] {
            for &p in points {
                let (n, m) = shape(p);
                eprintln!(
                    "{sweep_name}: N={nodes} backend={} point={p} (n={n}, m/node={m})",
                    backend.name()
                );
                let (solve_s, setup_s, ledger) =
                    run_point(n, m, nodes, backend, opts.iters)?;
                table.row(vec![
                    p.to_string(),
                    nodes.to_string(),
                    backend.name().to_string(),
                    format!("{solve_s:.3}"),
                    format!("{setup_s:.3}"),
                    format!("{:.4}", ledger.copy_seconds),
                    format!("{:.1}", ledger.h2d_bytes as f64 / 1e6),
                    format!("{:.1}", ledger.d2h_bytes as f64 / 1e6),
                ]);
            }
        }
    }
    Ok(table)
}

//! `psfit bench --solver` — the end-to-end solver benchmark: whole
//! Bi-cADMM solves timed as ADMM rounds/sec (fixed-round runs, scalar vs
//! SIMD) plus time-to-tolerance runs that also *verify* the cross-ISA
//! contract (identical final supports, objectives within 1e-5).
//!
//! Writes `BENCH_solver.json` (repo root by convention; schema-validated
//! by the CI smoke step), starting the repo's *end-to-end* perf
//! trajectory — the kernel microbenchmarks say how fast a matvec is,
//! this file says how fast the solver actually got.
//!
//! Two entry kinds per problem shape:
//!
//!   * `solver_rounds` — `max_iters` forced rounds under the scalar and
//!     the widest-supported ISA; reports rounds/sec for both and the
//!     speedup (the honest end-to-end win of the SIMD backend: consensus
//!     updates, projections, and transport dilute the kernel speedup).
//!   * `time_to_tol`  — default tolerances under both ISAs; reports wall
//!     seconds, iterations, convergence, whether the recovered supports
//!     match exactly, and the relative objective gap.
//!
//! The benchmark flips the process-wide ISA with `simd::select` between
//! runs (single-threaded A/B timing, exactly what that knob is for) and
//! restores the previously active ISA on exit.

use crate::admm::solver as admm_solver;
use crate::config::Config;
use crate::data::{shardfile, SyntheticSpec};
use crate::linalg::simd::{self, Isa, IsaChoice};
use crate::losses::make_loss;
use crate::metrics::CsvTable;
use crate::util::json::Json;

/// Options of the `psfit bench --solver` harness.
pub struct SolverBenchOpts {
    /// Small shapes + short runs (CI smoke).
    pub quick: bool,
    /// Where to write the JSON report.
    pub json: String,
    /// Optional CSV path (same convention as the figure harnesses).
    pub out: Option<String>,
}

struct RoundsEntry {
    n: usize,
    m: usize,
    nodes: usize,
    density: f64,
    rounds: usize,
    scalar_rounds_per_sec: f64,
    simd_rounds_per_sec: f64,
    scalar_wall_seconds: f64,
    simd_wall_seconds: f64,
}

struct TolEntry {
    n: usize,
    m: usize,
    nodes: usize,
    density: f64,
    scalar_wall_seconds: f64,
    simd_wall_seconds: f64,
    scalar_iters: usize,
    simd_iters: usize,
    converged: bool,
    support_match: bool,
    objective_rel_diff: f64,
}

struct OocoreEntry {
    n: usize,
    m: usize,
    nodes: usize,
    density: f64,
    rounds: usize,
    /// What the dense working set would occupy resident (m * n * 4).
    logical_dense_bytes: u64,
    /// What the mapped PSD1 files actually occupy on disk.
    shard_file_bytes: u64,
    resident_wall_seconds: f64,
    mapped_wall_seconds: f64,
    support_match: bool,
    bit_identical: bool,
}

fn ratio(a: f64, b: f64) -> f64 {
    if b > 0.0 {
        a / b
    } else {
        0.0
    }
}

fn report_json(
    rounds: &[RoundsEntry],
    tol: &[TolEntry],
    oocore: &[OocoreEntry],
    quick: bool,
    isa: Isa,
) -> Json {
    let mut entries: Vec<Json> = Vec::new();
    for e in rounds {
        entries.push(Json::obj(vec![
            ("name", Json::Str("solver_rounds".to_string())),
            ("n", Json::Num(e.n as f64)),
            ("m", Json::Num(e.m as f64)),
            ("nodes", Json::Num(e.nodes as f64)),
            ("density", Json::Num(e.density)),
            ("rounds", Json::Num(e.rounds as f64)),
            ("scalar_rounds_per_sec", Json::Num(e.scalar_rounds_per_sec)),
            ("simd_rounds_per_sec", Json::Num(e.simd_rounds_per_sec)),
            ("scalar_wall_seconds", Json::Num(e.scalar_wall_seconds)),
            ("simd_wall_seconds", Json::Num(e.simd_wall_seconds)),
            (
                "speedup",
                Json::Num(ratio(e.simd_rounds_per_sec, e.scalar_rounds_per_sec)),
            ),
        ]));
    }
    for e in tol {
        entries.push(Json::obj(vec![
            ("name", Json::Str("time_to_tol".to_string())),
            ("n", Json::Num(e.n as f64)),
            ("m", Json::Num(e.m as f64)),
            ("nodes", Json::Num(e.nodes as f64)),
            ("density", Json::Num(e.density)),
            ("scalar_wall_seconds", Json::Num(e.scalar_wall_seconds)),
            ("simd_wall_seconds", Json::Num(e.simd_wall_seconds)),
            ("scalar_iters", Json::Num(e.scalar_iters as f64)),
            ("simd_iters", Json::Num(e.simd_iters as f64)),
            ("converged", Json::Bool(e.converged)),
            ("support_match", Json::Bool(e.support_match)),
            ("objective_rel_diff", Json::Num(e.objective_rel_diff)),
            (
                "speedup",
                Json::Num(ratio(e.scalar_wall_seconds, e.simd_wall_seconds)),
            ),
        ]));
    }
    for e in oocore {
        entries.push(Json::obj(vec![
            ("name", Json::Str("oocore_workingset".to_string())),
            ("n", Json::Num(e.n as f64)),
            ("m", Json::Num(e.m as f64)),
            ("nodes", Json::Num(e.nodes as f64)),
            ("density", Json::Num(e.density)),
            ("rounds", Json::Num(e.rounds as f64)),
            ("logical_dense_bytes", Json::Num(e.logical_dense_bytes as f64)),
            ("shard_file_bytes", Json::Num(e.shard_file_bytes as f64)),
            ("resident_wall_seconds", Json::Num(e.resident_wall_seconds)),
            ("mapped_wall_seconds", Json::Num(e.mapped_wall_seconds)),
            ("support_match", Json::Bool(e.support_match)),
            ("bit_identical", Json::Bool(e.bit_identical)),
            (
                "mapped_overhead",
                Json::Num(ratio(e.mapped_wall_seconds, e.resident_wall_seconds)),
            ),
        ]));
    }
    Json::obj(vec![
        ("schema", Json::Num(1.0)),
        ("generated_by", Json::Str("psfit bench --solver".to_string())),
        ("quick", Json::Bool(quick)),
        ("isa", Json::Str(isa.name().to_string())),
        ("entries", Json::Arr(entries)),
    ])
}

/// Run the end-to-end solver benchmark and write `BENCH_solver.json`.
pub fn solver_bench(opts: &SolverBenchOpts) -> anyhow::Result<CsvTable> {
    let prev = simd::active();
    let result = run(opts);
    // restore whatever was active before the A/B flipping
    let _ = simd::select(IsaChoice::Force(prev));
    result
}

fn run(opts: &SolverBenchOpts) -> anyhow::Result<CsvTable> {
    // honor the pinned selection (`--isa` / `PSFIT_ISA`): the "simd" arm
    // is whatever the process selected at startup, so pinning scalar
    // really does time scalar against scalar (speedup ~1.0)
    let wide = simd::active();
    if wide == Isa::Scalar {
        eprintln!("# scalar isa selected/available: both sides time the scalar kernels");
    }

    // (n, m, nodes, density, forced rounds) for the rounds/sec entries
    let rounds_shapes: &[(usize, usize, usize, f64, usize)] = if opts.quick {
        &[(96, 768, 2, 1.0, 8)]
    } else {
        &[
            (512, 4096, 4, 1.0, 30),
            (512, 4096, 4, 0.05, 30),
            (1024, 8192, 4, 1.0, 12),
        ]
    };
    // (n, m, nodes) for the time-to-tolerance entries — the first shape
    // mirrors the solver test pinned to converge under default
    // tolerances in 400 iterations
    let tol_shapes: &[(usize, usize, usize)] = if opts.quick {
        &[(30, 240, 3)]
    } else {
        &[(30, 240, 3), (96, 1600, 4)]
    };

    let mut rounds_entries = Vec::new();
    for &(n, m, nodes, density, rounds) in rounds_shapes {
        eprintln!("# solver rounds/sec: n={n} m={m} nodes={nodes} density={density}");
        let mut spec = SyntheticSpec::regression(n, m, nodes);
        spec.density = density;
        let ds = spec.generate();
        let mut cfg = Config::default();
        cfg.platform.nodes = nodes;
        cfg.solver.kappa = spec.kappa();
        cfg.solver.max_iters = rounds;
        cfg.solver.tol_primal = 0.0; // force every round: fixed work per ISA

        let mut walls = [0.0f64; 2];
        for (slot, isa) in [Isa::Scalar, wide].into_iter().enumerate() {
            simd::select(IsaChoice::Force(isa))?;
            let run = super::run_timed(&ds, &cfg, true)?;
            anyhow::ensure!(run.result.iters == rounds, "fixed-round run terminated early");
            walls[slot] = run.solve_seconds;
        }
        rounds_entries.push(RoundsEntry {
            n,
            m,
            nodes,
            density,
            rounds,
            scalar_rounds_per_sec: ratio(rounds as f64, walls[0]),
            simd_rounds_per_sec: ratio(rounds as f64, walls[1]),
            scalar_wall_seconds: walls[0],
            simd_wall_seconds: walls[1],
        });
    }

    let mut tol_entries = Vec::new();
    for &(n, m, nodes) in tol_shapes {
        eprintln!("# solver time-to-tolerance: n={n} m={m} nodes={nodes}");
        let mut spec = SyntheticSpec::regression(n, m, nodes);
        spec.sparsity_level = 0.9;
        let ds = spec.generate();
        let mut cfg = Config::default();
        cfg.platform.nodes = nodes;
        cfg.solver.kappa = spec.kappa();
        cfg.solver.max_iters = 400;

        let loss = make_loss(cfg.loss, ds.width);
        let mut results = Vec::new();
        for isa in [Isa::Scalar, wide] {
            simd::select(IsaChoice::Force(isa))?;
            let run = super::run_timed(&ds, &cfg, true)?;
            let objective =
                admm_solver::objective(&ds, loss.as_ref(), cfg.solver.gamma, &run.result.x);
            results.push((run, objective));
        }
        let (scalar_run, scalar_obj) = &results[0];
        let (simd_run, simd_obj) = &results[1];
        let rel = (scalar_obj - simd_obj).abs() / scalar_obj.abs().max(1.0);
        tol_entries.push(TolEntry {
            n,
            m,
            nodes,
            density: 1.0,
            scalar_wall_seconds: scalar_run.solve_seconds,
            simd_wall_seconds: simd_run.solve_seconds,
            scalar_iters: scalar_run.result.iters,
            simd_iters: simd_run.result.iters,
            converged: scalar_run.result.converged && simd_run.result.converged,
            support_match: scalar_run.result.support == simd_run.result.support,
            objective_rel_diff: rel,
        });
    }

    // ---- out-of-core working set: mapped PSD1 shards vs resident --------
    // A sparse problem whose *logical dense* footprint dwarfs its CSR
    // file: the shape CI runs under an address-space cap that the dense
    // working set could never fit (see .github/workflows).  Pins that a
    // mapped fit is bit-identical to the resident fit and reports the
    // mmap overhead.
    let oocore_shapes: &[(usize, usize, usize, f64, usize)] = if opts.quick {
        &[(64, 512, 2, 0.02, 6)]
    } else {
        &[(512, 16384, 4, 0.01, 10)]
    };
    let mut oocore_entries = Vec::new();
    for &(n, m, nodes, density, rounds) in oocore_shapes {
        eprintln!("# oocore working set: n={n} m={m} nodes={nodes} density={density}");
        let mut spec = SyntheticSpec::regression(n, m, nodes);
        spec.density = density;
        let ds = spec.generate();
        let mut cfg = Config::default();
        cfg.platform.nodes = nodes;
        cfg.solver.kappa = spec.kappa();
        cfg.solver.max_iters = rounds;
        cfg.solver.tol_primal = 0.0; // fixed work on both sides

        // one PSD1 file per shard under the fit-time storage policy, so
        // the sparse shape maps as CSR — O(nnz) on disk and in the map
        let base = std::env::temp_dir().join(format!("psfit_bench_oocore_{n}x{m}"));
        let mut paths = Vec::new();
        let mut file_bytes = 0u64;
        for (i, shard) in ds.shards.iter().enumerate() {
            let p = shardfile::shard_path(&base, i);
            let stored = shard
                .with_storage_policy(cfg.platform.sparse, cfg.platform.sparse_threshold);
            shardfile::write_shard(&stored, &p)?;
            file_bytes += std::fs::metadata(&p)?.len();
            paths.push(p);
        }
        let mapped_ds = shardfile::open_dataset(&paths)?;

        let resident = super::run_timed(&ds, &cfg, true)?;
        let mapped = super::run_timed(&mapped_ds, &cfg, true)?;
        for p in &paths {
            let _ = std::fs::remove_file(p);
        }
        anyhow::ensure!(
            resident.result.iters == rounds && mapped.result.iters == rounds,
            "fixed-round oocore run terminated early"
        );
        let bit_identical = resident.result.z.len() == mapped.result.z.len()
            && resident
                .result
                .z
                .iter()
                .zip(&mapped.result.z)
                .all(|(a, b)| a.to_bits() == b.to_bits());
        oocore_entries.push(OocoreEntry {
            n,
            m,
            nodes,
            density,
            rounds,
            logical_dense_bytes: (m as u64) * (n as u64) * 4,
            shard_file_bytes: file_bytes,
            resident_wall_seconds: resident.solve_seconds,
            mapped_wall_seconds: mapped.solve_seconds,
            support_match: resident.result.support == mapped.result.support,
            bit_identical,
        });
    }

    // ---- emit ------------------------------------------------------------
    let json = report_json(&rounds_entries, &tol_entries, &oocore_entries, opts.quick, wide);
    std::fs::write(&opts.json, format!("{json}\n"))
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", opts.json))?;
    eprintln!("wrote {}", opts.json);

    let mut table = CsvTable::new(&[
        "entry", "n", "m", "nodes", "density", "scalar", "simd", "speedup", "note",
    ]);
    for e in &rounds_entries {
        table.row(vec![
            "solver_rounds".to_string(),
            e.n.to_string(),
            e.m.to_string(),
            e.nodes.to_string(),
            format!("{}", e.density),
            format!("{:.1} rounds/s", e.scalar_rounds_per_sec),
            format!("{:.1} rounds/s", e.simd_rounds_per_sec),
            format!("{:.2}", ratio(e.simd_rounds_per_sec, e.scalar_rounds_per_sec)),
            format!("{} rounds", e.rounds),
        ]);
    }
    for e in &tol_entries {
        table.row(vec![
            "time_to_tol".to_string(),
            e.n.to_string(),
            e.m.to_string(),
            e.nodes.to_string(),
            format!("{}", e.density),
            format!("{:.3} s / {} it", e.scalar_wall_seconds, e.scalar_iters),
            format!("{:.3} s / {} it", e.simd_wall_seconds, e.simd_iters),
            format!("{:.2}", ratio(e.scalar_wall_seconds, e.simd_wall_seconds)),
            format!(
                "converged={} support_match={} obj_rel={:.1e}",
                e.converged, e.support_match, e.objective_rel_diff
            ),
        ]);
    }
    for e in &oocore_entries {
        table.row(vec![
            "oocore_workingset".to_string(),
            e.n.to_string(),
            e.m.to_string(),
            e.nodes.to_string(),
            format!("{}", e.density),
            format!("{:.3} s resident", e.resident_wall_seconds),
            format!("{:.3} s mapped", e.mapped_wall_seconds),
            format!("{:.2}", ratio(e.mapped_wall_seconds, e.resident_wall_seconds)),
            format!(
                "bit_identical={} dense={:.1}MB file={:.1}MB",
                e.bit_identical,
                e.logical_dense_bytes as f64 / 1e6,
                e.shard_file_bytes as f64 / 1e6
            ),
        ]);
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_is_well_formed() {
        let rounds = vec![RoundsEntry {
            n: 96,
            m: 768,
            nodes: 2,
            density: 1.0,
            rounds: 8,
            scalar_rounds_per_sec: 100.0,
            simd_rounds_per_sec: 250.0,
            scalar_wall_seconds: 0.08,
            simd_wall_seconds: 0.032,
        }];
        let tol = vec![TolEntry {
            n: 40,
            m: 400,
            nodes: 2,
            density: 1.0,
            scalar_wall_seconds: 0.5,
            simd_wall_seconds: 0.25,
            scalar_iters: 120,
            simd_iters: 121,
            converged: true,
            support_match: true,
            objective_rel_diff: 3e-7,
        }];
        let oocore = vec![OocoreEntry {
            n: 64,
            m: 512,
            nodes: 2,
            density: 0.02,
            rounds: 6,
            logical_dense_bytes: 64 * 512 * 4,
            shard_file_bytes: 9000,
            resident_wall_seconds: 0.1,
            mapped_wall_seconds: 0.12,
            support_match: true,
            bit_identical: true,
        }];
        let parsed =
            Json::parse(&report_json(&rounds, &tol, &oocore, true, Isa::Avx2).to_string())
                .unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_usize(), Some(1));
        assert_eq!(parsed.get("isa").unwrap().as_str(), Some("avx2"));
        let arr = parsed.get("entries").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].get("name").unwrap().as_str(), Some("solver_rounds"));
        assert_eq!(arr[0].get("speedup").unwrap().as_f64(), Some(2.5));
        assert_eq!(arr[1].get("name").unwrap().as_str(), Some("time_to_tol"));
        assert_eq!(arr[1].get("support_match").unwrap().as_bool(), Some(true));
        assert_eq!(arr[1].get("speedup").unwrap().as_f64(), Some(2.0));
        assert_eq!(arr[2].get("name").unwrap().as_str(), Some("oocore_workingset"));
        assert_eq!(arr[2].get("bit_identical").unwrap().as_bool(), Some(true));
        assert_eq!(
            arr[2].get("logical_dense_bytes").unwrap().as_usize(),
            Some(64 * 512 * 4)
        );
        assert!(
            (arr[2].get("mapped_overhead").unwrap().as_f64().unwrap() - 1.2).abs() < 1e-9
        );
    }

    #[test]
    fn ratio_handles_zero_denominator() {
        assert_eq!(ratio(1.0, 0.0), 0.0);
        assert_eq!(ratio(6.0, 3.0), 2.0);
    }
}

//! Straggler-scaling scenario: wall-clock of sync (full-barrier) vs async
//! (partial-barrier) coordination as one node is slowed 1x-16x.
//!
//! Both modes run on `coordinator::AsyncCluster` with the same seeded
//! fault model, so the *only* difference is the coordination policy:
//! `sync` is quorum = 1.0 / staleness = 0 (which reproduces the
//! full-barrier clusters bit-for-bit), `async` is the configured partial
//! barrier.  Expected shape: sync wall-clock grows linearly with the
//! slowdown factor (the straggler gates every round); async stays nearly
//! flat, paying instead with bounded-stale folds and occasional resyncs —
//! all of which the emitted table reports.

use crate::admm::{self, SolveOptions};
use crate::config::{Config, CoordinationKind};
use crate::coordinator::FaultSpec;
use crate::data::SyntheticSpec;
use crate::driver;
use crate::metrics::{CoordinationStats, CsvTable};

/// Options of the straggler harness.
pub struct StragglerOpts {
    /// Larger factor sweep.
    pub full: bool,
    /// Cluster size; node 0 is the straggler.
    pub nodes: usize,
    /// Outer rounds (fixed horizon so wall-clock is comparable).
    pub iters: usize,
    /// Per-round delay unit: the slow node sleeps `base_ms * (factor - 1)`.
    pub base_ms: f64,
    /// Async-mode quorum fraction.
    pub quorum: f64,
    /// Async-mode staleness bound (rounds).
    pub max_staleness: usize,
    /// Optional CSV output path.
    pub out: Option<String>,
}

impl Default for StragglerOpts {
    fn default() -> Self {
        StragglerOpts {
            full: false,
            nodes: 3,
            iters: 12,
            base_ms: 3.0,
            quorum: 0.5,
            max_staleness: 2,
            out: None,
        }
    }
}

/// One (factor, mode) measurement.
pub struct StragglerPoint {
    /// Wall-clock of the fixed-horizon fit.
    pub wall_seconds: f64,
    /// Primal residual at the horizon.
    pub final_primal: f64,
    /// Coordination accounting of the run.
    pub stats: CoordinationStats,
}

/// Run one fixed-horizon fit under the given coordination policy with
/// node 0 slowed by `factor`.
pub fn run_point(
    opts: &StragglerOpts,
    factor: usize,
    quorum: f64,
    max_staleness: usize,
) -> anyhow::Result<StragglerPoint> {
    let (n, m_per_node) = if opts.full { (256, 800) } else { (48, 160) };
    let mut spec = SyntheticSpec::regression(n, m_per_node * opts.nodes, opts.nodes);
    spec.sparsity_level = 0.8;
    let ds = spec.generate();

    let mut cfg = Config::default();
    cfg.platform.nodes = opts.nodes;
    cfg.solver.kappa = spec.kappa();
    cfg.solver.max_iters = opts.iters;
    cfg.solver.tol_primal = 0.0; // fixed horizon
    cfg.solver.polish = false;
    cfg.coordinator.coordination = CoordinationKind::Async;
    cfg.coordinator.quorum = quorum;
    cfg.coordinator.max_staleness = max_staleness;
    cfg.coordinator.heartbeat_ms = 10;
    cfg.coordinator.faults =
        FaultSpec::default().straggler(0, opts.base_ms * (factor.saturating_sub(1)) as f64);

    let workers = driver::build_workers(&ds, &cfg)?;
    let dim = ds.n_features * ds.width;
    let mut cluster = driver::build_cluster(workers, dim, &cfg, false)?;
    let res = admm::solve(cluster.as_mut(), dim, &cfg, Some(&ds), &SolveOptions::default())?;
    Ok(StragglerPoint {
        wall_seconds: res.wall_seconds,
        final_primal: res.trace.last().map(|r| r.primal).unwrap_or(f64::NAN),
        stats: res.coordination.unwrap_or_default(),
    })
}

/// The full sweep: factors 1x-16x, sync vs async, one row per point.
pub fn straggler(opts: &StragglerOpts) -> anyhow::Result<CsvTable> {
    let factors = [1usize, 2, 4, 8, 16];
    let mut table = CsvTable::new(&[
        "slow_factor",
        "mode",
        "wall_s",
        "final_primal",
        "stale_folds",
        "drops",
        "resyncs",
        "straggler_folds",
    ]);
    for &factor in &factors {
        for (mode, quorum, staleness) in [
            ("sync", 1.0, 0usize),
            ("async", opts.quorum, opts.max_staleness),
        ] {
            eprintln!(
                "straggler: factor={factor} mode={mode} (N={}, {} rounds)",
                opts.nodes, opts.iters
            );
            let p = run_point(opts, factor, quorum, staleness)?;
            let stale_folds: u64 = p.stats.staleness_hist.iter().skip(1).sum();
            table.row(vec![
                factor.to_string(),
                mode.to_string(),
                format!("{:.4}", p.wall_seconds),
                format!("{:.3e}", p.final_primal),
                stale_folds.to_string(),
                p.stats.drops.to_string(),
                p.stats.resyncs.to_string(),
                p.stats
                    .participation
                    .first()
                    .copied()
                    .unwrap_or(0)
                    .to_string(),
            ]);
        }
    }
    Ok(table)
}

//! Table 1 — solution-time comparison: Bi-cADMM vs the exact MIP
//! (branch-and-bound, the Gurobi stand-in) vs Lasso, over
//! s_l in {0.6, 0.9} x m x n, N = 4 nodes.
//!
//! Expected shape (the paper's finding): Bi-cADMM seconds-scale and flat in
//! the grid; the MIP orders of magnitude slower / cut off at larger sizes;
//! Lasso in between, with asterisks where the l1 path fails to recover the
//! planted support.

use crate::baselines::{best_subset_bnb, lasso_path, BnbStatus};
use crate::config::{BackendKind, Config};
use crate::data::SyntheticSpec;
use crate::metrics::CsvTable;
use crate::sparsity::support_f1;
use crate::util::Stopwatch;

/// Options of the Table-1 harness.
pub struct Table1Opts {
    /// Paper-size grid instead of the scaled default.
    pub full: bool,
    /// Backend the Bi-cADMM column runs on.
    pub backend: BackendKind,
    /// BnB time budget in seconds (paper: 1800).
    pub mip_budget: f64,
    /// Optional CSV output path.
    pub out: Option<String>,
}

impl Default for Table1Opts {
    fn default() -> Self {
        Table1Opts {
            full: false,
            backend: BackendKind::Xla,
            mip_budget: 60.0,
            out: None,
        }
    }
}

/// Regenerate Table 1 (Bi-cADMM vs MIP vs Lasso).
pub fn table1(opts: &Table1Opts) -> anyhow::Result<CsvTable> {
    // paper grid: m in {1e5, 2e5, 3e5}, n in {2000, 4000}
    let (ms, ns, mip_budget) = if opts.full {
        (vec![100_000, 200_000, 300_000], vec![2000, 4000], 1800.0)
    } else {
        (vec![4_000, 8_000, 12_000], vec![128, 256], opts.mip_budget)
    };
    let sls = [0.6, 0.9];
    let nodes = 4;

    let mut table = CsvTable::new(&[
        "s_l",
        "m",
        "n",
        "bicadmm_s",
        "bicadmm_f1",
        "mip_s",
        "mip_status",
        "lasso_s",
        "lasso_recovered",
    ]);

    for &sl in &sls {
        for &m in &ms {
            for &n in &ns {
                let mut spec = SyntheticSpec::regression(n, m, nodes);
                spec.sparsity_level = sl;
                // enough noise that the MIP's relaxation bounds stay loose
                // (the regime where Gurobi's blow-up shows in the paper)
                spec.noise_std = 0.25;
                let ds = spec.generate();
                let kappa = spec.kappa();
                eprintln!("table1: s_l={sl} m={m} n={n} kappa={kappa}");

                // ---- Bi-cADMM (distributed, N=4) -----------------------
                let mut cfg = Config::default();
                cfg.platform.nodes = nodes;
                cfg.platform.backend = opts.backend;
                cfg.solver.kappa = kappa;
                cfg.solver.rho_c = 2.0;
                cfg.solver.rho_b = 1.0; // alpha = 0.5
                cfg.solver.rho_l = 2.0;
                cfg.solver.max_iters = 150;
                cfg.solver.polish = false;
                let run = super::run_timed(&ds, &cfg, true)?;
                let f1 = support_f1(&run.result.support, &ds.support_true);

                // ---- exact MIP by branch-and-bound ----------------------
                let (a, b) = ds.stacked();
                let mip = best_subset_bnb(&a, &b, kappa, cfg.solver.gamma, mip_budget);
                let mip_status = match mip.status {
                    BnbStatus::Optimal => "optimal".to_string(),
                    BnbStatus::CutOff => "cut off".to_string(),
                };

                // ---- Lasso path ----------------------------------------
                let watch = Stopwatch::start();
                let lasso = lasso_path(&a, &b, kappa, 50, 300);
                let lasso_s = watch.elapsed_secs();
                // "recovered" means: the kappa largest-|.| lasso coefficients
                // sit exactly on the true support (the paper's criterion for
                // dropping the asterisk)
                let lasso_top: Vec<usize> = {
                    let mut idx = crate::sparsity::top_k_indices(&lasso.x, kappa);
                    idx.sort_unstable();
                    idx
                };
                let recovered = lasso_top == ds.support_true;

                table.row(vec![
                    format!("{sl}"),
                    m.to_string(),
                    n.to_string(),
                    format!("{:.2}", run.solve_seconds),
                    format!("{:.3}", f1),
                    format!("{:.1}", mip.wall_seconds),
                    mip_status,
                    format!("{:.2}{}", lasso_s, if recovered { "" } else { "*" }),
                    recovered.to_string(),
                ]);
            }
        }
    }
    Ok(table)
}

//! `psfit bench --transport` — round latency and wire volume of the
//! transports: the in-process sequential and threaded clusters against a
//! localhost socket fleet.
//!
//! Every transport runs the *same* fixed-round solve on the same seed, so
//! besides timing this doubles as a parity check (the socket run must
//! recover the sequential baseline's support exactly).  Reported per
//! entry: round latency, rounds/sec, and bytes per round in both
//! directions.  For the in-process transports the bytes are the modeled
//! protocol volume (z down, x+u up); for the socket transport they are
//! the frames actually written to the wire, so the gap between the two is
//! the real framing + setup overhead of going multi-process.
//!
//! Entries merge into the existing `BENCH_solver.json` under the name
//! `transport_round`, preserving whatever `--solver` wrote there.

use std::collections::BTreeMap;

use crate::config::{Config, TransportKind};
use crate::data::SyntheticSpec;
use crate::metrics::CsvTable;
use crate::network::socket::worker::spawn_local_worker;
use crate::util::json::Json;

/// Options of the `psfit bench --transport` harness.
pub struct TransportBenchOpts {
    /// Small shape + short runs (CI smoke).
    pub quick: bool,
    /// JSON report path (merged into, not overwritten).
    pub json: String,
    /// Optional CSV path.
    pub out: Option<String>,
}

struct TransportEntry {
    transport: &'static str,
    n: usize,
    m: usize,
    nodes: usize,
    rounds: usize,
    wall_seconds: f64,
    net_down_bytes: u64,
    net_up_bytes: u64,
    wire_frames: u64,
    support_match: bool,
}

fn per_round(total: u64, rounds: usize) -> f64 {
    if rounds > 0 {
        total as f64 / rounds as f64
    } else {
        0.0
    }
}

/// Run the transport benchmark and merge its entries into the report.
pub fn transport_bench(opts: &TransportBenchOpts) -> anyhow::Result<CsvTable> {
    let shapes: &[(usize, usize, usize, usize)] = if opts.quick {
        &[(64, 512, 3, 6)]
    } else {
        &[(256, 2048, 3, 20), (512, 4096, 4, 12)]
    };

    let mut entries = Vec::new();
    for &(n, m, nodes, rounds) in shapes {
        let spec = SyntheticSpec::regression(n, m, nodes);
        let ds = spec.generate();
        let mut cfg = Config::default();
        cfg.platform.nodes = nodes;
        cfg.solver.kappa = spec.kappa();
        cfg.solver.max_iters = rounds;
        cfg.solver.tol_primal = 0.0; // force every round: fixed work per transport

        let mut baseline_support: Option<Vec<usize>> = None;
        for transport in ["sequential", "threaded", "socket"] {
            eprintln!("# transport rounds: {transport} n={n} m={m} nodes={nodes}");
            let mut run_cfg = cfg.clone();
            let threaded = match transport {
                "sequential" => false,
                "threaded" => true,
                _ => {
                    run_cfg.platform.transport = TransportKind::Socket;
                    run_cfg.platform.workers = (0..nodes)
                        .map(|_| spawn_local_worker())
                        .collect::<anyhow::Result<_>>()?;
                    true
                }
            };
            let run = super::run_timed(&ds, &run_cfg, threaded)?;
            anyhow::ensure!(
                run.result.iters == rounds,
                "fixed-round run terminated early on {transport}"
            );
            let support_match = match &baseline_support {
                None => {
                    baseline_support = Some(run.result.support.clone());
                    true
                }
                Some(base) => *base == run.result.support,
            };
            entries.push(TransportEntry {
                transport,
                n,
                m,
                nodes,
                rounds,
                wall_seconds: run.solve_seconds,
                net_down_bytes: run.result.transfers.net_down_bytes,
                net_up_bytes: run.result.transfers.net_up_bytes,
                wire_frames: run.result.transfers.wire_frames,
                support_match,
            });
        }
    }

    let json = merge_report(&opts.json, &entries, opts.quick);
    std::fs::write(&opts.json, format!("{json}\n"))
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", opts.json))?;
    eprintln!("wrote {}", opts.json);

    let mut table = CsvTable::new(&[
        "entry",
        "transport",
        "n",
        "m",
        "nodes",
        "round_ms",
        "down B/round",
        "up B/round",
        "frames",
        "note",
    ]);
    for e in &entries {
        table.row(vec![
            "transport_round".to_string(),
            e.transport.to_string(),
            e.n.to_string(),
            e.m.to_string(),
            e.nodes.to_string(),
            format!("{:.3}", 1000.0 * e.wall_seconds / e.rounds as f64),
            format!("{:.0}", per_round(e.net_down_bytes, e.rounds)),
            format!("{:.0}", per_round(e.net_up_bytes, e.rounds)),
            e.wire_frames.to_string(),
            format!("{} rounds, support_match={}", e.rounds, e.support_match),
        ]);
    }
    if let Some(path) = &opts.out {
        table.write_file(std::path::Path::new(path))?;
        eprintln!("wrote {path}");
    }
    Ok(table)
}

/// Fold `transport_round` entries into the report at `path`: existing
/// entries of every *other* kind survive untouched, previous
/// `transport_round` entries are replaced.  A missing or unparseable
/// report starts fresh.
fn merge_report(path: &str, entries: &[TransportEntry], quick: bool) -> Json {
    let mut report = match std::fs::read_to_string(path)
        .ok()
        .and_then(|t| Json::parse(&t).ok())
    {
        Some(Json::Obj(map)) => map,
        _ => {
            let mut map = BTreeMap::new();
            map.insert("schema".to_string(), Json::Num(1.0));
            map.insert("quick".to_string(), Json::Bool(quick));
            map.insert(
                "generated_by".to_string(),
                Json::Str("psfit bench --transport".to_string()),
            );
            map
        }
    };
    let mut kept: Vec<Json> = match report.remove("entries") {
        Some(Json::Arr(arr)) => arr
            .into_iter()
            .filter(|e| e.get("name").and_then(Json::as_str) != Some("transport_round"))
            .collect(),
        _ => Vec::new(),
    };
    for e in entries {
        let dim_payload = 3.0 * (e.n as f64) * 8.0 * e.nodes as f64;
        kept.push(Json::obj(vec![
            ("name", Json::Str("transport_round".to_string())),
            ("transport", Json::Str(e.transport.to_string())),
            ("n", Json::Num(e.n as f64)),
            ("m", Json::Num(e.m as f64)),
            ("nodes", Json::Num(e.nodes as f64)),
            ("rounds", Json::Num(e.rounds as f64)),
            (
                "round_ms",
                Json::Num(1000.0 * e.wall_seconds / e.rounds as f64),
            ),
            (
                "rounds_per_sec",
                Json::Num(if e.wall_seconds > 0.0 {
                    e.rounds as f64 / e.wall_seconds
                } else {
                    0.0
                }),
            ),
            (
                "net_down_bytes_per_round",
                Json::Num(per_round(e.net_down_bytes, e.rounds)),
            ),
            (
                "net_up_bytes_per_round",
                Json::Num(per_round(e.net_up_bytes, e.rounds)),
            ),
            ("payload_bytes_per_round", Json::Num(dim_payload)),
            ("wire_frames", Json::Num(e.wire_frames as f64)),
            ("support_match", Json::Bool(e.support_match)),
        ]));
    }
    report.insert("entries".to_string(), Json::Arr(kept));
    Json::Obj(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(transport: &'static str) -> TransportEntry {
        TransportEntry {
            transport,
            n: 64,
            m: 512,
            nodes: 3,
            rounds: 6,
            wall_seconds: 0.06,
            net_down_bytes: 9_000,
            net_up_bytes: 18_000,
            wire_frames: 24,
            support_match: true,
        }
    }

    #[test]
    fn merge_preserves_foreign_entries_and_replaces_stale_transport_rows() {
        let dir = std::env::temp_dir().join(format!("psfit-tb-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("report.json");
        let path_str = path.to_str().unwrap();
        std::fs::write(
            &path,
            r#"{"schema": 1, "quick": true, "isa": "scalar",
               "entries": [{"name": "solver_rounds", "n": 96},
                           {"name": "transport_round", "transport": "stale"}]}"#,
        )
        .unwrap();
        let merged = merge_report(path_str, &[entry("sequential"), entry("socket")], true);
        let arr = merged.get("entries").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3, "solver entry kept, stale row replaced");
        assert_eq!(arr[0].get("name").unwrap().as_str(), Some("solver_rounds"));
        let kinds: Vec<_> = arr
            .iter()
            .filter(|e| e.get("name").and_then(Json::as_str) == Some("transport_round"))
            .map(|e| e.get("transport").unwrap().as_str().unwrap().to_string())
            .collect();
        assert_eq!(kinds, vec!["sequential", "socket"]);
        // untouched top-level keys survive the merge
        assert_eq!(merged.get("isa").unwrap().as_str(), Some("scalar"));
        // round-trips as JSON with the expected derived fields
        let parsed = Json::parse(&merged.to_string()).unwrap();
        let e = &parsed.get("entries").unwrap().as_arr().unwrap()[1];
        assert_eq!(e.get("round_ms").unwrap().as_f64(), Some(10.0));
        assert_eq!(
            e.get("payload_bytes_per_round").unwrap().as_f64(),
            Some(3.0 * 64.0 * 8.0 * 3.0)
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn merge_starts_fresh_without_a_report() {
        let merged = merge_report("/nonexistent/psfit/report.json", &[entry("threaded")], false);
        assert_eq!(merged.get("schema").unwrap().as_usize(), Some(1));
        assert_eq!(merged.get("entries").unwrap().as_arr().unwrap().len(), 1);
    }
}

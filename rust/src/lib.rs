//! PsFiT-rs — Bi-linear consensus ADMM (Bi-cADMM) for distributed sparse
//! machine learning: a Rust + JAX/Pallas (AOT via XLA/PJRT) reproduction
//! of "A GPU-Accelerated Bi-linear ADMM Algorithm for Distributed Sparse
//! Machine Learning" (Olama et al., 2024).
//!
//! Architecture (see DESIGN.md):
//!   * [`admm`]     — the Bi-cADMM algorithm (Algorithms 1 & 2)
//!   * [`backend`]  — native ("CPU") and XLA-artifact ("GPU") data paths
//!   * [`runtime`]  — PJRT loader/executor for the AOT artifacts
//!   * [`network`]  — node workers + collectives; `network::socket` is
//!     the real multi-process transport (`psfit worker`)
//!   * [`serve`]    — multi-tenant fit/predict daemon over a worker fleet
//!   * [`coordinator`] — async round scheduler with bounded staleness,
//!     elastic membership, and deterministic fault injection
//!   * [`baselines`]— Lasso, best-subset branch-and-bound (Gurobi
//!     stand-in), IHT
//!   * [`path`]     — warm-started sparsity-path sweeps with
//!     checkpoint/resume (model selection along a budget ladder)
//!   * [`driver`]   — high-level fit API used by the CLI and examples
//!
//! New here?  Start with `docs/GUIDE.md` (user guide: install,
//! quickstart, every CLI knob) and the runnable programs in `examples/`.
#![warn(missing_docs)]

/// The Bi-cADMM algorithm: coordinator updates, node-level inner ADMM,
/// and the outer consensus loop.
pub mod admm;
/// Native and XLA compute backends for the node-level data path.
pub mod backend;
/// Centralized baselines: Lasso (FISTA), best-subset branch-and-bound,
/// and IHT.
pub mod baselines;
/// Validated configuration structs + JSON config-file loading.
pub mod config;
/// Asynchronous coordination: bounded staleness, elastic membership,
/// fault injection.
pub mod coordinator;
/// Dataset substrate: synthetic generators, partitioning, persistence.
pub mod data;
/// High-level fit/“solve this dataset under this config” entry points.
pub mod driver;
/// Experiment harnesses regenerating the paper's tables and figures.
pub mod harness;
/// Dense + CSR linear-algebra kernels (dependency-free Rust).
pub mod linalg;
/// The paper's model zoo: squared, logistic, hinge, and softmax losses.
pub mod losses;
/// Transfer/byte ledgers, iteration traces, and CSV emission.
pub mod metrics;
/// Simulated distributed layer: node workers, clusters, collectives.
pub mod network;
/// Warm-started sparsity-path sweeps with checkpoint/resume.
pub mod path;
/// PJRT loader/executor for the AOT-compiled XLA artifacts.
pub mod runtime;
/// `psfit serve`: multi-tenant fit/predict daemon over a worker fleet.
pub mod serve;
/// Sparsity machinery: l1 projections, s-update, hard thresholding.
pub mod sparsity;
/// Self-contained substrates: PRNG, JSON, CLI, bench/test kits, pool.
pub mod util;

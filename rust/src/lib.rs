//! PsFiT-rs — Bi-linear consensus ADMM (Bi-cADMM) for distributed sparse
//! machine learning: a Rust + JAX/Pallas (AOT via XLA/PJRT) reproduction
//! of "A GPU-Accelerated Bi-linear ADMM Algorithm for Distributed Sparse
//! Machine Learning" (Olama et al., 2024).
//!
//! Architecture (see DESIGN.md):
//!   * [`admm`]     — the Bi-cADMM algorithm (Algorithms 1 & 2)
//!   * [`backend`]  — native ("CPU") and XLA-artifact ("GPU") data paths
//!   * [`runtime`]  — PJRT loader/executor for the AOT artifacts
//!   * [`network`]  — node workers + collectives (the MPI stand-in)
//!   * [`coordinator`] — async round scheduler with bounded staleness,
//!     elastic membership, and deterministic fault injection
//!   * [`baselines`]— Lasso, best-subset branch-and-bound (Gurobi
//!     stand-in), IHT
//!   * [`driver`]   — high-level fit API used by the CLI and examples
pub mod admm;
pub mod backend;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod driver;
pub mod harness;
pub mod linalg;
pub mod losses;
pub mod metrics;
pub mod network;
pub mod runtime;
pub mod sparsity;
pub mod util;

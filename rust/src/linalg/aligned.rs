//! 64-byte-aligned `f32` storage for the SIMD kernel backend.
//!
//! [`AlignedVec`] is a fixed-length `f32` buffer whose first element sits
//! on a 64-byte boundary (one full AVX-512 lane, two AVX2 lanes, four NEON
//! lanes, and exactly one x86 cache line).  Combined with the padded row
//! stride of [`super::Matrix`] — every row rounded up to [`LANE_F32`]
//! elements — each *row start* of a dense matrix is 64-byte aligned, so
//! vector loads in the hot kernels never straddle a cache line at the row
//! head.
//!
//! The buffer is built from `#[repr(align(64))]` chunks of a plain `Vec`,
//! so the only `unsafe` here is the two `from_raw_parts` casts exposing the
//! chunk storage as a contiguous `&[f32]` — length and provenance both come
//! straight from the owning `Vec`.  Padding elements (between the logical
//! length and the chunk capacity) are always zero-initialized and are
//! *storage only*: they are never serialized, compared, or handed to
//! callers (`as_slice` stops at the logical length).

/// f32 elements per 64-byte alignment unit.
pub const LANE_F32: usize = 16;

/// One 64-byte alignment unit.
#[repr(align(64))]
#[derive(Clone, Copy, Debug)]
struct Lane([f32; LANE_F32]);

/// Fixed-length, 64-byte-aligned `f32` buffer (see the module docs).
#[derive(Clone, Debug)]
pub struct AlignedVec {
    lanes: Vec<Lane>,
    len: usize,
}

impl AlignedVec {
    /// Zero-filled buffer of `len` elements (padding included).
    pub fn zeroed(len: usize) -> AlignedVec {
        AlignedVec {
            lanes: vec![Lane([0.0; LANE_F32]); len.div_ceil(LANE_F32)],
            len,
        }
    }

    /// Copy of `src` in aligned storage.
    pub fn from_slice(src: &[f32]) -> AlignedVec {
        let mut v = AlignedVec::zeroed(src.len());
        v.as_mut_slice().copy_from_slice(src);
        v
    }

    /// Logical element count (excludes alignment padding).
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the buffer holds no logical elements.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The logical elements as a slice (padding excluded).
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        // Safety: `lanes` owns `len.div_ceil(LANE_F32) * LANE_F32 >= len`
        // contiguous f32s; `Lane` is a plain f32 array with no interior
        // padding, so the cast preserves layout and provenance.
        unsafe { std::slice::from_raw_parts(self.lanes.as_ptr() as *const f32, self.len) }
    }

    /// The logical elements as a mutable slice (padding excluded).
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        // Safety: as in `as_slice`, with unique access via `&mut self`.
        unsafe { std::slice::from_raw_parts_mut(self.lanes.as_mut_ptr() as *mut f32, self.len) }
    }
}

impl std::ops::Deref for AlignedVec {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        self.as_slice()
    }
}

impl std::ops::DerefMut for AlignedVec {
    fn deref_mut(&mut self) -> &mut [f32] {
        self.as_mut_slice()
    }
}

impl PartialEq for AlignedVec {
    fn eq(&self, other: &AlignedVec) -> bool {
        self.as_slice() == other.as_slice()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn start_is_64_byte_aligned() {
        for len in [0usize, 1, 15, 16, 17, 1000] {
            let v = AlignedVec::zeroed(len);
            assert_eq!(v.as_slice().as_ptr() as usize % 64, 0, "len {len}");
            assert_eq!(v.len(), len);
        }
    }

    #[test]
    fn slice_roundtrip_excludes_padding() {
        let src: Vec<f32> = (0..21).map(|i| i as f32).collect();
        let v = AlignedVec::from_slice(&src);
        assert_eq!(v.as_slice(), &src[..]);
        assert_eq!(v.len(), 21);
        let w = v.clone();
        assert_eq!(v, w);
    }

    #[test]
    fn mutation_via_deref() {
        let mut v = AlignedVec::zeroed(5);
        v[3] = 2.5;
        assert_eq!(v.as_slice(), &[0.0, 0.0, 0.0, 2.5, 0.0]);
    }
}

//! Conjugate gradient over an abstract SPD operator.
//!
//! Mirrors the CG baked into the `block_solve` artifact (same update
//! order), so backend-parity tests can compare trajectories, not just
//! fixed points.

use super::ops;

/// Solve `H x = rhs` where `apply(v, out)` computes `out = H v`.
/// Returns the number of iterations performed.
pub fn conjugate_gradient<F>(
    mut apply: F,
    rhs: &[f64],
    x: &mut [f64],
    max_iters: usize,
    tol: f64,
) -> usize
where
    F: FnMut(&[f64], &mut [f64]),
{
    let n = rhs.len();
    assert_eq!(x.len(), n);
    let mut r = vec![0.0; n];
    let mut hx = vec![0.0; n];
    apply(x, &mut hx);
    ops::sub(rhs, &hx, &mut r);
    let mut p = r.clone();
    let mut rs = ops::dot(&r, &r);
    let mut hp = vec![0.0; n];
    let tol2 = tol * tol;

    for it in 0..max_iters {
        if rs <= tol2 {
            return it;
        }
        apply(&p, &mut hp);
        let denom = ops::dot(&p, &hp);
        let alpha = if denom == 0.0 { 0.0 } else { rs / denom };
        ops::axpy(alpha, &p, x);
        ops::axpy(-alpha, &hp, &mut r);
        let rs_new = ops::dot(&r, &r);
        let beta = if rs == 0.0 { 0.0 } else { rs_new / rs };
        for (pi, ri) in p.iter_mut().zip(&r) {
            *pi = ri + beta * *pi;
        }
        rs = rs_new;
    }
    max_iters
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn solves_diagonal_system_in_one_pass() {
        let d = [2.0, 4.0, 8.0];
        let rhs = [2.0, 8.0, 32.0];
        let mut x = vec![0.0; 3];
        let iters = conjugate_gradient(
            |v, out| {
                for i in 0..3 {
                    out[i] = d[i] * v[i];
                }
            },
            &rhs,
            &mut x,
            50,
            1e-12,
        );
        assert!(iters <= 4);
        for (xi, want) in x.iter().zip([1.0, 2.0, 4.0]) {
            assert!((xi - want).abs() < 1e-9);
        }
    }

    #[test]
    fn converges_on_random_spd_within_n_iters() {
        let mut rng = Rng::seed_from(2);
        let n = 24;
        let b: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += b[k * n + i] * b[k * n + j];
                }
                a[i * n + j] = s + if i == j { 2.0 } else { 0.0 };
            }
        }
        let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut rhs = vec![0.0; n];
        for i in 0..n {
            rhs[i] = (0..n).map(|j| a[i * n + j] * x_true[j]).sum();
        }
        let mut x = vec![0.0; n];
        conjugate_gradient(
            |v, out| {
                for i in 0..n {
                    out[i] = (0..n).map(|j| a[i * n + j] * v[j]).sum();
                }
            },
            &rhs,
            &mut x,
            2 * n,
            1e-12,
        );
        for (xi, yi) in x.iter().zip(&x_true) {
            assert!((xi - yi).abs() < 1e-6);
        }
    }

    #[test]
    fn warm_start_at_solution_is_noop() {
        let rhs = [3.0, 5.0];
        let mut x = [1.5, 2.5]; // exact solution of 2I x = rhs
        let iters = conjugate_gradient(
            |v, out| {
                out[0] = 2.0 * v[0];
                out[1] = 2.0 * v[1];
            },
            &rhs,
            &mut x,
            10,
            1e-10,
        );
        assert_eq!(iters, 0);
    }
}

//! Dense Cholesky factorization (f64) for the native block solver.
//!
//! The native ("CPU") backend factors `rho_l * G_j + reg * I` once per
//! (outer-iteration penalty change) and then back-substitutes per inner
//! iteration — the classic direct alternative to the artifact's CG.

/// Lower-triangular Cholesky factor of a symmetric positive-definite matrix.
#[derive(Clone, Debug)]
pub struct Cholesky {
    n: usize,
    /// Row-major lower triangle (full n x n storage, upper ignored).
    l: Vec<f64>,
}

/// Factorization error: the matrix was not positive definite.
#[derive(Debug)]
pub struct NotPositiveDefinite(
    /// Pivot index at which factorization failed.
    pub usize,
);

impl std::fmt::Display for NotPositiveDefinite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "matrix not positive definite at pivot {}", self.0)
    }
}

impl std::error::Error for NotPositiveDefinite {}

impl Cholesky {
    /// Factor `a` (row-major n x n, symmetric PD).
    pub fn factor(a: &[f64], n: usize) -> Result<Cholesky, NotPositiveDefinite> {
        assert_eq!(a.len(), n * n);
        let mut l = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..=i {
                let mut sum = a[i * n + j];
                for k in 0..j {
                    sum -= l[i * n + k] * l[j * n + k];
                }
                if i == j {
                    if sum <= 0.0 {
                        return Err(NotPositiveDefinite(i));
                    }
                    l[i * n + i] = sum.sqrt();
                } else {
                    l[i * n + j] = sum / l[j * n + j];
                }
            }
        }
        Ok(Cholesky { n, l })
    }

    /// Solve A x = b in place.
    pub fn solve(&self, b: &mut [f64]) {
        let n = self.n;
        assert_eq!(b.len(), n);
        // forward: L y = b
        for i in 0..n {
            let mut sum = b[i];
            for k in 0..i {
                sum -= self.l[i * n + k] * b[k];
            }
            b[i] = sum / self.l[i * n + i];
        }
        // backward: L^T x = y
        for i in (0..n).rev() {
            let mut sum = b[i];
            for k in (i + 1)..n {
                sum -= self.l[k * n + i] * b[k];
            }
            b[i] = sum / self.l[i * n + i];
        }
    }

    /// Solve A X = B for `k` right-hand sides in place, amortizing one
    /// factorization across all columns (the multiclass block solve:
    /// `B = [b_0 | b_1 | ... | b_{k-1}]`, each column contiguous).
    ///
    /// Each column is solved with exactly the same substitution order as
    /// [`Cholesky::solve`], so a `k == 1` call is bit-identical to the
    /// single-vector path.
    pub fn solve_multi(&self, b: &mut [f64], k: usize) {
        assert_eq!(b.len(), k * self.n);
        for col in b.chunks_exact_mut(self.n) {
            self.solve(col);
        }
    }

    /// Dimension of the factored matrix.
    pub fn n(&self) -> usize {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(rng: &mut Rng, n: usize) -> Vec<f64> {
        // A = B^T B + n * I
        let b: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += b[k * n + i] * b[k * n + j];
                }
                a[i * n + j] = s + if i == j { n as f64 } else { 0.0 };
            }
        }
        a
    }

    #[test]
    fn solves_known_system() {
        // A = [[4, 2], [2, 3]], b = [10, 9] -> x = [1.5, 2]
        let a = [4.0, 2.0, 2.0, 3.0];
        let ch = Cholesky::factor(&a, 2).unwrap();
        let mut b = [10.0, 9.0];
        ch.solve(&mut b);
        assert!((b[0] - 1.5).abs() < 1e-12);
        assert!((b[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn random_spd_roundtrip() {
        let mut rng = Rng::seed_from(1);
        for n in [1, 2, 5, 16, 40] {
            let a = random_spd(&mut rng, n);
            let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut b = vec![0.0; n];
            for i in 0..n {
                for j in 0..n {
                    b[i] += a[i * n + j] * x_true[j];
                }
            }
            let ch = Cholesky::factor(&a, n).unwrap();
            ch.solve(&mut b);
            for (x, y) in b.iter().zip(&x_true) {
                assert!((x - y).abs() < 1e-8, "n={n}");
            }
        }
    }

    #[test]
    fn solve_multi_matches_columnwise_solve() {
        let mut rng = Rng::seed_from(7);
        let n = 6;
        let k = 3;
        let a = random_spd(&mut rng, n);
        let ch = Cholesky::factor(&a, n).unwrap();
        let b: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
        let mut multi = b.clone();
        ch.solve_multi(&mut multi, k);
        for c in 0..k {
            let mut single = b[c * n..(c + 1) * n].to_vec();
            ch.solve(&mut single);
            assert_eq!(&multi[c * n..(c + 1) * n], &single[..]);
        }
    }

    #[test]
    fn rejects_indefinite() {
        let a = [1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(Cholesky::factor(&a, 2).is_err());
    }
}

//! Compressed-sparse-row storage and kernels for the sparse data path.
//!
//! The paper's target workloads (text, one-hot, genomics) are
//! overwhelmingly zero-valued, so the dense kernels in [`super::kernels`]
//! burn O(m n) work regardless of density.  [`CsrMatrix`] stores only the
//! nonzeros; the kernels here are the sparse twins of the dense layer:
//!
//!   * `spmv`        — y = A x          (twin of `kernels::matvec`)
//!   * `spmv_t`      — y = A^T v        (twin of `kernels::matvec_t`)
//!   * `spmm`        — Y = A X, k RHS   (twin of `kernels::matmul`)
//!   * `spmm_t`      — Y = A^T V, k RHS (twin of `kernels::matmul_t`)
//!   * `gram_sparse` — G += A^T A       (twin of `kernels::gram`)
//!
//! Each has a `_naive` reference twin mirroring the `kernels.rs` contract,
//! pinned against it by the property tests and timed by `psfit bench`.
//! Like the dense layer, `spmv`/`spmm`/`spmv_t`/`spmm_t` are
//! runtime-ISA-dispatched (`foo_isa` pins a variant, `foo` routes through
//! [`super::simd::active`]); `gram_sparse` is a setup-time op and stays
//! scalar.
//!
//! # Padded value runs (SIMD layout)
//!
//! Internally each row's entry run is padded to a multiple of
//! [`SIMD_PAD`] entries with *storage-only* padding: value `0.0`, column
//! index equal to the row's last real column.  A padded run can be
//! consumed in full vector lanes with no tail handling — the zero values
//! contribute nothing and the duplicate in-range columns keep gathers in
//! bounds.  The padding is invisible outside the kernels: [`CsrMatrix::row`],
//! [`CsrMatrix::nnz`], [`CsrMatrix::values`], equality, and every
//! serializer see only the real entries, so LIBSVM round-trips and PSC1
//! checkpoint hashes are unchanged.  `CsrBlockView::row_lanes` hands the
//! padded run to a kernel only when its block range covers the row's full
//! real run (always true for full-width views and for rows whose entries
//! all fall inside the block); partial mid-row ranges fall back to the
//! exact subrange plus the shared scalar tail.
//!
//! Feature blocks are read **in place** through [`CsrBlockView`] — the
//! sparse twin of [`super::kernels::ColumnBlockView`].  Because column
//! indices are sorted within each row, the entries of a contiguous column
//! block `[col0, col0 + width)` form one contiguous subrange of every
//! row's entry list; a block view is just those per-row subranges,
//! computed once (binary search per row) and reused for every sweep.
//!
//! Determinism contract: identical to the dense layer — kernels are
//! single-threaded and, per ISA, their summation order is a fixed
//! function of the stored entry order, so results are bit-identical from
//! run to run and at any worker-pool width.  (Sparse and *dense* kernels
//! sum in different orders, so cross-storage agreement is to rounding,
//! not bits — the parity tests use 1e-5 like the tiled-vs-naive pins.)

use super::matrix::Matrix;
use super::simd::{self, Isa};

/// Entries per padded row run (covers both the AVX2 8-lane and NEON
/// 4-lane kernels).
pub const SIMD_PAD: usize = 8;

/// Row-major compressed sparse rows with padded per-row runs (see the
/// module docs): row `i`'s *real* entries live at
/// `col_idx[row_ptr[i]..row_ptr[i] + row_len[i]]` / `vals[..]`, column
/// indices strictly increasing within a row; the rest of the allocated
/// run `[.., row_ptr[i + 1])` is storage-only padding.
#[derive(Clone, Debug)]
pub struct CsrMatrix {
    /// Row count.
    pub rows: usize,
    /// Column count (logical width; trailing all-zero columns allowed).
    pub cols: usize,
    /// `rows + 1` offsets bounding each row's *allocated* (padded) run.
    row_ptr: Vec<usize>,
    /// Real entries per row (`<= row_ptr[i+1] - row_ptr[i]`).
    row_len: Vec<usize>,
    /// Column index of every stored entry (padding duplicates the row's
    /// last real column, keeping per-row order non-decreasing).
    col_idx: Vec<u32>,
    /// Value of every stored entry (padding is 0.0; explicit real zeros
    /// allowed).
    vals: Vec<f32>,
    /// Total real entries (cached sum of `row_len`).
    nnz: usize,
}

impl PartialEq for CsrMatrix {
    /// Logical equality: shape plus real entries; padding is ignored.
    fn eq(&self, other: &CsrMatrix) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && (0..self.rows).all(|i| self.row(i) == other.row(i))
    }
}

/// Builder accumulating padded runs row by row.
struct CsrBuilder {
    row_ptr: Vec<usize>,
    row_len: Vec<usize>,
    col_idx: Vec<u32>,
    vals: Vec<f32>,
    nnz: usize,
}

impl CsrBuilder {
    fn new(rows_hint: usize) -> CsrBuilder {
        CsrBuilder {
            row_ptr: {
                let mut v = Vec::with_capacity(rows_hint + 1);
                v.push(0);
                v
            },
            row_len: Vec::with_capacity(rows_hint),
            col_idx: Vec::new(),
            vals: Vec::new(),
            nnz: 0,
        }
    }

    /// Close the current row: record its real length and pad the run.
    fn finish_row(&mut self) {
        let start = *self.row_ptr.last().unwrap();
        let len = self.col_idx.len() - start;
        self.row_len.push(len);
        self.nnz += len;
        if len > 0 {
            let pad_col = *self.col_idx.last().unwrap();
            let padded = len.div_ceil(SIMD_PAD) * SIMD_PAD;
            for _ in len..padded {
                self.col_idx.push(pad_col);
                self.vals.push(0.0);
            }
        }
        self.row_ptr.push(self.col_idx.len());
    }

    fn build(self, rows: usize, cols: usize) -> CsrMatrix {
        debug_assert_eq!(self.row_len.len(), rows);
        CsrMatrix {
            rows,
            cols,
            row_ptr: self.row_ptr,
            row_len: self.row_len,
            col_idx: self.col_idx,
            vals: self.vals,
            nnz: self.nnz,
        }
    }
}

impl CsrMatrix {
    /// Build from per-row (column, value) entry lists.  Entries must have
    /// strictly increasing columns within each row; zeros may be stored
    /// explicitly (the LIBSVM reader keeps whatever the file says).
    pub fn from_rows(cols: usize, rows: Vec<Vec<(u32, f32)>>) -> CsrMatrix {
        let n_rows = rows.len();
        let mut b = CsrBuilder::new(n_rows);
        for row in &rows {
            let mut prev: Option<u32> = None;
            for &(c, v) in row {
                assert!((c as usize) < cols, "column {c} out of range {cols}");
                if let Some(p) = prev {
                    assert!(c > p, "columns must increase within a row");
                }
                prev = Some(c);
                b.col_idx.push(c);
                b.vals.push(v);
            }
            b.finish_row();
        }
        b.build(n_rows, cols)
    }

    /// Compress a dense matrix (exact: every nonzero entry kept).
    pub fn from_dense(a: &Matrix) -> CsrMatrix {
        let mut b = CsrBuilder::new(a.rows);
        for i in 0..a.rows {
            for (j, &v) in a.row(i).iter().enumerate() {
                if v != 0.0 {
                    b.col_idx.push(j as u32);
                    b.vals.push(v);
                }
            }
            b.finish_row();
        }
        b.build(a.rows, a.cols)
    }

    /// Expand back to dense (bit-exact: values are copied, not recomputed).
    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let row = out.row_mut(i);
            for (&c, &v) in cols.iter().zip(vals) {
                row[c as usize] = v;
            }
        }
        out
    }

    /// Stored real entries (including any explicit zeros; padding never
    /// counted).
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Stored-entry fraction in [0, 1] (1.0 for an empty matrix so the
    /// storage policy never picks CSR for degenerate shapes).
    pub fn density(&self) -> f64 {
        let size = self.rows * self.cols;
        if size == 0 {
            1.0
        } else {
            self.nnz() as f64 / size as f64
        }
    }

    /// Row `i`'s real entries: (column indices, values).
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (s, e) = self.row_bounds(i);
        (&self.col_idx[s..e], &self.vals[s..e])
    }

    /// Absolute bounds `[start, end)` of row `i`'s *real* entries within
    /// the entry arrays (what [`CsrMatrix::block_ranges`] partitions).
    #[inline]
    pub fn row_bounds(&self, i: usize) -> (usize, usize) {
        let s = self.row_ptr[i];
        (s, s + self.row_len[i])
    }

    /// All real stored values in row-major entry order (the checkpoint
    /// problem hash samples these; padding excluded, so the hash matches
    /// the historical unpadded layout).
    pub fn values(&self) -> impl Iterator<Item = f32> + '_ {
        (0..self.rows).flat_map(|i| self.row(i).1.iter().copied())
    }

    /// The raw storage arrays as a borrowed [`CsrParts`] — what mapped
    /// shards construct directly and every view/kernel path reads through.
    #[inline]
    pub fn parts(&self) -> CsrParts<'_> {
        CsrParts {
            row_ptr: &self.row_ptr,
            row_len: &self.row_len,
            col_idx: &self.col_idx,
            vals: &self.vals,
        }
    }

    /// Per-row entry subranges covering columns `[col0, col0 + width)` —
    /// the precomputation behind [`CsrBlockView`].  O(rows log nnz_row),
    /// done once per feature block at backend construction.
    pub fn block_ranges(&self, col0: usize, width: usize) -> Vec<(usize, usize)> {
        assert!(col0 + width <= self.cols, "column block out of range");
        self.parts().block_ranges(col0, width)
    }

    /// Borrowed view of the column block `[col0, col0 + width)` through
    /// precomputed `ranges` (from [`CsrMatrix::block_ranges`] with the
    /// same `col0` / `width`).
    pub fn block_view<'a>(
        &'a self,
        ranges: &'a [(usize, usize)],
        col0: usize,
        width: usize,
    ) -> CsrBlockView<'a> {
        assert!(col0 + width <= self.cols);
        CsrBlockView::new(self.parts(), 0, self.rows, col0, width, ranges)
    }

    /// y = A x over the whole matrix (convenience for the storage enum;
    /// dispatched like the block kernels — whole rows always qualify for
    /// the padded fast path, read straight off the allocated runs, so no
    /// block-range precomputation (or allocation) is needed).
    pub fn spmv(&self, x: &[f32], y: &mut [f32]) {
        spmv_parts(self.parts(), self.cols, x, y)
    }

    /// y = A^T v over the whole matrix.  Stays scalar on every ISA: the
    /// transposed product is a per-entry scatter, and neither AVX2 nor
    /// NEON has scatter stores (the block-level [`spmm_t`] vectorizes
    /// only the value scaling, a marginal win the convenience path skips).
    pub fn spmv_t(&self, v: &[f32], y: &mut [f32]) {
        spmv_t_parts(self.parts(), self.cols, v, y)
    }
}

/// y = A x over whole-matrix [`CsrParts`] — the storage-agnostic body of
/// [`CsrMatrix::spmv`], shared with mapped `PSD1` shards so resident and
/// mapped products are the same code path (hence bit-identical).
pub fn spmv_parts(a: CsrParts<'_>, ncols: usize, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), ncols);
    assert_eq!(y.len(), a.rows());
    let isa = simd::active();
    for (i, yi) in y.iter_mut().enumerate() {
        let (cols, vals) = if isa == Isa::Scalar {
            a.row(i)
        } else {
            // full padded run: lane-multiple length, zero-value tail
            let (s, pe) = (a.row_ptr[i], a.row_ptr[i + 1]);
            (&a.col_idx[s..pe], &a.vals[s..pe])
        };
        *yi = row_dot_isa(isa, cols, vals, 0, x);
    }
}

/// y = A^T v over whole-matrix [`CsrParts`] (see [`CsrMatrix::spmv_t`]).
pub fn spmv_t_parts(a: CsrParts<'_>, ncols: usize, v: &[f32], y: &mut [f32]) {
    assert_eq!(v.len(), a.rows());
    assert_eq!(y.len(), ncols);
    y.fill(0.0);
    for (i, &vi) in v.iter().enumerate() {
        let (cols, vals) = a.row(i);
        for (&c, &a) in cols.iter().zip(vals) {
            y[c as usize] += a * vi;
        }
    }
}

/// Borrowed raw CSR arrays — the storage-agnostic substrate every sparse
/// kernel path reads through.  A RAM-resident [`CsrMatrix`] lends its own
/// vectors; a mapped `PSD1` shard (`data::shardfile::MappedShard`) lends
/// `col_idx`/`vals` straight off the map with `row_ptr`/`row_len` decoded
/// at open.  Layout contract is the [`CsrMatrix`] one: `row_ptr` bounds the
/// *allocated* (padded) runs, `row_len` counts the real entries, padding
/// duplicates the last real column with value 0.
#[derive(Clone, Copy, Debug)]
pub struct CsrParts<'a> {
    /// `rows + 1` offsets bounding each row's allocated (padded) run.
    pub row_ptr: &'a [usize],
    /// Real entries per row.
    pub row_len: &'a [usize],
    /// Column index of every stored entry (incl. padding duplicates).
    pub col_idx: &'a [u32],
    /// Value of every stored entry (padding is 0.0).
    pub vals: &'a [f32],
}

impl<'a> CsrParts<'a> {
    /// Row count.
    #[inline]
    pub fn rows(&self) -> usize {
        self.row_len.len()
    }

    /// Absolute bounds `[start, end)` of row `i`'s *real* entries.
    #[inline]
    pub fn row_bounds(&self, i: usize) -> (usize, usize) {
        let s = self.row_ptr[i];
        (s, s + self.row_len[i])
    }

    /// Row `i`'s real entries: (column indices, values).
    #[inline]
    pub fn row(&self, i: usize) -> (&'a [u32], &'a [f32]) {
        let (s, e) = self.row_bounds(i);
        (&self.col_idx[s..e], &self.vals[s..e])
    }

    /// Per-row entry subranges covering columns `[col0, col0 + width)` —
    /// the precomputation behind [`CsrBlockView`].  O(rows log nnz_row).
    pub fn block_ranges(&self, col0: usize, width: usize) -> Vec<(usize, usize)> {
        let (lo, hi) = (col0 as u32, (col0 + width) as u32);
        (0..self.rows())
            .map(|i| {
                let (s, e) = self.row_bounds(i);
                let cols = &self.col_idx[s..e];
                let a = s + cols.partition_point(|&c| c < lo);
                let b = s + cols.partition_point(|&c| c < hi);
                (a, b)
            })
            .collect()
    }
}

/// Borrowed view of the contiguous column block `[col0, col0 + cols)` of a
/// CSR storage (resident or mapped) — the sparse twin of `ColumnBlockView`.
/// Column indices are rebased by `col0` on read, so kernels see block-local
/// columns.  `row0` offsets the view down the parent's rows, which is how
/// the mini-batch spans view a chunk of samples in place.
#[derive(Clone, Copy, Debug)]
pub struct CsrBlockView<'a> {
    parts: CsrParts<'a>,
    /// First parent row of the view (0 for whole-shard views).
    row0: usize,
    /// Rows viewed.
    rows: usize,
    cols: usize,
    col0: u32,
    /// Per-row `[start, end)` into the parent's entry arrays (real
    /// entries only); entry `i` describes parent row `row0 + i`.
    ranges: &'a [(usize, usize)],
}

impl<'a> CsrBlockView<'a> {
    /// View rows `[row0, row0 + rows)` × columns `[col0, col0 + cols)` of
    /// raw CSR storage through precomputed `ranges` (one per viewed row,
    /// each a subrange of the matching parent row's real entries).
    pub fn new(
        parts: CsrParts<'a>,
        row0: usize,
        rows: usize,
        col0: usize,
        cols: usize,
        ranges: &'a [(usize, usize)],
    ) -> CsrBlockView<'a> {
        assert_eq!(ranges.len(), rows);
        assert!(row0 + rows <= parts.rows(), "row span out of range");
        CsrBlockView {
            parts,
            row0,
            rows,
            cols,
            col0: col0 as u32,
            ranges,
        }
    }

    /// Rows of the viewed block.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Columns (block width) of the viewed block.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row `i`'s real entries within the block: (parent column indices,
    /// values).  Subtract [`CsrBlockView::col0`] for block-local columns.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (s, e) = self.ranges[i];
        (&self.parts.col_idx[s..e], &self.parts.vals[s..e])
    }

    /// Row `i`'s entries for a vector kernel: the padded run (length a
    /// multiple of [`SIMD_PAD`], zero-value tail, in-range duplicate
    /// columns) whenever the block range covers the row's full real run,
    /// otherwise the exact real subrange (the kernel then takes the
    /// shared scalar tail).  The extra entries contribute exactly 0 to
    /// any dot product, so both returns denote the same row.
    #[inline]
    pub(crate) fn row_lanes(&self, i: usize) -> (&[u32], &[f32]) {
        let (s, e) = self.ranges[i];
        let (rs, re) = self.parts.row_bounds(self.row0 + i);
        if s == rs && e == re {
            let pe = self.parts.row_ptr[self.row0 + i + 1];
            (&self.parts.col_idx[s..pe], &self.parts.vals[s..pe])
        } else {
            (&self.parts.col_idx[s..e], &self.parts.vals[s..e])
        }
    }

    /// First parent column of the block (subtract from `row` indices for
    /// block-local columns).
    #[inline]
    pub fn col0(&self) -> u32 {
        self.col0
    }

    /// Stored real entries inside the block.
    pub fn nnz(&self) -> usize {
        self.ranges.iter().map(|&(s, e)| e - s).sum()
    }
}

/// Scalar remainder of a sparse dot — the shared tail helper of the
/// sparse paths (the unroll-by-4 scalar kernel and every SIMD variant
/// finish here, in the same left-to-right order).
#[inline]
pub(crate) fn dot_sparse_tail(cols: &[u32], vals: &[f32], col0: u32, x: &[f32]) -> f32 {
    let mut tail = 0.0f32;
    for (&c, &v) in cols.iter().zip(vals) {
        tail += v * x[(c - col0) as usize];
    }
    tail
}

/// One sparse row dot under a pinned ISA (shared by the whole-matrix
/// [`CsrMatrix::spmv`] and, through the block kernels, every dispatched
/// spmv/spmm path).
#[inline]
fn row_dot_isa(isa: Isa, cols: &[u32], vals: &[f32], col0: u32, x: &[f32]) -> f32 {
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { simd::avx2::sparse_dot(cols, vals, col0, x) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { simd::neon::sparse_dot(cols, vals, col0, x) },
        Isa::Scalar => dot_sparse(cols, vals, col0, x),
        #[allow(unreachable_patterns)]
        other => panic!("isa {} not available on this host", other.name()),
    }
}

/// Sparse dot of one row's block entries against a dense vector indexed by
/// block-local column.  Four independent accumulators, fixed reduction
/// order `((a0 + a1) + (a2 + a3)) + tail` — the sparse analogue of the
/// dense `dot4` determinism contract.
#[inline]
fn dot_sparse(cols: &[u32], vals: &[f32], col0: u32, x: &[f32]) -> f32 {
    let mut acc = [0.0f32; 4];
    let mut cc = cols.chunks_exact(4);
    let mut cv = vals.chunks_exact(4);
    for (c4, v4) in (&mut cc).zip(&mut cv) {
        acc[0] += v4[0] * x[(c4[0] - col0) as usize];
        acc[1] += v4[1] * x[(c4[1] - col0) as usize];
        acc[2] += v4[2] * x[(c4[2] - col0) as usize];
        acc[3] += v4[3] * x[(c4[3] - col0) as usize];
    }
    let tail = dot_sparse_tail(cc.remainder(), cv.remainder(), col0, x);
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + tail
}

// ------------------------------------------------------------------- spmv

/// y = A x — naive reference (plain per-entry accumulation, single
/// accumulator, mirroring `matvec_naive`).
pub fn spmv_naive(a: &CsrBlockView, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), a.cols());
    assert_eq!(y.len(), a.rows());
    let col0 = a.col0();
    for (i, yi) in y.iter_mut().enumerate() {
        let (cols, vals) = a.row(i);
        let mut acc = 0.0f32;
        for (&c, &v) in cols.iter().zip(vals) {
            acc += v * x[(c - col0) as usize];
        }
        *yi = acc;
    }
}

/// y = A x — tiled-scalar variant (unroll-by-4 sparse row dot).
fn spmv_scalar(a: &CsrBlockView, x: &[f32], y: &mut [f32]) {
    let col0 = a.col0();
    for (i, yi) in y.iter_mut().enumerate() {
        let (cols, vals) = a.row(i);
        *yi = dot_sparse(cols, vals, col0, x);
    }
}

/// y = A x under a pinned ISA variant.
pub fn spmv_isa(isa: Isa, a: &CsrBlockView, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), a.cols());
    assert_eq!(y.len(), a.rows());
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { simd::avx2::spmv(a, x, y) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { simd::neon::spmv(a, x, y) },
        Isa::Scalar => spmv_scalar(a, x, y),
        #[allow(unreachable_patterns)]
        other => panic!("isa {} not available on this host", other.name()),
    }
}

/// y = A x — dispatched to the active ISA.
pub fn spmv(a: &CsrBlockView, x: &[f32], y: &mut [f32]) {
    spmv_isa(simd::active(), a, x, y)
}

/// Y = A X for `k` right-hand sides — naive reference (k naive spmv).
/// Layouts match the dense twins: `x` is `k` class-major vectors of
/// length `cols`, `y` is `k` vectors of length `rows`.
pub fn spmm_naive(a: &CsrBlockView, x: &[f32], k: usize, y: &mut [f32]) {
    let (m, n) = (a.rows(), a.cols());
    assert_eq!(x.len(), k * n);
    assert_eq!(y.len(), k * m);
    for r in 0..k {
        spmv_naive(a, &x[r * n..(r + 1) * n], &mut y[r * m..(r + 1) * m]);
    }
}

/// Y = A X — tiled-scalar variant: each row's entries are loaded once
/// and dotted against all `k` vectors while hot (the sparse analogue of
/// the multiclass batching in `matmul`).
fn spmm_scalar(a: &CsrBlockView, x: &[f32], k: usize, y: &mut [f32]) {
    let (m, n) = (a.rows(), a.cols());
    let col0 = a.col0();
    for i in 0..m {
        let (cols, vals) = a.row(i);
        for r in 0..k {
            y[r * m + i] = dot_sparse(cols, vals, col0, &x[r * n..(r + 1) * n]);
        }
    }
}

/// Y = A X for `k` right-hand sides under a pinned ISA variant.
pub fn spmm_isa(isa: Isa, a: &CsrBlockView, x: &[f32], k: usize, y: &mut [f32]) {
    let (m, n) = (a.rows(), a.cols());
    assert_eq!(x.len(), k * n);
    assert_eq!(y.len(), k * m);
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { simd::avx2::spmm(a, x, k, y) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { simd::neon::spmm(a, x, k, y) },
        Isa::Scalar => spmm_scalar(a, x, k, y),
        #[allow(unreachable_patterns)]
        other => panic!("isa {} not available on this host", other.name()),
    }
}

/// Y = A X for `k` right-hand sides — dispatched to the active ISA.
pub fn spmm(a: &CsrBlockView, x: &[f32], k: usize, y: &mut [f32]) {
    spmm_isa(simd::active(), a, x, k, y)
}

// ----------------------------------------------------------------- spmv_t

/// y = A^T v — naive reference (per-row scatter with the historical
/// skip-zero branch, mirroring `matvec_t_naive`).
pub fn spmv_t_naive(a: &CsrBlockView, v: &[f32], y: &mut [f32]) {
    assert_eq!(v.len(), a.rows());
    assert_eq!(y.len(), a.cols());
    let col0 = a.col0();
    y.fill(0.0);
    for (i, &vi) in v.iter().enumerate() {
        if vi == 0.0 {
            continue;
        }
        let (cols, vals) = a.row(i);
        for (&c, &aij) in cols.iter().zip(vals) {
            y[(c - col0) as usize] += aij * vi;
        }
    }
}

/// y = A^T v under a pinned ISA variant (shared with [`spmm_t_isa`], so
/// `k == 1` stays bit-identical).
pub fn spmv_t_isa(isa: Isa, a: &CsrBlockView, v: &[f32], y: &mut [f32]) {
    spmm_t_isa(isa, a, v, 1, y)
}

/// y = A^T v — dispatched to the active ISA (the per-iteration
/// data-touching op of the inner sweep on sparse shards).
pub fn spmv_t(a: &CsrBlockView, v: &[f32], y: &mut [f32]) {
    spmm_t_isa(simd::active(), a, v, 1, y)
}

/// Y = A^T V for `k` vectors — naive reference (k naive spmv_t).
pub fn spmm_t_naive(a: &CsrBlockView, v: &[f32], k: usize, y: &mut [f32]) {
    let (m, n) = (a.rows(), a.cols());
    assert_eq!(v.len(), k * m);
    assert_eq!(y.len(), k * n);
    for r in 0..k {
        spmv_t_naive(a, &v[r * m..(r + 1) * m], &mut y[r * n..(r + 1) * n]);
    }
}

/// Y = A^T V — tiled-scalar variant: each row's entries are read once and
/// scattered into all `k` accumulations.
fn spmm_t_scalar(a: &CsrBlockView, v: &[f32], k: usize, y: &mut [f32]) {
    let (m, n) = (a.rows(), a.cols());
    let col0 = a.col0();
    y.fill(0.0);
    for i in 0..m {
        let (cols, vals) = a.row(i);
        if cols.is_empty() {
            continue;
        }
        for r in 0..k {
            let vi = v[r * m + i];
            let yr = &mut y[r * n..(r + 1) * n];
            for (&c, &aij) in cols.iter().zip(vals) {
                yr[(c - col0) as usize] += aij * vi;
            }
        }
    }
}

/// Y = A^T V for `k` vectors under a pinned ISA variant.
pub fn spmm_t_isa(isa: Isa, a: &CsrBlockView, v: &[f32], k: usize, y: &mut [f32]) {
    let (m, n) = (a.rows(), a.cols());
    assert_eq!(v.len(), k * m);
    assert_eq!(y.len(), k * n);
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { simd::avx2::spmm_t(a, v, k, y) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { simd::neon::spmm_t(a, v, k, y) },
        Isa::Scalar => spmm_t_scalar(a, v, k, y),
        #[allow(unreachable_patterns)]
        other => panic!("isa {} not available on this host", other.name()),
    }
}

/// Y = A^T V for `k` vectors — dispatched to the active ISA.
pub fn spmm_t(a: &CsrBlockView, v: &[f32], k: usize, y: &mut [f32]) {
    spmm_t_isa(simd::active(), a, v, k, y)
}

// ------------------------------------------------------------ gram_sparse

/// G += A^T A — naive reference (per-row pair accumulation with the
/// historical skip-zero branch; upper triangle mirrored, composing across
/// calls exactly like `gram_naive`).
pub fn gram_sparse_naive(a: &CsrBlockView, g: &mut [f32]) {
    let n = a.cols();
    assert_eq!(g.len(), n * n);
    let col0 = a.col0();
    for i in 0..a.rows() {
        let (cols, vals) = a.row(i);
        for (p, &cp) in cols.iter().enumerate() {
            let ap = vals[p];
            if ap == 0.0 {
                continue;
            }
            let j = (cp - col0) as usize;
            let grow = &mut g[j * n..(j + 1) * n];
            for (&cq, &aq) in cols[p..].iter().zip(&vals[p..]) {
                grow[(cq - col0) as usize] += ap * aq;
            }
        }
    }
    mirror_upper(g, n);
}

/// G += A^T A — branch-free per-row pair accumulation.  Each stored row
/// contributes O(nnz_row^2) work instead of the dense O(n^2); upper
/// triangle computed then mirrored (mirroring only copies, so
/// accumulating across calls composes).  Setup-time op: scalar on every
/// ISA.
pub fn gram_sparse(a: &CsrBlockView, g: &mut [f32]) {
    let n = a.cols();
    assert_eq!(g.len(), n * n);
    let col0 = a.col0();
    for i in 0..a.rows() {
        let (cols, vals) = a.row(i);
        for (p, &cp) in cols.iter().enumerate() {
            let ap = vals[p];
            let j = (cp - col0) as usize;
            let grow = &mut g[j * n..(j + 1) * n];
            for (&cq, &aq) in cols[p..].iter().zip(&vals[p..]) {
                grow[(cq - col0) as usize] += ap * aq;
            }
        }
    }
    mirror_upper(g, n);
}

fn mirror_upper(g: &mut [f32], n: usize) {
    for j in 0..n {
        for k in (j + 1)..n {
            g[k * n + j] = g[j * n + k];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::kernels;
    use crate::util::rng::Rng;

    /// Random dense matrix with ~`density` nonzero fraction.
    fn rand_sparse(rng: &mut Rng, m: usize, n: usize, density: f64) -> Matrix {
        let mut a = Matrix::zeros(m, n);
        a.for_each_mut(|v| *v = rng.normal_f32());
        a.for_each_mut(|v| {
            if rng.uniform() >= density {
                *v = 0.0;
            }
        });
        a
    }

    fn close(a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            let scale = 1.0f32.max(x.abs()).max(y.abs());
            assert!((x - y).abs() <= 1e-5 * scale, "elem {i}: {x} vs {y}");
        }
    }

    #[test]
    fn dense_roundtrip_is_exact() {
        let mut rng = Rng::seed_from(1);
        for (m, n, d) in [(7, 9, 0.3), (4, 4, 0.0), (5, 3, 1.0), (0, 6, 0.5)] {
            let a = rand_sparse(&mut rng, m, n, d);
            let c = CsrMatrix::from_dense(&a);
            assert_eq!(c.to_dense(), a);
            let logical = a.to_vec();
            assert_eq!(c.nnz(), logical.iter().filter(|&&v| v != 0.0).count());
        }
    }

    #[test]
    fn padding_is_storage_only() {
        // 3 entries in one row: run padded to SIMD_PAD, but every logical
        // accessor sees exactly the 3 real entries
        let c = CsrMatrix::from_rows(4, vec![
            vec![(1, 1.0), (3, -2.0)],
            vec![(0, 5.0), (1, 6.0), (3, 7.0)],
            vec![],
            vec![(2, 9.0)],
        ]);
        assert_eq!(c.nnz(), 6);
        assert_eq!(c.row(1), (&[0u32, 1, 3][..], &[5.0f32, 6.0, 7.0][..]));
        assert_eq!(c.row(2), (&[][..], &[][..]));
        assert_eq!(c.values().collect::<Vec<_>>(), vec![1.0, -2.0, 5.0, 6.0, 7.0, 9.0]);
        // allocated runs are lane multiples with zero-value padding
        let (s1, e1) = c.row_bounds(1);
        assert_eq!(e1 - s1, 3);
        assert_eq!(c.row_ptr[2] - s1, SIMD_PAD);
        assert!(c.vals[e1..c.row_ptr[2]].iter().all(|&v| v == 0.0));
        assert!(c.col_idx[e1..c.row_ptr[2]].iter().all(|&cc| cc == 3));
        // equality ignores padding: a logically-equal matrix built from
        // the dense expansion compares equal
        assert_eq!(CsrMatrix::from_dense(&c.to_dense()), c);
    }

    #[test]
    fn density_counts_stored_entries() {
        let a = Matrix::from_rows(vec![vec![1.0, 0.0], vec![0.0, 2.0]]);
        let c = CsrMatrix::from_dense(&a);
        assert!((c.density() - 0.5).abs() < 1e-12);
        let empty = CsrMatrix::from_dense(&Matrix::zeros(0, 3));
        assert_eq!(empty.density(), 1.0);
    }

    #[test]
    fn whole_matrix_spmv_matches_dense() {
        let mut rng = Rng::seed_from(2);
        let a = rand_sparse(&mut rng, 13, 7, 0.4);
        let c = CsrMatrix::from_dense(&a);
        let x: Vec<f32> = (0..7).map(|_| rng.normal_f32()).collect();
        let v: Vec<f32> = (0..13).map(|_| rng.normal_f32()).collect();
        let (mut y0, mut y1) = (vec![0.0f32; 13], vec![0.0f32; 13]);
        a.matvec(&x, &mut y0);
        c.spmv(&x, &mut y1);
        close(&y0, &y1);
        let (mut z0, mut z1) = (vec![0.0f32; 7], vec![0.0f32; 7]);
        a.matvec_t(&v, &mut z0);
        c.spmv_t(&v, &mut z1);
        close(&z0, &z1);
    }

    #[test]
    fn block_kernels_match_dense_views_on_every_isa() {
        let mut rng = Rng::seed_from(3);
        // non-multiple-of-lane shapes; includes an empty (zero-entry) block
        for (m, n, col0, w, d) in [
            (9, 11, 3, 5, 0.3),
            (6, 7, 0, 7, 0.1),
            (14, 10, 4, 3, 0.0),
            (5, 8, 6, 2, 1.0),
            (11, 40, 0, 40, 0.6),
        ] {
            let a = rand_sparse(&mut rng, m, n, d);
            let c = CsrMatrix::from_dense(&a);
            let ranges = c.block_ranges(col0, w);
            let sv = c.block_view(&ranges, col0, w);
            let dv = a.column_block_view(col0, w);

            let x: Vec<f32> = (0..w).map(|_| rng.normal_f32()).collect();
            let v: Vec<f32> = (0..m).map(|_| rng.normal_f32()).collect();
            let mut y0 = vec![0.0f32; m];
            kernels::matvec_naive(&dv, &x, &mut y0);
            let mut z0 = vec![0.0f32; w];
            kernels::matvec_t_naive(&dv, &v, &mut z0);
            let mut g0 = vec![0.0f32; w * w];
            kernels::gram_naive(&dv, &mut g0);

            for isa in crate::linalg::simd::supported() {
                let mut y1 = vec![0.0f32; m];
                spmv_isa(isa, &sv, &x, &mut y1);
                close(&y0, &y1);
                let mut z1 = vec![0.0f32; w];
                spmv_t_isa(isa, &sv, &v, &mut z1);
                close(&z0, &z1);
            }
            let mut y1 = vec![0.0f32; m];
            spmv_naive(&sv, &x, &mut y1);
            close(&y0, &y1);
            let mut g1 = vec![0.0f32; w * w];
            gram_sparse(&sv, &mut g1);
            close(&g0, &g1);
            g1.fill(0.0);
            gram_sparse_naive(&sv, &mut g1);
            close(&g0, &g1);
        }
    }

    #[test]
    fn multi_rhs_matches_naive_and_k1_is_bit_identical() {
        let mut rng = Rng::seed_from(4);
        let (m, n, k) = (14, 6, 3);
        let a = rand_sparse(&mut rng, m, n, 0.35);
        let c = CsrMatrix::from_dense(&a);
        let ranges = c.block_ranges(0, n);
        let sv = c.block_view(&ranges, 0, n);
        let x: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let v: Vec<f32> = (0..k * m).map(|_| rng.normal_f32()).collect();

        let mut y0 = vec![0.0f32; k * m];
        spmm_naive(&sv, &x, k, &mut y0);
        let mut z0 = vec![0.0f32; k * n];
        spmm_t_naive(&sv, &v, k, &mut z0);

        for isa in crate::linalg::simd::supported() {
            let mut y1 = vec![0.0f32; k * m];
            spmm_isa(isa, &sv, &x, k, &mut y1);
            close(&y0, &y1);
            let mut z1 = vec![0.0f32; k * n];
            spmm_t_isa(isa, &sv, &v, k, &mut z1);
            close(&z0, &z1);

            // k == 1 bit-identical to the single-vector kernels
            let (mut s0, mut s1) = (vec![0.0f32; m], vec![0.0f32; m]);
            spmv_isa(isa, &sv, &x[..n], &mut s0);
            spmm_isa(isa, &sv, &x[..n], 1, &mut s1);
            assert_eq!(s0, s1, "{}", isa.name());
            let (mut t0, mut t1) = (vec![0.0f32; n], vec![0.0f32; n]);
            spmv_t_isa(isa, &sv, &v[..m], &mut t0);
            spmm_t_isa(isa, &sv, &v[..m], 1, &mut t1);
            assert_eq!(t0, t1, "{}", isa.name());
        }
    }

    #[test]
    fn gram_accumulates_across_calls() {
        let mut rng = Rng::seed_from(5);
        let a = rand_sparse(&mut rng, 10, 6, 0.4);
        let c = CsrMatrix::from_dense(&a);
        let ranges = c.block_ranges(0, 6);
        let sv = c.block_view(&ranges, 0, 6);
        let mut g1 = vec![0.0f32; 36];
        gram_sparse(&sv, &mut g1);
        let once = g1.clone();
        gram_sparse(&sv, &mut g1);
        let doubled: Vec<f32> = once.iter().map(|&x| 2.0 * x).collect();
        close(&doubled, &g1);
    }

    #[test]
    fn all_zero_rows_and_columns() {
        // row 1 and column 2 entirely zero
        let a = Matrix::from_rows(vec![
            vec![1.0, 0.0, 0.0, 2.0],
            vec![0.0, 0.0, 0.0, 0.0],
            vec![0.0, 3.0, 0.0, -1.0],
        ]);
        let c = CsrMatrix::from_dense(&a);
        let ranges = c.block_ranges(0, 4);
        let sv = c.block_view(&ranges, 0, 4);
        for isa in crate::linalg::simd::supported() {
            let mut y = vec![9.0f32; 3];
            spmv_isa(isa, &sv, &[1.0, 1.0, 1.0, 1.0], &mut y);
            assert_eq!(y, vec![3.0, 0.0, 2.0], "{}", isa.name());
            let mut z = vec![9.0f32; 4];
            spmv_t_isa(isa, &sv, &[1.0, 1.0, 1.0], &mut z);
            assert_eq!(z, vec![1.0, 3.0, 0.0, 1.0], "{}", isa.name());
        }
    }

    #[test]
    fn empty_matrix_is_degenerate_but_defined() {
        let c = CsrMatrix::from_dense(&Matrix::zeros(0, 4));
        let ranges = c.block_ranges(0, 4);
        let sv = c.block_view(&ranges, 0, 4);
        let x = [1.0f32; 4];
        let mut y: Vec<f32> = Vec::new();
        spmv(&sv, &x, &mut y);
        let mut z = [9.0f32; 4];
        spmv_t(&sv, &[], &mut z);
        assert_eq!(z, [0.0; 4]);
        let mut g = vec![0.0f32; 16];
        gram_sparse(&sv, &mut g);
        assert!(g.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn block_ranges_cover_disjointly() {
        let mut rng = Rng::seed_from(6);
        let a = rand_sparse(&mut rng, 12, 10, 0.5);
        let c = CsrMatrix::from_dense(&a);
        // blocks [0,4), [4,7), [7,10) must partition every row's real
        // entries (padding sits outside the covered bounds)
        let r0 = c.block_ranges(0, 4);
        let r1 = c.block_ranges(4, 3);
        let r2 = c.block_ranges(7, 3);
        for i in 0..12 {
            let (rs, re) = c.row_bounds(i);
            assert_eq!(r0[i].0, rs);
            assert_eq!(r0[i].1, r1[i].0);
            assert_eq!(r1[i].1, r2[i].0);
            assert_eq!(r2[i].1, re);
        }
    }

    #[test]
    fn row_lanes_pads_full_runs_and_not_partial_ones() {
        let mut rng = Rng::seed_from(7);
        let a = rand_sparse(&mut rng, 6, 20, 0.9);
        let c = CsrMatrix::from_dense(&a);
        // full-width view: every row qualifies for the padded fast path
        let full = c.block_ranges(0, 20);
        let sv = c.block_view(&full, 0, 20);
        for i in 0..6 {
            let (cols, vals) = sv.row_lanes(i);
            let real = sv.row(i).0.len();
            if real > 0 {
                assert_eq!(cols.len() % SIMD_PAD, 0, "row {i}");
                assert!(vals[real..].iter().all(|&v| v == 0.0));
            }
        }
        // a mid-row block gets the exact subrange
        let part = c.block_ranges(5, 6);
        let pv = c.block_view(&part, 5, 6);
        for i in 0..6 {
            let lanes = pv.row_lanes(i).0.len();
            let real = pv.row(i).0.len();
            let (rs, re) = c.row_bounds(i);
            let covers_full_run = part[i] == (rs, re);
            if !covers_full_run {
                assert_eq!(lanes, real, "row {i}");
            }
        }
    }
}

//! Compressed-sparse-row storage and kernels for the sparse data path.
//!
//! The paper's target workloads (text, one-hot, genomics) are
//! overwhelmingly zero-valued, so the dense kernels in [`super::kernels`]
//! burn O(m n) work regardless of density.  [`CsrMatrix`] stores only the
//! nonzeros; the kernels here are the sparse twins of the dense layer:
//!
//!   * `spmv`        — y = A x          (twin of `kernels::matvec`)
//!   * `spmv_t`      — y = A^T v        (twin of `kernels::matvec_t`)
//!   * `spmm`        — Y = A X, k RHS   (twin of `kernels::matmul`)
//!   * `spmm_t`      — Y = A^T V, k RHS (twin of `kernels::matmul_t`)
//!   * `gram_sparse` — G += A^T A       (twin of `kernels::gram`)
//!
//! Each has a `_naive` reference twin mirroring the `kernels.rs` contract,
//! pinned against it by the property tests and timed by `psfit bench`.
//!
//! Feature blocks are read **in place** through [`CsrBlockView`] — the
//! sparse twin of [`super::kernels::ColumnBlockView`].  Because column
//! indices are sorted within each row, the entries of a contiguous column
//! block `[col0, col0 + width)` form one contiguous subrange of every
//! row's entry list; a block view is just those per-row subranges,
//! computed once (binary search per row) and reused for every sweep.
//!
//! Determinism contract: identical to the dense layer — kernels are
//! single-threaded, their summation order is a fixed function of the
//! stored entry order, so results are bit-identical from run to run and
//! at any worker-pool width.  (Sparse and *dense* kernels sum in
//! different orders, so cross-storage agreement is to rounding, not bits
//! — the parity tests use 1e-5 like the tiled-vs-naive pins.)

use super::matrix::Matrix;

/// Row-major compressed sparse rows: row `i`'s entries live at
/// `col_idx[row_ptr[i]..row_ptr[i+1]]` / `vals[..]`, column indices
/// strictly increasing within a row.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    /// Row count.
    pub rows: usize,
    /// Column count (logical width; trailing all-zero columns allowed).
    pub cols: usize,
    /// `rows + 1` offsets into `col_idx` / `vals`.
    pub row_ptr: Vec<usize>,
    /// Column index of every stored entry, strictly increasing per row.
    pub col_idx: Vec<u32>,
    /// Value of every stored entry (explicit zeros allowed).
    pub vals: Vec<f32>,
}

impl CsrMatrix {
    /// Build from per-row (column, value) entry lists.  Entries must have
    /// strictly increasing columns within each row; zeros may be stored
    /// explicitly (the LIBSVM reader keeps whatever the file says).
    pub fn from_rows(cols: usize, rows: Vec<Vec<(u32, f32)>>) -> CsrMatrix {
        let mut row_ptr = Vec::with_capacity(rows.len() + 1);
        row_ptr.push(0usize);
        let nnz: usize = rows.iter().map(|r| r.len()).sum();
        let mut col_idx = Vec::with_capacity(nnz);
        let mut vals = Vec::with_capacity(nnz);
        for row in &rows {
            let mut prev: Option<u32> = None;
            for &(c, v) in row {
                assert!((c as usize) < cols, "column {c} out of range {cols}");
                if let Some(p) = prev {
                    assert!(c > p, "columns must increase within a row");
                }
                prev = Some(c);
                col_idx.push(c);
                vals.push(v);
            }
            row_ptr.push(col_idx.len());
        }
        CsrMatrix {
            rows: rows.len(),
            cols,
            row_ptr,
            col_idx,
            vals,
        }
    }

    /// Compress a dense matrix (exact: every nonzero entry kept).
    pub fn from_dense(a: &Matrix) -> CsrMatrix {
        let mut row_ptr = Vec::with_capacity(a.rows + 1);
        row_ptr.push(0usize);
        let mut col_idx = Vec::new();
        let mut vals = Vec::new();
        for i in 0..a.rows {
            for (j, &v) in a.row(i).iter().enumerate() {
                if v != 0.0 {
                    col_idx.push(j as u32);
                    vals.push(v);
                }
            }
            row_ptr.push(col_idx.len());
        }
        CsrMatrix {
            rows: a.rows,
            cols: a.cols,
            row_ptr,
            col_idx,
            vals,
        }
    }

    /// Expand back to dense (bit-exact: values are copied, not recomputed).
    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let row = &mut out.data[i * self.cols..(i + 1) * self.cols];
            for (&c, &v) in cols.iter().zip(vals) {
                row[c as usize] = v;
            }
        }
        out
    }

    /// Stored entries (including any explicit zeros).
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Stored-entry fraction in [0, 1] (1.0 for an empty matrix so the
    /// storage policy never picks CSR for degenerate shapes).
    pub fn density(&self) -> f64 {
        let size = self.rows * self.cols;
        if size == 0 {
            1.0
        } else {
            self.nnz() as f64 / size as f64
        }
    }

    /// Row `i`'s entries: (column indices, values).
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (s, e) = (self.row_ptr[i], self.row_ptr[i + 1]);
        (&self.col_idx[s..e], &self.vals[s..e])
    }

    /// Per-row entry subranges covering columns `[col0, col0 + width)` —
    /// the precomputation behind [`CsrBlockView`].  O(rows log nnz_row),
    /// done once per feature block at backend construction.
    pub fn block_ranges(&self, col0: usize, width: usize) -> Vec<(usize, usize)> {
        assert!(col0 + width <= self.cols, "column block out of range");
        let (lo, hi) = (col0 as u32, (col0 + width) as u32);
        (0..self.rows)
            .map(|i| {
                let (s, e) = (self.row_ptr[i], self.row_ptr[i + 1]);
                let cols = &self.col_idx[s..e];
                let a = s + cols.partition_point(|&c| c < lo);
                let b = s + cols.partition_point(|&c| c < hi);
                (a, b)
            })
            .collect()
    }

    /// Borrowed view of the column block `[col0, col0 + width)` through
    /// precomputed `ranges` (from [`CsrMatrix::block_ranges`] with the
    /// same `col0` / `width`).
    pub fn block_view<'a>(
        &'a self,
        ranges: &'a [(usize, usize)],
        col0: usize,
        width: usize,
    ) -> CsrBlockView<'a> {
        assert_eq!(ranges.len(), self.rows);
        assert!(col0 + width <= self.cols);
        CsrBlockView {
            rows: self.rows,
            cols: width,
            col0: col0 as u32,
            ranges,
            col_idx: &self.col_idx,
            vals: &self.vals,
        }
    }

    /// y = A x over the whole matrix (convenience for the storage enum).
    pub fn spmv(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for (i, yi) in y.iter_mut().enumerate() {
            let (cols, vals) = self.row(i);
            *yi = dot_sparse(cols, vals, 0, x);
        }
    }

    /// y = A^T v over the whole matrix.
    pub fn spmv_t(&self, v: &[f32], y: &mut [f32]) {
        assert_eq!(v.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        y.fill(0.0);
        for (i, &vi) in v.iter().enumerate() {
            let (cols, vals) = self.row(i);
            for (&c, &a) in cols.iter().zip(vals) {
                y[c as usize] += a * vi;
            }
        }
    }
}

/// Borrowed view of the contiguous column block `[col0, col0 + cols)` of a
/// [`CsrMatrix`] — the sparse twin of `ColumnBlockView`.  Column indices
/// are rebased by `col0` on read, so kernels see block-local columns.
#[derive(Clone, Copy, Debug)]
pub struct CsrBlockView<'a> {
    rows: usize,
    cols: usize,
    col0: u32,
    /// Per-row `[start, end)` into `col_idx` / `vals`.
    ranges: &'a [(usize, usize)],
    col_idx: &'a [u32],
    vals: &'a [f32],
}

impl<'a> CsrBlockView<'a> {
    /// Rows of the viewed block (same as the parent matrix).
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Columns (block width) of the viewed block.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row `i`'s entries within the block: (parent column indices, values).
    /// Subtract [`CsrBlockView::col0`] for block-local columns.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (s, e) = self.ranges[i];
        (&self.col_idx[s..e], &self.vals[s..e])
    }

    /// First parent column of the block (subtract from `row` indices for
    /// block-local columns).
    #[inline]
    pub fn col0(&self) -> u32 {
        self.col0
    }

    /// Stored entries inside the block.
    pub fn nnz(&self) -> usize {
        self.ranges.iter().map(|&(s, e)| e - s).sum()
    }
}

/// Sparse dot of one row's block entries against a dense vector indexed by
/// block-local column.  Four independent accumulators, fixed reduction
/// order `((a0 + a1) + (a2 + a3)) + tail` — the sparse analogue of the
/// dense `dot4` determinism contract.
#[inline]
fn dot_sparse(cols: &[u32], vals: &[f32], col0: u32, x: &[f32]) -> f32 {
    let mut acc = [0.0f32; 4];
    let mut cc = cols.chunks_exact(4);
    let mut cv = vals.chunks_exact(4);
    for (c4, v4) in (&mut cc).zip(&mut cv) {
        acc[0] += v4[0] * x[(c4[0] - col0) as usize];
        acc[1] += v4[1] * x[(c4[1] - col0) as usize];
        acc[2] += v4[2] * x[(c4[2] - col0) as usize];
        acc[3] += v4[3] * x[(c4[3] - col0) as usize];
    }
    let mut tail = 0.0f32;
    for (&c, &v) in cc.remainder().iter().zip(cv.remainder()) {
        tail += v * x[(c - col0) as usize];
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + tail
}

// ------------------------------------------------------------------- spmv

/// y = A x — naive reference (plain per-entry accumulation, single
/// accumulator, mirroring `matvec_naive`).
pub fn spmv_naive(a: &CsrBlockView, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), a.cols());
    assert_eq!(y.len(), a.rows());
    let col0 = a.col0();
    for (i, yi) in y.iter_mut().enumerate() {
        let (cols, vals) = a.row(i);
        let mut acc = 0.0f32;
        for (&c, &v) in cols.iter().zip(vals) {
            acc += v * x[(c - col0) as usize];
        }
        *yi = acc;
    }
}

/// y = A x — unroll-by-4 sparse row dot.
pub fn spmv(a: &CsrBlockView, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), a.cols());
    assert_eq!(y.len(), a.rows());
    let col0 = a.col0();
    for (i, yi) in y.iter_mut().enumerate() {
        let (cols, vals) = a.row(i);
        *yi = dot_sparse(cols, vals, col0, x);
    }
}

/// Y = A X for `k` right-hand sides — naive reference (k naive spmv).
/// Layouts match the dense twins: `x` is `k` class-major vectors of
/// length `cols`, `y` is `k` vectors of length `rows`.
pub fn spmm_naive(a: &CsrBlockView, x: &[f32], k: usize, y: &mut [f32]) {
    let (m, n) = (a.rows(), a.cols());
    assert_eq!(x.len(), k * n);
    assert_eq!(y.len(), k * m);
    for r in 0..k {
        spmv_naive(a, &x[r * n..(r + 1) * n], &mut y[r * m..(r + 1) * m]);
    }
}

/// Y = A X for `k` right-hand sides — each row's entries are loaded once
/// and dotted against all `k` vectors while hot (the sparse analogue of
/// the multiclass batching in `matmul`).
pub fn spmm(a: &CsrBlockView, x: &[f32], k: usize, y: &mut [f32]) {
    let (m, n) = (a.rows(), a.cols());
    assert_eq!(x.len(), k * n);
    assert_eq!(y.len(), k * m);
    let col0 = a.col0();
    for i in 0..m {
        let (cols, vals) = a.row(i);
        for r in 0..k {
            y[r * m + i] = dot_sparse(cols, vals, col0, &x[r * n..(r + 1) * n]);
        }
    }
}

// ----------------------------------------------------------------- spmv_t

/// y = A^T v — naive reference (per-row scatter with the historical
/// skip-zero branch, mirroring `matvec_t_naive`).
pub fn spmv_t_naive(a: &CsrBlockView, v: &[f32], y: &mut [f32]) {
    assert_eq!(v.len(), a.rows());
    assert_eq!(y.len(), a.cols());
    let col0 = a.col0();
    y.fill(0.0);
    for (i, &vi) in v.iter().enumerate() {
        if vi == 0.0 {
            continue;
        }
        let (cols, vals) = a.row(i);
        for (&c, &aij) in cols.iter().zip(vals) {
            y[(c - col0) as usize] += aij * vi;
        }
    }
}

/// y = A^T v — branch-free per-row scatter (the per-iteration
/// data-touching op of the inner sweep on sparse shards).
pub fn spmv_t(a: &CsrBlockView, v: &[f32], y: &mut [f32]) {
    spmm_t(a, v, 1, y)
}

/// Y = A^T V for `k` vectors — naive reference (k naive spmv_t).
pub fn spmm_t_naive(a: &CsrBlockView, v: &[f32], k: usize, y: &mut [f32]) {
    let (m, n) = (a.rows(), a.cols());
    assert_eq!(v.len(), k * m);
    assert_eq!(y.len(), k * n);
    for r in 0..k {
        spmv_t_naive(a, &v[r * m..(r + 1) * m], &mut y[r * n..(r + 1) * n]);
    }
}

/// Y = A^T V for `k` vectors — each row's entries are read once and
/// scattered into all `k` accumulations.
pub fn spmm_t(a: &CsrBlockView, v: &[f32], k: usize, y: &mut [f32]) {
    let (m, n) = (a.rows(), a.cols());
    assert_eq!(v.len(), k * m);
    assert_eq!(y.len(), k * n);
    let col0 = a.col0();
    y.fill(0.0);
    for i in 0..m {
        let (cols, vals) = a.row(i);
        if cols.is_empty() {
            continue;
        }
        for r in 0..k {
            let vi = v[r * m + i];
            let yr = &mut y[r * n..(r + 1) * n];
            for (&c, &aij) in cols.iter().zip(vals) {
                yr[(c - col0) as usize] += aij * vi;
            }
        }
    }
}

// ------------------------------------------------------------ gram_sparse

/// G += A^T A — naive reference (per-row pair accumulation with the
/// historical skip-zero branch; upper triangle mirrored, composing across
/// calls exactly like `gram_naive`).
pub fn gram_sparse_naive(a: &CsrBlockView, g: &mut [f32]) {
    let n = a.cols();
    assert_eq!(g.len(), n * n);
    let col0 = a.col0();
    for i in 0..a.rows() {
        let (cols, vals) = a.row(i);
        for (p, &cp) in cols.iter().enumerate() {
            let ap = vals[p];
            if ap == 0.0 {
                continue;
            }
            let j = (cp - col0) as usize;
            let grow = &mut g[j * n..(j + 1) * n];
            for (&cq, &aq) in cols[p..].iter().zip(&vals[p..]) {
                grow[(cq - col0) as usize] += ap * aq;
            }
        }
    }
    mirror_upper(g, n);
}

/// G += A^T A — branch-free per-row pair accumulation.  Each stored row
/// contributes O(nnz_row^2) work instead of the dense O(n^2); upper
/// triangle computed then mirrored (mirroring only copies, so
/// accumulating across calls composes).
pub fn gram_sparse(a: &CsrBlockView, g: &mut [f32]) {
    let n = a.cols();
    assert_eq!(g.len(), n * n);
    let col0 = a.col0();
    for i in 0..a.rows() {
        let (cols, vals) = a.row(i);
        for (p, &cp) in cols.iter().enumerate() {
            let ap = vals[p];
            let j = (cp - col0) as usize;
            let grow = &mut g[j * n..(j + 1) * n];
            for (&cq, &aq) in cols[p..].iter().zip(&vals[p..]) {
                grow[(cq - col0) as usize] += ap * aq;
            }
        }
    }
    mirror_upper(g, n);
}

fn mirror_upper(g: &mut [f32], n: usize) {
    for j in 0..n {
        for k in (j + 1)..n {
            g[k * n + j] = g[j * n + k];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::kernels;
    use crate::util::rng::Rng;

    /// Random dense matrix with ~`density` nonzero fraction.
    fn rand_sparse(rng: &mut Rng, m: usize, n: usize, density: f64) -> Matrix {
        let mut a = Matrix::zeros(m, n);
        rng.fill_normal_f32(&mut a.data);
        for v in a.data.iter_mut() {
            if rng.uniform() >= density {
                *v = 0.0;
            }
        }
        a
    }

    fn close(a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            let scale = 1.0f32.max(x.abs()).max(y.abs());
            assert!((x - y).abs() <= 1e-5 * scale, "elem {i}: {x} vs {y}");
        }
    }

    #[test]
    fn dense_roundtrip_is_exact() {
        let mut rng = Rng::seed_from(1);
        for (m, n, d) in [(7, 9, 0.3), (4, 4, 0.0), (5, 3, 1.0), (0, 6, 0.5)] {
            let a = rand_sparse(&mut rng, m, n, d);
            let c = CsrMatrix::from_dense(&a);
            assert_eq!(c.to_dense(), a);
            assert_eq!(c.nnz(), a.data.iter().filter(|&&v| v != 0.0).count());
        }
    }

    #[test]
    fn density_counts_stored_entries() {
        let a = Matrix::from_rows(vec![vec![1.0, 0.0], vec![0.0, 2.0]]);
        let c = CsrMatrix::from_dense(&a);
        assert!((c.density() - 0.5).abs() < 1e-12);
        let empty = CsrMatrix::from_dense(&Matrix::zeros(0, 3));
        assert_eq!(empty.density(), 1.0);
    }

    #[test]
    fn whole_matrix_spmv_matches_dense() {
        let mut rng = Rng::seed_from(2);
        let a = rand_sparse(&mut rng, 13, 7, 0.4);
        let c = CsrMatrix::from_dense(&a);
        let x: Vec<f32> = (0..7).map(|_| rng.normal_f32()).collect();
        let v: Vec<f32> = (0..13).map(|_| rng.normal_f32()).collect();
        let (mut y0, mut y1) = (vec![0.0f32; 13], vec![0.0f32; 13]);
        a.matvec(&x, &mut y0);
        c.spmv(&x, &mut y1);
        close(&y0, &y1);
        let (mut z0, mut z1) = (vec![0.0f32; 7], vec![0.0f32; 7]);
        a.matvec_t(&v, &mut z0);
        c.spmv_t(&v, &mut z1);
        close(&z0, &z1);
    }

    #[test]
    fn block_kernels_match_dense_views() {
        let mut rng = Rng::seed_from(3);
        // non-multiple-of-4 shapes; includes an empty (zero-entry) block
        for (m, n, col0, w, d) in [
            (9, 11, 3, 5, 0.3),
            (6, 7, 0, 7, 0.1),
            (14, 10, 4, 3, 0.0),
            (5, 8, 6, 2, 1.0),
        ] {
            let a = rand_sparse(&mut rng, m, n, d);
            let c = CsrMatrix::from_dense(&a);
            let ranges = c.block_ranges(col0, w);
            let sv = c.block_view(&ranges, col0, w);
            let dv = a.column_block_view(col0, w);

            let x: Vec<f32> = (0..w).map(|_| rng.normal_f32()).collect();
            let v: Vec<f32> = (0..m).map(|_| rng.normal_f32()).collect();
            let (mut y0, mut y1) = (vec![0.0f32; m], vec![0.0f32; m]);
            kernels::matvec(&dv, &x, &mut y0);
            spmv(&sv, &x, &mut y1);
            close(&y0, &y1);
            spmv_naive(&sv, &x, &mut y1);
            close(&y0, &y1);

            let (mut z0, mut z1) = (vec![0.0f32; w], vec![0.0f32; w]);
            kernels::matvec_t(&dv, &v, &mut z0);
            spmv_t(&sv, &v, &mut z1);
            close(&z0, &z1);
            spmv_t_naive(&sv, &v, &mut z1);
            close(&z0, &z1);

            let (mut g0, mut g1) = (vec![0.0f32; w * w], vec![0.0f32; w * w]);
            kernels::gram(&dv, &mut g0);
            gram_sparse(&sv, &mut g1);
            close(&g0, &g1);
            g1.fill(0.0);
            gram_sparse_naive(&sv, &mut g1);
            close(&g0, &g1);
        }
    }

    #[test]
    fn multi_rhs_matches_naive_and_k1_is_bit_identical() {
        let mut rng = Rng::seed_from(4);
        let (m, n, k) = (14, 6, 3);
        let a = rand_sparse(&mut rng, m, n, 0.35);
        let c = CsrMatrix::from_dense(&a);
        let ranges = c.block_ranges(0, n);
        let sv = c.block_view(&ranges, 0, n);
        let x: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let v: Vec<f32> = (0..k * m).map(|_| rng.normal_f32()).collect();

        let (mut y0, mut y1) = (vec![0.0f32; k * m], vec![0.0f32; k * m]);
        spmm_naive(&sv, &x, k, &mut y0);
        spmm(&sv, &x, k, &mut y1);
        close(&y0, &y1);
        let (mut z0, mut z1) = (vec![0.0f32; k * n], vec![0.0f32; k * n]);
        spmm_t_naive(&sv, &v, k, &mut z0);
        spmm_t(&sv, &v, k, &mut z1);
        close(&z0, &z1);

        // k == 1 bit-identical to the single-vector kernels
        let (mut s0, mut s1) = (vec![0.0f32; m], vec![0.0f32; m]);
        spmv(&sv, &x[..n], &mut s0);
        spmm(&sv, &x[..n], 1, &mut s1);
        assert_eq!(s0, s1);
        let (mut t0, mut t1) = (vec![0.0f32; n], vec![0.0f32; n]);
        spmv_t(&sv, &v[..m], &mut t0);
        spmm_t(&sv, &v[..m], 1, &mut t1);
        assert_eq!(t0, t1);
    }

    #[test]
    fn gram_accumulates_across_calls() {
        let mut rng = Rng::seed_from(5);
        let a = rand_sparse(&mut rng, 10, 6, 0.4);
        let c = CsrMatrix::from_dense(&a);
        let ranges = c.block_ranges(0, 6);
        let sv = c.block_view(&ranges, 0, 6);
        let mut g1 = vec![0.0f32; 36];
        gram_sparse(&sv, &mut g1);
        let once = g1.clone();
        gram_sparse(&sv, &mut g1);
        let doubled: Vec<f32> = once.iter().map(|&x| 2.0 * x).collect();
        close(&doubled, &g1);
    }

    #[test]
    fn all_zero_rows_and_columns() {
        // row 1 and column 2 entirely zero
        let a = Matrix::from_rows(vec![
            vec![1.0, 0.0, 0.0, 2.0],
            vec![0.0, 0.0, 0.0, 0.0],
            vec![0.0, 3.0, 0.0, -1.0],
        ]);
        let c = CsrMatrix::from_dense(&a);
        let ranges = c.block_ranges(0, 4);
        let sv = c.block_view(&ranges, 0, 4);
        let mut y = vec![9.0f32; 3];
        spmv(&sv, &[1.0, 1.0, 1.0, 1.0], &mut y);
        assert_eq!(y, vec![3.0, 0.0, 2.0]);
        let mut z = vec![9.0f32; 4];
        spmv_t(&sv, &[1.0, 1.0, 1.0], &mut z);
        assert_eq!(z, vec![1.0, 3.0, 0.0, 1.0]);
    }

    #[test]
    fn empty_matrix_is_degenerate_but_defined() {
        let c = CsrMatrix::from_dense(&Matrix::zeros(0, 4));
        let ranges = c.block_ranges(0, 4);
        let sv = c.block_view(&ranges, 0, 4);
        let x = [1.0f32; 4];
        let mut y: Vec<f32> = Vec::new();
        spmv(&sv, &x, &mut y);
        let mut z = [9.0f32; 4];
        spmv_t(&sv, &[], &mut z);
        assert_eq!(z, [0.0; 4]);
        let mut g = vec![0.0f32; 16];
        gram_sparse(&sv, &mut g);
        assert!(g.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn block_ranges_cover_disjointly() {
        let mut rng = Rng::seed_from(6);
        let a = rand_sparse(&mut rng, 12, 10, 0.5);
        let c = CsrMatrix::from_dense(&a);
        // blocks [0,4), [4,7), [7,10) must partition every row's entries
        let r0 = c.block_ranges(0, 4);
        let r1 = c.block_ranges(4, 3);
        let r2 = c.block_ranges(7, 3);
        for i in 0..12 {
            assert_eq!(r0[i].0, c.row_ptr[i]);
            assert_eq!(r0[i].1, r1[i].0);
            assert_eq!(r1[i].1, r2[i].0);
            assert_eq!(r2[i].1, c.row_ptr[i + 1]);
        }
    }
}

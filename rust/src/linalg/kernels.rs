//! Dense f32 kernels for the data-touching ops of the inner sweep:
//! `A_j^T corr` (transposed matvec), `A_j x_j` (matvec), the multi-vector
//! forms of both (all class columns at once), and the Gram setup
//! `A_j^T A_j`.
//!
//! Every public kernel is a **runtime-ISA-dispatched** entry point: it
//! routes through [`super::simd::active`] to an explicit AVX2+FMA or NEON
//! variant when the host (and the `platform.isa` / `PSFIT_ISA` knobs)
//! allow, and otherwise to the cache-tiled unroll-by-4 scalar kernels in
//! this file — the guaranteed fallback, bit-identical to the historical
//! implementation.  `foo_isa(isa, ...)` pins a specific variant (the
//! parity tests and `psfit bench` time them side by side); `foo(...)` is
//! `foo_isa(active(), ...)`.
//!
//! Every kernel is stride-aware: it reads its operand through a borrowed
//! [`ColumnBlockView`], so a feature block of a shard is consumed **in
//! place** — no packed per-block copy (the paper's feature decomposition
//! becomes a view, not a memcpy; `backend::native` reports the bytes this
//! saves in its transfer ledger).  Since the aligned-storage change,
//! `Matrix` rows are padded to 64-byte lanes, so whole-matrix views carry
//! a `row_stride >= cols` and every row start is cache-line aligned.
//!
//! Determinism contract: kernels are single-threaded and, *per ISA*, their
//! summation order is a fixed function of the view shape, so results are
//! bit-identical from run to run and at any worker-pool width (threading
//! happens per *block* in `util::pool`, above this layer, never inside a
//! kernel).  The multi-vector kernels visit each output element in the
//! same order as their single-vector counterparts, so the `k == 1` case
//! is bit-identical to `matvec` / `matvec_t` under the same ISA.
//! *Across* ISAs the summation orders differ (and FMA fuses the rounding),
//! so cross-ISA agreement is the 1e-5 contract below, like the twins.
//!
//! The `_naive` twin convention: every optimized kernel `foo` ships with
//! a `foo_naive` reference implementing the same contract with the
//! simplest possible loop.  The twins use *different* summation orders,
//! so they agree only to rounding — the property tests (and the CSR
//! kernels in [`super::csr`], which follow the same convention) pin
//! `|optimized - naive| <= 1e-5 * max(1, |value|)` element-wise, the
//! crate-wide kernel tolerance.

use super::simd::{self, Isa};

/// Borrowed view of the contiguous column range `[col0, col0 + cols)` of a
/// row-major matrix — the paper's feature block `A_j`, read in place.  A
/// whole matrix is the case `col0 == 0` with `row_stride` equal to the
/// matrix's (padded) stride.
#[derive(Clone, Copy, Debug)]
pub struct ColumnBlockView<'a> {
    /// Parent storage, offset so row `i` starts at `i * row_stride`.
    data: &'a [f32],
    rows: usize,
    cols: usize,
    row_stride: usize,
}

impl<'a> ColumnBlockView<'a> {
    /// View columns `[col0, col0 + cols)` of a row-major buffer with
    /// `row_stride` elements per row.
    pub fn new(
        data: &'a [f32],
        rows: usize,
        cols: usize,
        row_stride: usize,
        col0: usize,
    ) -> ColumnBlockView<'a> {
        assert!(col0 + cols <= row_stride, "column range exceeds stride");
        if rows == 0 {
            return ColumnBlockView {
                data: &data[..0],
                rows: 0,
                cols,
                row_stride,
            };
        }
        assert!(
            data.len() >= (rows - 1) * row_stride + col0 + cols,
            "buffer too short for {rows} rows of stride {row_stride}"
        );
        ColumnBlockView {
            data: &data[col0..],
            rows,
            cols,
            row_stride,
        }
    }

    /// Rows of the viewed block.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Columns of the viewed block.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Elements per stored row of the parent buffer.
    #[inline]
    pub fn row_stride(&self) -> usize {
        self.row_stride
    }

    /// Row `i` of the viewed block (length `cols`).
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.row_stride..i * self.row_stride + self.cols]
    }
}

/// Scalar remainder dot product — the single shared tail helper for every
/// dense path (the unroll-by-4 scalar kernels and the SIMD variants both
/// finish their sub-lane remainders here, in the same left-to-right
/// order, instead of re-implementing the loop per kernel).
#[inline]
pub(crate) fn dot_tail(a: &[f32], b: &[f32]) -> f32 {
    let mut tail = 0.0f32;
    for (&x, &y) in a.iter().zip(b) {
        tail += x * y;
    }
    tail
}

/// Unroll-by-4 dot product with four independent accumulators.  The fixed
/// reduction order `((a0 + a1) + (a2 + a3)) + tail` is part of the
/// determinism contract.
#[inline]
fn dot4(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0.0f32; 4];
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for (a4, b4) in (&mut ca).zip(&mut cb) {
        acc[0] += a4[0] * b4[0];
        acc[1] += a4[1] * b4[1];
        acc[2] += a4[2] * b4[2];
        acc[3] += a4[3] * b4[3];
    }
    let tail = dot_tail(ca.remainder(), cb.remainder());
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + tail
}

// ------------------------------------------------------------------ matvec

/// y = A x — naive reference (plain per-row dot, single accumulator).
pub fn matvec_naive(a: &ColumnBlockView, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), a.cols());
    assert_eq!(y.len(), a.rows());
    for (i, yi) in y.iter_mut().enumerate() {
        let mut acc = 0.0f32;
        for (&aij, &xj) in a.row(i).iter().zip(x) {
            acc += aij * xj;
        }
        *yi = acc;
    }
}

/// y = A x — tiled-scalar variant (unroll-by-4 per-row dot).
fn matvec_scalar(a: &ColumnBlockView, x: &[f32], y: &mut [f32]) {
    for (i, yi) in y.iter_mut().enumerate() {
        *yi = dot4(a.row(i), x);
    }
}

/// y = A x under a pinned ISA variant (panics if `isa` is unavailable on
/// this host — iterate [`simd::supported`] when probing).
pub fn matvec_isa(isa: Isa, a: &ColumnBlockView, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), a.cols());
    assert_eq!(y.len(), a.rows());
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { simd::avx2::matvec(a, x, y) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { simd::neon::matvec(a, x, y) },
        Isa::Scalar => matvec_scalar(a, x, y),
        #[allow(unreachable_patterns)]
        other => panic!("isa {} not available on this host", other.name()),
    }
}

/// y = A x — dispatched to the active ISA.
pub fn matvec(a: &ColumnBlockView, x: &[f32], y: &mut [f32]) {
    matvec_isa(simd::active(), a, x, y)
}

/// Y = A X for `k` right-hand sides — naive reference (k naive matvecs).
/// `x` is `k` vectors of length `cols` stored contiguously (class-major);
/// `y` is `k` vectors of length `rows`.
pub fn matmul_naive(a: &ColumnBlockView, x: &[f32], k: usize, y: &mut [f32]) {
    let (m, n) = (a.rows(), a.cols());
    assert_eq!(x.len(), k * n);
    assert_eq!(y.len(), k * m);
    for r in 0..k {
        matvec_naive(a, &x[r * n..(r + 1) * n], &mut y[r * m..(r + 1) * m]);
    }
}

/// Y = A X — tiled-scalar variant: each A row is loaded once and dotted
/// against all `k` vectors while hot (the multi-class batching the
/// softmax path uses instead of re-running per class column).
fn matmul_scalar(a: &ColumnBlockView, x: &[f32], k: usize, y: &mut [f32]) {
    let (m, n) = (a.rows(), a.cols());
    for i in 0..m {
        let row = a.row(i);
        for r in 0..k {
            y[r * m + i] = dot4(row, &x[r * n..(r + 1) * n]);
        }
    }
}

/// Y = A X for `k` right-hand sides under a pinned ISA variant.
pub fn matmul_isa(isa: Isa, a: &ColumnBlockView, x: &[f32], k: usize, y: &mut [f32]) {
    let (m, n) = (a.rows(), a.cols());
    assert_eq!(x.len(), k * n);
    assert_eq!(y.len(), k * m);
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { simd::avx2::matmul(a, x, k, y) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { simd::neon::matmul(a, x, k, y) },
        Isa::Scalar => matmul_scalar(a, x, k, y),
        #[allow(unreachable_patterns)]
        other => panic!("isa {} not available on this host", other.name()),
    }
}

/// Y = A X for `k` right-hand sides — dispatched to the active ISA.  The
/// `k == 1` case is bit-identical to [`matvec`] under every ISA (shared
/// per-row dot).
pub fn matmul(a: &ColumnBlockView, x: &[f32], k: usize, y: &mut [f32]) {
    matmul_isa(simd::active(), a, x, k, y)
}

// ---------------------------------------------------------------- matvec_t

/// y = A^T v — naive reference (per-row axpy with the historical
/// skip-zero branch).
pub fn matvec_t_naive(a: &ColumnBlockView, v: &[f32], y: &mut [f32]) {
    assert_eq!(v.len(), a.rows());
    assert_eq!(y.len(), a.cols());
    y.fill(0.0);
    for (i, &vi) in v.iter().enumerate() {
        if vi == 0.0 {
            continue;
        }
        for (yj, &aij) in y.iter_mut().zip(a.row(i)) {
            *yj += aij * vi;
        }
    }
}

/// y = A^T v under a pinned ISA variant (4-row tiles shared with
/// [`matmul_t_isa`], so `k == 1` stays bit-identical).
pub fn matvec_t_isa(isa: Isa, a: &ColumnBlockView, v: &[f32], y: &mut [f32]) {
    matmul_t_isa(isa, a, v, 1, y)
}

/// y = A^T v — dispatched to the active ISA.
pub fn matvec_t(a: &ColumnBlockView, v: &[f32], y: &mut [f32]) {
    matmul_t_isa(simd::active(), a, v, 1, y)
}

/// Y = A^T V for `k` vectors — naive reference (k naive matvec_t).
/// `v` is `k` vectors of length `rows` stored contiguously; `y` is `k`
/// vectors of length `cols`.
pub fn matmul_t_naive(a: &ColumnBlockView, v: &[f32], k: usize, y: &mut [f32]) {
    let (m, n) = (a.rows(), a.cols());
    assert_eq!(v.len(), k * m);
    assert_eq!(y.len(), k * n);
    for r in 0..k {
        matvec_t_naive(a, &v[r * m..(r + 1) * m], &mut y[r * n..(r + 1) * n]);
    }
}

/// Y = A^T V — tiled-scalar variant: 4-row tiles shared across all `k`
/// accumulations, so each A row is read once per tile instead of once per
/// class column.
fn matmul_t_scalar(a: &ColumnBlockView, v: &[f32], k: usize, y: &mut [f32]) {
    let (m, n) = (a.rows(), a.cols());
    y.fill(0.0);
    let mut i = 0;
    while i + 4 <= m {
        let (r0, r1, r2, r3) = (a.row(i), a.row(i + 1), a.row(i + 2), a.row(i + 3));
        for r in 0..k {
            let vr = &v[r * m..(r + 1) * m];
            let (v0, v1, v2, v3) = (vr[i], vr[i + 1], vr[i + 2], vr[i + 3]);
            let yr = &mut y[r * n..(r + 1) * n];
            for j in 0..n {
                yr[j] += r0[j] * v0 + r1[j] * v1 + r2[j] * v2 + r3[j] * v3;
            }
        }
        i += 4;
    }
    while i < m {
        let row = a.row(i);
        for r in 0..k {
            let vi = v[r * m + i];
            let yr = &mut y[r * n..(r + 1) * n];
            for j in 0..n {
                yr[j] += row[j] * vi;
            }
        }
        i += 1;
    }
}

/// Y = A^T V for `k` vectors under a pinned ISA variant.
pub fn matmul_t_isa(isa: Isa, a: &ColumnBlockView, v: &[f32], k: usize, y: &mut [f32]) {
    let (m, n) = (a.rows(), a.cols());
    assert_eq!(v.len(), k * m);
    assert_eq!(y.len(), k * n);
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { simd::avx2::matmul_t(a, v, k, y) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { simd::neon::matmul_t(a, v, k, y) },
        Isa::Scalar => matmul_t_scalar(a, v, k, y),
        #[allow(unreachable_patterns)]
        other => panic!("isa {} not available on this host", other.name()),
    }
}

/// Y = A^T V for `k` vectors — dispatched to the active ISA.
pub fn matmul_t(a: &ColumnBlockView, v: &[f32], k: usize, y: &mut [f32]) {
    matmul_t_isa(simd::active(), a, v, k, y)
}

// -------------------------------------------------------------------- gram

/// G += A^T A — naive reference (rank-1 row accumulation with the
/// historical per-element skip-zero branch; upper triangle mirrored).
pub fn gram_naive(a: &ColumnBlockView, g: &mut [f32]) {
    let n = a.cols();
    assert_eq!(g.len(), n * n);
    for i in 0..a.rows() {
        let row = a.row(i);
        for (j, &aj) in row.iter().enumerate() {
            if aj == 0.0 {
                continue;
            }
            let grow = &mut g[j * n..(j + 1) * n];
            for (k, &ak) in row.iter().enumerate().skip(j) {
                grow[k] += aj * ak;
            }
        }
    }
    mirror_upper(g, n);
}

/// G += A^T A — tiled-scalar variant: 4-row tiles, no per-element zero
/// branch (on dense data the branch mispredicts almost always and defeats
/// vectorization).  Upper triangle computed, then mirrored; accumulating
/// across calls composes (the mirror step only copies upper to lower).
fn gram_scalar(a: &ColumnBlockView, g: &mut [f32]) {
    let n = a.cols();
    let m = a.rows();
    let mut i = 0;
    while i + 4 <= m {
        let (r0, r1, r2, r3) = (a.row(i), a.row(i + 1), a.row(i + 2), a.row(i + 3));
        for j in 0..n {
            let (a0, a1, a2, a3) = (r0[j], r1[j], r2[j], r3[j]);
            let grow = &mut g[j * n..(j + 1) * n];
            for k in j..n {
                grow[k] += a0 * r0[k] + a1 * r1[k] + a2 * r2[k] + a3 * r3[k];
            }
        }
        i += 4;
    }
    while i < m {
        let row = a.row(i);
        for j in 0..n {
            let aj = row[j];
            let grow = &mut g[j * n..(j + 1) * n];
            for k in j..n {
                grow[k] += aj * row[k];
            }
        }
        i += 1;
    }
    mirror_upper(g, n);
}

/// G += A^T A under a pinned ISA variant.
pub fn gram_isa(isa: Isa, a: &ColumnBlockView, g: &mut [f32]) {
    let n = a.cols();
    assert_eq!(g.len(), n * n);
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { simd::avx2::gram(a, g) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { simd::neon::gram(a, g) },
        Isa::Scalar => gram_scalar(a, g),
        #[allow(unreachable_patterns)]
        other => panic!("isa {} not available on this host", other.name()),
    }
}

/// G += A^T A — dispatched to the active ISA.
pub fn gram(a: &ColumnBlockView, g: &mut [f32]) {
    gram_isa(simd::active(), a, g)
}

/// Copy the computed upper triangle onto the lower one (shared by the
/// scalar and SIMD gram variants; copying only, so accumulation composes).
pub(crate) fn mirror_upper(g: &mut [f32], n: usize) {
    for j in 0..n {
        for k in (j + 1)..n {
            g[k * n + j] = g[j * n + k];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_buf(rng: &mut Rng, len: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; len];
        rng.fill_normal_f32(&mut v);
        v
    }

    fn close(a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            let scale = 1.0f32.max(x.abs()).max(y.abs());
            assert!((x - y).abs() <= 1e-5 * scale, "elem {i}: {x} vs {y}");
        }
    }

    #[test]
    fn matvec_all_isas_match_naive_odd_shapes() {
        let mut rng = Rng::seed_from(1);
        // deliberately not multiples of any lane width
        for (m, n) in [(1, 1), (3, 5), (7, 9), (18, 13), (33, 1), (5, 37)] {
            let data = rand_buf(&mut rng, m * n);
            let a = ColumnBlockView::new(&data, m, n, n, 0);
            let x = rand_buf(&mut rng, n);
            let mut y0 = vec![0.0f32; m];
            matvec_naive(&a, &x, &mut y0);
            for isa in crate::linalg::simd::supported() {
                let mut y1 = vec![0.0f32; m];
                matvec_isa(isa, &a, &x, &mut y1);
                close(&y0, &y1);
            }
        }
    }

    #[test]
    fn matvec_t_all_isas_match_naive_with_zeros() {
        let mut rng = Rng::seed_from(2);
        for (m, n) in [(2, 3), (6, 4), (11, 7), (16, 16), (9, 33)] {
            let data = rand_buf(&mut rng, m * n);
            let a = ColumnBlockView::new(&data, m, n, n, 0);
            let mut v = rand_buf(&mut rng, m);
            v[0] = 0.0; // exercise the naive skip-zero branch
            let mut y0 = vec![0.0f32; n];
            matvec_t_naive(&a, &v, &mut y0);
            for isa in crate::linalg::simd::supported() {
                let mut y1 = vec![0.0f32; n];
                matvec_t_isa(isa, &a, &v, &mut y1);
                close(&y0, &y1);
            }
        }
    }

    #[test]
    fn multi_vector_kernels_match_naive() {
        let mut rng = Rng::seed_from(3);
        let (m, n, k) = (14, 6, 3);
        let data = rand_buf(&mut rng, m * n);
        let a = ColumnBlockView::new(&data, m, n, n, 0);
        let x = rand_buf(&mut rng, k * n);
        let v = rand_buf(&mut rng, k * m);
        let mut y0 = vec![0.0f32; k * m];
        matmul_naive(&a, &x, k, &mut y0);
        let mut z0 = vec![0.0f32; k * n];
        matmul_t_naive(&a, &v, k, &mut z0);
        for isa in crate::linalg::simd::supported() {
            let mut y1 = vec![0.0f32; k * m];
            matmul_isa(isa, &a, &x, k, &mut y1);
            close(&y0, &y1);
            let mut z1 = vec![0.0f32; k * n];
            matmul_t_isa(isa, &a, &v, k, &mut z1);
            close(&z0, &z1);
        }
    }

    #[test]
    fn multi_vector_k1_is_bit_identical_to_single_per_isa() {
        let mut rng = Rng::seed_from(4);
        let (m, n) = (13, 9);
        let data = rand_buf(&mut rng, m * n);
        let a = ColumnBlockView::new(&data, m, n, n, 0);
        let x = rand_buf(&mut rng, n);
        let v = rand_buf(&mut rng, m);
        for isa in crate::linalg::simd::supported() {
            let mut y0 = vec![0.0f32; m];
            let mut y1 = vec![0.0f32; m];
            matvec_isa(isa, &a, &x, &mut y0);
            matmul_isa(isa, &a, &x, 1, &mut y1);
            assert_eq!(y0, y1, "{}", isa.name());
            let mut z0 = vec![0.0f32; n];
            let mut z1 = vec![0.0f32; n];
            matvec_t_isa(isa, &a, &v, &mut z0);
            matmul_t_isa(isa, &a, &v, 1, &mut z1);
            assert_eq!(z0, z1, "{}", isa.name());
        }
    }

    #[test]
    fn gram_all_isas_match_naive_and_accumulate() {
        let mut rng = Rng::seed_from(5);
        for (m, n) in [(1, 3), (5, 4), (10, 6), (19, 8), (23, 21)] {
            let data = rand_buf(&mut rng, m * n);
            let a = ColumnBlockView::new(&data, m, n, n, 0);
            let mut g0 = vec![0.0f32; n * n];
            gram_naive(&a, &mut g0);
            for isa in crate::linalg::simd::supported() {
                let mut g1 = vec![0.0f32; n * n];
                gram_isa(isa, &a, &mut g1);
                close(&g0, &g1);
                // accumulating a second pass doubles every entry
                gram_isa(isa, &a, &mut g1);
                let doubled: Vec<f32> = g0.iter().map(|&x| 2.0 * x).collect();
                close(&doubled, &g1);
            }
        }
    }

    #[test]
    fn strided_view_reads_column_block_in_place() {
        let mut rng = Rng::seed_from(6);
        let (m, n) = (9, 11);
        let data = rand_buf(&mut rng, m * n);
        let (col0, w) = (3, 5);
        // packed copy of columns [3, 8)
        let packed: Vec<f32> = (0..m)
            .flat_map(|i| data[i * n + col0..i * n + col0 + w].to_vec())
            .collect();
        let full = ColumnBlockView::new(&packed, m, w, w, 0);
        let view = ColumnBlockView::new(&data, m, w, n, col0);
        assert_eq!(view.row_stride(), n);
        let x = rand_buf(&mut rng, w);
        for isa in crate::linalg::simd::supported() {
            // packed vs strided view: same kernel, same order — exact
            let mut y0 = vec![0.0f32; m];
            let mut y1 = vec![0.0f32; m];
            matvec_isa(isa, &full, &x, &mut y0);
            matvec_isa(isa, &view, &x, &mut y1);
            assert_eq!(y0, y1, "{}", isa.name());
            let mut g0 = vec![0.0f32; w * w];
            let mut g1 = vec![0.0f32; w * w];
            gram_isa(isa, &full, &mut g0);
            gram_isa(isa, &view, &mut g1);
            assert_eq!(g0, g1, "{}", isa.name());
        }
    }

    #[test]
    fn empty_and_degenerate_shapes() {
        let data: Vec<f32> = Vec::new();
        let a = ColumnBlockView::new(&data, 0, 4, 4, 0);
        let x = [1.0f32; 4];
        let mut y: Vec<f32> = Vec::new();
        matvec_naive(&a, &x, &mut y);
        for isa in crate::linalg::simd::supported() {
            matvec_isa(isa, &a, &x, &mut y);
            let mut z = [9.0f32; 4];
            matvec_t_isa(isa, &a, &[], &mut z);
            assert_eq!(z, [0.0; 4]); // zero rows: A^T v is the zero vector
            let mut g = vec![0.0f32; 16];
            gram_isa(isa, &a, &mut g);
            assert!(g.iter().all(|&v| v == 0.0));
        }
    }
}

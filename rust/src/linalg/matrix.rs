//! Row-major dense `f32` matrix with the operations the ADMM data path
//! needs: matvec, transposed matvec, Gram accumulation, column-block
//! extraction (the paper's feature decomposition) and row-tile packing (the
//! host->device staging copy of the GPU backend).
//!
//! The arithmetic lives in [`super::kernels`] (runtime-ISA-dispatched SIMD
//! with a cache-tiled scalar fallback); the methods here are thin wrappers
//! over a whole-matrix [`ColumnBlockView`], so every caller — packed block
//! or in-place view — goes through the same deterministic summation order.
//!
//! # Storage layout: 64-byte-aligned, padded stride
//!
//! Rows are stored at a *stride* of `cols` rounded up to
//! [`super::aligned::LANE_F32`] elements in an [`AlignedVec`], so every row
//! start is 64-byte aligned and full vector lanes never straddle a row
//! boundary.  The padding is storage only — it is always zero, is never
//! serialized (PSF1 / LIBSVM writers walk logical rows), never compared
//! (`PartialEq` walks logical rows), and never read by the kernels (views
//! carry the logical `cols`).  Dataset generation fills logical elements
//! in row-major order, so padded storage draws the exact same RNG sequence
//! as the historical contiguous layout — seeds reproduce bit-for-bit.

use super::aligned::{AlignedVec, LANE_F32};
use super::kernels::{self, ColumnBlockView};

/// Row-major dense f32 matrix (the data-path precision) with 64-byte
/// aligned, stride-padded rows — see the module docs for the layout.
#[derive(Clone, Debug)]
pub struct Matrix {
    /// Row count.
    pub rows: usize,
    /// Column count (logical; the storage stride is padded — see
    /// [`Matrix::stride`]).
    pub cols: usize,
    /// Elements per stored row: `cols` rounded up to a 64-byte lane.
    stride: usize,
    /// Aligned storage: element (i, j) at `data[i * stride + j]`.
    data: AlignedVec,
}

impl PartialEq for Matrix {
    /// Logical equality: shape plus row contents; alignment padding is
    /// ignored.
    fn eq(&self, other: &Matrix) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && (0..self.rows).all(|i| self.row(i) == other.row(i))
    }
}

/// The padded row stride for a logical column count.  Public because the
/// `PSD1` shard format stores dense payloads at exactly this stride, so
/// the converter and the mapped reader must agree with `Matrix` storage.
pub fn padded_stride(cols: usize) -> usize {
    cols.div_ceil(LANE_F32).max(1) * LANE_F32
}

impl Matrix {
    /// All-zero matrix of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        let stride = padded_stride(cols);
        Matrix {
            rows,
            cols,
            stride,
            data: AlignedVec::zeroed(rows * stride),
        }
    }

    /// Build from row vectors (all rows must have equal length).
    pub fn from_rows(rows: Vec<Vec<f32>>) -> Matrix {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        assert!(rows.iter().all(|x| x.len() == c), "ragged rows");
        let mut out = Matrix::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            out.row_mut(i).copy_from_slice(row);
        }
        out
    }

    /// Build from a contiguous row-major buffer of `rows * cols` elements
    /// (the PSF1 wire layout; repacked into padded storage here).
    pub fn from_flat(rows: usize, cols: usize, flat: &[f32]) -> Matrix {
        assert_eq!(flat.len(), rows * cols, "flat buffer shape mismatch");
        let mut out = Matrix::zeros(rows, cols);
        for i in 0..rows {
            out.row_mut(i).copy_from_slice(&flat[i * cols..(i + 1) * cols]);
        }
        out
    }

    /// Elements per stored row (`>= cols`, a multiple of the 64-byte
    /// lane).  This is the `row_stride` every [`ColumnBlockView`] over
    /// this matrix carries.
    #[inline]
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Element (i, j).
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.stride + j]
    }

    /// Mutable element (i, j).
    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.stride + j]
    }

    /// Row `i` as a slice (length `cols`; padding excluded).
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.stride..i * self.stride + self.cols]
    }

    /// Mutable row `i` (length `cols`; padding excluded).
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let s = self.stride;
        &mut self.data[i * s..i * s + self.cols]
    }

    /// Apply `f` to every logical element in row-major order (padding
    /// untouched).  Dataset generators fill and mask through this, so the
    /// RNG draw order is identical to the historical contiguous layout.
    pub fn for_each_mut<F: FnMut(&mut f32)>(&mut self, mut f: F) {
        for i in 0..self.rows {
            for v in self.row_mut(i) {
                f(v);
            }
        }
    }

    /// Contiguous row-major copy of the logical elements (no padding) —
    /// the serialization layout of PSF1 and the shape the XLA staging
    /// tiles expect.
    pub fn to_vec(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.rows * self.cols);
        for i in 0..self.rows {
            out.extend_from_slice(self.row(i));
        }
        out
    }

    /// The full padded storage (`rows * stride` elements, padding
    /// included) — the exact payload of a dense `PSD1` section, and the
    /// buffer row-span views (mini-batch chunks) slice in place.
    #[inline]
    pub fn padded_data(&self) -> &[f32] {
        &self.data
    }

    /// Borrowed whole-matrix view for the kernel layer.
    pub fn view(&self) -> ColumnBlockView<'_> {
        ColumnBlockView::new(&self.data, self.rows, self.cols, self.stride, 0)
    }

    /// Borrowed view of columns `[col0, col0 + width)` — the feature block
    /// `A_j` read in place, with no packing copy (contrast
    /// [`Matrix::column_block`]).
    pub fn column_block_view(&self, col0: usize, width: usize) -> ColumnBlockView<'_> {
        assert!(col0 + width <= self.cols);
        ColumnBlockView::new(&self.data, self.rows, width, self.stride, col0)
    }

    /// y = A x  (accumulates in f32, matching the XLA artifacts).
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        kernels::matvec(&self.view(), x, y);
    }

    /// y = A^T v.
    pub fn matvec_t(&self, v: &[f32], y: &mut [f32]) {
        kernels::matvec_t(&self.view(), v, y);
    }

    /// G += A^T A, writing into a `cols x cols` row-major buffer.
    ///
    /// Upper triangle computed then mirrored.  This is the setup-time op —
    /// the per-iteration path only does matvecs.
    pub fn gram_accumulate(&self, g: &mut [f32]) {
        kernels::gram(&self.view(), g);
    }

    /// Extract the column block `[col0, col0+width)` as a packed matrix.
    /// This is the paper's feature decomposition: block j of `A_i`.
    /// The XLA backend needs the packed (padded) copy for staging; the
    /// native backend reads the shard in place via
    /// [`Matrix::column_block_view`] instead.
    pub fn column_block(&self, col0: usize, width: usize) -> Matrix {
        assert!(col0 + width <= self.cols);
        let mut out = Matrix::zeros(self.rows, width);
        for i in 0..self.rows {
            let src = &self.row(i)[col0..col0 + width];
            out.row_mut(i).copy_from_slice(src);
        }
        out
    }

    /// Pack rows `[row0, row0+count)` into `buf` (contiguous `cols`-wide
    /// rows, zero-padded to `buf.len() / cols` rows).  This is the staging
    /// copy a real GPU backend performs host->device; the transfer ledger
    /// measures it.
    pub fn pack_row_tile(&self, row0: usize, count: usize, buf: &mut [f32]) {
        let tile_rows = buf.len() / self.cols;
        assert!(count <= tile_rows);
        assert!(row0 + count <= self.rows);
        for r in 0..count {
            buf[r * self.cols..(r + 1) * self.cols].copy_from_slice(self.row(row0 + r));
        }
        buf[count * self.cols..].fill(0.0);
    }

    /// Normalize each column to unit l2 norm (paper §4); returns the norms.
    pub fn normalize_columns(&mut self) -> Vec<f32> {
        let mut norms = vec![0.0f64; self.cols];
        for i in 0..self.rows {
            for (j, &v) in self.row(i).iter().enumerate() {
                norms[j] += (v as f64) * (v as f64);
            }
        }
        let norms: Vec<f32> = norms
            .iter()
            .map(|&s| if s > 0.0 { (s.sqrt()) as f32 } else { 1.0 })
            .collect();
        for i in 0..self.rows {
            let row = self.row_mut(i);
            for (v, &nrm) in row.iter_mut().zip(&norms) {
                *v /= nrm;
            }
        }
        norms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Matrix {
        Matrix::from_rows(vec![
            vec![1.0, 2.0, 3.0],
            vec![4.0, 5.0, 6.0],
            vec![7.0, 8.0, 10.0],
            vec![0.5, -1.0, 2.0],
        ])
    }

    #[test]
    fn storage_is_aligned_and_padded() {
        let a = sample();
        assert_eq!(a.stride(), LANE_F32);
        assert_eq!(a.row(0).as_ptr() as usize % 64, 0);
        assert_eq!(a.row(1).as_ptr() as usize % 64, 0);
        // wider than one lane: stride rounds up to the next lane
        let b = Matrix::zeros(2, LANE_F32 + 1);
        assert_eq!(b.stride(), 2 * LANE_F32);
        // logical serialization layout is unpadded
        assert_eq!(a.to_vec().len(), 12);
        assert_eq!(&a.to_vec()[3..6], &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn equality_ignores_padding() {
        let a = sample();
        let b = Matrix::from_flat(4, 3, &a.to_vec());
        assert_eq!(a, b);
        let mut c = b.clone();
        *c.at_mut(2, 1) += 1.0;
        assert_ne!(a, c);
    }

    #[test]
    fn for_each_mut_walks_row_major(){
        let mut a = Matrix::zeros(2, 3);
        let mut k = 0.0f32;
        a.for_each_mut(|v| {
            *v = k;
            k += 1.0;
        });
        assert_eq!(a.row(0), &[0.0, 1.0, 2.0]);
        assert_eq!(a.row(1), &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn matvec_known_values() {
        let a = sample();
        let mut y = vec![0.0; 4];
        a.matvec(&[1.0, 0.0, -1.0], &mut y);
        assert_eq!(y, vec![-2.0, -2.0, -3.0, -1.5]);
    }

    #[test]
    fn matvec_t_known_values() {
        let a = sample();
        let mut y = vec![0.0; 3];
        a.matvec_t(&[1.0, 1.0, 0.0, 2.0], &mut y);
        assert_eq!(y, vec![6.0, 5.0, 13.0]);
    }

    #[test]
    fn gram_matches_naive() {
        let a = sample();
        let mut g = vec![0.0f32; 9];
        a.gram_accumulate(&mut g);
        for j in 0..3 {
            for k in 0..3 {
                let want: f32 = (0..4).map(|i| a.at(i, j) * a.at(i, k)).sum();
                assert!((g[j * 3 + k] - want).abs() < 1e-5, "({j},{k})");
            }
        }
    }

    #[test]
    fn gram_accumulates_across_tiles() {
        let a = sample();
        let top = Matrix::from_rows(vec![a.row(0).to_vec(), a.row(1).to_vec()]);
        let bot = Matrix::from_rows(vec![a.row(2).to_vec(), a.row(3).to_vec()]);
        let mut g_whole = vec![0.0f32; 9];
        a.gram_accumulate(&mut g_whole);
        let mut g_tiled = vec![0.0f32; 9];
        top.gram_accumulate(&mut g_tiled);
        bot.gram_accumulate(&mut g_tiled);
        for (x, y) in g_whole.iter().zip(&g_tiled) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn column_block_extracts() {
        let a = sample();
        let b = a.column_block(1, 2);
        assert_eq!(b.rows, 4);
        assert_eq!(b.cols, 2);
        assert_eq!(b.row(2), &[8.0, 10.0]);
    }

    #[test]
    fn column_block_view_matches_packed_copy() {
        let a = sample();
        let packed = a.column_block(1, 2);
        let view = a.column_block_view(1, 2);
        let x = [0.5f32, -2.0];
        let mut y0 = vec![0.0f32; 4];
        let mut y1 = vec![0.0f32; 4];
        packed.matvec(&x, &mut y0);
        kernels::matvec(&view, &x, &mut y1);
        assert_eq!(y0, y1);
        let mut g0 = vec![0.0f32; 4];
        let mut g1 = vec![0.0f32; 4];
        packed.gram_accumulate(&mut g0);
        kernels::gram(&view, &mut g1);
        assert_eq!(g0, g1);
    }

    #[test]
    fn pack_row_tile_pads_with_zeros() {
        let a = sample();
        let mut buf = vec![f32::NAN; 3 * 3]; // 3-row tile
        a.pack_row_tile(2, 2, &mut buf);
        assert_eq!(&buf[0..3], a.row(2));
        assert_eq!(&buf[3..6], a.row(3));
        assert_eq!(&buf[6..9], &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn normalize_columns_unit_norm() {
        let mut a = sample();
        a.normalize_columns();
        for j in 0..a.cols {
            let s: f64 = (0..a.rows).map(|i| (a.at(i, j) as f64).powi(2)).sum();
            assert!((s.sqrt() - 1.0).abs() < 1e-5, "col {j}: {s}");
        }
    }
}

//! Dense linear algebra substrate for the native ("CPU") backend and the
//! coordinator-side global updates.
//!
//! Data-path matrices are `f32` (matching the XLA artifacts); factorizations
//! and solver-level scalar work run in `f64` for stability.  Everything here
//! is dependency-free Rust; the "GPU" path goes through `runtime::` instead.

/// 64-byte-aligned f32 storage for the SIMD kernel backend.
pub mod aligned;
pub mod cg;
/// Dense Cholesky factorization of SPD block normal matrices.
pub mod cholesky;
/// Compressed-sparse-row storage + kernels (the sparse data path).
pub mod csr;
/// Runtime-ISA-dispatched dense kernels with naive reference twins.
pub mod kernels;
/// Row-major dense matrix type (aligned, padded-stride storage).
pub mod matrix;
/// Vector operations shared by both precisions.
pub mod ops;
/// Runtime ISA dispatch + explicit AVX2/NEON kernel variants.
pub mod simd;

pub use aligned::AlignedVec;
pub use cg::conjugate_gradient;
pub use cholesky::Cholesky;
pub use csr::{CsrBlockView, CsrMatrix, CsrParts};
pub use kernels::ColumnBlockView;
pub use matrix::Matrix;
pub use simd::{Isa, IsaChoice};

//! Dense linear algebra substrate for the native ("CPU") backend and the
//! coordinator-side global updates.
//!
//! Data-path matrices are `f32` (matching the XLA artifacts); factorizations
//! and solver-level scalar work run in `f64` for stability.  Everything here
//! is dependency-free Rust; the "GPU" path goes through `runtime::` instead.

pub mod cg;
pub mod cholesky;
pub mod csr;
pub mod kernels;
pub mod matrix;
pub mod ops;

pub use cg::conjugate_gradient;
pub use cholesky::Cholesky;
pub use csr::{CsrBlockView, CsrMatrix};
pub use kernels::ColumnBlockView;
pub use matrix::Matrix;

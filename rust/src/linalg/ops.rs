//! Vector operations (f32 data path + f64 coordinator path).

/// Dot product (f64 coordinator path).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Dot product (f32 data path).
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// y += alpha * x (f32 data path).
#[inline]
pub fn axpy_f32(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// x *= alpha in place.
#[inline]
pub fn scale(alpha: f64, x: &mut [f64]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// out = a - b
#[inline]
pub fn sub(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o = x - y;
    }
}

/// Euclidean norm.
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// Euclidean norm of an f32 slice, accumulated in f64.
pub fn norm2_f32(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
}

/// l1 norm.
pub fn norm1(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// l-infinity norm.
pub fn norm_inf(x: &[f64]) -> f64 {
    x.iter().fold(0.0, |m, v| m.max(v.abs()))
}

/// Widen an f32 slice to a fresh f64 vector.
pub fn to_f64(x: &[f32]) -> Vec<f64> {
    x.iter().map(|&v| v as f64).collect()
}

/// Narrow an f64 slice to a fresh f32 vector.
pub fn to_f32(x: &[f64]) -> Vec<f32> {
    x.iter().map(|&v| v as f32).collect()
}

/// Euclidean distance squared.
pub fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_and_norms() {
        let a = [3.0, -4.0];
        assert_eq!(dot(&a, &a), 25.0);
        assert_eq!(norm2(&a), 5.0);
        assert_eq!(norm1(&a), 7.0);
        assert_eq!(norm_inf(&a), 4.0);
    }

    #[test]
    fn axpy_accumulates() {
        let x = [1.0, 2.0];
        let mut y = [10.0, 20.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0]);
    }

    #[test]
    fn sub_and_dist() {
        let a = [3.0, 5.0];
        let b = [1.0, 1.0];
        let mut out = [0.0; 2];
        sub(&a, &b, &mut out);
        assert_eq!(out, [2.0, 4.0]);
        assert_eq!(dist2(&a, &b), 20.0);
    }

    #[test]
    fn f32_f64_roundtrip() {
        let x = [1.5f32, -2.25];
        assert_eq!(to_f32(&to_f64(&x)), x.to_vec());
    }
}

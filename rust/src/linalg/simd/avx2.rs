//! AVX2 + FMA kernel variants (256-bit lanes, 8 f32 per vector).
//!
//! Every function here carries `#[target_feature(enable = "avx2,fma")]`
//! and is therefore `unsafe` to call: the dispatched entry points in
//! [`crate::linalg::kernels`] / [`crate::linalg::csr`] only route here
//! after [`super::available`] confirmed the host (so the only obligation
//! on callers is the feature check, which `super::active()` guarantees).
//! All memory access is through slice-bounds-checked indices or raw loads
//! whose ranges are proven by the surrounding `while i + LANES <= n`
//! loops.
//!
//! Determinism: fixed lane counts, fixed accumulator splits, and the
//! shared scalar tail helpers give every kernel a fixed summation order —
//! bit-identical run-to-run, with the `k == 1` multi-RHS cases sharing
//! the single-vector code paths.  FMA contracts multiply-add into one
//! rounding, so agreement with the scalar variants is the crate-wide
//! 1e-5 contract, not bit equality.

#![allow(unsafe_op_in_unsafe_fn)]

use std::arch::x86_64::*;

use crate::linalg::csr::{dot_sparse_tail, CsrBlockView};
use crate::linalg::kernels::{dot_tail, mirror_upper, ColumnBlockView};

/// Horizontal sum of one 256-bit accumulator, fixed reduction order:
/// (low128 + high128), then pairwise within the 128-bit half.
#[inline]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn hsum(v: __m256) -> f32 {
    let s = _mm_add_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps::<1>(v));
    let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
    let s = _mm_add_ss(s, _mm_movehdup_ps(s));
    _mm_cvtss_f32(s)
}

/// 8-wide FMA dot product with four independent accumulators (32 elements
/// per iteration), reduced `((a0 + a1) + (a2 + a3))` then the shared
/// scalar tail.
#[inline]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut acc2 = _mm256_setzero_ps();
    let mut acc3 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 32 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
        acc1 = _mm256_fmadd_ps(
            _mm256_loadu_ps(pa.add(i + 8)),
            _mm256_loadu_ps(pb.add(i + 8)),
            acc1,
        );
        acc2 = _mm256_fmadd_ps(
            _mm256_loadu_ps(pa.add(i + 16)),
            _mm256_loadu_ps(pb.add(i + 16)),
            acc2,
        );
        acc3 = _mm256_fmadd_ps(
            _mm256_loadu_ps(pa.add(i + 24)),
            _mm256_loadu_ps(pb.add(i + 24)),
            acc3,
        );
        i += 32;
    }
    while i + 8 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
        i += 8;
    }
    let acc = _mm256_add_ps(_mm256_add_ps(acc0, acc1), _mm256_add_ps(acc2, acc3));
    hsum(acc) + dot_tail(&a[i..], &b[i..])
}

/// y = A x.
///
/// # Safety
/// The host must support AVX2 and FMA — guaranteed when routed here by the
/// dispatchers after a [`super::available`] check; assert it yourself on
/// direct calls.
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn matvec(a: &ColumnBlockView, x: &[f32], y: &mut [f32]) {
    for (i, yi) in y.iter_mut().enumerate() {
        *yi = dot(a.row(i), x);
    }
}

/// Y = A X for `k` right-hand sides (each row dotted against all `k`
/// vectors while hot; shares [`dot`] with [`matvec`], so `k == 1` is
/// bit-identical).
///
/// # Safety
/// The host must support AVX2 and FMA — guaranteed when routed here by the
/// dispatchers after a [`super::available`] check; assert it yourself on
/// direct calls.
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn matmul(a: &ColumnBlockView, x: &[f32], k: usize, y: &mut [f32]) {
    let (m, n) = (a.rows(), a.cols());
    for i in 0..m {
        let row = a.row(i);
        for r in 0..k {
            y[r * m + i] = dot(row, &x[r * n..(r + 1) * n]);
        }
    }
}

/// yr[j..] += r0 v0 + r1 v1 + r2 v2 + r3 v3 over one row quad, 8-wide
/// with a scalar tail (shared by the tiled loop of [`matmul_t`]).
#[inline]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn axpy4(yr: &mut [f32], rows: [&[f32]; 4], vs: [f32; 4]) {
    let n = yr.len();
    let b0 = _mm256_set1_ps(vs[0]);
    let b1 = _mm256_set1_ps(vs[1]);
    let b2 = _mm256_set1_ps(vs[2]);
    let b3 = _mm256_set1_ps(vs[3]);
    let py = yr.as_mut_ptr();
    let mut j = 0usize;
    while j + 8 <= n {
        let mut t = _mm256_loadu_ps(py.add(j) as *const f32);
        t = _mm256_fmadd_ps(_mm256_loadu_ps(rows[0].as_ptr().add(j)), b0, t);
        t = _mm256_fmadd_ps(_mm256_loadu_ps(rows[1].as_ptr().add(j)), b1, t);
        t = _mm256_fmadd_ps(_mm256_loadu_ps(rows[2].as_ptr().add(j)), b2, t);
        t = _mm256_fmadd_ps(_mm256_loadu_ps(rows[3].as_ptr().add(j)), b3, t);
        _mm256_storeu_ps(py.add(j), t);
        j += 8;
    }
    while j < n {
        yr[j] += rows[0][j] * vs[0] + rows[1][j] * vs[1] + rows[2][j] * vs[2] + rows[3][j] * vs[3];
        j += 1;
    }
}

/// Y = A^T V for `k` vectors (4-row tiles shared across all `k`
/// accumulations; `matvec_t` is the `k == 1` case).
///
/// # Safety
/// The host must support AVX2 and FMA — guaranteed when routed here by the
/// dispatchers after a [`super::available`] check; assert it yourself on
/// direct calls.
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn matmul_t(a: &ColumnBlockView, v: &[f32], k: usize, y: &mut [f32]) {
    let (m, n) = (a.rows(), a.cols());
    y.fill(0.0);
    let mut i = 0;
    while i + 4 <= m {
        let rows = [a.row(i), a.row(i + 1), a.row(i + 2), a.row(i + 3)];
        for r in 0..k {
            let vr = &v[r * m..(r + 1) * m];
            let vs = [vr[i], vr[i + 1], vr[i + 2], vr[i + 3]];
            axpy4(&mut y[r * n..(r + 1) * n], rows, vs);
        }
        i += 4;
    }
    while i < m {
        let row = a.row(i);
        for r in 0..k {
            let vi = v[r * m + i];
            let b = _mm256_set1_ps(vi);
            let yr = &mut y[r * n..(r + 1) * n];
            let py = yr.as_mut_ptr();
            let mut j = 0usize;
            while j + 8 <= n {
                let t = _mm256_fmadd_ps(
                    _mm256_loadu_ps(row.as_ptr().add(j)),
                    b,
                    _mm256_loadu_ps(py.add(j) as *const f32),
                );
                _mm256_storeu_ps(py.add(j), t);
                j += 8;
            }
            while j < n {
                yr[j] += row[j] * vi;
                j += 1;
            }
        }
        i += 1;
    }
}

/// G += A^T A (upper triangle computed 8-wide then mirrored; accumulation
/// across calls composes exactly like the scalar variant).
///
/// # Safety
/// The host must support AVX2 and FMA — guaranteed when routed here by the
/// dispatchers after a [`super::available`] check; assert it yourself on
/// direct calls.
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn gram(a: &ColumnBlockView, g: &mut [f32]) {
    let n = a.cols();
    let m = a.rows();
    let mut i = 0;
    while i + 4 <= m {
        let rows = [a.row(i), a.row(i + 1), a.row(i + 2), a.row(i + 3)];
        for j in 0..n {
            let vs = [rows[0][j], rows[1][j], rows[2][j], rows[3][j]];
            axpy4_from(&mut g[j * n..(j + 1) * n], j, rows, vs);
        }
        i += 4;
    }
    while i < m {
        let row = a.row(i);
        for j in 0..n {
            let aj = row[j];
            let b = _mm256_set1_ps(aj);
            let grow = &mut g[j * n..(j + 1) * n];
            let pg = grow.as_mut_ptr();
            let mut kk = j;
            while kk + 8 <= n {
                let t = _mm256_fmadd_ps(
                    _mm256_loadu_ps(row.as_ptr().add(kk)),
                    b,
                    _mm256_loadu_ps(pg.add(kk) as *const f32),
                );
                _mm256_storeu_ps(pg.add(kk), t);
                kk += 8;
            }
            while kk < n {
                grow[kk] += aj * row[kk];
                kk += 1;
            }
        }
        i += 1;
    }
    mirror_upper(g, n);
}

/// [`axpy4`] starting at column `j0` (the triangular gram inner loop).
#[inline]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn axpy4_from(grow: &mut [f32], j0: usize, rows: [&[f32]; 4], vs: [f32; 4]) {
    let n = grow.len();
    let b0 = _mm256_set1_ps(vs[0]);
    let b1 = _mm256_set1_ps(vs[1]);
    let b2 = _mm256_set1_ps(vs[2]);
    let b3 = _mm256_set1_ps(vs[3]);
    let pg = grow.as_mut_ptr();
    let mut kk = j0;
    while kk + 8 <= n {
        let mut t = _mm256_loadu_ps(pg.add(kk) as *const f32);
        t = _mm256_fmadd_ps(_mm256_loadu_ps(rows[0].as_ptr().add(kk)), b0, t);
        t = _mm256_fmadd_ps(_mm256_loadu_ps(rows[1].as_ptr().add(kk)), b1, t);
        t = _mm256_fmadd_ps(_mm256_loadu_ps(rows[2].as_ptr().add(kk)), b2, t);
        t = _mm256_fmadd_ps(_mm256_loadu_ps(rows[3].as_ptr().add(kk)), b3, t);
        _mm256_storeu_ps(pg.add(kk), t);
        kk += 8;
    }
    while kk < n {
        grow[kk] +=
            rows[0][kk] * vs[0] + rows[1][kk] * vs[1] + rows[2][kk] * vs[2] + rows[3][kk] * vs[3];
        kk += 1;
    }
}

// ---------------------------------------------------------------- CSR

/// Gather-based sparse row dot: 8 column indices loaded, rebased by
/// `col0`, gathered from `x`, FMA'd against the stored values.  On padded
/// runs (see `CsrBlockView::row_lanes`) the loop consumes the zero-value
/// padding in full lanes and the shared tail is empty.
#[inline]
#[target_feature(enable = "avx2", enable = "fma")]
pub(crate) unsafe fn sparse_dot(cols: &[u32], vals: &[f32], col0: u32, x: &[f32]) -> f32 {
    let n = cols.len();
    debug_assert_eq!(n, vals.len());
    let c0 = _mm256_set1_epi32(col0 as i32);
    let mut acc = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 8 <= n {
        let idx = _mm256_loadu_si256(cols.as_ptr().add(i) as *const __m256i);
        let idx = _mm256_sub_epi32(idx, c0);
        let xv = _mm256_i32gather_ps::<4>(x.as_ptr(), idx);
        acc = _mm256_fmadd_ps(_mm256_loadu_ps(vals.as_ptr().add(i)), xv, acc);
        i += 8;
    }
    hsum(acc) + dot_sparse_tail(&cols[i..], &vals[i..], col0, x)
}

/// y = A x over a CSR block view.
///
/// # Safety
/// The host must support AVX2 and FMA — guaranteed when routed here by the
/// dispatchers after a [`super::available`] check; assert it yourself on
/// direct calls.
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn spmv(a: &CsrBlockView, x: &[f32], y: &mut [f32]) {
    let col0 = a.col0();
    for (i, yi) in y.iter_mut().enumerate() {
        let (cols, vals) = a.row_lanes(i);
        *yi = sparse_dot(cols, vals, col0, x);
    }
}

/// Y = A X for `k` right-hand sides (shares [`sparse_dot`] with [`spmv`],
/// so `k == 1` is bit-identical).
///
/// # Safety
/// The host must support AVX2 and FMA — guaranteed when routed here by the
/// dispatchers after a [`super::available`] check; assert it yourself on
/// direct calls.
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn spmm(a: &CsrBlockView, x: &[f32], k: usize, y: &mut [f32]) {
    let (m, n) = (a.rows(), a.cols());
    let col0 = a.col0();
    for i in 0..m {
        let (cols, vals) = a.row_lanes(i);
        for r in 0..k {
            y[r * m + i] = sparse_dot(cols, vals, col0, &x[r * n..(r + 1) * n]);
        }
    }
}

/// Y = A^T V for `k` vectors: values scaled 8 at a time, then scattered
/// (AVX2 has gathers but no scatters, so the stores stay scalar — the
/// products are what vectorizes).
///
/// # Safety
/// The host must support AVX2 and FMA — guaranteed when routed here by the
/// dispatchers after a [`super::available`] check; assert it yourself on
/// direct calls.
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn spmm_t(a: &CsrBlockView, v: &[f32], k: usize, y: &mut [f32]) {
    let (m, n) = (a.rows(), a.cols());
    let col0 = a.col0();
    y.fill(0.0);
    let mut prod = [0.0f32; 8];
    for i in 0..m {
        let (cols, vals) = a.row(i);
        let len = cols.len();
        if len == 0 {
            continue;
        }
        for r in 0..k {
            let vi = v[r * m + i];
            let b = _mm256_set1_ps(vi);
            let yr = &mut y[r * n..(r + 1) * n];
            let mut j = 0usize;
            while j + 8 <= len {
                let p = _mm256_mul_ps(_mm256_loadu_ps(vals.as_ptr().add(j)), b);
                _mm256_storeu_ps(prod.as_mut_ptr(), p);
                for (t, &pt) in prod.iter().enumerate() {
                    yr[(cols[j + t] - col0) as usize] += pt;
                }
                j += 8;
            }
            while j < len {
                yr[(cols[j] - col0) as usize] += vals[j] * vi;
                j += 1;
            }
        }
    }
}

//! Runtime ISA dispatch for the explicit-SIMD kernel backend.
//!
//! The data-touching kernels of the inner sweep (`matvec`, `matvec_t`,
//! `matmul`, `matmul_t`, `gram`, and the CSR `spmv` family) each exist in
//! up to three variants:
//!
//!   * **scalar** — the cache-tiled unroll-by-4 kernels of
//!     [`crate::linalg::kernels`] / [`crate::linalg::csr`]: the guaranteed
//!     fallback, bit-identical to the historical implementation;
//!   * **avx2** — 256-bit AVX2 + FMA (`std::arch::x86_64`), selected at
//!     runtime via `is_x86_feature_detected!`;
//!   * **neon** — 128-bit NEON (`std::arch::aarch64`), always available on
//!     aarch64 (NEON is architecturally mandatory there).
//!
//! # Selection
//!
//! The active ISA is resolved **once** per process, in priority order:
//!
//!   1. a forced override installed by [`select`] — the `platform.isa`
//!      JSON knob / `psfit --isa` CLI flag route here;
//!   2. the `PSFIT_ISA` environment variable (`auto|scalar|avx2|neon`,
//!      read once; unusable values warn on stderr and fall back to auto) —
//!      the CI matrix and the forced-ISA parity tests use this;
//!   3. auto-detection: the widest variant the host supports.
//!
//! Every dispatched kernel entry point reads [`active`] (one relaxed
//! atomic load), so a process never mixes ISAs mid-solve unless [`select`]
//! is explicitly called between solves (the solver benchmark does exactly
//! that to time scalar vs SIMD in one process).
//!
//! # Determinism and tolerance
//!
//! Each variant has a fixed internal summation order, so any *single* ISA
//! is bit-identical run-to-run, at any worker-pool width, and between the
//! `k == 1` multi-RHS case and its single-vector kernel.  *Across* ISAs
//! the orders differ (and FMA contracts `a*b + c` into one rounding), so
//! cross-ISA agreement is the crate-wide kernel contract
//! `|a - b| <= 1e-5 * max(1, |value|)` — the same tolerance as the
//! `_naive` twins, pinned by `tests/simd.rs`.

#[cfg(target_arch = "aarch64")]
pub mod neon;
#[cfg(target_arch = "x86_64")]
pub mod avx2;

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// An instruction-set variant of the kernel backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Cache-tiled unroll-by-4 scalar kernels (the guaranteed fallback).
    Scalar,
    /// 256-bit AVX2 + FMA (x86_64 only, runtime-detected).
    Avx2,
    /// 128-bit NEON (aarch64 only).
    Neon,
}

impl Isa {
    /// Canonical lowercase name (inverse of [`Isa::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    /// Parse an ISA name (`scalar|avx2|neon`).
    pub fn parse(s: &str) -> anyhow::Result<Isa> {
        match s {
            "scalar" => Ok(Isa::Scalar),
            "avx2" => Ok(Isa::Avx2),
            "neon" => Ok(Isa::Neon),
            other => anyhow::bail!("unknown isa `{other}` (scalar|avx2|neon)"),
        }
    }

    fn to_u8(self) -> u8 {
        match self {
            Isa::Scalar => 1,
            Isa::Avx2 => 2,
            Isa::Neon => 3,
        }
    }

    fn from_u8(v: u8) -> Option<Isa> {
        match v {
            1 => Some(Isa::Scalar),
            2 => Some(Isa::Avx2),
            3 => Some(Isa::Neon),
            _ => None,
        }
    }
}

/// The `platform.isa` / `PSFIT_ISA` setting: pick automatically or force
/// one variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum IsaChoice {
    /// Use the widest variant the host supports (the default).
    #[default]
    Auto,
    /// Force the named variant; [`select`] rejects it when unavailable.
    Force(Isa),
}

impl IsaChoice {
    /// Parse a choice (`auto|scalar|avx2|neon`).
    pub fn parse(s: &str) -> anyhow::Result<IsaChoice> {
        if s == "auto" {
            Ok(IsaChoice::Auto)
        } else {
            Ok(IsaChoice::Force(Isa::parse(s).map_err(|_| {
                anyhow::anyhow!("unknown isa `{s}` (auto|scalar|avx2|neon)")
            })?))
        }
    }

    /// Canonical name (inverse of [`IsaChoice::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            IsaChoice::Auto => "auto",
            IsaChoice::Force(isa) => isa.name(),
        }
    }
}

/// Whether this host can execute the given variant.
pub fn available(isa: Isa) -> bool {
    match isa {
        Isa::Scalar => true,
        Isa::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            {
                std::arch::is_x86_feature_detected!("avx2")
                    && std::arch::is_x86_feature_detected!("fma")
            }
            #[cfg(not(target_arch = "x86_64"))]
            {
                false
            }
        }
        Isa::Neon => cfg!(target_arch = "aarch64"),
    }
}

/// The widest variant the host supports.
pub fn detect_best() -> Isa {
    if available(Isa::Avx2) {
        Isa::Avx2
    } else if available(Isa::Neon) {
        Isa::Neon
    } else {
        Isa::Scalar
    }
}

/// Every variant this host can execute (always includes `Scalar`) — the
/// iteration set of the forced-ISA parity tests.
pub fn supported() -> Vec<Isa> {
    let mut out = vec![Isa::Scalar];
    for isa in [Isa::Avx2, Isa::Neon] {
        if available(isa) {
            out.push(isa);
        }
    }
    out
}

/// Forced override installed by [`select`]: 0 = none, else `Isa + 1`.
static OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// The `PSFIT_ISA` / auto-detected baseline, resolved once per process.
static BASELINE: OnceLock<Isa> = OnceLock::new();

fn baseline() -> Isa {
    *BASELINE.get_or_init(|| match std::env::var("PSFIT_ISA") {
        Err(_) => detect_best(),
        Ok(raw) => match IsaChoice::parse(&raw) {
            Ok(IsaChoice::Auto) => detect_best(),
            Ok(IsaChoice::Force(isa)) if available(isa) => isa,
            Ok(IsaChoice::Force(isa)) => {
                eprintln!(
                    "warning: PSFIT_ISA={} is not available on this host; using {}",
                    isa.name(),
                    detect_best().name()
                );
                detect_best()
            }
            Err(_) => {
                eprintln!(
                    "warning: invalid PSFIT_ISA value `{raw}` (auto|scalar|avx2|neon); using {}",
                    detect_best().name()
                );
                detect_best()
            }
        },
    })
}

/// The ISA the dispatched kernel entry points currently route to.
#[inline]
pub fn active() -> Isa {
    match Isa::from_u8(OVERRIDE.load(Ordering::Relaxed)) {
        Some(isa) => isa,
        None => baseline(),
    }
}

/// Install the process-wide ISA choice (the `platform.isa` knob).
///
/// `Auto` clears any previous override, restoring the `PSFIT_ISA` /
/// auto-detect baseline.  Forcing an unavailable variant is an error and
/// leaves the current selection untouched.  Returns the now-active ISA.
///
/// This is a process-global switch intended for startup (the CLI calls it
/// once after parsing config) and for single-threaded A/B timing (the
/// solver benchmark); it is not meant to be raced against in-flight
/// solves.
pub fn select(choice: IsaChoice) -> anyhow::Result<Isa> {
    match choice {
        IsaChoice::Auto => {
            OVERRIDE.store(0, Ordering::Relaxed);
            Ok(baseline())
        }
        IsaChoice::Force(isa) => {
            anyhow::ensure!(
                available(isa),
                "isa `{}` is not available on this host (supported: {})",
                isa.name(),
                supported()
                    .iter()
                    .map(|i| i.name())
                    .collect::<Vec<_>>()
                    .join(", ")
            );
            OVERRIDE.store(isa.to_u8(), Ordering::Relaxed);
            Ok(isa)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for s in ["scalar", "avx2", "neon"] {
            assert_eq!(Isa::parse(s).unwrap().name(), s);
        }
        assert!(Isa::parse("sse9").is_err());
        assert_eq!(IsaChoice::parse("auto").unwrap(), IsaChoice::Auto);
        assert_eq!(
            IsaChoice::parse("scalar").unwrap(),
            IsaChoice::Force(Isa::Scalar)
        );
        assert!(IsaChoice::parse("wide").is_err());
        assert_eq!(IsaChoice::default().name(), "auto");
    }

    #[test]
    fn scalar_is_always_supported() {
        assert!(available(Isa::Scalar));
        assert!(supported().contains(&Isa::Scalar));
        assert!(supported().contains(&detect_best()));
    }

    // select()/active() plumbing is pinned in tests/simd.rs, which owns a
    // mutex around the process-global override; unit tests here leave the
    // global state untouched so parallel in-crate tests stay deterministic.
}

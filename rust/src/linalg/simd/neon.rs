//! NEON kernel variants (128-bit lanes, 4 f32 per vector; aarch64 only).
//!
//! NEON is architecturally mandatory on aarch64, so [`super::available`]
//! always reports it there and these functions are selected by default.
//! The structure mirrors [`super::avx2`] at half the lane width: fixed
//! accumulator splits, shared scalar tail helpers, and `k == 1` multi-RHS
//! cases sharing the single-vector code paths — same determinism contract,
//! same 1e-5 cross-variant tolerance (`vfmaq_f32` is a fused
//! multiply-add, like FMA3).

#![allow(unsafe_op_in_unsafe_fn)]

use std::arch::aarch64::*;

use crate::linalg::csr::{dot_sparse_tail, CsrBlockView};
use crate::linalg::kernels::{dot_tail, mirror_upper, ColumnBlockView};

/// 4-wide FMA dot product with four independent accumulators (16 elements
/// per iteration), reduced `((a0 + a1) + (a2 + a3))` then the shared
/// scalar tail.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut acc2 = vdupq_n_f32(0.0);
    let mut acc3 = vdupq_n_f32(0.0);
    let mut i = 0usize;
    while i + 16 <= n {
        acc0 = vfmaq_f32(acc0, vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
        acc1 = vfmaq_f32(acc1, vld1q_f32(pa.add(i + 4)), vld1q_f32(pb.add(i + 4)));
        acc2 = vfmaq_f32(acc2, vld1q_f32(pa.add(i + 8)), vld1q_f32(pb.add(i + 8)));
        acc3 = vfmaq_f32(acc3, vld1q_f32(pa.add(i + 12)), vld1q_f32(pb.add(i + 12)));
        i += 16;
    }
    while i + 4 <= n {
        acc0 = vfmaq_f32(acc0, vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
        i += 4;
    }
    let acc = vaddq_f32(vaddq_f32(acc0, acc1), vaddq_f32(acc2, acc3));
    vaddvq_f32(acc) + dot_tail(&a[i..], &b[i..])
}

/// y = A x.
///
/// # Safety
/// The host must support NEON — guaranteed when routed here by the
/// dispatchers after a [`super::available`] check; assert it yourself on
/// direct calls.
#[target_feature(enable = "neon")]
pub unsafe fn matvec(a: &ColumnBlockView, x: &[f32], y: &mut [f32]) {
    for (i, yi) in y.iter_mut().enumerate() {
        *yi = dot(a.row(i), x);
    }
}

/// Y = A X for `k` right-hand sides (shares [`dot`] with [`matvec`], so
/// `k == 1` is bit-identical).
///
/// # Safety
/// The host must support NEON — guaranteed when routed here by the
/// dispatchers after a [`super::available`] check; assert it yourself on
/// direct calls.
#[target_feature(enable = "neon")]
pub unsafe fn matmul(a: &ColumnBlockView, x: &[f32], k: usize, y: &mut [f32]) {
    let (m, n) = (a.rows(), a.cols());
    for i in 0..m {
        let row = a.row(i);
        for r in 0..k {
            y[r * m + i] = dot(row, &x[r * n..(r + 1) * n]);
        }
    }
}

/// yr[j..] += r0 v0 + r1 v1 + r2 v2 + r3 v3 over one row quad, 4-wide
/// with a scalar tail.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn axpy4_from(yr: &mut [f32], j0: usize, rows: [&[f32]; 4], vs: [f32; 4]) {
    let n = yr.len();
    let b0 = vdupq_n_f32(vs[0]);
    let b1 = vdupq_n_f32(vs[1]);
    let b2 = vdupq_n_f32(vs[2]);
    let b3 = vdupq_n_f32(vs[3]);
    let py = yr.as_mut_ptr();
    let mut j = j0;
    while j + 4 <= n {
        let mut t = vld1q_f32(py.add(j) as *const f32);
        t = vfmaq_f32(t, vld1q_f32(rows[0].as_ptr().add(j)), b0);
        t = vfmaq_f32(t, vld1q_f32(rows[1].as_ptr().add(j)), b1);
        t = vfmaq_f32(t, vld1q_f32(rows[2].as_ptr().add(j)), b2);
        t = vfmaq_f32(t, vld1q_f32(rows[3].as_ptr().add(j)), b3);
        vst1q_f32(py.add(j), t);
        j += 4;
    }
    while j < n {
        yr[j] += rows[0][j] * vs[0] + rows[1][j] * vs[1] + rows[2][j] * vs[2] + rows[3][j] * vs[3];
        j += 1;
    }
}

/// yr[j0..] += row * v, 4-wide with a scalar tail.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn axpy1_from(yr: &mut [f32], j0: usize, row: &[f32], v: f32) {
    let n = yr.len();
    let b = vdupq_n_f32(v);
    let py = yr.as_mut_ptr();
    let mut j = j0;
    while j + 4 <= n {
        let t = vfmaq_f32(vld1q_f32(py.add(j) as *const f32), vld1q_f32(row.as_ptr().add(j)), b);
        vst1q_f32(py.add(j), t);
        j += 4;
    }
    while j < n {
        yr[j] += row[j] * v;
        j += 1;
    }
}

/// Y = A^T V for `k` vectors (4-row tiles shared across all `k`
/// accumulations; `matvec_t` is the `k == 1` case).
///
/// # Safety
/// The host must support NEON — guaranteed when routed here by the
/// dispatchers after a [`super::available`] check; assert it yourself on
/// direct calls.
#[target_feature(enable = "neon")]
pub unsafe fn matmul_t(a: &ColumnBlockView, v: &[f32], k: usize, y: &mut [f32]) {
    let (m, n) = (a.rows(), a.cols());
    y.fill(0.0);
    let mut i = 0;
    while i + 4 <= m {
        let rows = [a.row(i), a.row(i + 1), a.row(i + 2), a.row(i + 3)];
        for r in 0..k {
            let vr = &v[r * m..(r + 1) * m];
            let vs = [vr[i], vr[i + 1], vr[i + 2], vr[i + 3]];
            axpy4_from(&mut y[r * n..(r + 1) * n], 0, rows, vs);
        }
        i += 4;
    }
    while i < m {
        let row = a.row(i);
        for r in 0..k {
            axpy1_from(&mut y[r * n..(r + 1) * n], 0, row, v[r * m + i]);
        }
        i += 1;
    }
}

/// G += A^T A (upper triangle computed 4-wide then mirrored; accumulation
/// across calls composes exactly like the scalar variant).
///
/// # Safety
/// The host must support NEON — guaranteed when routed here by the
/// dispatchers after a [`super::available`] check; assert it yourself on
/// direct calls.
#[target_feature(enable = "neon")]
pub unsafe fn gram(a: &ColumnBlockView, g: &mut [f32]) {
    let n = a.cols();
    let m = a.rows();
    let mut i = 0;
    while i + 4 <= m {
        let rows = [a.row(i), a.row(i + 1), a.row(i + 2), a.row(i + 3)];
        for j in 0..n {
            let vs = [rows[0][j], rows[1][j], rows[2][j], rows[3][j]];
            axpy4_from(&mut g[j * n..(j + 1) * n], j, rows, vs);
        }
        i += 4;
    }
    while i < m {
        let row = a.row(i);
        for j in 0..n {
            axpy1_from(&mut g[j * n..(j + 1) * n], j, row, row[j]);
        }
        i += 1;
    }
    mirror_upper(g, n);
}

// ---------------------------------------------------------------- CSR

/// Sparse row dot with a manual 4-entry gather (NEON has no hardware
/// gather): values loaded as one lane, the four `x` operands assembled on
/// the stack, FMA'd, shared tail for the remainder.  Padded runs (see
/// `CsrBlockView::row_lanes`) land entirely in full lanes.
#[inline]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn sparse_dot(cols: &[u32], vals: &[f32], col0: u32, x: &[f32]) -> f32 {
    let n = cols.len();
    debug_assert_eq!(n, vals.len());
    let mut acc = vdupq_n_f32(0.0);
    let mut gather = [0.0f32; 4];
    let mut i = 0usize;
    while i + 4 <= n {
        gather[0] = x[(cols[i] - col0) as usize];
        gather[1] = x[(cols[i + 1] - col0) as usize];
        gather[2] = x[(cols[i + 2] - col0) as usize];
        gather[3] = x[(cols[i + 3] - col0) as usize];
        acc = vfmaq_f32(acc, vld1q_f32(vals.as_ptr().add(i)), vld1q_f32(gather.as_ptr()));
        i += 4;
    }
    vaddvq_f32(acc) + dot_sparse_tail(&cols[i..], &vals[i..], col0, x)
}

/// y = A x over a CSR block view.
///
/// # Safety
/// The host must support NEON — guaranteed when routed here by the
/// dispatchers after a [`super::available`] check; assert it yourself on
/// direct calls.
#[target_feature(enable = "neon")]
pub unsafe fn spmv(a: &CsrBlockView, x: &[f32], y: &mut [f32]) {
    let col0 = a.col0();
    for (i, yi) in y.iter_mut().enumerate() {
        let (cols, vals) = a.row_lanes(i);
        *yi = sparse_dot(cols, vals, col0, x);
    }
}

/// Y = A X for `k` right-hand sides (shares [`sparse_dot`] with [`spmv`],
/// so `k == 1` is bit-identical).
///
/// # Safety
/// The host must support NEON — guaranteed when routed here by the
/// dispatchers after a [`super::available`] check; assert it yourself on
/// direct calls.
#[target_feature(enable = "neon")]
pub unsafe fn spmm(a: &CsrBlockView, x: &[f32], k: usize, y: &mut [f32]) {
    let (m, n) = (a.rows(), a.cols());
    let col0 = a.col0();
    for i in 0..m {
        let (cols, vals) = a.row_lanes(i);
        for r in 0..k {
            y[r * m + i] = sparse_dot(cols, vals, col0, &x[r * n..(r + 1) * n]);
        }
    }
}

/// Y = A^T V for `k` vectors: values scaled 4 at a time, scattered with
/// scalar stores (no scatter instruction on NEON either).
///
/// # Safety
/// The host must support NEON — guaranteed when routed here by the
/// dispatchers after a [`super::available`] check; assert it yourself on
/// direct calls.
#[target_feature(enable = "neon")]
pub unsafe fn spmm_t(a: &CsrBlockView, v: &[f32], k: usize, y: &mut [f32]) {
    let (m, n) = (a.rows(), a.cols());
    let col0 = a.col0();
    y.fill(0.0);
    let mut prod = [0.0f32; 4];
    for i in 0..m {
        let (cols, vals) = a.row(i);
        let len = cols.len();
        if len == 0 {
            continue;
        }
        for r in 0..k {
            let vi = v[r * m + i];
            let b = vdupq_n_f32(vi);
            let yr = &mut y[r * n..(r + 1) * n];
            let mut j = 0usize;
            while j + 4 <= len {
                vst1q_f32(prod.as_mut_ptr(), vmulq_f32(vld1q_f32(vals.as_ptr().add(j)), b));
                for (t, &pt) in prod.iter().enumerate() {
                    yr[(cols[j + t] - col0) as usize] += pt;
                }
                j += 4;
            }
            while j < len {
                yr[(cols[j] - col0) as usize] += vals[j] * vi;
                j += 1;
            }
        }
    }
}

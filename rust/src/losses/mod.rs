//! The paper's model zoo: SLinR (squared), SLogR (logistic), SSVM (hinge),
//! SSR (softmax) — each a separable convex loss `sum_i phi(pred_i; b_i)`.
//!
//! A `Loss` supplies the three operations the stack needs:
//!   * `value`        — objective reporting / baselines
//!   * `grad_pred`    — gradient in prediction space (IHT & Lasso-path use)
//!   * `omega_update` — the separable prox of Eq. (21), the node-level
//!     omega-bar step.  The native implementations here mirror the Pallas
//!     kernels (`python/compile/kernels/prox.py`) exactly — same math, same
//!     damping — so the backend-parity tests can compare trajectories.
//!
//! Labels are stored row-major `(m, width)`: width 1 for the scalar losses
//! (values, or ±1 for classification), `k` one-hot columns for softmax.

pub mod scalar;
/// Multinomial softmax loss (SSR).
pub mod softmax;

pub use scalar::{Hinge, Logistic, Squared};
pub use softmax::Softmax;

/// Which of the paper's four losses a run minimizes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LossKind {
    /// Squared loss — sparse linear regression (SLS).
    Squared,
    /// Logistic loss — sparse logistic regression (SLogR).
    Logistic,
    /// Hinge loss — sparse SVM (SSVM).
    Hinge,
    /// Softmax cross-entropy — sparse softmax regression (SSR).
    Softmax,
}

impl LossKind {
    /// Parse a CLI/JSON loss name (paper aliases accepted).
    pub fn parse(name: &str) -> anyhow::Result<LossKind> {
        match name {
            "squared" | "sls" | "linreg" => Ok(LossKind::Squared),
            "logistic" | "slogr" => Ok(LossKind::Logistic),
            "hinge" | "svm" | "ssvm" => Ok(LossKind::Hinge),
            "softmax" | "ssr" => Ok(LossKind::Softmax),
            other => anyhow::bail!("unknown loss `{other}`"),
        }
    }

    /// Canonical name (inverse of [`LossKind::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            LossKind::Squared => "squared",
            LossKind::Logistic => "logistic",
            LossKind::Hinge => "hinge",
            LossKind::Softmax => "softmax",
        }
    }
}

/// A separable convex loss `sum_i phi(pred_i; b_i)` with the three
/// operations the stack needs.
pub trait Loss: Send + Sync {
    /// Which loss this is.
    fn kind(&self) -> LossKind;
    /// Human-readable name for reports.
    fn name(&self) -> &'static str;
    /// Columns of the prediction matrix (1, or k for softmax).
    fn width(&self) -> usize;
    /// Total loss over predictions `pred` (row-major (m, width)).
    fn value(&self, pred: &[f32], labels: &[f32]) -> f64;
    /// d(loss)/d(pred), written into `out` (same shape as `pred`).
    fn grad_pred(&self, pred: &[f32], labels: &[f32], out: &mut [f32]);
    /// Separable omega-bar prox (Eq. 21): per row solve
    ///   min_w phi(M w; b) + (M rho / 2) ||w - c||^2
    fn omega_update(&self, labels: &[f32], c: &[f32], m_blocks: f64, rho: f64, out: &mut [f32]);
}

/// Construct a loss by kind (softmax needs the class count).
pub fn make_loss(kind: LossKind, classes: usize) -> Box<dyn Loss> {
    match kind {
        LossKind::Squared => Box::new(Squared),
        LossKind::Logistic => Box::new(Logistic),
        LossKind::Hinge => Box::new(Hinge),
        LossKind::Softmax => Box::new(Softmax::new(classes)),
    }
}

#[cfg(test)]
pub(crate) mod test_util {
    use super::Loss;

    /// Finite-difference check of `grad_pred` at a random point.
    pub fn check_grad(loss: &dyn Loss, pred: &[f32], labels: &[f32], tol: f64) {
        let mut grad = vec![0.0f32; pred.len()];
        loss.grad_pred(pred, labels, &mut grad);
        let h = 1e-3f32;
        for i in 0..pred.len() {
            let mut p = pred.to_vec();
            p[i] += h;
            let up = loss.value(&p, labels);
            p[i] -= 2.0 * h;
            let dn = loss.value(&p, labels);
            let fd = (up - dn) / (2.0 * h as f64);
            assert!(
                (fd - grad[i] as f64).abs() < tol * (1.0 + fd.abs()),
                "grad[{i}] = {} vs fd {}",
                grad[i],
                fd
            );
        }
    }

    /// Check omega_update satisfies first-order optimality via the loss's
    /// own grad: M phi'(M w) + M rho (w - c) ~= 0 (smooth losses only).
    pub fn check_omega_stationarity(
        loss: &dyn Loss,
        labels: &[f32],
        c: &[f32],
        m_blocks: f64,
        rho: f64,
        tol: f64,
    ) {
        let mut w = vec![0.0f32; c.len()];
        loss.omega_update(labels, c, m_blocks, rho, &mut w);
        let scaled: Vec<f32> = w.iter().map(|&x| x * m_blocks as f32).collect();
        let mut g = vec![0.0f32; c.len()];
        loss.grad_pred(&scaled, labels, &mut g);
        for i in 0..c.len() {
            let total =
                m_blocks * g[i] as f64 + m_blocks * rho * (w[i] as f64 - c[i] as f64);
            assert!(total.abs() < tol, "omega grad[{i}] = {total}");
        }
    }
}

//! Scalar (width-1) losses: squared, logistic, hinge.
//!
//! Prox derivations (per sample, `h(w) = phi(M w; b) + (M rho / 2)(w-c)^2`):
//!
//! squared  phi(p) = (p - b)^2
//!          h'(w) = 2M(Mw - b) + M rho (w - c) = 0
//!                -> w = (2b + rho c) / (2M + rho)
//!
//! logistic phi(p) = log(1 + exp(-b p)), b in {-1, +1}
//!          Newton on h'(w) = -M b sigma(-bMw) + M rho (w - c),
//!          h'' = M^2 sigma' + M rho  (strongly convex, sigma' <= 1/4)
//!
//! hinge    phi(p) = max(0, 1 - b p); with s = bMc:
//!            s >= 1          -> w = c
//!            s <= 1 - M/rho  -> w = c + b / rho
//!            otherwise       -> w = b / M   (the kink)

use super::{Loss, LossKind};

/// Squared loss `(p - b)^2` — sparse linear regression (SLS).
pub struct Squared;

impl Loss for Squared {
    fn kind(&self) -> LossKind {
        LossKind::Squared
    }
    fn name(&self) -> &'static str {
        "squared"
    }
    fn width(&self) -> usize {
        1
    }

    fn value(&self, pred: &[f32], labels: &[f32]) -> f64 {
        pred.iter()
            .zip(labels)
            .map(|(&p, &b)| {
                let d = (p - b) as f64;
                d * d
            })
            .sum()
    }

    fn grad_pred(&self, pred: &[f32], labels: &[f32], out: &mut [f32]) {
        for ((o, &p), &b) in out.iter_mut().zip(pred).zip(labels) {
            *o = 2.0 * (p - b);
        }
    }

    fn omega_update(&self, labels: &[f32], c: &[f32], m_blocks: f64, rho: f64, out: &mut [f32]) {
        let m = m_blocks as f32;
        let r = rho as f32;
        for ((o, &b), &ci) in out.iter_mut().zip(labels).zip(c) {
            *o = (2.0 * b + r * ci) / (2.0 * m + r);
        }
    }
}

/// Logistic loss `log(1 + exp(-b p))` — sparse logistic regression.
pub struct Logistic;

pub(crate) const LOGISTIC_NEWTON_ITERS: usize = 12;

impl Loss for Logistic {
    fn kind(&self) -> LossKind {
        LossKind::Logistic
    }
    fn name(&self) -> &'static str {
        "logistic"
    }
    fn width(&self) -> usize {
        1
    }

    fn value(&self, pred: &[f32], labels: &[f32]) -> f64 {
        pred.iter()
            .zip(labels)
            .map(|(&p, &b)| {
                let z = -(b as f64) * p as f64;
                // log(1 + e^z), stably
                if z > 0.0 {
                    z + (1.0 + (-z).exp()).ln()
                } else {
                    (1.0 + z.exp()).ln()
                }
            })
            .sum()
    }

    fn grad_pred(&self, pred: &[f32], labels: &[f32], out: &mut [f32]) {
        for ((o, &p), &b) in out.iter_mut().zip(pred).zip(labels) {
            let z = (b as f64) * p as f64;
            let sig = 1.0 / (1.0 + z.exp()); // sigma(-bp)
            *o = (-(b as f64) * sig) as f32;
        }
    }

    fn omega_update(&self, labels: &[f32], c: &[f32], m_blocks: f64, rho: f64, out: &mut [f32]) {
        let m = m_blocks;
        for ((o, &b), &ci) in out.iter_mut().zip(labels).zip(c) {
            let b = b as f64;
            let ci = ci as f64;
            let mut w = ci;
            for _ in 0..LOGISTIC_NEWTON_ITERS {
                let sig = 1.0 / (1.0 + (b * m * w).exp()); // sigma(-bMw)
                let grad = -m * b * sig + m * rho * (w - ci);
                let hess = m * m * sig * (1.0 - sig) + m * rho;
                w -= grad / hess;
            }
            *o = w as f32;
        }
    }
}

/// Hinge loss `max(0, 1 - b p)` — sparse SVM.
pub struct Hinge;

impl Loss for Hinge {
    fn kind(&self) -> LossKind {
        LossKind::Hinge
    }
    fn name(&self) -> &'static str {
        "hinge"
    }
    fn width(&self) -> usize {
        1
    }

    fn value(&self, pred: &[f32], labels: &[f32]) -> f64 {
        pred.iter()
            .zip(labels)
            .map(|(&p, &b)| (1.0 - (b * p) as f64).max(0.0))
            .sum()
    }

    fn grad_pred(&self, pred: &[f32], labels: &[f32], out: &mut [f32]) {
        // subgradient: -b on the violating side, 0 elsewhere
        for ((o, &p), &b) in out.iter_mut().zip(pred).zip(labels) {
            *o = if (b * p) < 1.0 { -b } else { 0.0 };
        }
    }

    fn omega_update(&self, labels: &[f32], c: &[f32], m_blocks: f64, rho: f64, out: &mut [f32]) {
        let m = m_blocks;
        for ((o, &b), &ci) in out.iter_mut().zip(labels).zip(c) {
            let b = b as f64;
            let ci = ci as f64;
            let s = b * m * ci;
            let w = if s >= 1.0 {
                ci
            } else if s <= 1.0 - m / rho {
                ci + b / rho
            } else {
                b / m
            };
            *o = w as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_util::{check_grad, check_omega_stationarity};
    use super::*;
    use crate::util::rng::Rng;

    fn random_preds(rng: &mut Rng, m: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let pred: Vec<f32> = (0..m).map(|_| rng.normal_f32()).collect();
        let real: Vec<f32> = (0..m).map(|_| rng.normal_f32()).collect();
        let sign: Vec<f32> = (0..m)
            .map(|_| if rng.uniform() < 0.5 { -1.0 } else { 1.0 })
            .collect();
        (pred, real, sign)
    }

    #[test]
    fn squared_value_and_grad() {
        assert_eq!(Squared.value(&[2.0, 0.0], &[1.0, 1.0]), 2.0);
        let mut rng = Rng::seed_from(1);
        let (pred, real, _) = random_preds(&mut rng, 16);
        check_grad(&Squared, &pred, &real, 1e-3);
    }

    #[test]
    fn logistic_value_and_grad() {
        // phi(0) = ln 2
        let v = Logistic.value(&[0.0], &[1.0]);
        assert!((v - std::f64::consts::LN_2).abs() < 1e-9);
        let mut rng = Rng::seed_from(2);
        let (pred, _, sign) = random_preds(&mut rng, 16);
        check_grad(&Logistic, &pred, &sign, 1e-3);
    }

    #[test]
    fn hinge_value() {
        // b=1, p=0.5 -> 0.5; b=1, p=2 -> 0
        assert_eq!(Hinge.value(&[0.5, 2.0], &[1.0, 1.0]), 0.5);
    }

    #[test]
    fn squared_omega_stationarity() {
        let mut rng = Rng::seed_from(3);
        let (_, real, _) = random_preds(&mut rng, 32);
        let c: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
        check_omega_stationarity(&Squared, &real, &c, 4.0, 2.0, 1e-3);
    }

    #[test]
    fn logistic_omega_stationarity() {
        let mut rng = Rng::seed_from(4);
        let (_, _, sign) = random_preds(&mut rng, 32);
        let c: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
        check_omega_stationarity(&Logistic, &sign, &c, 2.0, 1.5, 1e-3);
    }

    #[test]
    fn hinge_omega_is_global_min_on_grid() {
        let mut rng = Rng::seed_from(5);
        let m_blocks = 2.0;
        let rho = 3.0;
        let labels: Vec<f32> = (0..16)
            .map(|_| if rng.uniform() < 0.5 { -1.0 } else { 1.0 })
            .collect();
        let c: Vec<f32> = (0..16).map(|_| rng.normal_f32()).collect();
        let mut w = vec![0.0f32; 16];
        Hinge.omega_update(&labels, &c, m_blocks, rho, &mut w);
        for i in 0..16 {
            let h = |wv: f64| {
                (1.0 - labels[i] as f64 * m_blocks * wv).max(0.0)
                    + m_blocks * rho / 2.0 * (wv - c[i] as f64).powi(2)
            };
            let h_star = h(w[i] as f64);
            for j in 0..800 {
                let cand = -4.0 + j as f64 * 0.01;
                assert!(h_star <= h(cand) + 1e-6, "i={i} cand={cand}");
            }
        }
    }

    #[test]
    fn omega_matches_limit_cases() {
        // rho -> infinity: w -> c for every loss.
        let labels = vec![1.0f32, -1.0];
        let c = vec![0.3f32, -0.7];
        for loss in [&Squared as &dyn Loss, &Logistic, &Hinge] {
            let mut w = vec![0.0f32; 2];
            loss.omega_update(&labels, &c, 2.0, 1e9, &mut w);
            for (a, b) in w.iter().zip(&c) {
                assert!((a - b).abs() < 1e-3, "{}", loss.name());
            }
        }
    }
}

//! Softmax (SSR) loss: per sample `phi(p; y) = logsumexp(p) - p_y` over K
//! classes.  The omega prox is a K-dimensional damped Newton with the exact
//! softmax Hessian inverted per sample by Sherman-Morrison — identical
//! structure to the `omega_softmax` Pallas kernel.

use super::{Loss, LossKind};

/// Softmax cross-entropy over `k` classes — sparse softmax regression.
pub struct Softmax {
    k: usize,
}

impl Softmax {
    /// Softmax loss over `k >= 2` classes.
    pub fn new(k: usize) -> Softmax {
        assert!(k >= 2, "softmax needs >= 2 classes");
        Softmax { k }
    }

    fn softmax_row(logits: &[f64], out: &mut [f64]) {
        let mx = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut sum = 0.0;
        for (o, &l) in out.iter_mut().zip(logits) {
            *o = (l - mx).exp();
            sum += *o;
        }
        for o in out.iter_mut() {
            *o /= sum;
        }
    }
}

const NEWTON_ITERS: usize = 12;
const STEP_MENU: [f64; 5] = [1.0, 0.5, 0.25, 0.125, 0.03125];

impl Loss for Softmax {
    fn kind(&self) -> LossKind {
        LossKind::Softmax
    }
    fn name(&self) -> &'static str {
        "softmax"
    }
    fn width(&self) -> usize {
        self.k
    }

    fn value(&self, pred: &[f32], labels: &[f32]) -> f64 {
        let k = self.k;
        let m = pred.len() / k;
        let mut total = 0.0;
        for i in 0..m {
            let row = &pred[i * k..(i + 1) * k];
            let lab = &labels[i * k..(i + 1) * k];
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
            let lse = mx
                + row
                    .iter()
                    .map(|&p| ((p as f64) - mx).exp())
                    .sum::<f64>()
                    .ln();
            let py: f64 = row
                .iter()
                .zip(lab)
                .map(|(&p, &y)| p as f64 * y as f64)
                .sum();
            total += lse - py;
        }
        total
    }

    fn grad_pred(&self, pred: &[f32], labels: &[f32], out: &mut [f32]) {
        let k = self.k;
        let m = pred.len() / k;
        let mut logits = vec![0.0f64; k];
        let mut probs = vec![0.0f64; k];
        for i in 0..m {
            for (l, &p) in logits.iter_mut().zip(&pred[i * k..(i + 1) * k]) {
                *l = p as f64;
            }
            Self::softmax_row(&logits, &mut probs);
            for j in 0..k {
                out[i * k + j] = (probs[j] - labels[i * k + j] as f64) as f32;
            }
        }
    }

    fn omega_update(&self, labels: &[f32], c: &[f32], m_blocks: f64, rho: f64, out: &mut [f32]) {
        let k = self.k;
        let m = c.len() / k;
        let mb = m_blocks;
        let mut w = vec![0.0f64; k];
        let mut logits = vec![0.0f64; k];
        let mut s = vec![0.0f64; k];
        let mut step = vec![0.0f64; k];
        let mut cand = vec![0.0f64; k];

        for i in 0..m {
            let ci = &c[i * k..(i + 1) * k];
            let yi = &labels[i * k..(i + 1) * k];
            for (wj, &cj) in w.iter_mut().zip(ci) {
                *wj = cj as f64;
            }
            let obj = |wv: &[f64], logits: &mut [f64], s: &mut [f64]| -> f64 {
                for (l, &x) in logits.iter_mut().zip(wv.iter()) {
                    *l = mb * x;
                }
                let mx = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let lse = mx + logits.iter().map(|&l| (l - mx).exp()).sum::<f64>().ln();
                let py: f64 = wv
                    .iter()
                    .zip(yi)
                    .map(|(&x, &y)| mb * x * y as f64)
                    .sum();
                let quad: f64 = wv
                    .iter()
                    .zip(ci)
                    .map(|(&x, &cj)| (x - cj as f64) * (x - cj as f64))
                    .sum();
                let _ = s;
                lse - py + mb * rho / 2.0 * quad
            };

            for _ in 0..NEWTON_ITERS {
                for (l, &x) in logits.iter_mut().zip(w.iter()) {
                    *l = mb * x;
                }
                Self::softmax_row(&logits, &mut s);
                // Newton step via Sherman-Morrison on H = diag(d) - u u^T,
                // d = M^2 s + M rho, u = M s; stable denominator
                // rho * sum(u/d) (== 1 - u^T D^-1 u exactly, since sum s = 1).
                let mut dot_udg = 0.0;
                let mut sum_du = 0.0;
                let mut dinv_g = vec![0.0f64; k];
                let mut dinv_u = vec![0.0f64; k];
                for j in 0..k {
                    let grad = mb * (s[j] - yi[j] as f64) + mb * rho * (w[j] - ci[j] as f64);
                    let d = mb * mb * s[j] + mb * rho;
                    let u = mb * s[j];
                    dinv_g[j] = grad / d;
                    dinv_u[j] = u / d;
                    dot_udg += u * dinv_g[j];
                    sum_du += dinv_u[j];
                }
                let denom = rho * sum_du;
                for j in 0..k {
                    step[j] = dinv_g[j] + dinv_u[j] * (dot_udg / denom);
                }
                // damped: best-of-menu line search (monotone descent)
                let mut best_f = obj(&w, &mut logits, &mut s);
                let mut best_eta = 0.0;
                for &eta in &STEP_MENU {
                    for j in 0..k {
                        cand[j] = w[j] - eta * step[j];
                    }
                    let f = obj(&cand, &mut logits, &mut s);
                    if f < best_f {
                        best_f = f;
                        best_eta = eta;
                    }
                }
                if best_eta == 0.0 {
                    break; // converged (no step improves)
                }
                for j in 0..k {
                    w[j] -= best_eta * step[j];
                }
            }
            for j in 0..k {
                out[i * k + j] = w[j] as f32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_util::{check_grad, check_omega_stationarity};
    use super::*;
    use crate::util::rng::Rng;

    fn onehot(rng: &mut Rng, m: usize, k: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * k];
        for i in 0..m {
            out[i * k + rng.below(k)] = 1.0;
        }
        out
    }

    #[test]
    fn value_uniform_logits() {
        // all-zero logits: phi = ln K per sample
        let sm = Softmax::new(4);
        let labels = vec![1.0, 0.0, 0.0, 0.0];
        let v = sm.value(&[0.0; 4], &labels);
        assert!((v - (4.0f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn grad_matches_finite_difference() {
        let mut rng = Rng::seed_from(6);
        let sm = Softmax::new(5);
        let pred: Vec<f32> = (0..20).map(|_| rng.normal_f32()).collect();
        let labels = onehot(&mut rng, 4, 5);
        check_grad(&sm, &pred, &labels, 2e-3);
    }

    #[test]
    fn omega_stationarity() {
        let mut rng = Rng::seed_from(7);
        let sm = Softmax::new(4);
        let labels = onehot(&mut rng, 12, 4);
        let c: Vec<f32> = (0..48).map(|_| rng.normal_f32()).collect();
        check_omega_stationarity(&sm, &labels, &c, 2.0, 1.5, 5e-3);
    }

    #[test]
    fn omega_hard_regime_still_converges() {
        // the regime that broke undamped Newton: big M, small rho
        let mut rng = Rng::seed_from(8);
        let sm = Softmax::new(4);
        let labels = onehot(&mut rng, 16, 4);
        let c: Vec<f32> = (0..64).map(|_| rng.normal_f32()).collect();
        check_omega_stationarity(&sm, &labels, &c, 4.0, 0.5, 2e-2);
    }

    #[test]
    fn omega_rho_infinity_returns_c() {
        let mut rng = Rng::seed_from(9);
        let sm = Softmax::new(3);
        let labels = onehot(&mut rng, 8, 3);
        let c: Vec<f32> = (0..24).map(|_| rng.normal_f32()).collect();
        let mut w = vec![0.0f32; 24];
        sm.omega_update(&labels, &c, 2.0, 1e9, &mut w);
        for (a, b) in w.iter().zip(&c) {
            assert!((a - b).abs() < 1e-3);
        }
    }
}

//! psfit — the PsFiT-rs command-line launcher.
//!
//! Subcommands:
//!   train   — fit a sparse model on a synthetic distributed dataset
//!             (--shards maps PSD1 files out of core; --minibatch runs
//!             seeded mini-batch rounds)
//!   convert — stream LIBSVM/CSV input into per-node PSD1 shard files
//!             in bounded memory (what train --shards maps)
//!   path    — warm-started sparsity-path sweep over descending budgets
//!             (checkpoint/resume via --checkpoint)
//!   fig1    — regenerate Figure 1 (residual convergence vs rho_b)
//!   table1  — regenerate Table 1 (Bi-cADMM vs MIP vs Lasso)
//!   fig2    — regenerate Figure 2 (feature scaling, CPU vs GPU backend)
//!   fig3    — regenerate Figure 3 (sample scaling)
//!   fig4    — regenerate Figure 4 (CPU<->GPU transfer time)
//!   straggler — sync vs async coordination under a 1x-16x slow node
//!   bench   — kernel micro-benchmarks (scalar vs SIMD, serial vs
//!             pooled); writes BENCH_kernels.json.  With --solver:
//!             end-to-end ADMM rounds/sec + time-to-tolerance; writes
//!             BENCH_solver.json.  With --transport: in-process vs
//!             localhost-socket round cost, merged into the same report
//!   pathbench — warm vs cold path sweeps across the density grid;
//!             writes BENCH_path.json
//!   worker  — standalone node process; prints its bound address and
//!             serves socket-transport coordinators until killed
//!             (--reconnect re-binds a dead listener instead of exiting)
//!   chaos   — deterministic fault-injection harness: runs socket fits
//!             through a seeded chaos proxy and checks support parity
//!             against a clean run.  With --numerics: poisons reply
//!             vectors with NaN/Inf/1e300 on a seeded schedule and
//!             asserts the reply guard quarantines every one.  With
//!             --coordinator: SIGKILLs and restarts the serve daemon on
//!             a seeded schedule and asserts journal recovery lands
//!             every job `done` with bit-identical artifacts
//!   serve   — multi-tenant fit/predict daemon over a worker fleet
//!             (--state-dir journals jobs + models durably; SIGTERM
//!             drains gracefully, kill -9 recovers on restart)
//!   submit / predict / jobs — client commands against `psfit serve`
//!   info    — print artifact manifest + platform info
//!
//! Scaled-down grids by default; `--full` switches to the paper's sizes.
//! See docs/GUIDE.md for a walkthrough of every knob.

use psfit::admm::{SolveOptions, SolveResult};
use psfit::config::{BackendKind, Config, CoordinationKind, TransportKind};
use psfit::data::{Dataset, SparseMode, SyntheticSpec, Task};
use psfit::driver;
use psfit::harness;
use psfit::losses::LossKind;
use psfit::network::socket::wire::JobSpec;
use psfit::network::socket::{run_worker, WorkerOpts};
use psfit::path;
use psfit::serve::{run_serve, JobPhase, ServeClient, ServeOpts};
use psfit::sparsity::support_f1;
use psfit::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let args = Args::parse_env()?;
    match args.subcommand.as_deref() {
        Some("train") => train(&args),
        Some("convert") => convert_cmd(&args),
        Some("path") => path_cmd(&args),
        Some("worker") => {
            if let Some(isa) = args.opt("isa") {
                let active =
                    psfit::linalg::simd::select(psfit::linalg::simd::IsaChoice::parse(isa)?)?;
                eprintln!("kernel isa:  {} (requested {isa})", active.name());
            }
            let opts = WorkerOpts {
                listen: args.opt("listen").unwrap_or("127.0.0.1:0").to_string(),
                reconnect: args.flag("reconnect"),
            };
            args.reject_unknown()?;
            run_worker(&opts)
        }
        Some("chaos") => {
            if args.flag("coordinator") {
                // coordinator kill/restart chaos: SIGKILL the serve daemon
                // mid-fit on a seeded schedule, assert journal recovery
                let opts = harness::coordinator::CoordinatorChaosOpts {
                    quick: args.flag("quick"),
                    seed: args.get("seed", 0xC00D)?,
                    kills: args.get("kills", 0)?,
                    jobs: args.get("jobs", 0)?,
                };
                args.reject_unknown()?;
                return harness::coordinator_chaos(&opts);
            }
            if args.flag("numerics") {
                // numerical poison harness: NaN/Inf/1e300 in reply vectors
                let opts = harness::numerics::NumericsOpts {
                    quick: args.flag("quick"),
                    seed: args.get("seed", 0xBADF1A)?,
                    faults: args.opt("faults").map(String::from),
                    nodes: args.get("nodes", 3)?,
                };
                args.reject_unknown()?;
                return harness::numerics(&opts);
            }
            let opts = harness::chaos::ChaosOpts {
                quick: args.flag("quick"),
                seed: args.get("seed", 0xC4A05)?,
                faults: args.opt("faults").map(String::from),
                nodes: args.get("nodes", 3)?,
            };
            args.reject_unknown()?;
            harness::chaos(&opts)
        }
        Some("serve") => {
            // a --config file's `serve` section supplies defaults; explicit
            // flags always win
            let file_cfg = match args.opt("config") {
                Some(path) => Config::from_json_file(std::path::Path::new(path))?,
                None => Config::default(),
            };
            let state_dir = match args.opt("state-dir") {
                Some(d) => Some(d.to_string()),
                None if !file_cfg.serve.state_dir.is_empty() => {
                    Some(file_cfg.serve.state_dir.clone())
                }
                None => None,
            };
            let opts = ServeOpts {
                listen: args.opt("listen").unwrap_or("127.0.0.1:7700").to_string(),
                workers: match args.opt("workers") {
                    Some(w) => parse_list(w, "--workers")?,
                    None => Vec::new(),
                },
                local_fleet: args.get("local-fleet", 0)?,
                connect_timeout_ms: args.get("connect-timeout-ms", 3000)?,
                read_timeout_ms: args.get("read-timeout-ms", 30_000)?,
                connect_retries: args.get("connect-retries", 3)?,
                state_dir,
                drain_grace_ms: args.get("drain-grace-ms", file_cfg.serve.drain_grace_ms)?,
                journal: file_cfg.serve.journal,
            };
            args.reject_unknown()?;
            run_serve(&opts)
        }
        Some("submit") => submit_cmd(&args),
        Some("predict") => predict_cmd(&args),
        Some("jobs") => jobs_cmd(&args),
        Some("pathbench") => {
            let opts = harness::path::PathBenchOpts {
                quick: args.flag("quick"),
                json: args.opt("json").unwrap_or("BENCH_path.json").to_string(),
                out: args.opt("out").map(String::from),
            };
            args.reject_unknown()?;
            let table = harness::path_bench(&opts)?;
            harness::emit(&table, opts.out.as_deref())
        }
        Some("fig1") => {
            let opts = harness::fig1::Fig1Opts {
                full: args.flag("full"),
                iters: args.get("iters", 60)?,
                backend: BackendKind::parse(args.opt("backend").unwrap_or("native"))?,
                out: args.opt("out").map(String::from),
            };
            args.reject_unknown()?;
            let table = harness::fig1(&opts)?;
            harness::emit(&table, opts.out.as_deref())
        }
        Some("table1") => {
            let opts = harness::table1::Table1Opts {
                full: args.flag("full"),
                backend: BackendKind::parse(args.opt("backend").unwrap_or("xla"))?,
                mip_budget: args.get("mip-budget", 60.0)?,
                out: args.opt("out").map(String::from),
            };
            args.reject_unknown()?;
            let table = harness::table1(&opts)?;
            harness::emit(&table, opts.out.as_deref())
        }
        Some(cmd @ ("fig2" | "fig3")) => {
            let cmd = cmd.to_string();
            let opts = harness::scaling::ScalingOpts {
                full: args.flag("full"),
                iters: args.get("iters", 10)?,
                out: args.opt("out").map(String::from),
            };
            args.reject_unknown()?;
            let table = if cmd == "fig2" {
                harness::fig2(&opts)?
            } else {
                harness::fig3(&opts)?
            };
            harness::emit(&table, opts.out.as_deref())
        }
        Some("fig4") => {
            let opts = harness::fig4::Fig4Opts {
                full: args.flag("full"),
                iters: args.get("iters", 10)?,
                pcie_gbps: Some(args.get("pcie-gbps", 16.0)?),
                out: args.opt("out").map(String::from),
            };
            args.reject_unknown()?;
            let table = harness::fig4(&opts)?;
            harness::emit(&table, opts.out.as_deref())
        }
        Some("straggler") => {
            let opts = harness::straggler::StragglerOpts {
                full: args.flag("full"),
                nodes: args.get("nodes", 3)?,
                iters: args.get("iters", 12)?,
                base_ms: args.get("base-ms", 3.0)?,
                quorum: args.get("quorum", 0.5)?,
                max_staleness: args.get("staleness", 2)?,
                out: args.opt("out").map(String::from),
            };
            args.reject_unknown()?;
            let table = harness::straggler(&opts)?;
            harness::emit(&table, opts.out.as_deref())
        }
        Some("bench") => {
            if let Some(isa) = args.opt("isa") {
                let active =
                    psfit::linalg::simd::select(psfit::linalg::simd::IsaChoice::parse(isa)?)?;
                eprintln!("kernel isa:  {} (requested {isa})", active.name());
            }
            if args.flag("transport") {
                // transport round-cost benchmark -> merged into BENCH_solver.json
                let opts = harness::transport::TransportBenchOpts {
                    quick: args.flag("quick"),
                    json: args.opt("json").unwrap_or("BENCH_solver.json").to_string(),
                    out: args.opt("out").map(String::from),
                };
                args.reject_unknown()?;
                let table = harness::transport_bench(&opts)?;
                return harness::emit(&table, opts.out.as_deref());
            }
            if args.flag("solver") {
                // end-to-end solver benchmark -> BENCH_solver.json
                let opts = harness::solver::SolverBenchOpts {
                    quick: args.flag("quick"),
                    json: args.opt("json").unwrap_or("BENCH_solver.json").to_string(),
                    out: args.opt("out").map(String::from),
                };
                args.reject_unknown()?;
                let table = harness::solver_bench(&opts)?;
                return harness::emit(&table, opts.out.as_deref());
            }
            let opts = harness::kernels::KernelBenchOpts {
                quick: args.flag("quick"),
                threads: args.get("threads", 0)?,
                json: args
                    .opt("json")
                    .unwrap_or("BENCH_kernels.json")
                    .to_string(),
                out: args.opt("out").map(String::from),
            };
            args.reject_unknown()?;
            let table = harness::kernels(&opts)?;
            harness::emit(&table, opts.out.as_deref())
        }
        Some("info") => info(&args),
        Some(other) => {
            anyhow::bail!(
                "unknown subcommand `{other}` (try: train, convert, path, fig1..fig4, table1, straggler, bench, pathbench, worker, chaos, serve, submit, predict, jobs, info)"
            )
        }
        None => {
            eprintln!(
                "usage: psfit <train|convert|path|fig1|fig2|fig3|fig4|table1|straggler|bench|pathbench|worker|chaos|serve|submit|predict|jobs|info> [options]"
            );
            eprintln!("  e.g.  psfit train --n 1000 --m 8000 --nodes 4 --sparsity 0.8 --backend xla");
            eprintln!("        psfit train --threads 8             (pooled native block sweeps)");
            eprintln!("        psfit train --coordination async --quorum 0.75 --staleness 2");
            eprintln!("        psfit train --density 0.02 --sparse auto    (CSR data path)");
            eprintln!("        psfit train --libsvm data.svm --kappa 50    (real sparse data)");
            eprintln!("        psfit convert --libsvm data.svm --nodes 4 --out data   (PSD1 shards)");
            eprintln!("        psfit train --shards data.0.psd1,data.1.psd1 --kappa 50 (mmap, out of core)");
            eprintln!("        psfit train --minibatch 4096 --minibatch-seed 7  (seeded chunk rounds)");
            eprintln!("        psfit path --budgets 200,100,50     (warm-started sparsity path)");
            eprintln!("        psfit path --budgets 64,32 --rho-ladder 2.0,1.0 --checkpoint run.psc");
            eprintln!("        psfit train --isa scalar            (pin the kernel ISA; also PSFIT_ISA)");
            eprintln!("        psfit fig1 --out results/fig1.csv        (--full for paper sizes)");
            eprintln!("        psfit bench --quick                 (writes BENCH_kernels.json)");
            eprintln!("        psfit bench --solver --quick        (writes BENCH_solver.json)");
            eprintln!("        psfit bench --transport --quick     (merges transport rounds into it)");
            eprintln!("        psfit pathbench --quick             (writes BENCH_path.json)");
            eprintln!("        psfit worker --listen 127.0.0.1:0   (standalone node process)");
            eprintln!("        psfit worker --listen 127.0.0.1:7701 --reconnect   (self-healing worker)");
            eprintln!("        psfit train --transport socket --workers host1:7777,host2:7777");
            eprintln!("        psfit train --transport socket --rejoin --min-workers 2 --checkpoint fit.psf");
            eprintln!("        psfit chaos --quick                 (seeded fault-injection harness)");
            eprintln!("        psfit chaos --numerics --quick      (seeded NaN/Inf poison harness)");
            eprintln!("        psfit chaos --coordinator --quick   (seeded coordinator kill/restart)");
            eprintln!("        psfit train --deadline 5000         (abort cleanly after 5 s, best-so-far)");
            eprintln!("        psfit train --libsvm data.svm --sanitize    (drop non-finite rows)");
            eprintln!("        psfit serve --local-fleet 2         (fit/predict daemon)");
            eprintln!("        psfit serve --local-fleet 2 --state-dir /var/lib/psfit   (durable jobs)");
            eprintln!("        psfit submit --n 200 --m 1600 --wait && psfit predict --job 1 --features 3:0.5");
            Ok(())
        }
    }
}

/// Parse the flags `train` and `path` share: problem shape, storage
/// policy, solver penalties, coordination, and the optional LIBSVM
/// source.  Returns the configured run plus the synthetic spec used when
/// no real data file was given.
fn shared_config(args: &Args) -> anyhow::Result<(Config, SyntheticSpec, Option<String>)> {
    let n: usize = args.get("n", 1000)?;
    let m: usize = args.get("m", 8000)?;
    let nodes: usize = args.get("nodes", 4)?;
    let sparsity: f64 = args.get("sparsity", 0.8)?;
    let loss = LossKind::parse(args.opt("loss").unwrap_or("squared"))?;
    let classes: usize = args.get("classes", 10)?;
    let backend = BackendKind::parse(args.opt("backend").unwrap_or("native"))?;

    let mut cfg = match args.opt("config") {
        Some(path) => Config::from_json_file(std::path::Path::new(path))?,
        None => Config::default(),
    };
    cfg.loss = loss;
    cfg.classes = classes;
    cfg.platform.nodes = nodes;
    cfg.platform.backend = backend;
    cfg.platform.devices_per_node = args.get("devices", cfg.platform.devices_per_node)?;
    cfg.platform.threads = args.get("threads", cfg.platform.threads)?;
    if let Some(mode) = args.opt("sparse") {
        cfg.platform.sparse = SparseMode::parse(mode)?;
    }
    cfg.platform.sparse_threshold =
        args.get("sparse-threshold", cfg.platform.sparse_threshold)?;
    if let Some(isa) = args.opt("isa") {
        cfg.platform.isa = psfit::linalg::simd::IsaChoice::parse(isa)?;
    }
    if let Some(t) = args.opt("transport") {
        cfg.platform.transport = TransportKind::parse(t)?;
    }
    if let Some(w) = args.opt("workers") {
        cfg.platform.workers = parse_list(w, "--workers")?;
    }
    cfg.platform.connect_timeout_ms =
        args.get("connect-timeout-ms", cfg.platform.connect_timeout_ms)?;
    cfg.platform.read_timeout_ms = args.get("read-timeout-ms", cfg.platform.read_timeout_ms)?;
    cfg.platform.connect_retries = args.get("connect-retries", cfg.platform.connect_retries)?;
    if args.flag("rejoin") {
        cfg.platform.rejoin = true;
    }
    // platform.quorum is a worker head-count; --quorum (a fraction) is the
    // async coordinator's, so the socket knob gets its own flag name
    cfg.platform.quorum = args.get("min-workers", cfg.platform.quorum)?;
    // install the process-wide kernel ISA now — "selected once at startup"
    let active = psfit::linalg::simd::select(cfg.platform.isa)?;
    eprintln!("kernel isa:  {} (requested {})", active.name(), cfg.platform.isa.name());
    cfg.platform.validate()?;
    cfg.solver.rho_c = args.get("rho-c", cfg.solver.rho_c)?;
    cfg.solver.rho_b = args.get("rho-b", cfg.solver.rho_b)?;
    cfg.solver.rho_l = args.get("rho-l", cfg.solver.rho_l)?;
    cfg.solver.max_iters = args.get("iters", cfg.solver.max_iters)?;
    cfg.solver.inner_iters = args.get("inner-iters", cfg.solver.inner_iters)?;
    cfg.solver.deadline_ms = args.get("deadline", cfg.solver.deadline_ms)?;
    cfg.solver.minibatch = args.get("minibatch", cfg.solver.minibatch)?;
    cfg.solver.minibatch_seed = args.get("minibatch-seed", cfg.solver.minibatch_seed)?;
    if let Some(coord) = args.opt("coordination") {
        cfg.coordinator.coordination = CoordinationKind::parse(coord)?;
    }
    cfg.coordinator.quorum = args.get("quorum", cfg.coordinator.quorum)?;
    cfg.coordinator.max_staleness = args.get("staleness", cfg.coordinator.max_staleness)?;
    cfg.coordinator.heartbeat_ms = args.get("heartbeat-ms", cfg.coordinator.heartbeat_ms)?;
    // flags may have overlaid the file config — re-check cross-section rules
    cfg.validate_cross()?;

    let mut spec = SyntheticSpec::regression(n, m, nodes);
    spec.sparsity_level = sparsity;
    spec.density = args.get("density", 1.0)?;
    spec.seed = args.get("seed", 42)?;
    spec.task = match loss {
        LossKind::Squared => Task::Regression,
        LossKind::Logistic | LossKind::Hinge => Task::Binary,
        LossKind::Softmax => Task::Multiclass { k: classes },
    };
    let libsvm = args.opt("libsvm").map(String::from);
    Ok((cfg, spec, libsvm))
}

/// Materialize the dataset: load + re-split the LIBSVM file when one was
/// given (updating `cfg.platform.nodes` to the actual shard count),
/// otherwise generate the synthetic spec.
fn build_dataset(
    cfg: &mut Config,
    spec: &SyntheticSpec,
    libsvm: Option<&str>,
    sanitize: bool,
) -> anyhow::Result<Dataset> {
    match libsvm {
        Some(path) => {
            anyhow::ensure!(
                cfg.loss != LossKind::Softmax,
                "--libsvm files are scalar-label (use squared/logistic/hinge)"
            );
            let path_ref = std::path::Path::new(path);
            let mut ds = if sanitize {
                psfit::data::io::load_libsvm_sanitized(path_ref, None)?
            } else {
                psfit::data::io::load_libsvm(path_ref, None)?
            };
            // the file loads as one shard; honor --nodes by re-splitting
            // its rows across the requested cluster
            let nodes = cfg.platform.nodes;
            if nodes > 1 {
                anyhow::ensure!(
                    ds.total_samples() >= nodes,
                    "{path}: {} samples cannot fill {nodes} nodes",
                    ds.total_samples()
                );
                ds = ds.resplit(nodes);
            }
            cfg.platform.nodes = ds.nodes();
            eprintln!(
                "loaded {path}: {} samples x {} features, density {:.4}",
                ds.total_samples(),
                ds.n_features,
                ds.density()
            );
            Ok(ds)
        }
        None => Ok(spec.generate()),
    }
}

fn train(args: &Args) -> anyhow::Result<()> {
    let (mut cfg, spec, libsvm) = shared_config(args)?;
    cfg.solver.kappa = args.get("kappa", spec.kappa())?;
    if let Some(ck) = args.opt("checkpoint") {
        cfg.solver.checkpoint = ck.to_string();
    }
    cfg.solver.checkpoint_every = args.get("checkpoint-every", cfg.solver.checkpoint_every)?;
    let trace_out = args.opt("trace").map(String::from);
    let model_out = args.opt("model-out").map(String::from);
    let shards_in = args.opt("shards").map(String::from);
    let nodes_explicit = args.opt("nodes").is_some();
    let sanitize = args.flag("sanitize");
    args.reject_unknown()?;

    let ds = match &shards_in {
        Some(list) => {
            anyhow::ensure!(
                libsvm.is_none(),
                "--shards and --libsvm are mutually exclusive"
            );
            let paths: Vec<std::path::PathBuf> = list
                .split(',')
                .map(|s| std::path::PathBuf::from(s.trim()))
                .collect();
            anyhow::ensure!(
                !nodes_explicit || paths.len() == cfg.platform.nodes,
                "--nodes {} does not match the {} shard file(s) given",
                cfg.platform.nodes,
                paths.len()
            );
            let ds = psfit::data::open_dataset(&paths)?;
            cfg.platform.nodes = ds.nodes();
            eprintln!(
                "mapped {} PSD1 shard(s): {} samples x {} features ({})",
                ds.nodes(),
                ds.total_samples(),
                ds.n_features,
                ds.shards
                    .iter()
                    .map(|s| s.data.storage_name())
                    .collect::<Vec<_>>()
                    .join(", ")
            );
            ds
        }
        None => build_dataset(&mut cfg, &spec, libsvm.as_deref(), sanitize)?,
    };
    if libsvm.is_some() || shards_in.is_some() {
        cfg.solver.kappa = cfg.solver.kappa.min(ds.n_features * ds.width).max(1);
    }
    if cfg.solver.minibatch > 0 {
        // one line per distinct chunk count across the roster (usually one)
        let counts: std::collections::BTreeSet<usize> = ds
            .shards
            .iter()
            .map(|s| s.rows().div_ceil(cfg.solver.minibatch).max(1))
            .collect();
        for n_chunks in counts {
            eprintln!(
                "minibatch:   {} rows/chunk, {} chunk(s), schedule fingerprint {:#018x}",
                cfg.solver.minibatch,
                n_chunks,
                psfit::admm::minibatch::schedule_fingerprint(
                    cfg.solver.minibatch_seed,
                    n_chunks
                )
            );
        }
    }
    let backend = cfg.platform.backend;
    eprintln!(
        "training {} (n={}, m={}, N={}, kappa={}, backend={}, coordination={})",
        loss_name(cfg.loss),
        ds.n_features,
        ds.total_samples(),
        ds.nodes(),
        cfg.solver.kappa,
        backend.name(),
        cfg.coordinator.coordination.name()
    );
    eprintln!(
        "storage:     policy {} (threshold {}), data density {:.4}",
        cfg.platform.sparse.name(),
        cfg.platform.sparse_threshold,
        ds.density()
    );
    let run = harness::run_timed(&ds, &cfg, true)?;
    let res = &run.result;

    println!("converged:   {} in {} iterations", res.converged, res.iters);
    if res.timed_out {
        println!(
            "deadline:    solver.deadline_ms = {} hit; result is the best-so-far iterate",
            cfg.solver.deadline_ms
        );
    }
    if res.restarts > 0 {
        println!(
            "watchdog:    {} safeguarded restart(s) performed during the solve",
            res.restarts
        );
    }
    println!("setup:       {:.3} s", run.setup_seconds);
    println!("solve:       {:.3} s", run.solve_seconds);
    if let Some(rec) = res.trace.last() {
        println!(
            "residuals:   primal {:.3e}  dual {:.3e}  bilinear {:.3e}",
            rec.primal, rec.dual, rec.bilinear
        );
    }
    println!(
        "support F1:  {:.3} ({} recovered / {} true)",
        support_f1(&res.support, &ds.support_true),
        res.support.len(),
        ds.support_true.len()
    );
    println!(
        "transfers:   h2d {:.1} MB, d2h {:.1} MB, {:.4} s copied; net {:.1} MB up / {:.1} MB down",
        res.transfers.h2d_bytes as f64 / 1e6,
        res.transfers.d2h_bytes as f64 / 1e6,
        res.transfers.copy_seconds,
        res.transfers.net_up_bytes as f64 / 1e6,
        res.transfers.net_down_bytes as f64 / 1e6,
    );
    // each savings counter prints only when it actually fired — an
    // untouched ledger must not fabricate "0.0 MB avoided" lines
    for line in res.transfers.savings_lines() {
        println!("             {line}");
    }
    if let Some(stats) = &res.coordination {
        println!("coordination: {}", stats.summary());
    }
    if let Some(path) = trace_out {
        if let Some(parent) = std::path::Path::new(&path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(&path, res.trace.to_csv())?;
        eprintln!("wrote {path}");
    }
    if let Some(path) = model_out {
        write_model(&path, &ds, res, &cfg)?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

/// `psfit convert`: stream a LIBSVM/CSV file into one `PSD1` shard per
/// node in bounded memory (two passes; the matrix is never resident).
/// The emitted shards are what `psfit train --shards` memory-maps.
fn convert_cmd(args: &Args) -> anyhow::Result<()> {
    use psfit::data::{ConvertInput, ConvertOptions};
    let input = match (args.opt("libsvm"), args.opt("csv")) {
        (Some(p), None) => ConvertInput::Libsvm(p.into()),
        (None, Some(p)) => ConvertInput::Csv(p.into()),
        _ => anyhow::bail!("convert needs exactly one of --libsvm <file> or --csv <file>"),
    };
    let out = args.opt("out").map(String::from).ok_or_else(|| {
        anyhow::anyhow!("convert needs --out <base> (emits <base>.<node>.psd1)")
    })?;
    let opts = ConvertOptions {
        nodes: args.get("nodes", 1)?,
        mode: match args.opt("sparse") {
            Some(m) => SparseMode::parse(m)?,
            None => SparseMode::Auto,
        },
        threshold: args.get("sparse-threshold", 0.25)?,
        n_features: args
            .opt("n-features")
            .map(|v| v.parse::<usize>())
            .transpose()
            .map_err(|e| anyhow::anyhow!("--n-features: {e}"))?,
        sanitize: args.flag("sanitize"),
    };
    args.reject_unknown()?;
    let summary = psfit::data::convert(&input, std::path::Path::new(&out), &opts)?;
    println!(
        "converted:   {} rows x {} features, density {:.4}",
        summary.rows, summary.cols, summary.density
    );
    if summary.dropped > 0 {
        println!("sanitized:   {} row(s) with non-finite values dropped", summary.dropped);
    }
    for (i, s) in summary.shards.iter().enumerate() {
        println!(
            "shard {i}:     {} ({} rows, {}, {} stored entries)",
            s.path.display(),
            s.rows,
            s.storage,
            s.nnz
        );
    }
    Ok(())
}

/// Write the fitted model as deterministic JSON: support indices plus the
/// exact f64 bit patterns of the objective and the support coefficients.
/// Two runs that agree bit-for-bit produce byte-identical files, so CI
/// checks socket-vs-local parity with a plain `cmp`.
fn write_model(path: &str, ds: &Dataset, res: &SolveResult, cfg: &Config) -> anyhow::Result<()> {
    let loss = psfit::losses::make_loss(cfg.loss, ds.width.max(cfg.classes));
    let objective = psfit::admm::solver::objective(ds, loss.as_ref(), cfg.solver.gamma, &res.x);
    let support: Vec<String> = res.support.iter().map(|s| s.to_string()).collect();
    let x_bits: Vec<String> = res
        .support
        .iter()
        .map(|&j| format!("\"{:016x}\"", res.x[j].to_bits()))
        .collect();
    let text = format!(
        "{{\n  \"n_features\": {},\n  \"width\": {},\n  \"support\": [{}],\n  \
         \"objective_bits\": \"{:016x}\",\n  \"x_bits\": [{}]\n}}\n",
        ds.n_features,
        ds.width,
        support.join(", "),
        objective.to_bits(),
        x_bits.join(", ")
    );
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, text)?;
    Ok(())
}

/// `psfit submit`: hand a fit job to a running `psfit serve` daemon.
fn submit_cmd(args: &Args) -> anyhow::Result<()> {
    let serve = args.opt("serve").unwrap_or("127.0.0.1:7700").to_string();
    let name = args.opt("name").unwrap_or("cli").to_string();
    let config = match args.opt("config") {
        Some(path) => Config::from_json_file(std::path::Path::new(path))?
            .to_json()
            .to_string(),
        None => String::new(),
    };
    let spec = JobSpec {
        n: args.get("n", 200)?,
        m: args.get("m", 1600)?,
        nodes: args.get("nodes", 2)?,
        sparsity: args.get("sparsity", 0.8)?,
        density: args.get("density", 1.0)?,
        noise_std: args.get("noise", 0.1)?,
        seed: args.get("seed", 42)?,
        kappa: args.get("kappa", 0)?,
        config,
    };
    let wait = args.flag("wait");
    let timeout: u64 = args.get("timeout-s", 300)?;
    args.reject_unknown()?;
    let mut client = ServeClient::connect(&serve)?;
    let job = client.submit(&name, spec)?;
    println!("job {job} submitted as `{name}`");
    if wait {
        let st = client.wait(job, std::time::Duration::from_secs(timeout))?;
        println!(
            "job {job} done: converged={} iters={} support={} objective={:.6e} wall={:.3}s",
            st.converged, st.iters, st.support_len, st.objective, st.wall_seconds
        );
    }
    report_reconnects(&client);
    Ok(())
}

/// Parse `--features 3:0.5,17:-1.2` into sparse (index, value) pairs.
fn parse_features(raw: &str) -> anyhow::Result<Vec<(u32, f64)>> {
    raw.split(',')
        .filter(|tok| !tok.trim().is_empty())
        .map(|tok| {
            let (i, v) = tok
                .trim()
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("feature `{tok}` is not index:value"))?;
            let idx = i
                .trim()
                .parse::<u32>()
                .map_err(|_| anyhow::anyhow!("bad feature index `{i}`"))?;
            let val = v
                .trim()
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("bad feature value `{v}`"))?;
            Ok((idx, val))
        })
        .collect()
}

/// `psfit predict`: score a sparse feature vector against a finished job.
fn predict_cmd(args: &Args) -> anyhow::Result<()> {
    let serve = args.opt("serve").unwrap_or("127.0.0.1:7700").to_string();
    let job: u64 = args.get("job", 0)?;
    let raw = args.require("features")?.to_string();
    args.reject_unknown()?;
    anyhow::ensure!(job > 0, "pass --job <id> (ids start at 1)");
    let features = parse_features(&raw)?;
    let mut client = ServeClient::connect(&serve)?;
    let values = client.predict(job, &features)?;
    for (c, v) in values.iter().enumerate() {
        println!("class {c}: {v:.6e}");
    }
    report_reconnects(&client);
    Ok(())
}

/// `psfit jobs`: list every job the daemon knows, id ascending.
fn jobs_cmd(args: &Args) -> anyhow::Result<()> {
    let serve = args.opt("serve").unwrap_or("127.0.0.1:7700").to_string();
    args.reject_unknown()?;
    let mut client = ServeClient::connect(&serve)?;
    let jobs = client.jobs()?;
    if jobs.is_empty() {
        println!("no jobs");
        return Ok(());
    }
    println!("{:>5}  {:<8}  {:<16}  detail", "job", "phase", "name");
    for j in &jobs {
        println!(
            "{:>5}  {:<8}  {:<16}  {}",
            j.job,
            JobPhase::from_code(j.phase)?.name(),
            j.name,
            if j.message.is_empty() { "-" } else { &j.message }
        );
    }
    report_reconnects(&client);
    Ok(())
}

/// Surface how many daemon restarts the client rode through — a restart
/// the retry loop hid must still be visible to the operator.
fn report_reconnects(client: &ServeClient) {
    if client.reconnects() > 0 {
        eprintln!(
            "reconnects:  {} (client re-dialed through a daemon restart)",
            client.reconnects()
        );
    }
}

/// Parse a comma-separated list like `200,100,50`.
fn parse_list<T: std::str::FromStr>(raw: &str, what: &str) -> anyhow::Result<Vec<T>> {
    raw.split(',')
        .map(|tok| {
            tok.trim()
                .parse::<T>()
                .map_err(|_| anyhow::anyhow!("invalid {what} entry `{tok}`"))
        })
        .collect()
}

fn path_cmd(args: &Args) -> anyhow::Result<()> {
    let (mut cfg, spec, libsvm) = shared_config(args)?;
    if let Some(b) = args.opt("budgets") {
        cfg.path.budgets = parse_list(b, "--budgets")?;
    }
    if let Some(r) = args.opt("rho-ladder") {
        cfg.path.rho_ladder = parse_list(r, "--rho-ladder")?;
    }
    if args.flag("cold") {
        cfg.path.warm_start = false;
    }
    if args.flag("cg") {
        cfg.path.direct = false;
    }
    if let Some(ck) = args.opt("checkpoint") {
        cfg.path.checkpoint = Some(ck.to_string());
    }
    let out = args.opt("out").map(String::from);
    let sanitize = args.flag("sanitize");
    args.reject_unknown()?;
    anyhow::ensure!(
        !cfg.path.budgets.is_empty(),
        "psfit path needs --budgets k1,k2,... (strictly descending) or a config with a \"path\" section"
    );
    cfg.path.validate()?;

    let ds = build_dataset(&mut cfg, &spec, libsvm.as_deref(), sanitize)?;
    eprintln!(
        "sparsity path over {} (n={}, m={}, N={}): {} budget(s) x {} rho rung(s), {}, {} solver",
        loss_name(cfg.loss),
        ds.n_features,
        ds.total_samples(),
        ds.nodes(),
        cfg.path.budgets.len(),
        cfg.path.rho_ladder.len().max(1),
        if cfg.path.warm_start { "warm-started" } else { "cold-started" },
        if cfg.path.direct { "direct" } else { "cg" },
    );
    if let Some(ck) = &cfg.path.checkpoint {
        eprintln!("checkpoint:  {ck} (saved after every point; resumes automatically)");
    }

    let outcome = path::run_path(&ds, &cfg, &SolveOptions::default(), true)?;
    if outcome.resumed_points > 0 {
        eprintln!(
            "resumed:     {} point(s) restored from checkpoint",
            outcome.resumed_points
        );
    }

    println!(
        "{:>7} {:>8} {:>5} {:>6} {:>10} {:>12} {:>8} {:>9} {:>7}",
        "kappa", "rho_c", "warm", "iters", "converged", "objective", "support", "wall_s", "reuse"
    );
    for p in &outcome.trace.points {
        println!(
            "{:>7} {:>8.3} {:>5} {:>6} {:>10} {:>12.4e} {:>8} {:>9.3} {:>7}",
            p.kappa,
            p.rho_c,
            p.warm,
            p.iters,
            p.converged,
            p.objective,
            p.support.len(),
            p.wall_seconds,
            p.chol_reuses,
        );
    }
    println!(
        "total:       {} outer iterations over {} point(s)",
        outcome.trace.total_iters(),
        outcome.trace.points.len()
    );
    if let Some(res) = &outcome.final_result {
        println!(
            "support F1:  {:.3} at the final point (kappa={})",
            support_f1(&res.support, &ds.support_true),
            outcome.trace.last().map(|p| p.kappa).unwrap_or(0),
        );
        for line in res.transfers.savings_lines() {
            println!("             {line}");
        }
    }
    if let Some(path) = out {
        if let Some(parent) = std::path::Path::new(&path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(&path, outcome.trace.to_csv())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn loss_name(l: LossKind) -> &'static str {
    match l {
        LossKind::Squared => "sparse linear regression (SLS)",
        LossKind::Logistic => "sparse logistic regression (SLogR)",
        LossKind::Hinge => "sparse SVM (SSVM)",
        LossKind::Softmax => "sparse softmax regression (SSR)",
    }
}

fn info(args: &Args) -> anyhow::Result<()> {
    args.reject_unknown()?;
    let dir = driver::default_artifacts_dir();
    println!("artifact dir: {}", dir.display());
    match psfit::runtime::Manifest::load(&dir.join("manifest.json")) {
        Ok(m) => {
            println!(
                "manifest: tile_m={} block_n={} bm={} cg_iters={} newton_iters={} classes={}",
                m.tile_m, m.block_n, m.bm, m.cg_iters, m.newton_iters, m.classes
            );
            println!("artifacts ({}):", m.artifacts.len());
            for (name, spec) in &m.artifacts {
                let ins: Vec<String> =
                    spec.inputs.iter().map(|t| format!("{:?}", t.shape)).collect();
                println!("  {name:18} {} <- {}", spec.file, ins.join(", "));
            }
        }
        Err(e) => println!("no manifest ({e}); run `make artifacts`"),
    }
    Ok(())
}

//! Metrics: transfer ledger (Figure 4), iteration traces (Figure 1),
//! and CSV/JSON emission for the experiment harnesses.

use std::fmt::Write as _;

/// Accounting of host<->device staging copies and network bytes.
///
/// On the XLA ("GPU") backend every tile pushed into a PJRT literal and
/// every result pulled back is recorded here — the measured analogue of the
/// paper's CPU<->GPU PCIe transfers.  An optional synthetic PCIe model
/// (`pcie_gbps`) converts bytes to modeled seconds for Figure 4's shape.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TransferLedger {
    /// host -> device bytes (staging tiles, vectors into literals)
    pub h2d_bytes: u64,
    /// device -> host bytes (results out of literals)
    pub d2h_bytes: u64,
    /// measured wall time spent in staging copies (seconds)
    pub copy_seconds: f64,
    /// network bytes node -> coordinator
    pub net_up_bytes: u64,
    /// network bytes coordinator -> node (regular round broadcasts)
    pub net_down_bytes: u64,
    /// coordinator -> node bytes spent re-synchronizing lagging or joining
    /// nodes (async coordination only; counted separately from the round
    /// broadcasts so the protocol overhead of staleness is visible)
    pub net_resync_bytes: u64,
    /// host-side packing bytes *avoided* by reading feature blocks in
    /// place through stride-aware column views instead of eagerly copying
    /// each block at backend construction (native backend; informational —
    /// not counted in `h2d_bytes`/`d2h_bytes`)
    pub host_copy_saved_bytes: u64,
    /// per-round allocation bytes *avoided* by the transport layer:
    /// broadcast payloads refilled in place (one shared `Arc` per round)
    /// and node reply buffers recycled by the solver instead of
    /// re-allocated (informational, like `host_copy_saved_bytes`)
    pub net_alloc_saved_bytes: u64,
    /// per-block Gram matrices `A_j^T A_j` computed at backend
    /// construction — they depend only on the data, so a warm-started
    /// sparsity path pays this once where a cold-started sweep pays it
    /// once per path point (native backend; informational)
    pub gram_builds: u64,
    /// Cholesky factorizations of `rho_l G + reg I` actually computed
    /// (native backend, `SolveMode::Direct`; one per distinct penalty set
    /// per block — see the keyed factorization cache)
    pub chol_factorizations: u64,
    /// penalty revisits that *reused* a cached Cholesky factor instead of
    /// refactoring (the path subsystem's rho ladder; informational)
    pub chol_reuses: u64,
    /// protocol frames actually put on a socket (both directions; zero
    /// for the in-process transports, whose byte counters are modeled
    /// rather than measured)
    pub wire_frames: u64,
}

impl TransferLedger {
    /// Record a host-to-device staging copy.
    pub fn record_h2d(&mut self, bytes: usize, seconds: f64) {
        self.h2d_bytes += bytes as u64;
        self.copy_seconds += seconds;
    }

    /// Record a device-to-host staging copy.
    pub fn record_d2h(&mut self, bytes: usize, seconds: f64) {
        self.d2h_bytes += bytes as u64;
        self.copy_seconds += seconds;
    }

    /// Accumulate another ledger's counters into this one (per-node
    /// ledgers merge into the cluster total).
    pub fn merge(&mut self, other: &TransferLedger) {
        self.h2d_bytes += other.h2d_bytes;
        self.d2h_bytes += other.d2h_bytes;
        self.copy_seconds += other.copy_seconds;
        self.net_up_bytes += other.net_up_bytes;
        self.net_down_bytes += other.net_down_bytes;
        self.net_resync_bytes += other.net_resync_bytes;
        self.host_copy_saved_bytes += other.host_copy_saved_bytes;
        self.net_alloc_saved_bytes += other.net_alloc_saved_bytes;
        self.gram_builds += other.gram_builds;
        self.chol_factorizations += other.chol_factorizations;
        self.chol_reuses += other.chol_reuses;
        self.wire_frames += other.wire_frames;
    }

    /// Human-readable notes for the *avoided*-work counters, one line per
    /// nonzero entry — and no line at all for a counter that never fired,
    /// so a run whose transport never touched the reuse ledger prints
    /// nothing spurious.  `psfit train` and `psfit path` render these
    /// verbatim (regression-tested in this module).
    pub fn savings_lines(&self) -> Vec<String> {
        let mut out = Vec::new();
        if self.host_copy_saved_bytes > 0 {
            out.push(format!(
                "{:.1} MB of block packing avoided (in-place column views)",
                self.host_copy_saved_bytes as f64 / 1e6
            ));
        }
        if self.net_alloc_saved_bytes > 0 {
            out.push(format!(
                "{:.1} MB of round-trip allocations avoided (reused buffers)",
                self.net_alloc_saved_bytes as f64 / 1e6
            ));
        }
        if self.chol_reuses > 0 {
            out.push(format!(
                "{} block factorization(s) reused across penalty revisits",
                self.chol_reuses
            ));
        }
        out
    }

    /// Modeled PCIe seconds for the recorded volume: bytes / bandwidth +
    /// a fixed per-transfer latency is approximated by the measured copy
    /// time when no model is given.
    pub fn modeled_transfer_seconds(&self, pcie_gbps: Option<f64>) -> f64 {
        match pcie_gbps {
            Some(gbps) => {
                (self.h2d_bytes + self.d2h_bytes) as f64 / (gbps * 1e9 / 8.0)
            }
            None => self.copy_seconds,
        }
    }
}

/// One outer Bi-cADMM iteration's convergence record (Eq. 14 residuals).
#[derive(Debug, Clone, PartialEq)]
pub struct IterRecord {
    /// Outer iteration index (0-based).
    pub iter: usize,
    /// primal residual  sum_i ||x_i - z||_2
    pub primal: f64,
    /// dual residual    sqrt(N) rho_c ||z - z_prev||_2
    pub dual: f64,
    /// bilinear residual |g(z, s, t)|
    pub bilinear: f64,
    /// wall-clock seconds since solve start
    pub wall: f64,
    /// node replies folded into this round's consensus average (equals the
    /// cluster size under synchronous coordination)
    pub participants: usize,
    /// largest staleness (in rounds) among the folded replies (0 under
    /// synchronous coordination)
    pub max_lag: usize,
    /// cumulative safeguarded watchdog restarts performed before this
    /// iteration (0 for a run the divergence watchdog never touched)
    pub restarts: usize,
}

/// Full convergence trace of one solve.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    /// One record per outer iteration, in order.
    pub records: Vec<IterRecord>,
}

impl Trace {
    /// Append an iteration record.
    pub fn push(&mut self, rec: IterRecord) {
        self.records.push(rec);
    }

    /// Number of recorded iterations.
    pub fn iters(&self) -> usize {
        self.records.len()
    }

    /// The final iteration record, if any.
    pub fn last(&self) -> Option<&IterRecord> {
        self.records.last()
    }

    /// CSV with header:
    /// iter,primal,dual,bilinear,wall,participants,max_lag,restarts
    pub fn to_csv(&self) -> String {
        let mut out =
            String::from("iter,primal,dual,bilinear,wall,participants,max_lag,restarts\n");
        for r in &self.records {
            let _ = writeln!(
                out,
                "{},{:.6e},{:.6e},{:.6e},{:.6e},{},{},{}",
                r.iter, r.primal, r.dual, r.bilinear, r.wall, r.participants, r.max_lag, r.restarts
            );
        }
        out
    }
}

/// Per-solve accounting of the asynchronous coordination protocol: how
/// often each node's reply made it into a global update, how stale the
/// folded replies were, and how much membership churn the run saw.
/// Produced by `coordinator::AsyncCluster`; `None` for synchronous
/// clusters.
#[derive(Debug, Clone, Default)]
pub struct CoordinationStats {
    /// Outer rounds the scheduler started.
    pub rounds: u64,
    /// Histogram of reply staleness at fold time: `staleness_hist[l]` is
    /// the number of folded replies that were `l` rounds old.
    pub staleness_hist: Vec<u64>,
    /// Per-node count of replies folded into a global update.
    pub participation: Vec<u64>,
    /// Replies discarded for exceeding the staleness bound.
    pub drops: u64,
    /// Resync broadcasts (fresh z pushed to a lagging or joining node).
    pub resyncs: u64,
    /// Nodes declared dead (shard degraded).
    pub deaths: u64,
    /// Nodes that joined after construction.
    pub joins: u64,
    /// Dead peers re-admitted mid-solve after a successful reconnect +
    /// warm-state resync (socket transport's self-healing path).
    pub rejoins: u64,
    /// Replies rejected by the numerical guard (non-finite values or a
    /// norm blowup) before folding; the node sat that round out exactly
    /// like a degraded peer.
    pub quarantined: u64,
}

impl CoordinationStats {
    /// Zeroed stats for a roster of `nodes`.
    pub fn new(nodes: usize) -> CoordinationStats {
        CoordinationStats {
            participation: vec![0; nodes],
            ..Default::default()
        }
    }

    /// Record a reply from `node` folded with staleness `lag`.
    pub fn record_fold(&mut self, node: usize, lag: usize) {
        if self.staleness_hist.len() <= lag {
            self.staleness_hist.resize(lag + 1, 0);
        }
        self.staleness_hist[lag] += 1;
        if self.participation.len() <= node {
            self.participation.resize(node + 1, 0);
        }
        self.participation[node] += 1;
    }

    /// Fraction of folded replies that were perfectly fresh (lag 0).
    pub fn fresh_fraction(&self) -> f64 {
        let total: u64 = self.staleness_hist.iter().sum();
        if total == 0 {
            return 1.0;
        }
        self.staleness_hist.first().copied().unwrap_or(0) as f64 / total as f64
    }

    /// One-line human summary for the CLI and harness logs.
    pub fn summary(&self) -> String {
        format!(
            "rounds {} | staleness hist {:?} | participation {:?} | drops {} resyncs {} deaths {} joins {} rejoins {} quarantined {}",
            self.rounds,
            self.staleness_hist,
            self.participation,
            self.drops,
            self.resyncs,
            self.deaths,
            self.joins,
            self.rejoins,
            self.quarantined
        )
    }
}

/// Generic CSV table builder for the figure/table harnesses.
#[derive(Debug, Clone)]
pub struct CsvTable {
    /// Column names.
    pub header: Vec<String>,
    /// Data rows (each exactly `header.len()` cells).
    pub rows: Vec<Vec<String>>,
}

impl CsvTable {
    /// Empty table with the given columns.
    pub fn new(header: &[&str]) -> CsvTable {
        CsvTable {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the column count).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells);
    }

    /// Render as CSV text.
    pub fn to_csv(&self) -> String {
        let mut out = self.header.join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }

    /// Fixed-width console rendering.
    pub fn to_pretty(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (w, c) in widths.iter_mut().zip(r) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", fmt_row(&self.header, &widths));
        let _ = writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for r in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(r, &widths));
        }
        out
    }

    /// Write the CSV to a file, creating parent directories.
    pub fn write_file(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_accumulates_and_merges() {
        let mut a = TransferLedger::default();
        a.record_h2d(100, 0.5);
        a.record_d2h(50, 0.25);
        let mut b = TransferLedger::default();
        b.record_h2d(10, 0.1);
        b.net_up_bytes = 7;
        a.merge(&b);
        assert_eq!(a.h2d_bytes, 110);
        assert_eq!(a.d2h_bytes, 50);
        assert_eq!(a.net_up_bytes, 7);
        assert!((a.copy_seconds - 0.85).abs() < 1e-12);
    }

    #[test]
    fn modeled_seconds_uses_bandwidth() {
        let mut l = TransferLedger::default();
        l.record_h2d(16_000_000_000 / 8, 1.0); // 2 GB
        let secs = l.modeled_transfer_seconds(Some(16.0)); // 16 Gbps
        assert!((secs - 1.0).abs() < 1e-9);
        assert_eq!(l.modeled_transfer_seconds(None), 1.0);
    }

    #[test]
    fn trace_csv_shape() {
        let mut t = Trace::default();
        t.push(IterRecord {
            iter: 0,
            primal: 1.0,
            dual: 2.0,
            bilinear: 3.0,
            wall: 0.1,
            participants: 4,
            max_lag: 1,
            restarts: 2,
        });
        let csv = t.to_csv();
        assert!(csv.starts_with("iter,primal,dual,bilinear,wall,participants,max_lag,restarts\n"));
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.lines().nth(1).unwrap().ends_with(",4,1,2"));
    }

    #[test]
    fn coordination_stats_histogram_and_participation() {
        let mut s = CoordinationStats::new(3);
        s.record_fold(0, 0);
        s.record_fold(1, 0);
        s.record_fold(1, 2);
        assert_eq!(s.staleness_hist, vec![2, 0, 1]);
        assert_eq!(s.participation, vec![1, 2, 0]);
        assert!((s.fresh_fraction() - 2.0 / 3.0).abs() < 1e-12);
        // folding from a node beyond the initial roster grows the table
        s.record_fold(5, 1);
        assert_eq!(s.participation.len(), 6);
        assert!(s.summary().contains("drops 0"));
        s.rejoins = 1;
        assert!(s.summary().contains("rejoins 1"));
        s.quarantined = 3;
        assert!(s.summary().contains("quarantined 3"));
    }

    #[test]
    fn resync_bytes_merge_separately() {
        let mut a = TransferLedger::default();
        a.net_down_bytes = 100;
        let mut b = TransferLedger::default();
        b.net_resync_bytes = 40;
        b.host_copy_saved_bytes = 16;
        b.net_alloc_saved_bytes = 24;
        b.gram_builds = 3;
        b.chol_factorizations = 2;
        b.chol_reuses = 5;
        b.wire_frames = 9;
        a.merge(&b);
        assert_eq!(a.net_down_bytes, 100);
        assert_eq!(a.net_resync_bytes, 40);
        assert_eq!(a.host_copy_saved_bytes, 16);
        assert_eq!(a.net_alloc_saved_bytes, 24);
        assert_eq!(a.gram_builds, 3);
        assert_eq!(a.chol_factorizations, 2);
        assert_eq!(a.chol_reuses, 5);
        assert_eq!(a.wire_frames, 9);
        // informational note: never folded into the transfer volume
        assert_eq!(a.h2d_bytes + a.d2h_bytes, 0);
    }

    /// Regression for the `psfit train` report: an untouched ledger must
    /// produce *no* savings lines (the sync path never fabricates a
    /// "0.0 MB avoided" line), and each counter gates its own line.
    #[test]
    fn savings_lines_gate_on_nonzero_counters() {
        let untouched = TransferLedger::default();
        assert!(untouched.savings_lines().is_empty());

        let mut l = TransferLedger::default();
        l.net_alloc_saved_bytes = 2_000_000;
        let lines = l.savings_lines();
        assert_eq!(lines.len(), 1);
        assert!(lines[0].contains("round-trip allocations"), "{lines:?}");

        l.host_copy_saved_bytes = 1;
        l.chol_reuses = 4;
        let lines = l.savings_lines();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains("block packing"));
        assert!(lines[2].contains("factorization(s) reused"));
    }

    #[test]
    fn csv_table_roundtrip() {
        let mut t = CsvTable::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
        assert!(t.to_pretty().contains('1'));
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn csv_table_rejects_ragged() {
        let mut t = CsvTable::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }
}

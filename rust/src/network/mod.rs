//! Simulated distributed layer: node workers, collectives, byte ledger.
//!
//! The paper runs over MPI (`mpi4py`): a global/coordinator node performs
//! the (z, t, s, v) updates while N computational nodes evaluate the
//! proximal operators.  Here each node is a worker owning its shard and
//! inner-ADMM state; the [`Cluster`] trait abstracts the transport:
//!
//!   * [`SequentialCluster`] — in-process loop (deterministic; tests)
//!   * [`ThreadedCluster`]   — one OS thread per node with channel-based
//!     Bcast/Collect, the MPI stand-in used by the benchmarks
//!   * [`crate::coordinator::AsyncCluster`] — partial-barrier rounds with
//!     bounded staleness, elastic membership, and fault injection
//!
//! The byte ledger records exactly the paper's protocol volume per round:
//! coordinator -> node: z (dim f64); node -> coordinator: x_i and u_i
//! (2 x dim f64) — "Collect: Gather x_i and u_i from all nodes".

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use crate::admm::LocalProx;
use crate::backend::BlockParams;
use crate::metrics::{CoordinationStats, TransferLedger};

/// One computational node's full state for the outer loop.
pub struct NodeWorker {
    pub id: usize,
    prox: LocalProx,
    /// Local estimate x_i (class-major flattened).
    x: Vec<f64>,
    /// Scaled consensus dual u_i = y_i / rho_c.
    u: Vec<f64>,
    first_round: bool,
    params: BlockParams,
    sweeps: usize,
}

impl NodeWorker {
    pub fn new(id: usize, prox: LocalProx, params: BlockParams, sweeps: usize) -> NodeWorker {
        let dim = prox.dim();
        NodeWorker {
            id,
            prox,
            x: vec![0.0; dim],
            u: vec![0.0; dim],
            first_round: true,
            params,
            sweeps,
        }
    }

    /// One outer round: receive z^k, refresh the dual (Eq. 9), evaluate
    /// the prox (7a)/(10), and write (x_i^{k+1}, u_i^k) for the Collect
    /// step into caller-owned buffers — the transport recycles those
    /// across rounds instead of cloning fresh vectors every time.
    pub fn round_into(&mut self, z: &[f64], x_out: &mut Vec<f64>, u_out: &mut Vec<f64>) {
        if self.first_round {
            self.first_round = false;
        } else {
            // u_i^k = u_i^{k-1} + x_i^k - z^k
            for i in 0..self.u.len() {
                self.u[i] += self.x[i] - z[i];
            }
        }
        u_out.clear();
        u_out.extend_from_slice(&self.u);
        let mut x_new = std::mem::take(&mut self.x);
        self.prox.solve(z, &self.u, self.params, self.sweeps, &mut x_new);
        self.x = x_new;
        x_out.clear();
        x_out.extend_from_slice(&self.x);
    }

    /// [`NodeWorker::round_into`] with freshly allocated reply vectors —
    /// the channel-based clusters need owned values on the wire.
    pub fn round(&mut self, z: &[f64]) -> (Vec<f64>, Vec<f64>) {
        let (mut x, mut u) = (Vec::new(), Vec::new());
        self.round_into(z, &mut x, &mut u);
        (x, u)
    }

    pub fn loss_value(&mut self) -> f64 {
        self.prox.loss_value()
    }

    pub fn ledger(&self) -> TransferLedger {
        self.prox.ledger()
    }
}

/// Reply from one node's round.
pub struct NodeReply {
    pub node: usize,
    /// Coordinator round the reply's `z` belonged to.  Synchronous
    /// clusters always tag the current round; the async coordinator may
    /// return cached replies lagging by up to its staleness bound.
    pub round: usize,
    /// Staleness in rounds, as judged by the cluster that produced the
    /// snapshot (always 0 for synchronous clusters).
    pub lag: usize,
    pub x: Vec<f64>,
    pub u: Vec<f64>,
}

pub trait Cluster {
    /// Total roster size (including degraded members, for threshold
    /// scaling — the solver weights its averages by actual replies).
    fn nodes(&self) -> usize;
    /// Broadcast z, run a coordination round, gather replies (sorted by
    /// node).  Node failure is an error value, not a process abort; the
    /// async coordinator degrades the dead shard and keeps going, so it
    /// only errors when no quorum is reachable at all.
    fn round(&mut self, z: &[f64]) -> anyhow::Result<Vec<NodeReply>>;
    /// Sum of local loss values at the current iterates (reporting).
    fn loss_value(&mut self) -> anyhow::Result<f64>;
    /// Merged transfer + network ledger (best-effort over live nodes).
    fn ledger(&mut self) -> TransferLedger;
    /// Hand a consumed round's replies back so the transport can refill
    /// their buffers in place next round (default: drop them).  The
    /// `net_alloc_saved_bytes` ledger entry records what reuse avoided.
    fn recycle(&mut self, _replies: Vec<NodeReply>) {}
    /// Async-protocol accounting, if this cluster keeps any.
    fn coordination(&self) -> Option<CoordinationStats> {
        None
    }
}

/// Refill a broadcast payload in place when the slot holds the only
/// remaining reference (every worker is done with last round's copy);
/// allocate fresh otherwise.  Returns the payload and whether the buffer
/// was reused — the single `Arc<Vec<f64>>` every node of a round shares.
pub(crate) fn refresh_payload(
    slot: &mut Option<Arc<Vec<f64>>>,
    z: &[f64],
) -> (Arc<Vec<f64>>, bool) {
    if let Some(mut arc) = slot.take() {
        if let Some(buf) = Arc::get_mut(&mut arc) {
            buf.clear();
            buf.extend_from_slice(z);
            *slot = Some(arc.clone());
            return (arc, true);
        }
    }
    let arc = Arc::new(z.to_vec());
    *slot = Some(arc.clone());
    (arc, false)
}

// ---------------------------------------------------------------------
// Sequential (in-process) cluster
// ---------------------------------------------------------------------

pub struct SequentialCluster {
    workers: Vec<NodeWorker>,
    net: TransferLedger,
    dim: usize,
    round: usize,
    /// Recycled reply objects whose buffers the next round refills in
    /// place (see [`Cluster::recycle`]).
    spare: Vec<NodeReply>,
}

impl SequentialCluster {
    pub fn new(workers: Vec<NodeWorker>, dim: usize) -> SequentialCluster {
        SequentialCluster {
            workers,
            net: TransferLedger::default(),
            dim,
            round: 0,
            spare: Vec::new(),
        }
    }
}

impl Cluster for SequentialCluster {
    fn nodes(&self) -> usize {
        self.workers.len()
    }

    fn round(&mut self, z: &[f64]) -> anyhow::Result<Vec<NodeReply>> {
        let bytes = self.dim as u64 * 8;
        let round = self.round;
        self.round += 1;
        let mut replies = Vec::with_capacity(self.workers.len());
        for w in self.workers.iter_mut() {
            self.net.net_down_bytes += bytes;
            let mut rep = self.spare.pop().unwrap_or_else(|| NodeReply {
                node: 0,
                round: 0,
                lag: 0,
                x: Vec::new(),
                u: Vec::new(),
            });
            if rep.x.capacity() >= self.dim && rep.u.capacity() >= self.dim {
                // both reply vectors refill in place — no allocation
                self.net.net_alloc_saved_bytes += 2 * bytes;
            }
            w.round_into(z, &mut rep.x, &mut rep.u);
            rep.node = w.id;
            rep.round = round;
            rep.lag = 0;
            self.net.net_up_bytes += 2 * bytes;
            replies.push(rep);
        }
        Ok(replies)
    }

    fn loss_value(&mut self) -> anyhow::Result<f64> {
        Ok(self.workers.iter_mut().map(|w| w.loss_value()).sum())
    }

    fn ledger(&mut self) -> TransferLedger {
        let mut total = self.net.clone();
        for w in &self.workers {
            total.merge(&w.ledger());
        }
        total
    }

    fn recycle(&mut self, mut replies: Vec<NodeReply>) {
        self.spare.append(&mut replies);
    }
}

// ---------------------------------------------------------------------
// Threaded cluster (one OS thread per node; channels as the wire)
// ---------------------------------------------------------------------

enum Command {
    Round(Arc<Vec<f64>>),
    Loss,
    Ledger,
}

enum Reply {
    Round(NodeReply),
    Loss(f64),
    Ledger(TransferLedger),
}

pub struct ThreadedCluster {
    senders: Vec<mpsc::Sender<Command>>,
    replies: mpsc::Receiver<Reply>,
    handles: Vec<std::thread::JoinHandle<()>>,
    net: TransferLedger,
    dim: usize,
    n: usize,
    round: usize,
    /// Broadcast payload reused across rounds (see [`refresh_payload`]).
    payload: Option<Arc<Vec<f64>>>,
}

impl ThreadedCluster {
    pub fn new(workers: Vec<NodeWorker>, dim: usize) -> ThreadedCluster {
        let n = workers.len();
        let (reply_tx, replies) = mpsc::channel::<Reply>();
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for mut w in workers {
            let (tx, rx) = mpsc::channel::<Command>();
            let out = reply_tx.clone();
            senders.push(tx);
            handles.push(std::thread::spawn(move || {
                while let Ok(cmd) = rx.recv() {
                    let reply = match cmd {
                        Command::Round(z) => {
                            let (x, u) = w.round(&z);
                            // the coordinator stamps the round tag on receipt
                            Reply::Round(NodeReply {
                                node: w.id,
                                round: 0,
                                lag: 0,
                                x,
                                u,
                            })
                        }
                        Command::Loss => Reply::Loss(w.loss_value()),
                        Command::Ledger => Reply::Ledger(w.ledger()),
                    };
                    if out.send(reply).is_err() {
                        break;
                    }
                }
            }));
        }
        ThreadedCluster {
            senders,
            replies,
            handles,
            net: TransferLedger::default(),
            dim,
            n,
            round: 0,
            payload: None,
        }
    }
}

impl Cluster for ThreadedCluster {
    fn nodes(&self) -> usize {
        self.n
    }

    fn round(&mut self, z: &[f64]) -> anyhow::Result<Vec<NodeReply>> {
        let (payload, reused) = refresh_payload(&mut self.payload, z);
        if reused {
            self.net.net_alloc_saved_bytes += self.dim as u64 * 8;
        }
        let bytes = self.dim as u64 * 8;
        let round = self.round;
        self.round += 1;
        for (i, tx) in self.senders.iter().enumerate() {
            if tx.send(Command::Round(payload.clone())).is_err() {
                anyhow::bail!("node {i} died before the round-{round} broadcast");
            }
            self.net.net_down_bytes += bytes;
        }
        let mut replies = Vec::with_capacity(self.n);
        for _ in 0..self.n {
            match self.replies.recv() {
                Ok(Reply::Round(mut r)) => {
                    self.net.net_up_bytes += 2 * bytes;
                    r.round = round;
                    replies.push(r);
                }
                Ok(_) => anyhow::bail!("protocol violation: non-round reply in round {round}"),
                Err(_) => anyhow::bail!("a node worker died during round {round}"),
            }
        }
        replies.sort_by_key(|r| r.node);
        Ok(replies)
    }

    fn loss_value(&mut self) -> anyhow::Result<f64> {
        for (i, tx) in self.senders.iter().enumerate() {
            if tx.send(Command::Loss).is_err() {
                anyhow::bail!("node {i} died before the loss query");
            }
        }
        let mut total = 0.0;
        for _ in 0..self.n {
            match self.replies.recv() {
                Ok(Reply::Loss(v)) => total += v,
                Ok(_) => anyhow::bail!("protocol violation: non-loss reply to loss query"),
                Err(_) => anyhow::bail!("a node worker died during the loss query"),
            }
        }
        Ok(total)
    }

    fn ledger(&mut self) -> TransferLedger {
        // Best-effort: skip dead nodes so a degraded cluster still reports
        // the traffic it actually observed.
        let mut total = self.net.clone();
        let mut expected = 0;
        for tx in &self.senders {
            if tx.send(Command::Ledger).is_ok() {
                expected += 1;
            }
        }
        for _ in 0..expected {
            match self.replies.recv_timeout(Duration::from_secs(10)) {
                Ok(Reply::Ledger(l)) => total.merge(&l),
                Ok(_) => continue,
                Err(_) => break,
            }
        }
        total
    }
}

impl Drop for ThreadedCluster {
    fn drop(&mut self) {
        self.senders.clear(); // closes channels; workers exit their loops
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::{NativeBackend, SolveMode};
    use crate::data::{FeaturePlan, SyntheticSpec};
    use crate::losses::Squared;

    fn make_workers(nodes: usize) -> (Vec<NodeWorker>, usize) {
        let ds = SyntheticSpec::regression(12, 40 * nodes, nodes).generate();
        let plan = FeaturePlan::new(12, 2, 512);
        let params = BlockParams {
            rho_l: 2.0,
            rho_c: 1.0,
            reg: 1.0 / (nodes as f64 * 10.0) + 1.0,
        };
        let workers = ds
            .shards
            .iter()
            .enumerate()
            .map(|(i, shard)| {
                let be = NativeBackend::new(shard, &plan, Box::new(Squared), SolveMode::Direct);
                NodeWorker::new(i, LocalProx::new(Box::new(be), plan.clone(), 1), params, 10)
            })
            .collect();
        (workers, 12)
    }

    #[test]
    fn threaded_matches_sequential() {
        let (w1, dim) = make_workers(3);
        let (w2, _) = make_workers(3);
        let mut seq = SequentialCluster::new(w1, dim);
        let mut thr = ThreadedCluster::new(w2, dim);
        let z = vec![0.05; dim];
        for k in 0..3 {
            let a = seq.round(&z).unwrap();
            let b = thr.round(&z).unwrap();
            for (ra, rb) in a.iter().zip(&b) {
                assert_eq!(ra.node, rb.node);
                assert_eq!(ra.round, k);
                assert_eq!(rb.round, k);
                for (x, y) in ra.x.iter().zip(&rb.x) {
                    assert!((x - y).abs() < 1e-12, "{x} vs {y}");
                }
                for (x, y) in ra.u.iter().zip(&rb.u) {
                    assert!((x - y).abs() < 1e-12);
                }
            }
        }
        assert!((seq.loss_value().unwrap() - thr.loss_value().unwrap()).abs() < 1e-9);
    }

    #[test]
    fn byte_ledger_counts_protocol_volume() {
        let (w, dim) = make_workers(2);
        let mut seq = SequentialCluster::new(w, dim);
        let z = vec![0.0; dim];
        seq.round(&z).unwrap();
        seq.round(&z).unwrap();
        let l = seq.ledger();
        // 2 rounds x 2 nodes x dim x 8 bytes down; twice that up
        assert_eq!(l.net_down_bytes, 2 * 2 * dim as u64 * 8);
        assert_eq!(l.net_up_bytes, 2 * 2 * 2 * dim as u64 * 8);
    }

    #[test]
    fn recycled_reply_buffers_and_payload_are_reused() {
        let (w, dim) = make_workers(2);
        let mut seq = SequentialCluster::new(w, dim);
        let z = vec![0.0; dim];
        let r1 = seq.round(&z).unwrap();
        assert_eq!(
            seq.ledger().net_alloc_saved_bytes,
            0,
            "first round has no buffers to reuse"
        );
        seq.recycle(r1);
        let r2 = seq.round(&z).unwrap();
        // 2 nodes x (x + u) x dim x 8 bytes refilled in place
        assert_eq!(seq.ledger().net_alloc_saved_bytes, 2 * 2 * dim as u64 * 8);
        assert!(r2.iter().all(|r| r.x.len() == dim && r.u.len() == dim));

        // the threaded transport reuses the one shared broadcast Arc:
        // workers drop their clones before replying, so round 2 refills it
        let (w, dim) = make_workers(2);
        let mut thr = ThreadedCluster::new(w, dim);
        thr.round(&z).unwrap();
        thr.round(&z).unwrap();
        assert_eq!(thr.ledger().net_alloc_saved_bytes, dim as u64 * 8);
    }

    #[test]
    fn dual_update_follows_consensus_protocol() {
        let (mut w, dim) = {
            let (mut ws, d) = make_workers(1);
            (ws.remove(0), d)
        };
        let z0 = vec![0.0; dim];
        let (x1, u0) = w.round(&z0);
        assert!(u0.iter().all(|&v| v == 0.0), "first-round dual must be 0");
        let z1 = vec![0.1; dim];
        let (_x2, u1) = w.round(&z1);
        // u1 = u0 + x1 - z1
        for i in 0..dim {
            assert!((u1[i] - (x1[i] - z1[i])).abs() < 1e-12);
        }
    }
}

//! Simulated distributed layer: node workers, collectives, byte ledger.
//!
//! The paper runs over MPI (`mpi4py`): a global/coordinator node performs
//! the (z, t, s, v) updates while N computational nodes evaluate the
//! proximal operators.  Here each node is a worker owning its shard and
//! inner-ADMM state; the [`Cluster`] trait abstracts the transport:
//!
//!   * [`SequentialCluster`] — in-process loop (deterministic; tests)
//!   * [`ThreadedCluster`]   — one OS thread per node with channel-based
//!     Bcast/Collect, the in-process stand-in used by the benchmarks
//!   * [`crate::coordinator::AsyncCluster`] — partial-barrier rounds with
//!     bounded staleness, elastic membership, and fault injection
//!   * [`socket::SocketCluster`] — real worker *processes* over TCP or
//!     Unix sockets (the `psfit worker` / `psfit serve` transport)
//!
//! The byte ledger records exactly the paper's protocol volume per round:
//! coordinator -> node: z (dim f64); node -> coordinator: x_i and u_i
//! (2 x dim f64) — "Collect: Gather x_i and u_i from all nodes".  The
//! in-process transports *model* those bytes; the socket transport counts
//! the frames it actually puts on the wire.

pub mod socket;

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use crate::admm::LocalProx;
use crate::backend::BlockParams;
use crate::metrics::{CoordinationStats, TransferLedger};

/// Serializable warm-start snapshot of one node's solver state.
///
/// Everything a node needs to continue a Bi-cADMM trajectory: the outer
/// consensus pair (x_i, u_i) in f64 and the inner sharing-ADMM state
/// (omega-bar, nu, per-block predictions) in f32.  The per-block
/// coefficients are *not* stored — they are recovered exactly by
/// scattering `x` back into blocks (the f64s were cast from those very
/// f32s, so the round trip is bit-exact).  Produced by
/// [`Cluster::export_warm`], consumed by [`Cluster::reseed`], and
/// serialized verbatim by `path::checkpoint`.
#[derive(Debug, Clone, PartialEq)]
pub struct WarmState {
    /// Node id this snapshot belongs to.
    pub node: usize,
    /// Local estimate x_i (class-major flattened, length n * width).
    pub x: Vec<f64>,
    /// Scaled consensus dual u_i (same layout as `x`).
    pub u: Vec<f64>,
    /// Inner omega-bar, class-major (width, m_i).
    pub omega: Vec<f32>,
    /// Inner scaled dual nu, class-major (width, m_i).
    pub nu: Vec<f32>,
    /// Per-block predictions A_j x_j, class-major (width, m_i) each.
    pub preds: Vec<Vec<f32>>,
}

/// One computational node's full state for the outer loop.
pub struct NodeWorker {
    /// Roster id (position in the cluster; stable across rounds).
    pub id: usize,
    prox: LocalProx,
    /// Local estimate x_i (class-major flattened).
    x: Vec<f64>,
    /// Scaled consensus dual u_i = y_i / rho_c.
    u: Vec<f64>,
    first_round: bool,
    params: BlockParams,
    sweeps: usize,
    /// Mini-batch chunk rows per round (0 = full batch).
    minibatch: usize,
    /// Seed of the deterministic chunk schedule.
    minibatch_seed: u64,
    /// Self-counted round index for the legacy [`NodeWorker::round_into`]
    /// path (transports that carry a round counter use
    /// [`NodeWorker::round_into_at`] instead, which keeps schedules
    /// replayable across checkpoint/resume and across processes).
    rounds_seen: u64,
}

impl NodeWorker {
    /// Node `id` over a prox evaluator, with the penalties and inner
    /// sweep count the outer loop will use.
    pub fn new(id: usize, prox: LocalProx, params: BlockParams, sweeps: usize) -> NodeWorker {
        let dim = prox.dim();
        NodeWorker {
            id,
            prox,
            x: vec![0.0; dim],
            u: vec![0.0; dim],
            first_round: true,
            params,
            sweeps,
            minibatch: 0,
            minibatch_seed: 0,
            rounds_seen: 0,
        }
    }

    /// Enable mini-batch rounds: each outer round's inner sweeps run over
    /// one `rows`-row chunk picked by the seeded deterministic schedule
    /// (`admm::minibatch`).  `rows = 0` (or >= the shard) is full batch —
    /// bit-identical to a plain solve by construction.
    pub fn with_minibatch(mut self, rows: usize, seed: u64) -> NodeWorker {
        self.minibatch = rows;
        self.minibatch_seed = seed;
        self
    }

    /// The row window this node's schedule picks for `round` (`None` =
    /// full batch).
    pub fn chunk_for(&self, round: u64) -> Option<(usize, usize)> {
        crate::admm::minibatch::chunk_for(
            self.minibatch,
            self.minibatch_seed,
            round,
            self.prox.samples(),
        )
    }

    /// One outer round at explicit global round index `round`: receive
    /// z^k, refresh the dual (Eq. 9), evaluate the prox (7a)/(10) — over
    /// the scheduled mini-batch chunk when one is configured — and write
    /// (x_i^{k+1}, u_i^k) for the Collect step into caller-owned buffers.
    ///
    /// The round index comes from the transport (the coordinator's
    /// counter, or the wire-carried `Round` frame), NOT from local state:
    /// that is what makes the chunk schedule identical across transports
    /// and across checkpoint/resume.
    pub fn round_into_at(
        &mut self,
        round: u64,
        z: &[f64],
        x_out: &mut Vec<f64>,
        u_out: &mut Vec<f64>,
    ) {
        self.rounds_seen = round + 1;
        if self.first_round {
            self.first_round = false;
        } else {
            // u_i^k = u_i^{k-1} + x_i^k - z^k
            for i in 0..self.u.len() {
                self.u[i] += self.x[i] - z[i];
            }
        }
        u_out.clear();
        u_out.extend_from_slice(&self.u);
        let span = self.chunk_for(round);
        let mut x_new = std::mem::take(&mut self.x);
        self.prox
            .solve_span(z, &self.u, self.params, self.sweeps, span, &mut x_new);
        self.x = x_new;
        x_out.clear();
        x_out.extend_from_slice(&self.x);
    }

    /// One outer round with a self-counted round index — the legacy entry
    /// point for transports that do not carry a round counter (the async
    /// coordinator).  Full-batch solves are unaffected; mini-batch runs
    /// are gated to round-carrying synchronous transports by
    /// `config::validate`.
    pub fn round_into(&mut self, z: &[f64], x_out: &mut Vec<f64>, u_out: &mut Vec<f64>) {
        self.round_into_at(self.rounds_seen, z, x_out, u_out)
    }

    /// [`NodeWorker::round_into_at`] with freshly allocated reply vectors —
    /// the channel-based clusters need owned values on the wire.
    pub fn round_at(&mut self, round: u64, z: &[f64]) -> (Vec<f64>, Vec<f64>) {
        let (mut x, mut u) = (Vec::new(), Vec::new());
        self.round_into_at(round, z, &mut x, &mut u);
        (x, u)
    }

    /// [`NodeWorker::round_into`] with freshly allocated reply vectors.
    pub fn round(&mut self, z: &[f64]) -> (Vec<f64>, Vec<f64>) {
        let (mut x, mut u) = (Vec::new(), Vec::new());
        self.round_into(z, &mut x, &mut u);
        (x, u)
    }

    /// Training loss at this node's current inner state (reporting).
    pub fn loss_value(&mut self) -> f64 {
        self.prox.loss_value()
    }

    /// This node's transfer/byte ledger (delegates to the backend).
    pub fn ledger(&self) -> TransferLedger {
        self.prox.ledger()
    }

    /// Snapshot this node's complete warm-start state (path subsystem).
    pub fn export_warm(&self) -> WarmState {
        let (omega, nu, preds) = self.prox.warm_parts();
        WarmState {
            node: self.id,
            x: self.x.clone(),
            u: self.u.clone(),
            omega,
            nu,
            preds,
        }
    }

    /// Restore a warm-start snapshot and swap in the next path point's
    /// penalties.  The next [`NodeWorker::round_into`] then continues the
    /// consensus protocol (dual refresh first) instead of cold-starting.
    pub fn reseed(&mut self, ws: &WarmState, params: BlockParams) {
        assert_eq!(ws.x.len(), self.x.len(), "warm x dimension mismatch");
        assert_eq!(ws.u.len(), self.u.len(), "warm u dimension mismatch");
        self.x.copy_from_slice(&ws.x);
        self.u.copy_from_slice(&ws.u);
        self.prox.reseed(&ws.x, &ws.omega, &ws.nu, &ws.preds);
        self.first_round = false;
        self.params = params;
    }
}

/// Reply from one node's round.
pub struct NodeReply {
    /// Which node produced the reply.
    pub node: usize,
    /// Coordinator round the reply's `z` belonged to.  Synchronous
    /// clusters always tag the current round; the async coordinator may
    /// return cached replies lagging by up to its staleness bound.
    pub round: usize,
    /// Staleness in rounds, as judged by the cluster that produced the
    /// snapshot (always 0 for synchronous clusters).
    pub lag: usize,
    /// The node's x_i^{k+1} (class-major flattened).
    pub x: Vec<f64>,
    /// The node's scaled dual u_i^k (same layout as `x`).
    pub u: Vec<f64>,
}

/// Transport abstraction over a set of node workers — the MPI stand-in.
///
/// Implementations: [`SequentialCluster`] (in-process loop),
/// [`ThreadedCluster`] (one OS thread per node), and
/// [`crate::coordinator::AsyncCluster`] (partial-barrier rounds).
pub trait Cluster {
    /// Total roster size (including degraded members, for threshold
    /// scaling — the solver weights its averages by actual replies).
    fn nodes(&self) -> usize;
    /// Broadcast z, run a coordination round, gather replies (sorted by
    /// node).  Node failure is an error value, not a process abort; the
    /// async coordinator degrades the dead shard and keeps going, so it
    /// only errors when no quorum is reachable at all.
    fn round(&mut self, z: &[f64]) -> anyhow::Result<Vec<NodeReply>>;
    /// Sum of local loss values at the current iterates (reporting).
    fn loss_value(&mut self) -> anyhow::Result<f64>;
    /// Merged transfer + network ledger (best-effort over live nodes).
    fn ledger(&mut self) -> TransferLedger;
    /// Hand a consumed round's replies back so the transport can refill
    /// their buffers in place next round (default: drop them).  The
    /// `net_alloc_saved_bytes` ledger entry records what reuse avoided.
    fn recycle(&mut self, _replies: Vec<NodeReply>) {}
    /// Async-protocol accounting, if this cluster keeps any.
    fn coordination(&self) -> Option<CoordinationStats> {
        None
    }
    /// Export every node's warm-start state, sorted by node id — the path
    /// subsystem's handoff between path points.  Transports override this;
    /// the default refuses so exotic clusters fail loudly rather than
    /// silently cold-start.
    fn export_warm(&mut self) -> anyhow::Result<Vec<WarmState>> {
        anyhow::bail!("this transport does not support warm-state export")
    }
    /// Restore every node from the given warm states (matched by node id)
    /// and swap in new block penalties — the inverse of
    /// [`Cluster::export_warm`].  `states` must cover every node.
    fn reseed(&mut self, states: &[WarmState], params: BlockParams) -> anyhow::Result<()> {
        let _ = (states, params);
        anyhow::bail!("this transport does not support warm re-seeding")
    }
    /// Jump the transport's round counter to `round` — called by
    /// `solve_checkpointed` when resuming mid-trajectory so round-indexed
    /// schedules (the mini-batch chunk schedule) replay exactly as if the
    /// run had never stopped.  Transports without a counter ignore it.
    fn fast_forward(&mut self, round: usize) {
        let _ = round;
    }
    /// Expel `node` from the roster as a structured death — the reply
    /// guard's escalation for repeat numerical offenders.  The threaded
    /// cluster severs the node's channel; the socket cluster kills the
    /// peer (making it eligible for rejoin/resync); the sequential
    /// cluster has no kill mechanism, so the default is a no-op and the
    /// guard keeps excluding the node round by round instead.
    fn banish(&mut self, node: usize, why: &str) {
        let _ = (node, why);
    }
}

/// Refill a broadcast payload in place when the slot holds the only
/// remaining reference (every worker is done with last round's copy);
/// allocate fresh otherwise.  Returns the payload and whether the buffer
/// was reused — the single `Arc<Vec<f64>>` every node of a round shares.
pub(crate) fn refresh_payload(
    slot: &mut Option<Arc<Vec<f64>>>,
    z: &[f64],
) -> (Arc<Vec<f64>>, bool) {
    if let Some(mut arc) = slot.take() {
        if let Some(buf) = Arc::get_mut(&mut arc) {
            buf.clear();
            buf.extend_from_slice(z);
            *slot = Some(arc.clone());
            return (arc, true);
        }
    }
    let arc = Arc::new(z.to_vec());
    *slot = Some(arc.clone());
    (arc, false)
}

// ---------------------------------------------------------------------
// Sequential (in-process) cluster
// ---------------------------------------------------------------------

/// In-process full-barrier cluster — deterministic, the test baseline.
pub struct SequentialCluster {
    workers: Vec<NodeWorker>,
    net: TransferLedger,
    dim: usize,
    round: usize,
    /// Recycled reply objects whose buffers the next round refills in
    /// place (see [`Cluster::recycle`]).
    spare: Vec<NodeReply>,
}

impl SequentialCluster {
    /// Wrap the workers; `dim` sizes the byte ledger entries.
    pub fn new(workers: Vec<NodeWorker>, dim: usize) -> SequentialCluster {
        SequentialCluster {
            workers,
            net: TransferLedger::default(),
            dim,
            round: 0,
            spare: Vec::new(),
        }
    }
}

impl Cluster for SequentialCluster {
    fn nodes(&self) -> usize {
        self.workers.len()
    }

    fn round(&mut self, z: &[f64]) -> anyhow::Result<Vec<NodeReply>> {
        let bytes = self.dim as u64 * 8;
        let round = self.round;
        self.round += 1;
        let mut replies = Vec::with_capacity(self.workers.len());
        for w in self.workers.iter_mut() {
            self.net.net_down_bytes += bytes;
            let mut rep = self.spare.pop().unwrap_or_else(|| NodeReply {
                node: 0,
                round: 0,
                lag: 0,
                x: Vec::new(),
                u: Vec::new(),
            });
            if rep.x.capacity() >= self.dim && rep.u.capacity() >= self.dim {
                // both reply vectors refill in place — no allocation
                self.net.net_alloc_saved_bytes += 2 * bytes;
            }
            w.round_into_at(round as u64, z, &mut rep.x, &mut rep.u);
            rep.node = w.id;
            rep.round = round;
            rep.lag = 0;
            self.net.net_up_bytes += 2 * bytes;
            replies.push(rep);
        }
        Ok(replies)
    }

    fn loss_value(&mut self) -> anyhow::Result<f64> {
        Ok(self.workers.iter_mut().map(|w| w.loss_value()).sum())
    }

    fn ledger(&mut self) -> TransferLedger {
        let mut total = self.net.clone();
        for w in &self.workers {
            total.merge(&w.ledger());
        }
        total
    }

    fn recycle(&mut self, mut replies: Vec<NodeReply>) {
        self.spare.append(&mut replies);
    }

    fn fast_forward(&mut self, round: usize) {
        self.round = round;
    }

    fn export_warm(&mut self) -> anyhow::Result<Vec<WarmState>> {
        let mut out: Vec<WarmState> = self.workers.iter().map(|w| w.export_warm()).collect();
        out.sort_by_key(|s| s.node);
        Ok(out)
    }

    fn reseed(&mut self, states: &[WarmState], params: BlockParams) -> anyhow::Result<()> {
        for w in self.workers.iter_mut() {
            let ws = states
                .iter()
                .find(|s| s.node == w.id)
                .ok_or_else(|| anyhow::anyhow!("no warm state for node {}", w.id))?;
            w.reseed(ws, params);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Threaded cluster (one OS thread per node; channels as the wire)
// ---------------------------------------------------------------------

enum Command {
    /// Broadcast payload + the coordinator's round counter; the worker
    /// echoes the counter in its reply so the coordinator can discard a
    /// straggler's answer to a round that already timed out.
    Round(Arc<Vec<f64>>, usize),
    Loss,
    Ledger,
    Export,
    /// Full warm-state set (each worker picks its own by id) + penalties.
    Reseed(Arc<Vec<WarmState>>, BlockParams),
}

enum Reply {
    Round(NodeReply),
    Loss(f64),
    Ledger(TransferLedger),
    Warm(Box<WarmState>),
    Reseeded(usize),
    ReseedFailed(usize),
}

/// One OS thread per node with channel Bcast/Collect — the in-process
/// stand-in the benchmarks use.
///
/// A node whose channel is closed (thread panicked, or severed via the
/// [`ThreadedCluster::kill_node`] chaos hook) is pruned from the roster
/// and subsequent rounds degrade to the survivors, mirroring the socket
/// transport's peer-death behavior; only losing *every* node is an error.
pub struct ThreadedCluster {
    /// Per-node command channel; `None` marks a node declared dead.
    senders: Vec<Option<mpsc::Sender<Command>>>,
    replies: mpsc::Receiver<Reply>,
    handles: Vec<std::thread::JoinHandle<()>>,
    net: TransferLedger,
    dim: usize,
    n: usize,
    round: usize,
    /// How long to wait for each query's replies before declaring the
    /// silent nodes dead.
    reply_timeout: Duration,
    /// Broadcast payload reused across rounds (see [`refresh_payload`]).
    payload: Option<Arc<Vec<f64>>>,
}

impl ThreadedCluster {
    /// Spawn one worker thread per node.
    pub fn new(workers: Vec<NodeWorker>, dim: usize) -> ThreadedCluster {
        let n = workers.len();
        let (reply_tx, replies) = mpsc::channel::<Reply>();
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for mut w in workers {
            let (tx, rx) = mpsc::channel::<Command>();
            let out = reply_tx.clone();
            senders.push(Some(tx));
            handles.push(std::thread::spawn(move || {
                while let Ok(cmd) = rx.recv() {
                    let reply = match cmd {
                        Command::Round(z, round) => {
                            let (x, u) = w.round_at(round as u64, &z);
                            Reply::Round(NodeReply {
                                node: w.id,
                                round,
                                lag: 0,
                                x,
                                u,
                            })
                        }
                        Command::Loss => Reply::Loss(w.loss_value()),
                        Command::Ledger => Reply::Ledger(w.ledger()),
                        Command::Export => Reply::Warm(Box::new(w.export_warm())),
                        Command::Reseed(states, params) => {
                            match states.iter().find(|s| s.node == w.id) {
                                Some(ws) => {
                                    w.reseed(ws, params);
                                    Reply::Reseeded(w.id)
                                }
                                None => Reply::ReseedFailed(w.id),
                            }
                        }
                    };
                    if out.send(reply).is_err() {
                        break;
                    }
                }
            }));
        }
        ThreadedCluster {
            senders,
            replies,
            handles,
            net: TransferLedger::default(),
            dim,
            n,
            round: 0,
            reply_timeout: Duration::from_secs(60),
            payload: None,
        }
    }

    /// Override the per-query reply deadline (default 60 s): how long a
    /// round waits for stragglers before declaring them dead.
    pub fn with_reply_timeout(mut self, timeout: Duration) -> ThreadedCluster {
        self.reply_timeout = timeout;
        self
    }

    /// Chaos hook: sever node `node`'s command channel, as if its process
    /// died mid-run.  The next round degrades to the survivors — the
    /// deterministic way to exercise the quorum-degradation path in tests.
    pub fn kill_node(&mut self, node: usize) {
        if let Some(slot) = self.senders.get_mut(node) {
            *slot = None;
        }
    }

    /// Nodes still reachable.
    pub fn live(&self) -> usize {
        self.senders.iter().filter(|s| s.is_some()).count()
    }

    /// Send one command to every live node, pruning nodes whose channel
    /// is closed.  Returns how many sends succeeded.
    fn broadcast<F: Fn() -> Command>(&mut self, make: F, what: &str) -> usize {
        let mut sent = 0;
        for i in 0..self.senders.len() {
            let ok = match &self.senders[i] {
                Some(tx) => tx.send(make()).is_ok(),
                None => continue,
            };
            if ok {
                sent += 1;
            } else {
                eprintln!("[threaded] node {i} is gone; degrading before the {what}");
                self.senders[i] = None;
            }
        }
        sent
    }
}

impl Cluster for ThreadedCluster {
    fn nodes(&self) -> usize {
        self.n
    }

    fn round(&mut self, z: &[f64]) -> anyhow::Result<Vec<NodeReply>> {
        let (payload, reused) = refresh_payload(&mut self.payload, z);
        if reused {
            self.net.net_alloc_saved_bytes += self.dim as u64 * 8;
        }
        let bytes = self.dim as u64 * 8;
        let round = self.round;
        self.round += 1;
        let expected = self.broadcast(|| Command::Round(payload.clone(), round), "round broadcast");
        anyhow::ensure!(expected > 0, "round {round}: every node worker is dead");
        self.net.net_down_bytes += expected as u64 * bytes;
        let mut replies = Vec::with_capacity(expected);
        let deadline = std::time::Instant::now() + self.reply_timeout;
        while replies.len() < expected {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            match self.replies.recv_timeout(deadline - now) {
                Ok(Reply::Round(r)) if r.round == round => {
                    self.net.net_up_bytes += 2 * bytes;
                    replies.push(r);
                }
                // a straggler's answer to a round that already timed out
                Ok(Reply::Round(_)) => continue,
                Ok(_) => anyhow::bail!("protocol violation: non-round reply in round {round}"),
                Err(_) => break,
            }
        }
        if replies.len() < expected {
            // declare the silent nodes dead and degrade to the survivors
            let mut saw = vec![false; self.n];
            for r in &replies {
                if r.node < self.n {
                    saw[r.node] = true;
                }
            }
            for i in 0..self.senders.len() {
                if self.senders[i].is_some() && !saw[i] {
                    eprintln!("[threaded] node {i} never replied to round {round}; degrading");
                    self.senders[i] = None;
                }
            }
        }
        anyhow::ensure!(
            !replies.is_empty(),
            "round {round}: the cluster lost every node"
        );
        replies.sort_by_key(|r| r.node);
        Ok(replies)
    }

    fn loss_value(&mut self) -> anyhow::Result<f64> {
        let expected = self.broadcast(|| Command::Loss, "loss query");
        anyhow::ensure!(expected > 0, "loss query: every node worker is dead");
        let mut total = 0.0;
        let mut got = 0usize;
        let deadline = std::time::Instant::now() + self.reply_timeout;
        while got < expected {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            match self.replies.recv_timeout(deadline - now) {
                Ok(Reply::Loss(v)) => {
                    total += v;
                    got += 1;
                }
                // a straggler's answer to a round that already timed out
                Ok(Reply::Round(_)) => continue,
                Ok(_) => anyhow::bail!("protocol violation: non-loss reply to loss query"),
                Err(_) => break,
            }
        }
        anyhow::ensure!(got > 0, "loss query: no node replied");
        if got < expected {
            eprintln!("[threaded] loss query degraded to {got} of {expected} node(s)");
        }
        Ok(total)
    }

    fn ledger(&mut self) -> TransferLedger {
        // Best-effort: skip dead nodes so a degraded cluster still reports
        // the traffic it actually observed.
        let mut total = self.net.clone();
        let mut expected = 0;
        for tx in self.senders.iter().flatten() {
            if tx.send(Command::Ledger).is_ok() {
                expected += 1;
            }
        }
        for _ in 0..expected {
            match self.replies.recv_timeout(Duration::from_secs(10)) {
                Ok(Reply::Ledger(l)) => total.merge(&l),
                Ok(_) => continue,
                Err(_) => break,
            }
        }
        total
    }

    fn export_warm(&mut self) -> anyhow::Result<Vec<WarmState>> {
        let expected = self.broadcast(|| Command::Export, "warm-state export");
        anyhow::ensure!(expected > 0, "warm-state export: every node worker is dead");
        let mut out = Vec::with_capacity(expected);
        let deadline = std::time::Instant::now() + self.reply_timeout;
        while out.len() < expected {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            match self.replies.recv_timeout(deadline - now) {
                Ok(Reply::Warm(ws)) => out.push(*ws),
                // a straggler's answer to a round that already timed out
                Ok(Reply::Round(_)) => continue,
                Ok(_) => anyhow::bail!("protocol violation: non-warm reply to export"),
                Err(_) => break,
            }
        }
        anyhow::ensure!(!out.is_empty(), "warm-state export: no node replied");
        if out.len() < expected {
            eprintln!(
                "[threaded] warm-state export degraded to {} of {expected} node(s)",
                out.len()
            );
        }
        out.sort_by_key(|s| s.node);
        Ok(out)
    }

    fn reseed(&mut self, states: &[WarmState], params: BlockParams) -> anyhow::Result<()> {
        let shared = Arc::new(states.to_vec());
        let expected = self.broadcast(|| Command::Reseed(shared.clone(), params), "re-seed");
        anyhow::ensure!(expected > 0, "re-seed: every node worker is dead");
        let mut got = 0usize;
        let deadline = std::time::Instant::now() + self.reply_timeout;
        while got < expected {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            match self.replies.recv_timeout(deadline - now) {
                Ok(Reply::Reseeded(_)) => got += 1,
                Ok(Reply::ReseedFailed(node)) => {
                    anyhow::bail!("no warm state for node {node}")
                }
                // a straggler's answer to a round that already timed out
                Ok(Reply::Round(_)) => continue,
                Ok(_) => anyhow::bail!("protocol violation: non-reseed reply to re-seed"),
                Err(_) => break,
            }
        }
        anyhow::ensure!(got > 0, "re-seed: no node replied");
        Ok(())
    }

    fn fast_forward(&mut self, round: usize) {
        self.round = round;
    }

    fn banish(&mut self, node: usize, why: &str) {
        eprintln!("[threaded] node {node} banished: {why}");
        self.kill_node(node);
    }
}

impl Drop for ThreadedCluster {
    fn drop(&mut self) {
        self.senders.clear(); // closes channels; workers exit their loops
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::{NativeBackend, SolveMode};
    use crate::data::{FeaturePlan, SyntheticSpec};
    use crate::losses::Squared;

    fn make_workers(nodes: usize) -> (Vec<NodeWorker>, usize) {
        let ds = SyntheticSpec::regression(12, 40 * nodes, nodes).generate();
        let plan = FeaturePlan::new(12, 2, 512);
        let params = BlockParams {
            rho_l: 2.0,
            rho_c: 1.0,
            reg: 1.0 / (nodes as f64 * 10.0) + 1.0,
        };
        let workers = ds
            .shards
            .iter()
            .enumerate()
            .map(|(i, shard)| {
                let be = NativeBackend::new(shard, &plan, Box::new(Squared), SolveMode::Direct);
                NodeWorker::new(i, LocalProx::new(Box::new(be), plan.clone(), 1), params, 10)
            })
            .collect();
        (workers, 12)
    }

    #[test]
    fn threaded_matches_sequential() {
        let (w1, dim) = make_workers(3);
        let (w2, _) = make_workers(3);
        let mut seq = SequentialCluster::new(w1, dim);
        let mut thr = ThreadedCluster::new(w2, dim);
        let z = vec![0.05; dim];
        for k in 0..3 {
            let a = seq.round(&z).unwrap();
            let b = thr.round(&z).unwrap();
            for (ra, rb) in a.iter().zip(&b) {
                assert_eq!(ra.node, rb.node);
                assert_eq!(ra.round, k);
                assert_eq!(rb.round, k);
                for (x, y) in ra.x.iter().zip(&rb.x) {
                    assert!((x - y).abs() < 1e-12, "{x} vs {y}");
                }
                for (x, y) in ra.u.iter().zip(&rb.u) {
                    assert!((x - y).abs() < 1e-12);
                }
            }
        }
        assert!((seq.loss_value().unwrap() - thr.loss_value().unwrap()).abs() < 1e-9);
    }

    #[test]
    fn byte_ledger_counts_protocol_volume() {
        let (w, dim) = make_workers(2);
        let mut seq = SequentialCluster::new(w, dim);
        let z = vec![0.0; dim];
        seq.round(&z).unwrap();
        seq.round(&z).unwrap();
        let l = seq.ledger();
        // 2 rounds x 2 nodes x dim x 8 bytes down; twice that up
        assert_eq!(l.net_down_bytes, 2 * 2 * dim as u64 * 8);
        assert_eq!(l.net_up_bytes, 2 * 2 * 2 * dim as u64 * 8);
    }

    #[test]
    fn recycled_reply_buffers_and_payload_are_reused() {
        let (w, dim) = make_workers(2);
        let mut seq = SequentialCluster::new(w, dim);
        let z = vec![0.0; dim];
        let r1 = seq.round(&z).unwrap();
        assert_eq!(
            seq.ledger().net_alloc_saved_bytes,
            0,
            "first round has no buffers to reuse"
        );
        seq.recycle(r1);
        let r2 = seq.round(&z).unwrap();
        // 2 nodes x (x + u) x dim x 8 bytes refilled in place
        assert_eq!(seq.ledger().net_alloc_saved_bytes, 2 * 2 * dim as u64 * 8);
        assert!(r2.iter().all(|r| r.x.len() == dim && r.u.len() == dim));

        // the threaded transport reuses the one shared broadcast Arc:
        // workers drop their clones before replying, so round 2 refills it
        let (w, dim) = make_workers(2);
        let mut thr = ThreadedCluster::new(w, dim);
        thr.round(&z).unwrap();
        thr.round(&z).unwrap();
        assert_eq!(thr.ledger().net_alloc_saved_bytes, dim as u64 * 8);
    }

    #[test]
    fn threaded_degrades_when_a_node_is_killed() {
        let (w, dim) = make_workers(3);
        let mut thr = ThreadedCluster::new(w, dim).with_reply_timeout(Duration::from_secs(5));
        let z = vec![0.0; dim];
        assert_eq!(thr.round(&z).unwrap().len(), 3);
        thr.kill_node(1);
        assert_eq!(thr.live(), 2);
        let r = thr.round(&z).unwrap();
        assert_eq!(r.len(), 2, "dead node must degrade, not abort");
        assert_eq!((r[0].node, r[1].node), (0, 2));
        // degraded queries keep working over the survivors
        assert!(thr.loss_value().unwrap().is_finite());
        assert_eq!(thr.export_warm().unwrap().len(), 2);
        thr.kill_node(0);
        thr.kill_node(2);
        assert!(thr.round(&z).is_err(), "zero survivors must be an error");
    }

    #[test]
    fn dual_update_follows_consensus_protocol() {
        let (mut w, dim) = {
            let (mut ws, d) = make_workers(1);
            (ws.remove(0), d)
        };
        let z0 = vec![0.0; dim];
        let (x1, u0) = w.round(&z0);
        assert!(u0.iter().all(|&v| v == 0.0), "first-round dual must be 0");
        let z1 = vec![0.1; dim];
        let (_x2, u1) = w.round(&z1);
        // u1 = u0 + x1 - z1
        for i in 0..dim {
            assert!((u1[i] - (x1[i] - z1[i])).abs() < 1e-12);
        }
    }
}

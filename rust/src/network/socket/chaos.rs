//! Deterministic wire-fault injection: the engine behind `psfit chaos`.
//!
//! [`ChaosProxy`] sits between a coordinator and one worker address and
//! forwards the PSFW byte stream *frame by frame*, injecting faults —
//! dropped connections, delayed / split / truncated frames, corrupted
//! checksums — according to a seeded [`ChaosSpec`].  Every fault decision
//! is a pure function of `(spec.seed, connection index, direction, frame
//! index)`, so a fixed seed reproduces the identical fault schedule on
//! every run: the `psfit chaos` harness relies on this to run the same
//! fault scenario twice and assert both runs converge to the clean run's
//! support.
//!
//! The proxy is handshake-aware: the first 8 bytes in each direction (the
//! `PSFW` magic + version) pass through untouched, and everything after is
//! parsed as `len | payload | checksum` frames, so faults land on frame
//! boundaries exactly where the real failure modes live (a corrupted
//! checksum exercises the decoder's integrity path, a truncated frame the
//! short-read path, a dropped connection the peer-death path).

use std::io::{Read, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::network::socket::wire::{fnv1a, MAX_FRAME};
use crate::network::socket::{connect, Endpoint, SocketListener, SocketStream};
use crate::util::rng::Rng;

/// A seeded fault schedule for one [`ChaosProxy`].
///
/// The five probabilities are per-frame and *mutually exclusive* (a frame
/// suffers at most one fault), so they must sum to at most `1.0`.
/// Parsed from the compact form `psfit chaos --faults` accepts, e.g.
/// `"drop=0.02,corrupt=0.02,delay=0.1:5,split=0.1,truncate=0.01"`.
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosSpec {
    /// Probability a frame kills the connection (both directions severed
    /// before the frame is forwarded).
    pub drop: f64,
    /// Probability a frame is forwarded truncated (length prefix + half
    /// the body) and the connection then severed — a mid-write crash.
    pub truncate: f64,
    /// Probability a frame's checksum trailer is corrupted in flight.
    pub corrupt: f64,
    /// Probability a frame is written in two separately-flushed halves —
    /// exercises short-read reassembly on the receiver.
    pub split: f64,
    /// Probability a frame is delayed before forwarding.
    pub delay: f64,
    /// Upper bound (milliseconds) on an injected delay.
    pub delay_ms: u64,
    /// Schedule seed: same seed, same faults, every run.
    pub seed: u64,
}

impl Default for ChaosSpec {
    fn default() -> Self {
        ChaosSpec {
            drop: 0.0,
            truncate: 0.0,
            corrupt: 0.0,
            split: 0.0,
            delay: 0.0,
            delay_ms: 5,
            seed: 0xC4A05,
        }
    }
}

impl ChaosSpec {
    /// Parse the compact `key=value,...` form.  Keys: `drop`, `truncate`,
    /// `corrupt`, `split`, `seed`, and `delay` (either `delay=p` or
    /// `delay=p:max_ms`).  Empty input is the all-quiet spec.
    pub fn parse(s: &str) -> anyhow::Result<ChaosSpec> {
        let mut spec = ChaosSpec::default();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("chaos spec `{part}` is not key=value"))?;
            let prob = |v: &str| -> anyhow::Result<f64> {
                let p: f64 = v
                    .parse()
                    .map_err(|_| anyhow::anyhow!("chaos spec `{key}`: `{v}` is not a number"))?;
                anyhow::ensure!(
                    (0.0..=1.0).contains(&p),
                    "chaos spec `{key}`: probability {p} outside [0, 1]"
                );
                Ok(p)
            };
            match key {
                "drop" => spec.drop = prob(value)?,
                "truncate" => spec.truncate = prob(value)?,
                "corrupt" => spec.corrupt = prob(value)?,
                "split" => spec.split = prob(value)?,
                "delay" => match value.split_once(':') {
                    Some((p, ms)) => {
                        spec.delay = prob(p)?;
                        spec.delay_ms = ms.parse().map_err(|_| {
                            anyhow::anyhow!("chaos spec `delay`: `{ms}` is not a millisecond count")
                        })?;
                    }
                    None => spec.delay = prob(value)?,
                },
                "seed" => {
                    spec.seed = value
                        .parse()
                        .map_err(|_| anyhow::anyhow!("chaos spec `seed`: `{value}` is not a u64"))?
                }
                other => anyhow::bail!("unknown chaos spec key `{other}`"),
            }
        }
        let total = spec.drop + spec.truncate + spec.corrupt + spec.split + spec.delay;
        anyhow::ensure!(
            total <= 1.0 + 1e-12,
            "chaos fault probabilities sum to {total}, which exceeds 1"
        );
        Ok(spec)
    }

    /// The fault (if any) frame number `frame` suffers on connection
    /// `conn` in direction `dir` (0 = client→upstream, 1 = upstream→
    /// client).  Pure in its arguments — this *is* the fault schedule.
    pub fn fault_for(&self, conn: u64, dir: u8, frame: u64) -> Fault {
        let mut key = [0u8; 25];
        key[..8].copy_from_slice(&self.seed.to_le_bytes());
        key[8..16].copy_from_slice(&conn.to_le_bytes());
        key[16] = dir;
        key[17..].copy_from_slice(&frame.to_le_bytes());
        let mut rng = Rng::seed_from(fnv1a(&key));
        let draw = rng.uniform();
        let mut edge = self.drop;
        if draw < edge {
            return Fault::Drop;
        }
        edge += self.truncate;
        if draw < edge {
            return Fault::Truncate;
        }
        edge += self.corrupt;
        if draw < edge {
            return Fault::Corrupt;
        }
        edge += self.split;
        if draw < edge {
            return Fault::Split;
        }
        edge += self.delay;
        if draw < edge {
            return Fault::Delay(1 + rng.below(self.delay_ms.max(1)));
        }
        Fault::Forward
    }

    /// FNV-1a digest of the fault schedule's first `frames_per_conn`
    /// decisions on the first `conns` connections (both directions) — the
    /// value `psfit chaos` prints so two runs can prove they faced the
    /// same schedule.
    pub fn schedule_fingerprint(&self, conns: u64, frames_per_conn: u64) -> u64 {
        let mut codes = Vec::with_capacity((conns * 2 * frames_per_conn) as usize);
        for conn in 0..conns {
            for dir in 0..2u8 {
                for frame in 0..frames_per_conn {
                    codes.push(match self.fault_for(conn, dir, frame) {
                        Fault::Forward => 0u8,
                        Fault::Drop => 1,
                        Fault::Truncate => 2,
                        Fault::Corrupt => 3,
                        Fault::Split => 4,
                        Fault::Delay(_) => 5,
                    });
                }
            }
        }
        fnv1a(&codes)
    }
}

impl std::fmt::Display for ChaosSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "drop={},truncate={},corrupt={},split={},delay={}:{},seed={}",
            self.drop, self.truncate, self.corrupt, self.split, self.delay, self.delay_ms, self.seed
        )
    }
}

/// One frame's fate under a [`ChaosSpec`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Forward untouched.
    Forward,
    /// Sever the connection without forwarding.
    Drop,
    /// Forward the length prefix and half the body, then sever.
    Truncate,
    /// Flip a byte of the checksum trailer and forward.
    Corrupt,
    /// Forward in two separately-flushed writes.
    Split,
    /// Sleep this many milliseconds, then forward.
    Delay(u64),
}

/// A fault-injecting TCP/Unix proxy in front of one worker address.
///
/// Spawning binds an ephemeral localhost port; point the coordinator's
/// roster entry at [`ChaosProxy::addr`] instead of the worker.  The
/// accept loop lives for the rest of the process (like
/// [`crate::network::socket::spawn_local_worker`]), and every accepted
/// connection — including rejoin redials after an injected drop — gets
/// the next connection index in the schedule.
pub struct ChaosProxy {
    addr: String,
    injected: Arc<AtomicU64>,
}

impl ChaosProxy {
    /// Stand up a proxy forwarding to `upstream` under `spec`.
    pub fn spawn(upstream: &str, spec: &ChaosSpec) -> anyhow::Result<ChaosProxy> {
        let listener = SocketListener::bind(&Endpoint::parse("127.0.0.1:0"))?;
        let addr = listener.local_endpoint();
        let injected = Arc::new(AtomicU64::new(0));
        let counter = Arc::clone(&injected);
        let spec = spec.clone();
        let upstream = upstream.to_string();
        std::thread::Builder::new()
            .name("psfit-chaos".into())
            .spawn(move || {
                let mut conn = 0u64;
                while let Ok(client) = listener.accept() {
                    let up = match connect(
                        &Endpoint::parse(&upstream),
                        Duration::from_millis(2000),
                        2,
                    ) {
                        Ok(s) => s,
                        Err(e) => {
                            eprintln!("[chaos] upstream {upstream} unreachable: {e}");
                            client.shutdown();
                            continue;
                        }
                    };
                    if let Err(e) = splice(client, up, &spec, conn, &counter) {
                        eprintln!("[chaos] connection {conn}: {e}");
                    }
                    conn += 1;
                }
            })
            .map_err(|e| anyhow::anyhow!("cannot spawn chaos proxy thread: {e}"))?;
        Ok(ChaosProxy { addr, injected })
    }

    /// The proxy's listen address — use this as the worker address.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Faults actually injected so far (frames seen × schedule hits).
    pub fn injected_faults(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }
}

/// Wire `client ⇄ upstream` through two pump threads, one per direction.
fn splice(
    client: SocketStream,
    upstream: SocketStream,
    spec: &ChaosSpec,
    conn: u64,
    injected: &Arc<AtomicU64>,
) -> anyhow::Result<()> {
    let c2 = client.try_clone()?;
    let u2 = upstream.try_clone()?;
    for (from, to, dir) in [(client, upstream, 0u8), (u2, c2, 1u8)] {
        let spec = spec.clone();
        let injected = Arc::clone(injected);
        std::thread::Builder::new()
            .name(format!("psfit-chaos-{conn}-{dir}"))
            .spawn(move || pump(from, to, &spec, conn, dir, &injected))
            .map_err(|e| anyhow::anyhow!("cannot spawn pump thread: {e}"))?;
    }
    Ok(())
}

/// Forward one direction frame-by-frame, applying the schedule.  Any read
/// or write failure — including an injected sever from the other
/// direction's pump — ends the pump and severs both underlying sockets.
fn pump(
    mut from: SocketStream,
    mut to: SocketStream,
    spec: &ChaosSpec,
    conn: u64,
    dir: u8,
    injected: &Arc<AtomicU64>,
) {
    // The 8-byte handshake passes through verbatim: faulting it would test
    // version negotiation, not the frame protocol.
    let mut hs = [0u8; 8];
    if from.read_exact(&mut hs).is_err() || to.write_all(&hs).is_err() || to.flush().is_err() {
        sever(&from, &to);
        return;
    }
    let mut frame = 0u64;
    loop {
        let mut lenb = [0u8; 4];
        if from.read_exact(&mut lenb).is_err() {
            break;
        }
        let len = u32::from_le_bytes(lenb) as usize;
        if len == 0 || len > MAX_FRAME {
            break; // malformed upstream bytes: sever rather than forward junk
        }
        let mut body = vec![0u8; len + 8]; // payload + checksum trailer
        if from.read_exact(&mut body).is_err() {
            break;
        }
        let fault = spec.fault_for(conn, dir, frame);
        frame += 1;
        if fault != Fault::Forward {
            injected.fetch_add(1, Ordering::Relaxed);
        }
        let forwarded = match fault {
            Fault::Forward => forward(&mut to, &lenb, &body),
            Fault::Drop => break,
            Fault::Truncate => {
                let _ = to.write_all(&lenb);
                let _ = to.write_all(&body[..len / 2]);
                let _ = to.flush();
                break;
            }
            Fault::Corrupt => {
                let last = body.len() - 1;
                body[last] ^= 0xFF;
                forward(&mut to, &lenb, &body)
            }
            Fault::Split => {
                let mid = body.len() / 2;
                to.write_all(&lenb)
                    .and_then(|()| to.write_all(&body[..mid]))
                    .and_then(|()| to.flush())
                    .and_then(|()| {
                        std::thread::sleep(Duration::from_millis(1));
                        to.write_all(&body[mid..])
                    })
                    .and_then(|()| to.flush())
            }
            Fault::Delay(ms) => {
                std::thread::sleep(Duration::from_millis(ms));
                forward(&mut to, &lenb, &body)
            }
        };
        if forwarded.is_err() {
            break;
        }
    }
    sever(&from, &to);
}

/// Write one intact frame.
fn forward(to: &mut SocketStream, lenb: &[u8; 4], body: &[u8]) -> std::io::Result<()> {
    to.write_all(lenb)?;
    to.write_all(body)?;
    to.flush()
}

/// Shut both sockets down so the opposite pump and both endpoints see the
/// connection die — an injected drop must look like a real crash.
fn sever(a: &SocketStream, b: &SocketStream) {
    a.shutdown();
    b.shutdown();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::socket::wire::{self, WireCommand};
    use crate::network::socket::{spawn_local_worker, Endpoint};

    #[test]
    fn spec_parses_the_compact_form_and_rejects_nonsense() {
        let s = ChaosSpec::parse("drop=0.05, delay=0.1:20, corrupt=0.02,seed=9").unwrap();
        assert_eq!(s.drop, 0.05);
        assert_eq!(s.delay, 0.1);
        assert_eq!(s.delay_ms, 20);
        assert_eq!(s.corrupt, 0.02);
        assert_eq!(s.seed, 9);
        assert_eq!(ChaosSpec::parse("").unwrap(), ChaosSpec::default());
        // display round-trips through parse
        assert_eq!(ChaosSpec::parse(&s.to_string()).unwrap(), s);
        for bad in [
            "drop",
            "drop=1.5",
            "warp=0.1",
            "delay=0.1:fast",
            "drop=0.6,corrupt=0.6",
        ] {
            assert!(ChaosSpec::parse(bad).is_err(), "`{bad}` should be rejected");
        }
    }

    #[test]
    fn fault_schedule_is_a_pure_function_of_the_seed() {
        let spec = ChaosSpec::parse("drop=0.1,corrupt=0.2,split=0.2,delay=0.2:8,seed=7").unwrap();
        for conn in 0..4 {
            for dir in 0..2 {
                for frame in 0..64 {
                    assert_eq!(
                        spec.fault_for(conn, dir, frame),
                        spec.clone().fault_for(conn, dir, frame)
                    );
                }
            }
        }
        assert_eq!(
            spec.schedule_fingerprint(8, 64),
            spec.schedule_fingerprint(8, 64)
        );
        let reseeded = ChaosSpec { seed: 8, ..spec.clone() };
        assert_ne!(
            spec.schedule_fingerprint(8, 64),
            reseeded.schedule_fingerprint(8, 64),
            "different seeds must give different schedules"
        );
        // the all-quiet spec never faults
        let quiet = ChaosSpec::default();
        for frame in 0..64 {
            assert_eq!(quiet.fault_for(0, 0, frame), Fault::Forward);
        }
        // a certain fault always fires
        let all = ChaosSpec { corrupt: 1.0, ..ChaosSpec::default() };
        assert_eq!(all.fault_for(3, 1, 17), Fault::Corrupt);
    }

    #[test]
    fn a_quiet_proxy_is_transparent() {
        let worker = spawn_local_worker().unwrap();
        let proxy = ChaosProxy::spawn(&worker, &ChaosSpec::default()).unwrap();
        let mut s = connect(
            &Endpoint::parse(proxy.addr()),
            Duration::from_secs(2),
            3,
        )
        .unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        wire::client_handshake(&mut s).unwrap();
        // Loss before Setup draws a clean protocol error through the proxy
        wire::write_frame(&mut s, &WireCommand::Loss).unwrap();
        match wire::read_frame(&mut s).unwrap() {
            Some((WireCommand::Error { message }, _)) => {
                assert!(message.contains("before setup"), "{message}")
            }
            other => panic!("expected error frame through the proxy, got {other:?}"),
        }
        assert_eq!(proxy.injected_faults(), 0);
    }

    #[test]
    fn a_corrupting_proxy_breaks_the_stream_cleanly() {
        let worker = spawn_local_worker().unwrap();
        let spec = ChaosSpec { corrupt: 1.0, ..ChaosSpec::default() };
        let proxy = ChaosProxy::spawn(&worker, &spec).unwrap();
        let mut s = connect(
            &Endpoint::parse(proxy.addr()),
            Duration::from_secs(2),
            3,
        )
        .unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        wire::client_handshake(&mut s).unwrap();
        wire::write_frame(&mut s, &WireCommand::Loss).unwrap();
        // the worker sees a corrupted checksum and kills the session; we
        // observe either a clean close or an error, never a hang or panic
        match wire::read_frame(&mut s) {
            Ok(None) | Err(_) => {}
            Ok(Some((cmd, _))) => panic!("corrupted frame still produced a reply: {cmd:?}"),
        }
        assert!(proxy.injected_faults() >= 1);
    }
}
